"""L2 — the gated JAX model (build-time only; lowered once by aot.py).

The central trick that keeps Python off the runtime path: every layer whose
removal LayerMerge searches over is *gated* by a runtime input, so a single
AOT-compiled HLO graph represents **every** (A, C) configuration of the
paper's Problem (2):

    conv   (l reducible):  y = gc[l] * (conv(x, w_l) + b_l) + (1 - gc[l]) * x
    act    (l < L):        z = ga[l] * sigma(y)            + (1 - ga[l]) * y
    gnorm  (ddpm):         z = gn[l] * GN(y)               + (1 - gn[l]) * y

With gates in {0,1} this is exactly the paper's sigma_{A,l} / f_{C,theta,l}
replacement (Sec. 3.1).  The Rust coordinator therefore evaluates and
fine-tunes arbitrary table entries (A~_ij, C~_ijk of Eq. 3/4) by feeding
gate vectors — zero recompilation in the table-construction hot loop.

Parameters travel as ONE flat f32 vector; ``specs.ParamEntry`` gives every
tensor's offset so Rust can slice/merge without Python.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from . import specs
from .kernels import conv as pallas_conv

MOMENTUM = 0.9
WEIGHT_DECAY = 5e-4
DISTILL_ALPHA = 0.5
DISTILL_TEMP = 2.0


# ---------------------------------------------------------------------------
# Parameter handling
# ---------------------------------------------------------------------------


def unflatten(spec: specs.Spec, flat):
    """Slice the flat parameter vector into named tensors."""
    out = {}
    for p in spec.params:
        out[p.name] = lax.dynamic_slice(flat, (p.offset,), (p.size,)).reshape(p.shape)
    return out


def init_params(spec: specs.Spec, seed: int = 0):
    """He-init (zero biases, unit scales); returns the flat f32 vector."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for p in spec.params:
        key, sub = jax.random.split(key)
        if p.name.endswith(".b") or p.name.endswith(".bias"):
            chunks.append(jnp.zeros((p.size,), jnp.float32))
        elif p.name.endswith(".scale"):
            chunks.append(jnp.ones((p.size,), jnp.float32))
        elif len(p.shape) == 4:
            cout, cin, kh, kw = p.shape
            std = math.sqrt(2.0 / (cin * kh * kw))
            w = jax.random.normal(sub, p.shape, jnp.float32) * std
            chunks.append(w.reshape(-1))
        else:
            std = math.sqrt(1.0 / p.shape[0])
            w = jax.random.normal(sub, p.shape, jnp.float32) * std
            chunks.append(w.reshape(-1))
    return jnp.concatenate(chunks)


# ---------------------------------------------------------------------------
# Layer primitives
# ---------------------------------------------------------------------------


def conv2d(x, w, stride: int, depthwise: bool):
    """SAME conv, NHWC activations, OIHW weights."""
    groups = x.shape[-1] if depthwise else 1
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "OIHW", "NHWC"),
        feature_group_count=groups)


def act_fn(kind: str, x):
    if kind == "swish":
        return x * jax.nn.sigmoid(x)
    return jax.nn.relu(x)  # "relu" and the act added after merged layers


def group_norm(x, scale, bias, groups: int, eps: float = 1e-5):
    b, h, w, c = x.shape
    xg = x.reshape(b, h, w, groups, c // groups)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * lax.rsqrt(var + eps)
    return xg.reshape(b, h, w, c) * scale + bias


def attention(x, wqkv, wout):
    """Single-head self-attention over spatial positions, residual."""
    b, h, w, c = x.shape
    seq = x.reshape(b, h * w, c)
    qkv = seq @ wqkv
    q, k, v = jnp.split(qkv, 3, axis=-1)
    att = jax.nn.softmax(q @ jnp.swapaxes(k, 1, 2) / math.sqrt(c), axis=-1)
    out = (att @ v) @ wout
    return x + out.reshape(b, h, w, c)


def upsample2x(x):
    return jnp.repeat(jnp.repeat(x, 2, axis=1), 2, axis=2)


def time_embedding(t, dim: int):
    """Sinusoidal timestep embedding, t: f32[B]."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / half)
    ang = t[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Gated forward pass
# ---------------------------------------------------------------------------


def gated_forward(spec: specs.Spec, flat, ga, gc, gn, x, t=None,
                  use_pallas: bool = False):
    """Run the gated network.

    Returns (output, feats): logits + penultimate features for classifiers,
    predicted noise + None for the diffusion model.

    ``ga``, ``gc``, ``gn`` are f32[L] gate vectors (1.0 = keep the original
    layer, 0.0 = replace by identity).  ``use_pallas`` routes the stem conv
    through the L1 Pallas kernel so it lowers into the same HLO (DESIGN §3).
    """
    P = unflatten(spec, flat)
    temb = None
    if spec.task == "diffusion":
        temb = time_embedding(t, spec.time_dim)
        temb = act_fn("swish", temb @ P["temb.w1"] + P["temb.b1"])

    stash = {}
    boundary = {0: x}  # boundary[i] = feature map entering conv i+1
    cur = x
    for c in spec.convs:
        li = c.idx - 1
        if c.concat_from is not None:
            cur = jnp.concatenate([cur, stash[c.concat_from]], axis=-1)
        if c.time_bias:
            tb = temb @ P[f"temb{c.idx}.w"] + P[f"temb{c.idx}.b"]
            cur = cur + tb[:, None, None, :]
        w = P[f"conv{c.idx}.w"]
        b = P[f"conv{c.idx}.b"]
        if use_pallas and c.idx == 1:
            y = pallas_conv.conv2d_same(cur, w, c.stride, c.depthwise) + b
        else:
            y = conv2d(cur, w, c.stride, c.depthwise) + b
        if c.conv_gated:
            g = gc[li]
            cur = g * y + (1.0 - g) * cur
        else:
            cur = y
        if c.gn:
            gng = gn[li]
            gy = group_norm(cur, P[f"gn{c.idx}.scale"], P[f"gn{c.idx}.bias"],
                            c.gn_groups)
            cur = gng * gy + (1.0 - gng) * cur
        if c.add_from is not None:
            skip = boundary[c.add_from - 1]
            if c.add_proj is not None:
                pw = P[f"proj{c.add_from}.w"]
                pb = P[f"proj{c.add_from}.b"]
                skip = conv2d(skip, pw, c.add_proj["stride"], False) + pb
            cur = cur + skip
        if c.act != "none" or c.act_gated:
            g = ga[li] if c.act_gated else (0.0 if c.act == "none" else 1.0)
            cur = g * act_fn(c.act if c.act != "none" else "relu", cur) \
                + (1.0 - g) * cur
        if c.stash_as is not None:
            stash[c.stash_as] = cur
        if c.barrier_reason == "attention":
            cur = attention(cur, P["attn.qkv.w"], P["attn.out.w"])
        if c.barrier_reason == "upsample":
            cur = upsample2x(cur)
        boundary[c.idx] = cur

    if spec.task == "classify":
        feats = cur.mean(axis=(1, 2))
        logits = feats @ P["head.w"] + P["head.b"]
        return logits, feats
    return cur, None


# ---------------------------------------------------------------------------
# Losses / steps (each returns a tuple — lowered with return_tuple=True)
# ---------------------------------------------------------------------------


def _cls_loss(spec, flat, ga, gc, gn, x, y1h):
    logits, _ = gated_forward(spec, flat, ga, gc, gn, x)
    logp = jax.nn.log_softmax(logits)
    loss = -(y1h * logp).sum(axis=-1).mean()
    acc = (jnp.argmax(logits, -1) == jnp.argmax(y1h, -1)).astype(jnp.float32).mean()
    return loss, acc


def _diff_loss(spec, flat, ga, gc, gn, x0, eps, t, abar):
    """Denoising loss on x_t = sqrt(abar) x0 + sqrt(1-abar) eps."""
    sq = jnp.sqrt(abar)[:, None, None, None]
    sq1 = jnp.sqrt(1.0 - abar)[:, None, None, None]
    xt = sq * x0 + sq1 * eps
    pred, _ = gated_forward(spec, flat, ga, gc, gn, xt, t)
    loss = jnp.mean((pred - eps) ** 2)
    return loss, -loss  # "acc" slot carries negative diffusion loss


def loss_eval(spec):
    if spec.task == "classify":
        def f(flat, ga, gc, gn, x, y1h):
            return _cls_loss(spec, flat, ga, gc, gn, x, y1h)
    else:
        def f(flat, ga, gc, gn, x0, eps, t, abar):
            return _diff_loss(spec, flat, ga, gc, gn, x0, eps, t, abar)
    return f


def _clip(g, max_norm=1.0):
    """Global-norm gradient clipping — keeps the norm-free nets stable
    across every gate configuration the table builder visits."""
    n = jnp.sqrt(jnp.sum(g * g) + 1e-12)
    return g * jnp.minimum(1.0, max_norm / n)


def train_step(spec):
    """One SGD-with-momentum step on the gated network."""
    if spec.task == "classify":
        def f(flat, mom, ga, gc, gn, x, y1h, lr):
            (loss, acc), g = jax.value_and_grad(
                lambda p: _cls_loss(spec, p, ga, gc, gn, x, y1h),
                has_aux=True)(flat)
            g = _clip(g) + WEIGHT_DECAY * flat
            mom2 = MOMENTUM * mom + g
            return (flat - lr * mom2, mom2, loss, acc)
    else:
        def f(flat, mom, ga, gc, gn, x0, eps, t, abar, lr):
            (loss, acc), g = jax.value_and_grad(
                lambda p: _diff_loss(spec, p, ga, gc, gn, x0, eps, t, abar),
                has_aux=True)(flat)
            mom2 = MOMENTUM * mom + _clip(g)
            return (flat - lr * mom2, mom2, loss, acc)
    return f


def distill_step(spec):
    """KD fine-tuning step (Hinton et al. 2014); teacher = pristine net."""
    ones = jnp.ones((spec.L,), jnp.float32)

    def f(tflat, flat, mom, ga, gc, gn, x, y1h, lr):
        tlogits, _ = gated_forward(spec, tflat, ones, ones, ones, x)
        tprob = jax.nn.softmax(tlogits / DISTILL_TEMP)

        def loss_fn(p):
            logits, _ = gated_forward(spec, p, ga, gc, gn, x)
            logp = jax.nn.log_softmax(logits)
            ce = -(y1h * logp).sum(-1).mean()
            logps = jax.nn.log_softmax(logits / DISTILL_TEMP)
            kd = -(tprob * logps).sum(-1).mean() * DISTILL_TEMP ** 2
            acc = (jnp.argmax(logits, -1) == jnp.argmax(y1h, -1)) \
                .astype(jnp.float32).mean()
            return (1 - DISTILL_ALPHA) * ce + DISTILL_ALPHA * kd, acc

        (loss, acc), g = jax.value_and_grad(loss_fn, has_aux=True)(flat)
        g = _clip(g) + WEIGHT_DECAY * flat
        mom2 = MOMENTUM * mom + g
        return (flat - lr * mom2, mom2, loss, acc)

    return f


def distill_cross(teacher_spec, student_spec):
    """KD with a *different* (smaller) student — paper Table 10 baseline."""
    tones = jnp.ones((teacher_spec.L,), jnp.float32)
    sones = jnp.ones((student_spec.L,), jnp.float32)

    def f(tflat, flat, mom, x, y1h, lr):
        tlogits, _ = gated_forward(teacher_spec, tflat, tones, tones, tones, x)
        tprob = jax.nn.softmax(tlogits / DISTILL_TEMP)

        def loss_fn(p):
            logits, _ = gated_forward(student_spec, p, sones, sones, sones, x)
            logp = jax.nn.log_softmax(logits)
            ce = -(y1h * logp).sum(-1).mean()
            logps = jax.nn.log_softmax(logits / DISTILL_TEMP)
            kd = -(tprob * logps).sum(-1).mean() * DISTILL_TEMP ** 2
            acc = (jnp.argmax(logits, -1) == jnp.argmax(y1h, -1)) \
                .astype(jnp.float32).mean()
            return (1 - DISTILL_ALPHA) * ce + DISTILL_ALPHA * kd, acc

        (loss, acc), g = jax.value_and_grad(loss_fn, has_aux=True)(flat)
        g = _clip(g) + WEIGHT_DECAY * flat
        mom2 = MOMENTUM * mom + g
        return (flat - lr * mom2, mom2, loss, acc)

    return f


def embed(spec):
    """Penultimate features — the FDD embedder (classifiers only)."""
    def f(flat, ga, gc, gn, x):
        _, feats = gated_forward(spec, flat, ga, gc, gn, x)
        return (feats,)
    return f


def sample_step(spec):
    """One DDIM step (Song et al. 2021); the schedule lives in Rust."""
    def f(flat, ga, gc, gn, xt, t, abar_t, abar_prev):
        eps, _ = gated_forward(spec, flat, ga, gc, gn, xt, t)
        sq = jnp.sqrt(abar_t)[:, None, None, None]
        sq1 = jnp.sqrt(1.0 - abar_t)[:, None, None, None]
        x0 = jnp.clip((xt - sq1 * eps) / sq, -1.0, 1.0)
        sp = jnp.sqrt(abar_prev)[:, None, None, None]
        sp1 = jnp.sqrt(1.0 - abar_prev)[:, None, None, None]
        return (sp * x0 + sp1 * eps,)
    return f


def fwd(spec, use_pallas: bool = False):
    if spec.task == "classify":
        def f(flat, ga, gc, gn, x):
            logits, _ = gated_forward(spec, flat, ga, gc, gn, x,
                                      use_pallas=use_pallas)
            return (logits,)
    else:
        def f(flat, ga, gc, gn, x, t):
            out, _ = gated_forward(spec, flat, ga, gc, gn, x, t,
                                   use_pallas=use_pallas)
            return (out,)
    return f
