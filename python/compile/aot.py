"""AOT compile path: lower every artifact the Rust coordinator needs.

Run once by ``make artifacts``; Python never executes after this.  The
interchange format is **HLO text**, not serialized HloModuleProto — jax
>= 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Artifact inventory (consumed by rust/src/runtime + exec + train):

  specs/<model>.spec.json      network IR (single source of truth)
  <model>/init.bin             flat f32 init parameters (little-endian)
  <model>/fwd.hlo.txt          gated forward
  <model>/loss_eval.hlo.txt    gated loss + metric
  <model>/train_step.hlo.txt   gated SGD-momentum step
  <model>/distill_step.hlo.txt gated KD step            (classify)
  <model>/embed.hlo.txt        penultimate features     (resnetish: FDD)
  <model>/sample_step.hlo.txt  one DDIM step            (diffusion)
  conv/<sig>.<variant>.hlo.txt merged-conv modules for the latency table
                               and the merged-network executor:
                                 plain    (x,w,b) -> conv+b          ("PyTorch format" op)
                                 fa_<act> (x,w,b) -> act(conv+b)     ("TensorRT format" op)
                                 far_<act>(x,w,b,r) -> act(conv+b+r)
  conv/<sig>.pallas.hlo.txt    same conv through the L1 Pallas kernel
                               (structure/correctness flavor)
  ew/<key>.hlo.txt             elementwise ops for the layerwise executor
                               (act/add/gn/attn/upsample/head)
  manifest.json                index of all of the above
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, specs
from .kernels import conv as pallas_conv

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower(fn, *shapes) -> str:
    args = [jax.ShapeDtypeStruct(s, F32) for s in shapes]
    # keep_unused: the Rust caller passes every declared argument — e.g.
    # the gn gate vector even for norm-free models — so the compiled
    # signature must not drop unused parameters.
    return to_hlo_text(jax.jit(fn, keep_unused=True).lower(*args))


def write(path: str, text: str, force: bool) -> None:
    if not force and os.path.exists(path):
        return
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)


# ---------------------------------------------------------------------------
# Per-model artifacts
# ---------------------------------------------------------------------------


def model_artifacts(sp: specs.Spec, out: str, force: bool) -> dict:
    B, H, W, C = sp.batch, sp.h, sp.w, sp.c
    P, L = sp.param_count, sp.L
    g = (L,)
    arts = {}

    def emit(name, fn, *shapes):
        path = f"{sp.name}/{name}.hlo.txt"
        write(os.path.join(out, path), lower(fn, *shapes), force)
        arts[name] = path

    if sp.task == "classify":
        x = (B, H, W, C)
        y = (B, sp.num_classes)
        emit("fwd", model.fwd(sp), (P,), g, g, g, x)
        emit("loss_eval", model.loss_eval(sp), (P,), g, g, g, x, y)
        emit("train_step", model.train_step(sp), (P,), (P,), g, g, g, x, y, ())
        emit("distill_step", model.distill_step(sp),
             (P,), (P,), (P,), g, g, g, x, y, ())
        emit("embed", model.embed(sp), (P,), g, g, g, x)
    else:
        x = (B, H, W, C)
        bs = (B,)
        emit("fwd", model.fwd(sp), (P,), g, g, g, x, bs)
        emit("loss_eval", model.loss_eval(sp), (P,), g, g, g, x, x, bs, bs)
        emit("train_step", model.train_step(sp),
             (P,), (P,), g, g, g, x, x, bs, bs, ())
        emit("sample_step", model.sample_step(sp),
             (P,), g, g, g, x, bs, bs, bs)

    # deterministic init params
    init_path = os.path.join(out, sp.name, "init.bin")
    if force or not os.path.exists(init_path):
        os.makedirs(os.path.dirname(init_path), exist_ok=True)
        flat = np.asarray(model.init_params(sp), dtype="<f4")
        flat.tofile(init_path)
    arts["init"] = f"{sp.name}/init.bin"

    spec_path = os.path.join(out, "specs", f"{sp.name}.spec.json")
    os.makedirs(os.path.dirname(spec_path), exist_ok=True)
    with open(spec_path, "w") as f:
        json.dump(sp.to_json(), f, indent=1)
    arts["spec"] = f"specs/{sp.name}.spec.json"
    return arts


def cross_distill_artifact(out: str, force: bool) -> str:
    """KD baseline of Table 10: mnv2ish-1.0 teacher -> mnv2ish-0.75 student."""
    t = specs.mnv2ish(1.0)
    s = specs.mnv2ish(0.75)
    fn = model.distill_cross(t, s)
    B, H, W, C = s.batch, s.h, s.w, s.c
    path = "kd/mnv2ish-0.75_from_1.0.hlo.txt"
    write(os.path.join(out, path),
          lower(fn, (t.param_count,), (s.param_count,), (s.param_count,),
                (B, H, W, C), (B, s.num_classes), ()), force)
    return path


# ---------------------------------------------------------------------------
# Conv + elementwise module families
# ---------------------------------------------------------------------------


def sig_str(sig) -> str:
    b, h, w, ci, co, k, s, dw = sig
    return f"b{b}h{h}w{w}i{ci}o{co}k{k}s{s}" + ("dw" if dw else "")


def conv_module(sig, variant: str):
    b, h, w, ci, co, k, s, dw = sig

    def act(kind, y):
        if kind == "relu":
            return jax.nn.relu(y)
        if kind == "swish":
            return y * jax.nn.sigmoid(y)
        return y

    def base(x, wgt, bias):
        return model.conv2d(x, wgt, s, dw) + bias

    if variant == "plain":
        return (lambda x, wgt, bias: (base(x, wgt, bias),)), \
            [(b, h, w, ci), (co, 1 if dw else ci, k, k), (co,)]
    if variant.startswith("fa_"):
        kind = variant[3:]
        return (lambda x, wgt, bias: (act(kind, base(x, wgt, bias)),)), \
            [(b, h, w, ci), (co, 1 if dw else ci, k, k), (co,)]
    if variant.startswith("far_"):
        kind = variant[4:]
        ho, wo = -(-h // s), -(-w // s)
        return (lambda x, wgt, bias, r: (act(kind, base(x, wgt, bias) + r),)), \
            [(b, h, w, ci), (co, 1 if dw else ci, k, k), (co,),
             (b, ho, wo, co)]
    if variant == "pallas":
        return (lambda x, wgt, bias:
                (pallas_conv.conv2d_same(x, wgt, s, dw) + bias,)), \
            [(b, h, w, ci), (co, 1 if dw else ci, k, k), (co,)]
    raise ValueError(variant)


def conv_artifacts(all_sigs, acts_by_sig, out: str, force: bool) -> dict:
    entries = {}
    for sig in sorted(all_sigs):
        ss = sig_str(sig)
        variants = ["plain"]
        for a in sorted(acts_by_sig.get(sig, {"relu", "none"})):
            variants += [f"fa_{a}", f"far_{a}"]
        ent = {}
        for v in variants:
            fn, shapes = conv_module(sig, v)
            path = f"conv/{ss}.{v}.hlo.txt"
            write(os.path.join(out, path), lower(fn, *shapes), force)
            ent[v] = path
        entries[ss] = ent
    return entries


def ew_artifacts(models, out: str, force: bool) -> dict:
    """Elementwise / structural ops for the layerwise executor."""
    entries = {}

    def emit(key, fn, *shapes):
        if key in entries:
            return
        path = f"ew/{key}.hlo.txt"
        write(os.path.join(out, path), lower(fn, *shapes), force)
        entries[key] = path

    for sp in models:
        B = sp.batch
        shapes = set()
        for c in sp.convs:
            shapes.add((B, c.h_out, c.w_out, c.cout))
            shapes.add((B, c.h_in, c.w_in, c.cin))
        for (b, h, w, ch) in sorted(shapes):
            base = f"b{b}h{h}w{w}c{ch}"
            emit(f"relu_{base}", lambda x: (jax.nn.relu(x),), (b, h, w, ch))
            emit(f"swish_{base}",
                 lambda x: (x * jax.nn.sigmoid(x),), (b, h, w, ch))
            emit(f"add_{base}", lambda x, y: (x + y,),
                 (b, h, w, ch), (b, h, w, ch))
        if sp.task == "classify":
            emit(f"head_{sp.name}",
                 lambda x, w_, b_: (x.mean(axis=(1, 2)) @ w_ + b_,),
                 (B, sp.convs[-1].h_out, sp.convs[-1].w_out, sp.head_hidden),
                 (sp.head_hidden, sp.num_classes), (sp.num_classes,))
        else:
            for c in sp.convs:
                if c.gn:
                    b, h, w, ch = B, c.h_out, c.w_out, c.cout
                    emit(f"gn{c.gn_groups}_b{b}h{h}w{w}c{ch}",
                         lambda x, s_, bi, g=c.gn_groups:
                         (model.group_norm(x, s_, bi, g),),
                         (b, h, w, ch), (ch,), (ch,))
                if c.barrier_reason == "attention":
                    b, h, w, ch = B, c.h_out, c.w_out, c.cout
                    emit(f"attn_b{b}h{h}w{w}c{ch}",
                         lambda x, q, o: (model.attention(x, q, o),),
                         (b, h, w, ch), (ch, 3 * ch), (ch, ch))
                if c.barrier_reason == "upsample":
                    b, h, w, ch = B, c.h_out, c.w_out, c.cout
                    emit(f"up_b{b}h{h}w{w}c{ch}",
                         lambda x: (model.upsample2x(x),), (b, h, w, ch))
    return entries


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default="all",
                    help="comma list or 'all' or 'smoke'")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.models == "all":
        names = list(specs.ALL_SPECS)
    elif args.models == "smoke":
        names = ["resnetish"]
    else:
        names = args.models.split(",")

    manifest = {"models": {}, "convs": {}, "ew": {}, "kd": {}}
    mans = os.path.join(args.out, "manifest.json")
    if os.path.exists(mans) and not args.force:
        with open(mans) as f:
            manifest = json.load(f)

    built = []
    all_sigs = set()
    acts_by_sig = {}
    for name in names:
        sp = specs.ALL_SPECS[name]()
        built.append(sp)
        manifest["models"][name] = model_artifacts(sp, args.out, args.force)
        print(f"[aot] {name}: L={sp.L} params={sp.param_count}")
        for sig in specs.merge_signatures(sp):
            all_sigs.add(sig)
            acts = acts_by_sig.setdefault(sig, set())
            acts.add("none")
            if sp.task == "diffusion":
                acts.add("swish")
            acts.add("relu")

    manifest["convs"].update(conv_artifacts(all_sigs, acts_by_sig,
                                            args.out, args.force))
    print(f"[aot] {len(all_sigs)} conv signatures")
    manifest["ew"].update(ew_artifacts(built, args.out, args.force))

    # Pallas flavor for a fixed signature test set (rust cross-checks).
    pallas_set = [s for s in sorted(all_sigs)
                  if s[5] <= 7 and s[3] <= 32 and s[4] <= 32][:8]
    for sig in pallas_set:
        ss = sig_str(sig)
        fn, shapes = conv_module(sig, "pallas")
        path = f"conv/{ss}.pallas.hlo.txt"
        write(os.path.join(args.out, path), lower(fn, *shapes), args.force)
        manifest["convs"].setdefault(ss, {})["pallas"] = path

    if "mnv2ish-1.0" in names and "mnv2ish-0.75" in names:
        manifest["kd"]["mnv2ish-0.75_from_1.0"] = \
            cross_distill_artifact(args.out, args.force)

    with open(mans, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest -> {mans}")


if __name__ == "__main__":
    main()
