"""Model family specifications — the single source of truth for network structure.

Each spec describes an L-layer CNN as the paper's alternating sequence of
convolution layers ``f_{theta_l}`` and activation layers ``sigma_l`` (Sec. 2),
plus the structural side information LayerMerge needs:

  * the irreducible set R (layers whose input/output shapes differ, Sec. 3.1),
  * merge barriers (self-attention, upsampling, skip-concatenation, and the
    strided-conv restriction of App. A),
  * skip-addition descriptors (mergeable via Dirac folding, App. A),
  * gated-GroupNorm positions (DDPM only, App. A "normalization layers"),
  * the flat parameter layout used by every AOT artifact.

The spec is serialized to ``artifacts/specs/<name>.spec.json`` and consumed by
the Rust coordinator (``rust/src/ir``).  Python never re-enters the loop after
``make artifacts``.

Architectures are scaled-down but structurally faithful versions of the
paper's models (see DESIGN.md §2):

  * ``resnetish``   — ResNet-34-style basic blocks with skip-addition and
                       strided projection shortcuts.
  * ``mnv2ish-1.0`` / ``mnv2ish-1.4`` / ``mnv2ish-0.75``
                    — MobileNetV2-style inverted residuals with depthwise
                       convs and no activation after the block (App. A).
  * ``ddpmish``     — DDPM-style U-Net: GroupNorm, time embedding,
                       self-attention barrier, upsample barrier and
                       skip-concatenation.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Optional


# ---------------------------------------------------------------------------
# Layer records
# ---------------------------------------------------------------------------


@dataclass
class Conv:
    """One main-chain convolution layer and its surrounding structure.

    ``idx`` is 1-based, matching the paper's ``l in [L]`` indexing.
    """

    idx: int
    cin: int
    cout: int
    k: int
    stride: int
    depthwise: bool
    h_in: int          # spatial resolution of the *input* feature map
    w_in: int
    act: str           # "relu" | "swish" | "none" — activation sigma_l after it
    act_gated: bool    # True if sigma_l may be replaced by id (l in A search)
    conv_gated: bool   # True if f_theta may be replaced by id (l not in R)
    barrier_after: bool  # no merging across the gap after this layer
    barrier_reason: str
    # Skip-addition: after this conv's output, add the *input* of conv
    # ``add_from`` (1-based; the tensor feeding that conv).  ``add_proj``
    # optionally names a projection conv applied to the skip branch.
    add_from: Optional[int] = None
    add_proj: Optional[dict] = None   # {"k":1,"stride":s,"cin":..,"cout":..}
    # Skip-concatenation: this conv's input is concat(prev_output, stash[tag]).
    concat_from: Optional[str] = None
    stash_as: Optional[str] = None    # stash this conv's post-act output
    # Gated GroupNorm applied after the conv (before act) when gate is 1.
    gn: bool = False
    gn_groups: int = 0
    # Time-embedding bias injected into this conv's input (ddpm only).  Time
    # injection points are barriers (DESIGN.md §2), so merging never crosses
    # a dynamic bias.
    time_bias: bool = False

    @property
    def h_out(self) -> int:
        return self.h_in // self.stride

    @property
    def w_out(self) -> int:
        return self.w_in // self.stride


@dataclass
class ParamEntry:
    name: str
    shape: list
    offset: int
    size: int


@dataclass
class Spec:
    name: str
    task: str                  # "classify" | "diffusion"
    h: int
    w: int
    c: int
    batch: int
    num_classes: int
    convs: list = field(default_factory=list)      # list[Conv]
    params: list = field(default_factory=list)     # list[ParamEntry]
    param_count: int = 0
    head_hidden: int = 0       # classifier feature dim (penultimate, for FDD)
    time_dim: int = 0          # time embedding dim (diffusion)
    attn_dim: int = 0

    # ----- construction helpers -------------------------------------------

    def add_param(self, name: str, shape) -> ParamEntry:
        size = 1
        for s in shape:
            size *= int(s)
        e = ParamEntry(name, [int(s) for s in shape], self.param_count, size)
        self.params.append(e)
        self.param_count += size
        return e

    # ----- derived structure ----------------------------------------------

    @property
    def L(self) -> int:
        return len(self.convs)

    def irreducible(self) -> list:
        """The set R: 1-based indices where input/output shapes differ."""
        return [c.idx for c in self.convs if not c.conv_gated]

    def finalize(self) -> None:
        """Apply the strided-conv restriction of App. A.

        Merging a stride>1 conv with a following conv of kernel size > 1
        blows up the merged kernel ((k2-1)*s1 + k1), so the activation after
        the strided conv is force-kept: we mark a barrier after it unless the
        next conv is 1x1.
        """
        for i, c in enumerate(self.convs[:-1]):
            nxt = self.convs[i + 1]
            if c.stride > 1 and nxt.k > 1 and not c.barrier_after:
                c.barrier_after = True
                c.barrier_reason = "stride"
        # Stashed tensors (skip-concat sources) must stay materialized in
        # the merged network, so a stash point is a merge barrier.
        for c in self.convs:
            if c.stash_as is not None and not c.barrier_after:
                c.barrier_after = True
                c.barrier_reason = "stash"
        # The last layer's activation is identity by definition (sigma_L=id).
        self.convs[-1].act_gated = False

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "task": self.task,
            "input": {"h": self.h, "w": self.w, "c": self.c, "batch": self.batch},
            "num_classes": self.num_classes,
            "head_hidden": self.head_hidden,
            "time_dim": self.time_dim,
            "param_count": self.param_count,
            "L": self.L,
            "convs": [dataclasses.asdict(c) for c in self.convs],
            "params": [dataclasses.asdict(p) for p in self.params],
        }


# ---------------------------------------------------------------------------
# resnetish — ResNet-34-style, scaled for the 32x32 synthetic task
# ---------------------------------------------------------------------------


def resnetish(batch: int = 32) -> Spec:
    sp = Spec(name="resnetish", task="classify", h=32, w=32, c=3,
              batch=batch, num_classes=10)
    widths = [16, 32, 64, 128]
    blocks = [2, 2, 2, 2]
    h = 32
    idx = 0
    cin = 3

    def conv(cin, cout, k, stride, h, act, act_gated, conv_gated, **kw):
        nonlocal idx
        idx += 1
        c = Conv(idx=idx, cin=cin, cout=cout, k=k, stride=stride,
                 depthwise=False, h_in=h, w_in=h, act=act,
                 act_gated=act_gated, conv_gated=conv_gated,
                 barrier_after=False, barrier_reason="", **kw)
        sp.convs.append(c)
        sp.add_param(f"conv{idx}.w", [cout, cin, k, k])
        sp.add_param(f"conv{idx}.b", [cout])
        return c

    # Stem.
    conv(cin, widths[0], 3, 1, h, "relu", True, False)
    cin = widths[0]
    for stage, (w_, nb) in enumerate(zip(widths, blocks)):
        for b in range(nb):
            stride = 2 if (stage > 0 and b == 0) else 1
            proj = None
            if stride != 1 or cin != w_:
                proj = {"k": 1, "stride": stride, "cin": cin, "cout": w_}
                sp.add_param(f"proj{idx+1}.w", [w_, cin, 1, 1])
                sp.add_param(f"proj{idx+1}.b", [w_])
            add_from = idx + 1  # input of the first conv in the block
            c1 = conv(cin, w_, 3, stride, h, "relu", True, stride == 1 and cin == w_)
            h2 = h // stride
            c2 = conv(w_, w_, 3, 1, h2, "relu", True, True,
                      add_from=add_from, add_proj=proj)
            h = h2
            cin = w_
    sp.head_hidden = cin
    sp.add_param("head.w", [cin, sp.num_classes])
    sp.add_param("head.b", [sp.num_classes])
    sp.finalize()
    return sp


# ---------------------------------------------------------------------------
# mnv2ish — MobileNetV2-style inverted residuals
# ---------------------------------------------------------------------------


def mnv2ish(width_mult: float = 1.0, batch: int = 32) -> Spec:
    def ch(v):
        # round to multiple of 4, MobileNet-style channel rounding
        return max(4, int(round(v * width_mult / 4)) * 4)

    name = f"mnv2ish-{width_mult}"
    sp = Spec(name=name, task="classify", h=32, w=32, c=3,
              batch=batch, num_classes=10)
    idx = 0
    h = 32

    def conv(cin, cout, k, stride, h, act, act_gated, conv_gated,
             depthwise=False, **kw):
        nonlocal idx
        idx += 1
        c = Conv(idx=idx, cin=cin, cout=cout, k=k, stride=stride,
                 depthwise=depthwise, h_in=h, w_in=h, act=act,
                 act_gated=act_gated, conv_gated=conv_gated,
                 barrier_after=False, barrier_reason="", **kw)
        sp.convs.append(c)
        if depthwise:
            sp.add_param(f"conv{idx}.w", [cout, 1, k, k])
        else:
            sp.add_param(f"conv{idx}.w", [cout, cin, k, k])
        sp.add_param(f"conv{idx}.b", [cout])
        return c

    # Stem: 3x3 s1 (CIFAR-resolution stem).
    cin = ch(16)
    conv(3, cin, 3, 1, h, "relu", True, False)

    # (expansion t, out channels, num blocks, stride of first block)
    cfg = [
        (1, ch(8), 1, 1),
        (4, ch(12), 2, 2),
        (4, ch(16), 2, 2),
        (4, ch(24), 2, 1),
    ]
    for (t, co, nb, s0) in cfg:
        for b in range(nb):
            stride = s0 if b == 0 else 1
            cexp = cin * t
            add_from = idx + 1 if (stride == 1 and cin == co) else None
            if t != 1:
                conv(cin, cexp, 1, 1, h, "relu", True, False)
            # depthwise 3x3 — replaceable by identity only at stride 1
            conv(cexp, cexp, 3, stride, h, "relu", True, stride == 1,
                 depthwise=True)
            h = h // stride
            # linear projection 1x1; inverted-residual add lands here.
            # MobileNetV2 has *no* activation after the block (App. A) — the
            # depth-compression trick of adding one after merged layers is
            # handled on the Rust side via the act gate (it exists in the
            # graph but its pristine value is 0 -> "none", gate can enable).
            conv(cexp, co, 1, 1, h, "none", True, False,
                 add_from=add_from)
            cin = co
    # Final 1x1 expansion before the head.
    cfin = ch(48)
    conv(cin, cfin, 1, 1, h, "relu", True, False)
    sp.head_hidden = cfin
    sp.add_param("head.w", [cfin, sp.num_classes])
    sp.add_param("head.b", [sp.num_classes])
    sp.finalize()
    return sp


# ---------------------------------------------------------------------------
# ddpmish — DDPM-style U-Net (diffusion task)
# ---------------------------------------------------------------------------


def ddpmish(batch: int = 16) -> Spec:
    sp = Spec(name="ddpmish", task="diffusion", h=16, w=16, c=3,
              batch=batch, num_classes=0)
    base = 16
    sp.time_dim = 32
    sp.attn_dim = base * 2
    idx = 0
    h = 16

    sp.add_param("temb.w1", [sp.time_dim, sp.time_dim])
    sp.add_param("temb.b1", [sp.time_dim])

    def conv(cin, cout, k, stride, h, act, act_gated, conv_gated, **kw):
        nonlocal idx
        idx += 1
        barrier_after = kw.pop("barrier_after", False)
        barrier_reason = kw.pop("barrier_reason", "")
        c = Conv(idx=idx, cin=cin, cout=cout, k=k, stride=stride,
                 depthwise=False, h_in=h, w_in=h, act=act,
                 act_gated=act_gated, conv_gated=conv_gated,
                 barrier_after=barrier_after, barrier_reason=barrier_reason,
                 **kw)
        sp.convs.append(c)
        sp.add_param(f"conv{idx}.w", [cout, cin, k, k])
        sp.add_param(f"conv{idx}.b", [cout])
        if kw.get("gn"):
            sp.add_param(f"gn{idx}.scale", [cout])
            sp.add_param(f"gn{idx}.bias", [cout])
        if kw.get("time_bias"):
            sp.add_param(f"temb{idx}.w", [sp.time_dim, cin])
            sp.add_param(f"temb{idx}.b", [cin])
        return c

    c1, c2 = base, base * 2

    # --- encoder ---
    conv(3, c1, 3, 1, 16, "swish", True, False, gn=True, gn_groups=4)
    # res block at 16x16 (two convs; time bias enters the second => barrier
    # in front of it, see DESIGN.md §2: injection points are barriers)
    a = idx + 1
    conv(c1, c1, 3, 1, 16, "swish", True, True, gn=True, gn_groups=4,
         barrier_after=True, barrier_reason="time")
    conv(c1, c1, 3, 1, 16, "none", True, True, add_from=a, time_bias=True,
         stash_as="e1")
    # downsample (irreducible, stride 2)
    conv(c1, c2, 3, 2, 16, "swish", True, False)
    h = 8
    # res block at 8x8, then self-attention barrier (paper: attention at the
    # 16x16 resolution of CIFAR; here the coarser level plays that role).
    a = idx + 1
    conv(c2, c2, 3, 1, 8, "swish", True, True, gn=True, gn_groups=4,
         barrier_after=True, barrier_reason="time")
    conv(c2, c2, 3, 1, 8, "none", True, True, add_from=a, time_bias=True,
         barrier_after=True, barrier_reason="attention", stash_as="e2")
    sp.add_param("attn.qkv.w", [c2, 3 * c2])
    sp.add_param("attn.out.w", [c2, c2])

    # --- middle ---
    a = idx + 1
    conv(c2, c2, 3, 1, 8, "swish", True, True, gn=True, gn_groups=4)
    conv(c2, c2, 3, 1, 8, "none", True, True, add_from=a,
         barrier_after=True, barrier_reason="mid")

    # --- decoder ---
    # skip-concat with e2, then res block; concat is a barrier by definition.
    conv(2 * c2, c2, 3, 1, 8, "swish", True, False, concat_from="e2",
         gn=True, gn_groups=4)
    conv(c2, c2, 3, 1, 8, "none", True, True,
         barrier_after=True, barrier_reason="upsample")
    # upsample 8->16 (nearest) then the paper's post-upsample 3x3 s1 conv —
    # explicitly a pruning candidate (App. A: "we include these convolution
    # layers as potential pruning candidates").
    conv(c2, c2, 3, 1, 16, "swish", True, True)
    # skip-concat with e1
    conv(c2 + c1, c1, 3, 1, 16, "swish", True, False, concat_from="e1",
         gn=True, gn_groups=4)
    a = idx + 1
    conv(c1, c1, 3, 1, 16, "swish", True, True, gn=True, gn_groups=4,
         barrier_after=True, barrier_reason="time")
    conv(c1, c1, 3, 1, 16, "swish", True, True, add_from=a, time_bias=True)
    # output head conv
    conv(c1, 3, 3, 1, 16, "none", False, False)
    sp.finalize()
    return sp


ALL_SPECS = {
    "resnetish": resnetish,
    "mnv2ish-1.0": lambda: mnv2ish(1.0),
    "mnv2ish-1.4": lambda: mnv2ish(1.4),
    "mnv2ish-0.75": lambda: mnv2ish(0.75),
    "ddpmish": ddpmish,
}


# ---------------------------------------------------------------------------
# Merge-signature enumeration (superset; exact K_ij logic lives in rust/ir).
# ---------------------------------------------------------------------------


def segments(spec: Spec):
    """Maximal merge-allowed spans [i, j] of 1-based conv indices.

    A span may not cross a barrier_after gap or a concat input boundary.
    """
    segs = []
    start = 1
    for c in spec.convs:
        nxt = None
        for d in spec.convs:
            if d.idx == c.idx + 1:
                nxt = d
        end_here = c.barrier_after or c.idx == spec.L or (
            nxt is not None and nxt.concat_from is not None)
        if end_here:
            segs.append((start, c.idx))
            start = c.idx + 1
    return segs


# Largest merged kernel size considered anywhere in the stack (see
# merge_signatures).  rust/src/ir/mod.rs K_MAX must match.
K_MAX = 13


def valid_span(spec: Spec, i: int, j: int) -> bool:
    """Whether the span ``(i, j]`` may become a single merged layer.

    Beyond barriers (handled by ``segments``), a span must nest with respect
    to every skip-addition (the paper merges across a skip-add only when
    every intermediate convolution merges into a single layer, App. A).  For
    an add whose source tensor is boundary ``p-1`` (the input of conv ``p``)
    and whose add point follows conv ``q``:

      * ``p-1 < i < q < j``   — the add lands strictly inside the merged
        layer but its source is outside: not expressible as one conv.
        (``q == j`` is fine: the add executes *after* the merged conv, on
        materialized boundary tensors.)
      * ``i < p-1 < j < q``   — an add beyond the span would need a tensor
        internal to the merged layer.  Note this rule also guarantees
        globally that any span ending exactly at ``q`` finds its source
        boundary materialized: no other span may swallow ``p-1``.
      * otherwise valid — the branch is fully inside (Dirac folding), fully
        outside, or cut exactly at boundaries.
    """
    for c in spec.convs:
        if c.add_from is None:
            continue
        p_src, q = c.add_from - 1, c.idx   # source boundary, add point
        if p_src < i < q < j:
            return False
        if i < p_src < j < q:
            return False
    return True


def merge_signatures(spec: Spec):
    """All conv shape signatures any merged layer could take (superset).

    A merged layer over the span ``(i, j]`` consumes the input of conv
    ``i+1`` and produces the output of conv ``j``; its stride is the product
    of strides and its kernel size is ``1 + sum over kept convs of (k-1)``
    (Eq. 1, with the stride-dilation generalization of App. A).  We
    enumerate all achievable k via subset sums with irreducible layers
    forced in, mirroring the Rust IR (cross-checked by an integration test).
    """
    sigs = set()
    for (s, e) in segments(spec):
        for i in range(s - 1, e):          # i: 0-based "merge-from" boundary
            stride = 1
            dw = True
            for j in range(i + 1, e + 1):  # j: 1-based end conv
                c = spec.convs[j - 1]
                stride *= c.stride
                dw = dw and c.depthwise
                if not valid_span(spec, i, j):
                    continue
                first = spec.convs[i]      # conv i+1, 1-based
                cin = first.cin
                cout = c.cout
                hin, win = first.h_in, first.w_in
                # achievable merged kernel sizes: subset sums of (k_l - 1)
                # with irreducible layers forced in.
                sums = {0}
                forced = 0
                for l in range(i + 1, j + 1):
                    cl = spec.convs[l - 1]
                    inc = (cl.k - 1) * _stride_prefix(spec, i, l)
                    if not cl.conv_gated:
                        forced += inc
                    else:
                        sums = sums | {ss + inc for ss in sums}
                for ssum in sums:
                    k = 1 + ssum + forced
                    # Merged kernels beyond K_MAX are never latency-optimal
                    # (conv cost grows ~k^2 — the paper's Fig. 1 point), so
                    # both sides of the stack exclude them.  Mirrored by
                    # rust/src/ir (K_MAX there must match).
                    if k > K_MAX:
                        continue
                    sigs.add((spec.batch, hin, win, cin, cout, k, stride,
                              dw and cin == cout))
    # every original layer is also a signature (for per-layer execution)
    for c in spec.convs:
        sigs.add((spec.batch, c.h_in, c.w_in, c.cin, c.cout, c.k, c.stride,
                  c.depthwise))
        # projection shortcuts execute as standalone convs in the merged
        # network whenever their residual add is not folded into a span
        if c.add_proj is not None:
            src = spec.convs[c.add_from - 1]
            sigs.add((spec.batch, src.h_in, src.w_in, c.add_proj["cin"],
                      c.add_proj["cout"], c.add_proj["k"],
                      c.add_proj["stride"], False))
    return sorted(sigs)


def _stride_prefix(spec: Spec, i: int, l: int) -> int:
    """Product of strides of convs i+1 .. l-1 (the dilation factor a later
    kernel's taps acquire when pulled back to the span input, App. A)."""
    p = 1
    for m in range(i + 1, l):
        p *= spec.convs[m - 1].stride
    return p


if __name__ == "__main__":
    for name, fn in ALL_SPECS.items():
        sp = fn()
        print(name, "L =", sp.L, "params =", sp.param_count,
              "R =", sp.irreducible(), "segments =", segments(sp),
              "#sigs =", len(merge_signatures(sp)))
