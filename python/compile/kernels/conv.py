"""L1 — merged-conv2d as a Pallas kernel (TPU-shaped, interpret-mode here).

Hardware adaptation of the paper's hot loop (DESIGN.md §3).  The paper's
merged layers are ordinary cuDNN convs whose kernel size k grows as layers
merge (Eq. 1) — the very effect LayerMerge controls.  On a TPU-like target
we express the k x k conv as **tap-accumulated MXU matmuls**:

    for each tap (dy, dx) in k x k:
        acc += X[dy::s, dx::s, :] . reshape(H'*W', Cin)
                 @  W[:, :, dy, dx] . T                    # (Cin, Cout)

so the MXU sees (H'W' x Cin) @ (Cin x Cout) matmuls — systolic-array
shaped; VMEM plays the role the paper's baselines give to cuDNN workspace.
Cost grows linearly in k^2 taps while HBM traffic stays ~constant (one
input read, one output write) — exactly the trade-off the latency tables
capture.

Schedule: at this repo's feature-map sizes (<= 32x32, <= 192 ch) one whole
image plus the accumulator fits comfortably in VMEM (~1.3 MB of a 16 MB
budget), so the grid is one program per batch element with full-image
blocks.  For ImageNet-scale inputs the same kernel row-tiles: BlockSpec
(TILE_H*s + k - 1) halo rows per program, accumulator (TILE_H*W' x Cout)
resident across the tap loop.  The §Perf analysis in EXPERIMENTS.md
reports VMEM footprint and MXU utilization estimates for both schedules.

interpret=True throughout: the CPU PJRT plugin cannot run Mosaic
custom-calls; correctness is enforced against ``ref.py`` by pytest +
hypothesis, and real-TPU performance is *estimated* from the schedule.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile height of the ImageNet-scale schedule (documented above; the
# interpret-mode grid below uses whole-image blocks instead).
TILE_H = 8


def _conv_kernel(x_ref, w_ref, o_ref, *, k: int, stride: int):
    """One batch element: tap-accumulated matmul conv, VALID padding."""
    x = x_ref[...]          # (H, W, Cin) block, resident in VMEM
    w = w_ref[...]          # (Cout, Cin, k, k)
    h, wd, cin = x.shape
    cout = w.shape[0]
    h_out = (h - k) // stride + 1
    w_out = (wd - k) // stride + 1
    acc = jnp.zeros((h_out * w_out, cout), jnp.float32)
    for dy in range(k):
        for dx in range(k):
            patch = jax.lax.slice(
                x, (dy, dx, 0),
                (dy + (h_out - 1) * stride + 1, dx + (w_out - 1) * stride + 1,
                 cin),
                (stride, stride, 1))
            acc = acc + patch.reshape(h_out * w_out, cin) @ w[:, :, dy, dx].T
    o_ref[...] = acc.reshape(h_out, w_out, cout)


def conv2d_valid(x, w, stride: int = 1):
    """VALID dense conv via the Pallas kernel.  x: NHWC, w: OIHW."""
    b, h, wd, cin = x.shape
    cout, cin2, k, _ = w.shape
    assert cin2 == cin, (x.shape, w.shape)
    h_out = (h - k) // stride + 1
    w_out = (wd - k) // stride + 1
    out = pl.pallas_call(
        functools.partial(_conv_kernel, k=k, stride=stride),
        out_shape=jax.ShapeDtypeStruct((b, h_out, w_out, cout), jnp.float32),
        grid=(b,),
        in_specs=[
            pl.BlockSpec((None, h, wd, cin), lambda nb: (nb, 0, 0, 0)),
            pl.BlockSpec((cout, cin, k, k), lambda nb: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, h_out, w_out, cout),
                               lambda nb: (nb, 0, 0, 0)),
        interpret=True,
    )(x, w)
    return out


def conv2d_same(x, w, stride: int = 1, depthwise: bool = False):
    """SAME conv through the Pallas kernel (depthwise is expanded to a
    diagonal dense kernel — correctness path only)."""
    k = w.shape[2]
    if depthwise:
        w = _expand_dw(w, x.shape[-1])
    h = x.shape[1]
    out_h = -(-h // stride)
    pad_total = max((out_h - 1) * stride + k - h, 0)
    lo = pad_total // 2
    hi = pad_total - lo
    x = jnp.pad(x, ((0, 0), (lo, hi), (lo, hi), (0, 0)))
    return conv2d_valid(x, w, stride)


def _expand_dw(w, c):
    """[C,1,k,k] depthwise kernel -> diagonal dense [C,C,k,k]."""
    eye = jnp.eye(c, dtype=w.dtype)[:, :, None, None]
    return eye * w[:, 0:1]
