"""Pure-jnp / numpy oracles for the L1 Pallas kernels.

These are the correctness ground truth for:

  * ``conv.py``  — the merged-conv2d Pallas kernel (vs lax.conv);
  * ``merge.py`` — the parameter-space convolution theta_2 * theta_1
                   (vs actually composing the two convolutions).

``merge_kernels``/``merge_bias`` also define the exact algebra the Rust
``merge`` module re-implements; ``python/tests`` pins fixtures so the two
implementations can never drift silently.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax import lax


def conv2d_valid(x, w, stride: int = 1, depthwise: bool = False):
    """Reference VALID conv, NHWC x OIHW -> NHWC."""
    groups = x.shape[-1] if depthwise else 1
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NHWC", "OIHW", "NHWC"),
        feature_group_count=groups)


def conv2d_same(x, w, stride: int = 1, depthwise: bool = False):
    groups = x.shape[-1] if depthwise else 1
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "OIHW", "NHWC"),
        feature_group_count=groups)


def merge_kernels(w1: np.ndarray, w2: np.ndarray, s1: int = 1) -> np.ndarray:
    """Parameter-space convolution: the single kernel equivalent to
    ``conv(conv(x, w1, stride=s1, VALID), w2, stride=s2, VALID)``.

    Derivation (Sec. 2 / App. A).  With
      y1[c1, u, v]   = sum_{i,a,b} w1[c1,i,a,b] x[i, u*s1+a, v*s1+b]
      y2[o, p, q]    = sum_{c1,e,f} w2[o,c1,e,f] y1[c1, p*s2+e, q*s2+f]
    substituting gives a single conv with stride s1*s2 and

      wm[o,i,dy,dx] = sum_{c1,e,f} w2[o,c1,e,f] * w1[c1,i, dy-e*s1, dx-f*s1]

    so Ker(wm) = (Ker(w2)-1)*s1 + Ker(w1)   (the paper's strided Eq. 1).
    """
    o2, c1b, k2, _ = w2.shape
    c1a, ci, k1, _ = w1.shape
    assert c1a == c1b, (w1.shape, w2.shape)
    km = (k2 - 1) * s1 + k1
    wm = np.zeros((o2, ci, km, km), dtype=np.float64)
    for e in range(k2):
        for f in range(k2):
            # wm[:, :, e*s1 : e*s1+k1, f*s1 : f*s1+k1] += w2[:,:,e,f] @ w1
            contrib = np.einsum("oc,cikl->oikl", w2[:, :, e, f], w1)
            wm[:, :, e * s1:e * s1 + k1, f * s1:f * s1 + k1] += contrib
    return wm.astype(w1.dtype)


def merge_bias(w2: np.ndarray, b1: np.ndarray, b2: np.ndarray) -> np.ndarray:
    """Bias of the composed conv: b2 + (sum over taps of w2) @ b1."""
    return b2 + np.einsum("ocef,c->o", w2, b1)


def dirac_kernel(c: int, k: int, dtype=np.float32) -> np.ndarray:
    """Identity conv kernel of size k (used to fold skip-addition, App. A)."""
    w = np.zeros((c, c, k, k), dtype=dtype)
    for i in range(c):
        w[i, i, k // 2, k // 2] = 1.0
    return w


def expand_depthwise(w: np.ndarray) -> np.ndarray:
    """Expand a depthwise kernel [C,1,k,k] to dense [C,C,k,k] (for merging
    a depthwise conv with a dense neighbour — the merged layer is dense)."""
    c, one, kh, kw = w.shape
    assert one == 1
    out = np.zeros((c, c, kh, kw), dtype=w.dtype)
    for i in range(c):
        out[i, i] = w[i, 0]
    return out


def embed_kernel(w: np.ndarray, k: int) -> np.ndarray:
    """Zero-pad a conv kernel spatially (centered) to size k x k."""
    o, i, kh, kw = w.shape
    assert k >= kh and (k - kh) % 2 == 0
    out = np.zeros((o, i, k, k), dtype=w.dtype)
    p = (k - kh) // 2
    out[:, :, p:p + kh, p:p + kw] = w
    return out
