"""L1 — the merge operator theta_2 * theta_1 as a Pallas kernel.

This is the paper's Sec. 2 parameter-space convolution: the single kernel
equivalent to composing two convolutions (with stride s1 on the inner one).
The merged weight is

    wm[o, i, dy, dx] = sum_{c,e,f} w2[o,c,e,f] * w1[c,i, dy - e*s1, dx - f*s1]

which we compute as k2^2 MXU matmuls over the channel dimensions: for each
outer tap (e, f), a (Cout x C) @ (C x Cin*k1*k1) matmul scattered into the
(dy, dx) window it affects.  The accumulator (the whole merged kernel,
Cout x Cin x km x km) stays resident in VMEM across the tap loop — merged
kernels are small (<= 13 x 13 here), so this is a pure compute kernel.

Oracle: ``ref.merge_kernels`` (numpy loops), itself validated against
actually composing the convs; pytest + hypothesis sweep shapes/strides.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _merge_kernel(w1_ref, w2_ref, o_ref, *, s1: int):
    w1 = w1_ref[...]        # (C, Cin, k1, k1)
    w2 = w2_ref[...]        # (Cout, C, k2, k2)
    c, cin, k1, _ = w1.shape
    cout, _, k2, _ = w2.shape
    km = (k2 - 1) * s1 + k1
    w1f = w1.reshape(c, cin * k1 * k1)
    acc = jnp.zeros((cout, cin, km, km), jnp.float32)
    for e in range(k2):
        for f in range(k2):
            contrib = (w2[:, :, e, f] @ w1f).reshape(cout, cin, k1, k1)
            acc = jax.lax.dynamic_update_slice(
                acc,
                jax.lax.dynamic_slice(
                    acc, (0, 0, e * s1, f * s1), (cout, cin, k1, k1))
                + contrib,
                (0, 0, e * s1, f * s1))
    o_ref[...] = acc


def merge_kernels(w1, w2, s1: int = 1):
    """Pallas merged kernel; w1: (C,Cin,k1,k1), w2: (Cout,C,k2,k2)."""
    c, cin, k1, _ = w1.shape
    cout, c2, k2, _ = w2.shape
    assert c == c2
    km = (k2 - 1) * s1 + k1
    return pl.pallas_call(
        functools.partial(_merge_kernel, s1=s1),
        out_shape=jax.ShapeDtypeStruct((cout, cin, km, km), jnp.float32),
        interpret=True,
    )(w1, w2)


def merge_bias(w2, b1, b2):
    """bm = b2 + (sum over w2 taps) @ b1 — small; plain jnp."""
    return b2 + jnp.einsum("ocef,c->o", w2, b1)
