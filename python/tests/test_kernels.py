"""L1 correctness: Pallas kernels vs the pure-jnp/numpy oracles.

hypothesis sweeps shapes/strides/kernel sizes; every property is the exact
contract the Rust side relies on (the merge algebra here is re-implemented
in rust/src/merge and pinned by fixtures in test_fixtures.py).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv as pconv
from compile.kernels import merge as pmerge
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# Pallas conv vs lax.conv
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 2), st.integers(6, 14), st.integers(1, 5),
       st.integers(1, 6), st.integers(1, 6), st.sampled_from([1, 3, 5]),
       st.sampled_from([1, 2]), st.integers(0, 10 ** 6))
def test_pallas_conv_valid_matches_ref(b, h, w_extra, ci, co, k, s, seed):
    w_sz = k + w_extra
    r = rng(seed)
    x = jnp.asarray(r.normal(size=(b, h + k, w_sz + k, ci)), jnp.float32)
    w = jnp.asarray(r.normal(size=(co, ci, k, k)), jnp.float32)
    got = pconv.conv2d_valid(x, w, s)
    want = ref.conv2d_valid(x, w, s)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 10), st.integers(2, 8), st.integers(2, 8),
       st.sampled_from([1, 3, 5, 7]), st.sampled_from([1, 2]),
       st.integers(0, 10 ** 6))
def test_pallas_conv_same_matches_ref(h, ci, co, k, s, seed):
    r = rng(seed)
    x = jnp.asarray(r.normal(size=(2, h, h, ci)), jnp.float32)
    w = jnp.asarray(r.normal(size=(co, ci, k, k)), jnp.float32)
    got = pconv.conv2d_same(x, w, s)
    want = ref.conv2d_same(x, w, s)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(4, 10), st.integers(2, 6), st.sampled_from([1, 3]),
       st.sampled_from([1, 2]), st.integers(0, 10 ** 6))
def test_pallas_conv_depthwise(h, c, k, s, seed):
    r = rng(seed)
    x = jnp.asarray(r.normal(size=(2, h, h, c)), jnp.float32)
    w = jnp.asarray(r.normal(size=(c, 1, k, k)), jnp.float32)
    got = pconv.conv2d_same(x, w, s, depthwise=True)
    want = ref.conv2d_same(x, w, s, depthwise=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_pallas_conv_1x1_is_channel_matmul():
    r = rng(0)
    x = jnp.asarray(r.normal(size=(2, 5, 5, 3)), jnp.float32)
    w = jnp.asarray(r.normal(size=(4, 3, 1, 1)), jnp.float32)
    got = pconv.conv2d_valid(x, w, 1)
    want = jnp.einsum("bhwc,oc->bhwo", x, w[:, :, 0, 0])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Merge algebra: the Sec. 2 equivalence f2 o f1 == f_{theta2 * theta1}
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 4),
       st.sampled_from([1, 3, 5]), st.sampled_from([1, 3]),
       st.sampled_from([1, 2]), st.sampled_from([1, 2]),
       st.integers(0, 10 ** 6))
def test_ref_merge_equals_composition(ci, c, co, k1, k2, s1, s2, seed):
    r = rng(seed)
    km = (k2 - 1) * s1 + k1
    h = km + 5 * s1 * s2
    x = jnp.asarray(r.normal(size=(2, h, h, ci)), jnp.float32)
    w1 = r.normal(size=(c, ci, k1, k1)).astype(np.float32)
    w2 = r.normal(size=(co, c, k2, k2)).astype(np.float32)
    composed = ref.conv2d_valid(ref.conv2d_valid(x, jnp.asarray(w1), s1),
                                jnp.asarray(w2), s2)
    wm = ref.merge_kernels(w1, w2, s1)
    assert wm.shape == (co, ci, km, km)  # Eq. 1 / App. A kernel-size law
    merged = ref.conv2d_valid(x, jnp.asarray(wm), s1 * s2)
    np.testing.assert_allclose(merged, composed, rtol=1e-3, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(1, 3), st.integers(1, 3),
       st.sampled_from([1, 3]), st.sampled_from([1, 3]),
       st.integers(0, 10 ** 6))
def test_merge_bias_equals_composition(ci, c, co, k1, k2, seed):
    r = rng(seed)
    h = k1 + k2 + 4
    x = jnp.asarray(r.normal(size=(1, h, h, ci)), jnp.float32)
    w1 = r.normal(size=(c, ci, k1, k1)).astype(np.float32)
    w2 = r.normal(size=(co, c, k2, k2)).astype(np.float32)
    b1 = r.normal(size=(c,)).astype(np.float32)
    b2 = r.normal(size=(co,)).astype(np.float32)
    composed = ref.conv2d_valid(
        ref.conv2d_valid(x, jnp.asarray(w1)) + b1, jnp.asarray(w2)) + b2
    wm = ref.merge_kernels(w1, w2)
    bm = ref.merge_bias(w2, b1, b2)
    merged = ref.conv2d_valid(x, jnp.asarray(wm)) + bm
    np.testing.assert_allclose(merged, composed, rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(1, 3), st.integers(1, 3),
       st.sampled_from([1, 3, 5]), st.sampled_from([1, 3]),
       st.sampled_from([1, 2]), st.integers(0, 10 ** 6))
def test_pallas_merge_matches_ref(ci, c, co, k1, k2, s1, seed):
    r = rng(seed)
    w1 = r.normal(size=(c, ci, k1, k1)).astype(np.float32)
    w2 = r.normal(size=(co, c, k2, k2)).astype(np.float32)
    got = pmerge.merge_kernels(jnp.asarray(w1), jnp.asarray(w2), s1)
    want = ref.merge_kernels(w1, w2, s1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_merge_associative():
    """(w3 * w2) * w1 == w3 * (w2 * w1) — the iterated merge of Eq. 2."""
    r = rng(7)
    w1 = r.normal(size=(3, 2, 3, 3)).astype(np.float32)
    w2 = r.normal(size=(4, 3, 3, 3)).astype(np.float32)
    w3 = r.normal(size=(2, 4, 3, 3)).astype(np.float32)
    a = ref.merge_kernels(ref.merge_kernels(w1, w2), w3)
    b = ref.merge_kernels(w1, ref.merge_kernels(w2, w3))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_merge_with_identity_is_noop():
    """theta_id does not grow the kernel (Sec. 3.1): id * w == w."""
    r = rng(3)
    w = r.normal(size=(4, 3, 3, 3)).astype(np.float32)
    ident = ref.dirac_kernel(4, 1)
    np.testing.assert_allclose(ref.merge_kernels(w, ident), w, rtol=1e-5)
    ident_in = ref.dirac_kernel(3, 1)
    np.testing.assert_allclose(ref.merge_kernels(ident_in, w), w, rtol=1e-5)


def test_dirac_fold_equals_residual_add():
    """x + conv(x, w) == conv(x, w + dirac) — the skip-addition fold."""
    r = rng(11)
    x = jnp.asarray(r.normal(size=(2, 8, 8, 4)), jnp.float32)
    w = r.normal(size=(4, 4, 3, 3)).astype(np.float32)
    lhs = ref.conv2d_same(x, jnp.asarray(w)) + x
    fold = w + ref.dirac_kernel(4, 3)
    rhs = ref.conv2d_same(x, jnp.asarray(fold))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


def test_expand_depthwise_equivalence():
    r = rng(5)
    x = jnp.asarray(r.normal(size=(2, 8, 8, 4)), jnp.float32)
    wdw = r.normal(size=(4, 1, 3, 3)).astype(np.float32)
    dw = ref.conv2d_same(x, jnp.asarray(wdw), depthwise=True)
    dense = ref.conv2d_same(x, jnp.asarray(ref.expand_depthwise(wdw)))
    np.testing.assert_allclose(dw, dense, rtol=1e-4, atol=1e-4)


def test_embed_kernel_padding_preserves_valid_interior():
    """Embedding a kernel into a larger one == the same conv on a padded
    input window (the alignment used when summing Dirac into a span)."""
    r = rng(9)
    x = jnp.asarray(r.normal(size=(1, 12, 12, 2)), jnp.float32)
    w = r.normal(size=(3, 2, 3, 3)).astype(np.float32)
    w5 = ref.embed_kernel(w, 5)
    small = ref.conv2d_valid(x, jnp.asarray(w))
    big = ref.conv2d_valid(x, jnp.asarray(w5))
    np.testing.assert_allclose(big, small[:, 1:-1, 1:-1, :],
                               rtol=1e-4, atol=1e-4)


def test_kernel_size_law():
    """Eq. 1: Ker = 1 + sum (Ker_l - 1) under stride 1."""
    sizes = [3, 1, 5, 3]
    r = rng(1)
    c = 2
    ws = [r.normal(size=(c, c, k, k)).astype(np.float32) for k in sizes]
    acc = ws[0]
    for w in ws[1:]:
        acc = ref.merge_kernels(acc, w)
    assert acc.shape[-1] == 1 + sum(k - 1 for k in sizes)
