"""L2 correctness: the gated graph implements the paper's replacement
operators sigma_{A,l} / f_{C,theta_l,l} exactly, for every model family."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model, specs
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def tiny_batch(sp, seed=0):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(sp.batch, sp.h, sp.w, sp.c)), jnp.float32)
    if sp.task == "classify":
        y = jax.nn.one_hot(
            jnp.asarray(r.integers(0, sp.num_classes, size=(sp.batch,))),
            sp.num_classes)
        return x, y
    eps = jnp.asarray(r.normal(size=x.shape), jnp.float32)
    t = jnp.asarray(r.uniform(0, 1000, size=(sp.batch,)), jnp.float32)
    abar = jnp.asarray(r.uniform(0.1, 0.99, size=(sp.batch,)), jnp.float32)
    return x, (eps, t, abar)


def gates(sp, ga=1.0, gc=1.0, gn=1.0):
    L = sp.L
    return (jnp.full((L,), ga, jnp.float32),
            jnp.full((L,), gc, jnp.float32),
            jnp.full((L,), gn, jnp.float32))


ALL = ["resnetish", "mnv2ish-1.0", "ddpmish"]


@pytest.fixture(scope="module")
def built():
    out = {}
    for name in ALL:
        sp = specs.ALL_SPECS[name]()
        flat = model.init_params(sp, seed=1)
        out[name] = (sp, flat)
    return out


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes(built, name):
    sp, flat = built[name]
    x, aux = tiny_batch(sp)
    ga, gc, gn = gates(sp)
    if sp.task == "classify":
        out, feats = model.gated_forward(sp, flat, ga, gc, gn, x)
        assert out.shape == (sp.batch, sp.num_classes)
        assert feats.shape == (sp.batch, sp.head_hidden)
    else:
        eps, t, abar = aux
        out, _ = model.gated_forward(sp, flat, ga, gc, gn, x, t)
        assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("name", ALL)
def test_conv_gate_zero_removes_layer(built, name):
    """gc[l] = 0 must make the output independent of theta_l — exactly the
    f_{C,theta,l} -> f_{theta_id} substitution of Problem (2)."""
    sp, flat = built[name]
    x, aux = tiny_batch(sp)
    t = aux[1] if sp.task == "diffusion" else None
    ga, gc, gn = gates(sp)
    reducible = [c for c in sp.convs if c.conv_gated]
    assert reducible, "spec has no reducible conv?"
    c = reducible[len(reducible) // 2]
    gc0 = gc.at[c.idx - 1].set(0.0)
    out0, _ = model.gated_forward(sp, flat, ga, gc0, gn, x, t)
    # perturb that conv's weights: output must not change
    pw = [p for p in sp.params if p.name == f"conv{c.idx}.w"][0]
    noise = jnp.zeros_like(flat).at[pw.offset:pw.offset + pw.size].set(7.7)
    out1, _ = model.gated_forward(sp, flat + noise, ga, gc0, gn, x, t)
    np.testing.assert_allclose(out0, out1, rtol=1e-5, atol=1e-5)
    # with the gate on, the same perturbation must change the output
    out2, _ = model.gated_forward(sp, flat + noise, ga, gc, gn, x, t)
    assert float(jnp.abs(out2 - out0).max()) > 1e-3


@pytest.mark.parametrize("name", ALL)
def test_act_gate_zero_linearizes(built, name):
    """ga[l] = 0 replaces sigma_l by id: for a net with ALL act/gn gates
    off, scaling the input scales the pre-head features linearly
    (classifier head aside, the net is one big linear conv — the
    depth-compression premise)."""
    sp, flat = built[name]
    if sp.task == "diffusion":
        pytest.skip("attention keeps ddpmish nonlinear by design")
    x, _ = tiny_batch(sp)
    ga, gc, gn = gates(sp, ga=0.0, gn=0.0)
    # remove biases to make the map exactly linear
    flat_nb = flat
    for p in sp.params:
        if p.name.endswith(".b"):
            flat_nb = flat_nb.at[p.offset:p.offset + p.size].set(0.0)
    _, f1 = model.gated_forward(sp, flat_nb, ga, gc, gn, x)
    _, f2 = model.gated_forward(sp, flat_nb, ga, gc, gn, 2.0 * x)
    np.testing.assert_allclose(2.0 * f1, f2, rtol=1e-3, atol=1e-3)


def test_two_conv_span_matches_merged_kernel_interior():
    """End-to-end depth-compression equivalence at the graph level: with
    the activation between resnetish convs 2,3 gated off, the two convs
    equal the single merged conv theta_3 * theta_2 on the interior
    (SAME-padding boundary rows differ by construction; the executor
    handles deployment padding — see DESIGN.md)."""
    sp = specs.resnetish()
    flat = model.init_params(sp, seed=3)
    P = model.unflatten(sp, flat)
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(2, 12, 12, 16)), jnp.float32)
    w2, b2 = P["conv2.w"], P["conv2.b"]
    w3, b3 = P["conv3.w"], P["conv3.b"]
    seq = ref.conv2d_same(ref.conv2d_same(x, w2) + b2, w3) + b3
    wm = ref.merge_kernels(np.asarray(w2), np.asarray(w3))
    bm = ref.merge_bias(np.asarray(w3), np.asarray(b2), np.asarray(b3))
    merged = ref.conv2d_same(x, jnp.asarray(wm)) + bm
    np.testing.assert_allclose(merged[:, 2:-2, 2:-2, :],
                               seq[:, 2:-2, 2:-2, :], rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("name", ALL)
def test_train_step_reduces_loss(built, name):
    sp, flat = built[name]
    x, aux = tiny_batch(sp)
    ga, gc, gn = gates(sp)
    step = jax.jit(model.train_step(sp))
    mom = jnp.zeros_like(flat)
    lr = jnp.float32(0.05 if sp.task == "classify" else 1e-3)
    if sp.task == "classify":
        args = (x, aux)
    else:
        eps, t, abar = aux
        args = (x, eps, t, abar)
    p = flat
    first = None
    for i in range(12):
        p, mom, loss, metric = step(p, mom, ga, gc, gn, *args, lr)
        if first is None:
            first = float(loss)
    assert float(loss) < first, (float(loss), first)
    assert np.isfinite(float(loss))


def test_distill_step_runs_and_improves():
    sp = specs.resnetish()
    flat = model.init_params(sp, seed=1)
    tflat = model.init_params(sp, seed=2)
    x, y = tiny_batch(sp)
    ga, gc, gn = gates(sp)
    step = jax.jit(model.distill_step(sp))
    mom = jnp.zeros_like(flat)
    p = flat
    first = None
    for _ in range(8):
        p, mom, loss, acc = step(tflat, p, mom, ga, gc, gn, x, y,
                                 jnp.float32(0.05))
        if first is None:
            first = float(loss)
    assert float(loss) < first


def test_sample_step_is_contractive_toward_clip_range():
    sp = specs.ddpmish()
    flat = model.init_params(sp, seed=1)
    ga, gc, gn = gates(sp)
    r = np.random.default_rng(0)
    xt = jnp.asarray(3.0 * r.normal(size=(sp.batch, sp.h, sp.w, sp.c)),
                     jnp.float32)
    t = jnp.full((sp.batch,), 900.0, jnp.float32)
    ab_t = jnp.full((sp.batch,), 0.05, jnp.float32)
    ab_p = jnp.full((sp.batch,), 0.3, jnp.float32)
    (x_prev,) = model.sample_step(sp)(flat, ga, gc, gn, xt, t, ab_t, ab_p)
    assert x_prev.shape == xt.shape
    assert bool(jnp.all(jnp.isfinite(x_prev)))


# ---------------------------------------------------------------------------
# Spec invariants the Rust IR depends on
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", list(specs.ALL_SPECS))
def test_spec_shape_chain(name):
    sp = specs.ALL_SPECS[name]()
    prev_c, prev_h = sp.c, sp.h
    stash = {}
    for c in sp.convs:
        cin = c.cin
        if c.concat_from is not None:
            cin = c.cin  # declared post-concat
            assert c.concat_from in stash
            assert cin == prev_c + stash[c.concat_from]
        else:
            assert cin == prev_c, (c.idx, cin, prev_c)
        assert c.h_in == prev_h, (c.idx, c.h_in, prev_h)
        if c.conv_gated:
            assert c.cin == c.cout and c.stride == 1, \
                f"irreducible layer {c.idx} marked reducible"
        prev_c, prev_h = c.cout, c.h_out
        if c.stash_as:
            stash[c.stash_as] = c.cout
        if c.barrier_reason == "upsample":
            prev_h *= 2


@pytest.mark.parametrize("name", list(specs.ALL_SPECS))
def test_spec_R_matches_reducibility(name):
    sp = specs.ALL_SPECS[name]()
    for c in sp.convs:
        shape_preserving = (c.cin == c.cout and c.stride == 1
                            and c.concat_from is None)
        if c.conv_gated:
            assert shape_preserving
    assert sp.convs[-1].act_gated is False  # sigma_L = id


@pytest.mark.parametrize("name", list(specs.ALL_SPECS))
def test_merge_signatures_wellformed(name):
    sp = specs.ALL_SPECS[name]()
    sigs = specs.merge_signatures(sp)
    assert sigs
    for (b, h, w, ci, co, k, s, dw) in sigs:
        assert k % 2 == 1 and k <= specs.K_MAX
        assert s in (1, 2, 4)
        if dw:
            assert ci == co


def test_valid_span_nesting_rule():
    sp = specs.resnetish()
    adds = [(c.add_from, c.idx) for c in sp.convs if c.add_from]
    assert adds
    p, q = adds[0]  # residual branch: source boundary p-1, add point q
    # a span that swallows the source boundary while the add point lies
    # beyond it would leave the add without its tensor -> invalid
    assert not specs.valid_span(sp, p - 2, q - 1)
    # covering the whole branch folds the add via Dirac -> valid
    assert specs.valid_span(sp, p - 1, q)
    # add landing exactly at the span end executes externally -> valid
    assert specs.valid_span(sp, p, q)


def test_stride_rule_applied():
    """App. A: stride>1 conv followed by k>1 conv forces a barrier."""
    sp = specs.resnetish()
    for i, c in enumerate(sp.convs[:-1]):
        nxt = sp.convs[i + 1]
        if c.stride > 1 and nxt.k > 1:
            assert c.barrier_after
