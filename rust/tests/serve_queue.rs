//! The serve suite: micro-batched queue machinery, host-only (no PJRT
//! runtime needed — sessions run on a deterministic host backend via
//! `Session::from_fn`, the same coalesce/pad/split/deliver path a
//! deployed `CompiledPlan` uses).
//!
//! Pins the ISSUE-2 acceptance properties:
//! * batched-vs-one-shot numerics parity (bit-identical),
//! * tail-padding correctness (zero rows, counted, never leaked),
//! * ordered ticket delivery under concurrent submitters,
//! * backpressure honors the queue bound; shutdown drains cleanly.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use layermerge::serve::{self, ServeCfg, Session};
use layermerge::util::tensor::Tensor;

const B: usize = 4; // spec batch size for the mock deployments
const TAIL: [usize; 1] = [3]; // per-row feature length

/// Deterministic per-row "network": row r of the output is a fixed
/// function of row r of the input ONLY (batch-content independence — the
/// property that makes micro-batching bit-exact).  out_tail = [2].
fn row_fn(row: &[f32]) -> [f32; 2] {
    let sum: f32 = row.iter().sum();
    let sq: f32 = row.iter().map(|v| v * v).sum();
    [sum * 0.5 + 1.0, sq - row[0]]
}

fn mock_backend(x: &Tensor, _t: Option<&Tensor>) -> anyhow::Result<Tensor> {
    anyhow::ensure!(x.dims[0] == B, "backend must see full batches");
    let rl: usize = x.dims[1..].iter().product();
    let mut out = Tensor::zeros(&[x.dims[0], 2]);
    for r in 0..x.dims[0] {
        let y = row_fn(&x.data[r * rl..(r + 1) * rl]);
        out.data[r * 2..(r + 1) * 2].copy_from_slice(&y);
    }
    Ok(out)
}

fn mock_session(workers: usize, queue_cap: usize) -> Session {
    Session::from_fn(B, &TAIL, false, ServeCfg { workers, queue_cap }, mock_backend)
}

fn req(rows: usize, seed: f32) -> Tensor {
    let rl: usize = TAIL.iter().product();
    Tensor::new(
        vec![rows, TAIL[0]],
        (0..rows * rl).map(|i| seed + i as f32 * 0.25).collect(),
    )
}

/// Expected output for a request, computed row-by-row on the host — what
/// any batch placement must reproduce exactly.
fn expect(x: &Tensor) -> Vec<f32> {
    let rl: usize = TAIL.iter().product();
    (0..x.dims[0])
        .flat_map(|r| row_fn(&x.data[r * rl..(r + 1) * rl]))
        .collect()
}

#[test]
fn full_batch_submit_is_bit_identical_to_infer() {
    let sess = mock_session(2, 16);
    let x = req(B, 0.5);
    let direct = sess.infer(&x, None).unwrap();
    let queued = sess.submit(x.clone()).unwrap().wait().unwrap();
    // bit-identical: same computation, same batch placement, zero padding
    assert_eq!(queued.dims, direct.dims);
    assert_eq!(queued.data, direct.data);
}

#[test]
fn sub_batch_submits_are_bit_identical_to_per_row_oracle() {
    let sess = mock_session(2, 64);
    // mixed request sizes: 1, 3, 2, 4, 1 rows
    let reqs: Vec<Tensor> = [1usize, 3, 2, 4, 1]
        .iter()
        .enumerate()
        .map(|(i, &rows)| req(rows, i as f32 * 10.0))
        .collect();
    let tickets: Vec<_> = reqs
        .iter()
        .map(|x| sess.submit(x.clone()).unwrap())
        .collect();
    for (x, tk) in reqs.iter().zip(tickets) {
        let got = tk.wait().unwrap();
        assert_eq!(got.dims, vec![x.dims[0], 2]);
        assert_eq!(got.data, expect(x), "request of {} rows", x.dims[0]);
    }
}

#[test]
fn tail_padding_is_counted_and_padded_rows_are_dropped() {
    let sess = mock_session(1, 16);
    // 3 rows -> 1 padded row in a B=4 batch
    let x = req(3, 7.0);
    let got = sess.submit(x.clone()).unwrap().wait().unwrap();
    assert_eq!(got.data, expect(&x));
    // stats are bumped before the ticket resolves, so they're visible now
    let s = sess.stats();
    assert_eq!(s.batches, 1);
    assert_eq!(s.padded_rows, B - 3);
    assert_eq!(s.rows, 3);
    assert_eq!(s.requests, 1);
    // padded output rows are dropped: result has exactly 3 rows
    assert_eq!(got.dims, vec![3, 2]);
}

#[test]
fn padded_region_content_is_zero() {
    let seen: Arc<Mutex<Vec<Vec<f32>>>> = Arc::new(Mutex::new(Vec::new()));
    let seen2 = Arc::clone(&seen);
    let sess = Session::from_fn(
        B,
        &TAIL,
        false,
        ServeCfg { workers: 1, queue_cap: 16 },
        move |x, t| {
            seen2.lock().unwrap().push(x.data.clone());
            mock_backend(x, t)
        },
    );
    let x = req(2, 3.0);
    sess.submit(x.clone()).unwrap().wait().unwrap();
    let batches = seen.lock().unwrap();
    assert_eq!(batches.len(), 1);
    let rl: usize = TAIL.iter().product();
    let data = &batches[0];
    assert_eq!(&data[..2 * rl], &x.data[..]);
    assert!(data[2 * rl..].iter().all(|&v| v == 0.0), "tail not zero-padded");
}

#[test]
fn ordered_delivery_under_concurrent_submitters() {
    let sess = mock_session(3, 128);
    let n_threads = 6;
    let per_thread = 40;
    std::thread::scope(|s| {
        for th in 0..n_threads {
            let sess = &sess;
            s.spawn(move || {
                for i in 0..per_thread {
                    // encode (thread, i) in the request payload
                    let rows = 1 + (th + i) % B;
                    let seed = (th * 1000 + i) as f32;
                    let x = req(rows, seed);
                    let want = expect(&x);
                    let got = sess.submit(x).unwrap().wait().unwrap();
                    // each ticket resolves to ITS OWN rows, in order,
                    // regardless of how requests interleaved in batches
                    assert_eq!(got.data, want, "thread {th} request {i}");
                }
            });
        }
    });
    let s = sess.stats();
    assert_eq!(s.requests, n_threads * per_thread);
    // coalescing happened: fewer batches than requests
    assert!(
        s.batches <= s.requests,
        "batches {} > requests {}",
        s.batches,
        s.requests
    );
}

#[test]
fn backpressure_honors_queue_bound() {
    // slow backend so the queue actually fills
    let sess = Session::from_fn(
        B,
        &TAIL,
        false,
        ServeCfg { workers: 1, queue_cap: 2 },
        |x, t| {
            std::thread::sleep(std::time::Duration::from_millis(2));
            mock_backend(x, t)
        },
    );
    std::thread::scope(|s| {
        for th in 0..4 {
            let sess = &sess;
            s.spawn(move || {
                for i in 0..20 {
                    let x = req(1 + (th + i) % B, (th * 100 + i) as f32);
                    let want = expect(&x);
                    let got = sess.submit(x).unwrap().wait().unwrap();
                    assert_eq!(got.data, want);
                }
            });
        }
    });
    let s = sess.stats();
    assert_eq!(s.requests, 80);
    // the bounded queue never held more than its capacity
    assert!(s.max_queue <= 2, "queue peaked at {} > cap 2", s.max_queue);
}

#[test]
fn shutdown_drains_accepted_requests() {
    let sess = Session::from_fn(
        B,
        &TAIL,
        false,
        ServeCfg { workers: 1, queue_cap: 64 },
        |x, t| {
            std::thread::sleep(std::time::Duration::from_millis(1));
            mock_backend(x, t)
        },
    );
    let reqs: Vec<Tensor> = (0..10).map(|i| req(1 + i % B, i as f32)).collect();
    let tickets: Vec<_> = reqs
        .iter()
        .map(|x| sess.submit(x.clone()).unwrap())
        .collect();
    // close + join while most requests are still queued
    sess.shutdown();
    for (x, tk) in reqs.iter().zip(tickets) {
        let got = tk.wait().unwrap();
        assert_eq!(got.data, expect(x), "request dropped on shutdown");
    }
}

#[test]
fn submit_after_close_errors() {
    let sess = mock_session(1, 8);
    sess.close();
    let err = sess.submit(req(1, 0.0)).unwrap_err();
    assert!(format!("{err}").contains("closed"), "{err}");
}

#[test]
fn oversized_and_misshapen_requests_are_rejected() {
    let sess = mock_session(1, 8);
    let err = sess.submit(req(B + 1, 0.0)).unwrap_err();
    assert!(format!("{err}").contains("exceed"), "{err}");
    let err = sess
        .submit(Tensor::new(vec![1, TAIL[0] + 1], vec![0.0; TAIL[0] + 1]))
        .unwrap_err();
    assert!(format!("{err}").contains("don't match"), "{err}");
    // t on a non-diffusion session is rejected
    let err = sess
        .submit_with(req(1, 0.0), Some(Tensor::new(vec![1], vec![0.0])))
        .unwrap_err();
    assert!(format!("{err}").contains("timestep"), "{err}");
}

#[test]
fn backend_errors_propagate_to_every_ticket_in_the_batch() {
    let sess = Session::from_fn(
        B,
        &TAIL,
        false,
        ServeCfg { workers: 1, queue_cap: 16 },
        |_, _| anyhow::bail!("device on fire"),
    );
    let t1 = sess.submit(req(2, 0.0)).unwrap();
    let t2 = sess.submit(req(2, 5.0)).unwrap();
    for t in [t1, t2] {
        let err = t.wait().unwrap_err();
        assert!(format!("{err}").contains("device on fire"), "{err}");
    }
}

#[test]
fn backend_panics_become_ticket_errors_and_worker_survives() {
    let calls = Arc::new(AtomicUsize::new(0));
    let c2 = Arc::clone(&calls);
    let sess = Session::from_fn(
        B,
        &TAIL,
        false,
        ServeCfg { workers: 1, queue_cap: 16 },
        move |x, t| {
            if c2.fetch_add(1, Ordering::Relaxed) == 0 {
                panic!("kaboom");
            }
            mock_backend(x, t)
        },
    );
    let err = sess.submit(req(1, 0.0)).unwrap().wait().unwrap_err();
    assert!(format!("{err}").contains("panicked"), "{err}");
    // the worker survived the panic and still serves the next request
    let x = req(2, 1.0);
    let got = sess.submit(x.clone()).unwrap().wait().unwrap();
    assert_eq!(got.data, expect(&x));
}

#[test]
fn single_client_coalesces_nothing_many_clients_coalesce() {
    // drive() wiring: closed-loop clients, latency + throughput stats
    let sess = mock_session(2, 64);
    let r1 = serve::drive(&sess, 1, 20, |_, i| (req(1, i as f32), None)).unwrap();
    assert_eq!(r1.requests, 20);
    assert_eq!(r1.rows, 20);
    assert!(r1.rows_per_s > 0.0 && r1.p50_ms >= 0.0);
    // closed-loop single client: every batch carries exactly one request
    assert_eq!(r1.batches, 20);

    // a deliberately slow single worker: 8 waiting clients must pile up
    // in the queue, so batches coalesce and come out fewer than requests
    let slow = Session::from_fn(
        B,
        &TAIL,
        false,
        ServeCfg { workers: 1, queue_cap: 64 },
        |x, t| {
            std::thread::sleep(std::time::Duration::from_millis(2));
            mock_backend(x, t)
        },
    );
    let r8 = serve::drive(&slow, 8, 20, |c, i| (req(1, (c * 100 + i) as f32), None))
        .unwrap();
    assert_eq!(r8.requests, 160);
    assert!(
        r8.batches < r8.requests,
        "no coalescing: {} batches for {} requests",
        r8.batches,
        r8.requests
    );
}
