//! The serve suite: micro-batched queue machinery, host-only (no PJRT
//! runtime needed — sessions run on a deterministic host backend via
//! `Session::from_fn`, the same coalesce/pad/split/deliver path a
//! deployed `CompiledPlan` uses).
//!
//! Pins the ISSUE-2 acceptance properties:
//! * batched-vs-one-shot numerics parity (bit-identical),
//! * tail-padding correctness (zero rows, counted, never leaked),
//! * ordered ticket delivery under concurrent submitters,
//! * backpressure honors the queue bound; shutdown drains cleanly.
//!
//! The ISSUE-6 robustness semantics (session side; the wire side lives
//! in `tests/serve_net.rs`):
//! * bounded ticket waits hand the ticket back instead of blocking,
//! * deadlines fail fast at submit and at dispatch, typed and counted,
//! * admission control sheds only with a warm service EWMA,
//! * backend faults are typed `BackendFailed`, counted per batch,
//! * the open-loop driver separates shed/expired/failed from successes.
//!
//! And the ISSUE-4 window-policy semantics:
//! * a partial batch dispatches no later than `max_wait_us` after its
//!   first request (bounded-wait guarantee),
//! * a filled batch preempts the window, and the expiry-vs-fill race is
//!   bit-identical to one-shot either way,
//! * `close()` flushes a held partial batch immediately,
//! * `infer` counts into `ServeStats` alongside `submit`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use layermerge::serve::{self, BatchPolicy, ServeCfg, ServeError, Session};
use layermerge::util::tensor::Tensor;

const B: usize = 4; // spec batch size for the mock deployments
const TAIL: [usize; 1] = [3]; // per-row feature length

/// Deterministic per-row "network": row r of the output is a fixed
/// function of row r of the input ONLY (batch-content independence — the
/// property that makes micro-batching bit-exact).  out_tail = [2].
fn row_fn(row: &[f32]) -> [f32; 2] {
    let sum: f32 = row.iter().sum();
    let sq: f32 = row.iter().map(|v| v * v).sum();
    [sum * 0.5 + 1.0, sq - row[0]]
}

fn mock_backend(x: &Tensor, _t: Option<&Tensor>) -> anyhow::Result<Tensor> {
    anyhow::ensure!(x.dims[0] == B, "backend must see full batches");
    let rl: usize = x.dims[1..].iter().product();
    let mut out = Tensor::zeros(&[x.dims[0], 2]);
    for r in 0..x.dims[0] {
        let y = row_fn(&x.data[r * rl..(r + 1) * rl]);
        out.data[r * 2..(r + 1) * 2].copy_from_slice(&y);
    }
    Ok(out)
}

fn mock_session(workers: usize, queue_cap: usize) -> Session {
    let cfg = ServeCfg { workers, queue_cap, policy: BatchPolicy::Greedy, ..ServeCfg::default() };
    Session::from_fn(B, &TAIL, false, cfg, mock_backend)
}

fn req(rows: usize, seed: f32) -> Tensor {
    let rl: usize = TAIL.iter().product();
    Tensor::new(
        vec![rows, TAIL[0]],
        (0..rows * rl).map(|i| seed + i as f32 * 0.25).collect(),
    )
}

/// Expected output for a request, computed row-by-row on the host — what
/// any batch placement must reproduce exactly.
fn expect(x: &Tensor) -> Vec<f32> {
    let rl: usize = TAIL.iter().product();
    (0..x.dims[0])
        .flat_map(|r| row_fn(&x.data[r * rl..(r + 1) * rl]))
        .collect()
}

#[test]
fn full_batch_submit_is_bit_identical_to_infer() {
    let sess = mock_session(2, 16);
    let x = req(B, 0.5);
    let direct = sess.infer(&x, None).unwrap();
    let queued = sess.submit(x.clone()).unwrap().wait().unwrap();
    // bit-identical: same computation, same batch placement, zero padding
    assert_eq!(queued.dims, direct.dims);
    assert_eq!(queued.data, direct.data);
}

#[test]
fn sub_batch_submits_are_bit_identical_to_per_row_oracle() {
    let sess = mock_session(2, 64);
    // mixed request sizes: 1, 3, 2, 4, 1 rows
    let reqs: Vec<Tensor> = [1usize, 3, 2, 4, 1]
        .iter()
        .enumerate()
        .map(|(i, &rows)| req(rows, i as f32 * 10.0))
        .collect();
    let tickets: Vec<_> = reqs
        .iter()
        .map(|x| sess.submit(x.clone()).unwrap())
        .collect();
    for (x, tk) in reqs.iter().zip(tickets) {
        let got = tk.wait().unwrap();
        assert_eq!(got.dims, vec![x.dims[0], 2]);
        assert_eq!(got.data, expect(x), "request of {} rows", x.dims[0]);
    }
}

#[test]
fn tail_padding_is_counted_and_padded_rows_are_dropped() {
    let sess = mock_session(1, 16);
    // 3 rows -> 1 padded row in a B=4 batch
    let x = req(3, 7.0);
    let got = sess.submit(x.clone()).unwrap().wait().unwrap();
    assert_eq!(got.data, expect(&x));
    // stats are bumped before the ticket resolves, so they're visible now
    let s = sess.stats();
    assert_eq!(s.batches, 1);
    assert_eq!(s.padded_rows, B - 3);
    assert_eq!(s.rows, 3);
    assert_eq!(s.requests, 1);
    // padded output rows are dropped: result has exactly 3 rows
    assert_eq!(got.dims, vec![3, 2]);
}

#[test]
fn padded_region_content_is_zero() {
    let seen: Arc<Mutex<Vec<Vec<f32>>>> = Arc::new(Mutex::new(Vec::new()));
    let seen2 = Arc::clone(&seen);
    let sess = Session::from_fn(
        B,
        &TAIL,
        false,
        ServeCfg { workers: 1, queue_cap: 16, policy: BatchPolicy::Greedy, ..ServeCfg::default() },
        move |x, t| {
            seen2.lock().unwrap().push(x.data.clone());
            mock_backend(x, t)
        },
    );
    let x = req(2, 3.0);
    sess.submit(x.clone()).unwrap().wait().unwrap();
    let batches = seen.lock().unwrap();
    assert_eq!(batches.len(), 1);
    let rl: usize = TAIL.iter().product();
    let data = &batches[0];
    assert_eq!(&data[..2 * rl], &x.data[..]);
    assert!(data[2 * rl..].iter().all(|&v| v == 0.0), "tail not zero-padded");
}

#[test]
fn ordered_delivery_under_concurrent_submitters() {
    let sess = mock_session(3, 128);
    let n_threads = 6;
    let per_thread = 40;
    std::thread::scope(|s| {
        for th in 0..n_threads {
            let sess = &sess;
            s.spawn(move || {
                for i in 0..per_thread {
                    // encode (thread, i) in the request payload
                    let rows = 1 + (th + i) % B;
                    let seed = (th * 1000 + i) as f32;
                    let x = req(rows, seed);
                    let want = expect(&x);
                    let got = sess.submit(x).unwrap().wait().unwrap();
                    // each ticket resolves to ITS OWN rows, in order,
                    // regardless of how requests interleaved in batches
                    assert_eq!(got.data, want, "thread {th} request {i}");
                }
            });
        }
    });
    let s = sess.stats();
    assert_eq!(s.requests, n_threads * per_thread);
    // coalescing happened: fewer batches than requests
    assert!(
        s.batches <= s.requests,
        "batches {} > requests {}",
        s.batches,
        s.requests
    );
}

#[test]
fn backpressure_honors_queue_bound() {
    // slow backend so the queue actually fills
    let sess = Session::from_fn(
        B,
        &TAIL,
        false,
        ServeCfg { workers: 1, queue_cap: 2, policy: BatchPolicy::Greedy, ..ServeCfg::default() },
        |x, t| {
            std::thread::sleep(std::time::Duration::from_millis(2));
            mock_backend(x, t)
        },
    );
    std::thread::scope(|s| {
        for th in 0..4 {
            let sess = &sess;
            s.spawn(move || {
                for i in 0..20 {
                    let x = req(1 + (th + i) % B, (th * 100 + i) as f32);
                    let want = expect(&x);
                    let got = sess.submit(x).unwrap().wait().unwrap();
                    assert_eq!(got.data, want);
                }
            });
        }
    });
    let s = sess.stats();
    assert_eq!(s.requests, 80);
    // the bounded queue never held more than its capacity
    assert!(s.max_queue <= 2, "queue peaked at {} > cap 2", s.max_queue);
}

#[test]
fn shutdown_drains_accepted_requests() {
    let sess = Session::from_fn(
        B,
        &TAIL,
        false,
        ServeCfg { workers: 1, queue_cap: 64, policy: BatchPolicy::Greedy, ..ServeCfg::default() },
        |x, t| {
            std::thread::sleep(std::time::Duration::from_millis(1));
            mock_backend(x, t)
        },
    );
    let reqs: Vec<Tensor> = (0..10).map(|i| req(1 + i % B, i as f32)).collect();
    let tickets: Vec<_> = reqs
        .iter()
        .map(|x| sess.submit(x.clone()).unwrap())
        .collect();
    // close + join while most requests are still queued
    sess.shutdown();
    for (x, tk) in reqs.iter().zip(tickets) {
        let got = tk.wait().unwrap();
        assert_eq!(got.data, expect(x), "request dropped on shutdown");
    }
}

#[test]
fn submit_after_close_errors() {
    let sess = mock_session(1, 8);
    sess.close();
    let err = sess.submit(req(1, 0.0)).unwrap_err();
    assert!(format!("{err}").contains("closed"), "{err}");
}

#[test]
fn oversized_and_misshapen_requests_are_rejected() {
    let sess = mock_session(1, 8);
    let err = sess.submit(req(B + 1, 0.0)).unwrap_err();
    assert!(format!("{err}").contains("exceed"), "{err}");
    let err = sess
        .submit(Tensor::new(vec![1, TAIL[0] + 1], vec![0.0; TAIL[0] + 1]))
        .unwrap_err();
    assert!(format!("{err}").contains("don't match"), "{err}");
    // t on a non-diffusion session is rejected
    let err = sess
        .submit_with(req(1, 0.0), Some(Tensor::new(vec![1], vec![0.0])))
        .unwrap_err();
    assert!(format!("{err}").contains("timestep"), "{err}");
}

#[test]
fn backend_errors_propagate_to_every_ticket_in_the_batch() {
    let sess = Session::from_fn(
        B,
        &TAIL,
        false,
        ServeCfg { workers: 1, queue_cap: 16, policy: BatchPolicy::Greedy, ..ServeCfg::default() },
        |_, _| anyhow::bail!("device on fire"),
    );
    let t1 = sess.submit(req(2, 0.0)).unwrap();
    let t2 = sess.submit(req(2, 5.0)).unwrap();
    for t in [t1, t2] {
        let err = t.wait().unwrap_err();
        assert!(format!("{err}").contains("device on fire"), "{err}");
    }
}

#[test]
fn backend_panics_become_ticket_errors_and_worker_survives() {
    let calls = Arc::new(AtomicUsize::new(0));
    let c2 = Arc::clone(&calls);
    let sess = Session::from_fn(
        B,
        &TAIL,
        false,
        ServeCfg { workers: 1, queue_cap: 16, policy: BatchPolicy::Greedy, ..ServeCfg::default() },
        move |x, t| {
            if c2.fetch_add(1, Ordering::Relaxed) == 0 {
                panic!("kaboom");
            }
            mock_backend(x, t)
        },
    );
    let err = sess.submit(req(1, 0.0)).unwrap().wait().unwrap_err();
    assert!(format!("{err}").contains("panicked"), "{err}");
    // the worker survived the panic and still serves the next request
    let x = req(2, 1.0);
    let got = sess.submit(x.clone()).unwrap().wait().unwrap();
    assert_eq!(got.data, expect(&x));
}

#[test]
fn single_client_coalesces_nothing_many_clients_coalesce() {
    // drive() wiring: closed-loop clients, latency + throughput stats
    let sess = mock_session(2, 64);
    let r1 = serve::drive(&sess, 1, 20, |_, i| (req(1, i as f32), None)).unwrap();
    assert_eq!(r1.requests, 20);
    assert_eq!(r1.rows, 20);
    assert!(r1.rows_per_s > 0.0 && r1.p50_ms >= 0.0);
    // closed-loop single client: every batch carries exactly one request
    assert_eq!(r1.batches, 20);

    // a deliberately slow single worker: 8 waiting clients must pile up
    // in the queue, so batches coalesce and come out fewer than requests
    let slow = Session::from_fn(
        B,
        &TAIL,
        false,
        ServeCfg { workers: 1, queue_cap: 64, policy: BatchPolicy::Greedy, ..ServeCfg::default() },
        |x, t| {
            std::thread::sleep(std::time::Duration::from_millis(2));
            mock_backend(x, t)
        },
    );
    let r8 = serve::drive(&slow, 8, 20, |c, i| (req(1, (c * 100 + i) as f32), None))
        .unwrap();
    assert_eq!(r8.requests, 160);
    assert!(
        r8.batches < r8.requests,
        "no coalescing: {} batches for {} requests",
        r8.batches,
        r8.requests
    );
}

fn window_session(workers: usize, max_wait_us: u64) -> Session {
    let cfg = ServeCfg {
        workers,
        queue_cap: 64,
        policy: BatchPolicy::Window { max_wait_us },
        ..ServeCfg::default()
    };
    Session::from_fn(B, &TAIL, false, cfg, mock_backend)
}

#[test]
fn window_partial_batch_dispatches_within_the_bound() {
    // 30ms window, one 1-row request, nothing else arrives: the batch
    // must be held for (roughly) the window, then dispatched padded —
    // never stranded, never shipped the instant it arrives
    let window_us = 30_000u64;
    let sess = window_session(1, window_us);
    let x = req(1, 2.0);
    let t0 = Instant::now();
    let got = sess.submit(x.clone()).unwrap().wait().unwrap();
    let waited = t0.elapsed();
    assert_eq!(got.data, expect(&x));
    assert!(
        waited >= Duration::from_micros(window_us / 2),
        "partial batch dispatched too early ({waited:?} << {window_us}us window)"
    );
    assert!(
        waited < Duration::from_micros(window_us * 20),
        "bounded wait violated: {waited:?} for a {window_us}us window"
    );
    let s = sess.stats();
    assert_eq!(s.batches, 1);
    assert_eq!(s.padded_rows, B - 1);
    assert_eq!(s.expired_windows, 1, "dispatch not attributed to window expiry");
}

#[test]
fn window_fill_preempts_expiry_and_stays_bit_identical() {
    // a very long window with requests that tile into full batches: fill
    // must preempt the window (no half-second stall), and every ticket
    // must still match the per-row oracle exactly
    let sess = window_session(2, 500_000);
    let reqs: Vec<Tensor> = [1usize, 3, 2, 2] // (1+3) and (2+2) tile to B=4
        .iter()
        .enumerate()
        .map(|(i, &rows)| req(rows, i as f32 * 5.0))
        .collect();
    let t0 = Instant::now();
    let tickets: Vec<_> = reqs
        .iter()
        .map(|x| sess.submit(x.clone()).unwrap())
        .collect();
    for (x, tk) in reqs.iter().zip(tickets) {
        let got = tk.wait().unwrap();
        assert_eq!(got.data, expect(x), "fill-vs-expiry race broke row parity");
    }
    assert!(
        t0.elapsed() < Duration::from_millis(250),
        "filled batches waited out the window: {:?}",
        t0.elapsed()
    );
    let s = sess.stats();
    assert_eq!(s.batches, 2);
    assert_eq!(s.padded_rows, 0);
    assert_eq!(s.expired_windows, 0);
}

#[test]
fn window_expiry_result_matches_one_shot_exactly() {
    // the same rows served two ways — held until the window expires
    // (padded partial batch) vs a synchronous full-batch infer — must be
    // bit-identical in the rows they share
    let sess = window_session(1, 5_000);
    let x = req(2, 9.0);
    let queued = sess.submit(x.clone()).unwrap().wait().unwrap();
    let mut full = Tensor::zeros(&[B, TAIL[0]]);
    full.data[..x.data.len()].copy_from_slice(&x.data);
    let oneshot = sess.infer(&full, None).unwrap();
    assert_eq!(queued.data[..], oneshot.data[..2 * 2]);
}

#[test]
fn close_flushes_a_held_partial_batch_immediately() {
    // 2s window; close() must dispatch the held partial at once — no
    // request is stranded for the full window on shutdown
    let sess = window_session(1, 2_000_000);
    let x = req(2, 1.0);
    let tk = sess.submit(x.clone()).unwrap();
    std::thread::sleep(Duration::from_millis(5)); // let the worker hold it
    let t0 = Instant::now();
    sess.close();
    let got = tk.wait().unwrap();
    assert!(
        t0.elapsed() < Duration::from_millis(500),
        "close() left the partial batch waiting: {:?}",
        t0.elapsed()
    );
    assert_eq!(got.data, expect(&x), "flushed batch lost row parity");
}

#[test]
fn infer_counts_into_stats_alongside_submit() {
    let sess = mock_session(1, 8);
    let full = req(B, 0.0);
    sess.infer(&full, None).unwrap();
    sess.infer(&full, None).unwrap();
    let got = sess.submit(req(3, 1.0)).unwrap().wait().unwrap();
    assert_eq!(got.dims, vec![3, 2]);
    let s = sess.stats();
    assert_eq!(s.requests, 3, "infer calls must count as requests");
    assert_eq!(s.batches, 3, "infer calls must count as batches");
    assert_eq!(s.rows, 2 * B + 3, "infer rows must count");
    assert_eq!(s.padded_rows, B - 3, "infer never pads");
}

#[test]
fn adaptive_policy_serves_and_bounds_its_window() {
    let cap_us = 5_000u64;
    let cfg = ServeCfg {
        workers: 1,
        queue_cap: 64,
        policy: BatchPolicy::Adaptive { target_occupancy: 0.9, max_wait_us: cap_us },
        ..ServeCfg::default()
    };
    let sess = Session::from_fn(B, &TAIL, false, cfg, |x, t| {
        std::thread::sleep(Duration::from_millis(1));
        mock_backend(x, t)
    });
    let r = serve::drive(&sess, 4, 10, |c, i| (req(1, (c * 50 + i) as f32), None))
        .unwrap();
    assert_eq!(r.requests, 40);
    assert!(r.occupancy > 0.0 && r.occupancy <= 1.0, "occupancy {}", r.occupancy);
    let s = sess.stats();
    assert!(
        s.cur_window_us as u64 <= cap_us,
        "adaptive window {} exceeded its latency cap {cap_us}",
        s.cur_window_us
    );
    assert_eq!(s.rows, 40);
}

#[test]
fn wait_timeout_hands_the_ticket_back_then_the_result() {
    let sess = Session::from_fn(
        B,
        &TAIL,
        false,
        ServeCfg { workers: 1, queue_cap: 16, policy: BatchPolicy::Greedy, ..ServeCfg::default() },
        |x, t| {
            std::thread::sleep(Duration::from_millis(50));
            mock_backend(x, t)
        },
    );
    let x = req(1, 4.0);
    let tk = sess.submit(x.clone()).unwrap();
    // 5ms against a 50ms batch: the bounded wait must return the ticket,
    // not block to completion
    let tk = match tk.wait_timeout(Duration::from_millis(5)) {
        Err(tk) => tk,
        Ok(r) => panic!("a 50ms batch cannot finish inside a 5ms wait: {r:?}"),
    };
    // the handed-back ticket still resolves to the right rows
    let got = tk
        .wait_timeout(Duration::from_secs(10))
        .expect("batch must finish well inside 10s")
        .unwrap();
    assert_eq!(got.data, expect(&x));
}

#[test]
fn past_deadline_fails_fast_without_enqueue() {
    let sess = mock_session(1, 8);
    let d = Instant::now();
    std::thread::sleep(Duration::from_millis(2));
    let err = sess.submit_deadline(req(1, 0.0), None, Some(d)).unwrap_err();
    assert_eq!(err, ServeError::DeadlineExceeded);
    let s = sess.stats();
    assert_eq!(s.expired_requests, 1);
    assert_eq!(s.requests, 0, "an expired request must never reach a batch");
}

#[test]
fn queued_request_expires_at_dispatch_while_ewma_is_cold() {
    // worker held 40ms by the first batch; the deadlined request behind
    // it is ADMITTED (no EWMA signal yet -> admission control stays out
    // of the way) and must then expire at dispatch time, typed
    let sess = Session::from_fn(
        B,
        &TAIL,
        false,
        ServeCfg { workers: 1, queue_cap: 16, policy: BatchPolicy::Greedy, ..ServeCfg::default() },
        |x, t| {
            std::thread::sleep(Duration::from_millis(40));
            mock_backend(x, t)
        },
    );
    let t1 = sess.submit(req(B, 0.0)).unwrap();
    std::thread::sleep(Duration::from_millis(10)); // the worker is mid-batch
    let d = Instant::now() + Duration::from_millis(5);
    let t2 = sess.submit_deadline(req(1, 1.0), None, Some(d)).unwrap();
    assert_eq!(t2.wait_coded().unwrap_err(), ServeError::DeadlineExceeded);
    t1.wait().unwrap();
    let s = sess.stats();
    assert_eq!(s.expired_requests, 1);
    assert_eq!(s.shed_requests, 0, "cold EWMA must not shed");
}

#[test]
fn admission_control_sheds_with_a_warm_ewma() {
    let cfg = ServeCfg {
        workers: 1,
        queue_cap: 64,
        policy: BatchPolicy::Greedy,
        slo: Some(Duration::from_millis(5)),
        ..ServeCfg::default()
    };
    let sess = Session::from_fn(B, &TAIL, false, cfg, |x, t| {
        std::thread::sleep(Duration::from_millis(30));
        mock_backend(x, t)
    });
    // cold EWMA: always admitted; this warms the service estimate
    sess.submit(req(B, 0.0)).unwrap().wait().unwrap();
    assert!(sess.ewma_service_us() >= 20_000, "{}", sess.ewma_service_us());
    // warm: one ~30ms predicted batch against a 5ms SLO -> shed
    let err = sess.submit_deadline(req(1, 1.0), None, None).unwrap_err();
    match err {
        ServeError::Shed { predicted_us, budget_us, .. } => {
            assert!(predicted_us > budget_us, "{predicted_us} <= {budget_us}");
            assert_eq!(budget_us, 5_000, "budget must be the configured SLO");
        }
        other => panic!("expected Shed, got {other:?}"),
    }
    assert_eq!(sess.stats().shed_requests, 1);
}

#[test]
fn backend_failures_are_typed_and_count_failed_batches() {
    let calls = Arc::new(AtomicUsize::new(0));
    let c2 = Arc::clone(&calls);
    let sess = Session::from_fn(
        B,
        &TAIL,
        false,
        ServeCfg { workers: 1, queue_cap: 16, policy: BatchPolicy::Greedy, ..ServeCfg::default() },
        move |x, t| match c2.fetch_add(1, Ordering::Relaxed) {
            0 => anyhow::bail!("transient device fault"),
            1 => panic!("kaboom"),
            _ => mock_backend(x, t),
        },
    );
    let e1 = sess.submit(req(1, 0.0)).unwrap().wait_coded().unwrap_err();
    assert!(
        matches!(e1, ServeError::BackendFailed(ref m) if m.contains("transient")),
        "{e1:?}"
    );
    let e2 = sess.submit(req(1, 1.0)).unwrap().wait_coded().unwrap_err();
    assert!(
        matches!(e2, ServeError::BackendFailed(ref m) if m.contains("panicked")),
        "{e2:?}"
    );
    // the worker survived both faults; the third batch serves
    let x = req(2, 2.0);
    assert_eq!(sess.submit(x.clone()).unwrap().wait().unwrap().data, expect(&x));
    let s = sess.stats();
    assert_eq!(s.failed_batches, 2, "each faulted batch counts exactly once");
}

#[test]
fn drive_open_deadline_separates_outcomes_from_successes() {
    // 20ms batches, 5ms deadlines, arrivals far above capacity: most
    // requests shed or expire, and the report must keep them out of the
    // success percentiles while still accounting for every completion
    let sess = Session::from_fn(
        B,
        &TAIL,
        false,
        ServeCfg { workers: 1, queue_cap: 64, policy: BatchPolicy::Greedy, ..ServeCfg::default() },
        |x, t| {
            std::thread::sleep(Duration::from_millis(20));
            mock_backend(x, t)
        },
    );
    let r = serve::drive_open_deadline(
        &sess,
        2_000.0,
        30,
        11,
        Some(Duration::from_millis(5)),
        |_, i| (req(1, i as f32), None),
    )
    .unwrap();
    assert_eq!(r.requests, 30);
    assert_eq!(
        r.ok_requests + r.shed + r.expired + r.failed,
        30,
        "classification must partition completions: {r:?}"
    );
    assert!(r.shed + r.expired > 0, "deadlines never engaged: {r:?}");
    assert!(r.ok_requests < 30, "nothing can be served this overloaded: {r:?}");
    if r.ok_requests == 0 {
        assert!(r.p50_ms.is_nan(), "empty success set must report NaN percentiles");
    } else {
        assert!(r.p50_ms.is_finite());
    }
}

#[test]
fn open_loop_drive_reports_queue_service_split() {
    let sess = window_session(2, 1_000);
    let r = serve::drive_open(&sess, 2_000.0, 40, 7, |_, i| (req(1, i as f32), None))
        .unwrap();
    assert_eq!(r.requests, 40);
    assert_eq!(r.rows, 40);
    assert!((r.arrival_rps - 2_000.0).abs() < 1e-9);
    assert!(r.queue_ms >= 0.0 && r.service_ms >= 0.0);
    assert!(r.p95_ms >= r.p50_ms, "p95 {} < p50 {}", r.p95_ms, r.p50_ms);
    assert!(r.occupancy > 0.0 && r.occupancy <= 1.0);
    // determinism of the arrival schedule: same seed, same generated gaps
    // (latencies differ, but the request/row accounting must not)
    let sess2 = window_session(2, 1_000);
    let r2 = serve::drive_open(&sess2, 2_000.0, 40, 7, |_, i| (req(1, i as f32), None))
        .unwrap();
    assert_eq!(r2.requests, r.requests);
    assert_eq!(r2.rows, r.rows);
}
