//! The multi-tenant fleet suite: `serve::Fleet` end to end on
//! deterministic host backends (no PJRT runtime needed).
//!
//! Pins the ISSUE-7 acceptance properties:
//! * shared-weight dedup: deploying the same plan onto a second tenant
//!   adds **zero** unique bytes and the whole second upload lands in
//!   `dedup_saved_bytes` — byte-exact accounting across a 3-rung ladder,
//! * weighted-fair scheduling: a flooding tenant cannot starve a light
//!   one — the light tenant's requests complete while the flood is
//!   still queued,
//! * deadline-aware routing: an idle ladder serves the cheapest rung, a
//!   backed-up cheap rung falls back up the ladder, and when no rung
//!   can meet the deadline the request is shed with the typed
//!   [`ServeError::Shed`],
//! * graceful hot swap: requests admitted before `swap_fn` complete
//!   bit-identically on the old dispatch, requests after run on the
//!   new one, and nothing is dropped,
//! * `par::shutdown_pool()` fails loudly while a fleet is live,
//! * the TCP tier routes `Infer` frames by tenant and `/stats` carries
//!   per-tenant breakdowns plus the fleet dedup/router counters.
//!
//! The TCP test binds `127.0.0.1:0`; where loopback sockets are
//! unavailable it skips cleanly instead of failing.

use std::panic;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use layermerge::exec::{Format, Plan};
use layermerge::serve::fleet::{Fleet, FleetCfg, TenantCfg};
use layermerge::serve::net::{NetCfg, NetClient, NetServer};
use layermerge::serve::{BatchPolicy, Engine, ServeError};
use layermerge::util::tensor::Tensor;

const TAIL: [usize; 1] = [3]; // per-row feature length for mock rungs

/// A deterministic mock rung: out[r] = (sum of row r, tag).  The tag
/// makes outputs attributable to a specific dispatch fn (which ladder
/// rung served the row; which side of a hot swap produced it), and the
/// optional sleep gives the rung a controllable service time.
fn rung_fn(
    tag: f32,
    service: Duration,
) -> impl Fn(&Tensor, Option<&Tensor>) -> anyhow::Result<Tensor> + Send + Sync + 'static {
    move |x, _t| {
        if !service.is_zero() {
            thread::sleep(service);
        }
        let rl: usize = x.dims[1..].iter().product();
        let mut out = Tensor::zeros(&[x.dims[0], 2]);
        for r in 0..x.dims[0] {
            out.data[r * 2] = x.data[r * rl..(r + 1) * rl].iter().sum::<f32>() * 0.5 + 1.0;
            out.data[r * 2 + 1] = tag;
        }
        Ok(out)
    }
}

/// What `rung_fn(tag, _)` returns for `x` — the bit-exact oracle.
fn expect(x: &Tensor, tag: f32) -> Vec<f32> {
    let rl: usize = x.dims[1..].iter().product();
    let mut out = Vec::with_capacity(x.dims[0] * 2);
    for r in 0..x.dims[0] {
        out.push(x.data[r * rl..(r + 1) * rl].iter().sum::<f32>() * 0.5 + 1.0);
        out.push(tag);
    }
    out
}

fn rows(n: usize, seed: f32) -> Tensor {
    let mut t = Tensor::zeros(&[n, TAIL[0]]);
    for (i, v) in t.data.iter_mut().enumerate() {
        *v = seed + i as f32 * 0.25;
    }
    t
}

fn cfg(workers: usize) -> FleetCfg {
    FleetCfg { workers, queue_cap: 512, quantum_rows: 4, ..FleetCfg::default() }
}

// ---------------------------------------------------------------------------
// Shared-weight dedup
// ---------------------------------------------------------------------------

/// Byte-exact dedup accounting across a 3-rung ladder shared by two
/// tenants.  Let the first lowering of the merged plan pay `u` unique
/// bytes and save `s` to intra-plan duplicates (total upload `u + s`).
/// The second tenant deploying the *same* plan must add zero unique
/// bytes and push the entire `u + s` upload into `dedup_saved_bytes`;
/// a genuinely different plan must add its own unique bytes.
#[test]
fn dedup_accounts_bytes_exactly_across_a_shared_ladder() {
    let engine = Engine::host();
    let (spec, params) =
        layermerge::ir::synth::by_name("hostnet-tiny").expect("synthetic spec");
    let orig = Arc::new(Plan::original(&spec, &params).unwrap());
    let (a, c, spans) = layermerge::solver::depth::greedy_full_solution(&spec);
    let merged = Arc::new(Plan::from_solution(&spec, &params, &a, &c, &spans).unwrap());

    let fleet = Fleet::new(cfg(1));
    fleet.add_tenant(TenantCfg::new("a", 1, BatchPolicy::Greedy)).unwrap();
    fleet.add_tenant(TenantCfg::new("b", 1, BatchPolicy::Greedy)).unwrap();

    fleet.deploy("a", &engine, &merged, Format::Fused, 300).unwrap();
    let s1 = fleet.stats();
    let (u, s) = (s1.unique_weight_bytes, s1.dedup_saved_bytes);
    assert!(u > 0, "lowering a plan must upload some weight bytes");

    // same plan, second tenant: every upload hits the shared cache
    fleet.deploy("b", &engine, &merged, Format::Fused, 300).unwrap();
    let s2 = fleet.stats();
    assert_eq!(
        s2.unique_weight_bytes, u,
        "re-deploying an identical plan must add no unique bytes"
    );
    assert_eq!(
        s2.dedup_saved_bytes,
        s + (u + s),
        "the whole second upload must be deduped away"
    );

    // a different plan on the same ladder pays its own unique bytes
    fleet.deploy("a", &engine, &orig, Format::Fused, 1_500).unwrap();
    let s3 = fleet.stats();
    assert!(
        s3.unique_weight_bytes > u,
        "the uncompressed plan has kernels the merged plan lacks"
    );
    assert!(s3.dedup_saved_bytes >= s2.dedup_saved_bytes);
    assert_eq!((s3.tenants, s3.rungs), (2, 3));
    fleet.shutdown();
}

// ---------------------------------------------------------------------------
// Weighted-fair scheduling
// ---------------------------------------------------------------------------

/// A tenant flooding 80 requests cannot starve a light tenant: DRR
/// interleaves batches, so the light tenant's 8 requests all complete
/// while the flood is still queued.  (Under FIFO-across-tenants the
/// light requests, submitted after the flood, would drain last.)
#[test]
fn flooding_tenant_does_not_starve_light_tenant() {
    let fleet = Fleet::new(cfg(1));
    for name in ["flood", "light"] {
        fleet.add_tenant(TenantCfg::new(name, 1, BatchPolicy::Greedy)).unwrap();
        fleet
            .deploy_fn(name, 4, &TAIL, false, 10_000, rung_fn(1.0, Duration::from_millis(10)))
            .unwrap();
    }

    let flood: Vec<_> = (0..80)
        .map(|i| fleet.submit("flood", rows(1, i as f32), None, None).unwrap())
        .collect();
    let light: Vec<_> = (0..8)
        .map(|i| fleet.submit("light", rows(1, 100.0 + i as f32), None, None).unwrap())
        .collect();

    for tk in light {
        let y = tk
            .wait_timeout_coded(Duration::from_secs(20))
            .unwrap_or_else(|_| panic!("light tenant ticket timed out — starved by the flood"))
            .expect("light tenant request failed");
        assert_eq!(y.dims[1], 2);
    }
    assert!(
        fleet.queue_depth("flood") > 0,
        "light tenant finished only after the flood fully drained — no fairness"
    );
    let ls = fleet.tenant_stats("light").unwrap();
    assert_eq!(ls.requests, 8);

    drop(flood); // late fulfillments into dropped tickets are harmless
    fleet.shutdown();
}

// ---------------------------------------------------------------------------
// Deadline-aware routing
// ---------------------------------------------------------------------------

/// Router behavior across one ladder: idle → cheapest rung (hit);
/// cheap rung backed up but the big rung still fits → fallback; no
/// rung fits → typed shed.  Service times are two orders of magnitude
/// above scheduling jitter, so the predicted-wait comparisons are
/// stable on slow machines.
#[test]
fn router_serves_cheapest_falls_back_and_sheds() {
    let fleet = Fleet::new(cfg(1));
    fleet.add_tenant(TenantCfg::new("t", 1, BatchPolicy::Greedy)).unwrap();
    // rung 0: cheap (100ms/batch), rung 1: big (300ms/batch)
    fleet
        .deploy_fn("t", 4, &TAIL, false, 100_000, rung_fn(1.0, Duration::from_millis(100)))
        .unwrap();
    fleet
        .deploy_fn("t", 4, &TAIL, false, 300_000, rung_fn(2.0, Duration::from_millis(300)))
        .unwrap();

    // (a) idle ladder + generous deadline: cheapest rung serves it
    let tk = fleet
        .submit("t", rows(1, 0.0), None, Some(Instant::now() + Duration::from_secs(2)))
        .unwrap();
    let y = tk.wait_coded().expect("idle ladder must serve");
    assert_eq!(y.data[1], 1.0, "an idle ladder must route to the cheapest rung");

    // (b) back up the cheap rung (pinned submits bypass the router),
    // then route a deadline only the big rung can meet
    let pinned: Vec<_> = (0..24)
        .map(|i| fleet.submit_rung("t", 0, rows(1, i as f32), None, None).unwrap())
        .collect();
    let tk = fleet
        .submit("t", rows(1, 50.0), None, Some(Instant::now() + Duration::from_millis(450)))
        .unwrap();

    // (c) and a deadline nothing can meet: typed shed at the door
    match fleet.submit("t", rows(1, 60.0), None, Some(Instant::now() + Duration::from_millis(150)))
    {
        Err(ServeError::Shed { predicted_us, budget_us, .. }) => {
            assert!(predicted_us > budget_us, "shed must report why it refused");
        }
        Err(other) => panic!("want Shed when no rung fits, got {other:?}"),
        Ok(_) => panic!("want Shed when no rung fits, got an admitted ticket"),
    }

    let y = tk.wait_coded().expect("fallback request must still be served");
    assert_eq!(y.data[1], 2.0, "the fallback request must run on the big rung");

    let rs = fleet.router_stats();
    assert!(rs.hits >= 1, "router stats: {rs:?}");
    assert!(rs.fallbacks >= 1, "router stats: {rs:?}");
    assert!(rs.sheds >= 1, "router stats: {rs:?}");

    drop(pinned);
    fleet.shutdown();
}

// ---------------------------------------------------------------------------
// Graceful hot swap
// ---------------------------------------------------------------------------

/// Hot swap drops nothing and never mixes plans: every request admitted
/// before `swap_fn` completes bit-identically on the old dispatch (its
/// dispatch is pinned at submit, so this holds even if the worker pops
/// it after the swap), and every request after runs on the new one.
#[test]
fn hot_swap_completes_in_flight_on_old_plan_with_zero_drops() {
    let fleet = Fleet::new(cfg(1));
    fleet.add_tenant(TenantCfg::new("t", 1, BatchPolicy::Greedy)).unwrap();
    fleet
        .deploy_fn("t", 2, &TAIL, false, 20_000, rung_fn(1.0, Duration::from_millis(20)))
        .unwrap();

    let a = rows(2, 0.0); // full batch: in service while b/c queue behind it
    let b = rows(1, 10.0);
    let c = rows(1, 20.0);
    let d = rows(1, 30.0);
    let tka = fleet.submit("t", a.clone(), None, None).unwrap();
    let tkb = fleet.submit("t", b.clone(), None, None).unwrap();
    let tkc = fleet.submit("t", c.clone(), None, None).unwrap();

    fleet.swap_fn("t", 0, 2, rung_fn(2.0, Duration::ZERO)).unwrap();
    let tkd = fleet.submit("t", d.clone(), None, None).unwrap();

    // zero drops: all four resolve; pre-swap bit-identical on the old fn
    assert_eq!(tka.wait_coded().expect("in-flight dropped by swap").data, expect(&a, 1.0));
    assert_eq!(tkb.wait_coded().expect("queued req dropped by swap").data, expect(&b, 1.0));
    assert_eq!(tkc.wait_coded().expect("queued req dropped by swap").data, expect(&c, 1.0));
    assert_eq!(tkd.wait_coded().expect("post-swap req dropped").data, expect(&d, 2.0));

    let ts = fleet.tenant_stats("t").unwrap();
    assert_eq!((ts.requests, ts.rows), (4, 5));

    // swapping an unknown rung or after close is a loud error, not UB
    assert!(fleet.swap_fn("t", 9, 2, rung_fn(3.0, Duration::ZERO)).is_err());
    fleet.close();
    assert!(fleet.swap_fn("t", 0, 2, rung_fn(3.0, Duration::ZERO)).is_err());
    fleet.shutdown();
}

// ---------------------------------------------------------------------------
// Table-seeded routing
// ---------------------------------------------------------------------------

/// Rung costs seeded from measured latency tables
/// ([`Fleet::deploy_seeded`]): with real deployed plans and **zero**
/// warmup traffic, the very first request must route to the merged
/// (cheaper) rung — even though the expensive rung was deployed first,
/// so correct routing proves the table seed, not ladder order.
/// Attribution is by bit-exact output comparison against each plan's
/// direct forward on the same backend.
#[test]
fn table_seeded_router_picks_merged_rung_on_first_request() {
    use layermerge::ir::synth;
    use layermerge::tables::{self, BuildCfg, LatencyMode};

    let (spec, flat) = synth::by_name("hostchain-tiny").unwrap();
    let engine = Engine::host();
    let bcfg = BuildCfg {
        mode: LatencyMode::Measured,
        warmup: 1,
        iters: 3,
        force: true,
        ..BuildCfg::default()
    };
    let cache = std::env::temp_dir().join(format!("lm_fleet_seed_{}", std::process::id()));
    std::fs::create_dir_all(&cache).unwrap();
    let t = tables::build_host(&spec, &flat, engine.backend(), &bcfg, &cache).unwrap();

    let orig = Arc::new(Plan::original(&spec, &flat).unwrap());
    let (a, c, spans) = layermerge::solver::depth::greedy_full_solution(&spec);
    let merged = Arc::new(Plan::from_solution(&spec, &flat, &a, &c, &spans).unwrap());
    assert!(
        t.plan_seed_us(&merged) < t.plan_seed_us(&orig),
        "table seeds must rank the merged plan cheaper: {}us vs {}us",
        t.plan_seed_us(&merged),
        t.plan_seed_us(&orig),
    );

    let fleet = Fleet::new(cfg(1));
    fleet.add_tenant(TenantCfg::new("t", 1, BatchPolicy::Greedy)).unwrap();
    // expensive rung FIRST: a correct first route proves the seed
    fleet.deploy_seeded("t", &engine, &orig, Format::Fused, &t).unwrap();
    fleet.deploy_seeded("t", &engine, &merged, Format::Fused, &t).unwrap();

    // one full-batch request (no padding, no prior traffic)
    let n: usize = spec.batch * spec.h * spec.w * spec.c;
    let x = Tensor::new(
        vec![spec.batch, spec.h, spec.w, spec.c],
        (0..n).map(|i| (i as f32 * 0.37).sin()).collect(),
    );
    let y = fleet
        .submit("t", x.clone(), None, None)
        .unwrap()
        .wait_coded()
        .expect("first request must be served");

    let y_merged = engine.infer(&merged, &x, None, Format::Fused).unwrap();
    let y_orig = engine.infer(&orig, &x, None, Format::Fused).unwrap();
    assert_eq!(
        y.data, y_merged.data,
        "first request must run on the table-seeded cheapest (merged) rung"
    );
    if y_orig.data != y_merged.data {
        assert_ne!(y.data, y_orig.data, "output matches the expensive rung");
    }
    let rs = fleet.router_stats();
    assert!(rs.hits >= 1, "router stats: {rs:?}");
    assert_eq!(rs.sheds, 0, "router stats: {rs:?}");
    fleet.shutdown();
}

// ---------------------------------------------------------------------------
// Pool lifecycle
// ---------------------------------------------------------------------------

/// `par::shutdown_pool()` must refuse — loudly — while a fleet holds
/// the compute pool, and the pool must remain usable afterwards.
#[test]
fn shutdown_pool_fails_loudly_with_a_live_fleet() {
    let fleet = Fleet::new(cfg(1));
    fleet.add_tenant(TenantCfg::new("t", 1, BatchPolicy::Greedy)).unwrap();
    fleet.deploy_fn("t", 4, &TAIL, false, 1_000, rung_fn(1.0, Duration::ZERO)).unwrap();

    let r = panic::catch_unwind(|| layermerge::util::par::shutdown_pool());
    assert!(r.is_err(), "shutdown_pool must panic while a fleet is live");

    // the refusal must not have wedged the pool: the fleet still serves
    let x = rows(1, 5.0);
    let y = fleet.submit("t", x.clone(), None, None).unwrap().wait().unwrap();
    assert_eq!(y.data, expect(&x, 1.0));
    fleet.shutdown();
}

// ---------------------------------------------------------------------------
// Fleet over TCP
// ---------------------------------------------------------------------------

/// The wire tier routes `Infer` frames by tenant, refuses ambiguous or
/// unknown tenants with typed errors, and the `/stats` frame carries
/// per-tenant breakdowns plus the fleet dedup/router counters.
#[test]
fn fleet_over_tcp_routes_tenants_and_reports_per_tenant_stats() {
    let fleet = Arc::new(Fleet::new(cfg(1)));
    fleet.add_tenant(TenantCfg::new("a", 2, BatchPolicy::Greedy)).unwrap();
    fleet.add_tenant(TenantCfg::new("b", 1, BatchPolicy::Greedy)).unwrap();
    fleet.deploy_fn("a", 4, &TAIL, false, 1_000, rung_fn(10.0, Duration::ZERO)).unwrap();
    fleet.deploy_fn("b", 4, &TAIL, false, 1_000, rung_fn(20.0, Duration::ZERO)).unwrap();

    let server = match NetServer::bind_fleet(Arc::clone(&fleet), "127.0.0.1:0", NetCfg::default())
    {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping fleet TCP test (no loopback): {e}");
            return;
        }
    };
    let mut c = NetClient::connect(server.addr()).unwrap();

    let x = rows(2, 1.0);
    let ya = c.infer_tenant("a", &x, None, None).unwrap().expect("tenant a must be served");
    assert_eq!(ya.data, expect(&x, 10.0), "frame routed to the wrong tenant's ladder");
    let yb = c.infer_tenant("b", &x, None, None).unwrap().expect("tenant b must be served");
    assert_eq!(yb.data, expect(&x, 20.0), "frame routed to the wrong tenant's ladder");

    // two tenants: an empty tenant field is ambiguous; unknown is refused
    assert!(c.infer_tenant("", &x, None, None).unwrap().is_err());
    assert!(c.infer_tenant("ghost", &x, None, None).unwrap().is_err());

    let j = c.stats().unwrap();
    assert!(j.get("requests").and_then(|v| v.as_usize()).unwrap() >= 2);
    let tenants = j.get("tenants").expect("fleet stats must break down by tenant");
    for name in ["a", "b"] {
        let t = tenants.get(name).unwrap_or_else(|| panic!("stats missing tenant {name}"));
        assert_eq!(t.get("requests").and_then(|v| v.as_usize()), Some(1));
    }
    let f = j.get("fleet").expect("fleet stats must carry dedup/router counters");
    for key in ["unique_weight_bytes", "dedup_saved_bytes", "router_hits", "router_sheds"] {
        assert!(f.get(key).is_some(), "fleet stats missing {key}");
    }

    drop(c);
    server.shutdown();
    match Arc::try_unwrap(fleet) {
        Ok(f) => f.shutdown(),
        Err(f) => f.close(),
    }
}
