//! The chaos suite: deterministic fault injection across every layer of
//! the serving stack, pinning the self-healing acceptance properties:
//!
//! * **No lost tickets.**  Under a fixed-seed soak with injected
//!   dispatch failures, panics, and delays, every admitted ticket
//!   resolves exactly once, no waiter hangs past its deadline plus a
//!   bounded grace, and the session counters partition the submitted
//!   requests exactly (`requests + shed + expired == submitted`).
//! * **Graceful degradation.**  Goodput falls roughly linearly with the
//!   injected fault rate — a 20% fault rate is not a cliff.
//! * **Resilient client.**  Through a flaky loopback proxy (dropped
//!   connections, stalls, truncated and corrupted frames) *plus* 5%
//!   injected backend faults, the retrying client keeps goodput at
//!   ≥ 90% of the fault-free baseline.
//! * **Self-healing fleet.**  A rung that keeps failing is quarantined
//!   (the router stops offering it and traffic falls back up the
//!   ladder), then re-admitted through a probation probe once healthy.
//! * **Typed client failures.**  A spent deadline is never retried, a
//!   dead endpoint opens the circuit breaker
//!   ([`ClientError::CircuitOpen`]), and a tiny read budget surfaces as
//!   [`ClientError::TimedOut`] — all downcastable through `anyhow`.
//! * **Backend-layer injection.**  `FaultBackend` is a transparent
//!   decorator when quiet and injects typed op failures on schedule.
//!
//! Every seed routes through [`chaos::env_seed`], so `LM_CHAOS_SEED`
//! reproduces a whole run.  Network tests bind `127.0.0.1:0` and skip
//! cleanly where loopback sockets are unavailable.

use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use layermerge::exec::{Format, Plan};
use layermerge::ir::synth;
use layermerge::runtime::HostBackend;
use layermerge::serve::chaos::{
    self, Fault, FaultBackend, FaultPlan, FaultProxy, FaultSpec, WireFaults,
};
use layermerge::serve::fleet::{Fleet, FleetCfg, TenantCfg};
use layermerge::serve::net::{
    BreakerCfg, ClientError, NetCfg, NetClient, NetClientCfg, NetServer, RetryClient,
    RetryPolicy,
};
use layermerge::serve::{BatchPolicy, Engine, ServeCfg, ServeError, Session};
use layermerge::util::rng::Rng;
use layermerge::util::tensor::Tensor;

const B: usize = 4;
const TAIL: [usize; 1] = [3];

/// Deterministic mock model: out[r] = [sum(row)*0.5 + 1, sum(sq) - row[0]].
fn mock_backend(x: &Tensor, _t: Option<&Tensor>) -> anyhow::Result<Tensor> {
    let rl: usize = x.dims[1..].iter().product();
    let mut out = Tensor::zeros(&[x.dims[0], 2]);
    for r in 0..x.dims[0] {
        let row = &x.data[r * rl..(r + 1) * rl];
        let sum: f32 = row.iter().sum();
        let sq: f32 = row.iter().map(|v| v * v).sum();
        out.data[r * 2] = sum * 0.5 + 1.0;
        out.data[r * 2 + 1] = sq - row[0];
    }
    Ok(out)
}

fn serve_cfg(workers: usize) -> ServeCfg {
    ServeCfg { workers, queue_cap: 256, policy: BatchPolicy::Greedy, ..ServeCfg::default() }
}

fn req(rows: usize, seed: f32) -> Tensor {
    let mut t = Tensor::zeros(&[rows, TAIL[0]]);
    for (i, v) in t.data.iter_mut().enumerate() {
        *v = seed + i as f32 * 0.25;
    }
    t
}

fn expect(x: &Tensor) -> Vec<f32> {
    let rl: usize = x.dims[1..].iter().product();
    let mut out = Vec::with_capacity(x.dims[0] * 2);
    for r in 0..x.dims[0] {
        let row = &x.data[r * rl..(r + 1) * rl];
        let sum: f32 = row.iter().sum();
        let sq: f32 = row.iter().map(|v| v * v).sum();
        out.push(sum * 0.5 + 1.0);
        out.push(sq - row[0]);
    }
    out
}

/// Bind a [`NetServer`] on an ephemeral loopback port, or skip the test
/// where the sandbox forbids loopback sockets.
fn bind_or_skip(sess: Session, cfg: NetCfg) -> Option<NetServer> {
    match NetServer::bind(Arc::new(sess), "127.0.0.1:0", cfg) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping: cannot bind loopback socket: {e:#}");
            None
        }
    }
}

/// Poll until `pred` holds or `for_ms` elapses; returns whether it held.
fn eventually(for_ms: u64, mut pred: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + Duration::from_millis(for_ms);
    loop {
        if pred() {
            return true;
        }
        if Instant::now() >= deadline {
            return false;
        }
        thread::sleep(Duration::from_millis(2));
    }
}

// ---------------------------------------------------------------------------
// Invariant soak: exactly-once tickets, bounded waits, coherent stats
// ---------------------------------------------------------------------------

/// 400 requests from 4 client threads against a session whose dispatch
/// fails 5%, panics 2%, and stalls 3% of batches (fixed seed).  Every
/// submit resolves exactly once — at the door with a typed refusal, or
/// through a ticket that completes within its deadline plus a bounded
/// grace — and the server counters partition the submissions exactly.
#[test]
fn soak_under_injected_faults_loses_no_tickets() {
    let spec = FaultSpec { fail: 0.05, panic: 0.02, delay: 0.03, delay_ms: 2 };
    let plan = FaultPlan::random(spec, chaos::env_seed(0xC4A05));
    let sess = Arc::new(Session::from_fn(
        B,
        &TAIL,
        false,
        serve_cfg(2),
        chaos::wrap_fn(Arc::clone(&plan), mock_backend),
    ));

    const THREADS: usize = 4;
    const PER: usize = 100;
    let mut tallies = Vec::new(); // (ok, failed, expired, shed) per thread
    thread::scope(|s| {
        let mut handles = Vec::new();
        for ti in 0..THREADS {
            let sess = Arc::clone(&sess);
            handles.push(s.spawn(move || {
                let (mut ok, mut failed, mut expired, mut shed) = (0usize, 0, 0, 0);
                for i in 0..PER {
                    let x = req(1 + (i % B), (ti * PER + i) as f32 * 0.1);
                    let deadline = (i % 2 == 0)
                        .then(|| Instant::now() + Duration::from_millis(50));
                    let ticket = match sess.submit_deadline(x.clone(), None, deadline) {
                        Ok(t) => t,
                        Err(ServeError::Shed { .. }) => {
                            shed += 1;
                            continue;
                        }
                        Err(ServeError::DeadlineExceeded) => {
                            expired += 1;
                            continue;
                        }
                        Err(e) => panic!("unexpected admission error: {e}"),
                    };
                    // "no waiter hangs": deadlined or not, the ticket must
                    // resolve within a bounded grace of its budget
                    match ticket.wait_timeout_coded(Duration::from_secs(10)) {
                        Ok(Ok(y)) => {
                            assert_eq!(y.data, expect(&x), "wrong result under chaos");
                            ok += 1;
                        }
                        Ok(Err(ServeError::BackendFailed(msg))) => {
                            assert!(
                                msg.contains("chaos"),
                                "only injected faults should fail batches: {msg}"
                            );
                            failed += 1;
                        }
                        Ok(Err(ServeError::DeadlineExceeded)) => expired += 1,
                        Ok(Err(e)) => panic!("unexpected ticket error: {e}"),
                        Err(_) => panic!("ticket hung past its deadline + grace"),
                    }
                }
                (ok, failed, expired, shed)
            }));
        }
        for h in handles {
            tallies.push(h.join().expect("client thread panicked"));
        }
    });

    let ok: usize = tallies.iter().map(|t| t.0).sum();
    let failed: usize = tallies.iter().map(|t| t.1).sum();
    let expired: usize = tallies.iter().map(|t| t.2).sum();
    let shed: usize = tallies.iter().map(|t| t.3).sum();
    let total = THREADS * PER;
    assert_eq!(ok + failed + expired + shed, total, "a submission vanished");

    let stats = sess.stats();
    // the server-side partition must agree with the client-side one
    assert_eq!(
        stats.requests + stats.expired_requests + stats.shed_requests,
        total,
        "server counters must partition the submissions: {stats:?}"
    );
    assert_eq!(stats.requests, ok + failed, "dispatched = ok + poisoned");
    assert_eq!(stats.expired_requests, expired, "expired tally mismatch");
    assert_eq!(stats.shed_requests, shed, "shed tally mismatch");
    assert!(
        stats.panicked_batches <= stats.failed_batches,
        "panics are a subset of failed batches: {stats:?}"
    );
    // the plan actually fired (5%+2%+3% over ~100+ batches), and failed
    // tickets exist iff batches failed
    let counts = plan.counts();
    assert!(counts.events > 0, "no fault events drawn");
    assert_eq!(failed > 0, stats.failed_batches > 0);
    assert!(ok > total / 2, "goodput collapsed under 10% faults: {ok}/{total}");
}

/// Goodput degrades roughly with the injected fault rate — no cliff.
#[test]
fn goodput_degrades_gracefully_with_fault_rate() {
    let mut fracs = Vec::new();
    for (i, rate) in [0.0f64, 0.05, 0.20].into_iter().enumerate() {
        let plan = FaultPlan::random(
            FaultSpec { fail: rate / 2.0, panic: rate / 2.0, delay: 0.0, delay_ms: 0 },
            chaos::env_seed(0xDE6 + i as u64),
        );
        // B = 1: every request is its own dispatch, so the ok-fraction
        // estimates (1 - rate) directly
        let sess = Session::from_fn(1, &TAIL, false, serve_cfg(2), chaos::wrap_fn(plan, mock_backend));
        const N: usize = 200;
        let mut ok = 0usize;
        for j in 0..N {
            if sess.infer(&req(1, j as f32), None).is_ok() {
                ok += 1;
            }
        }
        fracs.push(ok as f64 / N as f64);
    }
    assert_eq!(fracs[0], 1.0, "fault-free run must be perfect");
    assert!(fracs[1] >= 0.85, "5% faults took >15% goodput: {fracs:?}");
    assert!(fracs[2] >= 0.60, "20% faults fell off a cliff: {fracs:?}");
    assert!(
        fracs[1] >= fracs[2] - 0.05,
        "goodput should not improve with more faults: {fracs:?}"
    );
}

// ---------------------------------------------------------------------------
// Wire faults + retrying client
// ---------------------------------------------------------------------------

/// The headline resilience pin: through a proxy that drops connections,
/// stalls, truncates, and corrupts frames, in front of a server with 5%
/// injected backend faults, the retrying client holds goodput at ≥ 90%
/// of the fault-free baseline.
#[test]
fn retry_client_holds_goodput_through_wire_and_backend_faults() {
    let plan = FaultPlan::random(FaultSpec::failing(0.05), chaos::env_seed(0x60D9));
    let sess = Session::from_fn(
        B,
        &TAIL,
        false,
        serve_cfg(2),
        chaos::wrap_fn(plan, mock_backend),
    );
    let Some(server) = bind_or_skip(sess, NetCfg::default()) else { return };
    const N: usize = 40;
    // Inference is idempotent, so an executed-and-failed verdict (a
    // batch poisoned by an injected backend fault) is application-level
    // retryable in BOTH arms; the comparison then isolates what the
    // flaky wire costs, which is what RetryClient is for.
    const VERDICT_TRIES: usize = 4;

    // fault-free baseline: a plain client straight at the server
    let mut base_ok = 0usize;
    {
        let mut c = NetClient::connect(server.addr()).expect("loopback connect");
        for i in 0..N {
            let x = req(2, i as f32 * 0.3);
            for _ in 0..VERDICT_TRIES {
                match c.infer_deadline(&x, None, None) {
                    Ok(Ok(y)) => {
                        assert_eq!(y.data, expect(&x));
                        base_ok += 1;
                        break;
                    }
                    Ok(Err(_)) => continue,
                    Err(e) => panic!("clean wire must not fail transport: {e:#}"),
                }
            }
        }
    }
    assert_eq!(base_ok, N, "baseline with verdict retries must be perfect");

    // the same traffic through a flaky wire, with the retrying client
    let wire = WireFaults {
        drop_conn: 0.04,
        stall: 0.02,
        stall_ms: 10,
        truncate: 0.02,
        corrupt: 0.02,
    };
    let proxy = match FaultProxy::bind(server.addr(), wire, chaos::env_seed(0x71E9)) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("skipping: cannot bind fault proxy: {e:#}");
            return;
        }
    };
    let mut rc = RetryClient::new(proxy.addr())
        .with_retry(RetryPolicy { attempts: 6, base_ms: 1, cap_ms: 20 })
        .with_breaker(BreakerCfg { failure_threshold: 32, ..BreakerCfg::default() })
        .with_seed(chaos::env_seed(0x2e72));
    let mut chaos_ok = 0usize;
    for i in 0..N {
        let x = req(2, i as f32 * 0.3);
        for _ in 0..VERDICT_TRIES {
            if let Ok(Ok(y)) = rc.infer_deadline(&x, None, None) {
                assert_eq!(y.data, expect(&x), "retry must never return a wrong answer");
                chaos_ok += 1;
                break;
            }
        }
    }
    let counts = proxy.counts();
    assert!(
        chaos_ok * 10 >= base_ok * 9,
        "retrying goodput {chaos_ok}/{N} fell below 90% of baseline {base_ok} \
         (wire: {counts:?}, retry: {:?})",
        rc.retry_stats()
    );
    // the run was not vacuous: either the wire misbehaved and the client
    // retried through it, or (unlucky seed) nothing fired at all
    let injected = counts.dropped + counts.truncated + counts.corrupted + counts.stalled;
    assert!(
        rc.retry_stats().retries > 0 || injected == 0,
        "wire faults fired but the client never retried: {counts:?}"
    );
    proxy.shutdown();
    server.shutdown();
}

/// A hedged request races a second connection after the hedge delay and
/// the first successful leg wins — the result is still bit-exact.
#[test]
fn hedged_requests_return_correct_results() {
    let sess = Session::from_fn(B, &TAIL, false, serve_cfg(2), move |x, t| {
        thread::sleep(Duration::from_millis(15));
        mock_backend(x, t)
    });
    let Some(server) = bind_or_skip(sess, NetCfg::default()) else { return };
    let mut rc = RetryClient::new(server.addr())
        .with_hedge(Duration::from_millis(3))
        .with_seed(chaos::env_seed(0x4ed6));
    for i in 0..4 {
        let x = req(2, i as f32);
        let y = rc
            .infer_deadline(&x, None, None)
            .expect("transport")
            .expect("verdict");
        assert_eq!(y.data, expect(&x), "hedged result must be bit-exact");
    }
    assert!(
        rc.retry_stats().hedges >= 1,
        "a 3ms hedge against a 15ms server must fire: {:?}",
        rc.retry_stats()
    );
    server.shutdown();
}

/// A spent deadline is never retried: the client reports
/// `DeadlineExceeded` without touching the network.
#[test]
fn retry_client_never_retries_a_spent_deadline() {
    // no listener needed: the deadline is spent before the first attempt
    let addr = "127.0.0.1:9".parse().unwrap();
    let mut rc = RetryClient::new(addr)
        .with_retry(RetryPolicy { attempts: 4, base_ms: 1, cap_ms: 5 });
    let verdict = rc
        .infer_deadline(&req(1, 0.0), None, Some(Duration::ZERO))
        .expect("a spent deadline is a verdict, not a transport error");
    match verdict {
        Err((code, _)) => assert_eq!(code, layermerge::serve::proto::ErrCode::DeadlineExceeded),
        Ok(_) => panic!("a spent deadline cannot succeed"),
    }
    assert_eq!(rc.retry_stats().attempts, 0, "no wire attempt may be made");
    assert_eq!(rc.retry_stats().retries, 0, "a spent deadline is final");
}

/// Repeated transport failures open the circuit breaker; once open, the
/// client refuses instantly with a typed, downcastable error.
#[test]
fn circuit_breaker_opens_on_a_dead_endpoint() {
    // grab an ephemeral port, then close the listener so connects are
    // refused fast (skip where loopback is unavailable)
    let addr = match std::net::TcpListener::bind("127.0.0.1:0") {
        Ok(l) => l.local_addr().unwrap(),
        Err(e) => {
            eprintln!("skipping: cannot bind loopback socket: {e:#}");
            return;
        }
    };
    let mut rc = RetryClient::new(addr)
        .with_cfg(NetClientCfg {
            connect_timeout: Duration::from_millis(200),
            ..NetClientCfg::default()
        })
        .with_retry(RetryPolicy { attempts: 2, base_ms: 1, cap_ms: 2 })
        .with_breaker(BreakerCfg {
            failure_threshold: 3,
            cooldown: Duration::from_secs(30),
        });
    // two calls x two attempts = four consecutive failures >= threshold 3
    for _ in 0..2 {
        let r = rc.infer_deadline(&req(1, 0.0), None, None);
        assert!(r.is_err(), "nothing listens on {addr}");
    }
    assert_eq!(rc.breaker_state(), "open");
    let err = rc
        .infer_deadline(&req(1, 0.0), None, None)
        .expect_err("an open circuit must refuse");
    assert_eq!(
        err.downcast_ref::<ClientError>(),
        Some(&ClientError::CircuitOpen),
        "refusal must be the typed CircuitOpen: {err:#}"
    );
    assert!(rc.retry_stats().breaker_rejections >= 1);
}

/// A read budget smaller than the service time surfaces as the typed
/// [`ClientError::TimedOut`] rather than a generic io error.
#[test]
fn tiny_read_budget_times_out_with_a_typed_error() {
    let sess = Session::from_fn(B, &TAIL, false, serve_cfg(1), move |x, t| {
        thread::sleep(Duration::from_millis(200));
        mock_backend(x, t)
    });
    let Some(server) = bind_or_skip(sess, NetCfg::default()) else { return };
    let cfg = NetClientCfg { read_timeout: Duration::from_millis(20), ..NetClientCfg::default() };
    let mut c = NetClient::connect_cfg(server.addr(), cfg).expect("loopback connect");
    let err = c
        .infer_deadline(&req(1, 0.0), None, None)
        .expect_err("a 20ms read budget cannot survive a 200ms dispatch");
    assert_eq!(
        err.downcast_ref::<ClientError>(),
        Some(&ClientError::TimedOut),
        "want the typed TimedOut in the chain: {err:#}"
    );
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Fleet supervision: quarantine, fallback, probation, re-admission
// ---------------------------------------------------------------------------

/// The self-healing pin: a rung that keeps failing is quarantined (the
/// router bypasses it and traffic falls back up the ladder), then
/// re-admitted through a probation probe once it recovers.
#[test]
fn failing_rung_is_quarantined_then_readmitted() {
    use std::sync::atomic::{AtomicBool, Ordering};

    let fleet = Fleet::new(FleetCfg {
        workers: 1,
        queue_cap: 64,
        quarantine_after: 2,
        quarantine_cooldown_ms: 40,
        ..FleetCfg::default()
    });
    fleet.add_tenant(TenantCfg::new("t", 1, BatchPolicy::Greedy)).unwrap();

    // rung 0: cheap but poisonable; rung 1: slow but dependable
    let poisoned = Arc::new(AtomicBool::new(true));
    let flag = Arc::clone(&poisoned);
    fleet
        .deploy_fn("t", B, &TAIL, false, 100, move |x, t| {
            anyhow::ensure!(!flag.load(Ordering::SeqCst), "chaos: rung 0 is poisoned");
            mock_backend(x, t)
        })
        .unwrap();
    // the fallback is slow enough that its measured EWMA stays above the
    // cheap rung's seed — the probation probe must prefer the healed rung
    fleet
        .deploy_fn("t", B, &TAIL, false, 10_000, |x, t| {
            thread::sleep(Duration::from_millis(15));
            mock_backend(x, t)
        })
        .unwrap();
    let states = |fleet: &Fleet| fleet.rung_states("t").expect("tenant exists");
    assert_eq!(states(&fleet), vec!["healthy", "healthy"]);

    // poison rung 0 past the quarantine threshold (pinned submits bypass
    // the router, so the failures land deterministically on rung 0)
    for i in 0..2 {
        let t = fleet.submit_rung("t", 0, req(1, i as f32), None, None).unwrap();
        let r = t
            .wait_timeout(Duration::from_secs(5))
            .unwrap_or_else(|_| panic!("poisoned-rung ticket hung"));
        assert!(r.is_err(), "the poisoned rung must fail its batches");
    }
    // health is folded after fulfilment — poll briefly for the flip
    assert!(
        eventually(1000, || states(&fleet)[0] == "quarantined"),
        "two failed batches must quarantine rung 0: {:?}",
        states(&fleet)
    );

    // routed traffic now bypasses the quarantined rung and succeeds on
    // the expensive fallback
    let x = req(1, 7.0);
    let y = fleet
        .submit("t", x.clone(), None, None)
        .unwrap()
        .wait_timeout(Duration::from_secs(5))
        .unwrap_or_else(|_| panic!("fallback ticket hung"))
        .expect("the fallback rung serves it");
    assert_eq!(y.data, expect(&x));
    assert_eq!(states(&fleet)[0], "quarantined", "fallback must not touch rung 0");

    // heal the rung; after the cooldown the next routed request is the
    // probation probe, lands on the (cheaper) rung 0, and re-admits it
    poisoned.store(false, Ordering::SeqCst);
    thread::sleep(Duration::from_millis(60));
    let x = req(1, 8.0);
    let y = fleet
        .submit("t", x.clone(), None, None)
        .unwrap()
        .wait_timeout(Duration::from_secs(5))
        .unwrap_or_else(|_| panic!("probe ticket hung"))
        .expect("the probe succeeds on the healed rung");
    assert_eq!(y.data, expect(&x));
    assert!(
        eventually(1000, || states(&fleet)[0] == "healthy"),
        "a clean probe must re-admit rung 0: {:?}",
        states(&fleet)
    );
    fleet.shutdown();
}

/// A dirty probe re-arms the quarantine instead of re-admitting.
#[test]
fn dirty_probation_probe_rearms_quarantine() {
    let fleet = Fleet::new(FleetCfg {
        workers: 1,
        queue_cap: 64,
        quarantine_after: 1,
        quarantine_cooldown_ms: 20,
        ..FleetCfg::default()
    });
    fleet.add_tenant(TenantCfg::new("t", 1, BatchPolicy::Greedy)).unwrap();
    let plan = FaultPlan::random(FaultSpec::failing(1.0), chaos::env_seed(0xBAD));
    fleet
        .deploy_fn("t", B, &TAIL, false, 100, chaos::wrap_fn(plan, mock_backend))
        .unwrap();

    let fail_one = |i: usize| {
        let t = fleet.submit_rung("t", 0, req(1, i as f32), None, None).unwrap();
        let r = t
            .wait_timeout(Duration::from_secs(5))
            .unwrap_or_else(|_| panic!("poisoned-rung ticket hung"));
        assert!(r.is_err(), "the fully-poisoned rung must fail every batch");
    };
    let states = |fleet: &Fleet| fleet.rung_states("t").expect("tenant exists");
    fail_one(0);
    assert!(eventually(1000, || states(&fleet)[0] == "quarantined"));
    thread::sleep(Duration::from_millis(30));
    // sole-rung ladder: the router still offers it (full-ladder fallback),
    // the probe fails, and the quarantine re-arms
    fail_one(1);
    assert!(
        eventually(1000, || states(&fleet)[0] == "quarantined"),
        "a dirty probe must re-arm the quarantine: {:?}",
        states(&fleet)
    );
    fleet.shutdown();
}

// ---------------------------------------------------------------------------
// Backend-layer injection
// ---------------------------------------------------------------------------

/// A quiet `FaultBackend` is a transparent decorator — bit-identical to
/// the bare host backend on a real lowered plan — and an armed one
/// injects a typed, attributable op failure.
#[test]
fn fault_backend_is_transparent_when_quiet_and_typed_when_armed() {
    let (spec, params) = synth::by_name("hostnet-tiny").expect("synthetic spec");
    let plan = Arc::new(Plan::original(&spec, &params).expect("original plan"));
    let mut rng = Rng::new(chaos::env_seed(0xFA57));
    let mut x = Tensor::zeros(&[spec.batch, spec.h, spec.w, spec.c]);
    for v in x.data.iter_mut() {
        *v = (rng.uniform() as f32) - 0.5;
    }

    let want = Engine::host().infer(&plan, &x, None, Format::Fused).expect("bare host");

    let quiet = Engine::with_backend(Arc::new(FaultBackend::wrap(
        Arc::new(HostBackend::new()),
        FaultPlan::none(),
    )));
    let got = quiet.infer(&plan, &x, None, Format::Fused).expect("quiet decorator");
    assert_eq!(got.dims, want.dims);
    assert_eq!(got.data, want.data, "a quiet FaultBackend must be transparent");

    let armed_plan = FaultPlan::nth(0, Fault::Fail);
    let armed = Engine::with_backend(Arc::new(FaultBackend::wrap(
        Arc::new(HostBackend::new()),
        Arc::clone(&armed_plan),
    )));
    let err = armed
        .infer(&plan, &x, None, Format::Fused)
        .expect_err("the first dispatched op must fail");
    assert!(
        format!("{err:#}").contains("chaos"),
        "injected failures must be attributable: {err:#}"
    );
    assert_eq!(armed_plan.counts().failed, 1, "exactly one injection fired");
}
