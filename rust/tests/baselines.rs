//! Baseline-solver equivalence: the predecessor's two-stage DP
//! (`baselines::twostage`, Kim et al. 2023) must agree with Algorithm 1
//! (`solver::dp`) on the *objective* for every instance — both solve the
//! same surrogate problem on the same tables; only the recursion shape
//! (and therefore the solve time) differs.  This pins the claim the
//! solvers bench and `e2e` report build on: the obj ratio in
//! BENCH_merge.json is exactly 1, only `twostage_vs_dp_solve_speedup`
//! is interesting.

use layermerge::baselines::twostage;
use layermerge::solver::dp::{self, DpInput, SpanArc};
use layermerge::util::prop::check_res;
use layermerge::util::rng::Rng;

fn gen_instance(r: &mut Rng) -> DpInput {
    let l = 2 + r.below(4);
    let p = 40 + r.below(60);
    let mut arcs = vec![Vec::new(); l + 1];
    for j in 1..=l {
        for i in 0..j {
            for k in [1usize, 3, 5] {
                if r.uniform() < 0.7 {
                    arcs[j].push(SpanArc {
                        i,
                        k,
                        lat_ms: r.range(0.1, 2.0) as f64,
                        imp: r.uniform() * 3.0,
                    });
                }
            }
        }
    }
    DpInput { l_max: l, budget_ms: r.range(0.5, 5.0) as f64, p, arcs }
}

/// Both DPs round arcs to the same latency grid, so objective equality is
/// exact (up to float noise), and feasibility must agree too.
#[test]
fn twostage_matches_alg1_objective() {
    check_res("twostage == alg1 objective", 120, gen_instance, |inst| {
        match (dp::solve(inst), twostage::solve(inst)) {
            (None, None) => Ok(()),
            (Some(a), Some(b)) => {
                if (a.objective - b.objective).abs() > 1e-9 {
                    return Err(format!(
                        "objective {} (alg1) vs {} (twostage)",
                        a.objective, b.objective
                    ));
                }
                // both reconstructions must be real chains 0 -> L whose
                // spans exist in the instance
                for sol in [&a, &b] {
                    let mut at = 0usize;
                    for &(i, j, k) in &sol.spans {
                        if i != at || j <= i {
                            return Err(format!("broken chain {:?}", sol.spans));
                        }
                        if !inst.arcs[j].iter().any(|x| x.i == i && x.k == k) {
                            return Err(format!("span ({i},{j},{k}) has no arc"));
                        }
                        at = j;
                    }
                    if at != inst.l_max {
                        return Err(format!("chain stops at {at} of {}", inst.l_max));
                    }
                }
                Ok(())
            }
            (a, b) => Err(format!(
                "feasibility mismatch: alg1 {:?} vs twostage {:?}",
                a.map(|s| s.objective),
                b.map(|s| s.objective)
            )),
        }
    });
}

/// The collapse step may only ever *remove* dominated arcs — the fronts
/// it keeps are a subset of the input, and every kept arc is undominated
/// within its (j, i) group.
#[test]
fn collapse_keeps_undominated_subsets() {
    check_res("collapse fronts are undominated", 80, gen_instance, |inst| {
        let fronts = twostage::collapse(inst);
        if fronts.len() != inst.arcs.len() {
            return Err("front shape mismatch".into());
        }
        for (j, front) in fronts.iter().enumerate() {
            for a in front {
                if !inst.arcs[j]
                    .iter()
                    .any(|x| x.i == a.i && x.k == a.k && (x.lat_ms - a.lat_ms).abs() < 1e-12)
                {
                    return Err(format!("front arc {a:?} not in input arcs[{j}]"));
                }
                // undominated: no same-span arc that is both cheaper (in
                // rounded latency) and at least as valuable
                let unit = inst.budget_ms / inst.p as f64;
                let cost = |l: f64| (l / unit).floor() as usize;
                if front.iter().any(|x| {
                    x.i == a.i
                        && !(x.k == a.k && (x.lat_ms - a.lat_ms).abs() < 1e-12)
                        && cost(x.lat_ms) <= cost(a.lat_ms)
                        && x.imp > a.imp + 1e-12
                }) {
                    return Err(format!("dominated arc {a:?} survived collapse at j={j}"));
                }
            }
        }
        Ok(())
    });
}

/// A fixed instance where the two-stage structure is visible: the fronts
/// shrink the arc set but the winner is still found.
#[test]
fn twostage_picks_the_known_optimum() {
    let arcs = vec![
        vec![],
        vec![SpanArc { i: 0, k: 3, lat_ms: 1.0, imp: 1.0 }],
        vec![
            SpanArc { i: 1, k: 3, lat_ms: 1.0, imp: 1.0 },
            SpanArc { i: 0, k: 5, lat_ms: 1.2, imp: 2.5 },
        ],
    ];
    let inst = DpInput { l_max: 2, budget_ms: 1.5, p: 100, arcs };
    let sol = twostage::solve(&inst).unwrap();
    assert_eq!(sol.spans, vec![(0, 2, 5)]);
    assert!((sol.objective - 2.5).abs() < 1e-9);
}
