//! Backend-generic profiling layer, end to end on the host backend:
//! `Profiler::measure_span` must agree with deploying the same span as a
//! real single-step plan, and the whole offline loop
//! (`pipeline::e2e_host` — profile -> solve -> merge -> deploy ->
//! measure) must predict the deployed plan's latency within a generous
//! bound.  No artifacts and no XLA anywhere in this file.

use std::sync::Arc;

use layermerge::exec::{CompiledPlan, Format, Plan, Step};
use layermerge::ir::synth;
use layermerge::merge::MergedConv;
use layermerge::pipeline::{self, PipelineCfg};
use layermerge::profile::Profiler;
use layermerge::runtime::{Backend, HostBackend};
use layermerge::tables::{BuildCfg, LatencyMode};
use layermerge::util::rng::Rng;
use layermerge::util::tensor::Tensor;

fn scratch(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("lm_profile_it_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn host_profiler(iters: usize) -> Profiler {
    Profiler::new(Arc::new(HostBackend::new()), LatencyMode::Measured, 1, iters)
}

/// Build the span (i, j] at kernel `k` as a standalone one-step plan —
/// the deployment-side realization of the signature `measure_span` times.
fn single_span_plan(sp: &layermerge::ir::Spec, i: usize, j: usize, k: usize) -> Plan {
    let first = sp.conv(i + 1);
    let (ci, co) = (first.cin, sp.conv(j).cout);
    let (s, dw) = (sp.span_stride(i, j), sp.span_depthwise(i, j));
    let mut rng = Rng::new(0x7e57);
    let wn = co * if dw { 1 } else { ci } * k * k;
    let weight = Tensor::new(
        vec![co, if dw { 1 } else { ci }, k, k],
        (0..wn).map(|_| rng.normal()).collect(),
    );
    let step = Step {
        i: 0,
        j: 1,
        merged: MergedConv {
            i: 0,
            j: 1,
            weight,
            bias: (0..co).map(|_| rng.normal()).collect(),
            k,
            stride: s,
            depthwise: dw,
        },
        h_in: first.h_in,
        w_in: first.w_in,
        cin: ci,
        act: None,
        gn: None,
        res: None,
        concat: None,
        time_bias: None,
        stash_as: None,
        post: vec![],
    };
    Plan {
        spec_name: format!("test-span-{i}-{j}-{k}"),
        task: layermerge::ir::Task::Classify,
        batch: sp.batch,
        steps: vec![step],
        head: None,
        temb: None,
        l_total: 1,
    }
}

/// `measure_span` and a deployed single-span plan time the same kernel
/// through the same protocol, so they must land within timing noise of
/// each other.  The bound is deliberately generous (8x either way) —
/// this guards against *structural* mismatches (wrong geometry, wrong
/// stride, wrong format), not scheduler jitter.
#[test]
fn measure_span_agrees_with_deployed_single_span_plan() {
    let (sp, _) = synth::by_name("hostchain-tiny").unwrap();
    let prof = host_profiler(5);
    for (i, j, k) in [(0usize, 2usize, 3usize), (1, 3, 3), (2, 4, 3)] {
        let span_ms = prof.measure_span(&sp, i, j, k).unwrap();
        let plan = single_span_plan(&sp, i, j, k);
        let backend: Arc<dyn Backend> = Arc::clone(prof.backend());
        let cp = CompiledPlan::lower(Arc::new(plan), backend, Format::Eager).unwrap();
        let plan_ms = cp.measure(1, 5).unwrap().p50_ms;
        assert!(span_ms > 0.0 && plan_ms > 0.0, "({i},{j},{k}): {span_ms} / {plan_ms}");
        let ratio = span_ms / plan_ms;
        assert!(
            (0.125..=8.0).contains(&ratio),
            "span ({i},{j},{k}): measure_span {span_ms:.5}ms vs deployed {plan_ms:.5}ms \
             (ratio {ratio:.2}) — structural mismatch, not noise"
        );
    }
}

/// The profiler must be able to measure a full deployed plan too — the
/// "actual" side of the e2e report — and a merged plan of the same spec
/// must not come out slower than ~the original by more than noise.
#[test]
fn measure_plan_runs_on_original_and_merged() {
    let (sp, flat) = synth::by_name("hostchain-tiny").unwrap();
    let prof = host_profiler(5);
    let orig = Arc::new(Plan::original(&sp, &flat).unwrap());
    let (a, c, spans) = layermerge::solver::depth::greedy_full_solution(&sp);
    let merged = Arc::new(Plan::from_solution(&sp, &flat, &a, &c, &spans).unwrap());
    let o = prof.measure_plan(orig, Format::Eager).unwrap();
    let m = prof.measure_plan(merged, Format::Eager).unwrap();
    assert!(o.p50_ms > 0.0 && m.p50_ms > 0.0);
    assert_eq!(o.iters, 5);
    assert!(
        m.p50_ms < o.p50_ms * 4.0,
        "greedy-merged ({:.4}ms) wildly slower than original ({:.4}ms)",
        m.p50_ms,
        o.p50_ms
    );
}

/// The full offline loop: measured host tables -> Algorithm 1 -> merge ->
/// deploy -> measure.  The table-sum prediction and the measured deployed
/// latency are different protocols over the same kernels, so the relative
/// error is pinned only under a generous bound; the structural facts
/// (depth shrinks, both solvers agree, everything positive) are exact.
#[test]
fn e2e_host_prediction_tracks_measurement() {
    let cfg = PipelineCfg {
        build: BuildCfg {
            mode: LatencyMode::Measured,
            warmup: 1,
            iters: 3,
            force: true,
            ..BuildCfg::default()
        },
        lat_warmup: 1,
        lat_iters: 3,
        ..PipelineCfg::default()
    };
    let r = pipeline::e2e_host("hostchain-tiny", 0.6, &cfg, &scratch("e2e")).unwrap();
    assert!(r.pred_orig_ms > 0.0 && r.actual_orig_ms > 0.0);
    assert!(r.pred_merged_ms > 0.0 && r.actual_merged_ms > 0.0);
    assert!(r.depth_after <= r.depth_before, "{} -> {}", r.depth_before, r.depth_after);
    assert!(!r.spans.is_empty());
    // predicted merged latency respects the budget the DP solved for, up
    // to the floor-discretization slack (<= l_max/p of the budget)
    assert!(r.pred_merged_ms <= r.pred_orig_ms * 0.6 * 1.05 + 1e-6);
    // the two DPs solve the identical instance: same objective exactly
    assert!(
        (r.dp_objective - r.twostage_objective).abs() < 1e-9,
        "alg1 {} vs twostage {}",
        r.dp_objective,
        r.twostage_objective
    );
    // generous: the sum-approximation plus per-dispatch noise on a tiny
    // spec; catches order-of-magnitude modeling bugs, not jitter
    assert!(
        r.rel_err() < 2.5,
        "table prediction off by {:.0}% (pred {:.4}ms vs actual {:.4}ms)",
        r.rel_err() * 100.0,
        r.pred_merged_ms,
        r.actual_merged_ms
    );
}

/// Frontier emission over host tables: every (method, budget) point lands
/// in EXPERIMENTS.md exactly once, under the stable section marker.
#[test]
fn frontier_emits_to_experiments_md() {
    let dir = scratch("frontier");
    let md = dir.join("EXPERIMENTS.md");
    let _ = std::fs::remove_file(&md);
    let cfg = BuildCfg { mode: LatencyMode::Analytical, force: true, ..BuildCfg::default() };
    let pts =
        layermerge::report::frontier::emit("hostchain-tiny", &[0.7], &cfg, 100, &dir, &md)
            .unwrap();
    assert_eq!(pts.len(), layermerge::report::frontier::METHODS.len() + 1);
    let s = std::fs::read_to_string(&md).unwrap();
    assert!(s.contains("<!-- exp:frontier:hostchain-tiny -->"), "missing marker:\n{s}");
    // re-emitting replaces the section instead of appending a duplicate
    layermerge::report::frontier::emit("hostchain-tiny", &[0.7], &cfg, 100, &dir, &md).unwrap();
    let s2 = std::fs::read_to_string(&md).unwrap();
    assert_eq!(s2.matches("exp:frontier:hostchain-tiny").count(), 2, "begin + end only");
}
