//! Integration: the Rust IR's combinatorics agree with the Python specs
//! that generated the artifacts — every (i, j, k) the solver can pick has
//! a conv artifact (the enumeration-parity contract of DESIGN.md §4), and
//! structural invariants hold on all real model families.

mod common;

use common::ctx;
use layermerge::ir::{Spec, K_MAX};
use layermerge::model::sig_str;

const MODELS: [&str; 5] =
    ["resnetish", "mnv2ish-1.0", "mnv2ish-1.4", "mnv2ish-0.75", "ddpmish"];

fn load(t: &common::TestCtx, name: &str) -> Spec {
    Spec::load(&t.root.join(format!("specs/{name}.spec.json"))).unwrap()
}

#[test]
fn every_solver_span_has_a_conv_artifact() {
    let Some(t) = ctx() else { return };
    for name in MODELS {
        let spec = load(&t, name);
        let mut missing = Vec::new();
        for (i, j) in spec.spans() {
            let first = spec.conv(i + 1);
            for k in spec.kernel_options(i, j) {
                let sig = sig_str(
                    spec.batch, first.h_in, first.w_in, first.cin,
                    spec.conv(j).cout, k, spec.span_stride(i, j),
                    spec.span_depthwise(i, j),
                );
                if t.man.conv_art(&sig, "plain").is_none() {
                    missing.push(sig);
                }
            }
        }
        assert!(missing.is_empty(), "{name}: missing artifacts {missing:?}");
    }
}

#[test]
fn projection_shortcuts_have_artifacts() {
    let Some(t) = ctx() else { return };
    for name in MODELS {
        let spec = load(&t, name);
        for c in &spec.convs {
            if let (Some(af), Some(p)) = (c.add_from, &c.add_proj) {
                let src = spec.conv(af);
                let sig = sig_str(
                    spec.batch, src.h_in, src.w_in, p.cin, p.cout, p.k,
                    p.stride, false,
                );
                assert!(
                    t.man.conv_art(&sig, "plain").is_some(),
                    "{name}: missing projection artifact {sig}"
                );
            }
        }
    }
}

#[test]
fn irreducible_set_matches_shape_preservation() {
    let Some(t) = ctx() else { return };
    for name in MODELS {
        let spec = load(&t, name);
        for c in &spec.convs {
            let preserving =
                c.cin == c.cout && c.stride == 1 && c.concat_from.is_none();
            if c.conv_gated {
                assert!(preserving, "{name} layer {} wrongly reducible", c.idx);
            }
        }
        assert!(!spec.irreducible().is_empty(), "{name}: R empty?");
    }
}

#[test]
fn kernel_options_respect_cap_and_parity() {
    let Some(t) = ctx() else { return };
    for name in MODELS {
        let spec = load(&t, name);
        for (i, j) in spec.spans() {
            let opts = spec.kernel_options(i, j);
            assert!(!opts.is_empty() || {
                // spans whose forced kernel exceeds K_MAX legitimately
                // have no options — the solver then can't pick them
                true
            });
            for k in opts {
                assert!(k <= K_MAX && k % 2 == 1, "{name} ({i},{j}) k={k}");
            }
        }
    }
}

#[test]
fn segments_partition_the_chain() {
    let Some(t) = ctx() else { return };
    for name in MODELS {
        let spec = load(&t, name);
        let segs = spec.segments();
        let mut expect = 1usize;
        for (s, e) in &segs {
            assert_eq!(*s, expect, "{name}: segment gap");
            assert!(e >= s);
            expect = e + 1;
        }
        assert_eq!(expect, spec.len() + 1, "{name}: segments don't cover L");
    }
}

#[test]
fn single_layer_spans_always_available() {
    // the DP must always have the trivial cover (no merging at all)
    let Some(t) = ctx() else { return };
    for name in MODELS {
        let spec = load(&t, name);
        let spans = spec.spans();
        for j in 1..=spec.len() {
            assert!(
                spans.contains(&(j - 1, j)),
                "{name}: missing singleton span ({}, {j}]",
                j - 1
            );
        }
    }
}

#[test]
fn init_params_finite_and_sized() {
    let Some(t) = ctx() else { return };
    for name in MODELS {
        let spec = load(&t, name);
        let init = layermerge::util::tensor::Tensor::read_f32_file(
            &t.root.join(format!("{name}/init.bin")),
        )
        .unwrap();
        assert_eq!(init.len(), spec.param_count, "{name}: init size");
        assert!(init.iter().all(|v| v.is_finite()), "{name}: non-finite init");
        // parameter layout covers the vector exactly, without overlap
        let mut covered = 0usize;
        let mut max_end = 0usize;
        for p in &spec.params {
            assert_eq!(p.offset, covered, "{name}: layout gap at {}", p.name);
            covered += p.size;
            max_end = max_end.max(p.offset + p.size);
        }
        assert_eq!(max_end, spec.param_count);
    }
}
