//! Integration: the deployed merged network (exec::Plan) agrees with the
//! gated AOT graph that fine-tuning saw, across solution shapes — the
//! load-bearing correctness property of the whole deployment path.
//!
//! * original plan (no compression) == gated graph with pristine gates
//!   (exact: no padding reorder happens for singleton spans);
//! * merged multi-layer spans == gated graph up to the SAME-padding
//!   reorder boundary effect (small rel_l2; interior is exact — the
//!   merge-module unit tests pin the exact VALID-conv algebra);
//! * Fused format == Eager format (exact);
//! * `CompiledPlan` (the owned one-time lowering, via `Engine::lower`) ==
//!   one-shot `Engine::infer`, with zero `Runtime` cache lookups per
//!   forward after lowering.

mod common;

use std::collections::BTreeSet;
use std::sync::Arc;

use common::ctx;
use layermerge::exec::{Format, Plan};
use layermerge::ir::Spec;
use layermerge::model::{Batch, Model};
use layermerge::serve::Engine;
use layermerge::train::{self, Gen};

fn setup(engine: &Engine, name: &str) -> (Model, Vec<f32>) {
    let model = engine.load_model(name).unwrap();
    let params = model.init.clone();
    (model, params)
}

#[test]
fn original_plan_matches_gated_graph_exactly() {
    let Some(t) = ctx() else { return };
    let engine = t.engine();
    for name in ["resnetish", "mnv2ish-1.0"] {
        let (model, params) = setup(&engine, name);
        let gen = Gen::for_model(&model, 7);
        let batch = gen.batch(train::STREAM_EVAL, 0);
        let x = match &batch {
            Batch::Classify { x, .. } => x.clone(),
            _ => unreachable!(),
        };
        let gates = model.spec.pristine_gates();
        let gated = model.forward(&params, &gates, &batch).unwrap();
        let plan = Arc::new(Plan::original(&model.spec, &params).unwrap());
        let eager = engine.infer(&plan, &x, None, Format::Eager).unwrap();
        assert!(
            eager.rel_l2(&gated) < 1e-4,
            "{name}: original plan deviates rel_l2 {}",
            eager.rel_l2(&gated)
        );
        let fused = engine.infer(&plan, &x, None, Format::Fused).unwrap();
        assert!(fused.rel_l2(&eager) < 1e-5, "{name}: fused != eager");
    }
}

/// Build a "merge everything in each segment, keep all convs" solution —
/// the Depth baseline's extreme point — and check plan-vs-gated deviation
/// stays small (boundary-only effect).
#[test]
fn segment_merged_plan_close_to_gated_graph() {
    let Some(t) = ctx() else { return };
    let engine = t.engine();
    let (model, params) = setup(&engine, "resnetish");
    let spec: &Spec = &model.spec;
    // cover each segment greedily with valid spans of full kernels
    let (a, c, spans) = layermerge::solver::depth::greedy_full_solution(spec);
    assert!(
        spans.iter().any(|&(i, j, _)| j - i > 1),
        "expected at least one real merge in {spans:?}"
    );
    let a_set: BTreeSet<usize> = a.iter().copied().collect();
    let gates = spec.solution_gates(&a_set, &c, &spans);
    let gen = Gen::for_model(&model, 7);
    let batch = gen.batch(train::STREAM_EVAL, 1);
    let x = match &batch {
        Batch::Classify { x, .. } => x.clone(),
        _ => unreachable!(),
    };
    let gated = model.forward(&params, &gates, &batch).unwrap();
    let plan = Arc::new(Plan::from_solution(spec, &params, &a, &c, &spans).unwrap());
    assert!(plan.depth() < spec.len(), "merging must reduce depth");
    let eager = engine.infer(&plan, &x, None, Format::Eager).unwrap();
    let dev = eager.rel_l2(&gated);
    // SAME-padding reorder: boundary rows differ, logits shift slightly.
    assert!(dev < 0.35, "merged plan deviates too much: rel_l2 {dev}");
    let fused = engine.infer(&plan, &x, None, Format::Fused).unwrap();
    assert!(fused.rel_l2(&eager) < 1e-4, "fused != eager: {}", fused.rel_l2(&eager));
}

/// LayerOnly-style dropped layers must be *elided* from the plan (true
/// latency reduction), and numerics must match the gated graph exactly.
#[test]
fn dropped_layers_are_elided_and_exact() {
    let Some(t) = ctx() else { return };
    let engine = t.engine();
    let (model, params) = setup(&engine, "resnetish");
    let spec = &model.spec;
    // drop the first two reducible non-add layers
    let droppable: Vec<usize> = spec
        .convs
        .iter()
        .filter(|c| c.conv_gated && c.add_from.is_none())
        .map(|c| c.idx)
        .take(2)
        .collect();
    assert_eq!(droppable.len(), 2);
    let c_set: BTreeSet<usize> =
        (1..=spec.len()).filter(|l| !droppable.contains(l)).collect();
    let a = layermerge::solver::layeronly::deploy_a(spec, &c_set);
    let spans = layermerge::solver::layeronly::deploy_spans(spec, &c_set);
    let plan = Arc::new(Plan::from_solution(spec, &params, &a, &c_set, &spans).unwrap());
    assert_eq!(
        plan.depth(),
        spec.len() - droppable.len(),
        "dropped layers not elided"
    );
    let a_set: BTreeSet<usize> = a.iter().copied().collect();
    let gates = spec.solution_gates(&a_set, &c_set, &spans);
    let gen = Gen::for_model(&model, 7);
    let batch = gen.batch(train::STREAM_EVAL, 2);
    let x = match &batch {
        Batch::Classify { x, .. } => x.clone(),
        _ => unreachable!(),
    };
    let gated = model.forward(&params, &gates, &batch).unwrap();
    let eager = engine.infer(&plan, &x, None, Format::Eager).unwrap();
    assert!(
        eager.rel_l2(&gated) < 1e-4,
        "dropped-layer plan deviates: {}",
        eager.rel_l2(&gated)
    );
}

/// The owned lowered plan must be bit-equivalent to the one-shot forward
/// (same executables, same operand tensors, same op order), and its
/// steady-state loop must not touch the Runtime cache at all.
#[test]
fn compiled_plan_matches_forward_with_zero_runtime_loads() {
    let Some(t) = ctx() else { return };
    let engine = t.engine();
    for name in ["resnetish", "mnv2ish-1.0"] {
        let (model, params) = setup(&engine, name);
        let gen = Gen::for_model(&model, 7);
        let batch = gen.batch(train::STREAM_EVAL, 3);
        let x = match &batch {
            Batch::Classify { x, .. } => x.clone(),
            _ => unreachable!(),
        };
        let plan = Arc::new(Plan::original(&model.spec, &params).unwrap());
        for fmt in [Format::Eager, Format::Fused] {
            let oneshot = engine.infer(&plan, &x, None, fmt).unwrap();
            let cp = engine.lower(&plan, fmt).unwrap();
            // the owned CompiledPlan can outlive any borrow of the plan —
            // hand it to another thread and dispatch there
            let loads_before = engine.runtime().loads();
            let (got, got2) = std::thread::scope(|s| {
                s.spawn(|| (cp.forward(&x, None).unwrap(), cp.forward(&x, None).unwrap()))
                    .join()
                    .unwrap()
            });
            assert_eq!(
                engine.runtime().loads(),
                loads_before,
                "{name} {fmt:?}: compiled forward touched the Runtime cache"
            );
            assert!(
                got.rel_l2(&oneshot) < 1e-6,
                "{name} {fmt:?}: compiled != one-shot, rel_l2 {}",
                got.rel_l2(&oneshot)
            );
            assert!(got2.rel_l2(&got) < 1e-7, "{name} {fmt:?}: not deterministic");
        }
    }
}

/// Same equivalence for a *merged* solution (residual slots, canonical
/// boundary remapping, elided steps) — the dataflow cases the lowering's
/// slot/release analysis must get right.
#[test]
fn compiled_plan_matches_forward_on_merged_solution() {
    let Some(t) = ctx() else { return };
    let engine = t.engine();
    let (model, params) = setup(&engine, "resnetish");
    let spec: &Spec = &model.spec;
    // drop one reducible layer and merge the rest of its segment where
    // possible: exercises elision + non-chain boundary reads together
    let droppable: Vec<usize> = spec
        .convs
        .iter()
        .filter(|c| c.conv_gated && c.add_from.is_none())
        .map(|c| c.idx)
        .take(1)
        .collect();
    let c_set: BTreeSet<usize> =
        (1..=spec.len()).filter(|l| !droppable.contains(l)).collect();
    let a = layermerge::solver::layeronly::deploy_a(spec, &c_set);
    let spans = layermerge::solver::layeronly::deploy_spans(spec, &c_set);
    let plan = Arc::new(Plan::from_solution(spec, &params, &a, &c_set, &spans).unwrap());
    let gen = Gen::for_model(&model, 11);
    let batch = gen.batch(train::STREAM_EVAL, 4);
    let x = match &batch {
        Batch::Classify { x, .. } => x.clone(),
        _ => unreachable!(),
    };
    let oneshot = engine.infer(&plan, &x, None, Format::Eager).unwrap();
    let cp = engine.lower(&plan, Format::Eager).unwrap();
    let loads_before = engine.runtime().loads();
    let got = cp.forward(&x, None).unwrap();
    assert_eq!(
        engine.runtime().loads(),
        loads_before,
        "compiled forward must be load-free"
    );
    assert!(
        got.rel_l2(&oneshot) < 1e-6,
        "merged compiled != one-shot: rel_l2 {}",
        got.rel_l2(&oneshot)
    );
}

/// The diffusion plan must run end to end (concat, gn, attention,
/// upsample, time bias) and agree with the gated graph on the original
/// configuration.
#[test]
fn ddpm_original_plan_matches_gated_graph() {
    let Some(t) = ctx() else { return };
    let engine = t.engine();
    let (model, params) = setup(&engine, "ddpmish");
    let gen = Gen::for_model(&model, 7);
    let batch = gen.batch(train::STREAM_EVAL, 0);
    let (x0, tt) = match &batch {
        Batch::Diffusion { x0, t, .. } => (x0.clone(), t.clone()),
        _ => unreachable!(),
    };
    let gates = model.spec.pristine_gates();
    let gated = model.forward(&params, &gates, &batch).unwrap();
    let plan = Arc::new(Plan::original(&model.spec, &params).unwrap());
    let eager = engine.infer(&plan, &x0, Some(&tt), Format::Eager).unwrap();
    assert!(
        eager.rel_l2(&gated) < 1e-3,
        "ddpm plan deviates rel_l2 {}",
        eager.rel_l2(&gated)
    );
    // lowered form covers the full structural-op set: stash/concat slots,
    // time-bias injection, attention and upsample posts
    let cp = engine.lower(&plan, Format::Eager).unwrap();
    let loads_before = engine.runtime().loads();
    let compiled = cp.forward(&x0, Some(&tt)).unwrap();
    assert_eq!(
        engine.runtime().loads(),
        loads_before,
        "ddpm compiled forward load-free"
    );
    assert!(
        compiled.rel_l2(&eager) < 1e-6,
        "ddpm compiled != one-shot: rel_l2 {}",
        compiled.rel_l2(&eager)
    );
}
