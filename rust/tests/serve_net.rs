//! The network-tier suite: the TCP serving tier (`serve::net`) over
//! loopback, host-only (sessions run on deterministic host backends via
//! `Session::from_fn`; no PJRT runtime needed).
//!
//! Pins the ISSUE-6 acceptance properties:
//! * wire round-trips are bit-identical to the in-process oracle, and
//!   the `/stats` frame carries the shed/expired/failed counters,
//! * malformed input never kills the process: a truncated body gets a
//!   typed `BadFrame` and the connection keeps serving; wrong magic or
//!   a hostile length prefix gets one refusal and a close — and the
//!   server serves the next client either way,
//! * deadlines propagate: queued requests expire fast, and with a warm
//!   service EWMA admission control sheds at the door,
//! * overload at ~2x capacity sheds at admission with a bounded queue
//!   while the p99 of *admitted* requests stays within the SLO bound,
//! * fault isolation: a panicking batch poisons only its own reply, a
//!   mid-request disconnect costs one connection, backlog overflow gets
//!   a typed refusal — the server keeps serving after each,
//! * graceful drain: in-flight requests finish, idle connections get
//!   `ShuttingDown`, and the port stops accepting.
//!
//! Every test binds `127.0.0.1:0`; where loopback sockets are
//! unavailable the test skips cleanly instead of failing.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use layermerge::serve::net::{drive_net, NetCfg, NetClient, NetServer};
use layermerge::serve::proto::{self, ErrCode, Request, Response, MAX_FRAME};
use layermerge::serve::{BatchPolicy, ServeCfg, Session};
use layermerge::util::tensor::Tensor;

const B: usize = 4; // spec batch size for the mock deployments
const TAIL: [usize; 1] = [3]; // per-row feature length

/// Deterministic per-row "network" (same oracle as the serve_queue
/// suite): row r of the output depends on row r of the input only.
fn row_fn(row: &[f32]) -> [f32; 2] {
    let sum: f32 = row.iter().sum();
    let sq: f32 = row.iter().map(|v| v * v).sum();
    [sum * 0.5 + 1.0, sq - row[0]]
}

fn mock_backend(x: &Tensor, _t: Option<&Tensor>) -> anyhow::Result<Tensor> {
    let rl: usize = x.dims[1..].iter().product();
    let mut out = Tensor::zeros(&[x.dims[0], 2]);
    for r in 0..x.dims[0] {
        let y = row_fn(&x.data[r * rl..(r + 1) * rl]);
        out.data[r * 2..(r + 1) * 2].copy_from_slice(&y);
    }
    Ok(out)
}

fn serve_cfg(workers: usize, slo_ms: u64) -> ServeCfg {
    ServeCfg {
        workers,
        queue_cap: 256,
        policy: BatchPolicy::Greedy,
        slo: (slo_ms > 0).then_some(Duration::from_millis(slo_ms)),
        ..ServeCfg::default()
    }
}

fn req(rows: usize, seed: f32) -> Tensor {
    let rl: usize = TAIL.iter().product();
    Tensor::new(
        vec![rows, TAIL[0]],
        (0..rows * rl).map(|i| seed + i as f32 * 0.25).collect(),
    )
}

fn expect(x: &Tensor) -> Vec<f32> {
    let rl: usize = TAIL.iter().product();
    (0..x.dims[0])
        .flat_map(|r| row_fn(&x.data[r * rl..(r + 1) * rl]))
        .collect()
}

/// Bind an ephemeral loopback port, or skip the test cleanly in
/// environments with no usable loopback.
fn bind_or_skip(sess: Session, cfg: NetCfg) -> Option<NetServer> {
    match NetServer::bind(Arc::new(sess), "127.0.0.1:0", cfg) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping serve_net test (no loopback): {e}");
            None
        }
    }
}

/// Raw framed write for protocol-abuse tests (the length prefix is
/// whatever the test says it is).
fn send_raw(s: &mut TcpStream, body: &[u8]) {
    s.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
    s.write_all(body).unwrap();
    s.flush().unwrap();
}

/// Raw framed read; `None` on clean EOF.  The caller sets a read
/// timeout, so a server that stops replying fails the test instead of
/// hanging it.
fn read_raw(s: &mut TcpStream) -> Option<Vec<u8>> {
    let mut hdr = [0u8; 4];
    let mut at = 0usize;
    while at < 4 {
        match s.read(&mut hdr[at..]) {
            Ok(0) if at == 0 => return None,
            Ok(0) => panic!("connection closed mid-header"),
            Ok(n) => at += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => panic!("raw read failed: {e}"),
        }
    }
    let len = u32::from_le_bytes(hdr) as usize;
    assert!(len <= MAX_FRAME, "server sent an oversized frame ({len})");
    let mut body = vec![0u8; len];
    s.read_exact(&mut body).unwrap();
    Some(body)
}

fn raw_connect(addr: SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.set_write_timeout(Some(Duration::from_secs(10))).unwrap();
    s
}

/// Decode a response body or die trying — protocol-abuse tests only ever
/// expect well-formed replies back.
fn decode(body: &[u8]) -> Response {
    proto::decode_response(body).expect("server reply must decode")
}

#[test]
fn roundtrip_infer_and_stats_over_loopback() {
    let sess = Session::from_fn(B, &TAIL, false, serve_cfg(2, 0), mock_backend);
    let Some(server) = bind_or_skip(sess, NetCfg::default()) else { return };
    let mut c = NetClient::connect(server.addr()).unwrap();
    for i in 0..5 {
        let x = req(1 + i % B, i as f32 * 3.0);
        let y = c.infer(&x, None).unwrap();
        assert_eq!(y.dims, vec![x.dims[0], 2]);
        assert_eq!(y.data, expect(&x), "wire round-trip broke row parity");
    }
    let j = c.stats().unwrap();
    assert!(j.get("requests").and_then(|v| v.as_usize()).unwrap() >= 5);
    for key in ["shed_requests", "expired_requests", "failed_batches"] {
        assert!(j.get(key).is_some(), "stats frame missing {key}");
    }
    let net = j.get("net").expect("stats frame missing net counters");
    assert!(net.get("frames").and_then(|v| v.as_usize()).unwrap() >= 6);
    assert_eq!(net.get("handler_panics").and_then(|v| v.as_usize()), Some(0));
    server.shutdown();
}

#[test]
fn wrong_magic_gets_one_refusal_then_close_and_server_survives() {
    let sess = Session::from_fn(B, &TAIL, false, serve_cfg(1, 0), mock_backend);
    let Some(server) = bind_or_skip(sess, NetCfg::default()) else { return };
    let mut s = raw_connect(server.addr());
    // honest framing, alien body: not our magic
    send_raw(&mut s, b"XXXXxxxxxxxxxxxx");
    match decode(&read_raw(&mut s).expect("refusal frame")) {
        Response::Error { code, .. } => assert_eq!(code, ErrCode::BadFrame),
        other => panic!("expected a BadFrame error, got {other:?}"),
    }
    // framing trust is gone: the server closes this connection
    assert!(read_raw(&mut s).is_none(), "wrong-magic connection must close");
    // ...but the process and every other connection live on
    let mut c = NetClient::connect(server.addr()).unwrap();
    let x = req(2, 1.0);
    assert_eq!(c.infer(&x, None).unwrap().data, expect(&x));
    assert!(server.stats().bad_frames >= 1);
    server.shutdown();
}

#[test]
fn truncated_body_keeps_the_connection_serving() {
    let sess = Session::from_fn(B, &TAIL, false, serve_cfg(1, 0), mock_backend);
    let Some(server) = bind_or_skip(sess, NetCfg::default()) else { return };
    let mut s = raw_connect(server.addr());
    // our magic, honest length prefix, but the body stops inside the id
    let full = proto::encode_request(&Request::Infer {
        id: 9,
        deadline_us: 0,
        tenant: String::new(),
        x: req(1, 0.0),
        t: None,
    });
    send_raw(&mut s, &full[..10]);
    match decode(&read_raw(&mut s).expect("BadFrame reply")) {
        Response::Error { code, msg, .. } => {
            assert_eq!(code, ErrCode::BadFrame);
            assert!(msg.contains("truncated"), "{msg}");
        }
        other => panic!("expected a BadFrame error, got {other:?}"),
    }
    // the stream is still in sync: the same connection serves the next
    // (well-formed) request
    let x = req(3, 5.0);
    send_raw(
        &mut s,
        &proto::encode_request(&Request::Infer {
            id: 10,
            deadline_us: 0,
            tenant: String::new(),
            x: x.clone(),
            t: None,
        }),
    );
    match decode(&read_raw(&mut s).expect("tensor reply")) {
        Response::Tensor { id, y } => {
            assert_eq!(id, 10);
            assert_eq!(y.data, expect(&x));
        }
        other => panic!("expected a tensor, got {other:?}"),
    }
    server.shutdown();
}

#[test]
fn hostile_length_prefix_is_refused_without_allocation() {
    let sess = Session::from_fn(B, &TAIL, false, serve_cfg(1, 0), mock_backend);
    let Some(server) = bind_or_skip(sess, NetCfg::default()) else { return };
    let mut s = raw_connect(server.addr());
    // a length prefix claiming ~4GiB: refusal must not allocate it
    s.write_all(&u32::MAX.to_le_bytes()).unwrap();
    s.flush().unwrap();
    match decode(&read_raw(&mut s).expect("refusal frame")) {
        Response::Error { code, .. } => assert_eq!(code, ErrCode::BadFrame),
        other => panic!("expected a BadFrame error, got {other:?}"),
    }
    assert!(read_raw(&mut s).is_none(), "hostile-length connection must close");
    let mut c = NetClient::connect(server.addr()).unwrap();
    let x = req(1, 2.0);
    assert_eq!(c.infer(&x, None).unwrap().data, expect(&x));
    server.shutdown();
}

#[test]
fn queued_request_expires_fast_behind_a_busy_worker() {
    // one worker held 40ms per batch; a 1ms-deadline request queued
    // behind it must come back DeadlineExceeded, not 40ms late
    let sess = Session::from_fn(B, &TAIL, false, serve_cfg(1, 0), |x, t| {
        std::thread::sleep(Duration::from_millis(40));
        mock_backend(x, t)
    });
    let Some(server) = bind_or_skip(sess, NetCfg::default()) else { return };
    let addr = server.addr();
    let busy = std::thread::spawn(move || {
        let mut c = NetClient::connect(addr).unwrap();
        let x = req(B, 0.0);
        assert_eq!(c.infer(&x, None).unwrap().data, expect(&x));
    });
    std::thread::sleep(Duration::from_millis(10)); // the worker is mid-batch
    let mut c = NetClient::connect(addr).unwrap();
    let verdict = c
        .infer_deadline(&req(1, 1.0), None, Some(Duration::from_millis(1)))
        .unwrap();
    match verdict {
        Err((code, _)) => assert_eq!(code, ErrCode::DeadlineExceeded),
        Ok(_) => panic!("a 1ms-deadline request behind a 40ms batch must expire"),
    }
    busy.join().unwrap();
    assert!(server.session().stats().expired_requests >= 1);
    server.shutdown();
}

#[test]
fn warm_ewma_sheds_at_admission() {
    // 30ms batches against a 10ms SLO: the first request warms the EWMA
    // (always admitted cold), the second is shed at the door
    let sess = Session::from_fn(B, &TAIL, false, serve_cfg(1, 10), |x, t| {
        std::thread::sleep(Duration::from_millis(30));
        mock_backend(x, t)
    });
    let Some(server) = bind_or_skip(sess, NetCfg::default()) else { return };
    let mut c = NetClient::connect(server.addr()).unwrap();
    let x = req(B, 0.0);
    assert_eq!(c.infer(&x, None).unwrap().data, expect(&x));
    assert!(server.session().ewma_service_us() >= 20_000);
    match c.infer_deadline(&req(1, 1.0), None, None).unwrap() {
        Err((code, msg)) => {
            assert_eq!(code, ErrCode::Shed);
            assert!(msg.contains("shed at admission"), "{msg}");
        }
        Ok(_) => panic!("a 30ms predicted wait must be shed against a 10ms SLO"),
    }
    assert_eq!(server.session().stats().shed_requests, 1);
    server.shutdown();
}

#[test]
fn overload_sheds_at_admission_with_bounded_queue_and_slo_p99() {
    // ~2x capacity: one worker, 10ms per batch, B=4 -> ~400 one-row
    // requests/s capacity; offer ~800/s with a 15ms deadline == SLO
    let sess = Session::from_fn(B, &TAIL, false, serve_cfg(1, 15), |x, t| {
        std::thread::sleep(Duration::from_millis(10));
        mock_backend(x, t)
    });
    let net_cfg = NetCfg { conn_workers: 16, ..NetCfg::default() };
    let Some(server) = bind_or_skip(sess, net_cfg) else { return };
    let r = drive_net(
        server.addr(),
        800.0,
        160,
        16,
        Some(Duration::from_millis(15)),
        42,
        |i| (req(1, i as f32), None),
    )
    .unwrap();
    assert_eq!(r.requests, 160);
    assert_eq!(
        r.ok + r.shed + r.expired + r.failed,
        r.requests,
        "outcome classification must partition completions: {r:?}"
    );
    assert!(r.ok > 0, "overload must not starve every request: {r:?}");
    assert!(r.shed > 0, "admission control never engaged at 2x capacity: {r:?}");
    assert_eq!(r.failed, 0, "no transport/backend failures expected: {r:?}");
    // p99 of ADMITTED requests holds the SLO bound (deadline + a few
    // batch service times of slack); shedding at the door is what keeps
    // it there — an unbounded queue would blow far past this
    assert!(
        r.p99_ms.is_finite() && r.p99_ms < 80.0,
        "p99 of admitted requests out of bounds: {r:?}"
    );
    let s = server.session().stats();
    assert!(s.shed_requests > 0);
    assert!(
        s.max_queue <= 64,
        "queue depth {} not bounded under overload",
        s.max_queue
    );
    server.shutdown();
}

#[test]
fn mid_request_disconnects_cost_one_connection_each() {
    let sess = Session::from_fn(B, &TAIL, false, serve_cfg(1, 0), |x, t| {
        std::thread::sleep(Duration::from_millis(10));
        mock_backend(x, t)
    });
    let Some(server) = bind_or_skip(sess, NetCfg::default()) else { return };
    // peer A: full request frame, then vanish before the reply
    {
        let mut s = raw_connect(server.addr());
        send_raw(
            &mut s,
            &proto::encode_request(&Request::Infer {
                id: 1,
                deadline_us: 0,
                tenant: String::new(),
                x: req(1, 0.0),
                t: None,
            }),
        );
    } // dropped here
    // peer B: half a length prefix, then vanish mid-frame
    {
        let mut s = raw_connect(server.addr());
        s.write_all(&[0x10, 0x00]).unwrap();
        s.flush().unwrap();
    }
    std::thread::sleep(Duration::from_millis(60));
    // the server is still serving, and nothing panicked
    let mut c = NetClient::connect(server.addr()).unwrap();
    let x = req(2, 3.0);
    assert_eq!(c.infer(&x, None).unwrap().data, expect(&x));
    assert_eq!(server.stats().handler_panics, 0);
    server.shutdown();
}

#[test]
fn nth_batch_panic_is_isolated_and_the_server_keeps_serving() {
    let calls = Arc::new(AtomicUsize::new(0));
    let c2 = Arc::clone(&calls);
    let sess = Session::from_fn(B, &TAIL, false, serve_cfg(1, 0), move |x, t| {
        if c2.fetch_add(1, Ordering::Relaxed) == 1 {
            panic!("injected fault on batch 2");
        }
        mock_backend(x, t)
    });
    let Some(server) = bind_or_skip(sess, NetCfg::default()) else { return };
    let mut c = NetClient::connect(server.addr()).unwrap();
    let x1 = req(1, 0.0);
    assert_eq!(c.infer(&x1, None).unwrap().data, expect(&x1));
    // batch 2 panics: this reply (and only this one) is a typed failure
    match c.infer_deadline(&req(1, 1.0), None, None).unwrap() {
        Err((code, msg)) => {
            assert_eq!(code, ErrCode::BackendFailed);
            assert!(msg.contains("panicked"), "{msg}");
        }
        Ok(_) => panic!("the panicking batch must fail its reply"),
    }
    // same connection, next request: served again
    let x3 = req(2, 2.0);
    assert_eq!(c.infer(&x3, None).unwrap().data, expect(&x3));
    let s = server.session().stats();
    assert_eq!(s.failed_batches, 1);
    assert_eq!(server.stats().handler_panics, 0, "panic crossed the session boundary");
    server.shutdown();
}

#[test]
fn backlog_overflow_gets_a_typed_refusal() {
    let sess = Session::from_fn(B, &TAIL, false, serve_cfg(1, 0), mock_backend);
    let cfg = NetCfg { conn_workers: 1, backlog: 1, ..NetCfg::default() };
    let Some(server) = bind_or_skip(sess, cfg) else { return };
    // conn A occupies the only handler (a served request proves it)
    let mut a = NetClient::connect(server.addr()).unwrap();
    let xa = req(1, 0.0);
    assert_eq!(a.infer(&xa, None).unwrap().data, expect(&xa));
    // conn B fills the one-slot backlog
    let _b = raw_connect(server.addr());
    std::thread::sleep(Duration::from_millis(100));
    // conn C overflows it: best-effort Shed frame, then close
    let mut c = raw_connect(server.addr());
    match decode(&read_raw(&mut c).expect("refusal frame")) {
        Response::Error { code, .. } => assert_eq!(code, ErrCode::Shed),
        other => panic!("expected a Shed refusal, got {other:?}"),
    }
    assert!(read_raw(&mut c).is_none(), "refused connection must close");
    assert!(server.stats().refused >= 1);
    server.shutdown();
}

#[test]
fn graceful_drain_finishes_in_flight_and_notifies_idle_conns() {
    let sess = Session::from_fn(B, &TAIL, false, serve_cfg(1, 0), |x, t| {
        std::thread::sleep(Duration::from_millis(30));
        mock_backend(x, t)
    });
    let Some(server) = bind_or_skip(sess, NetCfg::default()) else { return };
    let addr = server.addr();
    // an idle connection, already owned by a handler
    let mut idle = raw_connect(addr);
    std::thread::sleep(Duration::from_millis(20));
    // an in-flight request racing the shutdown
    let inflight = std::thread::spawn(move || {
        let mut c = NetClient::connect(addr).unwrap();
        let x = req(2, 7.0);
        (c.infer(&x, None).unwrap().data, expect(&x))
    });
    std::thread::sleep(Duration::from_millis(10));
    server.shutdown();
    // the in-flight request finished, correctly, across the drain
    let (got, want) = inflight.join().unwrap();
    assert_eq!(got, want, "drain dropped or corrupted an in-flight request");
    // the idle connection got a typed goodbye
    match decode(&read_raw(&mut idle).expect("drain notice")) {
        Response::Error { code, .. } => assert_eq!(code, ErrCode::ShuttingDown),
        other => panic!("expected ShuttingDown, got {other:?}"),
    }
    // and the port no longer accepts
    assert!(
        TcpStream::connect(addr).is_err(),
        "listener still accepting after shutdown"
    );
}
