//! Property parity: the flat-GEMM kernel/merge path against the retained
//! naive oracles, across random configurations (dense, depthwise,
//! strided) — the load-bearing guarantee that the fast host path computes
//! the paper's Sec. 2 operator exactly.  Host-only: no artifacts needed.

use layermerge::kernels::{
    available_isas, conv2d_valid, conv2d_valid_ref, gemm, gemm_packed, gemm_packed_epi_i8_isa,
    gemm_packed_epi_isa, gemm_ref, Isa, PackedB, PackedBI8,
};
use layermerge::merge::{expand_depthwise, merge_kernels, merge_kernels_ref};
use layermerge::util::prop::check_res;
use layermerge::util::rng::Rng;
use layermerge::util::tensor::Tensor;

fn randt(r: &mut Rng, dims: &[usize]) -> Tensor {
    let n: usize = dims.iter().product();
    Tensor::new(dims.to_vec(), (0..n).map(|_| r.normal()).collect())
}

#[test]
fn gemm_matches_naive_over_random_shapes() {
    check_res(
        "gemm == naive triple loop",
        25,
        |r| {
            let (m, k, n) = (1 + r.below(24), 1 + r.below(40), 1 + r.below(24));
            let a: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
            (m, k, n, a, b)
        },
        |(m, k, n, a, b)| {
            let mut want = vec![0.0f32; m * n];
            gemm_ref(*m, *k, *n, a, b, &mut want);
            let mut got = vec![0.0f32; m * n];
            gemm(*m, *k, *n, a, b, &mut got);
            let diff = want
                .iter()
                .zip(&got)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            if diff < 1e-3 {
                Ok(())
            } else {
                Err(format!("({m},{k},{n}) diff {diff}"))
            }
        },
    );
}

#[test]
fn packed_gemm_matches_naive_over_random_shapes() {
    check_res(
        "packed micro-kernel == naive triple loop",
        25,
        |r| {
            let (m, k, n) = (1 + r.below(40), 1 + r.below(60), 1 + r.below(40));
            let a: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
            (m, k, n, a, b)
        },
        |(m, k, n, a, b)| {
            let mut want = vec![0.0f32; m * n];
            gemm_ref(*m, *k, *n, a, b, &mut want);
            let bp = PackedB::pack(*k, *n, b);
            let mut got = vec![0.0f32; m * n];
            gemm_packed(*m, a, &bp, &mut got);
            let diff = want
                .iter()
                .zip(&got)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            if diff < 1e-3 {
                Ok(())
            } else {
                Err(format!("packed ({m},{k},{n}) diff {diff}"))
            }
        },
    );
}

/// Every SIMD kernel this host can run matches the scalar micro-kernel
/// (itself pinned against `gemm_ref` above) at shapes that are **not**
/// multiples of MR=4 / NR=16 — the edge-tile paths where a vector kernel
/// most plausibly diverges.  `available_isas` reports hardware capability
/// regardless of `LM_FORCE_SCALAR`, so the CI scalar-pinned run still
/// exercises the vector kernels here.
#[test]
fn every_available_isa_matches_scalar_at_ragged_shapes() {
    let mut r = Rng::new(0x15a0);
    for &m in &[1usize, 3, 17, 63] {
        for &n in &[1usize, 3, 17, 63] {
            for &k in &[1usize, 5, 128, 129] {
                let a: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
                let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
                let mut want = vec![0.0f32; m * n];
                gemm_ref(m, k, n, &a, &b, &mut want);
                let bp = PackedB::pack(k, n, &b);
                for isa in available_isas() {
                    let mut got = vec![0.0f32; m * n];
                    gemm_packed_epi_isa(isa, m, &a, &bp, &mut got, None);
                    let diff = want
                        .iter()
                        .zip(&got)
                        .map(|(x, y)| (x - y).abs())
                        .fold(0.0f32, f32::max);
                    assert!(diff < 1e-3, "{isa:?} ({m},{k},{n}) diff {diff}");
                }
            }
        }
    }
}

/// The int8 kernels across ISAs at the same ragged grid: the scalar int8
/// kernel must track the f32 reference within quantization tolerance, and
/// every vector int8 kernel must match the scalar int8 kernel *bitwise*
/// (integer accumulation is order-independent and the dequantization
/// expression is identical, so there is no reassociation slack to allow).
#[test]
fn int8_isas_agree_and_track_f32_at_ragged_shapes() {
    let mut r = Rng::new(0x18a8);
    for &m in &[1usize, 3, 17, 63] {
        for &n in &[1usize, 3, 17, 63] {
            for &k in &[1usize, 5, 128, 129] {
                let a: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
                let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
                let mut want = vec![0.0f32; m * n];
                gemm_ref(m, k, n, &a, &b, &mut want);
                let bp = PackedBI8::pack(k, n, &b);
                let mut scalar = vec![0.0f32; m * n];
                gemm_packed_epi_i8_isa(Isa::Scalar, m, &a, &bp, &mut scalar, None, None);
                let tol = 0.15 * (k as f32).sqrt() + 0.01;
                let qdiff = want
                    .iter()
                    .zip(&scalar)
                    .map(|(x, y)| (x - y).abs())
                    .fold(0.0f32, f32::max);
                assert!(qdiff < tol, "int8 scalar ({m},{k},{n}) diff {qdiff} > {tol}");
                for isa in available_isas() {
                    let mut got = vec![0.0f32; m * n];
                    gemm_packed_epi_i8_isa(isa, m, &a, &bp, &mut got, None, None);
                    let diff = scalar
                        .iter()
                        .zip(&got)
                        .map(|(x, y)| (x - y).abs())
                        .fold(0.0f32, f32::max);
                    assert!(diff < 1e-6, "int8 {isa:?} ({m},{k},{n}) diff {diff}");
                }
            }
        }
    }
}

#[test]
fn conv2d_valid_matches_oracle_over_random_configs() {
    check_res(
        "im2col conv == direct conv",
        20,
        |r| {
            let k = [1usize, 3, 5][r.below(3)];
            let s = 1 + r.below(3);
            let h = k + s * (1 + r.below(4));
            let w = k + s * (1 + r.below(4));
            let (b, ci, co) = (1 + r.below(2), 1 + r.below(5), 1 + r.below(5));
            let x = randt(r, &[b, h, w, ci]);
            let wt = randt(r, &[co, ci, k, k]);
            (x, wt, s)
        },
        |(x, w, s)| {
            let want = conv2d_valid_ref(x, w, *s);
            let got = conv2d_valid(x, w, *s);
            if got.dims != want.dims {
                return Err(format!("dims {:?} vs {:?}", got.dims, want.dims));
            }
            let diff = got.max_abs_diff(&want);
            if diff < 1e-3 {
                Ok(())
            } else {
                Err(format!("x {:?} w {:?} s {s}: diff {diff}", x.dims, w.dims))
            }
        },
    );
}

#[test]
fn merge_kernels_matches_oracle_over_random_spans() {
    check_res(
        "GEMM merge == naive merge (incl. depthwise, strided)",
        25,
        |r| {
            let k1 = [1usize, 3, 5][r.below(3)];
            let k2 = [1usize, 3][r.below(2)];
            let s1 = 1 + r.below(2);
            let depthwise = r.below(3) == 0;
            let (w1, c) = if depthwise {
                // a depthwise inner layer expands to a diagonal dense
                // kernel before composing — the span_merge path
                let ch = 1 + r.below(6);
                (expand_depthwise(&randt(r, &[ch, 1, k1, k1])), ch)
            } else {
                let ci = 1 + r.below(4);
                let c = 1 + r.below(6);
                (randt(r, &[c, ci, k1, k1]), c)
            };
            let co = 1 + r.below(4);
            let w2 = randt(r, &[co, c, k2, k2]);
            (w1, w2, s1)
        },
        |(w1, w2, s1)| {
            let fast = merge_kernels(w1, w2, *s1);
            let slow = merge_kernels_ref(w1, w2, *s1);
            if fast.dims != slow.dims {
                return Err(format!("dims {:?} vs {:?}", fast.dims, slow.dims));
            }
            let diff = fast.max_abs_diff(&slow);
            if diff < 1e-3 {
                Ok(())
            } else {
                Err(format!("w1 {:?} w2 {:?} s {s1}: diff {diff}", w1.dims, w2.dims))
            }
        },
    );
}

/// End-to-end algebra property on the fast path only: convolving with the
/// GEMM-merged kernel equals the two-conv composition (both convs on the
/// im2col path), across strides — merged-network numerics don't depend on
/// which host path produced the kernel.
#[test]
fn merged_kernel_reproduces_composition_on_fast_path() {
    check_res(
        "conv(x, merge(w1,w2,s)) == conv(conv(x,w1,s), w2)",
        15,
        |r| {
            let k1 = [1usize, 3][r.below(2)];
            let k2 = [1usize, 3][r.below(2)];
            let s1 = 1 + r.below(2);
            let (ci, c, co) = (1 + r.below(3), 1 + r.below(4), 1 + r.below(3));
            let km = (k2 - 1) * s1 + k1;
            let h = km + s1 * (1 + r.below(3));
            let x = randt(r, &[1 + r.below(2), h, h, ci]);
            let w1 = randt(r, &[c, ci, k1, k1]);
            let w2 = randt(r, &[co, c, k2, k2]);
            (x, w1, w2, s1)
        },
        |(x, w1, w2, s1)| {
            let composed = conv2d_valid(&conv2d_valid(x, w1, *s1), w2, 1);
            let wm = merge_kernels(w1, w2, *s1);
            let merged = conv2d_valid(x, &wm, *s1);
            let diff = composed.max_abs_diff(&merged);
            if diff < 1e-3 {
                Ok(())
            } else {
                Err(format!("x {:?} s {s1}: diff {diff}", x.dims))
            }
        },
    );
}
