//! Shared helpers for integration tests.  Tests that need the AOT
//! artifacts skip (with a notice) when `make artifacts` has not run —
//! `make test` always builds them first.

// each test binary compiles its own copy and uses a different subset
#![allow(dead_code)]

use std::path::PathBuf;
use std::sync::Arc;

use layermerge::model::Manifest;
use layermerge::runtime::Runtime;
use layermerge::serve::Engine;

pub struct TestCtx {
    pub rt: Arc<Runtime>,
    pub man: Manifest,
    pub root: PathBuf,
}

impl TestCtx {
    /// Owning deployment handle over the test artifacts (shares the
    /// runtime; reloads the manifest, which isn't `Clone`).
    pub fn engine(&self) -> Engine {
        let man = Manifest::load(&self.root).expect("manifest");
        Engine::new(Arc::clone(&self.rt), Arc::new(man))
    }
}

pub fn ctx() -> Option<TestCtx> {
    let root = PathBuf::from("artifacts");
    if !root.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    let rt = Arc::new(Runtime::new(&root).expect("pjrt cpu client"));
    let man = Manifest::load(&root).expect("manifest");
    Some(TestCtx { rt, man, root })
}

pub fn rand_tensor(
    rng: &mut layermerge::util::rng::Rng,
    dims: &[usize],
) -> layermerge::util::tensor::Tensor {
    let n: usize = dims.iter().product();
    layermerge::util::tensor::Tensor::new(
        dims.to_vec(),
        (0..n).map(|_| rng.normal()).collect(),
    )
}
