//! Zero-allocation / zero-spawn steady-state suite (host backend, no
//! artifacts, no XLA):
//!
//! * the register-blocked micro-kernel matches `gemm_ref` at shapes that
//!   are **not** multiples of MR/NR (the panel/tile edge paths);
//! * a lowered host forward stops allocating after the first call — the
//!   arena miss counter is flat from forward 2 on while hits keep
//!   climbing;
//! * 100 steady-state forwards spawn zero threads — the compute pool's
//!   monotonic spawn counter does not move;
//! * batch-parallel attention equals per-batch serial composition;
//! * a warmed serving worker serves every request allocation-free;
//! * the int8 weight format reaches the same zero-allocation steady
//!   state (its per-row activation quantization scratch comes from the
//!   arena) and its forward tracks the f32 forward within quantization
//!   tolerance end to end.

use std::sync::Arc;

use layermerge::exec::{CompiledPlan, Format, Plan};
use layermerge::ir::synth;
use layermerge::kernels::{self, gemm_packed, gemm_ref, PackedB};
use layermerge::runtime::{Backend, HostBackend, WeightFormat};
use layermerge::serve::{ServeCfg, Session};
use layermerge::util::par;
use layermerge::util::rng::Rng;
use layermerge::util::tensor::Tensor;

fn randt(r: &mut Rng, dims: &[usize]) -> Tensor {
    let n: usize = dims.iter().product();
    Tensor::new(dims.to_vec(), (0..n).map(|_| r.normal()).collect())
}

#[test]
fn micro_kernel_parity_at_ragged_shapes() {
    // none of these m/n are multiples of GEMM_MR=4 / GEMM_NR=16 except
    // the identities; k crosses the old KC=128 cache-block boundary
    let mut r = Rng::new(0x5ead);
    for &m in &[1usize, 3, 17, 63] {
        for &n in &[1usize, 3, 17, 63] {
            for &k in &[1usize, 5, 128, 129] {
                let a: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
                let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
                let mut want = vec![0.0f32; m * n];
                gemm_ref(m, k, n, &a, &b, &mut want);
                let bp = PackedB::pack(k, n, &b);
                let mut got = vec![0.0f32; m * n];
                gemm_packed(m, &a, &bp, &mut got);
                let diff = want
                    .iter()
                    .zip(&got)
                    .map(|(x, y)| (x - y).abs())
                    .fold(0.0f32, f32::max);
                assert!(diff < 1e-3, "({m},{k},{n}) diff {diff}");
            }
        }
    }
}

fn lowered_chain(name: &str, fmt: Format) -> (Arc<HostBackend>, CompiledPlan, Tensor) {
    lowered_chain_wf(name, fmt, WeightFormat::F32)
}

/// [`lowered_chain`] with an explicit weight format — the int8 suite
/// lowers the same spec through `HostBackend::with_format`.  The input is
/// seeded identically regardless of format, so two chains over the same
/// spec see the same activations.
fn lowered_chain_wf(
    name: &str,
    fmt: Format,
    wf: WeightFormat,
) -> (Arc<HostBackend>, CompiledPlan, Tensor) {
    let (spec, params) = synth::by_name(name).unwrap();
    let plan = Arc::new(Plan::original(&spec, &params).unwrap());
    let be = Arc::new(HostBackend::with_format(wf));
    let bedyn: Arc<dyn Backend> = be.clone();
    let cp = CompiledPlan::lower(plan, bedyn, fmt).unwrap();
    let mut rng = Rng::new(0xa11c);
    let x = randt(&mut rng, &[spec.batch, spec.h, spec.w, spec.c]);
    (be, cp, x)
}

#[test]
fn steady_state_forward_is_allocation_free() {
    for fmt in [Format::Eager, Format::Fused] {
        let (be, cp, x) = lowered_chain("hostchain-tiny", fmt);
        let first = cp.forward(&x, None).unwrap();
        let arena = be.arena();
        assert!(arena.misses() > 0, "{fmt:?}: first forward must charge the arena");
        let (h0, m0) = (arena.hits(), arena.misses());
        for _ in 0..5 {
            let out = cp.forward(&x, None).unwrap();
            assert_eq!(out.dims, first.dims);
            assert!(out.max_abs_diff(&first) < 1e-6, "steady forwards must agree");
        }
        assert_eq!(
            arena.misses(),
            m0,
            "{fmt:?}: steady-state forwards (2nd on) must perform zero buffer allocations"
        );
        assert!(
            arena.hits() > h0,
            "{fmt:?}: steady-state forwards must be served from the arena"
        );
    }
}

/// The int8 path must reach the same steady state as f32: the dynamic
/// per-row activation quantization buffers come from the arena, so from
/// forward 2 on the miss counter is flat — zero allocations per forward.
#[test]
fn int8_steady_state_forward_is_allocation_free() {
    for fmt in [Format::Eager, Format::Fused] {
        let (be, cp, x) = lowered_chain_wf("hostchain-tiny", fmt, WeightFormat::Int8);
        assert_eq!(cp.weight_format(), WeightFormat::Int8);
        let first = cp.forward(&x, None).unwrap();
        let arena = be.arena();
        assert!(arena.misses() > 0, "{fmt:?}: first int8 forward must charge the arena");
        let (h0, m0) = (arena.hits(), arena.misses());
        for _ in 0..5 {
            let out = cp.forward(&x, None).unwrap();
            assert_eq!(out.dims, first.dims);
            assert!(out.max_abs_diff(&first) < 1e-6, "steady int8 forwards must agree");
        }
        assert_eq!(
            arena.misses(),
            m0,
            "{fmt:?}: steady-state int8 forwards must perform zero buffer allocations"
        );
        assert!(
            arena.hits() > h0,
            "{fmt:?}: steady-state int8 forwards must be served from the arena"
        );
    }
}

/// End-to-end accuracy gate for the int8 weight format: lowering hostnet
/// with int8 dense-conv weights must track the f32 forward within
/// quantization tolerance — per-channel weight scales plus dynamic
/// per-row activation scales keep the deployed network's outputs close,
/// not just each GEMM's.
#[test]
fn int8_forward_tracks_f32_forward_on_hostnet() {
    let (_bef, cpf, x) = lowered_chain_wf("hostnet", Format::Fused, WeightFormat::F32);
    let (_bei, cpi, _) = lowered_chain_wf("hostnet", Format::Fused, WeightFormat::Int8);
    assert_eq!(cpf.weight_format(), WeightFormat::F32);
    assert_eq!(cpi.weight_format(), WeightFormat::Int8);
    let want = cpf.forward(&x, None).unwrap();
    let got = cpi.forward(&x, None).unwrap();
    assert_eq!(want.dims, got.dims);
    let scale = want.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    assert!(scale > 0.0, "f32 forward produced all zeros — gate is vacuous");
    let diff = want.max_abs_diff(&got);
    let tol = 0.05 * scale + 0.05;
    assert!(
        diff < tol,
        "int8 forward deviates from f32 by {diff} (tolerance {tol}, output scale {scale})"
    );
}

#[test]
fn steady_state_forward_spawns_no_threads() {
    // hostchain (not -tiny): its conv GEMMs are big enough to dispatch on
    // the compute pool, so the warm forward provably initializes it
    let (_be, cp, x) = lowered_chain("hostchain", Format::Fused);
    cp.forward(&x, None).unwrap();
    let spawned = par::pool_spawns();
    let threads = par::pool_threads();
    for _ in 0..100 {
        cp.forward(&x, None).unwrap();
    }
    assert_eq!(
        par::pool_spawns(),
        spawned,
        "steady-state forwards must not spawn threads"
    );
    assert_eq!(par::pool_threads(), threads, "pool size must stay stable");
}

#[test]
fn parallel_attention_matches_per_batch_composition() {
    let mut r = Rng::new(0xa77e);
    let (bn, h, w, c) = (4usize, 5usize, 5usize, 6usize);
    let x = randt(&mut r, &[bn, h, w, c]);
    let wqkv = randt(&mut r, &[c, 3 * c]);
    let wout = randt(&mut r, &[c, c]);
    let arena = layermerge::util::arena::Arena::new();
    let batched = kernels::attention(&x, &wqkv, &wout, Some(&arena));
    let plain = kernels::attention(&x, &wqkv, &wout, None);
    assert!(batched.max_abs_diff(&plain) < 1e-6, "arena path must not change numerics");
    // attention is per-sample: the batched result equals each batch
    // element pushed through alone
    let plane = h * w * c;
    for n in 0..bn {
        let xn = Tensor::new(vec![1, h, w, c], x.data[n * plane..(n + 1) * plane].to_vec());
        let yn = kernels::attention(&xn, &wqkv, &wout, None);
        let got = &batched.data[n * plane..(n + 1) * plane];
        let diff = yn
            .data
            .iter()
            .zip(got)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(diff < 1e-4, "batch {n} deviates: {diff}");
    }
}

#[test]
fn warmed_serving_worker_is_allocation_free() {
    let (spec, params) = synth::by_name("hostchain-tiny").unwrap();
    let plan = Arc::new(Plan::original(&spec, &params).unwrap());
    let be = Arc::new(HostBackend::new());
    let bedyn: Arc<dyn Backend> = be.clone();
    let cp = CompiledPlan::lower(plan, bedyn, Format::Fused).unwrap();
    let cfg = ServeCfg { workers: 1, queue_cap: 16, warmup: true, ..ServeCfg::default() };
    let sess = Session::new(Arc::new(cp), cfg).unwrap();
    let mut rng = Rng::new(0x3357);
    let full = randt(&mut rng, &[spec.batch, spec.h, spec.w, spec.c]);
    // request 1: the single worker has finished its warmup forward by the
    // time it serves this (warmup runs before the queue loop), so the
    // arena shard is already charged
    sess.submit(full.clone()).unwrap().wait().unwrap();
    let m0 = be.arena().misses();
    for _ in 0..5 {
        sess.submit(full.clone()).unwrap().wait().unwrap();
    }
    assert_eq!(
        be.arena().misses(),
        m0,
        "a warmed serving worker must serve steady-state requests allocation-free"
    );
    sess.shutdown();
}
