//! Host-backend parity + transfer-residency suite.  Runs with **no**
//! artifacts and no XLA — this is the test bed that makes the paper's
//! latency machinery exercisable from a fresh offline checkout.
//!
//! * every op variant the lowering can emit (plain / fa_* / far_* convs
//!   incl. stride>1 and depthwise, group norm, upsample, attention, head)
//!   is pinned against a naive scalar oracle;
//! * a lowered chain-topology plan performs exactly 1 upload + 1
//!   download per steady-state forward (the device-residency property,
//!   counter-asserted);
//! * Fused == Eager on original and greedy-merged synthetic plans;
//! * an original-plan forward matches a layer-by-layer scalar reference
//!   end to end;
//! * the serving Session coalesces correctly on the host backend.

mod common;

use std::sync::Arc;

use common::rand_tensor as randt;
use layermerge::exec::{Format, Plan};
use layermerge::ir::synth;
use layermerge::kernels::Act;
use layermerge::merge::expand_depthwise;
use layermerge::runtime::{Backend, HostBackend, OpDesc, Value};
use layermerge::serve::{Engine, ServeCfg};
use layermerge::solver::depth::greedy_full_solution;
use layermerge::util::rng::Rng;
use layermerge::util::tensor::Tensor;

// ---------------------------------------------------------------------------
// Naive scalar oracles (deliberately independent of crate::kernels)
// ---------------------------------------------------------------------------

/// SAME conv + bias (+ residual) (+ act), XLA padding convention.
fn conv_same_ref(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    stride: usize,
    depthwise: bool,
    act: Option<Act>,
    res: Option<&Tensor>,
) -> Tensor {
    let wd = if depthwise { expand_depthwise(w) } else { w.clone() };
    let (bn, h, wdt, ci) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (co, _, k) = (wd.dims[0], wd.dims[1], wd.dims[2]);
    let ho = h.div_ceil(stride);
    let wo = wdt.div_ceil(stride);
    let plo_h = (((ho - 1) * stride + k).saturating_sub(h)) / 2;
    let plo_w = (((wo - 1) * stride + k).saturating_sub(wdt)) / 2;
    let mut y = Tensor::zeros(&[bn, ho, wo, co]);
    for n in 0..bn {
        for p in 0..ho {
            for q in 0..wo {
                for o in 0..co {
                    let mut acc = bias[o];
                    for c in 0..ci {
                        for a in 0..k {
                            for b2 in 0..k {
                                let iy = p * stride + a;
                                let ix = q * stride + b2;
                                if iy >= plo_h
                                    && ix >= plo_w
                                    && iy - plo_h < h
                                    && ix - plo_w < wdt
                                {
                                    acc += x.at4(n, iy - plo_h, ix - plo_w, c)
                                        * wd.at4(o, c, a, b2);
                                }
                            }
                        }
                    }
                    if let Some(r) = res {
                        acc += r.at4(n, p, q, o);
                    }
                    y.set4(
                        n,
                        p,
                        q,
                        o,
                        match act {
                            Some(Act::Relu) => acc.max(0.0),
                            Some(Act::Swish) => acc / (1.0 + (-acc).exp()),
                            None => acc,
                        },
                    );
                }
            }
        }
    }
    y
}

fn group_norm_ref(x: &Tensor, scale: &[f32], bias: &[f32], groups: usize) -> Tensor {
    let (bn, h, w, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let cg = c / groups;
    let mut y = Tensor::zeros(&[bn, h, w, c]);
    for n in 0..bn {
        for g in 0..groups {
            let mut vals = Vec::new();
            for p in 0..h * w {
                for ci in g * cg..(g + 1) * cg {
                    vals.push(x.data[(n * h * w + p) * c + ci]);
                }
            }
            let m: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
            let var: f32 =
                vals.iter().map(|v| (v - m) * (v - m)).sum::<f32>() / vals.len() as f32;
            let inv = 1.0 / (var + 1e-5).sqrt();
            for p in 0..h * w {
                for ci in g * cg..(g + 1) * cg {
                    let idx = (n * h * w + p) * c + ci;
                    y.data[idx] = (x.data[idx] - m) * inv * scale[ci] + bias[ci];
                }
            }
        }
    }
    y
}

fn attention_ref(x: &Tensor, wqkv: &Tensor, wout: &Tensor) -> Tensor {
    let (bn, h, w, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let s = h * w;
    let mut y = x.clone();
    for n in 0..bn {
        let proj = |i: usize, o: usize| -> f32 {
            (0..c).map(|ci| x.data[(n * s + i) * c + ci] * wqkv.data[ci * 3 * c + o]).sum()
        };
        let mut att = vec![0.0f32; s * s];
        for i in 0..s {
            for j in 0..s {
                let dot: f32 = (0..c).map(|ci| proj(i, ci) * proj(j, c + ci)).sum();
                att[i * s + j] = dot / (c as f32).sqrt();
            }
        }
        for row in att.chunks_mut(s) {
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for v in row.iter_mut() {
                *v = (*v - mx).exp();
                sum += *v;
            }
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
        for i in 0..s {
            for oc in 0..c {
                let mut acc = 0.0f32;
                for ci in 0..c {
                    let o1: f32 =
                        (0..s).map(|j| att[i * s + j] * proj(j, 2 * c + ci)).sum();
                    acc += o1 * wout.data[ci * c + oc];
                }
                y.data[(n * s + i) * c + oc] += acc;
            }
        }
    }
    y
}

fn head_ref(x: &Tensor, w: &Tensor, b: &[f32]) -> Tensor {
    let (bn, h, wd, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let classes = w.dims[1];
    let mut y = Tensor::zeros(&[bn, classes]);
    for n in 0..bn {
        let mut pooled = vec![0.0f32; c];
        for p in 0..h * wd {
            for (ci, pv) in pooled.iter_mut().enumerate() {
                *pv += x.data[(n * h * wd + p) * c + ci];
            }
        }
        for pv in pooled.iter_mut() {
            *pv /= (h * wd) as f32;
        }
        for o in 0..classes {
            y.data[n * classes + o] =
                b[o] + (0..c).map(|ci| pooled[ci] * w.data[ci * classes + o]).sum::<f32>();
        }
    }
    y
}

fn run_host(be: &HostBackend, desc: OpDesc, args: &[&Tensor]) -> Tensor {
    let vals: Vec<Value> = args.iter().map(|t| be.upload(t).unwrap()).collect();
    let refs: Vec<&Value> = vals.iter().collect();
    let op = be.lower_op(&desc).unwrap();
    be.download(&be.run(&op, &refs).unwrap()).unwrap()
}

// ---------------------------------------------------------------------------
// Op parity
// ---------------------------------------------------------------------------

#[test]
fn conv_variants_match_oracle() {
    let be = HostBackend::new();
    let mut rng = Rng::new(0xc0);
    // (b, h, cin, cout, k, stride, depthwise)
    let shapes = [
        (2usize, 8usize, 3usize, 5usize, 3usize, 1usize, false),
        (1, 8, 4, 6, 3, 2, false),
        (1, 7, 2, 3, 5, 2, false),
        (2, 6, 4, 4, 1, 1, false),
        (1, 8, 6, 6, 3, 1, true),
        (1, 8, 4, 4, 3, 2, true),
    ];
    for (b, h, cin, cout, k, stride, dw) in shapes {
        for act in [None, Some(Act::Relu), Some(Act::Swish)] {
            for residual in [false, true] {
                let x = randt(&mut rng, &[b, h, h, cin]);
                let w = randt(&mut rng, &[cout, if dw { 1 } else { cin }, k, k]);
                let bias: Vec<f32> = (0..cout).map(|_| rng.normal()).collect();
                let bt = Tensor::new(vec![cout], bias.clone());
                let (ho, wo) = (h.div_ceil(stride), h.div_ceil(stride));
                let r = randt(&mut rng, &[b, ho, wo, cout]);
                let desc = OpDesc::Conv {
                    b,
                    h,
                    w: h,
                    cin,
                    cout,
                    k,
                    stride,
                    depthwise: dw,
                    act,
                    residual,
                };
                let mut args: Vec<&Tensor> = vec![&x, &w, &bt];
                if residual {
                    args.push(&r);
                }
                let got = run_host(&be, desc, &args);
                let want =
                    conv_same_ref(&x, &w, &bias, stride, dw, act, residual.then_some(&r));
                assert_eq!(got.dims, want.dims);
                assert!(
                    got.max_abs_diff(&want) < 1e-3,
                    "conv b{b} h{h} i{cin} o{cout} k{k} s{stride} dw{dw} act {act:?} \
                     res {residual}: diff {}",
                    got.max_abs_diff(&want)
                );
            }
        }
    }
}

#[test]
fn elementwise_ops_match_oracle() {
    let be = HostBackend::new();
    let mut rng = Rng::new(0xe1);
    let (b, h, c) = (2usize, 4usize, 8usize);
    let x = randt(&mut rng, &[b, h, h, c]);

    // group norm
    let scale: Vec<f32> = (0..c).map(|_| 1.0 + 0.1 * rng.normal()).collect();
    let bias: Vec<f32> = (0..c).map(|_| rng.normal() * 0.2).collect();
    let st = Tensor::new(vec![c], scale.clone());
    let bt = Tensor::new(vec![c], bias.clone());
    let got = run_host(&be, OpDesc::GroupNorm { b, h, w: h, c, groups: 4 }, &[&x, &st, &bt]);
    let want = group_norm_ref(&x, &scale, &bias, 4);
    assert!(got.max_abs_diff(&want) < 1e-3, "gn diff {}", got.max_abs_diff(&want));

    // add
    let y2 = randt(&mut rng, &[b, h, h, c]);
    let got = run_host(&be, OpDesc::Add { b, h, w: h, c }, &[&x, &y2]);
    for (i, v) in got.data.iter().enumerate() {
        assert!((v - (x.data[i] + y2.data[i])).abs() < 1e-6);
    }

    // activations
    for act in [Act::Relu, Act::Swish] {
        let got = run_host(&be, OpDesc::Activation { act, b, h, w: h, c }, &[&x]);
        for (i, v) in got.data.iter().enumerate() {
            assert!((v - act.apply(x.data[i])).abs() < 1e-6);
        }
    }

    // upsample
    let got = run_host(&be, OpDesc::Upsample { b, h, w: h, c }, &[&x]);
    assert_eq!(got.dims, vec![b, 2 * h, 2 * h, c]);
    for n in 0..b {
        for p in 0..2 * h {
            for q in 0..2 * h {
                for ci in 0..c {
                    assert_eq!(got.at4(n, p, q, ci), x.at4(n, p / 2, q / 2, ci));
                }
            }
        }
    }
}

#[test]
fn attention_and_head_match_oracle() {
    let be = HostBackend::new();
    let mut rng = Rng::new(0xa7);
    let (b, h, c) = (1usize, 3usize, 4usize);
    let x = randt(&mut rng, &[b, h, h, c]);
    let wqkv = randt(&mut rng, &[c, 3 * c]);
    let wout = randt(&mut rng, &[c, c]);
    let got = run_host(&be, OpDesc::Attention { b, h, w: h, c }, &[&x, &wqkv, &wout]);
    let want = attention_ref(&x, &wqkv, &wout);
    assert!(got.max_abs_diff(&want) < 1e-3, "attn diff {}", got.max_abs_diff(&want));

    let (hb, hh, hidden, classes) = (2usize, 4usize, 6usize, 10usize);
    let xh = randt(&mut rng, &[hb, hh, hh, hidden]);
    let w = randt(&mut rng, &[hidden, classes]);
    let bias: Vec<f32> = (0..classes).map(|_| rng.normal()).collect();
    let bt = Tensor::new(vec![classes], bias.clone());
    let got = run_host(
        &be,
        OpDesc::Head { b: hb, h: hh, w: hh, hidden, classes, model: "x".into() },
        &[&xh, &w, &bt],
    );
    let want = head_ref(&xh, &w, &bias);
    assert_eq!(got.dims, vec![hb, classes]);
    assert!(got.max_abs_diff(&want) < 1e-3, "head diff {}", got.max_abs_diff(&want));
}

// ---------------------------------------------------------------------------
// Lowered plans end to end
// ---------------------------------------------------------------------------

/// Layer-by-layer scalar reference for a chain classifier spec.
fn chain_ref_forward(spec: &layermerge::ir::Spec, flat: &[f32], x: &Tensor) -> Tensor {
    let mut cur = x.clone();
    for l in 1..=spec.len() {
        let c = spec.conv(l);
        let w = Tensor::new(
            spec.param(&format!("conv{l}.w")).shape.clone(),
            spec.param_slice(flat, &format!("conv{l}.w")).to_vec(),
        );
        let b = spec.param_slice(flat, &format!("conv{l}.b"));
        let act = if l < spec.len() { Act::parse(&c.act) } else { None };
        cur = conv_same_ref(&cur, &w, b, c.stride, c.depthwise, act, None);
    }
    let hw = Tensor::new(
        spec.param("head.w").shape.clone(),
        spec.param_slice(flat, "head.w").to_vec(),
    );
    head_ref(&cur, &hw, spec.param_slice(flat, "head.b"))
}

#[test]
fn chain_plan_matches_layerwise_reference() {
    let (spec, params) = synth::by_name("hostchain-tiny").unwrap();
    let engine = Engine::host();
    let plan = Arc::new(Plan::original(&spec, &params).unwrap());
    let mut rng = Rng::new(7);
    let x = randt(&mut rng, &[spec.batch, spec.h, spec.w, spec.c]);
    let want = chain_ref_forward(&spec, &params, &x);
    for fmt in [Format::Eager, Format::Fused] {
        let got = engine.lower(&plan, fmt).unwrap().forward(&x, None).unwrap();
        assert_eq!(got.dims, want.dims);
        assert!(
            got.rel_l2(&want) < 1e-4,
            "{fmt:?} vs reference: rel_l2 {}",
            got.rel_l2(&want)
        );
    }
}

#[test]
fn fused_equals_eager_on_original_and_merged_plans() {
    let (spec, params) = synth::by_name("hostnet-tiny").unwrap();
    let engine = Engine::host();
    let mut rng = Rng::new(8);
    let x = randt(&mut rng, &[spec.batch, spec.h, spec.w, spec.c]);
    let orig = Arc::new(Plan::original(&spec, &params).unwrap());
    let (a, c, spans) = greedy_full_solution(&spec);
    let merged = Arc::new(Plan::from_solution(&spec, &params, &a, &c, &spans).unwrap());
    assert!(merged.depth() < orig.depth(), "greedy cover must reduce depth");
    for plan in [&orig, &merged] {
        let eager = engine.lower(plan, Format::Eager).unwrap().forward(&x, None).unwrap();
        let fused = engine.lower(plan, Format::Fused).unwrap().forward(&x, None).unwrap();
        assert!(
            fused.rel_l2(&eager) < 1e-5,
            "fused != eager (depth {}): rel_l2 {}",
            plan.depth(),
            fused.rel_l2(&eager)
        );
    }
}

#[test]
fn chain_forward_is_one_upload_one_download() {
    let (spec, params) = synth::by_name("hostchain-tiny").unwrap();
    let engine = Engine::host();
    let plan = Arc::new(Plan::original(&spec, &params).unwrap());
    let mut rng = Rng::new(9);
    let x = randt(&mut rng, &[spec.batch, spec.h, spec.w, spec.c]);
    for fmt in [Format::Eager, Format::Fused] {
        let cp = engine.lower(&plan, fmt).unwrap();
        let be = cp.backend();
        for _ in 0..3 {
            let (u0, d0) = (be.uploads(), be.downloads());
            cp.forward(&x, None).unwrap();
            assert_eq!(
                (be.uploads() - u0, be.downloads() - d0),
                (1, 1),
                "{fmt:?}: steady-state chain forward must be exactly one \
                 upload (input) + one download (output)"
            );
        }
    }
}

#[test]
fn residual_plan_stays_resident_too() {
    // boundary slots and projections are backend values — residuals must
    // not add transfers (the eager add runs as a backend op)
    let (spec, params) = synth::by_name("hostnet-tiny").unwrap();
    let engine = Engine::host();
    let plan = Arc::new(Plan::original(&spec, &params).unwrap());
    let mut rng = Rng::new(10);
    let x = randt(&mut rng, &[spec.batch, spec.h, spec.w, spec.c]);
    let cp = engine.lower(&plan, Format::Eager).unwrap();
    let be = cp.backend();
    let (u0, d0) = (be.uploads(), be.downloads());
    cp.forward(&x, None).unwrap();
    assert_eq!((be.uploads() - u0, be.downloads() - d0), (1, 1));
}

#[test]
fn per_dispatch_backend_round_trips_every_step() {
    let (spec, params) = synth::by_name("hostchain-tiny").unwrap();
    let engine = Engine::with_backend(Arc::new(HostBackend::per_dispatch()));
    let plan = Arc::new(Plan::original(&spec, &params).unwrap());
    let mut rng = Rng::new(11);
    let x = randt(&mut rng, &[spec.batch, spec.h, spec.w, spec.c]);
    let cp = engine.lower(&plan, Format::Fused).unwrap();
    let be = cp.backend();
    let (u0, d0) = (be.uploads(), be.downloads());
    cp.forward(&x, None).unwrap();
    let (du, dd) = (be.uploads() - u0, be.downloads() - d0);
    // every step round-trips >= 3 operands in and 1 out, plus the head
    let steps = plan.depth() + 1;
    assert!(
        du >= steps && dd >= 3 * steps,
        "per-dispatch transfers too low: {du} uploads / {dd} downloads for {steps} ops"
    );
}

#[test]
fn measure_runs_end_to_end_without_xla() {
    let (spec, params) = synth::by_name("hostnet-tiny").unwrap();
    let engine = Engine::host();
    let plan = Arc::new(Plan::original(&spec, &params).unwrap());
    let stats = engine.measure(&plan, Format::Fused, 1, 5).unwrap();
    assert_eq!(stats.iters, 5);
    assert!(stats.p50_ms > 0.0 && stats.p95_ms >= stats.p50_ms);
}

// ---------------------------------------------------------------------------
// Serving on the host backend
// ---------------------------------------------------------------------------

#[test]
fn serve_session_coalesces_on_host_backend() {
    let (spec, params) = synth::by_name("hostnet-tiny").unwrap();
    let engine = Engine::host();
    let plan = Arc::new(Plan::original(&spec, &params).unwrap());
    let cp = engine.lower(&plan, Format::Fused).unwrap();
    let mut rng = Rng::new(12);
    let rows: Vec<Tensor> = (0..4)
        .map(|_| randt(&mut rng, &[1, spec.h, spec.w, spec.c]))
        .collect();
    // expected: each row computed alone in a zero-padded full batch
    // (every per-row op is batch-independent, so position is irrelevant)
    let expected: Vec<Tensor> = rows
        .iter()
        .map(|r| {
            let mut xb = Tensor::zeros(&[spec.batch, spec.h, spec.w, spec.c]);
            xb.data[..r.data.len()].copy_from_slice(&r.data);
            let full = cp.forward(&xb, None).unwrap();
            let classes = full.dims[1];
            Tensor::new(vec![1, classes], full.data[..classes].to_vec())
        })
        .collect();
    let scfg = ServeCfg { workers: 2, queue_cap: 16, ..ServeCfg::default() };
    let sess = engine.deploy_cfg(Arc::clone(&plan), Format::Fused, scfg).unwrap();
    let tickets: Vec<_> =
        rows.iter().map(|r| sess.submit(r.clone()).unwrap()).collect();
    for (t, want) in tickets.into_iter().zip(&expected) {
        let got = t.wait().unwrap();
        assert_eq!(got.dims, want.dims);
        assert!(
            got.max_abs_diff(want) < 1e-6,
            "served row deviates: {}",
            got.max_abs_diff(want)
        );
    }
    let stats = sess.stats();
    assert_eq!(stats.rows, 4);
    sess.shutdown();
}
