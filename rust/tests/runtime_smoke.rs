//! Integration: the PJRT runtime loads and executes AOT artifacts with
//! correct numerics — conv modules vs a host oracle, Pallas flavor vs XLA
//! flavor, and the elementwise module family.

mod common;

use common::{ctx, rand_tensor};
use layermerge::model::sig_str;
use layermerge::util::rng::Rng;
use layermerge::util::tensor::Tensor;

/// Host SAME conv oracle (NHWC x OIHW), stride 1.
fn host_conv_same(x: &Tensor, w: &Tensor, bias: &[f32]) -> Tensor {
    let (b, h, wd, ci) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (co, _ci, k) = (w.dims[0], w.dims[1], w.dims[2]);
    let p = k / 2;
    let mut y = Tensor::zeros(&[b, h, wd, co]);
    for n in 0..b {
        for i in 0..h {
            for j in 0..wd {
                for o in 0..co {
                    let mut acc = bias[o];
                    for c in 0..ci {
                        for a in 0..k {
                            for bb in 0..k {
                                let ii = i + a;
                                let jj = j + bb;
                                if ii >= p && jj >= p && ii - p < h && jj - p < wd {
                                    acc += x.at4(n, ii - p, jj - p, c) * w.at4(o, c, a, bb);
                                }
                            }
                        }
                    }
                    y.set4(n, i, j, o, acc);
                }
            }
        }
    }
    y
}

#[test]
fn conv_module_matches_host_oracle() {
    let Some(t) = ctx() else { return };
    // resnetish stem signature: b32 h32 w32 i3 o16 k3 s1
    let sig = sig_str(32, 32, 32, 3, 16, 3, 1, false);
    let rel = t.man.conv_art(&sig, "plain").expect("stem conv artifact");
    let exec = t.rt.load(&rel).unwrap();
    let mut rng = Rng::new(11);
    let x = rand_tensor(&mut rng, &[32, 32, 32, 3]);
    let w = rand_tensor(&mut rng, &[16, 3, 3, 3]);
    let b = rand_tensor(&mut rng, &[16]);
    let got = exec.run(&[&x, &w, &b]).unwrap().remove(0);
    let want = host_conv_same(&x, &w, &b.data);
    assert!(got.rel_l2(&want) < 1e-4, "rel_l2 {}", got.rel_l2(&want));
}

#[test]
fn pallas_flavor_matches_xla_flavor() {
    let Some(t) = ctx() else { return };
    let mut rng = Rng::new(12);
    let mut checked = 0;
    for sig in t.man.conv_sigs() {
        let Some(prel) = t.man.conv_art(&sig, "pallas") else { continue };
        let xrel = t.man.conv_art(&sig, "plain").unwrap();
        let pe = t.rt.load(&prel).unwrap();
        let xe = t.rt.load(&xrel).unwrap();
        // parse dims back out of the signature string
        let parse = |tag: &str, next: &str| -> usize {
            let s = &sig[sig.find(tag).unwrap() + tag.len()..];
            let end = s.find(next).unwrap();
            s[..end].parse().unwrap()
        };
        let (b, h, w) = (parse("b", "h"), parse("h", "w"), parse("w", "i"));
        let (ci, co) = (parse("i", "o"), parse("o", "k"));
        let k = parse("k", "s");
        let dw = sig.ends_with("dw");
        let x = rand_tensor(&mut rng, &[b, h, w, ci]);
        let wt = rand_tensor(&mut rng, &[co, if dw { 1 } else { ci }, k, k]);
        let bias = rand_tensor(&mut rng, &[co]);
        let py = pe.run(&[&x, &wt, &bias]).unwrap().remove(0);
        let xy = xe.run(&[&x, &wt, &bias]).unwrap().remove(0);
        assert!(
            py.rel_l2(&xy) < 1e-4,
            "pallas vs xla mismatch on {sig}: rel_l2 {}",
            py.rel_l2(&xy)
        );
        checked += 1;
    }
    assert!(checked >= 4, "expected several pallas test signatures, got {checked}");
    eprintln!("pallas-vs-xla checked {checked} signatures");
}

#[test]
fn fused_variant_equals_plain_plus_act() {
    let Some(t) = ctx() else { return };
    let sig = sig_str(32, 32, 32, 16, 16, 3, 1, false);
    let plain = t.rt.load(&t.man.conv_art(&sig, "plain").unwrap()).unwrap();
    let fused = t.rt.load(&t.man.conv_art(&sig, "fa_relu").unwrap()).unwrap();
    let mut rng = Rng::new(13);
    let x = rand_tensor(&mut rng, &[32, 32, 32, 16]);
    let w = rand_tensor(&mut rng, &[16, 16, 3, 3]);
    let b = rand_tensor(&mut rng, &[16]);
    let mut y = plain.run(&[&x, &w, &b]).unwrap().remove(0);
    for v in &mut y.data {
        *v = v.max(0.0);
    }
    let yf = fused.run(&[&x, &w, &b]).unwrap().remove(0);
    assert!(yf.rel_l2(&y) < 1e-5);
}

#[test]
fn residual_variant_adds_input() {
    let Some(t) = ctx() else { return };
    let sig = sig_str(32, 32, 32, 16, 16, 3, 1, false);
    let plain = t.rt.load(&t.man.conv_art(&sig, "plain").unwrap()).unwrap();
    let farv = t.rt.load(&t.man.conv_art(&sig, "far_none").unwrap()).unwrap();
    let mut rng = Rng::new(14);
    let x = rand_tensor(&mut rng, &[32, 32, 32, 16]);
    let w = rand_tensor(&mut rng, &[16, 16, 3, 3]);
    let b = rand_tensor(&mut rng, &[16]);
    let r = rand_tensor(&mut rng, &[32, 32, 32, 16]);
    let mut y = plain.run(&[&x, &w, &b]).unwrap().remove(0);
    for (a, bb) in y.data.iter_mut().zip(&r.data) {
        *a += *bb;
    }
    let yf = farv.run(&[&x, &w, &b, &r]).unwrap().remove(0);
    assert!(yf.rel_l2(&y) < 1e-5);
}

#[test]
fn executable_cache_hits() {
    let Some(t) = ctx() else { return };
    let sig = sig_str(32, 32, 32, 3, 16, 3, 1, false);
    let rel = t.man.conv_art(&sig, "plain").unwrap();
    let before = *t.rt.compile_count.lock().unwrap();
    let _a = t.rt.load(&rel).unwrap();
    let _b = t.rt.load(&rel).unwrap();
    let after = *t.rt.compile_count.lock().unwrap();
    assert!(after <= before + 1, "cache miss on repeated load");
}
