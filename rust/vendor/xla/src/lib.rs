//! Build-time **stub** of the `xla-rs` PJRT bindings.
//!
//! The real crate links the XLA C++ runtime, which the offline image does
//! not ship.  This stub reproduces the exact API surface
//! `layermerge::runtime` consumes so the whole workspace builds and the
//! host-side test suite runs from a fresh checkout; every entry point
//! fails fast at `PjRtClient::cpu()` with a clear message.  Swap the
//! `xla` path dependency in `rust/Cargo.toml` for the real bindings (and
//! run `make artifacts`) to execute the AOT graphs for real — no source
//! change needed, the signatures match.

use std::fmt;

/// Error type mirroring `xla_rs::Error` closely enough for `{e:?}`
/// formatting and `?` conversion into `anyhow::Error`.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn stub() -> Error {
        Error {
            msg: "xla stub: the real XLA/PJRT runtime is not vendored in this \
                  build (see rust/vendor/xla/src/lib.rs)"
                .to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Clone)]
pub struct PjRtClient {
    _priv: (),
}

pub struct PjRtDevice {
    _priv: (),
}

pub struct PjRtLoadedExecutable {
    _priv: (),
}

pub struct PjRtBuffer {
    _priv: (),
}

pub struct Literal {
    _priv: (),
}

pub struct ArrayShape {
    dims: Vec<i64>,
}

pub struct HloModuleProto {
    _priv: (),
}

pub struct XlaComputation {
    _priv: (),
}

impl PjRtClient {
    /// Always fails in the stub — this is the single choke point: nothing
    /// downstream (compile/execute/transfer) is reachable without a client.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub())
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub())
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(Error::stub())
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub())
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub())
    }
}

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal { _priv: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::stub())
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error::stub())
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::stub())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub())
    }
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub())
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_fails_fast_with_clear_message() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("xla stub"));
    }
}
