//! Minimal in-tree substitute for the `anyhow` crate (DESIGN.md §2: the
//! offline vendor set ships no third-party registry, so the few external
//! APIs this repo leans on are reimplemented as path dependencies).
//!
//! Implements exactly the surface the repo uses: `Error`, `Result`,
//! `anyhow!`, `bail!`, `ensure!`, and the `Context` extension trait on
//! `Result` and `Option`.  Like the real crate, `Error` intentionally does
//! **not** implement `std::error::Error` — that is what lets the blanket
//! `From<E: std::error::Error>` conversion coexist with the reflexive
//! `From<Error>` used by `?`.

use std::error::Error as StdError;
use std::fmt;

/// A string-backed error with an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from any displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Prepend a context line (what `Context::context` delegates to).
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg), source: self.source }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut src = self.source.as_deref().and_then(StdError::source);
        while let Some(s) = src {
            write!(f, "\n  caused by: {s}")?;
            src = s.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Anything convertible into [`Error`] — implemented for std errors and
/// for `Error` itself so `Context` works on both (the real crate's
/// `ext::StdError` trick).
#[doc(hidden)]
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl<E: StdError + Send + Sync + 'static> IntoError for E {
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

/// `anyhow::Context` — attach context to errors and missing options.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($t:tt)*) => {
        $crate::Error::msg(format!($($t)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))
    }

    #[test]
    fn context_chains() {
        let e = io_err().context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest: disk on fire");
        let e2 = io_err()
            .with_context(|| format!("pass {}", 2))
            .unwrap_err()
            .context("outer");
        assert_eq!(format!("{e2}"), "outer: pass 2: disk on fire");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
        assert_eq!(Some(3u32).context("fine").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "wanted {}", "true");
            if !ok {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(format!("{}", f(false).unwrap_err()), "wanted true");
        let e: Error = anyhow!("x = {}", 5);
        assert_eq!(format!("{e}"), "x = 5");
    }

    #[test]
    fn ensure_bare() {
        fn f(x: u32) -> Result<()> {
            ensure!(x > 1);
            Ok(())
        }
        assert!(f(2).is_ok());
        assert!(format!("{}", f(0).unwrap_err()).contains("x > 1"));
    }

    #[test]
    fn question_mark_conversion() {
        fn f() -> Result<()> {
            io_err()?;
            Ok(())
        }
        assert!(f().is_err());
    }
}
