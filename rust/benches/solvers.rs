//! Bench: the paper's solver complexity claims (Sec. 3.4 — "the DP
//! algorithm is highly efficient, typically completing within a few
//! seconds on CPU").  Times Algorithm 1, the LayerOnly knapsack (Eq. 8),
//! and the predecessor's two-stage DP (`baselines::twostage`) on the
//! *same* instances at paper-scale (L = 17..34, P = 10 * T0 as in
//! App. C), then runs the offline `e2e_host` loop once and records the
//! predicted-vs-actual latency error of the measured tables.
//!
//! Extends `BENCH_merge.json` (schema `layermerge.bench.merge.v1`) with
//! the `solver *` rows and the `solver_*`/`twostage_*`/`e2e_*` derived
//! keys via the shared read-modify-write (`bench::record`).
//! `BENCH_SMOKE=1` runs one tiny instance and skips the JSON write.

use layermerge::baselines::twostage;
use layermerge::bench::{bench, smoke, stats_json};
use layermerge::pipeline::{self, PipelineCfg};
use layermerge::solver::dp::{self, DpInput, SpanArc};
use layermerge::solver::layeronly::{self, KnapsackInput};
use layermerge::tables::{BuildCfg, LatencyMode};
use layermerge::util::json::Json;
use layermerge::util::rng::Rng;

fn synthetic_arcs(l: usize, seg: usize, rng: &mut Rng) -> Vec<Vec<SpanArc>> {
    let mut arcs = vec![Vec::new(); l + 1];
    for j in 1..=l {
        let lo = j.saturating_sub(seg);
        for i in lo..j {
            for k in (1..=13).step_by(2) {
                if rng.uniform() < 0.6 {
                    arcs[j].push(SpanArc {
                        i,
                        k,
                        lat_ms: rng.range(0.05, 2.0) as f64,
                        imp: rng.uniform() * 2.0,
                    });
                }
            }
        }
    }
    arcs
}

fn main() -> anyhow::Result<()> {
    let mut rows: Vec<Json> = Vec::new();
    let mut derived: Vec<(String, Json)> = Vec::new();
    let mut rng = Rng::new(42);

    println!("== solver benches (paper Sec. 3.4 complexity) ==");
    let sizes: &[(usize, usize)] = if smoke() {
        &[(17, 1000)]
    } else {
        &[(17usize, 1000usize), (34, 1000), (34, 10000), (64, 10000)]
    };
    let budget_ms = if smoke() { 20.0 } else { 400.0 };
    // Alg. 1 vs the predecessor's two-stage DP on identical instances:
    // same objective (pinned by tests/baselines.rs), different solve time
    for &(l, p) in sizes {
        let arcs = synthetic_arcs(l, 8, &mut rng);
        let n_arcs: usize = arcs.iter().map(|a| a.len()).sum();
        let input = DpInput { l_max: l, budget_ms: 10.0, p, arcs };
        let s1 = bench(
            &format!("solver alg1_dp L={l} P={p} arcs={n_arcs}"),
            2,
            budget_ms,
            || {
                std::hint::black_box(dp::solve(&input));
            },
        );
        println!("{}", s1.row());
        let s2 = bench(
            &format!("solver twostage_dp L={l} P={p} arcs={n_arcs}"),
            2,
            budget_ms,
            || {
                std::hint::black_box(twostage::solve(&input));
            },
        );
        let front: usize = twostage::collapse(&input).iter().map(|a| a.len()).sum();
        println!(
            "{}  ({:.2}x vs alg1; fronts {front}/{n_arcs} arcs)",
            s2.row(),
            s1.p50_ms / s2.p50_ms
        );
        rows.push(stats_json(&s1));
        rows.push(stats_json(&s2));
        let o1 = dp::solve(&input).map(|s| s.objective).unwrap_or(0.0);
        let o2 = twostage::solve(&input).map(|s| s.objective).unwrap_or(0.0);
        if l == sizes.last().unwrap().0 {
            derived.push((
                "twostage_vs_dp_obj_ratio".into(),
                Json::num(if o1.abs() > 1e-12 { o2 / o1 } else { 1.0 }),
            ));
            derived.push((
                "twostage_vs_dp_solve_speedup".into(),
                Json::num(s1.p50_ms / s2.p50_ms.max(1e-9)),
            ));
        }
    }

    let knap_sizes: &[usize] = if smoke() { &[17] } else { &[17usize, 34, 64] };
    for &l in knap_sizes {
        let mut rng2 = Rng::new(7);
        let input = KnapsackInput {
            lat_ms: std::iter::once(0.0)
                .chain((0..l).map(|_| rng2.range(0.05, 1.0) as f64))
                .collect(),
            imp: std::iter::once(0.0)
                .chain((0..l).map(|_| rng2.uniform()))
                .collect(),
            forced: std::iter::once(false)
                .chain((0..l).map(|_| rng2.uniform() < 0.2))
                .collect(),
            budget_ms: 8.0,
            p: 10000,
        };
        let s = bench(&format!("solver layeronly_knapsack L={l} P=10000"), 2, budget_ms, || {
            std::hint::black_box(layeronly::solve(&input));
        });
        println!("{}", s.row());
        rows.push(stats_json(&s));
    }

    // the offline paper loop: measured host tables -> DP -> deploy ->
    // measure; record how well the table sum predicts the deployed plan
    println!("== e2e host loop (profile -> solve -> merge -> measure) ==");
    let cfg = PipelineCfg {
        build: BuildCfg {
            mode: LatencyMode::Measured,
            warmup: if smoke() { 1 } else { 3 },
            iters: if smoke() { 3 } else { 15 },
            force: true,
            ..BuildCfg::default()
        },
        lat_warmup: if smoke() { 1 } else { 3 },
        lat_iters: if smoke() { 3 } else { 15 },
        ..PipelineCfg::default()
    };
    let cache = std::env::temp_dir().join("lm_solvers_bench");
    let r = pipeline::e2e_host("hostchain-tiny", 0.6, &cfg, &cache)?;
    println!(
        "e2e hostchain-tiny: pred {:.4}ms actual {:.4}ms (err {:.1}%), \
         speedup pred {:.2}x actual {:.2}x, depth {} -> {}",
        r.pred_merged_ms,
        r.actual_merged_ms,
        r.rel_err() * 100.0,
        r.pred_speedup(),
        r.actual_speedup(),
        r.depth_before,
        r.depth_after
    );
    derived.push(("e2e_pred_vs_actual_err".into(), Json::num(r.rel_err())));
    derived.push(("e2e_actual_speedup".into(), Json::num(r.actual_speedup())));

    if smoke() {
        println!("(BENCH_SMOKE=1: skipping BENCH_merge.json write)");
        return Ok(());
    }

    // shared RMW: this bench owns the "solver *" rows and the
    // solver_*/twostage_*/e2e_* derived keys
    layermerge::bench::record(
        &["solver "],
        &["solver_", "twostage_", "e2e_"],
        rows,
        derived,
    )
}
