//! Bench: the paper's solver complexity claims (Sec. 3.4 — "the DP
//! algorithm is highly efficient, typically completing within a few
//! seconds on CPU").  Times Algorithm 1, the LayerOnly knapsack (Eq. 8)
//! and the \hat{C}_{ijk} selection (Eq. 3) at paper-scale instances
//! (L = 17..34, P = 10 * T0 as in App. C).

use layermerge::bench::bench;
use layermerge::solver::dp::{self, DpInput, SpanArc};
use layermerge::solver::layeronly::{self, KnapsackInput};
use layermerge::util::rng::Rng;

fn synthetic_arcs(l: usize, seg: usize, rng: &mut Rng) -> Vec<Vec<SpanArc>> {
    let mut arcs = vec![Vec::new(); l + 1];
    for j in 1..=l {
        let lo = j.saturating_sub(seg);
        for i in lo..j {
            for k in (1..=13).step_by(2) {
                if rng.uniform() < 0.6 {
                    arcs[j].push(SpanArc {
                        i,
                        k,
                        lat_ms: rng.range(0.05, 2.0) as f64,
                        imp: rng.uniform() * 2.0,
                    });
                }
            }
        }
    }
    arcs
}

fn main() {
    println!("== solver benches (paper Sec. 3.4 complexity) ==");
    let mut rng = Rng::new(42);
    for (l, p) in [(17usize, 1000usize), (34, 1000), (34, 10000), (64, 10000)] {
        let arcs = synthetic_arcs(l, 8, &mut rng);
        let n_arcs: usize = arcs.iter().map(|a| a.len()).sum();
        let input = DpInput { l_max: l, budget_ms: 10.0, p, arcs };
        let s = bench(
            &format!("alg1_dp L={l} P={p} arcs={n_arcs}"),
            2,
            400.0,
            || {
                let sol = dp::solve(&input);
                std::hint::black_box(&sol);
            },
        );
        println!("{}", s.row());
    }

    for l in [17usize, 34, 64] {
        let mut rng2 = Rng::new(7);
        let input = KnapsackInput {
            lat_ms: std::iter::once(0.0)
                .chain((0..l).map(|_| rng2.range(0.05, 1.0) as f64))
                .collect(),
            imp: std::iter::once(0.0)
                .chain((0..l).map(|_| rng2.uniform()))
                .collect(),
            forced: std::iter::once(false)
                .chain((0..l).map(|_| rng2.uniform() < 0.2))
                .collect(),
            budget_ms: 8.0,
            p: 10000,
        };
        let s = bench(&format!("layeronly_knapsack L={l} P=10000"), 2, 300.0, || {
            std::hint::black_box(layeronly::solve(&input));
        });
        println!("{}", s.row());
    }
    println!("done");
}
