//! Bench: micro-batched worker-pool serving (`layermerge::serve`) —
//! closed-loop throughput at 1/4/16 concurrent clients, plus the
//! batch-forming policy comparison (`serving_window`): greedy vs window
//! vs adaptive under deterministic open-loop Poisson arrivals at three
//! rates.  The window policies exist to cut tail padding at light and
//! moderate load; the record shows padded-rows-per-batch and p95 (via the
//! corrected nearest-rank percentile) side by side so the tradeoff is a
//! number, not a guess.
//!
//! Extends `BENCH_merge.json` (schema `layermerge.bench.merge.v1`) with
//! `serving`, `serving_window`, `serving_net`, and `serving_fleet`
//! records (`serving_net` drives the TCP tier over loopback at
//! 0.5x/1x/2x capacity and records goodput, shed rate, and
//! p99-of-admitted; `serving_fleet` records the multi-tenant fleet's
//! shared-weight dedup bytes and the deadline router's goodput against
//! an always-biggest-plan baseline): read-modify-write so the
//! merge/forward rows written by `cargo bench --bench merge_ops` are
//! preserved, per the ROADMAP rule that perf records are extended, never
//! replaced.  `BENCH_SMOKE=1` runs tiny request counts and skips the
//! JSON write (the CI compile-and-run gate).
//!
//! The host-mock sessions exercise the real queue machinery (bounded
//! queue, policy-driven coalescing, padding, ticket split) against
//! backends with a fixed per-dispatch overhead plus per-row compute —
//! the cost shape that makes micro-batching pay.  With `make artifacts`
//! + real XLA bindings, a trailing section drives a deployed `resnetish`
//! plan the same way.

use std::sync::Arc;
use std::time::Duration;

use layermerge::bench::smoke;
use layermerge::serve::net::{drive_net, NetCfg, NetServer};
use layermerge::serve::{self, BatchPolicy, Engine, LoadReport, ServeCfg, Session};
use layermerge::util::json::Json;
use layermerge::util::tensor::Tensor;

const MOCK_BATCH: usize = 8;
const MOCK_TAIL: [usize; 1] = [64];

/// Deterministic compute ballast (black-boxed so it isn't optimized out).
fn spin(units: usize) -> f32 {
    let mut acc = 0.0f32;
    for i in 0..units {
        acc += std::hint::black_box((i as f32) * 1e-3).sin();
    }
    acc
}

/// Mock "device": ~fixed dispatch overhead + per-row work; row r of the
/// output depends only on row r of the input.
fn mock_backend(x: &Tensor, _t: Option<&Tensor>) -> anyhow::Result<Tensor> {
    std::hint::black_box(spin(120_000)); // per-dispatch overhead
    let rl: usize = x.dims[1..].iter().product();
    let b = x.dims[0];
    let mut out = Tensor::zeros(&[b, 2]);
    for r in 0..b {
        std::hint::black_box(spin(8_000)); // per-row work
        let row = &x.data[r * rl..(r + 1) * rl];
        out.data[r * 2] = row.iter().sum();
        out.data[r * 2 + 1] = row.iter().map(|v| v * v).sum();
    }
    Ok(out)
}

/// Sleep-based mock for the window-policy comparison: the timing is the
/// subject under test, so the cost model must be stable across machines
/// — a fixed dispatch overhead plus per-row service (padding rows cost
/// the same as real ones, exactly like a device computing them).
fn timed_backend(x: &Tensor, _t: Option<&Tensor>) -> anyhow::Result<Tensor> {
    std::thread::sleep(Duration::from_micros(500 + 50 * x.dims[0] as u64));
    let rl: usize = x.dims[1..].iter().product();
    let b = x.dims[0];
    let mut out = Tensor::zeros(&[b, 2]);
    for r in 0..b {
        let row = &x.data[r * rl..(r + 1) * rl];
        out.data[r * 2] = row.iter().sum();
        out.data[r * 2 + 1] = row.iter().map(|v| v * v).sum();
    }
    Ok(out)
}

const NET_DISPATCH_US: u64 = 2_000;
const NET_PER_ROW_US: u64 = 250;

/// Sleep-based mock for the TCP-tier bench: slow enough that loopback
/// round-trips are cheap relative to service time, so measured shedding
/// comes from the admission controller, not the client harness.
fn net_backend(x: &Tensor, _t: Option<&Tensor>) -> anyhow::Result<Tensor> {
    std::thread::sleep(Duration::from_micros(
        NET_DISPATCH_US + NET_PER_ROW_US * x.dims[0] as u64,
    ));
    let rl: usize = x.dims[1..].iter().product();
    let b = x.dims[0];
    let mut out = Tensor::zeros(&[b, 2]);
    for r in 0..b {
        let row = &x.data[r * rl..(r + 1) * rl];
        out.data[r * 2] = row.iter().sum();
        out.data[r * 2 + 1] = row.iter().map(|v| v * v).sum();
    }
    Ok(out)
}

fn report_json(name: &str, r: &LoadReport) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("iters", Json::num(r.requests as f64)),
        ("mean_ms", Json::num(r.mean_ms)),
        ("p50_ms", Json::num(r.p50_ms)),
        ("p95_ms", Json::num(r.p95_ms)),
        ("min_ms", Json::num(r.min_ms)),
    ])
}

fn drive_levels(
    sess: &Session,
    tag: &str,
    levels: &[usize],
    requests: usize,
    rows: &mut Vec<Json>,
    derived: &mut Vec<(String, Json)>,
) -> anyhow::Result<Vec<LoadReport>> {
    let mut reports = Vec::new();
    for &clients in levels {
        let r = serve::drive(sess, clients, requests, |c, i| {
            let rl: usize = MOCK_TAIL.iter().product();
            let seed = (c * 7919 + i) as f32;
            (
                Tensor::new(
                    vec![1, MOCK_TAIL[0]],
                    (0..rl).map(|k| seed + k as f32 * 0.125).collect(),
                ),
                None,
            )
        })?;
        println!("{}", r.row(&format!("{tag} clients={clients}")));
        rows.push(report_json(&format!("{tag} clients={clients}"), &r));
        derived.push((
            format!("serving_rows_per_s_c{clients}"),
            Json::num(r.rows_per_s),
        ));
        reports.push(r);
    }
    Ok(reports)
}

/// The `serving_window` record: greedy vs window vs adaptive batch
/// forming under open-loop Poisson arrivals at several rates.
fn window_policy_bench(
    rows: &mut Vec<Json>,
    derived: &mut Vec<(String, Json)>,
) -> anyhow::Result<()> {
    const WINDOW_US: u64 = 3_000;
    let rates: &[f64] = if smoke() { &[2_000.0] } else { &[500.0, 2_000.0, 6_000.0] };
    let requests = if smoke() { 24 } else { 160 };
    let policies: [(&str, BatchPolicy); 3] = [
        ("greedy", BatchPolicy::Greedy),
        ("window", BatchPolicy::Window { max_wait_us: WINDOW_US }),
        (
            "adaptive",
            BatchPolicy::Adaptive { target_occupancy: 0.75, max_wait_us: WINDOW_US },
        ),
    ];
    println!("== serving window-policy benches (open-loop arrivals, host mock) ==");
    for (ri, &rps) in rates.iter().enumerate() {
        let mut padded: Vec<(&str, f64)> = Vec::new();
        for (pol_name, policy) in policies {
            let cfg = ServeCfg { workers: 2, queue_cap: 512, policy, ..ServeCfg::default() };
            let sess = Session::from_fn(MOCK_BATCH, &MOCK_TAIL, false, cfg, timed_backend);
            let r = serve::drive_open(&sess, rps, requests, 0xbea7 + ri as u64, |_, i| {
                let rl: usize = MOCK_TAIL.iter().product();
                (
                    Tensor::new(
                        vec![1, MOCK_TAIL[0]],
                        (0..rl).map(|k| (i + k) as f32 * 0.25).collect(),
                    ),
                    None,
                )
            })?;
            let name = format!("serve window {pol_name} rps={rps:.0}");
            println!("{}", r.row(&name));
            rows.push(report_json(&name, &r));
            let tag = format!("{pol_name}_rps{rps:.0}");
            derived.push((
                format!("serving_window_padded_per_batch_{tag}"),
                Json::num(r.padded_per_batch()),
            ));
            derived.push((
                format!("serving_window_occupancy_{tag}"),
                Json::num(r.occupancy),
            ));
            derived.push((format!("serving_window_p95_ms_{tag}"), Json::num(r.p95_ms)));
            if pol_name == "window" {
                // the configured bound the p95 must respect: the window
                // itself plus dispatch time (generous 4x for scheduling)
                let bound_ms = WINDOW_US as f64 / 1e3 + 4.0 * r.service_ms.max(0.1);
                derived.push((
                    format!("serving_window_p95_bound_ms_rps{rps:.0}"),
                    Json::num(bound_ms),
                ));
                derived.push((
                    format!("serving_window_p95_within_bound_rps{rps:.0}"),
                    Json::num(if r.p95_ms <= bound_ms { 1.0 } else { 0.0 }),
                ));
            }
            padded.push((pol_name, r.padded_per_batch()));
            sess.shutdown();
        }
        let greedy_ppb = padded[0].1;
        let best_windowed =
            padded[1..].iter().map(|&(_, p)| p).fold(f64::INFINITY, f64::min);
        derived.push((
            format!("serving_window_padding_win_rps{rps:.0}"),
            Json::num(greedy_ppb - best_windowed),
        ));
        println!(
            "  rps={rps:.0}: padded/batch greedy {greedy_ppb:.2} vs best windowed \
             {best_windowed:.2} ({})",
            if best_windowed < greedy_ppb { "window policy wins" } else { "no win" }
        );
    }
    Ok(())
}

/// The `serving_net` record: the TCP tier under open-loop Poisson load
/// over loopback at 0.5x/1x/2x of analytic capacity, with per-request
/// deadlines equal to the session SLO.  Goodput, shed rate, and
/// p99-of-admitted per rate; at 2x overload the p99 of *admitted*
/// requests must stay within the SLO bound — the admission controller
/// sheds the rest at the door instead of letting the queue grow.
fn net_tier_bench(
    rows: &mut Vec<Json>,
    derived: &mut Vec<(String, Json)>,
) -> anyhow::Result<()> {
    const SLO_MS: u64 = 25;
    // analytic capacity for 1-row requests: workers x batch rows per
    // full-batch service time
    let batch_us = (NET_DISPATCH_US + NET_PER_ROW_US * MOCK_BATCH as u64) as f64;
    let capacity_rps = 2.0 * MOCK_BATCH as f64 * 1e6 / batch_us;
    let levels: &[(&str, f64)] =
        if smoke() { &[("x2", 2.0)] } else { &[("x05", 0.5), ("x1", 1.0), ("x2", 2.0)] };
    let requests = if smoke() { 32 } else { 600 };
    let cfg = ServeCfg {
        workers: 2,
        queue_cap: 256,
        policy: BatchPolicy::Greedy,
        slo: Some(Duration::from_millis(SLO_MS)),
        ..ServeCfg::default()
    };
    let sess = Arc::new(Session::from_fn(MOCK_BATCH, &MOCK_TAIL, false, cfg, net_backend));
    // a handler thread owns its connection for the connection's lifetime,
    // so the pool must be at least as wide as the driver's connections
    let net_cfg = NetCfg { conn_workers: 8, ..NetCfg::default() };
    let server = match NetServer::bind(Arc::clone(&sess), "127.0.0.1:0", net_cfg) {
        Ok(s) => s,
        Err(e) => {
            // no loopback in this sandbox — the record is simply absent
            println!("(skipping serving_net bench: {e})");
            return Ok(());
        }
    };
    let addr = server.addr();
    println!("== serving net benches (TCP tier on {addr}, host mock) ==");
    let finite = |v: f64| Json::num(if v.is_finite() { v } else { -1.0 });
    for (si, &(tag, mult)) in levels.iter().enumerate() {
        let rps = capacity_rps * mult;
        let r = drive_net(
            addr,
            rps,
            requests,
            6,
            Some(Duration::from_millis(SLO_MS)),
            0x5e71e7 + si as u64,
            |i| {
                let rl: usize = MOCK_TAIL.iter().product();
                (
                    Tensor::new(
                        vec![1, MOCK_TAIL[0]],
                        (0..rl).map(|k| (i + k) as f32 * 0.5).collect(),
                    ),
                    None,
                )
            },
        )?;
        let name = format!("serve net {tag} rps={rps:.0}");
        println!("{}", r.row(&name));
        rows.push(Json::obj(vec![
            ("name", Json::str(&name)),
            ("iters", Json::num(r.requests as f64)),
            ("goodput_rps", finite(r.goodput_rps)),
            ("shed_rate", Json::num(r.shed_rate())),
            ("p50_ms", finite(r.p50_ms)),
            ("p95_ms", finite(r.p95_ms)),
            ("p99_ms", finite(r.p99_ms)),
        ]));
        derived.push((format!("serving_net_goodput_rps_{tag}"), finite(r.goodput_rps)));
        derived.push((format!("serving_net_shed_rate_{tag}"), Json::num(r.shed_rate())));
        derived.push((format!("serving_net_p99_ms_{tag}"), finite(r.p99_ms)));
        if tag == "x2" {
            // bound for admitted requests: the SLO itself plus a few
            // full-batch service times of scheduling slack
            let bound_ms = SLO_MS as f64 + 6.0 * batch_us / 1e3;
            derived.push(("serving_net_p99_bound_ms_x2".into(), Json::num(bound_ms)));
            derived.push((
                "serving_net_p99_within_slo_x2".into(),
                Json::num(if r.p99_ms.is_finite() && r.p99_ms <= bound_ms {
                    1.0
                } else {
                    0.0
                }),
            ));
        }
    }
    let net = server.stats();
    println!(
        "  net tier: {} conns accepted, {} frames, {} bad frames, {} handler panics",
        net.accepted, net.frames, net.bad_frames, net.handler_panics
    );
    server.shutdown();
    if let Ok(s) = Arc::try_unwrap(sess) {
        s.shutdown();
    }
    Ok(())
}

const FLEET_CHEAP_DISPATCH_US: u64 = 800;
const FLEET_CHEAP_ROW_US: u64 = 25;
const FLEET_BIG_DISPATCH_US: u64 = 6_000;
const FLEET_BIG_ROW_US: u64 = 250;

/// A sleep-based fleet rung with a fixed cost profile (the ladder's
/// compressed/original pair is modelled as cheap vs expensive service).
fn fleet_rung(
    dispatch_us: u64,
    row_us: u64,
) -> impl Fn(&Tensor, Option<&Tensor>) -> anyhow::Result<Tensor> + Send + Sync + 'static {
    move |x: &Tensor, _t: Option<&Tensor>| {
        std::thread::sleep(Duration::from_micros(dispatch_us + row_us * x.dims[0] as u64));
        let rl: usize = x.dims[1..].iter().product();
        let b = x.dims[0];
        let mut out = Tensor::zeros(&[b, 2]);
        for r in 0..b {
            let row = &x.data[r * rl..(r + 1) * rl];
            out.data[r * 2] = row.iter().sum();
            out.data[r * 2 + 1] = row.iter().map(|v| v * v).sum();
        }
        Ok(out)
    }
}

/// Open-loop load pinned to one ladder rung via `submit_rung` — the
/// "always-biggest-plan" baseline the router's goodput is judged against.
fn drive_pinned(
    fleet: &layermerge::serve::fleet::Fleet,
    rung: usize,
    rps: f64,
    requests: usize,
    deadline: Duration,
    seed: u64,
) -> anyhow::Result<(usize, f64)> {
    let mut rng = layermerge::util::rng::Rng::new(seed);
    let mut pending = Vec::with_capacity(requests);
    let mut sched = 0.0f64;
    let t0 = std::time::Instant::now();
    for i in 0..requests {
        sched += -(1.0 - rng.uniform()).ln() / rps;
        let target = t0 + Duration::from_secs_f64(sched);
        if let Some(d) = target.checked_duration_since(std::time::Instant::now()) {
            std::thread::sleep(d);
        }
        let rl: usize = MOCK_TAIL.iter().product();
        let x = Tensor::new(
            vec![1, MOCK_TAIL[0]],
            (0..rl).map(|k| (i + k) as f32 * 0.5).collect(),
        );
        let arrival = std::time::Instant::now();
        if let Ok(tk) = fleet.submit_rung("t", rung, x, None, Some(arrival + deadline)) {
            pending.push(tk);
        }
    }
    let mut ok = 0usize;
    for tk in pending {
        if matches!(tk.wait_timeout_coded(Duration::from_secs(30)), Ok(Ok(_))) {
            ok += 1;
        }
    }
    Ok((ok, t0.elapsed().as_secs_f64()))
}

/// The `serving_fleet` record: (a) shared-weight dedup bytes when two
/// tenants deploy the same host-lowered budget ladder through one
/// [`WeightCache`]; (b) goodput of deadline-aware ladder routing vs the
/// always-biggest-plan baseline under identical open-loop load.
fn fleet_bench(
    rows: &mut Vec<Json>,
    derived: &mut Vec<(String, Json)>,
) -> anyhow::Result<()> {
    use layermerge::exec::{Format, Plan};
    use layermerge::serve::fleet::{drive_fleet, Fleet, FleetCfg, FleetLoad, TenantCfg};

    println!("== serving fleet benches (multi-tenant ladder) ==");
    // -- (a) dedup: two tenants share one base model's 2-rung ladder ------
    let engine = Engine::host();
    let (spec, params) = layermerge::ir::synth::by_name("hostnet-tiny")
        .ok_or_else(|| anyhow::anyhow!("hostnet-tiny synthetic spec missing"))?;
    let orig = Arc::new(Plan::original(&spec, &params)?);
    let (a, c, spans) = layermerge::solver::depth::greedy_full_solution(&spec);
    let merged = Arc::new(Plan::from_solution(&spec, &params, &a, &c, &spans)?);
    let fleet = Fleet::new(FleetCfg { workers: 2, ..FleetCfg::default() });
    for name in ["interactive", "batch"] {
        fleet.add_tenant(TenantCfg::new(name, 1, BatchPolicy::Greedy))?;
        fleet.deploy(name, &engine, &merged, Format::Fused, 200)?;
        fleet.deploy(name, &engine, &orig, Format::Fused, 800)?;
    }
    let fs = fleet.stats();
    println!(
        "  weight dedup: {} tenants x {} rungs, {} unique bytes, {} bytes deduped away",
        fs.tenants, fs.rungs / fs.tenants.max(1), fs.unique_weight_bytes, fs.dedup_saved_bytes
    );
    rows.push(Json::obj(vec![
        ("name", Json::str("fleet dedup hostnet-tiny")),
        ("iters", Json::num(fs.rungs as f64)),
        ("unique_weight_bytes", Json::num(fs.unique_weight_bytes as f64)),
        ("dedup_saved_bytes", Json::num(fs.dedup_saved_bytes as f64)),
    ]));
    derived.push((
        "fleet_dedup_saved_bytes".into(),
        Json::num(fs.dedup_saved_bytes as f64),
    ));
    derived.push((
        "fleet_unique_weight_bytes".into(),
        Json::num(fs.unique_weight_bytes as f64),
    ));
    fleet.shutdown();

    // -- (b) router goodput vs always-biggest baseline --------------------
    // cheap rung fits the deadline at this load; the big rung alone
    // cannot keep up, so pinning everything to it (what a ladder-less
    // deployment would do) starves goodput
    let requests = if smoke() { 24 } else { 300 };
    let deadline = Duration::from_millis(25);
    let cheap_batch_us =
        (FLEET_CHEAP_DISPATCH_US + FLEET_CHEAP_ROW_US * MOCK_BATCH as u64) as f64;
    let rps = 0.6 * 2.0 * MOCK_BATCH as f64 * 1e6 / cheap_batch_us;
    let make_fleet = || -> anyhow::Result<Fleet> {
        let f = Fleet::new(FleetCfg {
            workers: 2,
            queue_cap: 512,
            quantum_rows: 4,
            ..FleetCfg::default()
        });
        f.add_tenant(TenantCfg::new("t", 1, BatchPolicy::Greedy))?;
        f.deploy_fn(
            "t", MOCK_BATCH, &MOCK_TAIL, false, 1_000,
            fleet_rung(FLEET_CHEAP_DISPATCH_US, FLEET_CHEAP_ROW_US),
        )?;
        f.deploy_fn(
            "t", MOCK_BATCH, &MOCK_TAIL, false, 8_000,
            fleet_rung(FLEET_BIG_DISPATCH_US, FLEET_BIG_ROW_US),
        )?;
        Ok(f)
    };

    let routed = make_fleet()?;
    let reports = drive_fleet(
        &routed,
        &[FleetLoad {
            tenant: "t".into(),
            rps,
            requests,
            deadline: Some(deadline),
            seed: 0xf1ee7,
        }],
        |_, i| {
            let rl: usize = MOCK_TAIL.iter().product();
            (
                Tensor::new(
                    vec![1, MOCK_TAIL[0]],
                    (0..rl).map(|k| (i + k) as f32 * 0.5).collect(),
                ),
                None,
            )
        },
    )?;
    let r = &reports[0];
    let rs = routed.router_stats();
    println!("{}", r.row(&format!("fleet routed rps={rps:.0}")));
    println!(
        "  router: {} hits, {} fallbacks, {} sheds (hit-rate {:.2})",
        rs.hits, rs.fallbacks, rs.sheds, rs.hit_rate()
    );
    routed.shutdown();

    let pinned = make_fleet()?;
    let (base_ok, base_wall) =
        drive_pinned(&pinned, 1, rps, requests, deadline, 0xf1ee7)?;
    let base_goodput = base_ok as f64 / base_wall.max(1e-9);
    println!(
        "fleet always-biggest           {rps:.0} rps  ok {base_ok:>4}  goodput {base_goodput:>7.1}/s"
    );
    pinned.shutdown();

    let finite = |v: f64| Json::num(if v.is_finite() { v } else { -1.0 });
    rows.push(Json::obj(vec![
        ("name", Json::str(&format!("fleet routed rps={rps:.0}"))),
        ("iters", Json::num(r.requests as f64)),
        ("goodput_rps", finite(r.goodput_rps)),
        ("shed_rate", Json::num(r.shed_rate())),
        ("p50_ms", finite(r.p50_ms)),
        ("p99_ms", finite(r.p99_ms)),
        ("router_hit_rate", Json::num(rs.hit_rate())),
        ("baseline_goodput_rps", finite(base_goodput)),
    ]));
    derived.push(("fleet_router_goodput".into(), finite(r.goodput_rps)));
    derived.push(("fleet_baseline_goodput".into(), finite(base_goodput)));
    derived.push((
        "fleet_router_vs_biggest".into(),
        Json::num(r.goodput_rps / base_goodput.max(1e-9)),
    ));
    derived.push(("fleet_router_hit_rate".into(), Json::num(rs.hit_rate())));
    Ok(())
}

/// Chaos bench: closed-loop goodput under 5% injected backend faults
/// plus a flaky wire (dropped connections, truncated/corrupted frames,
/// stalls), with and without the retrying client, against a fault-free
/// baseline.  Records the acceptance headline: the retrying client's
/// goodput retention vs that baseline.  Seeds route through
/// `LM_CHAOS_SEED` so a run is reproducible.
fn chaos_bench(
    rows: &mut Vec<Json>,
    derived: &mut Vec<(String, Json)>,
) -> anyhow::Result<()> {
    use layermerge::serve::chaos::{self, FaultPlan, FaultProxy, FaultSpec, WireFaults};
    use layermerge::serve::net::{NetClient, RetryClient, RetryPolicy};

    // light sleep-based mock: fast enough that the bench stays cheap,
    // slow enough that service time dominates loopback round-trips
    fn light_backend(x: &Tensor, _t: Option<&Tensor>) -> anyhow::Result<Tensor> {
        std::thread::sleep(Duration::from_micros(300));
        let rl: usize = x.dims[1..].iter().product();
        let b = x.dims[0];
        let mut out = Tensor::zeros(&[b, 2]);
        for r in 0..b {
            let row = &x.data[r * rl..(r + 1) * rl];
            out.data[r * 2] = row.iter().sum();
            out.data[r * 2 + 1] = row.iter().map(|v| v * v).sum();
        }
        Ok(out)
    }
    let requests = if smoke() { 16 } else { 200 };
    let input = |i: usize| {
        Tensor::new(
            vec![1, MOCK_TAIL[0]],
            (0..MOCK_TAIL[0]).map(|k| (i + k) as f32 * 0.5).collect(),
        )
    };
    let serve_cfg = || ServeCfg {
        workers: 2,
        queue_cap: 256,
        policy: BatchPolicy::Greedy,
        ..ServeCfg::default()
    };
    let bind = |sess: Session| {
        NetServer::bind(Arc::new(sess), "127.0.0.1:0", NetCfg::default())
    };

    // arm 1: fault-free baseline, plain client
    let clean = match bind(Session::from_fn(
        MOCK_BATCH,
        &MOCK_TAIL,
        false,
        serve_cfg(),
        light_backend,
    )) {
        Ok(s) => s,
        Err(e) => {
            println!("(skipping chaos bench: {e})");
            return Ok(());
        }
    };
    println!("== chaos benches (5% backend faults + flaky wire, host mock) ==");
    let mut base_ok = 0usize;
    let base_start = std::time::Instant::now();
    {
        let mut c = NetClient::connect(clean.addr())?;
        for i in 0..requests {
            if matches!(c.infer_deadline(&input(i), None, None), Ok(Ok(_))) {
                base_ok += 1;
            }
        }
    }
    let base_rps = base_ok as f64 / base_start.elapsed().as_secs_f64().max(1e-9);
    clean.shutdown();

    // arms 2 and 3 share the faulty server + flaky wire profile
    let spec = FaultSpec::failing(0.05);
    let wire = WireFaults {
        drop_conn: 0.04,
        stall: 0.02,
        stall_ms: 5,
        truncate: 0.02,
        corrupt: 0.02,
    };
    let faulty = |seed: u64| {
        bind(Session::from_fn(
            MOCK_BATCH,
            &MOCK_TAIL,
            false,
            serve_cfg(),
            chaos::wrap_fn(FaultPlan::random(spec, chaos::env_seed(seed)), light_backend),
        ))
    };

    // arm 2: plain client (reconnecting on transport failure, no retry —
    // a wire fault costs the in-flight request)
    let server = faulty(0xbe4c01)?;
    let proxy = FaultProxy::bind(server.addr(), wire, chaos::env_seed(0xbe4c02))?;
    let mut plain_ok = 0usize;
    let plain_start = std::time::Instant::now();
    {
        let mut conn: Option<NetClient> = None;
        for i in 0..requests {
            if conn.is_none() {
                conn = NetClient::connect(proxy.addr()).ok();
            }
            let Some(c) = conn.as_mut() else { continue };
            match c.infer_deadline(&input(i), None, None) {
                Ok(Ok(_)) => plain_ok += 1,
                Ok(Err(_)) => {}
                Err(_) => conn = None, // dead wire: pay the reconnect
            }
        }
    }
    let plain_rps = plain_ok as f64 / plain_start.elapsed().as_secs_f64().max(1e-9);
    let wire_counts = proxy.counts();
    proxy.shutdown();
    server.shutdown();

    // arm 3: the retrying client over the same fault profile
    let server = faulty(0xbe4c01)?;
    let proxy = FaultProxy::bind(server.addr(), wire, chaos::env_seed(0xbe4c02))?;
    let mut rc = RetryClient::new(proxy.addr())
        .with_retry(RetryPolicy { attempts: 6, base_ms: 1, cap_ms: 20 })
        .with_seed(chaos::env_seed(0xbe4c03));
    let mut retry_ok = 0usize;
    let retry_start = std::time::Instant::now();
    for i in 0..requests {
        if matches!(rc.infer_deadline(&input(i), None, None), Ok(Ok(_))) {
            retry_ok += 1;
        }
    }
    let retry_rps = retry_ok as f64 / retry_start.elapsed().as_secs_f64().max(1e-9);
    let rstats = rc.retry_stats();
    proxy.shutdown();
    server.shutdown();

    let n = requests as f64;
    // retention = completed-request ratio vs the fault-free baseline; a
    // closed-loop rps ratio would charge the retrier for its own backoff
    // sleeps, which is latency, not lost goodput (rps is recorded too)
    let retention = retry_ok as f64 / (base_ok as f64).max(1.0);
    println!(
        "  baseline {base_ok}/{requests} ok ({base_rps:.0} rps) | plain-through-chaos \
         {plain_ok}/{requests} ({plain_rps:.0} rps) | retry-through-chaos \
         {retry_ok}/{requests} ({retry_rps:.0} rps, {} retries) | retention {retention:.2}",
        rstats.retries
    );
    println!(
        "  wire: {} conns, {} forwarded, {} dropped, {} stalled, {} truncated, {} corrupted",
        wire_counts.conns,
        wire_counts.forwarded,
        wire_counts.dropped,
        wire_counts.stalled,
        wire_counts.truncated,
        wire_counts.corrupted
    );
    for (name, ok, rps) in [
        ("chaos baseline", base_ok, base_rps),
        ("chaos faulty plain", plain_ok, plain_rps),
        ("chaos faulty retry", retry_ok, retry_rps),
    ] {
        rows.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("iters", Json::num(n)),
            ("ok", Json::num(ok as f64)),
            ("ok_frac", Json::num(ok as f64 / n.max(1.0))),
            ("goodput_rps", Json::num(rps)),
        ]));
    }
    derived.push(("chaos_goodput_baseline_rps".into(), Json::num(base_rps)));
    derived.push(("chaos_goodput_plain_rps".into(), Json::num(plain_rps)));
    derived.push(("chaos_goodput_retry_rps".into(), Json::num(retry_rps)));
    derived.push(("chaos_ok_frac_plain".into(), Json::num(plain_ok as f64 / n)));
    derived.push(("chaos_ok_frac_retry".into(), Json::num(retry_ok as f64 / n)));
    derived.push(("chaos_goodput_retention".into(), Json::num(retention)));
    derived.push((
        "chaos_retry_recovers".into(),
        Json::num(if retention >= 0.9 { 1.0 } else { 0.0 }),
    ));
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let mut rows: Vec<Json> = Vec::new();
    let mut derived: Vec<(String, Json)> = Vec::new();
    let levels: &[usize] = if smoke() { &[1, 4] } else { &[1, 4, 16] };
    let requests = if smoke() { 8 } else { 64 };

    println!("== serving benches (micro-batched Session, host mock) ==");
    let sess = Session::from_fn(
        MOCK_BATCH,
        &MOCK_TAIL,
        false,
        ServeCfg { workers: 2, queue_cap: 256, policy: BatchPolicy::Greedy, ..ServeCfg::default() },
        mock_backend,
    );
    let reports = drive_levels(&sess, "serve mock", levels, requests, &mut rows, &mut derived)?;
    let single = reports[0].rows_per_s;
    let best_multi = reports[1..]
        .iter()
        .map(|r| r.rows_per_s)
        .fold(f64::NEG_INFINITY, f64::max);
    derived.push((
        "serving_multi_vs_single".into(),
        Json::num(best_multi / single.max(1e-12)),
    ));
    let s = sess.stats();
    derived.push((
        "serving_coalesce_rows_per_batch".into(),
        Json::num(s.rows as f64 / (s.batches.max(1)) as f64),
    ));
    println!(
        "  multi-vs-single throughput {:.2}x, {:.2} rows/batch coalesced",
        best_multi / single.max(1e-12),
        s.rows as f64 / s.batches.max(1) as f64
    );
    sess.shutdown();

    window_policy_bench(&mut rows, &mut derived)?;
    net_tier_bench(&mut rows, &mut derived)?;
    fleet_bench(&mut rows, &mut derived)?;
    chaos_bench(&mut rows, &mut derived)?;

    // a deployed plan, when the artifacts + real XLA runtime are present
    let root = std::path::Path::new("artifacts");
    if root.join("manifest.json").exists() && !smoke() {
        match Engine::open(root) {
            Ok(engine) => {
                use layermerge::exec::{Format, Plan};
                println!("== serving benches (deployed resnetish plan) ==");
                let model = engine.load_model("resnetish")?;
                let plan = Arc::new(Plan::original(&model.spec, &model.init)?);
                let sess = engine.deploy_cfg(
                    plan,
                    Format::Fused,
                    ServeCfg {
                        workers: 2,
                        queue_cap: 256,
                        policy: BatchPolicy::Greedy,
                        ..ServeCfg::default()
                    },
                )?;
                let gen = layermerge::train::Gen::for_model(&model, 5);
                let pool = serve::classify_request_pool(&gen, 2);
                for &clients in levels {
                    let r = serve::drive(&sess, clients, requests.min(32), |c, i| {
                        (pool[(c * 31 + i) % pool.len()].0.clone(), None)
                    })?;
                    let name = format!("serve resnetish clients={clients}");
                    println!("{}", r.row(&name));
                    rows.push(report_json(&name, &r));
                    derived.push((
                        format!("serving_plan_rows_per_s_c{clients}"),
                        Json::num(r.rows_per_s),
                    ));
                }
                sess.shutdown();
            }
            Err(e) => println!("(skipping deployed-plan serving bench: {e})"),
        }
    } else if !smoke() {
        println!("(skipping deployed-plan serving bench: run `make artifacts` first)");
    }

    if smoke() {
        println!("(BENCH_SMOKE=1: skipping BENCH_merge.json write)");
        return Ok(());
    }

    // shared RMW: this bench owns the serve/fleet/chaos rows and the
    // serving_*/fleet_*/chaos_* derived keys
    layermerge::bench::record(
        &["serve ", "fleet ", "chaos "],
        &["serving_", "fleet_", "chaos_"],
        rows,
        derived,
    )
}
