//! Bench: micro-batched worker-pool serving (`layermerge::serve`) —
//! throughput at 1/4/16 concurrent closed-loop clients.
//!
//! Extends `BENCH_merge.json` (schema `layermerge.bench.merge.v1`) with a
//! `serving` record: read-modify-write so the merge/forward rows written
//! by `cargo bench --bench merge_ops` are preserved, per the ROADMAP rule
//! that perf records are extended, never replaced.
//!
//! The host-mock session exercises the real queue machinery (bounded
//! queue, coalescing, padding, ticket split) against a backend with a
//! fixed per-dispatch overhead plus per-row compute — the cost shape that
//! makes micro-batching pay: concurrent clients amortize the dispatch
//! overhead, so multi-client throughput must come out >= single-client.
//! With `make artifacts` + real XLA bindings, a second section drives a
//! deployed `resnetish` plan the same way.

use layermerge::serve::{self, Engine, LoadReport, ServeCfg, Session};
use layermerge::util::json::Json;
use layermerge::util::tensor::Tensor;

const MOCK_BATCH: usize = 8;
const MOCK_TAIL: [usize; 1] = [64];
const CLIENT_LEVELS: [usize; 3] = [1, 4, 16];
const REQUESTS: usize = 64;

/// Deterministic compute ballast (black-boxed so it isn't optimized out).
fn spin(units: usize) -> f32 {
    let mut acc = 0.0f32;
    for i in 0..units {
        acc += std::hint::black_box((i as f32) * 1e-3).sin();
    }
    acc
}

/// Mock "device": ~fixed dispatch overhead + per-row work; row r of the
/// output depends only on row r of the input.
fn mock_backend(x: &Tensor, _t: Option<&Tensor>) -> anyhow::Result<Tensor> {
    std::hint::black_box(spin(120_000)); // per-dispatch overhead
    let rl: usize = x.dims[1..].iter().product();
    let b = x.dims[0];
    let mut out = Tensor::zeros(&[b, 2]);
    for r in 0..b {
        std::hint::black_box(spin(8_000)); // per-row work
        let row = &x.data[r * rl..(r + 1) * rl];
        out.data[r * 2] = row.iter().sum();
        out.data[r * 2 + 1] = row.iter().map(|v| v * v).sum();
    }
    Ok(out)
}

fn report_json(name: &str, r: &LoadReport) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("iters", Json::num(r.requests as f64)),
        ("mean_ms", Json::num(r.mean_ms)),
        ("p50_ms", Json::num(r.p50_ms)),
        ("p95_ms", Json::num(r.p95_ms)),
        ("min_ms", Json::num(r.min_ms)),
    ])
}

fn drive_levels(
    sess: &Session,
    tag: &str,
    rows: &mut Vec<Json>,
    derived: &mut Vec<(String, Json)>,
) -> anyhow::Result<Vec<LoadReport>> {
    let mut reports = Vec::new();
    for clients in CLIENT_LEVELS {
        let r = serve::drive(sess, clients, REQUESTS, |c, i| {
            let rl: usize = MOCK_TAIL.iter().product();
            let seed = (c * 7919 + i) as f32;
            (
                Tensor::new(
                    vec![1, MOCK_TAIL[0]],
                    (0..rl).map(|k| seed + k as f32 * 0.125).collect(),
                ),
                None,
            )
        })?;
        println!("{}", r.row(&format!("{tag} clients={clients}")));
        rows.push(report_json(&format!("{tag} clients={clients}"), &r));
        derived.push((
            format!("serving_rows_per_s_c{clients}"),
            Json::num(r.rows_per_s),
        ));
        reports.push(r);
    }
    Ok(reports)
}

fn main() -> anyhow::Result<()> {
    let mut rows: Vec<Json> = Vec::new();
    let mut derived: Vec<(String, Json)> = Vec::new();

    println!("== serving benches (micro-batched Session, host mock) ==");
    let sess = Session::from_fn(
        MOCK_BATCH,
        &MOCK_TAIL,
        false,
        ServeCfg { workers: 2, queue_cap: 256 },
        mock_backend,
    );
    let reports = drive_levels(&sess, "serve mock", &mut rows, &mut derived)?;
    let single = reports[0].rows_per_s;
    let best_multi = reports[1..]
        .iter()
        .map(|r| r.rows_per_s)
        .fold(f64::NEG_INFINITY, f64::max);
    derived.push((
        "serving_multi_vs_single".into(),
        Json::num(best_multi / single.max(1e-12)),
    ));
    let s = sess.stats();
    derived.push((
        "serving_coalesce_rows_per_batch".into(),
        Json::num(s.rows as f64 / (s.batches.max(1)) as f64),
    ));
    println!(
        "  multi-vs-single throughput {:.2}x, {:.2} rows/batch coalesced",
        best_multi / single.max(1e-12),
        s.rows as f64 / s.batches.max(1) as f64
    );
    sess.shutdown();

    // a deployed plan, when the artifacts + real XLA runtime are present
    let root = std::path::Path::new("artifacts");
    if root.join("manifest.json").exists() {
        match Engine::open(root) {
            Ok(engine) => {
                use layermerge::exec::{Format, Plan};
                use std::sync::Arc;
                println!("== serving benches (deployed resnetish plan) ==");
                let model = engine.load_model("resnetish")?;
                let plan = Arc::new(Plan::original(&model.spec, &model.init)?);
                let sess = engine.deploy_cfg(
                    plan,
                    Format::Fused,
                    ServeCfg { workers: 2, queue_cap: 256 },
                )?;
                let gen = layermerge::train::Gen::for_model(&model, 5);
                let pool = serve::classify_request_pool(&gen, 2);
                for clients in CLIENT_LEVELS {
                    let r = serve::drive(&sess, clients, REQUESTS.min(32), |c, i| {
                        (pool[(c * 31 + i) % pool.len()].0.clone(), None)
                    })?;
                    let name = format!("serve resnetish clients={clients}");
                    println!("{}", r.row(&name));
                    rows.push(report_json(&name, &r));
                    derived.push((
                        format!("serving_plan_rows_per_s_c{clients}"),
                        Json::num(r.rows_per_s),
                    ));
                }
                sess.shutdown();
            }
            Err(e) => println!("(skipping deployed-plan serving bench: {e})"),
        }
    } else {
        println!("(skipping deployed-plan serving bench: run `make artifacts` first)");
    }

    // merge into BENCH_merge.json: keep every non-serving row and derived
    // key from previous bench runs, replace the serving ones
    let path = std::env::var("BENCH_OUT").unwrap_or_else(|_| {
        format!("{}/../BENCH_merge.json", env!("CARGO_MANIFEST_DIR"))
    });
    let (mut all_rows, mut all_derived): (Vec<Json>, Vec<(String, Json)>) =
        (Vec::new(), Vec::new());
    if let Ok(text) = std::fs::read_to_string(&path) {
        if let Ok(prev) = Json::parse(&text) {
            if let Some(prev_rows) = prev.get("rows").and_then(|r| r.as_arr()) {
                for r in prev_rows {
                    let name = r.get("name").and_then(|n| n.as_str()).unwrap_or("");
                    if !name.starts_with("serve ") {
                        all_rows.push(r.clone());
                    }
                }
            }
            if let Some(prev_d) = prev.get("derived").and_then(|d| d.as_obj()) {
                for (k, v) in prev_d {
                    if !k.starts_with("serving_") {
                        all_derived.push((k.clone(), v.clone()));
                    }
                }
            }
        }
    }
    all_rows.extend(rows);
    all_derived.extend(derived);
    let out = Json::obj(vec![
        ("schema", Json::str("layermerge.bench.merge.v1")),
        ("rows", Json::Arr(all_rows)),
        (
            "derived",
            Json::obj(
                all_derived
                    .iter()
                    .map(|(k, v)| (k.as_str(), v.clone()))
                    .collect(),
            ),
        ),
    ]);
    std::fs::write(&path, out.to_string())?;
    println!("wrote {path}");
    Ok(())
}
