//! Bench: end-to-end table regeneration in fast mode — times each phase
//! of the pipeline (pretrain reuse, table build, solve, fine-tune, deploy)
//! for the Table-1/2 workloads.  The full paper-fidelity tables are
//! produced by `layermerge table1..table11`; this target proves the
//! regeneration path and reports its cost.

use layermerge::experiments::Ctx;
use layermerge::pipeline::{Method, PipelineCfg};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let root = std::path::Path::new("artifacts");
    if !root.join("manifest.json").exists() {
        println!("(skipping paper_tables bench: run `make artifacts` first)");
        return Ok(());
    }
    std::env::set_var("LM_FAST", "1");
    let cfg = PipelineCfg::default();
    let ctx = Ctx::new(root, std::env::current_dir()?, cfg)?;
    println!("== paper-table pipeline phases (LM_FAST mode) ==");
    for model in ["resnetish", "mnv2ish-1.0"] {
        let t0 = Instant::now();
        let mut pipe = ctx.pipeline(model)?;
        let t_pre = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        pipe.ensure_tables()?;
        let t_tab = t1.elapsed().as_secs_f64();
        let t2 = Instant::now();
        let sol = pipe.solve(Method::LayerMerge, 0.65)?;
        let t_solve = t2.elapsed().as_secs_f64();
        let t3 = Instant::now();
        let c = pipe.finetune_and_deploy(Method::LayerMerge, 0.65, &sol, Some(5), false)?;
        let t_dep = t3.elapsed().as_secs_f64();
        println!(
            "{model:<14} pretrain+orig {t_pre:>7.2}s | tables {t_tab:>7.2}s | solve {t_solve:>7.4}s | finetune+deploy {t_dep:>7.2}s | depth {} -> {}",
            pipe.model.spec.len(),
            c.depth
        );
    }
    Ok(())
}
