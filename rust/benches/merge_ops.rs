//! Bench: parameter-space merging (Sec. 2's theta_2 * theta_1 operator and
//! the full span composition of Algorithm 2) — the deployment-time hot
//! path of the merge engine — plus the eager vs compiled-plan forward
//! comparison.
//!
//! Emits a machine-readable perf record (`BENCH_merge.json` at the repo
//! root, override with `BENCH_OUT`) in a stable schema so the trajectory
//! of the GEMM merge path and the zero-overhead execution plans can be
//! compared across PRs:
//!
//! ```json
//! { "schema": "layermerge.bench.merge.v1",
//!   "rows": [ {name, iters, mean_ms, p50_ms, p95_ms, min_ms}, ... ],
//!   "derived": { "merge_speedup_c256": ..., ... } }
//! ```

use std::collections::BTreeSet;
use std::sync::Arc;

use layermerge::bench::{bench, bench_iters, smoke, stats_json};
use layermerge::exec::{CompiledPlan, Format, Plan};
use layermerge::ir::synth;
use layermerge::kernels::{
    gemm, gemm_packed, gemm_packed_epi_i8, gemm_packed_epi_isa, Isa, PackedB, PackedBI8,
};
use layermerge::merge::{dirac, expand_depthwise, merge_kernels, merge_kernels_ref};
use layermerge::runtime::{Backend, HostBackend};
use layermerge::util::json::Json;
use layermerge::util::par;
use layermerge::util::rng::Rng;
use layermerge::util::tensor::Tensor;

fn randt(rng: &mut Rng, dims: &[usize]) -> Tensor {
    let n: usize = dims.iter().product();
    Tensor::new(dims.to_vec(), (0..n).map(|_| rng.normal()).collect())
}

fn main() -> anyhow::Result<()> {
    let mut rows: Vec<Json> = Vec::new();
    let mut derived: Vec<(String, Json)> = Vec::new();
    let mut rng = Rng::new(1);

    // BENCH_SMOKE=1: one tiny shape, minimal budgets, no JSON write —
    // the CI gate that keeps this bench compiling and running
    let shapes: &[(usize, usize, usize)] = if smoke() {
        &[(16, 3, 3)]
    } else {
        &[(16, 3, 3), (64, 3, 3), (64, 7, 3), (128, 11, 3)]
    };
    let (budget_ms, naive_iters) = if smoke() { (10.0, 1) } else { (300.0, 5) };

    println!("== merge-operator benches (flat-GEMM vs naive oracle) ==");
    for &(c, k1, k2) in shapes {
        let w1 = randt(&mut rng, &[c, c, k1, k1]);
        let w2 = randt(&mut rng, &[c, c, k2, k2]);
        let fast = bench(
            &format!("merge_kernels_gemm c={c} k1={k1} k2={k2}"),
            2,
            budget_ms,
            || {
                std::hint::black_box(merge_kernels(&w1, &w2, 1));
            },
        );
        println!("{}", fast.row());
        let slow = bench_iters(
            &format!("merge_kernels_naive c={c} k1={k1} k2={k2}"),
            1,
            naive_iters,
            || {
                std::hint::black_box(merge_kernels_ref(&w1, &w2, 1));
            },
        );
        println!("{}  ({:.1}x vs naive)", slow.row(), slow.p50_ms / fast.p50_ms);
        rows.push(stats_json(&fast));
        rows.push(stats_json(&slow));
    }

    // Acceptance target: ResNet-scale 256-channel span, k1=k2=3, s1=1.
    // (skipped in smoke: the naive oracle at 256 channels is seconds-slow)
    if !smoke() {
        let (c, k1, k2) = (256usize, 3usize, 3usize);
        let w1 = randt(&mut rng, &[c, c, k1, k1]);
        let w2 = randt(&mut rng, &[c, c, k2, k2]);
        // parity guard so the reported speedup is honest
        let diff = merge_kernels(&w1, &w2, 1).max_abs_diff(&merge_kernels_ref(&w1, &w2, 1));
        assert!(diff < 1e-3, "GEMM/naive parity broken: {diff}");
        let fast = bench("merge_kernels_gemm c=256 k1=3 k2=3", 1, 500.0, || {
            std::hint::black_box(merge_kernels(&w1, &w2, 1));
        });
        println!("{}", fast.row());
        let slow = bench_iters("merge_kernels_naive c=256 k1=3 k2=3", 0, 3, || {
            std::hint::black_box(merge_kernels_ref(&w1, &w2, 1));
        });
        let speedup = slow.p50_ms / fast.p50_ms;
        println!("{}  ({speedup:.1}x vs naive)", slow.row());
        rows.push(stats_json(&fast));
        rows.push(stats_json(&slow));
        derived.push(("merge_speedup_c256".into(), Json::num(speedup)));
        derived.push(("merge_parity_max_abs_diff".into(), Json::num(diff as f64)));
    }

    // inverted-residual merge: 1x1 -> dw3x3 -> 1x1 (+Dirac), the
    // DepthShrinker-style case MobileNetV2 spans hit constantly
    let (cin, cexp) = (24usize, 96usize);
    let w_exp = randt(&mut rng, &[cexp, cin, 1, 1]);
    let w_dw = expand_depthwise(&randt(&mut rng, &[cexp, 1, 3, 3]));
    let w_proj = randt(&mut rng, &[cin, cexp, 1, 1]);
    let s = bench("merge_inverted_residual 24->96dw->24 (+dirac)", 2, budget_ms, || {
        let m1 = merge_kernels(&w_exp, &w_dw, 1);
        let mut m2 = merge_kernels(&m1, &w_proj, 1);
        let d = dirac(cin, m2.dims[2]);
        for (a, b) in m2.data.iter_mut().zip(&d.data) {
            *a += *b;
        }
        std::hint::black_box(&m2);
    });
    println!("{}", s.row());
    rows.push(stats_json(&s));

    // full span composition on the real resnetish spec, if artifacts exist
    let spec_path = std::path::Path::new("artifacts/specs/resnetish.spec.json");
    if spec_path.exists() && !smoke() {
        let spec = layermerge::ir::Spec::load(spec_path)?;
        let flat: Vec<f32> = (0..spec.param_count).map(|_| rng.normal() * 0.1).collect();
        let kept: BTreeSet<usize> = [2usize, 3].into_iter().collect();
        let s = bench("span_merge resnetish (1,3] residual block", 2, 300.0, || {
            std::hint::black_box(layermerge::merge::span_merge(&spec, &flat, 1, 3, &kept));
        });
        println!("{}", s.row());
        rows.push(stats_json(&s));
    } else {
        println!("(skipping span_merge bench: run `make artifacts` first)");
    }

    // eager one-shot (lower per call) vs compiled plan (lower once):
    // the per-dispatch overhead the zero-overhead execution plans remove.
    let root = std::path::Path::new("artifacts");
    if root.join("manifest.json").exists() && !smoke() {
        use layermerge::exec::{Format, Plan};
        use layermerge::serve::Engine;
        use std::sync::Arc;

        println!("== forward benches (eager re-lower vs compiled plan) ==");
        let engine = Engine::open(root)?;
        let model = engine.load_model("resnetish")?;
        let spec = &model.spec;
        let plan = Arc::new(Plan::original(spec, &model.init)?);
        let x = randt(&mut rng, &[spec.batch, spec.h, spec.w, spec.c]);

        let oneshot = bench("forward eager (re-lower each call)", 3, 500.0, || {
            std::hint::black_box(
                engine.infer(&plan, &x, None, Format::Eager).unwrap(),
            );
        });
        println!("{}", oneshot.row());
        let cp = engine.lower(&plan, Format::Eager)?;
        let loads_before = engine.runtime().loads();
        let compiled = bench("forward eager (compiled plan)", 3, 500.0, || {
            std::hint::black_box(cp.forward(&x, None).unwrap());
        });
        println!("{}", compiled.row());
        assert_eq!(
            engine.runtime().loads(),
            loads_before,
            "compiled-plan forward must not touch the Runtime cache"
        );
        rows.push(stats_json(&oneshot));
        rows.push(stats_json(&compiled));
        derived.push(("forward_oneshot_p50_ms".into(), Json::num(oneshot.p50_ms)));
        derived.push(("forward_compiled_p50_ms".into(), Json::num(compiled.p50_ms)));
        derived.push((
            "forward_overhead_saved_ms".into(),
            Json::num(oneshot.p50_ms - compiled.p50_ms),
        ));
    } else {
        println!("(skipping forward bench: run `make artifacts` first)");
    }

    // register-blocked micro-kernel over packed panels vs the axpy GEMM
    // (acceptance target: packed beats axpy at >= 256^3)
    println!(
        "== GEMM micro-kernel (packed panels) vs axpy [isa {}] ==",
        layermerge::kernels::isa().name()
    );
    let gemm_dims: &[usize] = if smoke() { &[48] } else { &[128, 256, 384] };
    for &d in gemm_dims {
        let a: Vec<f32> = (0..d * d).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..d * d).map(|_| rng.normal()).collect();
        let mut c = vec![0.0f32; d * d];
        let axpy = bench(&format!("gemm_axpy {d}x{d}x{d}"), 2, budget_ms, || {
            c.fill(0.0);
            gemm(d, d, d, &a, &b, &mut c);
            std::hint::black_box(&c);
        });
        println!("{}", axpy.row());
        let bp = PackedB::pack(d, d, &b);
        let packed = bench(&format!("packed_gemm {d}x{d}x{d}"), 2, budget_ms, || {
            c.fill(0.0);
            gemm_packed(d, &a, &bp, &mut c);
            std::hint::black_box(&c);
        });
        println!("{}  ({:.2}x vs axpy)", packed.row(), axpy.p50_ms / packed.p50_ms);
        rows.push(stats_json(&axpy));
        rows.push(stats_json(&packed));
        if d == 256 {
            derived.push((
                "packed_gemm_speedup_256".into(),
                Json::num(axpy.p50_ms / packed.p50_ms),
            ));
        }

        // SIMD win vs the scalar-forced kernel, and int8 win vs f32-SIMD,
        // at the acceptance shape (every shape in smoke so CI exercises
        // the forced-ISA and quantized bench paths)
        if d == 256 || smoke() {
            let scalar = bench(&format!("scalar_gemm {d}x{d}x{d}"), 2, budget_ms, || {
                c.fill(0.0);
                gemm_packed_epi_isa(Isa::Scalar, d, &a, &bp, &mut c, None);
                std::hint::black_box(&c);
            });
            println!(
                "{}  (simd {:.2}x vs scalar)",
                scalar.row(),
                scalar.p50_ms / packed.p50_ms
            );
            let bpi = PackedBI8::pack(d, d, &b);
            let int8 = bench(&format!("int8_gemm {d}x{d}x{d}"), 2, budget_ms, || {
                c.fill(0.0);
                gemm_packed_epi_i8(d, &a, &bpi, &mut c, None, None);
                std::hint::black_box(&c);
            });
            println!(
                "{}  (int8 {:.2}x vs f32-simd)",
                int8.row(),
                packed.p50_ms / int8.p50_ms
            );
            rows.push(stats_json(&scalar));
            rows.push(stats_json(&int8));
            if d == 256 {
                derived.push((
                    "packed_gemm_simd_speedup".into(),
                    Json::num(scalar.p50_ms / packed.p50_ms),
                ));
                derived.push((
                    "int8_speedup".into(),
                    Json::num(packed.p50_ms / int8.p50_ms),
                ));
            }
        }
    }

    // persistent-pool dispatch vs the legacy per-call scoped spawn on an
    // identical chunked elementwise pass — the orchestration overhead the
    // compute pool removes from every kernel dispatch
    println!("== par dispatch: persistent pool vs scoped spawn ==");
    let elems = if smoke() { 1 << 16 } else { 1 << 22 };
    let threads = par::max_threads();
    let chunk = (elems / (threads * 4)).max(1);
    let mut buf = vec![1.0f32; elems];
    let pool_b = bench("par pool elemwise", 2, budget_ms, || {
        par::par_chunks_mut(&mut buf, chunk, threads, |_, ch| {
            for v in ch {
                *v = v.mul_add(1.000_1, 0.1).fract();
            }
        });
    });
    println!("{}", pool_b.row());
    let scoped_b = bench("par scoped elemwise", 2, budget_ms, || {
        par::par_chunks_mut_scoped(&mut buf, chunk, threads, |_, ch| {
            for v in ch {
                *v = v.mul_add(1.000_1, 0.1).fract();
            }
        });
    });
    println!("{}  (pool {:.2}x vs scoped)", scoped_b.row(), scoped_b.p50_ms / pool_b.p50_ms);
    rows.push(stats_json(&pool_b));
    rows.push(stats_json(&scoped_b));
    derived.push((
        "pool_dispatch_speedup".into(),
        Json::num(scoped_b.p50_ms / pool_b.p50_ms),
    ));

    // steady-state lowered host forward: packed weights + arena reuse;
    // the derived alloc rate must be 0.0 from the second forward on
    println!("== steady-state host forward (packed weights + arena) ==");
    let spec_name = if smoke() { "hostchain-tiny" } else { "hostchain" };
    let (spec, params) = synth::by_name(spec_name).expect("synth spec");
    let plan = Arc::new(Plan::original(&spec, &params)?);
    let be = Arc::new(HostBackend::new());
    let bedyn: Arc<dyn Backend> = be.clone();
    let cp = CompiledPlan::lower(plan, bedyn, Format::Fused)?;
    let x = randt(&mut rng, &[spec.batch, spec.h, spec.w, spec.c]);
    cp.forward(&x, None)?; // warm: charges the arena, initializes the pool
    let m0 = be.arena().misses();
    let fwd = bench(&format!("steady_forward {spec_name}"), 1, budget_ms, || {
        std::hint::black_box(cp.forward(&x, None).unwrap());
    });
    let allocs = (be.arena().misses() - m0) as f64 / fwd.iters as f64;
    println!("{}  ({allocs:.2} arena allocs/forward)", fwd.row());
    rows.push(stats_json(&fwd));
    derived.push(("steady_forward_p50_ms".into(), Json::num(fwd.p50_ms)));
    derived.push(("steady_forward_allocs_per_forward".into(), Json::num(allocs)));

    if smoke() {
        println!("(BENCH_SMOKE=1: skipping BENCH_merge.json write)");
        return Ok(());
    }

    // shared RMW: replace only what this bench owns, preserve the rest
    layermerge::bench::record(
        &[
            "merge_kernels_", "merge_inverted_residual", "span_merge ",
            "forward ", "gemm_axpy ", "packed_gemm ", "scalar_gemm ",
            "int8_gemm ", "par ", "steady_forward ",
        ],
        &["merge_", "forward_", "packed_gemm_", "int8_", "pool_", "steady_"],
        rows,
        derived,
    )
}
