//! Bench: parameter-space merging (Sec. 2's theta_2 * theta_1 operator and
//! the full span composition of Algorithm 2) — the deployment-time hot
//! path of the merge engine.

use std::collections::BTreeSet;

use layermerge::bench::bench;
use layermerge::merge::{dirac, expand_depthwise, merge_kernels};
use layermerge::util::rng::Rng;
use layermerge::util::tensor::Tensor;

fn randt(rng: &mut Rng, dims: &[usize]) -> Tensor {
    let n: usize = dims.iter().product();
    Tensor::new(dims.to_vec(), (0..n).map(|_| rng.normal()).collect())
}

fn main() {
    println!("== merge-operator benches ==");
    let mut rng = Rng::new(1);
    for &(c, k1, k2) in &[(16usize, 3usize, 3usize), (64, 3, 3), (64, 7, 3), (128, 11, 3)] {
        let w1 = randt(&mut rng, &[c, c, k1, k1]);
        let w2 = randt(&mut rng, &[c, c, k2, k2]);
        let s = bench(
            &format!("merge_kernels c={c} k1={k1} k2={k2}"),
            2,
            300.0,
            || {
                std::hint::black_box(merge_kernels(&w1, &w2, 1));
            },
        );
        println!("{}", s.row());
    }

    // inverted-residual merge: 1x1 -> dw3x3 -> 1x1 (+Dirac), the
    // DepthShrinker-style case MobileNetV2 spans hit constantly
    let (cin, cexp) = (24usize, 96usize);
    let w_exp = randt(&mut rng, &[cexp, cin, 1, 1]);
    let w_dw = expand_depthwise(&randt(&mut rng, &[cexp, 1, 3, 3]));
    let w_proj = randt(&mut rng, &[cin, cexp, 1, 1]);
    let s = bench("merge_inverted_residual 24->96dw->24 (+dirac)", 2, 300.0, || {
        let m1 = merge_kernels(&w_exp, &w_dw, 1);
        let mut m2 = merge_kernels(&m1, &w_proj, 1);
        let d = dirac(cin, m2.dims[2]);
        for (a, b) in m2.data.iter_mut().zip(&d.data) {
            *a += *b;
        }
        std::hint::black_box(&m2);
    });
    println!("{}", s.row());

    // full span composition on the real resnetish spec, if artifacts exist
    let spec_path = std::path::Path::new("artifacts/specs/resnetish.spec.json");
    if spec_path.exists() {
        let spec = layermerge::ir::Spec::load(spec_path).unwrap();
        let flat: Vec<f32> = (0..spec.param_count).map(|_| rng.normal() * 0.1).collect();
        let kept: BTreeSet<usize> = [2usize, 3].into_iter().collect();
        let s = bench("span_merge resnetish (1,3] residual block", 2, 300.0, || {
            std::hint::black_box(layermerge::merge::span_merge(&spec, &flat, 1, 3, &kept));
        });
        println!("{}", s.row());
    } else {
        println!("(skipping span_merge bench: run `make artifacts` first)");
    }
    println!("done");
}
