//! Bench: dispatch + transfer overhead — **device-resident** forward
//! (activations and pre-uploaded operands flow between steps as backend
//! values) vs the **per-dispatch round-trip** path (every operand crosses
//! the host<->device boundary on every op, the cost shape `Exec::run`
//! had before the backend abstraction).  Runs on the native host backend
//! over the synthetic specs, so the numbers are real with no artifacts
//! and no XLA — and extends `BENCH_merge.json` (schema
//! `layermerge.bench.merge.v1`, read-modify-write) with the
//! `resident_forward` record: per-mode p50 latency and the counted
//! transfer totals per forward.
//!
//! With `make artifacts` + real XLA bindings, a trailing section also
//! times the PJRT gated train/eval step the importance builder hammers.

use std::sync::Arc;

use layermerge::bench::{bench, smoke, stats_json};
use layermerge::exec::{Format, Plan};
use layermerge::ir::synth;
use layermerge::runtime::{Backend, HostBackend};
use layermerge::serve::Engine;
use layermerge::util::json::Json;
use layermerge::util::rng::Rng;
use layermerge::util::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let mut rows: Vec<Json> = Vec::new();
    let mut derived: Vec<(String, Json)> = Vec::new();

    // BENCH_SMOKE=1: tiny synthetic specs, minimal budgets, no JSON
    // write — the CI gate that keeps this bench compiling and running
    let specs: &[&str] =
        if smoke() { &["hostnet-tiny", "hostchain-tiny"] } else { &["hostnet", "hostchain"] };
    let budget_ms = if smoke() { 10.0 } else { 300.0 };

    println!("== runtime dispatch benches (host backend, resident vs per-dispatch) ==");
    for &name in specs {
        let (spec, params) = synth::by_name(name).expect("synthetic spec");
        let plan = Arc::new(Plan::original(&spec, &params)?);
        let mut rng = Rng::new(0xd15);
        let n = spec.batch * spec.h * spec.w * spec.c;
        let x = Tensor::new(
            vec![spec.batch, spec.h, spec.w, spec.c],
            (0..n).map(|_| rng.normal()).collect(),
        );

        // resident: operands uploaded once at lowering, activations flow
        // as backend values
        let resident = Engine::host();
        let cp = resident.lower(&plan, Format::Fused)?;
        let s_res = bench(&format!("resident forward {name} fused"), 3, budget_ms, || {
            std::hint::black_box(cp.forward(&x, None).unwrap());
        });
        println!("{}", s_res.row());
        let be = resident.backend();
        let (u0, d0) = (be.uploads(), be.downloads());
        cp.forward(&x, None)?;
        let res_xfer = (be.uploads() - u0) + (be.downloads() - d0);

        // per-dispatch: the same lowered plan on the round-trip backend
        let dispatch = Engine::with_backend(Arc::new(HostBackend::per_dispatch()));
        let cpd = dispatch.lower(&plan, Format::Fused)?;
        let s_dis = bench(&format!("dispatch forward {name} fused"), 3, budget_ms, || {
            std::hint::black_box(cpd.forward(&x, None).unwrap());
        });
        let bd = dispatch.backend();
        let (u1, d1) = (bd.uploads(), bd.downloads());
        cpd.forward(&x, None)?;
        let dis_xfer = (bd.uploads() - u1) + (bd.downloads() - d1);
        let speedup = s_dis.p50_ms / s_res.p50_ms;
        println!(
            "{}  (resident {speedup:.2}x faster; {res_xfer} vs {dis_xfer} transfers/forward)",
            s_dis.row()
        );
        assert!(
            res_xfer < dis_xfer,
            "residency must cut transfers: {res_xfer} vs {dis_xfer}"
        );

        rows.push(stats_json(&s_res));
        rows.push(stats_json(&s_dis));
        derived.push((format!("resident_forward_p50_ms_{name}"), Json::num(s_res.p50_ms)));
        derived.push((format!("dispatch_forward_p50_ms_{name}"), Json::num(s_dis.p50_ms)));
        derived.push((format!("resident_speedup_{name}"), Json::num(speedup)));
        derived.push((
            format!("resident_transfers_per_forward_{name}"),
            Json::num(res_xfer as f64),
        ));
        derived.push((
            format!("dispatch_transfers_per_forward_{name}"),
            Json::num(dis_xfer as f64),
        ));
    }

    // PJRT section: the gated train/eval step, when artifacts + real XLA
    // bindings are present (skipped offline — the stub fails at client
    // creation inside Engine::open).
    let root = std::path::Path::new("artifacts");
    if root.join("manifest.json").exists() && !smoke() {
        match Engine::open(root) {
            Ok(engine) => {
                use layermerge::train::{self, Gen};
                println!("== runtime dispatch benches (PJRT gated graph) ==");
                for name in ["resnetish", "mnv2ish-1.0", "ddpmish"] {
                    let Ok(model) = engine.load_model(name) else {
                        println!("(skipping {name})");
                        continue;
                    };
                    let gen = Gen::for_model(&model, 0xda7a);
                    let gates = model.spec.pristine_gates();
                    let batch = gen.batch(train::STREAM_TRAIN, 0);
                    let mut params = model.init.clone();
                    let mut mom = vec![0.0f32; params.len()];
                    let s = bench(&format!("{name} gated eval step"), 2, 500.0, || {
                        std::hint::black_box(model.eval(&params, &gates, &batch).unwrap());
                    });
                    println!("{}", s.row());
                    let s = bench(&format!("{name} gated train step"), 2, 500.0, || {
                        std::hint::black_box(
                            model.step(&mut params, &mut mom, &gates, &batch, 0.01).unwrap(),
                        );
                    });
                    println!("{}", s.row());
                }
            }
            Err(e) => println!("(skipping PJRT dispatch bench: {e})"),
        }
    } else {
        println!("(skipping PJRT dispatch bench: run `make artifacts` first)");
    }

    if smoke() {
        println!("(BENCH_SMOKE=1: skipping BENCH_merge.json write)");
        return Ok(());
    }

    // shared RMW: this bench owns the "resident/dispatch forward *" rows
    // and the resident_* / dispatch_* derived keys
    layermerge::bench::record(
        &["resident forward ", "dispatch forward "],
        &["resident_", "dispatch_"],
        rows,
        derived,
    )
}
