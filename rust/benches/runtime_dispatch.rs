//! Bench: PJRT runtime dispatch costs — the per-op overhead that makes
//! depth reduction pay (the "PyTorch format" premise of Tables 1-5), plus
//! the gated train/eval step the importance builder hammers.

use layermerge::bench::bench;
use layermerge::ir::Task;
use layermerge::model::{Manifest, Model};
use layermerge::runtime::Runtime;
use layermerge::train::{self, Gen};
use layermerge::util::rng::Rng;
use layermerge::util::tensor::Tensor;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let root = std::path::Path::new("artifacts");
    if !root.join("manifest.json").exists() {
        println!("(skipping runtime bench: run `make artifacts` first)");
        return Ok(());
    }
    let rt = Arc::new(Runtime::new(root)?);
    let man = Manifest::load(root)?;
    println!("== runtime dispatch benches ==");

    // smallest elementwise op == pure dispatch + transfer overhead
    if let Some(rel) = man.ew_art("relu_b32h4w4c128") {
        let exec = rt.load(&rel)?;
        let mut rng = Rng::new(5);
        let x = Tensor::new(vec![32, 4, 4, 128], (0..32 * 4 * 4 * 128).map(|_| rng.normal()).collect());
        let s = bench("dispatch relu 32x4x4x128 (overhead floor)", 5, 300.0, || {
            std::hint::black_box(exec.run(&[&x]).unwrap());
        });
        println!("{}", s.row());
    }

    for name in ["resnetish", "mnv2ish-1.0", "ddpmish"] {
        let Ok(model) = Model::load(rt.clone(), &man, name) else {
            println!("(skipping {name})");
            continue;
        };
        let gen = Gen::for_model(&model, 0xda7a);
        let gates = model.spec.pristine_gates();
        let batch = gen.batch(train::STREAM_TRAIN, 0);
        let mut params = model.init.clone();
        let mut mom = vec![0.0f32; params.len()];
        let s = bench(&format!("{name} gated eval step"), 2, 500.0, || {
            std::hint::black_box(model.eval(&params, &gates, &batch).unwrap());
        });
        println!("{}", s.row());
        let s = bench(&format!("{name} gated train step"), 2, 500.0, || {
            std::hint::black_box(
                model.step(&mut params, &mut mom, &gates, &batch, 0.01).unwrap(),
            );
        });
        println!("{}", s.row());
        let _ = match model.spec.task {
            Task::Classify | Task::Diffusion => (),
        };
    }
    Ok(())
}
