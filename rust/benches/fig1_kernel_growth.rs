//! Bench: Figure 1 — the latency cost of merged-kernel growth, measured
//! end-to-end through PJRT on the same conv modules the latency table
//! uses.  Prints the same series as `layermerge fig1`.

use layermerge::bench::bench;
use layermerge::model::{sig_str, Manifest};
use layermerge::runtime::Runtime;
use layermerge::util::rng::Rng;
use layermerge::util::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let root = std::path::Path::new("artifacts");
    if !root.join("manifest.json").exists() {
        println!("(skipping fig1 bench: run `make artifacts` first)");
        return Ok(());
    }
    let rt = Runtime::new(root)?;
    let man = Manifest::load(root)?;
    let (b, h, w, c) = (32usize, 32usize, 32usize, 16usize);
    let mut rng = Rng::new(3);
    println!("== Figure 1: merged conv latency vs kernel size (b{b} {h}x{w} c{c}) ==");
    let mut base3 = None;
    for k in (1..=13usize).step_by(2) {
        let sig = sig_str(b, h, w, c, c, k, 1, false);
        let Some(rel) = man.conv_art(&sig, "plain") else {
            println!("k={k}: no artifact ({sig})");
            continue;
        };
        let exec = rt.load(&rel)?;
        let n = b * h * w * c;
        let x = Tensor::new(vec![b, h, w, c], (0..n).map(|_| rng.normal()).collect());
        let wt = Tensor::new(vec![c, c, k, k], (0..c * c * k * k).map(|_| rng.normal()).collect());
        let bias = Tensor::zeros(&[c]);
        let s = bench(&format!("conv k={k}"), 3, 400.0, || {
            std::hint::black_box(exec.run(&[&x, &wt, &bias]).unwrap());
        });
        if k == 3 {
            base3 = Some(s.p50_ms);
        }
        let note = match (k, base3) {
            (k, Some(b3)) if k > 3 => {
                let n_merged = (k - 1) / 2;
                format!(
                    "  (merges {n_merged} 3x3 layers; unmerged chain ~{:.3}ms -> {})",
                    b3 * n_merged as f64,
                    if s.p50_ms < b3 * n_merged as f64 { "merge WINS" } else { "merge loses" }
                )
            }
            _ => String::new(),
        };
        println!("{}{}", s.row(), note);
    }
    Ok(())
}
