//! High-level handle over one AOT-compiled model family: the spec, the
//! gated-graph executables, and typed step/eval wrappers.
//!
//! Everything runs through the *single* gated graph (DESIGN.md §4): the
//! coordinator changes (A, C) configurations by feeding gate vectors, so
//! the table-construction hot loop never recompiles.

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::ir::{Gates, Spec, Task};
use crate::runtime::{Exec, Runtime};
use crate::util::json::Json;
use crate::util::tensor::Tensor;

/// Parsed artifacts/manifest.json.
pub struct Manifest {
    pub json: Json,
}

impl Manifest {
    pub fn load(root: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(root.join("manifest.json"))
            .context("manifest.json (run `make artifacts`)")?;
        Ok(Manifest { json: Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))? })
    }

    pub fn model_art(&self, model: &str, name: &str) -> Result<String> {
        Ok(self
            .json
            .req("models")
            .get(model)
            .with_context(|| format!("model {model} not in manifest"))?
            .req(name)
            .as_str()
            .with_context(|| format!("artifact {model}/{name}"))?
            .to_string())
    }

    /// Conv module path for a shape signature + variant, if emitted.
    pub fn conv_art(&self, sig: &str, variant: &str) -> Option<String> {
        self.json
            .req("convs")
            .get(sig)?
            .get(variant)?
            .as_str()
            .map(String::from)
    }

    pub fn ew_art(&self, key: &str) -> Option<String> {
        self.json.req("ew").get(key)?.as_str().map(String::from)
    }

    pub fn conv_sigs(&self) -> Vec<String> {
        self.json
            .req("convs")
            .as_obj()
            .map(|m| m.keys().cloned().collect())
            .unwrap_or_default()
    }
}

/// The canonical conv-signature key (must match aot.py::sig_str).
pub fn sig_str(
    b: usize,
    h: usize,
    w: usize,
    ci: usize,
    co: usize,
    k: usize,
    s: usize,
    dw: bool,
) -> String {
    format!("b{b}h{h}w{w}i{ci}o{co}k{k}s{s}{}", if dw { "dw" } else { "" })
}

/// One batch of training/eval data, already in model layout.
#[derive(Debug, Clone)]
pub enum Batch {
    /// x: [B,H,W,C], y: one-hot [B,num_classes]
    Classify { x: Tensor, y: Tensor },
    /// x0, eps: [B,H,W,C]; t, abar: [B]
    Diffusion { x0: Tensor, eps: Tensor, t: Tensor, abar: Tensor },
}

pub struct Model {
    pub spec: Spec,
    pub rt: Arc<Runtime>,
    pub name: String,
    fwd: Arc<Exec>,
    loss_eval: Arc<Exec>,
    train_step: Arc<Exec>,
    distill_step: Option<Arc<Exec>>,
    embed: Option<Arc<Exec>>,
    sample_step: Option<Arc<Exec>>,
    pub init: Vec<f32>,
}

impl Model {
    pub fn load(rt: Arc<Runtime>, man: &Manifest, name: &str) -> Result<Model> {
        let spec = Spec::load(&rt.root().join(man.model_art(name, "spec")?))?;
        let init =
            Tensor::read_f32_file(&rt.root().join(man.model_art(name, "init")?))?;
        anyhow::ensure!(init.len() == spec.param_count, "init size mismatch");
        let fwd = rt.load(&man.model_art(name, "fwd")?)?;
        let loss_eval = rt.load(&man.model_art(name, "loss_eval")?)?;
        let train_step = rt.load(&man.model_art(name, "train_step")?)?;
        let distill_step = match spec.task {
            Task::Classify => Some(rt.load(&man.model_art(name, "distill_step")?)?),
            Task::Diffusion => None,
        };
        let embed = match spec.task {
            Task::Classify => Some(rt.load(&man.model_art(name, "embed")?)?),
            Task::Diffusion => None,
        };
        let sample_step = match spec.task {
            Task::Diffusion => Some(rt.load(&man.model_art(name, "sample_step")?)?),
            Task::Classify => None,
        };
        Ok(Model {
            spec,
            rt,
            name: name.to_string(),
            fwd,
            loss_eval,
            train_step,
            distill_step,
            embed,
            sample_step,
            init,
        })
    }

    fn gate_tensors(&self, g: &Gates) -> (Tensor, Tensor, Tensor) {
        let l = self.spec.len();
        (
            Tensor::new(vec![l], g.act.clone()),
            Tensor::new(vec![l], g.conv.clone()),
            Tensor::new(vec![l], g.gn.clone()),
        )
    }

    /// Forward pass: logits (classify) or predicted noise (diffusion).
    pub fn forward(&self, params: &[f32], g: &Gates, batch: &Batch) -> Result<Tensor> {
        let p = Tensor::new(vec![params.len()], params.to_vec());
        let (ga, gc, gn) = self.gate_tensors(g);
        let out = match batch {
            Batch::Classify { x, .. } => self.fwd.run(&[&p, &ga, &gc, &gn, x])?,
            Batch::Diffusion { x0, t, .. } => {
                self.fwd.run(&[&p, &ga, &gc, &gn, x0, t])?
            }
        };
        Ok(out.into_iter().next().unwrap())
    }

    /// (loss, metric): metric is accuracy for classify, negative diffusion
    /// loss for diffusion (the paper's Perf definition, Sec. 3.1).
    pub fn eval(&self, params: &[f32], g: &Gates, batch: &Batch) -> Result<(f32, f32)> {
        let p = Tensor::new(vec![params.len()], params.to_vec());
        let (ga, gc, gn) = self.gate_tensors(g);
        let out = match batch {
            Batch::Classify { x, y } => {
                self.loss_eval.run(&[&p, &ga, &gc, &gn, x, y])?
            }
            Batch::Diffusion { x0, eps, t, abar } => {
                self.loss_eval.run(&[&p, &ga, &gc, &gn, x0, eps, t, abar])?
            }
        };
        Ok((out[0].data[0], out[1].data[0]))
    }

    /// One SGD-momentum step; updates `params` and `mom` in place.
    pub fn step(
        &self,
        params: &mut Vec<f32>,
        mom: &mut Vec<f32>,
        g: &Gates,
        batch: &Batch,
        lr: f32,
    ) -> Result<(f32, f32)> {
        let p = Tensor::new(vec![params.len()], std::mem::take(params));
        let m = Tensor::new(vec![mom.len()], std::mem::take(mom));
        let (ga, gc, gn) = self.gate_tensors(g);
        let lrt = Tensor::scalar(lr);
        let out = match batch {
            Batch::Classify { x, y } => {
                self.train_step.run(&[&p, &m, &ga, &gc, &gn, x, y, &lrt])?
            }
            Batch::Diffusion { x0, eps, t, abar } => self
                .train_step
                .run(&[&p, &m, &ga, &gc, &gn, x0, eps, t, abar, &lrt])?,
        };
        let mut it = out.into_iter();
        *params = it.next().unwrap().data;
        *mom = it.next().unwrap().data;
        let loss = it.next().unwrap().data[0];
        let metric = it.next().unwrap().data[0];
        Ok((loss, metric))
    }

    /// One KD step (teacher = pristine parameters).
    pub fn distill(
        &self,
        teacher: &[f32],
        params: &mut Vec<f32>,
        mom: &mut Vec<f32>,
        g: &Gates,
        batch: &Batch,
        lr: f32,
    ) -> Result<(f32, f32)> {
        let ds = self
            .distill_step
            .as_ref()
            .context("distill_step only exists for classifiers")?;
        let (x, y) = match batch {
            Batch::Classify { x, y } => (x, y),
            _ => anyhow::bail!("distill needs a classify batch"),
        };
        let tp = Tensor::new(vec![teacher.len()], teacher.to_vec());
        let p = Tensor::new(vec![params.len()], std::mem::take(params));
        let m = Tensor::new(vec![mom.len()], std::mem::take(mom));
        let (ga, gc, gn) = self.gate_tensors(g);
        let lrt = Tensor::scalar(lr);
        let out = ds.run(&[&tp, &p, &m, &ga, &gc, &gn, x, y, &lrt])?;
        let mut it = out.into_iter();
        *params = it.next().unwrap().data;
        *mom = it.next().unwrap().data;
        Ok((it.next().unwrap().data[0], it.next().unwrap().data[0]))
    }

    /// Penultimate features (FDD embedder).
    pub fn embed(&self, params: &[f32], g: &Gates, x: &Tensor) -> Result<Tensor> {
        let e = self.embed.as_ref().context("embed is classifier-only")?;
        let p = Tensor::new(vec![params.len()], params.to_vec());
        let (ga, gc, gn) = self.gate_tensors(g);
        Ok(e.run(&[&p, &ga, &gc, &gn, x])?.into_iter().next().unwrap())
    }

    /// One DDIM step on the gated graph.
    pub fn sample_step(
        &self,
        params: &[f32],
        g: &Gates,
        xt: &Tensor,
        t: &Tensor,
        abar_t: &Tensor,
        abar_prev: &Tensor,
    ) -> Result<Tensor> {
        let s = self.sample_step.as_ref().context("diffusion-only")?;
        let p = Tensor::new(vec![params.len()], params.to_vec());
        let (ga, gc, gn) = self.gate_tensors(g);
        Ok(s
            .run(&[&p, &ga, &gc, &gn, xt, t, abar_t, abar_prev])?
            .into_iter()
            .next()
            .unwrap())
    }
}
