//! Merged-network executor — runs the *deployed* compressed model.
//!
//! After Algorithm 1 picks (A*, C*) and fine-tuning finishes, `Plan`
//! materializes the merged network: one `span_merge`d conv per span plus
//! the structural ops (residual adds whose branch wasn't folded, group
//! norm, attention, upsampling, skip-concat, classifier head, time-bias
//! injection).  Two execution formats mirror the paper's measurement
//! targets (DESIGN.md §2):
//!
//! * `Format::Eager` ("PyTorch format") — one PJRT dispatch per op:
//!   conv, then act, then add, each its own executable.
//! * `Format::Fused` ("TensorRT format") — conv+bias+act(+residual) as a
//!   single fused executable per merged layer (XLA fuses internally).
//!
//! Dispatch runs through [`CompiledPlan`], a one-time lowering of the
//! plan: every artifact is resolved to its `Arc<Exec>` up front, bias and
//! group-norm tensors are materialized once, and boundary activations
//! flow through refcounted buffers that are released at their last use —
//! the steady-state loop performs **zero** `Runtime` cache-mutex
//! acquisitions, path hashes, or full-tensor boundary clones per step.
//!
//! `CompiledPlan` **owns** its plan (`Arc<Plan>`): it has no lifetime
//! parameter, is `Send + Sync`, and can be handed to worker threads.
//! Deployment goes through [`crate::serve::Engine::deploy`] (worker-pool
//! serving) or [`crate::serve::Engine::lower`] (a bare compiled plan for
//! hot loops); `CompiledPlan::lower` is the underlying constructor.
//!
//! The plan is also the ground truth for end-to-end latency measurements
//! (Tables 1-5) and for the merged-vs-pruned numerics report.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::ir::{Spec, Task};
use crate::merge::{span_merge, MergedConv};
use crate::model::{sig_str, Manifest};
use crate::runtime::{Exec, Runtime};
use crate::util::tensor::Tensor;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Format {
    Eager,
    Fused,
}

#[derive(Debug, Clone)]
pub struct ProjParams {
    pub w: Tensor,
    pub b: Vec<f32>,
    pub stride: usize,
}

#[derive(Debug, Clone)]
pub enum Post {
    Attention { wqkv: Tensor, wout: Tensor },
    Upsample,
}

#[derive(Debug, Clone)]
pub struct Step {
    pub i: usize,
    pub j: usize,
    pub merged: MergedConv,
    /// input feature-map geometry (after concat)
    pub h_in: usize,
    pub w_in: usize,
    pub cin: usize,
    /// activation applied at the boundary ("relu"/"swish"), if any
    pub act: Option<String>,
    /// group norm applied at the boundary: (scale, bias, groups)
    pub gn: Option<(Vec<f32>, Vec<f32>, usize)>,
    /// unfolded residual: (source boundary index, optional projection)
    pub res: Option<(usize, Option<ProjParams>)>,
    /// concat the stash tag onto the span input
    pub concat: Option<String>,
    /// time-bias injection at the span input: (w [tdim,cin], b [cin])
    pub time_bias: Option<(Tensor, Vec<f32>)>,
    pub stash_as: Option<String>,
    pub post: Vec<Post>,
}

pub struct Plan {
    pub spec_name: String,
    pub task: Task,
    pub batch: usize,
    pub steps: Vec<Step>,
    /// classifier head (w, b)
    pub head: Option<(Tensor, Vec<f32>)>,
    /// diffusion time embedding MLP (w1, b1) and dim
    pub temb: Option<(Tensor, Vec<f32>, usize)>,
    pub l_total: usize,
}

impl Plan {
    /// Plan for the ORIGINAL network: every layer its own span, all convs
    /// and activations kept.
    pub fn original(spec: &Spec, flat: &[f32]) -> Result<Plan> {
        let a: Vec<usize> = (1..spec.len()).collect(); // singleton spans: acts stay pristine
        let c: BTreeSet<usize> = (1..=spec.len()).collect();
        let spans: Vec<(usize, usize, usize)> =
            (1..=spec.len()).map(|j| (j - 1, j, spec.conv(j).k)).collect();
        Plan::from_solution(spec, flat, &a, &c, &spans)
    }

    /// Build the deployed network from a solution.
    ///
    /// `a` = kept interior boundaries; `c` = kept conv set (superset of R);
    /// `spans` = (i, j, k) from the solver (k recorded for bookkeeping).
    pub fn from_solution(
        spec: &Spec,
        flat: &[f32],
        a: &[usize],
        c: &BTreeSet<usize>,
        spans: &[(usize, usize, usize)],
    ) -> Result<Plan> {
        let a_set: BTreeSet<usize> = a.iter().copied().collect();
        let mut steps: Vec<Step> = Vec::new();
        // canonical boundary resolution: spans that reduce to an exact
        // identity (e.g. a layer dropped by LayerOnly) are elided — the
        // deployed network genuinely skips them.
        let mut canon: BTreeMap<usize, usize> = BTreeMap::new();
        canon.insert(0, 0);
        for &(i, j, _k) in spans {
            let kept: BTreeSet<usize> =
                ((i + 1)..=j).filter(|l| c.contains(l) || !spec.conv(*l).conv_gated).collect();
            let merged = span_merge(spec, flat, i, j, &kept);
            let first = spec.conv(i + 1);
            let cj = spec.conv(j);
            // boundary activation: pristine act, or — for multi-layer
            // merged spans ending at a pristine-linear position — the
            // App. A added activation (mirrors ir::solution_gates).
            let act = if !cj.act_gated {
                if cj.act == "none" { None } else { Some(cj.act.clone()) }
            } else if j == spec.len() || !a_set.contains(&j) {
                None // sigma_L = id / activation pruned by the solver
            } else if cj.act != "none" {
                Some(cj.act.clone())
            } else if j - i > 1 {
                Some("relu".to_string())
            } else {
                None
            };
            let gn = if cj.gn {
                Some((
                    spec.param_slice(flat, &format!("gn{j}.scale")).to_vec(),
                    spec.param_slice(flat, &format!("gn{j}.bias")).to_vec(),
                    cj.gn_groups,
                ))
            } else {
                None
            };
            // external residual: add point at j with source before span
            let res = match cj.add_from {
                Some(af) if af - 1 < i => {
                    let proj = cj.add_proj.as_ref().map(|p| ProjParams {
                        w: Tensor::new(
                            spec.param(&format!("proj{af}.w")).shape.clone(),
                            spec.param_slice(flat, &format!("proj{af}.w")).to_vec(),
                        ),
                        b: spec.param_slice(flat, &format!("proj{af}.b")).to_vec(),
                        stride: p.stride,
                    });
                    Some((af - 1, proj))
                }
                _ => None,
            };
            let time_bias = if first.time_bias {
                Some((
                    Tensor::new(
                        spec.param(&format!("temb{}.w", i + 1)).shape.clone(),
                        spec.param_slice(flat, &format!("temb{}.w", i + 1)).to_vec(),
                    ),
                    spec.param_slice(flat, &format!("temb{}.b", i + 1)).to_vec(),
                ))
            } else {
                None
            };
            let mut post = Vec::new();
            if cj.barrier_reason == "attention" {
                post.push(Post::Attention {
                    wqkv: Tensor::new(
                        spec.param("attn.qkv.w").shape.clone(),
                        spec.param_slice(flat, "attn.qkv.w").to_vec(),
                    ),
                    wout: Tensor::new(
                        spec.param("attn.out.w").shape.clone(),
                        spec.param_slice(flat, "attn.out.w").to_vec(),
                    ),
                });
            }
            if cj.barrier_reason == "upsample" {
                post.push(Post::Upsample);
            }
            // identity elision: dropped layer -> no dispatch at all
            let is_identity = merged.k == 1
                && merged.stride == 1
                && !merged.depthwise
                && act.is_none()
                && gn.is_none()
                && res.is_none()
                && first.concat_from.is_none()
                && time_bias.is_none()
                && cj.stash_as.is_none()
                && post.is_empty()
                && {
                    let d = crate::merge::dirac(first.cin, 1);
                    merged.weight.dims == d.dims
                        && merged.weight.max_abs_diff(&d) < 1e-7
                        && merged.bias.iter().all(|b| b.abs() < 1e-7)
                };
            let src = *canon.get(&i).unwrap_or(&i);
            if is_identity {
                canon.insert(j, src);
                continue;
            }
            canon.insert(j, j);
            steps.push(Step {
                i: src,
                j,
                merged,
                h_in: first.h_in,
                w_in: first.w_in,
                cin: first.cin,
                act,
                gn,
                res,
                concat: first.concat_from.clone(),
                time_bias,
                stash_as: cj.stash_as.clone(),
                post,
            });
        }
        // remap residual sources through the canonical boundary map
        for s in &mut steps {
            if let Some((src, _)) = &mut s.res {
                *src = *canon.get(src).unwrap_or(src);
            }
        }
        let head = match spec.task {
            Task::Classify => Some((
                Tensor::new(
                    spec.param("head.w").shape.clone(),
                    spec.param_slice(flat, "head.w").to_vec(),
                ),
                spec.param_slice(flat, "head.b").to_vec(),
            )),
            Task::Diffusion => None,
        };
        let temb = match spec.task {
            Task::Diffusion => Some((
                Tensor::new(
                    spec.param("temb.w1").shape.clone(),
                    spec.param_slice(flat, "temb.w1").to_vec(),
                ),
                spec.param_slice(flat, "temb.b1").to_vec(),
                spec.time_dim,
            )),
            Task::Classify => None,
        };
        Ok(Plan {
            spec_name: spec.name.clone(),
            task: spec.task,
            batch: spec.batch,
            steps,
            head,
            temb,
            l_total: spec.len(),
        })
    }

    pub fn depth(&self) -> usize {
        self.steps.len()
    }

    /// Does a forward through this plan require a timestep tensor?
    pub fn needs_time(&self) -> bool {
        self.task == Task::Diffusion
    }
}

impl CompiledPlan {
    /// Lower a plan against a runtime + manifest: resolve every
    /// executable, pre-materialize operand tensors, and precompute the
    /// boundary-buffer lifetimes.  One-time cost; the returned
    /// `CompiledPlan` dispatches with no per-step artifact resolution and
    /// keeps the plan alive through its `Arc` (weight tensors are shared,
    /// not copied).  Callers normally reach this through
    /// [`crate::serve::Engine::lower`] / [`crate::serve::Engine::deploy`].
    pub fn lower(
        plan: Arc<Plan>,
        rt: &Runtime,
        man: &Manifest,
        fmt: Format,
    ) -> Result<CompiledPlan> {
        let b = plan.batch;

        // Pass 1 — dataflow: which steps read their input from the running
        // buffer vs a stored boundary, which boundaries need a slot at
        // all, and where each slot's last read happens.
        let mut from_cur = Vec::with_capacity(plan.steps.len());
        let mut prev_j = 0usize;
        for step in &plan.steps {
            from_cur.push(step.i == prev_j);
            prev_j = step.j;
        }
        let mut slot_of: BTreeMap<usize, usize> = BTreeMap::new();
        let mut last_read: BTreeMap<usize, usize> = BTreeMap::new();
        for (s, step) in plan.steps.iter().enumerate() {
            if !from_cur[s] {
                slot_of.insert(step.i, 0);
                last_read.insert(step.i, s);
            }
            if let Some((src, _)) = &step.res {
                slot_of.insert(*src, 0);
                last_read.insert(*src, s);
            }
        }
        for (idx, slot) in slot_of.values_mut().enumerate() {
            *slot = idx;
        }

        // Pass 2 — shape propagation + artifact resolution.  Shapes are
        // derived exactly as the dispatch loop would observe them (SAME
        // convs divide by stride; upsample doubles), so every signature
        // matches what an eager forward would have requested.
        let mut shapes: BTreeMap<usize, (usize, usize, usize)> = BTreeMap::new();
        let input_dims = plan.steps.first().map(|f| [b, f.h_in, f.w_in, f.cin]);
        if let Some(f) = plan.steps.first() {
            anyhow::ensure!(
                f.concat.is_none(),
                "first step cannot read a stash (nothing stashed yet)"
            );
            shapes.insert(f.i, (f.h_in, f.w_in, f.cin));
        }
        let mut stash_of: BTreeMap<String, (usize, (usize, usize, usize))> = BTreeMap::new();
        let mut csteps: Vec<CompiledStep> = Vec::with_capacity(plan.steps.len());
        for (s, step) in plan.steps.iter().enumerate() {
            let (h, w, mut c) = *shapes
                .get(&step.i)
                .with_context(|| format!("boundary {} shape unknown", step.i))?;
            let concat_slot = match &step.concat {
                Some(tag) => {
                    let (slot, (hs, ws, cs)) = stash_of
                        .get(tag)
                        .with_context(|| format!("stash {tag} not materialized"))?
                        .clone();
                    anyhow::ensure!(
                        hs == h && ws == w,
                        "concat geometry mismatch at step {s}: {h}x{w} vs {hs}x{ws}"
                    );
                    c += cs;
                    Some(slot)
                }
                None => None,
            };
            let m = &step.merged;
            let co = m.bias.len();
            let sig = sig_str(b, h, w, c, co, m.k, m.stride, m.depthwise);
            // SAME padding: output spatial dims are ceil(in / stride)
            let (ho, wo) = (h.div_ceil(m.stride), w.div_ceil(m.stride));
            let ew_base = format!("b{b}h{ho}w{wo}c{co}");
            let res = match &step.res {
                Some((src, proj)) => {
                    let (hs, ws, cs) = *shapes
                        .get(src)
                        .with_context(|| format!("res boundary {src} shape unknown"))?;
                    // projection weight is read from the plan at dispatch;
                    // only the exec + materialized bias live here
                    let proj = match proj {
                        Some(p) => {
                            let psig =
                                sig_str(b, hs, ws, cs, p.b.len(), 1, p.stride, false);
                            let rel = man
                                .conv_art(&psig, "plain")
                                .with_context(|| format!("proj artifact {psig}"))?;
                            Some((
                                rt.load(&rel)?,
                                Tensor::new(vec![p.b.len()], p.b.clone()),
                            ))
                        }
                        None => None,
                    };
                    Some(CompiledRes { slot: slot_of[src], proj })
                }
                None => None,
            };
            // op order mirrors the gated graph: conv -> gn -> add -> act.
            // Fused format collapses conv(+add)(+act) into one dispatch
            // whenever no group norm sits in between.
            let can_fuse = fmt == Format::Fused && step.gn.is_none();
            let (conv, fuse_res, gn, add, act) = if can_fuse {
                let variant = match (&step.act, &res) {
                    (Some(a), Some(_)) => format!("far_{a}"),
                    (Some(a), None) => format!("fa_{a}"),
                    (None, Some(_)) => "far_none".to_string(),
                    (None, None) => "plain".to_string(),
                };
                let rel = man
                    .conv_art(&sig, &variant)
                    .with_context(|| format!("conv artifact {sig}.{variant}"))?;
                (rt.load(&rel)?, res.is_some(), None, None, None)
            } else {
                let rel = man
                    .conv_art(&sig, "plain")
                    .with_context(|| format!("conv artifact {sig}"))?;
                let conv = rt.load(&rel)?;
                let gn = match &step.gn {
                    Some((scale, bias, groups)) => {
                        let rel = man
                            .ew_art(&format!("gn{groups}_{ew_base}"))
                            .with_context(|| format!("gn artifact gn{groups}_{ew_base}"))?;
                        Some((
                            rt.load(&rel)?,
                            Tensor::new(vec![scale.len()], scale.clone()),
                            Tensor::new(vec![bias.len()], bias.clone()),
                        ))
                    }
                    None => None,
                };
                // missing add artifact falls back to a host-side add
                let add = match (&res, man.ew_art(&format!("add_{ew_base}"))) {
                    (Some(_), Some(rel)) => Some(rt.load(&rel)?),
                    _ => None,
                };
                let act = match &step.act {
                    Some(a) => {
                        let rel = man
                            .ew_art(&format!("{a}_{ew_base}"))
                            .with_context(|| format!("act artifact {a}_{ew_base}"))?;
                        Some(rt.load(&rel)?)
                    }
                    None => None,
                };
                (conv, false, gn, add, act)
            };
            // stash captures the pre-post-op output; posts then reshape
            let (mut hc, mut wc, cc) = (ho, wo, co);
            let stash_to = step.stash_as.as_ref().map(|tag| {
                // re-stashing a tag overwrites in place (same slot), like
                // the eager path's HashMap insert did
                let slot = match stash_of.get(tag) {
                    Some((slot, _)) => *slot,
                    None => stash_of.len(),
                };
                stash_of.insert(tag.clone(), (slot, (hc, wc, cc)));
                slot
            });
            let mut post = Vec::new();
            for p in &step.post {
                let base = format!("b{b}h{hc}w{wc}c{cc}");
                match p {
                    Post::Attention { .. } => {
                        let rel = man
                            .ew_art(&format!("attn_{base}"))
                            .context("attn artifact")?;
                        post.push(CompiledPost::Attention(rt.load(&rel)?));
                    }
                    Post::Upsample => {
                        let rel =
                            man.ew_art(&format!("up_{base}")).context("up artifact")?;
                        post.push(CompiledPost::Upsample(rt.load(&rel)?));
                        hc *= 2;
                        wc *= 2;
                    }
                }
            }
            shapes.insert(step.j, (hc, wc, cc));
            let release = last_read
                .iter()
                .filter(|&(_, &ls)| ls == s)
                .map(|(bid, _)| slot_of[bid])
                .collect();
            csteps.push(CompiledStep {
                src: if from_cur[s] {
                    InputSrc::Cur
                } else {
                    InputSrc::Boundary(slot_of[&step.i])
                },
                concat_slot,
                conv,
                bias: Tensor::new(vec![co], m.bias.clone()),
                fuse_res,
                gn,
                res,
                add,
                act,
                stash_to,
                post,
                store_slot: slot_of.get(&step.j).copied(),
                release,
            });
        }
        let head = match &plan.head {
            Some((_, hb)) => {
                let rel = man
                    .ew_art(&format!("head_{}", plan.spec_name))
                    .context("head artifact")?;
                Some((rt.load(&rel)?, Tensor::new(vec![hb.len()], hb.clone())))
            }
            None => None,
        };
        let input_slot = plan.steps.first().and_then(|f| slot_of.get(&f.i).copied());
        Ok(CompiledPlan {
            fmt,
            task: plan.task,
            batch: b,
            steps: csteps,
            head,
            input_dims,
            input_slot,
            n_slots: slot_of.len(),
            n_stash: stash_of.len(),
            plan,
        })
    }
}

/// Sinusoidal + MLP time embedding (host side; 32-dim — negligible).
fn temb_embed(w1: &Tensor, b1: &[f32], dim: usize, t: &Tensor) -> Vec<f32> {
    let b = t.dims[0];
    let half = dim / 2;
    let mut emb = vec![0.0f32; b * dim];
    for n in 0..b {
        for i in 0..half {
            let freq = (-(10000.0f32.ln()) * i as f32 / half as f32).exp();
            let ang = t.data[n] * freq;
            emb[n * dim + i] = ang.sin();
            emb[n * dim + half + i] = ang.cos();
        }
    }
    // dense + swish
    let mut out = vec![0.0f32; b * dim];
    for n in 0..b {
        for o in 0..dim {
            let mut acc = b1[o];
            for i in 0..dim {
                acc += emb[n * dim + i] * w1.data[i * dim + o];
            }
            out[n * dim + o] = acc / (1.0 + (-acc).exp());
        }
    }
    out
}

/// Where a step reads its input from.
enum InputSrc {
    /// The running activation produced by the previous step.
    Cur,
    /// A stored boundary slot (non-chain dataflow).
    Boundary(usize),
}

struct CompiledRes {
    slot: usize,
    /// resolved projection: (exec, bias); the projection weight is read
    /// from the owning plan's step at dispatch
    proj: Option<(Arc<Exec>, Tensor)>,
}

enum CompiledPost {
    Attention(Arc<Exec>),
    Upsample(Arc<Exec>),
}

/// One lowered step.  Weight-scale operand tensors (merged conv weight,
/// time-bias MLP, attention projections) are NOT duplicated here — the
/// dispatch loop reads them from the plan step at the same index, which
/// the `CompiledPlan`'s `Arc<Plan>` keeps alive.
struct CompiledStep {
    src: InputSrc,
    concat_slot: Option<usize>,
    conv: Arc<Exec>,
    /// bias materialized once at lowering (was rebuilt per dispatch)
    bias: Tensor,
    /// Fused format: the conv executable consumes the residual directly.
    fuse_res: bool,
    gn: Option<(Arc<Exec>, Tensor, Tensor)>,
    res: Option<CompiledRes>,
    /// Eager residual add; `None` with `res` set means host-side add.
    add: Option<Arc<Exec>>,
    act: Option<Arc<Exec>>,
    stash_to: Option<usize>,
    post: Vec<CompiledPost>,
    /// store the step output into this boundary slot (a later step reads it)
    store_slot: Option<usize>,
    /// boundary slots whose last reader is this step — freed afterwards
    release: Vec<usize>,
}

/// A `Plan` lowered against a runtime + manifest: straight-line dispatch
/// over pre-resolved executables and pre-materialized operands.
///
/// Owns its plan (`Arc<Plan>`), so it is `'static` and `Send + Sync` —
/// a deployed network can be shared across worker threads (see
/// [`crate::serve::Session`]).  Create with [`CompiledPlan::lower`] or
/// [`crate::serve::Engine::lower`].
pub struct CompiledPlan {
    plan: Arc<Plan>,
    pub fmt: Format,
    task: Task,
    batch: usize,
    steps: Vec<CompiledStep>,
    /// classifier head: (exec, bias); weight read from the plan
    head: Option<(Arc<Exec>, Tensor)>,
    input_dims: Option<[usize; 4]>,
    /// slot for the network input, when some step's residual reads it
    input_slot: Option<usize>,
    n_slots: usize,
    n_stash: usize,
}

fn run_one(
    exec: &Exec,
    args: &[&Tensor],
    timing: &mut Option<&mut f64>,
) -> Result<Tensor> {
    let t0 = Instant::now();
    let out = exec.run(args)?;
    if let Some(ms) = timing.as_deref_mut() {
        *ms += t0.elapsed().as_secs_f64() * 1e3;
    }
    Ok(out.into_iter().next().unwrap())
}

/// A boundary value flowing through the dispatch loop: either the
/// caller's input tensor (borrowed — never copied unless mutated) or a
/// refcounted intermediate.  Cloning is a pointer copy either way.
#[derive(Clone)]
enum Val<'a> {
    X(&'a Tensor),
    T(Arc<Tensor>),
}

impl<'a> Val<'a> {
    fn as_ref(&self) -> &Tensor {
        match self {
            Val::X(x) => x,
            Val::T(a) => a,
        }
    }

    /// Mutable access, copy-on-write: borrowed input and shared
    /// intermediates are cloned only at this point.
    fn make_mut(&mut self) -> &mut Tensor {
        if let Val::X(x) = *self {
            *self = Val::T(Arc::new(x.clone()));
        }
        match self {
            Val::T(a) => Arc::make_mut(a),
            Val::X(_) => unreachable!(),
        }
    }

    fn into_tensor(self) -> Tensor {
        match self {
            Val::X(x) => x.clone(),
            Val::T(a) => Arc::try_unwrap(a).unwrap_or_else(|a| (*a).clone()),
        }
    }
}

impl CompiledPlan {
    pub fn depth(&self) -> usize {
        self.steps.len()
    }

    /// The plan this compiled form was lowered from.
    pub fn plan(&self) -> &Arc<Plan> {
        &self.plan
    }

    /// Expected input tensor dims `[batch, h, w, c]` (None: empty plan).
    pub fn input_dims(&self) -> Option<[usize; 4]> {
        self.input_dims
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn task(&self) -> Task {
        self.task
    }

    /// Forward through the lowered network.
    pub fn forward(&self, x: &Tensor, t: Option<&Tensor>) -> Result<Tensor> {
        self.forward_inner(x, t, None)
    }

    /// Forward with per-dispatch timing accumulation (ms).
    pub fn forward_timed(&self, x: &Tensor, t: Option<&Tensor>) -> Result<(Tensor, f64)> {
        let mut ms = 0.0;
        let out = self.forward_inner(x, t, Some(&mut ms))?;
        Ok((out, ms))
    }

    fn forward_inner(
        &self,
        x: &Tensor,
        t: Option<&Tensor>,
        mut timing: Option<&mut f64>,
    ) -> Result<Tensor> {
        if let Some(d) = &self.input_dims {
            anyhow::ensure!(
                x.dims.as_slice() == &d[..],
                "input dims {:?} don't match the lowered plan ({:?})",
                x.dims,
                d
            );
        }
        let temb = match (t, &self.plan.temb) {
            (Some(tt), Some((w1, b1, dim))) => Some(temb_embed(w1, b1, *dim, tt)),
            _ => None,
        };
        let mut slots: Vec<Option<Val<'_>>> = vec![None; self.n_slots];
        let mut stash: Vec<Option<Val<'_>>> = vec![None; self.n_stash];
        let mut cur = Val::X(x);
        if let Some(s0) = self.input_slot {
            slots[s0] = Some(cur.clone());
        }
        let b = self.batch;

        // compiled steps are 1:1 with plan steps (lowering never skips);
        // the plan step carries the weight-scale operand tensors
        debug_assert_eq!(self.steps.len(), self.plan.steps.len());
        for (step, pstep) in self.steps.iter().zip(&self.plan.steps) {
            let mut input: Val<'_> = match step.src {
                InputSrc::Cur => cur.clone(),
                InputSrc::Boundary(s) => {
                    slots[s].clone().context("boundary not materialized")?
                }
            };
            // skip-concat (host; see DESIGN.md §4)
            if let Some(cs) = step.concat_slot {
                let other = stash[cs].as_ref().context("missing stash")?;
                input = Val::T(Arc::new(concat_channels(input.as_ref(), other.as_ref())));
            }
            // time-bias injection (host; 32-dim MLP output)
            if let Some((tw, tb)) = &pstep.time_bias {
                let temb = temb.as_ref().context("t required")?;
                let dim = tw.dims[0];
                let cin = tw.dims[1];
                let inp = input.make_mut();
                for n in 0..b {
                    let mut bias = vec![0.0f32; cin];
                    for o in 0..cin {
                        let mut acc = tb[o];
                        for i in 0..dim {
                            acc += temb[n * dim + i] * tw.data[i * cin + o];
                        }
                        bias[o] = acc;
                    }
                    let hw = inp.dims[1] * inp.dims[2];
                    for p in 0..hw {
                        for o in 0..cin {
                            let idx = (n * hw + p) * cin + o;
                            inp.data[idx] += bias[o];
                        }
                    }
                }
            }
            // resolve the residual input (shape = conv output shape);
            // the projection weight lives in the plan step
            let res_t: Option<Val<'_>> = match &step.res {
                Some(r) => {
                    let base = slots[r.slot]
                        .clone()
                        .context("res boundary not materialized")?;
                    let pproj = pstep.res.as_ref().and_then(|(_, p)| p.as_ref());
                    Some(match (&r.proj, pproj) {
                        (Some((exec, pb)), Some(p)) => Val::T(Arc::new(run_one(
                            exec,
                            &[base.as_ref(), &p.w, pb],
                            &mut timing,
                        )?)),
                        _ => base,
                    })
                }
                None => None,
            };

            let weight = &pstep.merged.weight;
            let mut y = match (&res_t, step.fuse_res) {
                (Some(r), true) => run_one(
                    &step.conv,
                    &[input.as_ref(), weight, &step.bias, r.as_ref()],
                    &mut timing,
                )?,
                _ => run_one(
                    &step.conv,
                    &[input.as_ref(), weight, &step.bias],
                    &mut timing,
                )?,
            };
            drop(input);
            if let Some((exec, scale, bias)) = &step.gn {
                y = run_one(exec, &[&y, scale, bias], &mut timing)?;
            }
            if !step.fuse_res {
                if let Some(r) = &res_t {
                    match &step.add {
                        Some(exec) => {
                            y = run_one(exec, &[&y, r.as_ref()], &mut timing)?
                        }
                        None => {
                            for (a, bb) in y.data.iter_mut().zip(&r.as_ref().data) {
                                *a += *bb;
                            }
                        }
                    }
                }
            }
            if let Some(exec) = &step.act {
                y = run_one(exec, &[&y], &mut timing)?;
            }
            cur = Val::T(Arc::new(y));
            if let Some(si) = step.stash_to {
                stash[si] = Some(cur.clone());
            }
            for (p, pp) in step.post.iter().zip(&pstep.post) {
                cur = Val::T(Arc::new(match (p, pp) {
                    (CompiledPost::Attention(exec), Post::Attention { wqkv, wout }) => {
                        run_one(exec, &[cur.as_ref(), wqkv, wout], &mut timing)?
                    }
                    (CompiledPost::Upsample(exec), _) => {
                        run_one(exec, &[cur.as_ref()], &mut timing)?
                    }
                    _ => unreachable!("compiled post order diverged from plan"),
                }));
            }
            if let Some(slot) = step.store_slot {
                slots[slot] = Some(cur.clone());
            }
            for &s in &step.release {
                slots[s] = None;
            }
        }

        // classifier head (weight from the plan, bias materialized)
        if let Some((exec, hb)) = &self.head {
            let (hw, _) = self
                .plan
                .head
                .as_ref()
                .context("compiled head without plan head")?;
            cur = Val::T(Arc::new(run_one(
                exec,
                &[cur.as_ref(), hw, hb],
                &mut timing,
            )?));
        }
        Ok(cur.into_tensor())
    }

    /// End-to-end latency with the App. C protocol.
    pub fn measure(&self, warmup: usize, iters: usize) -> Result<f64> {
        let dims = self
            .input_dims
            .context("cannot measure an empty plan (no steps)")?;
        let mut rng = crate::util::rng::Rng::new(0xbe9c);
        let n: usize = dims.iter().product();
        let x = Tensor::new(dims.to_vec(), (0..n).map(|_| rng.normal()).collect());
        let t = match self.task {
            Task::Diffusion => Some(Tensor::full(&[self.batch], 500.0)),
            Task::Classify => None,
        };
        for _ in 0..warmup {
            self.forward(&x, t.as_ref())?;
        }
        let mut times = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            self.forward(&x, t.as_ref())?;
            times.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Ok(times[times.len() / 2])
    }
}

/// Channel-dim concat of two NHWC tensors (host side).
pub fn concat_channels(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(&a.dims[..3], &b.dims[..3]);
    let (n, h, w, ca) = (a.dims[0], a.dims[1], a.dims[2], a.dims[3]);
    let cb = b.dims[3];
    let mut out = Tensor::zeros(&[n, h, w, ca + cb]);
    for i in 0..n * h * w {
        out.data[i * (ca + cb)..i * (ca + cb) + ca]
            .copy_from_slice(&a.data[i * ca..(i + 1) * ca]);
        out.data[i * (ca + cb) + ca..(i + 1) * (ca + cb)]
            .copy_from_slice(&b.data[i * cb..(i + 1) * cb]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compiled_plan_is_send_sync_and_static() {
        // the load-bearing property of the owning redesign: a deployed
        // network can cross thread boundaries (serve::Session workers)
        fn check<T: Send + Sync + 'static>() {}
        check::<CompiledPlan>();
        check::<Plan>();
    }

    #[test]
    fn concat_layout() {
        let a = Tensor::new(vec![1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(vec![1, 1, 2, 1], vec![9.0, 8.0]);
        let c = concat_channels(&a, &b);
        assert_eq!(c.dims, vec![1, 1, 2, 3]);
        assert_eq!(c.data, vec![1.0, 2.0, 9.0, 3.0, 4.0, 8.0]);
    }
}
