//! Merged-network executor — runs the *deployed* compressed model.
//!
//! After Algorithm 1 picks (A*, C*) and fine-tuning finishes, `Plan`
//! materializes the merged network: one `span_merge`d conv per span plus
//! the structural ops (residual adds whose branch wasn't folded, group
//! norm, attention, upsampling, skip-concat, classifier head, time-bias
//! injection).  Two execution formats mirror the paper's measurement
//! targets (DESIGN.md §2):
//!
//! * `Format::Eager` ("PyTorch format") — one PJRT dispatch per op:
//!   conv, then act, then add, each its own executable.
//! * `Format::Fused` ("TensorRT format") — conv+bias+act(+residual) as a
//!   single fused executable per merged layer (XLA fuses internally).
//!
//! Dispatch runs through [`CompiledPlan`], a one-time lowering of the
//! plan against a [`crate::runtime::Backend`]: every op is resolved once
//! (`Backend::lower_op`), every weight-scale operand — merged conv
//! weights, biases, group-norm affines, projection / attention / head
//! weights — is **uploaded once** as a persistent backend [`Value`], and
//! boundary activations flow between steps as backend-resident handles
//! released at their last use.  The steady-state loop performs **zero**
//! `Runtime` cache-mutex acquisitions, path hashes, or host<->device
//! round trips per step: data crosses the transfer boundary only at the
//! input upload, the genuine host points (skip-concat, time-bias
//! injection, the host-add fallback when an add artifact is missing) and
//! the final output download — counter-asserted by
//! `tests/host_backend.rs`.
//!
//! `CompiledPlan` **owns** its plan (`Arc<Plan>`) and backend: it has no
//! lifetime parameter, is `Send + Sync`, and can be handed to worker
//! threads.  Deployment goes through [`crate::serve::Engine::deploy`]
//! (worker-pool serving) or [`crate::serve::Engine::lower`] (a bare
//! compiled plan for hot loops); `CompiledPlan::lower` is the underlying
//! constructor.  With `Engine::host()` the same lowered plan executes on
//! the native host kernels — no artifacts, no XLA.
//!
//! The plan is also the ground truth for end-to-end latency measurements
//! (Tables 1-5) and for the merged-vs-pruned numerics report.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::ir::{Spec, Task};
use crate::kernels::{self, Act};
use crate::merge::{span_merge, MergedConv};
use crate::runtime::{Backend, LatencyStats, OpDesc, OpHandle, Value, WeightFormat};
use crate::util::par;
use crate::util::tensor::Tensor;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Format {
    Eager,
    Fused,
}

#[derive(Debug, Clone)]
pub struct ProjParams {
    pub w: Tensor,
    pub b: Vec<f32>,
    pub stride: usize,
}

#[derive(Debug, Clone)]
pub enum Post {
    Attention { wqkv: Tensor, wout: Tensor },
    Upsample,
}

#[derive(Debug, Clone)]
pub struct Step {
    pub i: usize,
    pub j: usize,
    pub merged: MergedConv,
    /// input feature-map geometry (after concat)
    pub h_in: usize,
    pub w_in: usize,
    pub cin: usize,
    /// activation applied at the boundary ("relu"/"swish"), if any
    pub act: Option<String>,
    /// group norm applied at the boundary: (scale, bias, groups)
    pub gn: Option<(Vec<f32>, Vec<f32>, usize)>,
    /// unfolded residual: (source boundary index, optional projection)
    pub res: Option<(usize, Option<ProjParams>)>,
    /// concat the stash tag onto the span input
    pub concat: Option<String>,
    /// time-bias injection at the span input: (w [tdim,cin], b [cin])
    pub time_bias: Option<(Tensor, Vec<f32>)>,
    pub stash_as: Option<String>,
    pub post: Vec<Post>,
}

pub struct Plan {
    pub spec_name: String,
    pub task: Task,
    pub batch: usize,
    pub steps: Vec<Step>,
    /// classifier head (w, b)
    pub head: Option<(Tensor, Vec<f32>)>,
    /// diffusion time embedding MLP (w1, b1) and dim
    pub temb: Option<(Tensor, Vec<f32>, usize)>,
    pub l_total: usize,
}

impl Plan {
    /// Plan for the ORIGINAL network: every layer its own span, all convs
    /// and activations kept.
    pub fn original(spec: &Spec, flat: &[f32]) -> Result<Plan> {
        let a: Vec<usize> = (1..spec.len()).collect(); // singleton spans: acts stay pristine
        let c: BTreeSet<usize> = (1..=spec.len()).collect();
        let spans: Vec<(usize, usize, usize)> =
            (1..=spec.len()).map(|j| (j - 1, j, spec.conv(j).k)).collect();
        Plan::from_solution(spec, flat, &a, &c, &spans)
    }

    /// Build the deployed network from a solution.
    ///
    /// `a` = kept interior boundaries; `c` = kept conv set (superset of R);
    /// `spans` = (i, j, k) from the solver (k recorded for bookkeeping).
    pub fn from_solution(
        spec: &Spec,
        flat: &[f32],
        a: &[usize],
        c: &BTreeSet<usize>,
        spans: &[(usize, usize, usize)],
    ) -> Result<Plan> {
        let a_set: BTreeSet<usize> = a.iter().copied().collect();
        let mut steps: Vec<Step> = Vec::new();
        // canonical boundary resolution: spans that reduce to an exact
        // identity (e.g. a layer dropped by LayerOnly) are elided — the
        // deployed network genuinely skips them.
        let mut canon: BTreeMap<usize, usize> = BTreeMap::new();
        canon.insert(0, 0);
        for &(i, j, _k) in spans {
            let kept: BTreeSet<usize> =
                ((i + 1)..=j).filter(|l| c.contains(l) || !spec.conv(*l).conv_gated).collect();
            let merged = span_merge(spec, flat, i, j, &kept);
            let first = spec.conv(i + 1);
            let cj = spec.conv(j);
            // boundary activation: pristine act, or — for multi-layer
            // merged spans ending at a pristine-linear position — the
            // App. A added activation (mirrors ir::solution_gates).
            let act = if !cj.act_gated {
                if cj.act == "none" { None } else { Some(cj.act.clone()) }
            } else if j == spec.len() || !a_set.contains(&j) {
                None // sigma_L = id / activation pruned by the solver
            } else if cj.act != "none" {
                Some(cj.act.clone())
            } else if j - i > 1 {
                Some("relu".to_string())
            } else {
                None
            };
            let gn = if cj.gn {
                Some((
                    spec.param_slice(flat, &format!("gn{j}.scale")).to_vec(),
                    spec.param_slice(flat, &format!("gn{j}.bias")).to_vec(),
                    cj.gn_groups,
                ))
            } else {
                None
            };
            // external residual: add point at j with source before span
            let res = match cj.add_from {
                Some(af) if af - 1 < i => {
                    let proj = cj.add_proj.as_ref().map(|p| ProjParams {
                        w: Tensor::new(
                            spec.param(&format!("proj{af}.w")).shape.clone(),
                            spec.param_slice(flat, &format!("proj{af}.w")).to_vec(),
                        ),
                        b: spec.param_slice(flat, &format!("proj{af}.b")).to_vec(),
                        stride: p.stride,
                    });
                    Some((af - 1, proj))
                }
                _ => None,
            };
            let time_bias = if first.time_bias {
                Some((
                    Tensor::new(
                        spec.param(&format!("temb{}.w", i + 1)).shape.clone(),
                        spec.param_slice(flat, &format!("temb{}.w", i + 1)).to_vec(),
                    ),
                    spec.param_slice(flat, &format!("temb{}.b", i + 1)).to_vec(),
                ))
            } else {
                None
            };
            let mut post = Vec::new();
            if cj.barrier_reason == "attention" {
                post.push(Post::Attention {
                    wqkv: Tensor::new(
                        spec.param("attn.qkv.w").shape.clone(),
                        spec.param_slice(flat, "attn.qkv.w").to_vec(),
                    ),
                    wout: Tensor::new(
                        spec.param("attn.out.w").shape.clone(),
                        spec.param_slice(flat, "attn.out.w").to_vec(),
                    ),
                });
            }
            if cj.barrier_reason == "upsample" {
                post.push(Post::Upsample);
            }
            // identity elision: dropped layer -> no dispatch at all
            let is_identity = merged.k == 1
                && merged.stride == 1
                && !merged.depthwise
                && act.is_none()
                && gn.is_none()
                && res.is_none()
                && first.concat_from.is_none()
                && time_bias.is_none()
                && cj.stash_as.is_none()
                && post.is_empty()
                && {
                    let d = crate::merge::dirac(first.cin, 1);
                    merged.weight.dims == d.dims
                        && merged.weight.max_abs_diff(&d) < 1e-7
                        && merged.bias.iter().all(|b| b.abs() < 1e-7)
                };
            let src = *canon.get(&i).unwrap_or(&i);
            if is_identity {
                canon.insert(j, src);
                continue;
            }
            canon.insert(j, j);
            steps.push(Step {
                i: src,
                j,
                merged,
                h_in: first.h_in,
                w_in: first.w_in,
                cin: first.cin,
                act,
                gn,
                res,
                concat: first.concat_from.clone(),
                time_bias,
                stash_as: cj.stash_as.clone(),
                post,
            });
        }
        // remap residual sources through the canonical boundary map
        for s in &mut steps {
            if let Some((src, _)) = &mut s.res {
                *src = *canon.get(src).unwrap_or(src);
            }
        }
        let head = match spec.task {
            Task::Classify => Some((
                Tensor::new(
                    spec.param("head.w").shape.clone(),
                    spec.param_slice(flat, "head.w").to_vec(),
                ),
                spec.param_slice(flat, "head.b").to_vec(),
            )),
            Task::Diffusion => None,
        };
        let temb = match spec.task {
            Task::Diffusion => Some((
                Tensor::new(
                    spec.param("temb.w1").shape.clone(),
                    spec.param_slice(flat, "temb.w1").to_vec(),
                ),
                spec.param_slice(flat, "temb.b1").to_vec(),
                spec.time_dim,
            )),
            Task::Classify => None,
        };
        Ok(Plan {
            spec_name: spec.name.clone(),
            task: spec.task,
            batch: spec.batch,
            steps,
            head,
            temb,
            l_total: spec.len(),
        })
    }

    pub fn depth(&self) -> usize {
        self.steps.len()
    }

    /// Does a forward through this plan require a timestep tensor?
    pub fn needs_time(&self) -> bool {
        self.task == Task::Diffusion
    }
}

/// Content-addressed upload cache: dedups identical weight operands
/// across plans lowered against the same backend.
///
/// The product shape of depth compression is one base model lowered into
/// a *ladder* of budget variants; merged spans that coincide across
/// budget points (and every untouched operand — group-norm affines,
/// projections, attention/head weights) are byte-identical, so a fleet
/// threads one `WeightCache` through [`CompiledPlan::lower_cached`] and
/// every repeated operand becomes an `Arc` refcount bump instead of a
/// fresh upload.  Keys are a 64-bit FNV-1a over (layout tag, dims, f32
/// bits): the layout tag separates plain uploads from `upload_weight`
/// packings (plain vs depthwise vs int8-quantized dense conv pack, per
/// the backend's [`WeightFormat`]), so two tensors with equal bytes but
/// different execution layouts never alias.
///
/// Byte accounting feeds `serve::fleet::FleetStats`:
/// [`WeightCache::unique_bytes`] is what the deduped fleet actually
/// holds, [`WeightCache::saved_bytes`] is what naive per-plan lowering
/// would have uploaded on top of that.
pub struct WeightCache {
    inner: Mutex<WeightCacheInner>,
}

#[derive(Default)]
struct WeightCacheInner {
    map: BTreeMap<u64, Value>,
    unique_bytes: usize,
    saved_bytes: usize,
}

impl WeightCache {
    pub fn new() -> WeightCache {
        WeightCache { inner: Mutex::new(WeightCacheInner::default()) }
    }

    /// FNV-1a-64 over layout tag + dims + raw f32 bits.
    fn key(tag: u8, t: &Tensor) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |byte: u8| {
            h ^= u64::from(byte);
            h = h.wrapping_mul(0x100_0000_01b3);
        };
        eat(tag);
        for &d in &t.dims {
            for b in (d as u64).to_le_bytes() {
                eat(b);
            }
        }
        for &v in &t.data {
            for b in v.to_bits().to_le_bytes() {
                eat(b);
            }
        }
        h
    }

    /// Upload `t` through `be` (via `upload_weight` when `desc` is given,
    /// plain `upload` otherwise), or return the cached [`Value`] clone if
    /// an identical operand was uploaded before.
    fn get_or_upload(
        &self,
        be: &dyn Backend,
        desc: Option<&OpDesc>,
        t: &Tensor,
    ) -> Result<Value> {
        let tag = match desc {
            None => 0u8,
            Some(OpDesc::Conv { depthwise, .. }) => {
                // dense convs lower per the backend's weight format;
                // depthwise stays f32 in every format (see upload_weight)
                if !*depthwise && be.weight_format() == WeightFormat::Int8 {
                    4
                } else {
                    1 + u8::from(*depthwise)
                }
            }
            Some(_) => 3,
        };
        let k = Self::key(tag, t);
        let bytes = t.data.len() * std::mem::size_of::<f32>();
        // lowering is a one-time cost; holding the lock across the upload
        // keeps hit/miss accounting exact under concurrent lowering
        let mut g = self.inner.lock().unwrap();
        if let Some(v) = g.map.get(&k) {
            g.saved_bytes += bytes;
            return Ok(v.clone());
        }
        let v = match desc {
            Some(d) => be.upload_weight(d, t)?,
            None => be.upload(t)?,
        };
        g.unique_bytes += bytes;
        g.map.insert(k, v.clone());
        Ok(v)
    }

    /// Bytes of distinct weight data actually uploaded through this cache.
    pub fn unique_bytes(&self) -> usize {
        self.inner.lock().unwrap().unique_bytes
    }

    /// Bytes a cache-less lowering would have uploaded again (dedup wins).
    pub fn saved_bytes(&self) -> usize {
        self.inner.lock().unwrap().saved_bytes
    }

    /// Distinct cached operands (diagnostics).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for WeightCache {
    fn default() -> Self {
        WeightCache::new()
    }
}

impl CompiledPlan {
    /// Lower a plan against a backend: resolve every op once
    /// (`Backend::lower_op`), upload every operand tensor once as a
    /// persistent backend [`Value`] (weights, biases, group-norm affines,
    /// projection / attention / head operands), and precompute the
    /// boundary-buffer lifetimes.  Conv and projection weights go through
    /// `Backend::upload_weight`, which on the host backend pre-packs them
    /// into their GEMM-ready layout — the steady-state forward never
    /// re-transposes a weight, and with the host arena it allocates no
    /// buffers at all from the second call on.  One-time cost; the
    /// returned `CompiledPlan` dispatches with no per-step resolution and
    /// no operand transfers, and keeps the plan alive through its `Arc`.
    /// Callers normally reach this through
    /// [`crate::serve::Engine::lower`] / [`crate::serve::Engine::deploy`].
    pub fn lower(
        plan: Arc<Plan>,
        backend: Arc<dyn Backend>,
        fmt: Format,
    ) -> Result<CompiledPlan> {
        CompiledPlan::lower_cached(plan, backend, fmt, None)
    }

    /// [`CompiledPlan::lower`] with an optional shared [`WeightCache`]:
    /// identical weight operands (same bytes, dims, and execution layout)
    /// resolve to `Arc` clones of the first upload instead of fresh
    /// backend buffers.  A fleet lowering a ladder of budget variants of
    /// one base model threads a single cache through every rung — merged
    /// spans that coincide across budget points share storage, and the
    /// cache's byte counters feed `FleetStats`.
    pub fn lower_cached(
        plan: Arc<Plan>,
        backend: Arc<dyn Backend>,
        fmt: Format,
        cache: Option<&WeightCache>,
    ) -> Result<CompiledPlan> {
        let b = plan.batch;
        let be = &*backend;
        // every operand upload funnels through these two, so a cache hit
        // is indistinguishable from a fresh upload to the rest of lowering
        let up = |t: &Tensor| -> Result<Value> {
            match cache {
                Some(c) => c.get_or_upload(be, None, t),
                None => be.upload(t),
            }
        };
        let upw = |desc: &OpDesc, t: &Tensor| -> Result<Value> {
            match cache {
                Some(c) => c.get_or_upload(be, Some(desc), t),
                None => be.upload_weight(desc, t),
            }
        };

        // Pass 1 — dataflow: which steps read their input from the running
        // buffer vs a stored boundary, which boundaries need a slot at
        // all, and where each slot's last read happens.
        let mut from_cur = Vec::with_capacity(plan.steps.len());
        let mut prev_j = 0usize;
        for step in &plan.steps {
            from_cur.push(step.i == prev_j);
            prev_j = step.j;
        }
        let mut slot_of: BTreeMap<usize, usize> = BTreeMap::new();
        let mut last_read: BTreeMap<usize, usize> = BTreeMap::new();
        for (s, step) in plan.steps.iter().enumerate() {
            if !from_cur[s] {
                slot_of.insert(step.i, 0);
                last_read.insert(step.i, s);
            }
            if let Some((src, _)) = &step.res {
                slot_of.insert(*src, 0);
                last_read.insert(*src, s);
            }
        }
        for (idx, slot) in slot_of.values_mut().enumerate() {
            *slot = idx;
        }

        // Pass 2 — shape propagation + artifact resolution.  Shapes are
        // derived exactly as the dispatch loop would observe them (SAME
        // convs divide by stride; upsample doubles), so every signature
        // matches what an eager forward would have requested.
        let mut shapes: BTreeMap<usize, (usize, usize, usize)> = BTreeMap::new();
        let input_dims = plan.steps.first().map(|f| [b, f.h_in, f.w_in, f.cin]);
        if let Some(f) = plan.steps.first() {
            anyhow::ensure!(
                f.concat.is_none(),
                "first step cannot read a stash (nothing stashed yet)"
            );
            shapes.insert(f.i, (f.h_in, f.w_in, f.cin));
        }
        let mut stash_of: BTreeMap<String, (usize, (usize, usize, usize))> = BTreeMap::new();
        let mut csteps: Vec<CompiledStep> = Vec::with_capacity(plan.steps.len());
        for (s, step) in plan.steps.iter().enumerate() {
            let (h, w, mut c) = *shapes
                .get(&step.i)
                .with_context(|| format!("boundary {} shape unknown", step.i))?;
            let concat_slot = match &step.concat {
                Some(tag) => {
                    let (slot, (hs, ws, cs)) = stash_of
                        .get(tag)
                        .with_context(|| format!("stash {tag} not materialized"))?
                        .clone();
                    anyhow::ensure!(
                        hs == h && ws == w,
                        "concat geometry mismatch at step {s}: {h}x{w} vs {hs}x{ws}"
                    );
                    c += cs;
                    Some(slot)
                }
                None => None,
            };
            let m = &step.merged;
            let co = m.bias.len();
            let act = match &step.act {
                Some(a) => Some(
                    Act::parse(a).with_context(|| format!("unknown activation {a}"))?,
                ),
                None => None,
            };
            // SAME padding: output spatial dims are ceil(in / stride)
            let (ho, wo) = (h.div_ceil(m.stride), w.div_ceil(m.stride));
            let res = match &step.res {
                Some((src, proj)) => {
                    let (hs, ws, cs) = *shapes
                        .get(src)
                        .with_context(|| format!("res boundary {src} shape unknown"))?;
                    let proj = match proj {
                        Some(p) => {
                            let desc = OpDesc::Conv {
                                b,
                                h: hs,
                                w: ws,
                                cin: cs,
                                cout: p.b.len(),
                                k: 1,
                                stride: p.stride,
                                depthwise: false,
                                act: None,
                                residual: false,
                            };
                            Some((
                                be.lower_op(&desc)
                                    .with_context(|| format!("proj op at step {s}"))?,
                                upw(&desc, &p.w)?,
                                up(&Tensor::new(vec![p.b.len()], p.b.clone()))?,
                            ))
                        }
                        None => None,
                    };
                    Some(CompiledRes { slot: slot_of[src], proj })
                }
                None => None,
            };
            // op order mirrors the gated graph: conv -> gn -> add -> act.
            // Fused format collapses conv(+add)(+act) into one dispatch
            // whenever no group norm sits in between.
            let can_fuse = fmt == Format::Fused && step.gn.is_none();
            let conv_desc = |fused_act: Option<Act>, residual: bool| OpDesc::Conv {
                b,
                h,
                w,
                cin: c,
                cout: co,
                k: m.k,
                stride: m.stride,
                depthwise: m.depthwise,
                act: fused_act,
                residual,
            };
            let (conv, fuse_res, gn, add, act_op) = if can_fuse {
                let conv = be
                    .lower_op(&conv_desc(act, res.is_some()))
                    .with_context(|| format!("fused conv op at step {s}"))?;
                (conv, res.is_some(), None, None, None)
            } else {
                let conv = be
                    .lower_op(&conv_desc(None, false))
                    .with_context(|| format!("conv op at step {s}"))?;
                let gn = match &step.gn {
                    Some((scale, bias, groups)) => Some((
                        be.lower_op(&OpDesc::GroupNorm {
                            b,
                            h: ho,
                            w: wo,
                            c: co,
                            groups: *groups,
                        })
                        .with_context(|| format!("gn op at step {s}"))?,
                        up(&Tensor::new(vec![scale.len()], scale.clone()))?,
                        up(&Tensor::new(vec![bias.len()], bias.clone()))?,
                    )),
                    None => None,
                };
                // a backend without an add op (missing AOT artifact)
                // falls back to a host-side add at dispatch; a *broken*
                // add op (supported but failing to lower) is a hard error
                let add_desc = OpDesc::Add { b, h: ho, w: wo, c: co };
                let add = match &res {
                    Some(_) if be.supports(&add_desc) => Some(
                        be.lower_op(&add_desc)
                            .with_context(|| format!("add op at step {s}"))?,
                    ),
                    _ => None,
                };
                let act_op = match act {
                    Some(a) => Some(
                        be.lower_op(&OpDesc::Activation {
                            act: a,
                            b,
                            h: ho,
                            w: wo,
                            c: co,
                        })
                        .with_context(|| format!("act op at step {s}"))?,
                    ),
                    None => None,
                };
                (conv, false, gn, add, act_op)
            };
            // stash captures the pre-post-op output; posts then reshape
            let (mut hc, mut wc, cc) = (ho, wo, co);
            let stash_to = step.stash_as.as_ref().map(|tag| {
                // re-stashing a tag overwrites in place (same slot), like
                // the eager path's HashMap insert did
                let slot = match stash_of.get(tag) {
                    Some((slot, _)) => *slot,
                    None => stash_of.len(),
                };
                stash_of.insert(tag.clone(), (slot, (hc, wc, cc)));
                slot
            });
            let mut post = Vec::new();
            for p in &step.post {
                match p {
                    Post::Attention { wqkv, wout } => {
                        post.push(CompiledPost::Attention(
                            be.lower_op(&OpDesc::Attention { b, h: hc, w: wc, c: cc })
                                .with_context(|| format!("attn op at step {s}"))?,
                            up(wqkv)?,
                            up(wout)?,
                        ));
                    }
                    Post::Upsample => {
                        post.push(CompiledPost::Upsample(
                            be.lower_op(&OpDesc::Upsample { b, h: hc, w: wc, c: cc })
                                .with_context(|| format!("up op at step {s}"))?,
                        ));
                        hc *= 2;
                        wc *= 2;
                    }
                }
            }
            shapes.insert(step.j, (hc, wc, cc));
            let release = last_read
                .iter()
                .filter(|&(_, &ls)| ls == s)
                .map(|(bid, _)| slot_of[bid])
                .collect();
            csteps.push(CompiledStep {
                src: if from_cur[s] {
                    InputSrc::Cur
                } else {
                    InputSrc::Boundary(slot_of[&step.i])
                },
                concat_slot,
                conv,
                // packed once into the backend's execution layout — the
                // forward never re-transposes a weight
                weight: upw(&conv_desc(None, false), &m.weight)?,
                bias: up(&Tensor::new(vec![co], m.bias.clone()))?,
                fuse_res,
                gn,
                res,
                add,
                act: act_op,
                time_bias: step.time_bias.clone(),
                stash_to,
                post,
                store_slot: slot_of.get(&step.j).copied(),
                release,
            });
        }
        let head = match &plan.head {
            Some((hw, hb)) => {
                let last = plan
                    .steps
                    .last()
                    .context("cannot lower a head over an empty plan")?;
                let (fh, fw, fc) = *shapes
                    .get(&last.j)
                    .context("final boundary shape unknown")?;
                anyhow::ensure!(
                    fc == hw.dims[0],
                    "head input channels {fc} vs head weight {:?}",
                    hw.dims
                );
                Some((
                    be.lower_op(&OpDesc::Head {
                        b,
                        h: fh,
                        w: fw,
                        hidden: fc,
                        classes: hb.len(),
                        model: plan.spec_name.clone(),
                    })
                    .context("head op")?,
                    up(hw)?,
                    up(&Tensor::new(vec![hb.len()], hb.clone()))?,
                ))
            }
            None => None,
        };
        let input_slot = plan.steps.first().and_then(|f| slot_of.get(&f.i).copied());
        let weight_format = backend.weight_format();
        Ok(CompiledPlan {
            fmt,
            task: plan.task,
            batch: b,
            steps: csteps,
            head,
            input_dims,
            input_slot,
            n_slots: slot_of.len(),
            n_stash: stash_of.len(),
            weight_format,
            backend,
            plan,
        })
    }
}

/// Sinusoidal + MLP time embedding (host side).  The dense layer runs on
/// [`kernels::gemm`]; only the sinusoid construction and the swish
/// epilogue stay scalar.
fn temb_embed(w1: &Tensor, b1: &[f32], dim: usize, t: &Tensor) -> Vec<f32> {
    let b = t.dims[0];
    let half = dim / 2;
    let mut emb = vec![0.0f32; b * dim];
    for n in 0..b {
        for i in 0..half {
            let freq = (-(10000.0f32.ln()) * i as f32 / half as f32).exp();
            let ang = t.data[n] * freq;
            emb[n * dim + i] = ang.sin();
            emb[n * dim + half + i] = ang.cos();
        }
    }
    // dense [b, dim] @ [dim, dim] + bias, then swish
    let mut out = vec![0.0f32; b * dim];
    kernels::gemm(b, dim, dim, &emb, &w1.data, &mut out);
    for row in out.chunks_mut(dim) {
        for (v, &bb) in row.iter_mut().zip(b1) {
            let acc = *v + bb;
            *v = acc / (1.0 + (-acc).exp());
        }
    }
    out
}

/// Per-sample time-bias injection at a span input: `bias = temb @ tw + tb`
/// (one GEMM), broadcast-added over every spatial position (parallel per
/// batch element).
fn inject_time_bias(inp: &mut Tensor, temb: &[f32], tw: &Tensor, tb: &[f32]) {
    let b = inp.dims[0];
    let dim = tw.dims[0];
    let cin = tw.dims[1];
    debug_assert_eq!(inp.dims[3], cin);
    let mut bias = vec![0.0f32; b * cin];
    kernels::gemm(b, dim, cin, temb, &tw.data, &mut bias);
    let hw = inp.dims[1] * inp.dims[2];
    let threads = par::auto_threads(inp.data.len());
    par::par_chunks_mut(&mut inp.data, hw * cin, threads, |n, chunk| {
        let brow = &bias[n * cin..(n + 1) * cin];
        for px in chunk.chunks_mut(cin) {
            for ((v, &bv), &tbv) in px.iter_mut().zip(brow).zip(tb) {
                *v += bv + tbv;
            }
        }
    });
}

/// Where a step reads its input from.
enum InputSrc {
    /// The running activation produced by the previous step.
    Cur,
    /// A stored boundary slot (non-chain dataflow).
    Boundary(usize),
}

struct CompiledRes {
    slot: usize,
    /// resolved projection: (op, uploaded weight, uploaded bias)
    proj: Option<(OpHandle, Value, Value)>,
}

enum CompiledPost {
    /// (op, uploaded wqkv, uploaded wout)
    Attention(OpHandle, Value, Value),
    Upsample(OpHandle),
}

/// One lowered step: backend-resolved ops plus every operand pre-uploaded
/// as a persistent backend [`Value`] — the dispatch loop never touches
/// the plan's host tensors except at the genuine host points.
struct CompiledStep {
    src: InputSrc,
    concat_slot: Option<usize>,
    conv: OpHandle,
    /// merged conv weight, uploaded once at lowering
    weight: Value,
    /// merged bias, uploaded once at lowering
    bias: Value,
    /// Fused format: the conv op consumes the residual directly.
    fuse_res: bool,
    gn: Option<(OpHandle, Value, Value)>,
    res: Option<CompiledRes>,
    /// Eager residual add; `None` with `res` set means host-side add
    /// (download both operands, add, re-upload — a counted host point).
    add: Option<OpHandle>,
    act: Option<OpHandle>,
    /// time-bias injection operands (host point; stays a host op)
    time_bias: Option<(Tensor, Vec<f32>)>,
    stash_to: Option<usize>,
    post: Vec<CompiledPost>,
    /// store the step output into this boundary slot (a later step reads it)
    store_slot: Option<usize>,
    /// boundary slots whose last reader is this step — freed afterwards
    release: Vec<usize>,
}

/// A `Plan` lowered against a [`Backend`]: straight-line dispatch over
/// pre-resolved ops and pre-uploaded operands, activations flowing as
/// backend-resident [`Value`]s.
///
/// Owns its plan (`Arc<Plan>`) and backend, so it is `'static` and
/// `Send + Sync` — a deployed network can be shared across worker threads
/// (see [`crate::serve::Session`]).  Create with [`CompiledPlan::lower`]
/// or [`crate::serve::Engine::lower`].
pub struct CompiledPlan {
    plan: Arc<Plan>,
    backend: Arc<dyn Backend>,
    pub fmt: Format,
    task: Task,
    batch: usize,
    steps: Vec<CompiledStep>,
    /// classifier head: (op, uploaded weight, uploaded bias)
    head: Option<(OpHandle, Value, Value)>,
    input_dims: Option<[usize; 4]>,
    /// slot for the network input, when some step's residual reads it
    input_slot: Option<usize>,
    n_slots: usize,
    n_stash: usize,
    /// The backend's weight format at lower time — recorded so serving
    /// stats / reports stay attributable even through backend decorators.
    weight_format: WeightFormat,
}

fn run_op(
    be: &dyn Backend,
    op: &OpHandle,
    args: &[&Value],
    timing: &mut Option<&mut f64>,
) -> Result<Value> {
    let t0 = Instant::now();
    let out = be.run(op, args)?;
    if let Some(ms) = timing.as_deref_mut() {
        *ms += t0.elapsed().as_secs_f64() * 1e3;
    }
    Ok(out)
}

impl CompiledPlan {
    pub fn depth(&self) -> usize {
        self.steps.len()
    }

    /// The plan this compiled form was lowered from.
    pub fn plan(&self) -> &Arc<Plan> {
        &self.plan
    }

    /// The backend this plan was lowered against (transfer counters live
    /// here — see `Backend::uploads` / `Backend::downloads`).
    pub fn backend(&self) -> &Arc<dyn Backend> {
        &self.backend
    }

    /// The weight format this plan's operands were lowered into.
    pub fn weight_format(&self) -> WeightFormat {
        self.weight_format
    }

    /// Expected input tensor dims `[batch, h, w, c]` (None: empty plan).
    pub fn input_dims(&self) -> Option<[usize; 4]> {
        self.input_dims
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn task(&self) -> Task {
        self.task
    }

    /// Forward through the lowered network.
    pub fn forward(&self, x: &Tensor, t: Option<&Tensor>) -> Result<Tensor> {
        self.forward_inner(x, t, None)
    }

    /// Forward with per-dispatch timing accumulation (ms).
    pub fn forward_timed(&self, x: &Tensor, t: Option<&Tensor>) -> Result<(Tensor, f64)> {
        let mut ms = 0.0;
        let out = self.forward_inner(x, t, Some(&mut ms))?;
        Ok((out, ms))
    }

    fn forward_inner(
        &self,
        x: &Tensor,
        t: Option<&Tensor>,
        mut timing: Option<&mut f64>,
    ) -> Result<Tensor> {
        if let Some(d) = &self.input_dims {
            anyhow::ensure!(
                x.dims.as_slice() == &d[..],
                "input dims {:?} don't match the lowered plan ({:?})",
                x.dims,
                d
            );
        }
        let temb = match (t, &self.plan.temb) {
            (Some(tt), Some((w1, b1, dim))) => Some(temb_embed(w1, b1, *dim, tt)),
            _ => None,
        };
        let be = &*self.backend;
        let mut slots: Vec<Option<Value>> = vec![None; self.n_slots];
        let mut stash: Vec<Option<Value>> = vec![None; self.n_stash];
        // the single steady-state upload: the network input
        let mut cur: Value = be.upload(x)?;
        if let Some(s0) = self.input_slot {
            slots[s0] = Some(cur.clone());
        }

        for step in &self.steps {
            let mut input: Value = match step.src {
                InputSrc::Cur => cur.clone(),
                InputSrc::Boundary(s) => {
                    slots[s].clone().context("boundary not materialized")?
                }
            };
            // skip-concat — genuine host point (see DESIGN.md §4): both
            // operands come down, the concat goes back up
            if let Some(cs) = step.concat_slot {
                let other = stash[cs].as_ref().context("missing stash")?;
                let joined =
                    concat_channels(&be.download(&input)?, &be.download(other)?);
                input = be.upload(&joined)?;
            }
            // time-bias injection — host point (per-sample GEMM + add)
            if let Some((tw, tb)) = &step.time_bias {
                let temb = temb.as_ref().context("t required")?;
                let mut inp = be.download(&input)?;
                inject_time_bias(&mut inp, temb, tw, tb);
                input = be.upload(&inp)?;
            }
            // resolve the residual input (shape = conv output shape)
            let res_v: Option<Value> = match &step.res {
                Some(r) => {
                    let base = slots[r.slot]
                        .clone()
                        .context("res boundary not materialized")?;
                    Some(match &r.proj {
                        Some((op, pw, pb)) => {
                            run_op(be, op, &[&base, pw, pb], &mut timing)?
                        }
                        None => base,
                    })
                }
                None => None,
            };

            let mut y = match (&res_v, step.fuse_res) {
                (Some(r), true) => run_op(
                    be,
                    &step.conv,
                    &[&input, &step.weight, &step.bias, r],
                    &mut timing,
                )?,
                _ => run_op(
                    be,
                    &step.conv,
                    &[&input, &step.weight, &step.bias],
                    &mut timing,
                )?,
            };
            drop(input);
            if let Some((op, scale, bias)) = &step.gn {
                y = run_op(be, op, &[&y, scale, bias], &mut timing)?;
            }
            if !step.fuse_res {
                if let Some(r) = &res_v {
                    match &step.add {
                        Some(op) => y = run_op(be, op, &[&y, r], &mut timing)?,
                        None => {
                            // host-add fallback (no add op on this
                            // backend) — a counted host point
                            let mut a = be.download(&y)?;
                            let rb = be.download(r)?;
                            for (av, bv) in a.data.iter_mut().zip(&rb.data) {
                                *av += *bv;
                            }
                            y = be.upload(&a)?;
                        }
                    }
                }
            }
            if let Some(op) = &step.act {
                y = run_op(be, op, &[&y], &mut timing)?;
            }
            cur = y;
            if let Some(si) = step.stash_to {
                stash[si] = Some(cur.clone());
            }
            for p in &step.post {
                cur = match p {
                    CompiledPost::Attention(op, wqkv, wout) => {
                        run_op(be, op, &[&cur, wqkv, wout], &mut timing)?
                    }
                    CompiledPost::Upsample(op) => {
                        run_op(be, op, &[&cur], &mut timing)?
                    }
                };
            }
            if let Some(slot) = step.store_slot {
                slots[slot] = Some(cur.clone());
            }
            for &s in &step.release {
                slots[s] = None;
            }
        }

        if let Some((op, hw, hb)) = &self.head {
            cur = run_op(be, op, &[&cur, hw, hb], &mut timing)?;
        }
        // the single steady-state download: the network output
        be.download(&cur)
    }

    /// End-to-end latency with the App. C protocol (shared
    /// [`crate::runtime::measure_protocol`] implementation).
    pub fn measure(&self, warmup: usize, iters: usize) -> Result<LatencyStats> {
        let dims = self
            .input_dims
            .context("cannot measure an empty plan (no steps)")?;
        let mut rng = crate::util::rng::Rng::new(0xbe9c);
        let n: usize = dims.iter().product();
        let x = Tensor::new(dims.to_vec(), (0..n).map(|_| rng.normal()).collect());
        let t = match self.task {
            Task::Diffusion => Some(Tensor::full(&[self.batch], 500.0)),
            Task::Classify => None,
        };
        crate::runtime::measure_protocol(warmup, iters, || {
            self.forward(&x, t.as_ref()).map(|_| ())
        })
    }
}

/// Channel-dim concat of two NHWC tensors (host side) — parallel
/// row-block copies via [`crate::util::par`].
pub fn concat_channels(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(&a.dims[..3], &b.dims[..3]);
    let (n, h, w, ca) = (a.dims[0], a.dims[1], a.dims[2], a.dims[3]);
    let cb = b.dims[3];
    let cc = ca + cb;
    let rows = n * h * w;
    let mut out = Tensor::zeros(&[n, h, w, cc]);
    let threads = par::auto_threads(out.data.len());
    let rows_per = rows.div_ceil(threads * 4).max(1);
    par::par_chunks_mut(&mut out.data, rows_per * cc, threads, |ci, chunk| {
        let r0 = ci * rows_per;
        for (i, px) in chunk.chunks_mut(cc).enumerate() {
            let r = r0 + i;
            px[..ca].copy_from_slice(&a.data[r * ca..(r + 1) * ca]);
            px[ca..].copy_from_slice(&b.data[r * cb..(r + 1) * cb]);
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compiled_plan_is_send_sync_and_static() {
        // the load-bearing property of the owning redesign: a deployed
        // network can cross thread boundaries (serve::Session workers)
        fn check<T: Send + Sync + 'static>() {}
        check::<CompiledPlan>();
        check::<Plan>();
    }

    #[test]
    fn weight_cache_key_separates_layouts_not_contents() {
        let a = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        // same bytes + dims + tag -> same key (the dedup hit)
        assert_eq!(WeightCache::key(0, &a), WeightCache::key(0, &b));
        // same bytes under a different execution layout must not alias
        assert_ne!(WeightCache::key(0, &a), WeightCache::key(1, &a));
        assert_ne!(WeightCache::key(1, &a), WeightCache::key(2, &a));
        // the int8 dense-conv layout is its own key space too
        assert_ne!(WeightCache::key(4, &a), WeightCache::key(1, &a));
        assert_ne!(WeightCache::key(4, &a), WeightCache::key(0, &a));
        // same bytes, different shape must not alias
        let c = Tensor::new(vec![3, 2], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_ne!(WeightCache::key(0, &a), WeightCache::key(0, &c));
        // different bytes must not alias
        let d = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 7.0]);
        assert_ne!(WeightCache::key(0, &a), WeightCache::key(0, &d));
    }

    #[test]
    fn concat_layout() {
        let a = Tensor::new(vec![1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(vec![1, 1, 2, 1], vec![9.0, 8.0]);
        let c = concat_channels(&a, &b);
        assert_eq!(c.dims, vec![1, 1, 2, 3]);
        assert_eq!(c.data, vec![1.0, 2.0, 9.0, 3.0, 4.0, 8.0]);
    }

    #[test]
    fn time_bias_gemm_matches_scalar_reference() {
        let mut rng = crate::util::rng::Rng::new(41);
        let (b, h, w, cin, dim) = (2usize, 3usize, 3usize, 5usize, 4usize);
        let tw = Tensor::new(
            vec![dim, cin],
            (0..dim * cin).map(|_| rng.normal()).collect(),
        );
        let tb: Vec<f32> = (0..cin).map(|_| rng.normal()).collect();
        let temb: Vec<f32> = (0..b * dim).map(|_| rng.normal()).collect();
        let x0 = Tensor::new(
            vec![b, h, w, cin],
            (0..b * h * w * cin).map(|_| rng.normal()).collect(),
        );
        // scalar reference (the pre-GEMM implementation)
        let mut want = x0.clone();
        for n in 0..b {
            for o in 0..cin {
                let mut acc = tb[o];
                for i in 0..dim {
                    acc += temb[n * dim + i] * tw.data[i * cin + o];
                }
                for p in 0..h * w {
                    want.data[(n * h * w + p) * cin + o] += acc;
                }
            }
        }
        let mut got = x0.clone();
        inject_time_bias(&mut got, &temb, &tw, &tb);
        assert!(got.max_abs_diff(&want) < 1e-4, "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn temb_embed_gemm_matches_scalar_reference() {
        let mut rng = crate::util::rng::Rng::new(42);
        let (b, dim) = (3usize, 8usize);
        let w1 = Tensor::new(
            vec![dim, dim],
            (0..dim * dim).map(|_| rng.normal()).collect(),
        );
        let b1: Vec<f32> = (0..dim).map(|_| rng.normal()).collect();
        let t = Tensor::new(vec![b], vec![0.0, 17.0, 500.0]);
        let got = temb_embed(&w1, &b1, dim, &t);
        // scalar reference
        let half = dim / 2;
        let mut emb = vec![0.0f32; b * dim];
        for n in 0..b {
            for i in 0..half {
                let freq = (-(10000.0f32.ln()) * i as f32 / half as f32).exp();
                let ang = t.data[n] * freq;
                emb[n * dim + i] = ang.sin();
                emb[n * dim + half + i] = ang.cos();
            }
        }
        for n in 0..b {
            for o in 0..dim {
                let mut acc = b1[o];
                for i in 0..dim {
                    acc += emb[n * dim + i] * w1.data[i * dim + o];
                }
                let want = acc / (1.0 + (-acc).exp());
                let diff = (got[n * dim + o] - want).abs();
                assert!(diff < 1e-4, "({n},{o}) diff {diff}");
            }
        }
    }
}
