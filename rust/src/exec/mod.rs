//! Merged-network executor — runs the *deployed* compressed model.
//!
//! After Algorithm 1 picks (A*, C*) and fine-tuning finishes, `Plan`
//! materializes the merged network: one `span_merge`d conv per span plus
//! the structural ops (residual adds whose branch wasn't folded, group
//! norm, attention, upsampling, skip-concat, classifier head, time-bias
//! injection).  Two execution formats mirror the paper's measurement
//! targets (DESIGN.md §2):
//!
//! * `Format::Eager` ("PyTorch format") — one PJRT dispatch per op:
//!   conv, then act, then add, each its own executable.
//! * `Format::Fused` ("TensorRT format") — conv+bias+act(+residual) as a
//!   single fused executable per merged layer (XLA fuses internally).
//!
//! The plan is also the ground truth for end-to-end latency measurements
//! (Tables 1-5) and for the merged-vs-pruned numerics report.

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::ir::{Spec, Task};
use crate::merge::{span_merge, MergedConv};
use crate::model::{sig_str, Manifest};
use crate::runtime::Runtime;
use crate::util::tensor::Tensor;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Format {
    Eager,
    Fused,
}

#[derive(Debug, Clone)]
pub struct ProjParams {
    pub w: Tensor,
    pub b: Vec<f32>,
    pub stride: usize,
}

#[derive(Debug, Clone)]
pub enum Post {
    Attention { wqkv: Tensor, wout: Tensor },
    Upsample,
}

#[derive(Debug, Clone)]
pub struct Step {
    pub i: usize,
    pub j: usize,
    pub merged: MergedConv,
    /// input feature-map geometry (after concat)
    pub h_in: usize,
    pub w_in: usize,
    pub cin: usize,
    /// activation applied at the boundary ("relu"/"swish"), if any
    pub act: Option<String>,
    /// group norm applied at the boundary: (scale, bias, groups)
    pub gn: Option<(Vec<f32>, Vec<f32>, usize)>,
    /// unfolded residual: (source boundary index, optional projection)
    pub res: Option<(usize, Option<ProjParams>)>,
    /// concat the stash tag onto the span input
    pub concat: Option<String>,
    /// time-bias injection at the span input: (w [tdim,cin], b [cin])
    pub time_bias: Option<(Tensor, Vec<f32>)>,
    pub stash_as: Option<String>,
    pub post: Vec<Post>,
}

pub struct Plan {
    pub spec_name: String,
    pub task: Task,
    pub batch: usize,
    pub steps: Vec<Step>,
    /// classifier head (w, b)
    pub head: Option<(Tensor, Vec<f32>)>,
    /// diffusion time embedding MLP (w1, b1) and dim
    pub temb: Option<(Tensor, Vec<f32>, usize)>,
    pub l_total: usize,
}

impl Plan {
    /// Plan for the ORIGINAL network: every layer its own span, all convs
    /// and activations kept.
    pub fn original(spec: &Spec, flat: &[f32]) -> Result<Plan> {
        let a: Vec<usize> = (1..spec.len()).collect(); // singleton spans: acts stay pristine
        let c: BTreeSet<usize> = (1..=spec.len()).collect();
        let spans: Vec<(usize, usize, usize)> =
            (1..=spec.len()).map(|j| (j - 1, j, spec.conv(j).k)).collect();
        Plan::from_solution(spec, flat, &a, &c, &spans)
    }

    /// Build the deployed network from a solution.
    ///
    /// `a` = kept interior boundaries; `c` = kept conv set (superset of R);
    /// `spans` = (i, j, k) from the solver (k recorded for bookkeeping).
    pub fn from_solution(
        spec: &Spec,
        flat: &[f32],
        a: &[usize],
        c: &BTreeSet<usize>,
        spans: &[(usize, usize, usize)],
    ) -> Result<Plan> {
        let a_set: BTreeSet<usize> = a.iter().copied().collect();
        let mut steps: Vec<Step> = Vec::new();
        // canonical boundary resolution: spans that reduce to an exact
        // identity (e.g. a layer dropped by LayerOnly) are elided — the
        // deployed network genuinely skips them.
        let mut canon: BTreeMap<usize, usize> = BTreeMap::new();
        canon.insert(0, 0);
        for &(i, j, _k) in spans {
            let kept: BTreeSet<usize> =
                ((i + 1)..=j).filter(|l| c.contains(l) || !spec.conv(*l).conv_gated).collect();
            let merged = span_merge(spec, flat, i, j, &kept);
            let first = spec.conv(i + 1);
            let cj = spec.conv(j);
            // boundary activation: pristine act, or — for multi-layer
            // merged spans ending at a pristine-linear position — the
            // App. A added activation (mirrors ir::solution_gates).
            let act = if !cj.act_gated {
                if cj.act == "none" { None } else { Some(cj.act.clone()) }
            } else if j == spec.len() || !a_set.contains(&j) {
                None // sigma_L = id / activation pruned by the solver
            } else if cj.act != "none" {
                Some(cj.act.clone())
            } else if j - i > 1 {
                Some("relu".to_string())
            } else {
                None
            };
            let gn = if cj.gn {
                Some((
                    spec.param_slice(flat, &format!("gn{j}.scale")).to_vec(),
                    spec.param_slice(flat, &format!("gn{j}.bias")).to_vec(),
                    cj.gn_groups,
                ))
            } else {
                None
            };
            // external residual: add point at j with source before span
            let res = match cj.add_from {
                Some(af) if af - 1 < i => {
                    let proj = cj.add_proj.as_ref().map(|p| ProjParams {
                        w: Tensor::new(
                            spec.param(&format!("proj{af}.w")).shape.clone(),
                            spec.param_slice(flat, &format!("proj{af}.w")).to_vec(),
                        ),
                        b: spec.param_slice(flat, &format!("proj{af}.b")).to_vec(),
                        stride: p.stride,
                    });
                    Some((af - 1, proj))
                }
                _ => None,
            };
            let time_bias = if first.time_bias {
                Some((
                    Tensor::new(
                        spec.param(&format!("temb{}.w", i + 1)).shape.clone(),
                        spec.param_slice(flat, &format!("temb{}.w", i + 1)).to_vec(),
                    ),
                    spec.param_slice(flat, &format!("temb{}.b", i + 1)).to_vec(),
                ))
            } else {
                None
            };
            let mut post = Vec::new();
            if cj.barrier_reason == "attention" {
                post.push(Post::Attention {
                    wqkv: Tensor::new(
                        spec.param("attn.qkv.w").shape.clone(),
                        spec.param_slice(flat, "attn.qkv.w").to_vec(),
                    ),
                    wout: Tensor::new(
                        spec.param("attn.out.w").shape.clone(),
                        spec.param_slice(flat, "attn.out.w").to_vec(),
                    ),
                });
            }
            if cj.barrier_reason == "upsample" {
                post.push(Post::Upsample);
            }
            // identity elision: dropped layer -> no dispatch at all
            let is_identity = merged.k == 1
                && merged.stride == 1
                && !merged.depthwise
                && act.is_none()
                && gn.is_none()
                && res.is_none()
                && first.concat_from.is_none()
                && time_bias.is_none()
                && cj.stash_as.is_none()
                && post.is_empty()
                && {
                    let d = crate::merge::dirac(first.cin, 1);
                    merged.weight.dims == d.dims
                        && merged.weight.max_abs_diff(&d) < 1e-7
                        && merged.bias.iter().all(|b| b.abs() < 1e-7)
                };
            let src = *canon.get(&i).unwrap_or(&i);
            if is_identity {
                canon.insert(j, src);
                continue;
            }
            canon.insert(j, j);
            steps.push(Step {
                i: src,
                j,
                merged,
                h_in: first.h_in,
                w_in: first.w_in,
                cin: first.cin,
                act,
                gn,
                res,
                concat: first.concat_from.clone(),
                time_bias,
                stash_as: cj.stash_as.clone(),
                post,
            });
        }
        // remap residual sources through the canonical boundary map
        for s in &mut steps {
            if let Some((src, _)) = &mut s.res {
                *src = *canon.get(src).unwrap_or(src);
            }
        }
        let head = match spec.task {
            Task::Classify => Some((
                Tensor::new(
                    spec.param("head.w").shape.clone(),
                    spec.param_slice(flat, "head.w").to_vec(),
                ),
                spec.param_slice(flat, "head.b").to_vec(),
            )),
            Task::Diffusion => None,
        };
        let temb = match spec.task {
            Task::Diffusion => Some((
                Tensor::new(
                    spec.param("temb.w1").shape.clone(),
                    spec.param_slice(flat, "temb.w1").to_vec(),
                ),
                spec.param_slice(flat, "temb.b1").to_vec(),
                spec.time_dim,
            )),
            Task::Classify => None,
        };
        Ok(Plan {
            spec_name: spec.name.clone(),
            task: spec.task,
            batch: spec.batch,
            steps,
            head,
            temb,
            l_total: spec.len(),
        })
    }

    pub fn depth(&self) -> usize {
        self.steps.len()
    }

    /// Sinusoidal + MLP time embedding (host side; 32-dim — negligible).
    fn temb_vec(&self, t: &Tensor) -> Vec<f32> {
        let (w1, b1, dim) = self.temb.as_ref().expect("diffusion only");
        let b = t.dims[0];
        let half = dim / 2;
        let mut emb = vec![0.0f32; b * dim];
        for n in 0..b {
            for i in 0..half {
                let freq = (-(10000.0f32.ln()) * i as f32 / half as f32).exp();
                let ang = t.data[n] * freq;
                emb[n * dim + i] = ang.sin();
                emb[n * dim + half + i] = ang.cos();
            }
        }
        // dense + swish
        let mut out = vec![0.0f32; b * dim];
        for n in 0..b {
            for o in 0..*dim {
                let mut acc = b1[o];
                for i in 0..*dim {
                    acc += emb[n * dim + i] * w1.data[i * dim + o];
                }
                out[n * dim + o] = acc / (1.0 + (-acc).exp());
            }
        }
        out
    }

    /// Forward through the merged network.
    pub fn forward(
        &self,
        rt: &Runtime,
        man: &Manifest,
        x: &Tensor,
        t: Option<&Tensor>,
        fmt: Format,
    ) -> Result<Tensor> {
        self.forward_inner(rt, man, x, t, fmt, None)
    }

    /// Forward with per-dispatch timing accumulation (ms).
    pub fn forward_timed(
        &self,
        rt: &Runtime,
        man: &Manifest,
        x: &Tensor,
        t: Option<&Tensor>,
        fmt: Format,
    ) -> Result<(Tensor, f64)> {
        let mut ms = 0.0;
        let out = self.forward_inner(rt, man, x, t, fmt, Some(&mut ms))?;
        Ok((out, ms))
    }

    fn forward_inner(
        &self,
        rt: &Runtime,
        man: &Manifest,
        x: &Tensor,
        t: Option<&Tensor>,
        fmt: Format,
        mut timing: Option<&mut f64>,
    ) -> Result<Tensor> {
        let temb = t.map(|tt| self.temb_vec(tt));
        let mut boundaries: BTreeMap<usize, Tensor> = BTreeMap::new();
        boundaries.insert(0, x.clone());
        let mut stash: HashMap<String, Tensor> = HashMap::new();
        let b = self.batch;

        let run = |rel: &str, args: &[&Tensor], timing: &mut Option<&mut f64>|
         -> Result<Tensor> {
            let exec = rt.load(rel)?;
            let t0 = Instant::now();
            let out = exec.run(args)?;
            if let Some(ms) = timing.as_deref_mut() {
                *ms += t0.elapsed().as_secs_f64() * 1e3;
            }
            Ok(out.into_iter().next().unwrap())
        };

        let mut cur = x.clone();
        for step in &self.steps {
            let mut input = boundaries
                .get(&step.i)
                .cloned()
                .with_context(|| format!("boundary {} not materialized", step.i))?;
            // skip-concat (host; see DESIGN.md §4)
            if let Some(tag) = &step.concat {
                let other = stash.get(tag).context("missing stash")?;
                input = concat_channels(&input, other);
            }
            // time-bias injection (host; 32-dim MLP output)
            if let Some((tw, tb)) = &step.time_bias {
                let temb = temb.as_ref().context("t required")?;
                let dim = tw.dims[0];
                let cin = tw.dims[1];
                for n in 0..b {
                    let mut bias = vec![0.0f32; cin];
                    for o in 0..cin {
                        let mut acc = tb[o];
                        for i in 0..dim {
                            acc += temb[n * dim + i] * tw.data[i * cin + o];
                        }
                        bias[o] = acc;
                    }
                    let hw = input.dims[1] * input.dims[2];
                    for p in 0..hw {
                        for o in 0..cin {
                            let idx = (n * hw + p) * cin + o;
                            input.data[idx] += bias[o];
                        }
                    }
                }
            }
            let m = &step.merged;
            let sig = sig_str(
                b, input.dims[1], input.dims[2], input.dims[3], m.bias.len(),
                m.k, m.stride, m.depthwise,
            );
            let wt = &m.weight;
            let bt = Tensor::new(vec![m.bias.len()], m.bias.clone());
            // resolve the residual input (shape = conv output shape)
            let res_t: Option<Tensor> = match &step.res {
                Some((src, proj)) => {
                    let base = boundaries
                        .get(src)
                        .cloned()
                        .with_context(|| format!("res boundary {src}"))?;
                    Some(match proj {
                        Some(p) => {
                            let psig = sig_str(
                                b, base.dims[1], base.dims[2], base.dims[3],
                                p.b.len(), 1, p.stride, false,
                            );
                            let rel = man
                                .conv_art(&psig, "plain")
                                .with_context(|| format!("proj artifact {psig}"))?;
                            let pb = Tensor::new(vec![p.b.len()], p.b.clone());
                            run(&rel, &[&base, &p.w, &pb], &mut timing)?
                        }
                        None => base,
                    })
                }
                None => None,
            };

            // op order mirrors the gated graph: conv -> gn -> add -> act.
            // Fused format collapses conv(+add)(+act) into one dispatch
            // whenever no group norm sits in between.
            let can_fuse = fmt == Format::Fused && step.gn.is_none();
            cur = if can_fuse {
                let variant = match (&step.act, &res_t) {
                    (Some(a), Some(_)) => format!("far_{a}"),
                    (Some(a), None) => format!("fa_{a}"),
                    (None, Some(_)) => "far_none".to_string(),
                    (None, None) => "plain".to_string(),
                };
                let rel = man
                    .conv_art(&sig, &variant)
                    .with_context(|| format!("conv artifact {sig}.{variant}"))?;
                match &res_t {
                    Some(r) => run(&rel, &[&input, wt, &bt, r], &mut timing)?,
                    None => run(&rel, &[&input, wt, &bt], &mut timing)?,
                }
            } else {
                let rel = man
                    .conv_art(&sig, "plain")
                    .with_context(|| format!("conv artifact {sig}"))?;
                let mut y = run(&rel, &[&input, wt, &bt], &mut timing)?;
                if let Some((scale, bias, groups)) = &step.gn {
                    let base = format!(
                        "b{}h{}w{}c{}", b, y.dims[1], y.dims[2], y.dims[3]
                    );
                    let gnrel = man
                        .ew_art(&format!("gn{groups}_{base}"))
                        .with_context(|| format!("gn artifact gn{groups}_{base}"))?;
                    let st = Tensor::new(vec![scale.len()], scale.clone());
                    let bt2 = Tensor::new(vec![bias.len()], bias.clone());
                    y = run(&gnrel, &[&y, &st, &bt2], &mut timing)?;
                }
                if let Some(r) = &res_t {
                    let base = format!(
                        "b{}h{}w{}c{}", b, y.dims[1], y.dims[2], y.dims[3]
                    );
                    if let Some(addrel) = man.ew_art(&format!("add_{base}")) {
                        y = run(&addrel, &[&y, r], &mut timing)?;
                    } else {
                        for (a, bb) in y.data.iter_mut().zip(&r.data) {
                            *a += *bb;
                        }
                    }
                }
                if let Some(a) = &step.act {
                    let base = format!(
                        "b{}h{}w{}c{}", b, y.dims[1], y.dims[2], y.dims[3]
                    );
                    let rel = man
                        .ew_art(&format!("{a}_{base}"))
                        .with_context(|| format!("act artifact {a}_{base}"))?;
                    y = run(&rel, &[&y], &mut timing)?;
                }
                y
            };
            if let Some(tag) = &step.stash_as {
                stash.insert(tag.clone(), cur.clone());
            }
            for p in &step.post {
                let base =
                    format!("b{}h{}w{}c{}", b, cur.dims[1], cur.dims[2], cur.dims[3]);
                match p {
                    Post::Attention { wqkv, wout } => {
                        let rel = man
                            .ew_art(&format!("attn_{base}"))
                            .context("attn artifact")?;
                        cur = run(&rel, &[&cur, wqkv, wout], &mut timing)?;
                    }
                    Post::Upsample => {
                        let rel =
                            man.ew_art(&format!("up_{base}")).context("up artifact")?;
                        cur = run(&rel, &[&cur], &mut timing)?;
                    }
                }
            }
            boundaries.insert(step.j, cur.clone());
        }

        // classifier head
        if let Some((hw, hb)) = &self.head {
            let rel = man
                .ew_art(&format!("head_{}", self.spec_name))
                .context("head artifact")?;
            let hbt = Tensor::new(vec![hb.len()], hb.clone());
            cur = run(&rel, &[&cur, hw, &hbt], &mut timing)?;
        }
        Ok(cur)
    }

    /// End-to-end latency with the App. C protocol.
    pub fn measure(
        &self,
        rt: &Runtime,
        man: &Manifest,
        fmt: Format,
        warmup: usize,
        iters: usize,
    ) -> Result<f64> {
        let mut rng = crate::util::rng::Rng::new(0xbe9c);
        let first = &self.steps[0];
        let n = self.batch * first.h_in * first.w_in * first.cin;
        let x = Tensor::new(
            vec![self.batch, first.h_in, first.w_in, first.cin],
            (0..n).map(|_| rng.normal()).collect(),
        );
        let t = match self.task {
            Task::Diffusion => Some(Tensor::full(&[self.batch], 500.0)),
            Task::Classify => None,
        };
        for _ in 0..warmup {
            self.forward(rt, man, &x, t.as_ref(), fmt)?;
        }
        let mut times = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            self.forward(rt, man, &x, t.as_ref(), fmt)?;
            times.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Ok(times[times.len() / 2])
    }
}

/// Channel-dim concat of two NHWC tensors (host side).
pub fn concat_channels(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(&a.dims[..3], &b.dims[..3]);
    let (n, h, w, ca) = (a.dims[0], a.dims[1], a.dims[2], a.dims[3]);
    let cb = b.dims[3];
    let mut out = Tensor::zeros(&[n, h, w, ca + cb]);
    for i in 0..n * h * w {
        out.data[i * (ca + cb)..i * (ca + cb) + ca]
            .copy_from_slice(&a.data[i * ca..(i + 1) * ca]);
        out.data[i * (ca + cb) + ca..(i + 1) * (ca + cb)]
            .copy_from_slice(&b.data[i * cb..(i + 1) * cb]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concat_layout() {
        let a = Tensor::new(vec![1, 1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::new(vec![1, 1, 2, 1], vec![9.0, 8.0]);
        let c = concat_channels(&a, &b);
        assert_eq!(c.dims, vec![1, 1, 2, 3]);
        assert_eq!(c.data, vec![1.0, 2.0, 9.0, 3.0, 4.0, 8.0]);
    }
}
