//! `profile` — backend-generic latency measurement for the table layer.
//!
//! LayerMerge's tables are built from *measured* per-span latencies
//! (Sec. 3.2 / App. C), but the measurement path used to assume a PJRT
//! artifact inventory: `tables::build` looked conv signatures up in the
//! manifest and loaded AOT executables by hand.  This module replaces
//! that with measurement through the [`crate::runtime::Backend`] trait:
//!
//! * a **conv signature** is measured by lowering a minimal single-step
//!   [`CompiledPlan`] through the backend and timing it with the same
//!   warm-up/percentile protocol every other latency number uses
//!   ([`crate::runtime::measure_protocol`]).  On the PJRT backend the
//!   plan lowering resolves the same `plain` conv artifact the old path
//!   loaded manually; on [`crate::runtime::HostBackend`] it dispatches
//!   the native kernels — so `LatencyMode::Measured` now works with no
//!   XLA and no artifacts at all.
//! * a **fixed (non-conv) op** — head, residual add, group norm,
//!   attention, upsample — cannot be a plan step, so it is measured by
//!   lowering its [`OpDesc`] directly and running it under
//!   `measure_protocol`.  A backend that does not support the op (e.g.
//!   a manifest that never emitted the artifact) contributes zero,
//!   matching the old skip-on-missing-artifact behaviour.
//!
//! `LatencyMode::Analytical` short-circuits to the roofline model
//! ([`crate::tables::analytical_conv_ms`]) for fast mode / CI.

use std::sync::Arc;

use anyhow::Result;

use crate::exec::{CompiledPlan, Format, Plan, Step};
use crate::ir::{Spec, Task};
use crate::merge::MergedConv;
use crate::runtime::{measure_protocol, Backend, LatencyStats, OpDesc, Value};
use crate::tables::{analytical_conv_ms, BuildCfg, LatencyMode};
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

/// Measures `(spec, span)` latencies against any [`Backend`].
pub struct Profiler {
    backend: Arc<dyn Backend>,
    pub mode: LatencyMode,
    pub warmup: usize,
    pub iters: usize,
}

impl Profiler {
    pub fn new(
        backend: Arc<dyn Backend>,
        mode: LatencyMode,
        warmup: usize,
        iters: usize,
    ) -> Profiler {
        Profiler { backend, mode, warmup, iters: iters.max(1) }
    }

    /// A profiler following the table builder's measurement protocol.
    pub fn from_cfg(backend: Arc<dyn Backend>, cfg: &BuildCfg) -> Profiler {
        Profiler::new(backend, cfg.mode, cfg.warmup, cfg.iters)
    }

    pub fn backend(&self) -> &Arc<dyn Backend> {
        &self.backend
    }

    /// Measure (or model) one conv signature's latency in ms.
    pub fn conv_ms(
        &self,
        b: usize,
        h: usize,
        w: usize,
        ci: usize,
        co: usize,
        k: usize,
        s: usize,
        dw: bool,
    ) -> Result<f64> {
        if self.mode == LatencyMode::Analytical {
            return Ok(analytical_conv_ms(b, h, w, ci, co, k, s, dw));
        }
        let cp = self.lower_conv(b, h, w, ci, co, k, s, dw)?;
        Ok(cp.measure(self.warmup, self.iters)?.p50_ms)
    }

    /// Latency of span (i, j] realized at kernel size `k` — the merged
    /// conv module's signature, exactly as the table builder derives it.
    pub fn measure_span(&self, sp: &Spec, i: usize, j: usize, k: usize) -> Result<f64> {
        let first = sp.conv(i + 1);
        self.conv_ms(
            sp.batch,
            first.h_in,
            first.w_in,
            first.cin,
            sp.conv(j).cout,
            k,
            sp.span_stride(i, j),
            sp.span_depthwise(i, j),
        )
    }

    /// Latency of original layer `idx` (1-based) on its own.
    pub fn layer_ms(&self, sp: &Spec, idx: usize) -> Result<f64> {
        let c = sp.conv(idx);
        self.conv_ms(sp.batch, c.h_in, c.w_in, c.cin, c.cout, c.k, c.stride, c.depthwise)
    }

    /// End-to-end latency of a full deployed plan under the profiler's
    /// protocol — the "actual" side of predicted-vs-actual comparisons.
    pub fn measure_plan(&self, plan: Arc<Plan>, fmt: Format) -> Result<LatencyStats> {
        CompiledPlan::lower(plan, Arc::clone(&self.backend), fmt)?
            .measure(self.warmup, self.iters)
    }

    /// Fixed (non-conv) latency of a model: head / attention / upsample /
    /// group-norm / residual-add ops, summed once (sum approximation,
    /// Sec. 3.2).
    pub fn fixed_ms(&self, sp: &Spec) -> Result<f64> {
        let b = sp.batch;
        if self.mode == LatencyMode::Analytical {
            // ops are bandwidth-bound elementwise kernels
            let mut ms = 0.0;
            for c in &sp.convs {
                let bytes = 4.0 * (b * c.h_out() * c.w_out() * c.cout) as f64;
                if c.add_from.is_some() {
                    ms += bytes * 2.0 / 25.0e9 * 1e3 + 0.02;
                }
                if c.gn {
                    ms += bytes * 2.0 / 25.0e9 * 1e3 + 0.02;
                }
                if c.barrier_reason == "attention" || c.barrier_reason == "upsample" {
                    ms += bytes * 3.0 / 25.0e9 * 1e3 + 0.05;
                }
            }
            return Ok(ms + 0.05);
        }
        let mut ms = 0.0;
        let mut rng = Rng::new(0xf1);
        // classifier head
        if sp.num_classes > 0 {
            let last = sp.convs.last().unwrap();
            let desc = OpDesc::Head {
                b,
                h: last.h_out(),
                w: last.w_out(),
                hidden: sp.head_hidden,
                classes: sp.num_classes,
                model: sp.name.clone(),
            };
            let x = rand_tensor(&mut rng, &[b, last.h_out(), last.w_out(), sp.head_hidden]);
            let w = rand_tensor(&mut rng, &[sp.head_hidden, sp.num_classes]);
            let bias = rand_tensor(&mut rng, &[sp.num_classes]);
            ms += self.op_ms(&desc, &[&x, &w, &bias])?;
        }
        for c in &sp.convs {
            let shape = [b, c.h_out(), c.w_out(), c.cout];
            if c.add_from.is_some() {
                let desc = OpDesc::Add { b, h: c.h_out(), w: c.w_out(), c: c.cout };
                let x = rand_tensor(&mut rng, &shape);
                let y = rand_tensor(&mut rng, &shape);
                ms += self.op_ms(&desc, &[&x, &y])?;
            }
            if c.gn {
                let desc = OpDesc::GroupNorm {
                    b,
                    h: c.h_out(),
                    w: c.w_out(),
                    c: c.cout,
                    groups: c.gn_groups,
                };
                let x = rand_tensor(&mut rng, &shape);
                let s1 = rand_tensor(&mut rng, &[c.cout]);
                let s2 = rand_tensor(&mut rng, &[c.cout]);
                ms += self.op_ms(&desc, &[&x, &s1, &s2])?;
            }
            if c.barrier_reason == "attention" {
                let desc = OpDesc::Attention { b, h: c.h_out(), w: c.w_out(), c: c.cout };
                let x = rand_tensor(&mut rng, &shape);
                let q = rand_tensor(&mut rng, &[c.cout, 3 * c.cout]);
                let o = rand_tensor(&mut rng, &[c.cout, c.cout]);
                ms += self.op_ms(&desc, &[&x, &q, &o])?;
            }
            if c.barrier_reason == "upsample" {
                let desc = OpDesc::Upsample { b, h: c.h_out(), w: c.w_out(), c: c.cout };
                let x = rand_tensor(&mut rng, &shape);
                ms += self.op_ms(&desc, &[&x])?;
            }
        }
        Ok(ms)
    }

    /// Lower one conv signature as a minimal single-step plan.  Eager
    /// format with no boundary activation lowers to the `plain` conv
    /// module — the op the Eager deployment actually dispatches, which
    /// is what the old artifact path measured.
    fn lower_conv(
        &self,
        b: usize,
        h: usize,
        w: usize,
        ci: usize,
        co: usize,
        k: usize,
        s: usize,
        dw: bool,
    ) -> Result<CompiledPlan> {
        let mut rng = Rng::new(0x1a7e ^ (k as u64) << 8 ^ ci as u64);
        let weight = rand_tensor(&mut rng, &[co, if dw { 1 } else { ci }, k, k]);
        let bias: Vec<f32> = (0..co).map(|_| rng.normal()).collect();
        let step = Step {
            i: 0,
            j: 1,
            merged: MergedConv { i: 0, j: 1, weight, bias, k, stride: s, depthwise: dw },
            h_in: h,
            w_in: w,
            cin: ci,
            act: None,
            gn: None,
            res: None,
            concat: None,
            time_bias: None,
            stash_as: None,
            post: vec![],
        };
        let plan = Plan {
            spec_name: format!("profile-b{b}h{h}w{w}c{ci}x{co}k{k}s{s}{}", if dw { "dw" } else { "" }),
            task: Task::Classify,
            batch: b,
            steps: vec![step],
            head: None,
            temb: None,
            l_total: 1,
        };
        CompiledPlan::lower(Arc::new(plan), Arc::clone(&self.backend), Format::Eager)
    }

    /// Measure one lowered op under the shared protocol; an unsupported
    /// op contributes zero (parity with the old missing-artifact skip).
    fn op_ms(&self, desc: &OpDesc, args: &[&Tensor]) -> Result<f64> {
        if !self.backend.supports(desc) {
            return Ok(0.0);
        }
        let op = self.backend.lower_op(desc)?;
        let vals: Vec<Value> =
            args.iter().map(|t| self.backend.upload(t)).collect::<Result<Vec<_>>>()?;
        let refs: Vec<&Value> = vals.iter().collect();
        let stats = measure_protocol(self.warmup, self.iters, || {
            self.backend.run(&op, &refs).map(|_| ())
        })?;
        Ok(stats.p50_ms)
    }
}

fn rand_tensor(rng: &mut Rng, dims: &[usize]) -> Tensor {
    let n: usize = dims.iter().product();
    Tensor::new(dims.to_vec(), (0..n).map(|_| rng.normal()).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::HostBackend;

    fn host_prof(mode: LatencyMode) -> Profiler {
        Profiler::new(Arc::new(HostBackend::new()), mode, 1, 3)
    }

    #[test]
    fn analytical_mode_needs_no_dispatch() {
        let p = host_prof(LatencyMode::Analytical);
        let ms = p.conv_ms(2, 8, 8, 4, 4, 3, 1, false).unwrap();
        assert!((ms - analytical_conv_ms(2, 8, 8, 4, 4, 3, 1, false)).abs() < 1e-12);
        assert_eq!(p.backend().uploads(), 0, "analytical mode must not touch the backend");
    }

    #[test]
    fn measured_conv_on_host_is_positive() {
        let p = host_prof(LatencyMode::Measured);
        let ms = p.conv_ms(1, 4, 4, 3, 3, 3, 1, false).unwrap();
        assert!(ms > 0.0, "measured conv latency must be positive, got {ms}");
    }

    #[test]
    fn measured_span_matches_spec_signature() {
        let sp = crate::ir::tests::toy_spec();
        let p = host_prof(LatencyMode::Measured);
        // span (1, 3]: starts at conv2's input geometry
        let ms = p.measure_span(&sp, 1, 3, 5).unwrap();
        assert!(ms > 0.0);
    }

    #[test]
    fn fixed_ms_on_host_counts_head_and_adds() {
        let sp = crate::ir::tests::toy_spec();
        let p = host_prof(LatencyMode::Measured);
        let ms = p.fixed_ms(&sp).unwrap();
        // toy_spec has a classifier head and a residual add: both measured
        assert!(ms > 0.0, "fixed ops must contribute latency, got {ms}");
    }
}
