//! In-tree micro-benchmark harness (criterion substitute; DESIGN.md §2).
//!
//! `cargo bench` targets under `rust/benches/` use `harness = false` and
//! drive this module.  Each paper table/figure also has a renderer here so
//! `layermerge tableN` and the bench targets print identical rows.

use std::time::Instant;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub min_ms: f64,
}

impl BenchStats {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>8} iters  mean {:>9.4}ms  p50 {:>9.4}ms  p95 {:>9.4}ms  min {:>9.4}ms",
            self.name, self.iters, self.mean_ms, self.p50_ms, self.p95_ms, self.min_ms
        )
    }
}

/// Sort the raw per-iteration timings and summarize — shared by both
/// bench flavours; quantiles go through the crate-wide nearest-rank
/// [`crate::util::stats::percentile`], like every other latency number.
fn summarize(name: &str, mut times: Vec<f64>) -> BenchStats {
    crate::util::stats::sort_samples(&mut times);
    BenchStats {
        name: name.to_string(),
        iters: times.len(),
        mean_ms: times.iter().sum::<f64>() / times.len() as f64,
        p50_ms: crate::util::stats::percentile(&times, 0.5),
        p95_ms: crate::util::stats::percentile(&times, 0.95),
        min_ms: times[0],
    }
}

fn timed_iters<F: FnMut()>(iters: usize, f: &mut F) -> Vec<f64> {
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64() * 1e3);
    }
    times
}

/// True when `BENCH_SMOKE=1`: bench targets run tiny shapes/iteration
/// budgets and skip the `BENCH_merge.json` write — the fast
/// compile-and-run gate `scripts/ci.sh` uses so bench code can't rot
/// between perf PRs.  Real perf records come from `scripts/bench.sh`
/// without the variable.
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").is_ok_and(|v| v == "1")
}

/// Time `f` with warm-up; iteration count adapts to hit ~`budget_ms` of
/// total measurement time (criterion-ish behaviour without the crate).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, budget_ms: f64, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    // estimate per-iter cost
    let t0 = Instant::now();
    f();
    let per = t0.elapsed().as_secs_f64() * 1e3;
    let iters = ((budget_ms / per.max(1e-6)) as usize).clamp(5, 2000);
    summarize(name, timed_iters(iters, &mut f))
}

/// Time `f` for exactly `iters` iterations — for costly baselines (e.g.
/// the naive merge oracle at ResNet scale) where the adaptive budget of
/// [`bench`] would run for minutes.
pub fn bench_iters<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    summarize(name, timed_iters(iters.max(1), &mut f))
}

/// Render a paper-style table to stdout and return it as markdown lines.
pub struct TableOut {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TableOut {
    pub fn new(title: &str, header: &[&str]) -> TableOut {
        TableOut {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn markdown(&self) -> String {
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.header.iter().map(|_| "---|").collect::<String>()
        ));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.markdown());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let s = bench("noop-ish", 2, 5.0, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.iters >= 5);
        assert!(s.p50_ms >= 0.0 && s.mean_ms >= s.min_ms);
    }

    #[test]
    fn table_markdown_shape() {
        let mut t = TableOut::new("Table X", &["Network", "Acc", "Speed-up"]);
        t.row(vec!["net".into(), "0.9".into(), "1.5x".into()]);
        let md = t.markdown();
        assert!(md.contains("| Network | Acc | Speed-up |"));
        assert!(md.contains("| net | 0.9 | 1.5x |"));
    }
}
