//! In-tree micro-benchmark harness (criterion substitute; DESIGN.md §2).
//!
//! `cargo bench` targets under `rust/benches/` use `harness = false` and
//! drive this module.  Each paper table/figure also has a renderer here so
//! `layermerge tableN` and the bench targets print identical rows.

use std::time::Instant;

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub min_ms: f64,
}

impl BenchStats {
    pub fn row(&self) -> String {
        format!(
            "{:<44} {:>8} iters  mean {:>9.4}ms  p50 {:>9.4}ms  p95 {:>9.4}ms  min {:>9.4}ms",
            self.name, self.iters, self.mean_ms, self.p50_ms, self.p95_ms, self.min_ms
        )
    }
}

/// Sort the raw per-iteration timings and summarize — shared by both
/// bench flavours; quantiles go through the crate-wide nearest-rank
/// [`crate::util::stats::percentile`], like every other latency number.
fn summarize(name: &str, mut times: Vec<f64>) -> BenchStats {
    crate::util::stats::sort_samples(&mut times);
    BenchStats {
        name: name.to_string(),
        iters: times.len(),
        mean_ms: times.iter().sum::<f64>() / times.len() as f64,
        p50_ms: crate::util::stats::percentile(&times, 0.5),
        p95_ms: crate::util::stats::percentile(&times, 0.95),
        min_ms: times[0],
    }
}

fn timed_iters<F: FnMut()>(iters: usize, f: &mut F) -> Vec<f64> {
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64() * 1e3);
    }
    times
}

/// True when `BENCH_SMOKE=1`: bench targets run tiny shapes/iteration
/// budgets and skip the `BENCH_merge.json` write — the fast
/// compile-and-run gate `scripts/ci.sh` uses so bench code can't rot
/// between perf PRs.  Real perf records come from `scripts/bench.sh`
/// without the variable.
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").is_ok_and(|v| v == "1")
}

/// Time `f` with warm-up; iteration count adapts to hit ~`budget_ms` of
/// total measurement time (criterion-ish behaviour without the crate).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, budget_ms: f64, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    // estimate per-iter cost
    let t0 = Instant::now();
    f();
    let per = t0.elapsed().as_secs_f64() * 1e3;
    let iters = ((budget_ms / per.max(1e-6)) as usize).clamp(5, 2000);
    summarize(name, timed_iters(iters, &mut f))
}

/// Time `f` for exactly `iters` iterations — for costly baselines (e.g.
/// the naive merge oracle at ResNet scale) where the adaptive budget of
/// [`bench`] would run for minutes.
pub fn bench_iters<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    summarize(name, timed_iters(iters.max(1), &mut f))
}

/// One bench result as a `BENCH_merge.json` row (the v1 schema's
/// `{name, iters, mean_ms, p50_ms, p95_ms, min_ms}` shape).
pub fn stats_json(s: &BenchStats) -> Json {
    Json::obj(vec![
        ("name", Json::str(&s.name)),
        ("iters", Json::num(s.iters as f64)),
        ("mean_ms", Json::num(s.mean_ms)),
        ("p50_ms", Json::num(s.p50_ms)),
        ("p95_ms", Json::num(s.p95_ms)),
        ("min_ms", Json::num(s.min_ms)),
    ])
}

/// Where the shared perf record lives: `BENCH_merge.json` at the repo
/// root, overridable with `BENCH_OUT` (tests point it at a scratch file).
pub fn record_path() -> String {
    std::env::var("BENCH_OUT")
        .unwrap_or_else(|_| format!("{}/../BENCH_merge.json", env!("CARGO_MANIFEST_DIR")))
}

/// Read-modify-write the shared `BENCH_merge.json` perf record (schema
/// `layermerge.bench.merge.v1`).
///
/// Every bench target *owns* a set of row-name prefixes (`own_rows`) and
/// derived-key prefixes (`own_keys`): rows and keys from the previous
/// record that match an owned prefix are replaced by this run's `rows` /
/// `derived`, everything else is preserved verbatim — so the benches can
/// be re-run in any order without clobbering each other, and a new bench
/// target's keys survive without the older benches listing them.
pub fn record(
    own_rows: &[&str],
    own_keys: &[&str],
    rows: Vec<Json>,
    derived: Vec<(String, Json)>,
) -> anyhow::Result<()> {
    let path = record_path();
    let mut all_rows: Vec<Json> = Vec::new();
    let mut all_derived: Vec<(String, Json)> = Vec::new();
    if let Ok(text) = std::fs::read_to_string(&path) {
        if let Ok(prev) = Json::parse(&text) {
            if let Some(prev_rows) = prev.get("rows").and_then(|r| r.as_arr()) {
                for r in prev_rows {
                    let name = r.get("name").and_then(|n| n.as_str()).unwrap_or("");
                    if !own_rows.iter().any(|p| name.starts_with(p)) {
                        all_rows.push(r.clone());
                    }
                }
            }
            if let Some(prev_d) = prev.get("derived").and_then(|d| d.as_obj()) {
                for (k, v) in prev_d {
                    if !own_keys.iter().any(|p| k.starts_with(p)) {
                        all_derived.push((k.clone(), v.clone()));
                    }
                }
            }
        }
    }
    all_rows.extend(rows);
    all_derived.extend(derived);
    let out = Json::obj(vec![
        ("schema", Json::str("layermerge.bench.merge.v1")),
        ("rows", Json::Arr(all_rows)),
        (
            "derived",
            Json::obj(
                all_derived.iter().map(|(k, v)| (k.as_str(), v.clone())).collect(),
            ),
        ),
    ]);
    std::fs::write(&path, out.to_string())?;
    println!("wrote {path}");
    Ok(())
}

/// Render a paper-style table to stdout and return it as markdown lines.
pub struct TableOut {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TableOut {
    pub fn new(title: &str, header: &[&str]) -> TableOut {
        TableOut {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn markdown(&self) -> String {
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&format!("| {} |\n", self.header.join(" | ")));
        out.push_str(&format!(
            "|{}\n",
            self.header.iter().map(|_| "---|").collect::<String>()
        ));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.markdown());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let s = bench("noop-ish", 2, 5.0, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.iters >= 5);
        assert!(s.p50_ms >= 0.0 && s.mean_ms >= s.min_ms);
    }

    #[test]
    fn record_preserves_unowned_and_replaces_owned() {
        let path = std::env::temp_dir().join(format!(
            "lm_bench_record_{}.json",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        std::env::set_var("BENCH_OUT", &path);
        // someone else's run: a "serve x" row + serving_* key
        record(
            &["serve "],
            &["serving_"],
            vec![Json::obj(vec![("name", Json::str("serve x")), ("p50_ms", Json::num(1.0))])],
            vec![("serving_tps".into(), Json::num(9.0))],
        )
        .unwrap();
        // our run owns solver rows/keys; the serving record must survive
        record(
            &["solver "],
            &["solver_", "twostage_"],
            vec![Json::obj(vec![("name", Json::str("solver dp")), ("p50_ms", Json::num(2.0))])],
            vec![("twostage_vs_dp_obj_ratio".into(), Json::num(1.0))],
        )
        .unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(j.get("schema").and_then(|s| s.as_str()), Some("layermerge.bench.merge.v1"));
        let names: Vec<&str> = j
            .get("rows")
            .and_then(|r| r.as_arr())
            .unwrap()
            .iter()
            .filter_map(|r| r.get("name").and_then(|n| n.as_str()))
            .collect();
        assert!(names.contains(&"serve x") && names.contains(&"solver dp"), "{names:?}");
        let d = j.get("derived").unwrap();
        assert!(d.get("serving_tps").is_some());
        assert!(d.get("twostage_vs_dp_obj_ratio").is_some());
        // re-running the owner replaces, never duplicates
        record(&["solver "], &["solver_", "twostage_"], vec![], vec![]).unwrap();
        let j2 = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let rows2 = j2.get("rows").and_then(|r| r.as_arr()).unwrap();
        assert_eq!(rows2.len(), 1, "solver row dropped, serve row kept");
        std::env::remove_var("BENCH_OUT");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn table_markdown_shape() {
        let mut t = TableOut::new("Table X", &["Network", "Acc", "Speed-up"]);
        t.row(vec!["net".into(), "0.9".into(), "1.5x".into()]);
        let md = t.markdown();
        assert!(md.contains("| Network | Acc | Speed-up |"));
        assert!(md.contains("| net | 0.9 | 1.5x |"));
    }
}
