//! Runtime-detected SIMD inner kernels for the packed micro-kernel GEMM.
//!
//! The panel layout of [`super::PackedB`] (NR-wide, k-major, zero-padded)
//! was designed for exactly this: the full-tile inner loop is MR
//! broadcast-FMA sweeps over two (AVX2) or four (NEON) vector registers
//! per row, and the zero padding means edge *columns* never need masked
//! loads — only the final store is clipped to the real width.
//!
//! ISA selection happens **once per process** ([`isa`]), not per call:
//! `is_x86_feature_detected!` reads cpuid behind a cache but still costs
//! a branch + call on the hot path, and the selected ISA must be stable
//! anyway so measured latency tables stay attributable to one kernel
//! config (the `Tables` fingerprint mixes [`Isa::tag`] in).  Setting
//! `LM_FORCE_SCALAR=1` before first use pins the dispatcher to the scalar
//! reference kernel — the troubleshooting escape hatch when a SIMD result
//! looks wrong on some exotic core.
//!
//! The forced-ISA entry points (`gemm_packed_epi_isa` in the parent
//! module) exist so parity tests and the `packed_gemm_simd_speedup` bench
//! can run scalar and vector kernels against each other inside one
//! process; [`available_isas`] reports what the host can actually run
//! (ignoring `LM_FORCE_SCALAR`, which only changes the *default*).

use std::sync::OnceLock;

/// Instruction set the packed-GEMM inner kernels dispatch on.  `Scalar`
/// is the portable register-blocked loop (`gemm_packed_rows`), always
/// available and kept bit-identical as the reference fallback.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Isa {
    Scalar,
    Avx2,
    Neon,
}

impl Isa {
    /// Stable lowercase spelling, used in `profile` / `e2e` / `/stats`
    /// output and in bench record attribution.
    pub fn name(&self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Small stable integer for fingerprint mixing (`tables::`): a cached
    /// measured table must not survive a kernel-config change.
    pub fn tag(&self) -> u64 {
        match self {
            Isa::Scalar => 0,
            Isa::Avx2 => 1,
            Isa::Neon => 2,
        }
    }
}

static ISA: OnceLock<Isa> = OnceLock::new();

/// The process-wide kernel ISA, detected once on first use: AVX2+FMA on
/// x86-64, NEON on aarch64, scalar otherwise — or scalar unconditionally
/// when `LM_FORCE_SCALAR=1`.
pub fn isa() -> Isa {
    *ISA.get_or_init(|| {
        if std::env::var("LM_FORCE_SCALAR").as_deref() == Ok("1") {
            return Isa::Scalar;
        }
        best_hw_isa()
    })
}

/// Every ISA this host can execute, scalar first.  Hardware capability
/// only — `LM_FORCE_SCALAR` does not shrink this list, so parity suites
/// exercise the vector kernels even in a scalar-pinned CI run.
pub fn available_isas() -> Vec<Isa> {
    let mut v = vec![Isa::Scalar];
    if best_hw_isa() != Isa::Scalar {
        v.push(best_hw_isa());
    }
    v
}

fn best_hw_isa() -> Isa {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return Isa::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return Isa::Neon;
        }
    }
    Isa::Scalar
}

/// AVX2+FMA inner kernels.  Layout contract is identical to the scalar
/// `gemm_packed_rows`: NR = 16 columns per panel = two `__m256`, MR = 4
/// rows of C accumulated in 8 ymm registers per full tile.
#[cfg(target_arch = "x86_64")]
pub(super) mod x86 {
    use super::super::{GEMM_MR, GEMM_NR};
    use std::arch::x86_64::*;

    /// f32 micro-kernel sweep for C rows `[r0, r0 + rows)` (`c_chunk`),
    /// accumulating (`+=`) like the scalar kernel.  Full tiles keep 4x16
    /// accumulators in registers; edge rows (< MR) run a 2-register
    /// per-row sweep over the same zero-padded panel; ragged panel tails
    /// spill to a stack tile and clip the store to `nw`.
    ///
    /// # Safety
    /// Caller must have verified AVX2 and FMA support (`Isa::Avx2` is
    /// only ever produced by runtime detection).
    #[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn gemm_rows_f32(
        r0: usize,
        rows: usize,
        k: usize,
        n: usize,
        a: &[f32],
        bdata: &[f32],
        c_chunk: &mut [f32],
    ) {
        let np = n.div_ceil(GEMM_NR);
        let mut i0 = 0;
        while i0 < rows {
            let mr = GEMM_MR.min(rows - i0);
            for p in 0..np {
                let j0 = p * GEMM_NR;
                let nw = GEMM_NR.min(n - j0);
                let panel = &bdata[p * k * GEMM_NR..(p + 1) * k * GEMM_NR];
                if mr == GEMM_MR {
                    let mut acc = [[_mm256_setzero_ps(); 2]; GEMM_MR];
                    for kk in 0..k {
                        let b0 = _mm256_loadu_ps(panel.as_ptr().add(kk * GEMM_NR));
                        let b1 = _mm256_loadu_ps(panel.as_ptr().add(kk * GEMM_NR + 8));
                        for i in 0..GEMM_MR {
                            let av = _mm256_set1_ps(*a.get_unchecked((r0 + i0 + i) * k + kk));
                            acc[i][0] = _mm256_fmadd_ps(av, b0, acc[i][0]);
                            acc[i][1] = _mm256_fmadd_ps(av, b1, acc[i][1]);
                        }
                    }
                    if nw == GEMM_NR {
                        for i in 0..GEMM_MR {
                            let cp = c_chunk.as_mut_ptr().add((i0 + i) * n + j0);
                            _mm256_storeu_ps(cp, _mm256_add_ps(_mm256_loadu_ps(cp), acc[i][0]));
                            let cp8 = cp.add(8);
                            _mm256_storeu_ps(cp8, _mm256_add_ps(_mm256_loadu_ps(cp8), acc[i][1]));
                        }
                    } else {
                        let mut tmp = [0.0f32; GEMM_NR];
                        for i in 0..GEMM_MR {
                            _mm256_storeu_ps(tmp.as_mut_ptr(), acc[i][0]);
                            _mm256_storeu_ps(tmp.as_mut_ptr().add(8), acc[i][1]);
                            let crow = &mut c_chunk[(i0 + i) * n + j0..][..nw];
                            for (cv, &av) in crow.iter_mut().zip(&tmp[..nw]) {
                                *cv += av;
                            }
                        }
                    }
                } else {
                    for i in 0..mr {
                        let arow = &a[(r0 + i0 + i) * k..][..k];
                        let mut acc0 = _mm256_setzero_ps();
                        let mut acc1 = _mm256_setzero_ps();
                        for (kk, &av) in arow.iter().enumerate() {
                            let avv = _mm256_set1_ps(av);
                            let b0 = _mm256_loadu_ps(panel.as_ptr().add(kk * GEMM_NR));
                            let b1 = _mm256_loadu_ps(panel.as_ptr().add(kk * GEMM_NR + 8));
                            acc0 = _mm256_fmadd_ps(avv, b0, acc0);
                            acc1 = _mm256_fmadd_ps(avv, b1, acc1);
                        }
                        let mut tmp = [0.0f32; GEMM_NR];
                        _mm256_storeu_ps(tmp.as_mut_ptr(), acc0);
                        _mm256_storeu_ps(tmp.as_mut_ptr().add(8), acc1);
                        let crow = &mut c_chunk[(i0 + i) * n + j0..][..nw];
                        for (cv, &av) in crow.iter_mut().zip(&tmp[..nw]) {
                            *cv += av;
                        }
                    }
                }
            }
            i0 += mr;
        }
    }

    /// int8 micro-kernel sweep with i32 accumulation and dequantization
    /// fused into the tile store: `c[i][j] += acc_i32 * ascale[i] *
    /// bscale[j]`.  `aq` / `ascale` are chunk-local (row 0 = first row of
    /// `c_chunk`).
    ///
    /// Two k-steps per iteration: the two 16-wide i8 panel rows widen to
    /// i16 (`cvtepi8_epi16`) and interleave per 128-bit lane
    /// (`unpacklo/hi_epi16`), so one `madd_epi16` against a broadcast
    /// (a_k, a_k+1) i16 pair yields 8 i32 per-column dot-pair sums.  The
    /// lane interleave permutes columns: acc0 holds {0..3, 8..11}, acc1
    /// holds {4..7, 12..15}; the spill loop un-permutes.  |acc| grows by
    /// at most 2*127^2 per k-pair, so i32 is safe for any k the im2col
    /// path can produce (overflow needs k > 2^31 / 127^2 ≈ 133k).
    ///
    /// # Safety
    /// Caller must have verified AVX2 support.
    #[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm_rows_i8(
        rows: usize,
        k: usize,
        n: usize,
        aq: &[i8],
        ascale: &[f32],
        bdata: &[i8],
        bscale: &[f32],
        c_chunk: &mut [f32],
    ) {
        let np = n.div_ceil(GEMM_NR);
        let mut i0 = 0;
        while i0 < rows {
            let mr = GEMM_MR.min(rows - i0);
            for p in 0..np {
                let j0 = p * GEMM_NR;
                let nw = GEMM_NR.min(n - j0);
                let panel = &bdata[p * k * GEMM_NR..(p + 1) * k * GEMM_NR];
                let mut acc = [[_mm256_setzero_si256(); 2]; GEMM_MR];
                let mut kk = 0;
                while kk < k {
                    let b0 = _mm256_cvtepi8_epi16(_mm_loadu_si128(
                        panel.as_ptr().add(kk * GEMM_NR) as *const __m128i,
                    ));
                    let b1 = if kk + 1 < k {
                        _mm256_cvtepi8_epi16(_mm_loadu_si128(
                            panel.as_ptr().add((kk + 1) * GEMM_NR) as *const __m128i,
                        ))
                    } else {
                        _mm256_setzero_si256()
                    };
                    let lo = _mm256_unpacklo_epi16(b0, b1);
                    let hi = _mm256_unpackhi_epi16(b0, b1);
                    for i in 0..mr {
                        let a0 = *aq.get_unchecked((i0 + i) * k + kk) as i16 as u16 as u32;
                        let a1 = if kk + 1 < k {
                            *aq.get_unchecked((i0 + i) * k + kk + 1) as i16 as u16 as u32
                        } else {
                            0
                        };
                        let pair = _mm256_set1_epi32(((a1 << 16) | a0) as i32);
                        acc[i][0] = _mm256_add_epi32(acc[i][0], _mm256_madd_epi16(lo, pair));
                        acc[i][1] = _mm256_add_epi32(acc[i][1], _mm256_madd_epi16(hi, pair));
                    }
                    kk += 2;
                }
                for i in 0..mr {
                    let mut t0 = [0i32; 8];
                    let mut t1 = [0i32; 8];
                    _mm256_storeu_si256(t0.as_mut_ptr() as *mut __m256i, acc[i][0]);
                    _mm256_storeu_si256(t1.as_mut_ptr() as *mut __m256i, acc[i][1]);
                    let s = ascale[i0 + i];
                    let crow = &mut c_chunk[(i0 + i) * n + j0..][..nw];
                    for (j, cv) in crow.iter_mut().enumerate() {
                        // un-permute the unpack lane order (see above)
                        let v = match j {
                            0..=3 => t0[j],
                            4..=7 => t1[j - 4],
                            8..=11 => t0[j - 4],
                            _ => t1[j - 8],
                        };
                        *cv += v as f32 * s * bscale[j0 + j];
                    }
                }
            }
            i0 += mr;
        }
    }
}

/// NEON inner kernels (aarch64).  f32 only: the int8 path falls back to
/// the scalar i32-accumulating kernel on aarch64 — the f32 kernel is
/// where the panel layout pays off, and the scalar i8 loop is already
/// auto-vectorizable; a hand-written `vmlal_s8` kernel can land once it
/// can be benchmarked on real hardware.
#[cfg(target_arch = "aarch64")]
pub(super) mod arm {
    use super::super::{GEMM_MR, GEMM_NR};
    use std::arch::aarch64::*;

    /// f32 micro-kernel sweep, NEON: NR = 16 columns = four `float32x4_t`
    /// per row, MR = 4 rows in 16 q-register accumulators per full tile.
    /// Same accumulate / spill / clip contract as the AVX2 kernel.
    ///
    /// # Safety
    /// Caller must have verified NEON support.
    #[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
    #[target_feature(enable = "neon")]
    pub unsafe fn gemm_rows_f32(
        r0: usize,
        rows: usize,
        k: usize,
        n: usize,
        a: &[f32],
        bdata: &[f32],
        c_chunk: &mut [f32],
    ) {
        let np = n.div_ceil(GEMM_NR);
        let mut i0 = 0;
        while i0 < rows {
            let mr = GEMM_MR.min(rows - i0);
            for p in 0..np {
                let j0 = p * GEMM_NR;
                let nw = GEMM_NR.min(n - j0);
                let panel = &bdata[p * k * GEMM_NR..(p + 1) * k * GEMM_NR];
                if mr == GEMM_MR {
                    let mut acc = [[vdupq_n_f32(0.0); 4]; GEMM_MR];
                    for kk in 0..k {
                        let bp = panel.as_ptr().add(kk * GEMM_NR);
                        let b = [
                            vld1q_f32(bp),
                            vld1q_f32(bp.add(4)),
                            vld1q_f32(bp.add(8)),
                            vld1q_f32(bp.add(12)),
                        ];
                        for i in 0..GEMM_MR {
                            let av = vdupq_n_f32(*a.get_unchecked((r0 + i0 + i) * k + kk));
                            for q in 0..4 {
                                acc[i][q] = vfmaq_f32(acc[i][q], b[q], av);
                            }
                        }
                    }
                    if nw == GEMM_NR {
                        for i in 0..GEMM_MR {
                            let cp = c_chunk.as_mut_ptr().add((i0 + i) * n + j0);
                            for q in 0..4 {
                                let cq = cp.add(4 * q);
                                vst1q_f32(cq, vaddq_f32(vld1q_f32(cq), acc[i][q]));
                            }
                        }
                    } else {
                        let mut tmp = [0.0f32; GEMM_NR];
                        for i in 0..GEMM_MR {
                            for q in 0..4 {
                                vst1q_f32(tmp.as_mut_ptr().add(4 * q), acc[i][q]);
                            }
                            let crow = &mut c_chunk[(i0 + i) * n + j0..][..nw];
                            for (cv, &av) in crow.iter_mut().zip(&tmp[..nw]) {
                                *cv += av;
                            }
                        }
                    }
                } else {
                    for i in 0..mr {
                        let arow = &a[(r0 + i0 + i) * k..][..k];
                        let mut acc = [vdupq_n_f32(0.0); 4];
                        for (kk, &av) in arow.iter().enumerate() {
                            let avv = vdupq_n_f32(av);
                            let bp = panel.as_ptr().add(kk * GEMM_NR);
                            for q in 0..4 {
                                acc[q] = vfmaq_f32(acc[q], vld1q_f32(bp.add(4 * q)), avv);
                            }
                        }
                        let mut tmp = [0.0f32; GEMM_NR];
                        for q in 0..4 {
                            vst1q_f32(tmp.as_mut_ptr().add(4 * q), acc[q]);
                        }
                        let crow = &mut c_chunk[(i0 + i) * n + j0..][..nw];
                        for (cv, &av) in crow.iter_mut().zip(&tmp[..nw]) {
                            *cv += av;
                        }
                    }
                }
            }
            i0 += mr;
        }
    }
}
