//! Host compute kernels — two parallel f32 GEMM paths (a register-blocked
//! MR×NR micro-kernel over pre-packed B panels for the deployment hot
//! path, and the sparse-aware axpy [`gemm`] kept for the accumulate-heavy
//! merge algebra), an im2col-based VALID convolution over [`PackedConv`]
//! weights with a fusable [`Epilogue`], and the full op set the native
//! host backend (`runtime::HostBackend`) needs to execute a lowered plan
//! with zero XLA dependency: SAME-padded (optionally depthwise) conv, the
//! fused bias+activation+residual epilogue, group norm, 2x nearest
//! upsampling, single-head spatial attention, and the mean-pool + dense
//! classifier head.  Transient buffers come from an optional
//! [`crate::util::arena::Arena`], which is what makes the steady-state
//! lowered forward allocation-free.
//!
//! This is the deployment-time *host* hot path: the merge algebra
//! (`crate::merge`) composes span kernels out of per-tap matrix multiplies
//! over flat slices, and the numerics reports/oracles convolve merged
//! kernels on the host.  Both were 5–6-deep scalar loops before this
//! module existed (billions of scalar ops for ResNet-scale 512-channel
//! spans) — here they are expressed as GEMMs with contiguous,
//! vectorizable inner loops, parallelized over rows with
//! [`crate::util::par`].
//!
//! Layout conventions match the rest of the repo: activations are NHWC,
//! kernels are OIHW, everything row-major f32 (`util::tensor::Tensor`).
//! The naive reference implementations are retained as test oracles
//! ([`conv2d_valid_ref`], and `merge::merge_kernels_ref`) and as the
//! baseline side of `benches/merge_ops.rs`; the host-backend op variants
//! are pinned against naive oracles by `tests/host_backend.rs`.

use crate::util::arena::Arena;
use crate::util::par;
use crate::util::tensor::Tensor;

mod simd;
pub use simd::{available_isas, isa, Isa};

/// Below this many FLOPs a GEMM runs serially — pool dispatch is cheap
/// but a small product finishes before a parked worker wakes.
const PAR_FLOP_MIN: usize = 1 << 21;

/// Cache block over the contraction dimension: a block of B rows
/// (`KC x n` floats) stays resident while every C row sweeps it.
const KC: usize = 128;

fn gemm_threads(flops: usize) -> usize {
    if flops < PAR_FLOP_MIN {
        1
    } else {
        par::max_threads()
    }
}

/// `C += A · B` for row-major flat slices: A is `m x k`, B is `k x n`,
/// C is `m x n`.  Accumulating (`+=`) so callers can fold multiple
/// products into one buffer (the merge algebra's per-tap scatter does).
///
/// Parallel over row blocks of C, cache-blocked over k; the inner loop is
/// a contiguous axpy the compiler auto-vectorizes.  Zero entries of A are
/// skipped — identity/Dirac factors are common in span composition.
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "A is {m}x{k}");
    assert_eq!(b.len(), k * n, "B is {k}x{n}");
    assert_eq!(c.len(), m * n, "C is {m}x{n}");
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let threads = gemm_threads(2 * m * k * n);
    // ~4 chunks per thread keeps the atomic-claim queue balanced when row
    // costs vary (sparse A rows finish early).
    let rows_per = m.div_ceil(threads * 4).max(1);
    par::par_chunks_mut(c, rows_per * n, threads, |ci, chunk| {
        gemm_rows(ci * rows_per, chunk.len() / n, k, n, a, b, chunk);
    });
}

/// Serial kernel: rows `[r0, r0 + rows)` of C (passed as `c_chunk`).
fn gemm_rows(r0: usize, rows: usize, k: usize, n: usize, a: &[f32], b: &[f32], c_chunk: &mut [f32]) {
    let mut kb = 0;
    while kb < k {
        let kend = (kb + KC).min(k);
        for i in 0..rows {
            let arow = &a[(r0 + i) * k + kb..(r0 + i) * k + kend];
            let crow = &mut c_chunk[i * n..(i + 1) * n];
            for (p, &av) in arow.iter().enumerate() {
                if av != 0.0 {
                    let brow = &b[(kb + p) * n..(kb + p) * n + n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
        }
        kb = kend;
    }
}

// ---------------------------------------------------------------------------
// Register-blocked micro-kernel over packed B panels
// ---------------------------------------------------------------------------

/// Micro-tile rows: MR rows of C accumulate in registers per panel sweep.
pub const GEMM_MR: usize = 4;
/// Micro-tile columns (panel width): NR-wide register accumulators.
pub const GEMM_NR: usize = 16;

/// `B` re-packed once into NR-wide column panels (k-major inside each
/// panel), the layout the register-blocked micro-kernel streams with unit
/// stride.  Edge panels are zero-padded to NR so the kernel's compute is
/// uniform; stores are clipped to the real width.
pub struct PackedB {
    k: usize,
    n: usize,
    data: Vec<f32>,
}

impl PackedB {
    pub fn pack(k: usize, n: usize, b: &[f32]) -> PackedB {
        assert_eq!(b.len(), k * n, "B is {k}x{n}");
        let np = n.div_ceil(GEMM_NR.max(1));
        let mut data = vec![0.0f32; np * k * GEMM_NR];
        for p in 0..np {
            let j0 = p * GEMM_NR;
            let w = GEMM_NR.min(n - j0);
            let panel = &mut data[p * k * GEMM_NR..(p + 1) * k * GEMM_NR];
            for kk in 0..k {
                panel[kk * GEMM_NR..kk * GEMM_NR + w]
                    .copy_from_slice(&b[kk * n + j0..kk * n + j0 + w]);
            }
        }
        PackedB { k, n, data }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }
}

/// Fusable GEMM epilogue — `c = act(c + bias (+ res))` applied per row
/// block while the tile is still cache-hot, the host twin of the
/// `fa_*` / `far_*` fused artifact variants.  `bias` is per output column
/// (length n); `res` is the full m×n residual.
pub struct Epilogue<'a> {
    pub bias: &'a [f32],
    pub act: Option<Act>,
    pub res: Option<&'a [f32]>,
}

fn epilogue_rows(chunk: &mut [f32], n: usize, r0: usize, e: &Epilogue) {
    for (i, row) in chunk.chunks_mut(n).enumerate() {
        let roff = (r0 + i) * n;
        match e.res {
            Some(rd) => {
                for (j, v) in row.iter_mut().enumerate() {
                    let acc = *v + e.bias[j] + rd[roff + j];
                    *v = match e.act {
                        Some(a) => a.apply(acc),
                        None => acc,
                    };
                }
            }
            None => {
                for (j, v) in row.iter_mut().enumerate() {
                    let acc = *v + e.bias[j];
                    *v = match e.act {
                        Some(a) => a.apply(acc),
                        None => acc,
                    };
                }
            }
        }
    }
}

/// `C += A · B` with B pre-packed into panels — the BLIS-style
/// register-blocked path.  Same accumulation order as [`gemm`] (k
/// ascending, single pass), so results match the axpy path bit for bit.
pub fn gemm_packed(m: usize, a: &[f32], bp: &PackedB, c: &mut [f32]) {
    gemm_packed_epi(m, a, bp, c, None);
}

/// [`gemm_packed`] with the epilogue fused into the tile loop: each row
/// block gets bias/activation/residual applied right after its last
/// panel, instead of a second pass over C from memory.  The inner tile
/// sweep dispatches on the process-wide [`isa()`] (AVX2+FMA / NEON /
/// scalar), selected once at first use.
pub fn gemm_packed_epi(m: usize, a: &[f32], bp: &PackedB, c: &mut [f32], epi: Option<&Epilogue>) {
    gemm_packed_epi_inner(isa(), m, a, bp, c, epi);
}

/// [`gemm_packed_epi`] with the inner-kernel ISA forced instead of
/// detected — the hook parity tests and the `packed_gemm_simd_speedup`
/// bench use to compare kernels inside one process.  Panics if `isa_sel`
/// is not in [`available_isas`] (the SIMD kernels are `unsafe` precisely
/// because the caller vouches for hardware support).
pub fn gemm_packed_epi_isa(
    isa_sel: Isa,
    m: usize,
    a: &[f32],
    bp: &PackedB,
    c: &mut [f32],
    epi: Option<&Epilogue>,
) {
    assert!(
        available_isas().contains(&isa_sel),
        "ISA {isa_sel:?} is not available on this host"
    );
    gemm_packed_epi_inner(isa_sel, m, a, bp, c, epi);
}

fn gemm_packed_epi_inner(
    isa_sel: Isa,
    m: usize,
    a: &[f32],
    bp: &PackedB,
    c: &mut [f32],
    epi: Option<&Epilogue>,
) {
    let (k, n) = (bp.k, bp.n);
    assert_eq!(a.len(), m * k, "A is {m}x{k}");
    assert_eq!(c.len(), m * n, "C is {m}x{n}");
    if let Some(e) = epi {
        assert_eq!(e.bias.len(), n, "epilogue bias length vs n");
        if let Some(r) = e.res {
            assert_eq!(r.len(), m * n, "epilogue residual vs C");
        }
    }
    if m == 0 || n == 0 {
        return;
    }
    let threads = gemm_threads(2 * m * k.max(1) * n);
    let rows_per = m.div_ceil(threads * 4).max(1);
    par::par_chunks_mut(c, rows_per * n, threads, |ci, chunk| {
        let r0 = ci * rows_per;
        let rows = chunk.len() / n;
        if k > 0 {
            gemm_packed_rows_isa(isa_sel, r0, rows, k, n, a, &bp.data, chunk);
        }
        if let Some(e) = epi {
            epilogue_rows(chunk, n, r0, e);
        }
    });
}

/// Route one row-chunk tile sweep to the selected inner kernel.  The
/// vector arms only exist on their architecture; anything else (including
/// a foreign `Isa` value on the wrong arch, which `gemm_packed_epi_isa`
/// already rejects) lands on the scalar reference kernel.
#[allow(clippy::too_many_arguments)]
fn gemm_packed_rows_isa(
    isa_sel: Isa,
    r0: usize,
    rows: usize,
    k: usize,
    n: usize,
    a: &[f32],
    bdata: &[f32],
    c_chunk: &mut [f32],
) {
    match isa_sel {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Isa::Avx2 is only produced by runtime feature detection
        // (isa() / available_isas()), which verified avx2+fma.
        Isa::Avx2 => unsafe { simd::x86::gemm_rows_f32(r0, rows, k, n, a, bdata, c_chunk) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: Isa::Neon is only produced by runtime feature detection.
        Isa::Neon => unsafe { simd::arm::gemm_rows_f32(r0, rows, k, n, a, bdata, c_chunk) },
        _ => gemm_packed_rows(r0, rows, k, n, a, bdata, c_chunk),
    }
}

/// Serial micro-kernel sweep: rows `[r0, r0 + rows)` of C against every
/// packed panel.  Full MR×NR tiles accumulate in registers; the ≤ MR-1
/// edge rows fall back to a per-row axpy over the panel.
#[allow(clippy::too_many_arguments)]
fn gemm_packed_rows(
    r0: usize,
    rows: usize,
    k: usize,
    n: usize,
    a: &[f32],
    bdata: &[f32],
    c_chunk: &mut [f32],
) {
    let np = n.div_ceil(GEMM_NR);
    let mut i0 = 0;
    while i0 < rows {
        let mr = GEMM_MR.min(rows - i0);
        for p in 0..np {
            let j0 = p * GEMM_NR;
            let nw = GEMM_NR.min(n - j0);
            let panel = &bdata[p * k * GEMM_NR..(p + 1) * k * GEMM_NR];
            if mr == GEMM_MR {
                let a0 = &a[(r0 + i0) * k..][..k];
                let a1 = &a[(r0 + i0 + 1) * k..][..k];
                let a2 = &a[(r0 + i0 + 2) * k..][..k];
                let a3 = &a[(r0 + i0 + 3) * k..][..k];
                let mut acc = [[0.0f32; GEMM_NR]; GEMM_MR];
                for kk in 0..k {
                    let b = &panel[kk * GEMM_NR..kk * GEMM_NR + GEMM_NR];
                    let (v0, v1, v2, v3) = (a0[kk], a1[kk], a2[kk], a3[kk]);
                    for j in 0..GEMM_NR {
                        acc[0][j] += v0 * b[j];
                        acc[1][j] += v1 * b[j];
                        acc[2][j] += v2 * b[j];
                        acc[3][j] += v3 * b[j];
                    }
                }
                for (i, arow) in acc.iter().enumerate() {
                    let crow = &mut c_chunk[(i0 + i) * n + j0..][..nw];
                    for (cv, &av) in crow.iter_mut().zip(arow) {
                        *cv += av;
                    }
                }
            } else {
                for i in 0..mr {
                    let arow = &a[(r0 + i0 + i) * k..][..k];
                    let crow = &mut c_chunk[(i0 + i) * n + j0..][..nw];
                    for (kk, &av) in arow.iter().enumerate() {
                        if av != 0.0 {
                            let b = &panel[kk * GEMM_NR..kk * GEMM_NR + nw];
                            for (cv, &bv) in crow.iter_mut().zip(b) {
                                *cv += av * bv;
                            }
                        }
                    }
                }
            }
        }
        i0 += mr;
    }
}

// ---------------------------------------------------------------------------
// int8 per-channel quantized panels
// ---------------------------------------------------------------------------

/// `B` quantized to int8 with **symmetric per-output-column scales** and
/// packed into the same NR-wide zero-padded panel layout as [`PackedB`].
/// `deq(q[kk][j]) = q * scales[j]`; zero-max columns get scale 1.0 so
/// dequantization is always well-defined.  Weights are quantized once
/// (at `CompiledPlan::lower` via `Backend::upload_weight`); activations
/// stay f32 and are quantized dynamically per row at GEMM time.
pub struct PackedBI8 {
    k: usize,
    n: usize,
    data: Vec<i8>,
    scales: Vec<f32>,
}

impl PackedBI8 {
    pub fn pack(k: usize, n: usize, b: &[f32]) -> PackedBI8 {
        assert_eq!(b.len(), k * n, "B is {k}x{n}");
        let mut scales = vec![1.0f32; n];
        for (j, s) in scales.iter_mut().enumerate() {
            let mut mx = 0.0f32;
            for kk in 0..k {
                mx = mx.max(b[kk * n + j].abs());
            }
            if mx > 0.0 {
                *s = mx / 127.0;
            }
        }
        let np = n.div_ceil(GEMM_NR.max(1));
        let mut data = vec![0i8; np * k * GEMM_NR];
        for p in 0..np {
            let j0 = p * GEMM_NR;
            let w = GEMM_NR.min(n - j0);
            let panel = &mut data[p * k * GEMM_NR..(p + 1) * k * GEMM_NR];
            for kk in 0..k {
                for j in 0..w {
                    let q = (b[kk * n + j0 + j] / scales[j0 + j]).round();
                    panel[kk * GEMM_NR + j] = q.clamp(-127.0, 127.0) as i8;
                }
            }
        }
        PackedBI8 { k, n, data, scales }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Per-output-column dequantization scales (length n).
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }
}

/// View the first `len` bytes of an f32 scratch buffer as i8 — the arena
/// only vends `Vec<f32>`, and the quantized-A scratch must come from it
/// to keep the steady-state forward allocation-free.  Sound: i8 has
/// alignment 1 and no validity niche, and the arena hands back
/// initialized memory.
fn as_i8_mut(v: &mut [f32], len: usize) -> &mut [i8] {
    assert!(len <= v.len() * 4, "i8 view larger than backing f32 buffer");
    unsafe { std::slice::from_raw_parts_mut(v.as_mut_ptr() as *mut i8, len) }
}

/// `C += deq(quant(A) · Bq)` over int8 panels, epilogue fused — the
/// quantized twin of [`gemm_packed_epi`].  Each parallel row-chunk
/// quantizes **its own** A rows (symmetric per-row scale, scratch from
/// the worker's arena shard), sweeps the int8 tiles with i32
/// accumulators, and dequantizes into C at tile-store time while the
/// accumulators are still in registers; bias/act/residual then run on
/// the cache-hot chunk.  `arena: None` falls back to heap scratch.
pub fn gemm_packed_epi_i8(
    m: usize,
    a: &[f32],
    bp: &PackedBI8,
    c: &mut [f32],
    epi: Option<&Epilogue>,
    arena: Option<&Arena>,
) {
    gemm_packed_epi_i8_inner(isa(), m, a, bp, c, epi, arena);
}

/// [`gemm_packed_epi_i8`] with the inner-kernel ISA forced — see
/// [`gemm_packed_epi_isa`].
pub fn gemm_packed_epi_i8_isa(
    isa_sel: Isa,
    m: usize,
    a: &[f32],
    bp: &PackedBI8,
    c: &mut [f32],
    epi: Option<&Epilogue>,
    arena: Option<&Arena>,
) {
    assert!(
        available_isas().contains(&isa_sel),
        "ISA {isa_sel:?} is not available on this host"
    );
    gemm_packed_epi_i8_inner(isa_sel, m, a, bp, c, epi, arena);
}

fn gemm_packed_epi_i8_inner(
    isa_sel: Isa,
    m: usize,
    a: &[f32],
    bp: &PackedBI8,
    c: &mut [f32],
    epi: Option<&Epilogue>,
    arena: Option<&Arena>,
) {
    let (k, n) = (bp.k, bp.n);
    assert_eq!(a.len(), m * k, "A is {m}x{k}");
    assert_eq!(c.len(), m * n, "C is {m}x{n}");
    if let Some(e) = epi {
        assert_eq!(e.bias.len(), n, "epilogue bias length vs n");
        if let Some(r) = e.res {
            assert_eq!(r.len(), m * n, "epilogue residual vs C");
        }
    }
    if m == 0 || n == 0 {
        return;
    }
    let threads = gemm_threads(2 * m * k.max(1) * n);
    let rows_per = m.div_ceil(threads * 4).max(1);
    par::par_chunks_mut(c, rows_per * n, threads, |ci, chunk| {
        let r0 = ci * rows_per;
        let rows = chunk.len() / n;
        if k > 0 {
            let mut aqbuf = take_buf(arena, (rows * k).div_ceil(4), false);
            let mut asc = take_buf(arena, rows, false);
            let aq = as_i8_mut(&mut aqbuf, rows * k);
            for i in 0..rows {
                let arow = &a[(r0 + i) * k..][..k];
                let mut mx = 0.0f32;
                for &v in arow {
                    mx = mx.max(v.abs());
                }
                let s = if mx > 0.0 { mx / 127.0 } else { 1.0 };
                asc[i] = s;
                let inv = 1.0 / s;
                for (kk, &v) in arow.iter().enumerate() {
                    aq[i * k + kk] = (v * inv).round().clamp(-127.0, 127.0) as i8;
                }
            }
            gemm_packed_rows_i8_isa(isa_sel, rows, k, n, aq, &asc, &bp.data, &bp.scales, chunk);
            give_buf(arena, aqbuf);
            give_buf(arena, asc);
        }
        if let Some(e) = epi {
            epilogue_rows(chunk, n, r0, e);
        }
    });
}

/// Route one int8 row-chunk sweep: AVX2 on x86-64, scalar everywhere
/// else (including NEON — see `simd::arm`).
#[allow(clippy::too_many_arguments)]
fn gemm_packed_rows_i8_isa(
    isa_sel: Isa,
    rows: usize,
    k: usize,
    n: usize,
    aq: &[i8],
    ascale: &[f32],
    bdata: &[i8],
    bscale: &[f32],
    c_chunk: &mut [f32],
) {
    match isa_sel {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: Isa::Avx2 is only produced by runtime feature detection.
        Isa::Avx2 => unsafe {
            simd::x86::gemm_rows_i8(rows, k, n, aq, ascale, bdata, bscale, c_chunk)
        },
        _ => gemm_packed_rows_i8(rows, k, n, aq, ascale, bdata, bscale, c_chunk),
    }
}

/// Scalar int8 micro-kernel sweep (reference fallback and parity
/// oracle): full MR×NR tiles accumulate in i32 registers, edge rows run
/// a per-row sweep; dequantization (`* ascale[row] * bscale[col]`) is
/// applied at the clipped store.  `aq` / `ascale` are chunk-local.
#[allow(clippy::too_many_arguments)]
fn gemm_packed_rows_i8(
    rows: usize,
    k: usize,
    n: usize,
    aq: &[i8],
    ascale: &[f32],
    bdata: &[i8],
    bscale: &[f32],
    c_chunk: &mut [f32],
) {
    let np = n.div_ceil(GEMM_NR);
    let mut i0 = 0;
    while i0 < rows {
        let mr = GEMM_MR.min(rows - i0);
        for p in 0..np {
            let j0 = p * GEMM_NR;
            let nw = GEMM_NR.min(n - j0);
            let panel = &bdata[p * k * GEMM_NR..(p + 1) * k * GEMM_NR];
            if mr == GEMM_MR {
                let a0 = &aq[i0 * k..][..k];
                let a1 = &aq[(i0 + 1) * k..][..k];
                let a2 = &aq[(i0 + 2) * k..][..k];
                let a3 = &aq[(i0 + 3) * k..][..k];
                let mut acc = [[0i32; GEMM_NR]; GEMM_MR];
                for kk in 0..k {
                    let b = &panel[kk * GEMM_NR..kk * GEMM_NR + GEMM_NR];
                    let (v0, v1, v2, v3) =
                        (a0[kk] as i32, a1[kk] as i32, a2[kk] as i32, a3[kk] as i32);
                    for j in 0..GEMM_NR {
                        let bv = b[j] as i32;
                        acc[0][j] += v0 * bv;
                        acc[1][j] += v1 * bv;
                        acc[2][j] += v2 * bv;
                        acc[3][j] += v3 * bv;
                    }
                }
                for (i, arow) in acc.iter().enumerate() {
                    let s = ascale[i0 + i];
                    let crow = &mut c_chunk[(i0 + i) * n + j0..][..nw];
                    for (j, cv) in crow.iter_mut().enumerate() {
                        *cv += arow[j] as f32 * s * bscale[j0 + j];
                    }
                }
            } else {
                for i in 0..mr {
                    let arow = &aq[(i0 + i) * k..][..k];
                    let mut acc = [0i32; GEMM_NR];
                    for (kk, &av) in arow.iter().enumerate() {
                        if av != 0 {
                            let b = &panel[kk * GEMM_NR..kk * GEMM_NR + nw];
                            let av = av as i32;
                            for (j, &bv) in b.iter().enumerate() {
                                acc[j] += av * bv as i32;
                            }
                        }
                    }
                    let s = ascale[i0 + i];
                    let crow = &mut c_chunk[(i0 + i) * n + j0..][..nw];
                    for (j, cv) in crow.iter_mut().enumerate() {
                        *cv += acc[j] as f32 * s * bscale[j0 + j];
                    }
                }
            }
        }
        i0 += mr;
    }
}

// ---------------------------------------------------------------------------
// Packed convolution weights
// ---------------------------------------------------------------------------

/// A conv weight lowered **once** into its GEMM-ready execution layout:
/// im2col-transposed `[(a, b, c), o]` + NR-panel packed for dense convs,
/// tap-major `[k*k, c]` for depthwise.  `CompiledPlan::lower` packs every
/// conv/projection weight at lowering time; non-lowered callers (merge
/// oracle, report numerics) use [`PackedConv::pack`] directly so they too
/// pay the transpose once per weight instead of once per call.
pub enum PackedConv {
    Dense { co: usize, ci: usize, k: usize, panels: PackedB },
    DenseI8 { co: usize, ci: usize, k: usize, panels: PackedBI8 },
    Depthwise { c: usize, k: usize, wt: Vec<f32> },
}

impl PackedConv {
    pub fn pack(w: &Tensor, depthwise: bool) -> PackedConv {
        assert_eq!(w.dims[2], w.dims[3], "square kernels only");
        if depthwise {
            let (c, one, k) = (w.dims[0], w.dims[1], w.dims[2]);
            assert_eq!(one, 1, "depthwise kernel must be [C,1,k,k]");
            let mut wt = vec![0.0f32; k * k * c];
            for ch in 0..c {
                for a in 0..k {
                    for b2 in 0..k {
                        wt[(a * k + b2) * c + ch] = w.data[(ch * k + a) * k + b2];
                    }
                }
            }
            PackedConv::Depthwise { c, k, wt }
        } else {
            let (co, ci, k) = (w.dims[0], w.dims[1], w.dims[2]);
            let kk = k * k * ci;
            // OIHW -> [(a, b, c), o] so the product lands in NHWC order
            let mut wt = vec![0.0f32; kk * co];
            for o in 0..co {
                for c in 0..ci {
                    for a in 0..k {
                        for b in 0..k {
                            wt[((a * k + b) * ci + c) * co + o] =
                                w.data[((o * ci + c) * k + a) * k + b];
                        }
                    }
                }
            }
            PackedConv::Dense { co, ci, k, panels: PackedB::pack(kk, co, &wt) }
        }
    }

    /// Dense conv weight lowered to **int8 per-output-channel quantized**
    /// panels ([`PackedBI8`]): same im2col transpose as [`pack`], then
    /// symmetric per-`co`-column quantization.  Depthwise weights stay
    /// f32 (their direct kernel never goes through the GEMM) — callers
    /// gate on `!depthwise` and fall back to [`pack`].
    ///
    /// [`pack`]: PackedConv::pack
    pub fn pack_i8(w: &Tensor) -> PackedConv {
        assert_eq!(w.dims[2], w.dims[3], "square kernels only");
        let (co, ci, k) = (w.dims[0], w.dims[1], w.dims[2]);
        let kk = k * k * ci;
        let mut wt = vec![0.0f32; kk * co];
        for o in 0..co {
            for c in 0..ci {
                for a in 0..k {
                    for b in 0..k {
                        wt[((a * k + b) * ci + c) * co + o] =
                            w.data[((o * ci + c) * k + a) * k + b];
                    }
                }
            }
        }
        PackedConv::DenseI8 { co, ci, k, panels: PackedBI8::pack(kk, co, &wt) }
    }

    pub fn k(&self) -> usize {
        match self {
            PackedConv::Dense { k, .. }
            | PackedConv::DenseI8 { k, .. }
            | PackedConv::Depthwise { k, .. } => *k,
        }
    }

    pub fn out_channels(&self) -> usize {
        match self {
            PackedConv::Dense { co, .. } | PackedConv::DenseI8 { co, .. } => *co,
            PackedConv::Depthwise { c, .. } => *c,
        }
    }

    pub fn depthwise(&self) -> bool {
        matches!(self, PackedConv::Depthwise { .. })
    }

    /// True for the int8-quantized dense layout — what the weight-cache
    /// key and `/stats` attribution discriminate on.
    pub fn quantized(&self) -> bool {
        matches!(self, PackedConv::DenseI8 { .. })
    }

    /// VALID conv with this packed weight — the one-shot helper for
    /// callers that convolve one weight against many inputs.
    pub fn conv_valid(&self, x: &Tensor, stride: usize) -> Tensor {
        conv2d_valid_packed(x, self, stride, None, None)
    }

    /// SAME conv with this packed weight.
    pub fn conv_same(&self, x: &Tensor, stride: usize) -> Tensor {
        conv2d_same_packed(x, self, stride, None, None)
    }
}

/// Arena-or-heap scratch: the lowered execution path passes the backend
/// arena (steady-state reuse, counted); one-shot callers pass `None`.
/// Shared with `runtime::HostBackend`'s op interpreter so the
/// arena-or-heap policy has exactly one implementation.
pub(crate) fn take_buf(arena: Option<&Arena>, len: usize, zeroed: bool) -> Vec<f32> {
    match arena {
        Some(a) if zeroed => a.take_zeroed(len),
        Some(a) => a.take(len),
        None => vec![0.0; len],
    }
}

fn give_buf(arena: Option<&Arena>, v: Vec<f32>) {
    if let Some(a) = arena {
        a.give(v);
    }
}

/// VALID conv on host tensors via im2col + the packed micro-kernel GEMM:
/// `x` NHWC `[B, H, W, Ci]`, output NHWC.  The im2col patch layout is
/// `(a, b, c)` so each kernel row gathers as a single contiguous `k*Ci`
/// memcpy from the NHWC input; 1x1 stride-1 convs skip im2col entirely
/// (the NHWC input *is* the A matrix).  `epi` fuses the conv epilogue
/// into the GEMM tile loop; `arena` recycles the column/output buffers.
pub fn conv2d_valid_packed(
    x: &Tensor,
    pc: &PackedConv,
    stride: usize,
    epi: Option<&Epilogue>,
    arena: Option<&Arena>,
) -> Tensor {
    assert!(stride >= 1);
    match pc {
        PackedConv::Dense { co, ci, k, panels } => {
            dense_conv_valid(x, *co, *ci, *k, DensePanels::F32(panels), stride, epi, arena)
        }
        PackedConv::DenseI8 { co, ci, k, panels } => {
            dense_conv_valid(x, *co, *ci, *k, DensePanels::I8(panels), stride, epi, arena)
        }
        PackedConv::Depthwise { c, k, wt } => {
            depthwise_conv2d_valid_packed(x, *c, *k, wt, stride, epi, arena)
        }
    }
}

/// The two dense panel layouts share one im2col driver; only the final
/// GEMM call differs (f32 micro-kernel vs int8 quantize-sweep-dequant).
enum DensePanels<'a> {
    F32(&'a PackedB),
    I8(&'a PackedBI8),
}

impl DensePanels<'_> {
    fn gemm_epi(
        &self,
        rows: usize,
        a: &[f32],
        c: &mut [f32],
        epi: Option<&Epilogue>,
        arena: Option<&Arena>,
    ) {
        match self {
            DensePanels::F32(panels) => gemm_packed_epi(rows, a, panels, c, epi),
            DensePanels::I8(panels) => gemm_packed_epi_i8(rows, a, panels, c, epi, arena),
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn dense_conv_valid(
    x: &Tensor,
    co: usize,
    ci: usize,
    k: usize,
    panels: DensePanels,
    stride: usize,
    epi: Option<&Epilogue>,
    arena: Option<&Arena>,
) -> Tensor {
    let (bn, h, wd, cx) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    assert_eq!(cx, ci, "channel mismatch: x {:?} vs packed ci {ci}", x.dims);
    assert!(h >= k && wd >= k, "input {h}x{wd} smaller than kernel {k}");
    let ho = (h - k) / stride + 1;
    let wo = (wd - k) / stride + 1;
    let rows = bn * ho * wo;
    if k == 1 && stride == 1 {
        let mut y = Tensor::new(vec![bn, ho, wo, co], take_buf(arena, rows * co, true));
        panels.gemm_epi(rows, &x.data, &mut y.data, epi, arena);
        return y;
    }
    let kk = k * k * ci;
    // im2col: one contiguous k*ci run per kernel row a.  Rows are
    // batched per parallel chunk (like gemm's row blocks) so the
    // claim overhead stays negligible next to the memcpys.
    let mut cols = take_buf(arena, rows * kk, false);
    let threads = gemm_threads(rows * kk * 4);
    let rows_per = rows.div_ceil(threads * 4).max(1);
    par::par_chunks_mut(&mut cols, rows_per * kk, threads, |chunk_idx, dst| {
        let row0 = chunk_idx * rows_per;
        for (ri, drow) in dst.chunks_mut(kk).enumerate() {
            let row = row0 + ri;
            let n = row / (ho * wo);
            let r = row % (ho * wo);
            let (p, q) = (r / wo, r % wo);
            for a in 0..k {
                let src = ((n * h + p * stride + a) * wd + q * stride) * cx;
                drow[a * k * cx..(a + 1) * k * cx].copy_from_slice(&x.data[src..src + k * cx]);
            }
        }
    });
    let mut y = Tensor::new(vec![bn, ho, wo, co], take_buf(arena, rows * co, true));
    panels.gemm_epi(rows, &cols, &mut y.data, epi, arena);
    give_buf(arena, cols);
    y
}

/// VALID conv on host tensors — packs the weight per call and runs the
/// packed path.  Loop callers should hold a [`PackedConv`] instead.
pub fn conv2d_valid(x: &Tensor, w: &Tensor, stride: usize) -> Tensor {
    assert_eq!(
        x.dims[3], w.dims[1],
        "channel mismatch: x {:?} vs w {:?}",
        x.dims, w.dims
    );
    PackedConv::pack(w, false).conv_valid(x, stride)
}

/// Naive triple-loop `C += A · B` — the GEMM test oracle (shared by the
/// unit tests here and `tests/gemm_parity.rs`; same role as
/// [`conv2d_valid_ref`]).  O(m·k·n) scalar ops; never call on hot paths.
pub fn gemm_ref(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..m {
        for p in 0..k {
            for j in 0..n {
                c[i * n + j] += a[i * k + p] * b[p * n + j];
            }
        }
    }
}

/// Direct 6-loop VALID conv — retained as the test oracle and the naive
/// baseline in `benches/merge_ops.rs` (formerly the `#[cfg(test)]` oracle
/// inside `merge`).
pub fn conv2d_valid_ref(x: &Tensor, w: &Tensor, stride: usize) -> Tensor {
    let (b, h, wd, ci) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (co, ci2, k) = (w.dims[0], w.dims[1], w.dims[2]);
    assert_eq!(ci, ci2);
    let ho = (h - k) / stride + 1;
    let wo = (wd - k) / stride + 1;
    let mut y = Tensor::zeros(&[b, ho, wo, co]);
    for n in 0..b {
        for p in 0..ho {
            for q in 0..wo {
                for o in 0..co {
                    let mut acc = 0.0;
                    for c in 0..ci {
                        for a in 0..k {
                            for bb in 0..k {
                                acc += x.at4(n, p * stride + a, q * stride + bb, c)
                                    * w.at4(o, c, a, bb);
                            }
                        }
                    }
                    y.set4(n, p, q, o, acc);
                }
            }
        }
    }
    y
}

// ---------------------------------------------------------------------------
// Host-backend op set (runtime::HostBackend dispatches onto these)
// ---------------------------------------------------------------------------

/// Activation kinds the deployment stack knows — mirrors the AOT conv
/// artifact variants (`fa_relu` / `fa_swish` / `far_*`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    Relu,
    Swish,
}

impl Act {
    /// The artifact-variant spelling ("relu" / "swish").
    pub fn name(&self) -> &'static str {
        match self {
            Act::Relu => "relu",
            Act::Swish => "swish",
        }
    }

    /// Parse the spec's activation string; "none" is not an `Act` — model
    /// it as `Option<Act>::None` at the call site.
    pub fn parse(s: &str) -> Option<Act> {
        match s {
            "relu" => Some(Act::Relu),
            "swish" => Some(Act::Swish),
            _ => None,
        }
    }

    #[inline]
    pub fn apply(&self, x: f32) -> f32 {
        match self {
            Act::Relu => x.max(0.0),
            Act::Swish => x / (1.0 + (-x).exp()),
        }
    }
}

/// XLA/TF "SAME" padding split for one spatial dim: total padding is
/// `max((ceil(n/s) - 1) * s + k - n, 0)`, low half rounded down.
fn same_pad(n: usize, k: usize, stride: usize) -> (usize, usize) {
    let out = n.div_ceil(stride);
    let tot = ((out - 1) * stride + k).saturating_sub(n);
    (tot / 2, tot - tot / 2)
}

/// Zero-pad NHWC spatially (parallel per-batch row copies), pad plane
/// from the arena when one is supplied.
fn pad2d_buf(
    x: &Tensor,
    ph: (usize, usize),
    pw: (usize, usize),
    arena: Option<&Arena>,
) -> Tensor {
    let (bn, h, wd, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (hp, wp) = (h + ph.0 + ph.1, wd + pw.0 + pw.1);
    let plane = hp * wp * c;
    let mut out = Tensor::new(vec![bn, hp, wp, c], take_buf(arena, bn * plane, true));
    let threads = par::auto_threads(out.data.len());
    par::par_chunks_mut(&mut out.data, plane, threads, |n, dst| {
        for i in 0..h {
            let src = ((n * h + i) * wd) * c;
            let d0 = ((ph.0 + i) * wp + pw.0) * c;
            dst[d0..d0 + wd * c].copy_from_slice(&x.data[src..src + wd * c]);
        }
    });
    out
}

/// Zero-pad NHWC spatially (heap-allocating variant).
fn pad2d(x: &Tensor, ph: (usize, usize), pw: (usize, usize)) -> Tensor {
    pad2d_buf(x, ph, pw, None)
}

/// SAME conv over a pre-packed weight, matching the AOT `conv` artifacts
/// exactly: `x` NHWC, output spatial dims `ceil(in / stride)`.  Dense
/// goes through im2col + the packed micro-kernel; depthwise runs a direct
/// tap-accumulated kernel over the tap-major packed weight (expanding to
/// a diagonal dense kernel would be CxC memory for C useful rows).  The
/// optional [`Epilogue`] fuses bias/activation/residual into the kernel's
/// tile loop; the optional [`Arena`] recycles pad/column/output buffers.
pub fn conv2d_same_packed(
    x: &Tensor,
    pc: &PackedConv,
    stride: usize,
    epi: Option<&Epilogue>,
    arena: Option<&Arena>,
) -> Tensor {
    let (h, wd) = (x.dims[1], x.dims[2]);
    let k = pc.k();
    let ph = same_pad(h, k, stride);
    let pw = same_pad(wd, k, stride);
    if ph.0 + ph.1 + pw.0 + pw.1 == 0 {
        conv2d_valid_packed(x, pc, stride, epi, arena)
    } else {
        let padded = pad2d_buf(x, ph, pw, arena);
        let y = conv2d_valid_packed(&padded, pc, stride, epi, arena);
        give_buf(arena, padded.data);
        y
    }
}

/// SAME conv on host tensors — packs the weight per call and runs the
/// packed path.  Lowered plans hold a [`PackedConv`] instead (packed once
/// at `CompiledPlan::lower`).
pub fn conv2d_same(x: &Tensor, w: &Tensor, stride: usize, depthwise: bool) -> Tensor {
    if !depthwise {
        assert_eq!(
            x.dims[3], w.dims[1],
            "channel mismatch: x {:?} vs w {:?}",
            x.dims, w.dims
        );
    }
    conv2d_same_packed(x, &PackedConv::pack(w, depthwise), stride, None, None)
}

/// VALID depthwise conv over the tap-major packed weight: `x` NHWC
/// `[B, H, W, C]`.  Per tap, the inner loop is a contiguous fused
/// multiply-add over the channel dim; parallel over output-row blocks,
/// with the epilogue applied per finished row while it is cache-hot.
#[allow(clippy::too_many_arguments)]
fn depthwise_conv2d_valid_packed(
    x: &Tensor,
    c: usize,
    k: usize,
    wt: &[f32],
    stride: usize,
    epi: Option<&Epilogue>,
    arena: Option<&Arena>,
) -> Tensor {
    let (bn, h, wd, cx) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    assert_eq!(cx, c, "channel mismatch: x {:?} vs packed c {c}", x.dims);
    let ho = (h - k) / stride + 1;
    let wo = (wd - k) / stride + 1;
    let mut y = Tensor::new(vec![bn, ho, wo, c], take_buf(arena, bn * ho * wo * c, true));
    let rows = bn * ho;
    let threads = gemm_threads(2 * rows * wo * c * k * k);
    let rows_per = rows.div_ceil(threads * 4).max(1);
    par::par_chunks_mut(&mut y.data, rows_per * wo * c, threads, |ci, chunk| {
        let r0 = ci * rows_per;
        for (ri, drow) in chunk.chunks_mut(wo * c).enumerate() {
            let row = r0 + ri;
            let n = row / ho;
            let p = row % ho;
            for a in 0..k {
                let iy = p * stride + a;
                for b2 in 0..k {
                    let wtap = &wt[(a * k + b2) * c..][..c];
                    for q in 0..wo {
                        let src = ((n * h + iy) * wd + q * stride + b2) * c;
                        let xrow = &x.data[src..src + c];
                        let d = &mut drow[q * c..(q + 1) * c];
                        for ((dv, &xv), &wv) in d.iter_mut().zip(xrow).zip(wtap) {
                            *dv += xv * wv;
                        }
                    }
                }
            }
            if let Some(e) = epi {
                let roff = row * wo * c;
                for (qi, px) in drow.chunks_mut(c).enumerate() {
                    let base = roff + qi * c;
                    for (o, v) in px.iter_mut().enumerate() {
                        let mut acc = *v + e.bias[o];
                        if let Some(rd) = e.res {
                            acc += rd[base + o];
                        }
                        *v = match e.act {
                            Some(aa) => aa.apply(acc),
                            None => acc,
                        };
                    }
                }
            }
        }
    });
    y
}

/// Fused conv epilogue — `y = act(y + bias (+ res))`, in place, parallel
/// over pixel blocks.  This is the host twin of the `fa_*` / `far_*`
/// fused artifact variants (one pass over the output instead of three).
pub fn bias_act_res(y: &mut Tensor, bias: &[f32], act: Option<Act>, res: Option<&Tensor>) {
    let c = *y.dims.last().expect("bias_act_res needs a channel dim");
    assert_eq!(bias.len(), c, "bias length vs channel dim");
    if let Some(r) = res {
        assert_eq!(r.dims, y.dims, "residual shape mismatch");
    }
    let rows = y.data.len() / c;
    let threads = par::auto_threads(y.data.len());
    let rows_per = rows.div_ceil(threads * 4).max(1);
    let rdata = res.map(|r| &r.data[..]);
    par::par_chunks_mut(&mut y.data, rows_per * c, threads, |ci, chunk| {
        let base = ci * rows_per * c;
        for (pi, px) in chunk.chunks_mut(c).enumerate() {
            let roff = base + pi * c;
            for (o, v) in px.iter_mut().enumerate() {
                let mut acc = *v + bias[o];
                if let Some(rd) = rdata {
                    acc += rd[roff + o];
                }
                *v = match act {
                    Some(a) => a.apply(acc),
                    None => acc,
                };
            }
        }
    });
}

/// Elementwise activation in place (parallel) — the host twin of the
/// `relu_*` / `swish_*` elementwise artifacts.
pub fn act_inplace(y: &mut Tensor, act: Act) {
    let threads = par::auto_threads(y.data.len());
    let chunk = y.data.len().div_ceil(threads * 4).max(1);
    par::par_chunks_mut(&mut y.data, chunk, threads, |_, c| {
        for v in c {
            *v = act.apply(*v);
        }
    });
}

/// Elementwise activation into a pre-sized output (`y` may be dirty arena
/// scratch — every element is written).
pub fn act_into(x: &Tensor, act: Act, y: &mut Tensor) {
    assert_eq!(x.dims, y.dims, "act_into shape mismatch");
    let threads = par::auto_threads(x.data.len());
    let chunk = x.data.len().div_ceil(threads * 4).max(1);
    par::par_chunks_mut(&mut y.data, chunk, threads, |ci, dst| {
        let base = ci * chunk;
        for (j, v) in dst.iter_mut().enumerate() {
            *v = act.apply(x.data[base + j]);
        }
    });
}

/// Elementwise add into a pre-sized output (`y` may be dirty arena
/// scratch — every element is written).
pub fn add_into(a: &Tensor, b: &Tensor, y: &mut Tensor) {
    assert_eq!(a.dims, b.dims, "add shape mismatch");
    assert_eq!(a.dims, y.dims, "add_into output shape mismatch");
    let threads = par::auto_threads(a.data.len());
    let chunk = a.data.len().div_ceil(threads * 4).max(1);
    par::par_chunks_mut(&mut y.data, chunk, threads, |ci, dst| {
        let base = ci * chunk;
        for (j, v) in dst.iter_mut().enumerate() {
            *v = a.data[base + j] + b.data[base + j];
        }
    });
}

/// Group norm over NHWC, matching `python/compile/model.py::group_norm`:
/// per (batch, group) statistics over (H, W, C/groups), eps 1e-5,
/// per-channel scale + bias.  Parallel over batch elements.
pub fn group_norm(x: &Tensor, scale: &[f32], bias: &[f32], groups: usize) -> Tensor {
    let mut y = Tensor::zeros(&x.dims);
    group_norm_into(x, scale, bias, groups, &mut y);
    y
}

/// [`group_norm`] into a pre-sized output (`y` may be dirty arena
/// scratch — every element is written).
pub fn group_norm_into(x: &Tensor, scale: &[f32], bias: &[f32], groups: usize, y: &mut Tensor) {
    let (bn, h, wd, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    assert!(groups >= 1 && c % groups == 0, "channels {c} not divisible into {groups} groups");
    assert_eq!(scale.len(), c);
    assert_eq!(bias.len(), c);
    assert_eq!(y.dims, x.dims, "group_norm_into output shape mismatch");
    let cg = c / groups;
    let hw = h * wd;
    let plane = hw * c;
    let _ = bn;
    let threads = par::auto_threads(x.data.len());
    par::par_chunks_mut(&mut y.data, plane, threads, |n, out| {
        let xin = &x.data[n * plane..(n + 1) * plane];
        for g in 0..groups {
            let c0 = g * cg;
            let (mut sum, mut sq) = (0.0f64, 0.0f64);
            for p in 0..hw {
                for v in &xin[p * c + c0..p * c + c0 + cg] {
                    let v = *v as f64;
                    sum += v;
                    sq += v * v;
                }
            }
            let cnt = (hw * cg) as f64;
            let mean = sum / cnt;
            let var = (sq / cnt - mean * mean).max(0.0);
            let inv = 1.0 / (var + 1e-5).sqrt();
            for p in 0..hw {
                for (o, v) in xin[p * c + c0..p * c + c0 + cg].iter().enumerate() {
                    let ci = c0 + o;
                    out[p * c + ci] =
                        ((*v as f64 - mean) * inv) as f32 * scale[ci] + bias[ci];
                }
            }
        }
    });
}

/// 2x nearest-neighbour upsampling (NHWC) — each pixel's channel block is
/// copied twice along W, each expanded row twice along H.
pub fn upsample2x(x: &Tensor) -> Tensor {
    let (bn, h, wd, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let mut y = Tensor::zeros(&[bn, 2 * h, 2 * wd, c]);
    upsample2x_into(x, &mut y);
    y
}

/// [`upsample2x`] into a pre-sized `[B, 2H, 2W, C]` output (`y` may be
/// dirty arena scratch — every element is written).
pub fn upsample2x_into(x: &Tensor, y: &mut Tensor) {
    let (bn, h, wd, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    assert_eq!(y.dims, vec![bn, 2 * h, 2 * wd, c], "upsample2x_into output shape");
    let orow = 2 * wd * c;
    let threads = par::auto_threads(y.data.len());
    par::par_chunks_mut(&mut y.data, 2 * orow, threads, |r, chunk| {
        let n = r / h;
        let i = r % h;
        let src = ((n * h + i) * wd) * c;
        let (row0, row1) = chunk.split_at_mut(orow);
        for q in 0..wd {
            let px = &x.data[src + q * c..src + (q + 1) * c];
            row0[2 * q * c..(2 * q + 1) * c].copy_from_slice(px);
            row0[(2 * q + 1) * c..(2 * q + 2) * c].copy_from_slice(px);
        }
        row1.copy_from_slice(row0);
    });
}

/// Single-head self-attention over spatial positions with residual,
/// matching `model.py::attention`: `softmax(q kᵀ / sqrt(c)) v @ wout + x`.
/// The qkv projection is one big [`gemm`]; the per-batch products then
/// **dispatch on the compute pool** (this was the last op still serial
/// over the batch dim), with each batch task drawing its q/kᵀ/v/att
/// scratch from the arena's per-thread shard.  Inside a batch task the
/// inner GEMMs run serially (`par::in_pool_worker`).
pub fn attention(x: &Tensor, wqkv: &Tensor, wout: &Tensor, arena: Option<&Arena>) -> Tensor {
    let (bn, h, wd, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    assert_eq!(wqkv.dims, vec![c, 3 * c], "wqkv must be [C, 3C]");
    assert_eq!(wout.dims, vec![c, c], "wout must be [C, C]");
    // arena-less callers still recycle within the call: a transient local
    // arena caps scratch at one set per thread instead of six fresh
    // buffers per batch element
    let local;
    let arena = Some(match arena {
        Some(a) => a,
        None => {
            local = Arena::new();
            &local
        }
    });
    let s = h * wd;
    let mut qkv = take_buf(arena, bn * s * 3 * c, true);
    gemm(bn * s, c, 3 * c, &x.data, &wqkv.data, &mut qkv);
    let scale = 1.0 / (c as f32).sqrt();
    let mut y = Tensor::new(x.dims.clone(), take_buf(arena, bn * s * c, false));
    let flops = 2 * s * s * c + 2 * s * c * c;
    // batch-parallel only when the batch dim can actually feed every
    // worker; below that, the serial outer loop keeps the *inner* GEMMs
    // free to parallelize across the pool (small-bn / large-spatial
    // inputs would otherwise cap at bn-way parallelism)
    let threads = if bn >= par::max_threads() && bn * flops >= PAR_FLOP_MIN {
        par::max_threads()
    } else {
        1
    };
    par::par_chunks_mut(&mut y.data, s * c, threads, |n, yplane| {
        let mut q = take_buf(arena, s * c, false);
        let mut kt = take_buf(arena, c * s, false);
        let mut v = take_buf(arena, s * c, false);
        let mut att = take_buf(arena, s * s, true);
        let mut av = take_buf(arena, s * c, true);
        let mut out = take_buf(arena, s * c, true);
        for i in 0..s {
            let row = &qkv[(n * s + i) * 3 * c..][..3 * c];
            q[i * c..(i + 1) * c].copy_from_slice(&row[..c]);
            for (ci, &kv) in row[c..2 * c].iter().enumerate() {
                kt[ci * s + i] = kv; // K transposed for the q·kᵀ GEMM
            }
            v[i * c..(i + 1) * c].copy_from_slice(&row[2 * c..]);
        }
        gemm(s, c, s, &q, &kt, &mut att);
        for row in att.chunks_mut(s) {
            let mut mx = f32::NEG_INFINITY;
            for val in row.iter_mut() {
                *val *= scale;
                mx = mx.max(*val);
            }
            let mut sum = 0.0f32;
            for val in row.iter_mut() {
                *val = (*val - mx).exp();
                sum += *val;
            }
            for val in row.iter_mut() {
                *val /= sum;
            }
        }
        gemm(s, s, c, &att, &v, &mut av);
        gemm(s, c, c, &av, &wout.data, &mut out);
        let xplane = &x.data[n * s * c..(n + 1) * s * c];
        for ((yv, &xv), &ov) in yplane.iter_mut().zip(xplane).zip(&out) {
            *yv = xv + ov;
        }
        give_buf(arena, q);
        give_buf(arena, kt);
        give_buf(arena, v);
        give_buf(arena, att);
        give_buf(arena, av);
        give_buf(arena, out);
    });
    give_buf(arena, qkv);
    y
}

/// Classifier head: global mean pool over (H, W) then a dense layer —
/// `x.mean(axis=(1,2)) @ w + b`, `w` `[C, classes]`.
pub fn mean_pool_dense(x: &Tensor, w: &Tensor, b: &[f32]) -> Tensor {
    let mut y = Tensor::zeros(&[x.dims[0], w.dims[1]]);
    mean_pool_dense_into(x, w, b, None, &mut y);
    y
}

/// [`mean_pool_dense`] into a pre-sized zeroed `[B, classes]` output,
/// with the pooled scratch drawn from the arena.
pub fn mean_pool_dense_into(
    x: &Tensor,
    w: &Tensor,
    b: &[f32],
    arena: Option<&Arena>,
    y: &mut Tensor,
) {
    let (bn, h, wd, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    assert_eq!(w.dims[0], c, "head weight rows vs channels");
    let classes = w.dims[1];
    assert_eq!(b.len(), classes);
    assert_eq!(y.dims, vec![bn, classes], "mean_pool_dense_into output shape");
    let hw = (h * wd) as f32;
    let mut pooled = take_buf(arena, bn * c, true);
    for n in 0..bn {
        let dst = &mut pooled[n * c..(n + 1) * c];
        for p in 0..h * wd {
            let src = &x.data[(n * h * wd + p) * c..][..c];
            for (d, &s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }
        for d in dst.iter_mut() {
            *d /= hw;
        }
    }
    gemm(bn, c, classes, &pooled, &w.data, &mut y.data);
    give_buf(arena, pooled);
    for row in y.data.chunks_mut(classes) {
        for (v, &bb) in row.iter_mut().zip(b) {
            *v += bb;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randt(r: &mut Rng, dims: &[usize]) -> Tensor {
        let n: usize = dims.iter().product();
        Tensor::new(dims.to_vec(), (0..n).map(|_| r.normal()).collect())
    }

    #[test]
    fn gemm_matches_naive() {
        let mut r = Rng::new(21);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 4), (17, 33, 9), (64, 200, 48)] {
            let a: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
            let mut want = vec![0.0f32; m * n];
            gemm_ref(m, k, n, &a, &b, &mut want);
            let mut got = vec![0.0f32; m * n];
            gemm(m, k, n, &a, &b, &mut got);
            let diff = want
                .iter()
                .zip(&got)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(diff < 1e-4, "({m},{k},{n}) diff {diff}");
        }
    }

    #[test]
    fn gemm_packed_matches_axpy_and_ref() {
        let mut r = Rng::new(31);
        for &(m, k, n) in &[(1, 1, 1), (4, 16, 16), (5, 7, 17), (63, 129, 33), (96, 40, 64)] {
            let a: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
            let mut want = vec![0.0f32; m * n];
            gemm_ref(m, k, n, &a, &b, &mut want);
            let bp = PackedB::pack(k, n, &b);
            assert_eq!((bp.k(), bp.n()), (k, n));
            let mut got = vec![0.0f32; m * n];
            gemm_packed(m, &a, &bp, &mut got);
            let diff = want
                .iter()
                .zip(&got)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(diff < 1e-3, "packed ({m},{k},{n}) diff {diff}");
        }
    }

    #[test]
    fn gemm_packed_accumulates_like_gemm() {
        let mut r = Rng::new(32);
        let (m, k, n) = (9, 11, 21);
        let a: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
        let bp = PackedB::pack(k, n, &b);
        let mut once = vec![0.0f32; m * n];
        gemm_packed(m, &a, &bp, &mut once);
        let mut twice = vec![0.0f32; m * n];
        gemm_packed(m, &a, &bp, &mut twice);
        gemm_packed(m, &a, &bp, &mut twice);
        for (x, y) in once.iter().zip(&twice) {
            assert!((2.0 * x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn fused_epilogue_matches_separate_bias_act_res() {
        let mut r = Rng::new(33);
        let (m, k, n) = (10, 13, 18);
        let a: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
        let bias: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let res: Vec<f32> = (0..m * n).map(|_| r.normal()).collect();
        let bp = PackedB::pack(k, n, &b);
        for act in [None, Some(Act::Relu), Some(Act::Swish)] {
            for with_res in [false, true] {
                // reference: plain GEMM then the separate epilogue pass
                let mut want = Tensor::zeros(&[m, n]);
                gemm(m, k, n, &a, &b, &mut want.data);
                let rt = Tensor::new(vec![m, n], res.clone());
                bias_act_res(&mut want, &bias, act, with_res.then_some(&rt));
                let mut got = vec![0.0f32; m * n];
                let epi = Epilogue {
                    bias: &bias,
                    act,
                    res: with_res.then_some(&res[..]),
                };
                gemm_packed_epi(m, &a, &bp, &mut got, Some(&epi));
                let diff = want
                    .data
                    .iter()
                    .zip(&got)
                    .map(|(x, y)| (x - y).abs())
                    .fold(0.0f32, f32::max);
                assert!(diff < 1e-4, "act {act:?} res {with_res}: diff {diff}");
            }
        }
    }

    #[test]
    fn packed_conv_helper_reuses_one_packing() {
        let mut r = Rng::new(34);
        let w = randt(&mut r, &[5, 3, 3, 3]);
        let pc = PackedConv::pack(&w, false);
        assert_eq!((pc.k(), pc.out_channels(), pc.depthwise()), (3, 5, false));
        for &h in &[7usize, 9, 12] {
            let x = randt(&mut r, &[1, h, h, 3]);
            let want = conv2d_valid_ref(&x, &w, 1);
            let got = pc.conv_valid(&x, 1);
            assert_eq!(got.dims, want.dims);
            assert!(got.max_abs_diff(&want) < 1e-3);
        }
        let dw = randt(&mut r, &[4, 1, 3, 3]);
        let pdw = PackedConv::pack(&dw, true);
        assert!(pdw.depthwise());
        let x = randt(&mut r, &[2, 8, 8, 4]);
        let want = conv2d_same(&x, &dw, 2, true);
        let got = pdw.conv_same(&x, 2);
        assert_eq!(got.dims, want.dims);
        assert!(got.max_abs_diff(&want) < 1e-6);
    }

    #[test]
    fn conv_packed_with_arena_hits_on_second_call() {
        use crate::util::arena::Arena;
        let mut r = Rng::new(35);
        let x = randt(&mut r, &[1, 9, 9, 3]);
        let w = randt(&mut r, &[4, 3, 3, 3]);
        let pc = PackedConv::pack(&w, false);
        let arena = Arena::new();
        let bias = vec![0.0f32; 4];
        let epi = Epilogue { bias: &bias, act: None, res: None };
        let y1 = conv2d_same_packed(&x, &pc, 1, Some(&epi), Some(&arena));
        let m1 = arena.misses();
        assert!(m1 > 0, "first call must populate the arena");
        arena.give(y1.data); // the Value wrapper does this in production
        let y2 = conv2d_same_packed(&x, &pc, 1, Some(&epi), Some(&arena));
        assert_eq!(arena.misses(), m1, "second call must be allocation-free");
        assert!(arena.hits() > 0);
        let want = conv2d_same(&x, &w, 1, false);
        assert!(y2.max_abs_diff(&want) < 1e-6);
    }

    #[test]
    fn elementwise_into_variants_match() {
        let mut r = Rng::new(36);
        let a = randt(&mut r, &[2, 3, 3, 4]);
        let b = randt(&mut r, &[2, 3, 3, 4]);
        let mut add = Tensor::full(&a.dims.clone(), 9.9);
        add_into(&a, &b, &mut add);
        for (i, v) in add.data.iter().enumerate() {
            assert!((v - (a.data[i] + b.data[i])).abs() < 1e-6);
        }
        let mut act = Tensor::full(&a.dims.clone(), 9.9);
        act_into(&a, Act::Relu, &mut act);
        for (i, v) in act.data.iter().enumerate() {
            assert_eq!(*v, a.data[i].max(0.0));
        }
        let mut up = Tensor::full(&[2, 6, 6, 4], 9.9);
        upsample2x_into(&a, &mut up);
        assert_eq!(up.data, upsample2x(&a).data);
        let scale = vec![1.0f32; 4];
        let zero = vec![0.0f32; 4];
        let mut gn = Tensor::full(&a.dims.clone(), 9.9);
        group_norm_into(&a, &scale, &zero, 2, &mut gn);
        assert_eq!(gn.data, group_norm(&a, &scale, &zero, 2).data);
    }

    #[test]
    fn gemm_accumulates() {
        // C += A·B twice == 2·(A·B)
        let mut r = Rng::new(22);
        let (m, k, n) = (4, 6, 5);
        let a: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
        let mut once = vec![0.0f32; m * n];
        gemm(m, k, n, &a, &b, &mut once);
        let mut twice = vec![0.0f32; m * n];
        gemm(m, k, n, &a, &b, &mut twice);
        gemm(m, k, n, &a, &b, &mut twice);
        for (x, y) in once.iter().zip(&twice) {
            assert!((2.0 * x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn gemm_parallel_path_matches() {
        // Large enough to cross PAR_FLOP_MIN with LM_THREADS unset.
        let mut r = Rng::new(23);
        let (m, k, n) = (96, 130, 97); // k > KC exercises the k-blocking
        let a: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
        let mut want = vec![0.0f32; m * n];
        gemm_ref(m, k, n, &a, &b, &mut want);
        let mut got = vec![0.0f32; m * n];
        gemm(m, k, n, &a, &b, &mut got);
        let diff = want
            .iter()
            .zip(&got)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(diff < 1e-3, "diff {diff}");
    }

    #[test]
    fn conv_matches_oracle() {
        let mut r = Rng::new(24);
        for &(b, h, ci, co, k, s) in &[
            (1, 8, 3, 4, 3, 1),
            (2, 9, 2, 5, 3, 2),
            (1, 11, 4, 4, 5, 3),
            (2, 7, 1, 2, 1, 1),
            (1, 13, 6, 3, 7, 2),
        ] {
            let x = randt(&mut r, &[b, h, h, ci]);
            let w = randt(&mut r, &[co, ci, k, k]);
            let want = conv2d_valid_ref(&x, &w, s);
            let got = conv2d_valid(&x, &w, s);
            assert_eq!(got.dims, want.dims);
            assert!(
                got.max_abs_diff(&want) < 1e-3,
                "(b{b} h{h} ci{ci} co{co} k{k} s{s}) diff {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn conv_rectangular_input() {
        let mut r = Rng::new(25);
        let x = randt(&mut r, &[2, 10, 6, 3]);
        let w = randt(&mut r, &[4, 3, 3, 3]);
        let want = conv2d_valid_ref(&x, &w, 2);
        let got = conv2d_valid(&x, &w, 2);
        assert_eq!(got.dims, vec![2, 4, 2, 4]);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn same_pad_matches_xla_convention() {
        assert_eq!(same_pad(8, 3, 1), (1, 1)); // out 8, tot 2
        assert_eq!(same_pad(8, 3, 2), (0, 1)); // out 4, tot 1: low rounds down
        assert_eq!(same_pad(8, 1, 1), (0, 0));
        assert_eq!(same_pad(7, 5, 2), (1, 2)); // out 4, tot 3
    }

    #[test]
    fn conv_same_matches_manually_padded_valid() {
        let mut r = Rng::new(26);
        for &(b, h, ci, co, k, s) in
            &[(1, 8, 3, 4, 3, 1), (2, 8, 2, 3, 3, 2), (1, 7, 2, 2, 5, 2), (1, 6, 3, 5, 1, 1)]
        {
            let x = randt(&mut r, &[b, h, h, ci]);
            let w = randt(&mut r, &[co, ci, k, k]);
            let ph = same_pad(h, k, s);
            let want = conv2d_valid_ref(&pad2d(&x, ph, ph), &w, s);
            let got = conv2d_same(&x, &w, s, false);
            assert_eq!(got.dims, vec![b, h.div_ceil(s), h.div_ceil(s), co]);
            assert!(
                got.max_abs_diff(&want) < 1e-3,
                "(b{b} h{h} ci{ci} co{co} k{k} s{s}) diff {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn depthwise_matches_expanded_dense() {
        let mut r = Rng::new(27);
        for &(b, h, c, k, s) in &[(1, 8, 4, 3, 1), (2, 8, 6, 3, 2), (1, 9, 3, 5, 2)] {
            let x = randt(&mut r, &[b, h, h, c]);
            let w = randt(&mut r, &[c, 1, k, k]);
            let dense = crate::merge::expand_depthwise(&w);
            let want = conv2d_same(&x, &dense, s, false);
            let got = conv2d_same(&x, &w, s, true);
            assert_eq!(got.dims, want.dims);
            assert!(
                got.max_abs_diff(&want) < 1e-3,
                "(b{b} h{h} c{c} k{k} s{s}) diff {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn bias_act_res_matches_scalar_epilogue() {
        let mut r = Rng::new(28);
        let bias: Vec<f32> = (0..5).map(|_| r.normal()).collect();
        let res = randt(&mut r, &[2, 3, 3, 5]);
        for act in [None, Some(Act::Relu), Some(Act::Swish)] {
            for with_res in [false, true] {
                let y0 = randt(&mut r, &[2, 3, 3, 5]);
                let mut got = y0.clone();
                bias_act_res(&mut got, &bias, act, with_res.then_some(&res));
                for (i, (&v0, &g)) in y0.data.iter().zip(&got.data).enumerate() {
                    let mut want = v0 + bias[i % 5];
                    if with_res {
                        want += res.data[i];
                    }
                    if let Some(a) = act {
                        want = a.apply(want);
                    }
                    assert!((want - g).abs() < 1e-5, "act {act:?} res {with_res} idx {i}");
                }
            }
        }
    }

    #[test]
    fn group_norm_normalizes_per_group() {
        let mut r = Rng::new(29);
        let x = randt(&mut r, &[2, 4, 4, 8]);
        let ones = vec![1.0f32; 8];
        let zeros = vec![0.0f32; 8];
        let y = group_norm(&x, &ones, &zeros, 2);
        // each (batch, group) block must come out ~zero-mean unit-var
        for n in 0..2 {
            for g in 0..2 {
                let mut vals = Vec::new();
                for p in 0..16 {
                    for ci in g * 4..(g + 1) * 4 {
                        vals.push(y.data[(n * 16 + p) * 8 + ci]);
                    }
                }
                let m: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
                let v: f32 =
                    vals.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / vals.len() as f32;
                assert!(m.abs() < 1e-4, "mean {m}");
                assert!((v - 1.0).abs() < 1e-2, "var {v}");
            }
        }
    }

    #[test]
    fn upsample_repeats_pixels() {
        let x = Tensor::new(vec![1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]);
        let y = upsample2x(&x);
        assert_eq!(y.dims, vec![1, 4, 4, 1]);
        assert_eq!(
            y.data,
            vec![
                1.0, 1.0, 2.0, 2.0, 1.0, 1.0, 2.0, 2.0, //
                3.0, 3.0, 4.0, 4.0, 3.0, 3.0, 4.0, 4.0,
            ]
        );
    }

    #[test]
    fn mean_pool_dense_small() {
        // 1 batch, 2x1 spatial, 2 channels: pooled = [(1+3)/2, (2+4)/2]
        let x = Tensor::new(vec![1, 2, 1, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let w = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let y = mean_pool_dense(&x, &w, &[0.5, -0.5]);
        assert_eq!(y.dims, vec![1, 2]);
        assert!((y.data[0] - 2.5).abs() < 1e-6 && (y.data[1] - 2.5).abs() < 1e-6);
    }

    #[test]
    fn isa_name_tags_are_stable() {
        assert_eq!((Isa::Scalar.name(), Isa::Scalar.tag()), ("scalar", 0));
        assert_eq!((Isa::Avx2.name(), Isa::Avx2.tag()), ("avx2", 1));
        assert_eq!((Isa::Neon.name(), Isa::Neon.tag()), ("neon", 2));
        let avail = available_isas();
        assert_eq!(avail[0], Isa::Scalar, "scalar must always be available");
        assert!(avail.contains(&isa()) || isa() == Isa::Scalar);
    }

    #[test]
    fn forced_isa_kernels_match_scalar_with_epilogue() {
        // every hardware ISA against the scalar kernel, with the fused
        // epilogue engaged — FMA reassociation allows small drift
        let mut r = Rng::new(41);
        for &(m, k, n) in &[(1, 1, 1), (4, 16, 16), (5, 7, 17), (63, 129, 33), (96, 40, 64)] {
            let a: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
            let bias: Vec<f32> = (0..n).map(|_| r.normal()).collect();
            let res: Vec<f32> = (0..m * n).map(|_| r.normal()).collect();
            let bp = PackedB::pack(k, n, &b);
            let epi = Epilogue { bias: &bias, act: Some(Act::Swish), res: Some(&res[..]) };
            let mut want = vec![0.0f32; m * n];
            gemm_packed_epi_isa(Isa::Scalar, m, &a, &bp, &mut want, Some(&epi));
            for isa_sel in available_isas() {
                let mut got = vec![0.0f32; m * n];
                gemm_packed_epi_isa(isa_sel, m, &a, &bp, &mut got, Some(&epi));
                let diff = want
                    .iter()
                    .zip(&got)
                    .map(|(x, y)| (x - y).abs())
                    .fold(0.0f32, f32::max);
                assert!(diff < 1e-3, "{isa_sel:?} ({m},{k},{n}) diff {diff}");
            }
        }
    }

    #[test]
    fn packed_bi8_quantizes_per_column() {
        // col 0 spans [-2, 4] -> scale 4/127, col 1 all zero -> scale 1.0
        let b = vec![4.0f32, 0.0, -2.0, 0.0, 1.0, 0.0];
        let bp = PackedBI8::pack(3, 2, &b);
        assert_eq!((bp.k(), bp.n()), (3, 2));
        assert!((bp.scales()[0] - 4.0 / 127.0).abs() < 1e-7);
        assert_eq!(bp.scales()[1], 1.0);
        // the column max must quantize to exactly 127
        assert_eq!(bp.data[0], 127);
    }

    #[test]
    fn int8_gemm_tracks_f32_within_quant_tolerance() {
        let mut r = Rng::new(42);
        for &(m, k, n) in &[(1, 1, 1), (4, 16, 16), (5, 7, 17), (63, 129, 33)] {
            let a: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
            let mut want = vec![0.0f32; m * n];
            gemm_ref(m, k, n, &a, &b, &mut want);
            let bp = PackedBI8::pack(k, n, &b);
            let mut got = vec![0.0f32; m * n];
            gemm_packed_epi_i8(m, &a, &bp, &mut got, None, None);
            let diff = want
                .iter()
                .zip(&got)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            // two symmetric 8-bit quantizations, errors growing ~sqrt(k)
            let tol = 0.15 * (k as f32).sqrt() + 0.01;
            assert!(diff < tol, "int8 ({m},{k},{n}) diff {diff} > {tol}");
        }
    }

    #[test]
    fn int8_isa_kernels_match_scalar_int8_exactly() {
        // integer accumulation + identical dequant expression: every ISA
        // must agree with the scalar int8 kernel to f32 ulps, not just
        // within quantization noise
        let mut r = Rng::new(43);
        for &(m, k, n) in &[(3, 5, 17), (17, 129, 63), (64, 128, 48)] {
            let a: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
            let bp = PackedBI8::pack(k, n, &b);
            let mut want = vec![0.0f32; m * n];
            gemm_packed_epi_i8_isa(Isa::Scalar, m, &a, &bp, &mut want, None, None);
            for isa_sel in available_isas() {
                let mut got = vec![0.0f32; m * n];
                gemm_packed_epi_i8_isa(isa_sel, m, &a, &bp, &mut got, None, None);
                let diff = want
                    .iter()
                    .zip(&got)
                    .map(|(x, y)| (x - y).abs())
                    .fold(0.0f32, f32::max);
                assert!(diff < 1e-6, "{isa_sel:?} int8 ({m},{k},{n}) diff {diff}");
            }
        }
    }

    #[test]
    fn int8_conv_matches_f32_conv_within_tolerance() {
        let mut r = Rng::new(44);
        for &(b, h, ci, co, k, s) in &[(1, 8, 3, 4, 3, 1), (2, 9, 2, 5, 3, 2), (2, 7, 3, 2, 1, 1)] {
            let x = randt(&mut r, &[b, h, h, ci]);
            let w = randt(&mut r, &[co, ci, k, k]);
            let pc8 = PackedConv::pack_i8(&w);
            assert!(pc8.quantized() && !pc8.depthwise());
            assert_eq!((pc8.k(), pc8.out_channels()), (k, co));
            let want = conv2d_same(&x, &w, s, false);
            let got = conv2d_same_packed(&x, &pc8, s, None, None);
            assert_eq!(got.dims, want.dims);
            let scale = want.data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
            let diff = got.max_abs_diff(&want);
            assert!(diff < 0.05 * scale + 0.01, "(b{b} h{h} ci{ci} co{co} k{k} s{s}) diff {diff}");
        }
    }

    #[test]
    fn int8_conv_with_arena_hits_on_second_call() {
        use crate::util::arena::Arena;
        let mut r = Rng::new(45);
        let x = randt(&mut r, &[1, 9, 9, 3]);
        let w = randt(&mut r, &[4, 3, 3, 3]);
        let pc = PackedConv::pack_i8(&w);
        let arena = Arena::new();
        let bias = vec![0.0f32; 4];
        let epi = Epilogue { bias: &bias, act: None, res: None };
        let y1 = conv2d_same_packed(&x, &pc, 1, Some(&epi), Some(&arena));
        let m1 = arena.misses();
        assert!(m1 > 0, "first call must populate the arena");
        arena.give(y1.data);
        let y2 = conv2d_same_packed(&x, &pc, 1, Some(&epi), Some(&arena));
        assert_eq!(arena.misses(), m1, "second int8 call must be allocation-free");
        assert!(arena.hits() > 0);
        arena.give(y2.data);
    }
}
