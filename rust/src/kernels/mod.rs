//! Host compute kernels — cache-blocked parallel f32 GEMM and an
//! im2col-based VALID convolution.
//!
//! This is the deployment-time *host* hot path: the merge algebra
//! (`crate::merge`) composes span kernels out of per-tap matrix multiplies
//! over flat slices, and the numerics reports/oracles convolve merged
//! kernels on the host.  Both were 5–6-deep scalar loops before this
//! module existed (billions of scalar ops for ResNet-scale 512-channel
//! spans) — here they are expressed as GEMMs with contiguous,
//! vectorizable inner loops, parallelized over rows with
//! [`crate::util::par`].
//!
//! Layout conventions match the rest of the repo: activations are NHWC,
//! kernels are OIHW, everything row-major f32 (`util::tensor::Tensor`).
//! The naive reference implementations are retained as test oracles
//! ([`conv2d_valid_ref`], and `merge::merge_kernels_ref`) and as the
//! baseline side of `benches/merge_ops.rs`.

use crate::util::par;
use crate::util::tensor::Tensor;

/// Below this many FLOPs a GEMM runs serially — thread spawn would
/// dominate (scoped threads cost ~10µs each).
const PAR_FLOP_MIN: usize = 1 << 21;

/// Cache block over the contraction dimension: a block of B rows
/// (`KC x n` floats) stays resident while every C row sweeps it.
const KC: usize = 128;

fn gemm_threads(flops: usize) -> usize {
    if flops < PAR_FLOP_MIN {
        1
    } else {
        par::max_threads()
    }
}

/// `C += A · B` for row-major flat slices: A is `m x k`, B is `k x n`,
/// C is `m x n`.  Accumulating (`+=`) so callers can fold multiple
/// products into one buffer (the merge algebra's per-tap scatter does).
///
/// Parallel over row blocks of C, cache-blocked over k; the inner loop is
/// a contiguous axpy the compiler auto-vectorizes.  Zero entries of A are
/// skipped — identity/Dirac factors are common in span composition.
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "A is {m}x{k}");
    assert_eq!(b.len(), k * n, "B is {k}x{n}");
    assert_eq!(c.len(), m * n, "C is {m}x{n}");
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let threads = gemm_threads(2 * m * k * n);
    // ~4 chunks per thread keeps the atomic-claim queue balanced when row
    // costs vary (sparse A rows finish early).
    let rows_per = m.div_ceil(threads * 4).max(1);
    par::par_chunks_mut(c, rows_per * n, threads, |ci, chunk| {
        gemm_rows(ci * rows_per, chunk.len() / n, k, n, a, b, chunk);
    });
}

/// Serial kernel: rows `[r0, r0 + rows)` of C (passed as `c_chunk`).
fn gemm_rows(r0: usize, rows: usize, k: usize, n: usize, a: &[f32], b: &[f32], c_chunk: &mut [f32]) {
    let mut kb = 0;
    while kb < k {
        let kend = (kb + KC).min(k);
        for i in 0..rows {
            let arow = &a[(r0 + i) * k + kb..(r0 + i) * k + kend];
            let crow = &mut c_chunk[i * n..(i + 1) * n];
            for (p, &av) in arow.iter().enumerate() {
                if av != 0.0 {
                    let brow = &b[(kb + p) * n..(kb + p) * n + n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
        }
        kb = kend;
    }
}

/// VALID conv on host tensors via im2col + GEMM: `x` NHWC
/// `[B, H, W, Ci]`, `w` OIHW `[Co, Ci, k, k]`, output NHWC.
///
/// The im2col patch layout is `(a, b, c)` so each kernel row gathers as a
/// single contiguous `k*Ci` memcpy from the NHWC input, and the weight is
/// transposed once to `[(a, b, c), o]` so the product lands directly in
/// NHWC order.
pub fn conv2d_valid(x: &Tensor, w: &Tensor, stride: usize) -> Tensor {
    assert!(stride >= 1);
    let (bn, h, wd, ci) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (co, ci2, k) = (w.dims[0], w.dims[1], w.dims[2]);
    assert_eq!(ci, ci2, "channel mismatch: x {:?} vs w {:?}", x.dims, w.dims);
    assert_eq!(w.dims[2], w.dims[3], "square kernels only");
    assert!(h >= k && wd >= k, "input {h}x{wd} smaller than kernel {k}");
    let ho = (h - k) / stride + 1;
    let wo = (wd - k) / stride + 1;
    let kk = k * k * ci;
    let rows = bn * ho * wo;

    // im2col: one contiguous k*ci run per kernel row a.  Rows are batched
    // per parallel chunk (like gemm's row blocks) so the claim overhead
    // stays negligible next to the memcpys.
    let mut cols = vec![0.0f32; rows * kk];
    let threads = gemm_threads(rows * kk * 4);
    let rows_per = rows.div_ceil(threads * 4).max(1);
    par::par_chunks_mut(&mut cols, rows_per * kk, threads, |chunk_idx, dst| {
        let row0 = chunk_idx * rows_per;
        for (ri, drow) in dst.chunks_mut(kk).enumerate() {
            let row = row0 + ri;
            let n = row / (ho * wo);
            let r = row % (ho * wo);
            let (p, q) = (r / wo, r % wo);
            for a in 0..k {
                let src = ((n * h + p * stride + a) * wd + q * stride) * ci;
                drow[a * k * ci..(a + 1) * k * ci]
                    .copy_from_slice(&x.data[src..src + k * ci]);
            }
        }
    });

    // weight: OIHW -> [(a, b, c), o]
    let mut wt = vec![0.0f32; kk * co];
    for o in 0..co {
        for c in 0..ci {
            for a in 0..k {
                for b in 0..k {
                    wt[((a * k + b) * ci + c) * co + o] = w.data[((o * ci + c) * k + a) * k + b];
                }
            }
        }
    }

    let mut y = Tensor::zeros(&[bn, ho, wo, co]);
    gemm(rows, kk, co, &cols, &wt, &mut y.data);
    y
}

/// Naive triple-loop `C += A · B` — the GEMM test oracle (shared by the
/// unit tests here and `tests/gemm_parity.rs`; same role as
/// [`conv2d_valid_ref`]).  O(m·k·n) scalar ops; never call on hot paths.
pub fn gemm_ref(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..m {
        for p in 0..k {
            for j in 0..n {
                c[i * n + j] += a[i * k + p] * b[p * n + j];
            }
        }
    }
}

/// Direct 6-loop VALID conv — retained as the test oracle and the naive
/// baseline in `benches/merge_ops.rs` (formerly the `#[cfg(test)]` oracle
/// inside `merge`).
pub fn conv2d_valid_ref(x: &Tensor, w: &Tensor, stride: usize) -> Tensor {
    let (b, h, wd, ci) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (co, ci2, k) = (w.dims[0], w.dims[1], w.dims[2]);
    assert_eq!(ci, ci2);
    let ho = (h - k) / stride + 1;
    let wo = (wd - k) / stride + 1;
    let mut y = Tensor::zeros(&[b, ho, wo, co]);
    for n in 0..b {
        for p in 0..ho {
            for q in 0..wo {
                for o in 0..co {
                    let mut acc = 0.0;
                    for c in 0..ci {
                        for a in 0..k {
                            for bb in 0..k {
                                acc += x.at4(n, p * stride + a, q * stride + bb, c)
                                    * w.at4(o, c, a, bb);
                            }
                        }
                    }
                    y.set4(n, p, q, o, acc);
                }
            }
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randt(r: &mut Rng, dims: &[usize]) -> Tensor {
        let n: usize = dims.iter().product();
        Tensor::new(dims.to_vec(), (0..n).map(|_| r.normal()).collect())
    }

    #[test]
    fn gemm_matches_naive() {
        let mut r = Rng::new(21);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 4), (17, 33, 9), (64, 200, 48)] {
            let a: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
            let mut want = vec![0.0f32; m * n];
            gemm_ref(m, k, n, &a, &b, &mut want);
            let mut got = vec![0.0f32; m * n];
            gemm(m, k, n, &a, &b, &mut got);
            let diff = want
                .iter()
                .zip(&got)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(diff < 1e-4, "({m},{k},{n}) diff {diff}");
        }
    }

    #[test]
    fn gemm_accumulates() {
        // C += A·B twice == 2·(A·B)
        let mut r = Rng::new(22);
        let (m, k, n) = (4, 6, 5);
        let a: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
        let mut once = vec![0.0f32; m * n];
        gemm(m, k, n, &a, &b, &mut once);
        let mut twice = vec![0.0f32; m * n];
        gemm(m, k, n, &a, &b, &mut twice);
        gemm(m, k, n, &a, &b, &mut twice);
        for (x, y) in once.iter().zip(&twice) {
            assert!((2.0 * x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn gemm_parallel_path_matches() {
        // Large enough to cross PAR_FLOP_MIN with LM_THREADS unset.
        let mut r = Rng::new(23);
        let (m, k, n) = (96, 130, 97); // k > KC exercises the k-blocking
        let a: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
        let mut want = vec![0.0f32; m * n];
        gemm_ref(m, k, n, &a, &b, &mut want);
        let mut got = vec![0.0f32; m * n];
        gemm(m, k, n, &a, &b, &mut got);
        let diff = want
            .iter()
            .zip(&got)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(diff < 1e-3, "diff {diff}");
    }

    #[test]
    fn conv_matches_oracle() {
        let mut r = Rng::new(24);
        for &(b, h, ci, co, k, s) in &[
            (1, 8, 3, 4, 3, 1),
            (2, 9, 2, 5, 3, 2),
            (1, 11, 4, 4, 5, 3),
            (2, 7, 1, 2, 1, 1),
            (1, 13, 6, 3, 7, 2),
        ] {
            let x = randt(&mut r, &[b, h, h, ci]);
            let w = randt(&mut r, &[co, ci, k, k]);
            let want = conv2d_valid_ref(&x, &w, s);
            let got = conv2d_valid(&x, &w, s);
            assert_eq!(got.dims, want.dims);
            assert!(
                got.max_abs_diff(&want) < 1e-3,
                "(b{b} h{h} ci{ci} co{co} k{k} s{s}) diff {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn conv_rectangular_input() {
        let mut r = Rng::new(25);
        let x = randt(&mut r, &[2, 10, 6, 3]);
        let w = randt(&mut r, &[4, 3, 3, 3]);
        let want = conv2d_valid_ref(&x, &w, 2);
        let got = conv2d_valid(&x, &w, 2);
        assert_eq!(got.dims, vec![2, 4, 2, 4]);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }
}
