//! Host compute kernels — cache-blocked parallel f32 GEMM, an
//! im2col-based VALID convolution, and the full op set the native host
//! backend (`runtime::HostBackend`) needs to execute a lowered plan with
//! zero XLA dependency: SAME-padded (optionally depthwise) conv, the
//! fused bias+activation+residual epilogue, group norm, 2x nearest
//! upsampling, single-head spatial attention, and the mean-pool + dense
//! classifier head.
//!
//! This is the deployment-time *host* hot path: the merge algebra
//! (`crate::merge`) composes span kernels out of per-tap matrix multiplies
//! over flat slices, and the numerics reports/oracles convolve merged
//! kernels on the host.  Both were 5–6-deep scalar loops before this
//! module existed (billions of scalar ops for ResNet-scale 512-channel
//! spans) — here they are expressed as GEMMs with contiguous,
//! vectorizable inner loops, parallelized over rows with
//! [`crate::util::par`].
//!
//! Layout conventions match the rest of the repo: activations are NHWC,
//! kernels are OIHW, everything row-major f32 (`util::tensor::Tensor`).
//! The naive reference implementations are retained as test oracles
//! ([`conv2d_valid_ref`], and `merge::merge_kernels_ref`) and as the
//! baseline side of `benches/merge_ops.rs`; the host-backend op variants
//! are pinned against naive oracles by `tests/host_backend.rs`.

use crate::util::par;
use crate::util::tensor::Tensor;

/// Below this many FLOPs a GEMM runs serially — thread spawn would
/// dominate (scoped threads cost ~10µs each).
const PAR_FLOP_MIN: usize = 1 << 21;

/// Cache block over the contraction dimension: a block of B rows
/// (`KC x n` floats) stays resident while every C row sweeps it.
const KC: usize = 128;

fn gemm_threads(flops: usize) -> usize {
    if flops < PAR_FLOP_MIN {
        1
    } else {
        par::max_threads()
    }
}

/// `C += A · B` for row-major flat slices: A is `m x k`, B is `k x n`,
/// C is `m x n`.  Accumulating (`+=`) so callers can fold multiple
/// products into one buffer (the merge algebra's per-tap scatter does).
///
/// Parallel over row blocks of C, cache-blocked over k; the inner loop is
/// a contiguous axpy the compiler auto-vectorizes.  Zero entries of A are
/// skipped — identity/Dirac factors are common in span composition.
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "A is {m}x{k}");
    assert_eq!(b.len(), k * n, "B is {k}x{n}");
    assert_eq!(c.len(), m * n, "C is {m}x{n}");
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let threads = gemm_threads(2 * m * k * n);
    // ~4 chunks per thread keeps the atomic-claim queue balanced when row
    // costs vary (sparse A rows finish early).
    let rows_per = m.div_ceil(threads * 4).max(1);
    par::par_chunks_mut(c, rows_per * n, threads, |ci, chunk| {
        gemm_rows(ci * rows_per, chunk.len() / n, k, n, a, b, chunk);
    });
}

/// Serial kernel: rows `[r0, r0 + rows)` of C (passed as `c_chunk`).
fn gemm_rows(r0: usize, rows: usize, k: usize, n: usize, a: &[f32], b: &[f32], c_chunk: &mut [f32]) {
    let mut kb = 0;
    while kb < k {
        let kend = (kb + KC).min(k);
        for i in 0..rows {
            let arow = &a[(r0 + i) * k + kb..(r0 + i) * k + kend];
            let crow = &mut c_chunk[i * n..(i + 1) * n];
            for (p, &av) in arow.iter().enumerate() {
                if av != 0.0 {
                    let brow = &b[(kb + p) * n..(kb + p) * n + n];
                    for (cv, &bv) in crow.iter_mut().zip(brow) {
                        *cv += av * bv;
                    }
                }
            }
        }
        kb = kend;
    }
}

/// VALID conv on host tensors via im2col + GEMM: `x` NHWC
/// `[B, H, W, Ci]`, `w` OIHW `[Co, Ci, k, k]`, output NHWC.
///
/// The im2col patch layout is `(a, b, c)` so each kernel row gathers as a
/// single contiguous `k*Ci` memcpy from the NHWC input, and the weight is
/// transposed once to `[(a, b, c), o]` so the product lands directly in
/// NHWC order.
pub fn conv2d_valid(x: &Tensor, w: &Tensor, stride: usize) -> Tensor {
    assert!(stride >= 1);
    let (bn, h, wd, ci) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (co, ci2, k) = (w.dims[0], w.dims[1], w.dims[2]);
    assert_eq!(ci, ci2, "channel mismatch: x {:?} vs w {:?}", x.dims, w.dims);
    assert_eq!(w.dims[2], w.dims[3], "square kernels only");
    assert!(h >= k && wd >= k, "input {h}x{wd} smaller than kernel {k}");
    let ho = (h - k) / stride + 1;
    let wo = (wd - k) / stride + 1;
    let kk = k * k * ci;
    let rows = bn * ho * wo;

    // im2col: one contiguous k*ci run per kernel row a.  Rows are batched
    // per parallel chunk (like gemm's row blocks) so the claim overhead
    // stays negligible next to the memcpys.
    let mut cols = vec![0.0f32; rows * kk];
    let threads = gemm_threads(rows * kk * 4);
    let rows_per = rows.div_ceil(threads * 4).max(1);
    par::par_chunks_mut(&mut cols, rows_per * kk, threads, |chunk_idx, dst| {
        let row0 = chunk_idx * rows_per;
        for (ri, drow) in dst.chunks_mut(kk).enumerate() {
            let row = row0 + ri;
            let n = row / (ho * wo);
            let r = row % (ho * wo);
            let (p, q) = (r / wo, r % wo);
            for a in 0..k {
                let src = ((n * h + p * stride + a) * wd + q * stride) * ci;
                drow[a * k * ci..(a + 1) * k * ci]
                    .copy_from_slice(&x.data[src..src + k * ci]);
            }
        }
    });

    // weight: OIHW -> [(a, b, c), o]
    let mut wt = vec![0.0f32; kk * co];
    for o in 0..co {
        for c in 0..ci {
            for a in 0..k {
                for b in 0..k {
                    wt[((a * k + b) * ci + c) * co + o] = w.data[((o * ci + c) * k + a) * k + b];
                }
            }
        }
    }

    let mut y = Tensor::zeros(&[bn, ho, wo, co]);
    gemm(rows, kk, co, &cols, &wt, &mut y.data);
    y
}

/// Naive triple-loop `C += A · B` — the GEMM test oracle (shared by the
/// unit tests here and `tests/gemm_parity.rs`; same role as
/// [`conv2d_valid_ref`]).  O(m·k·n) scalar ops; never call on hot paths.
pub fn gemm_ref(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    for i in 0..m {
        for p in 0..k {
            for j in 0..n {
                c[i * n + j] += a[i * k + p] * b[p * n + j];
            }
        }
    }
}

/// Direct 6-loop VALID conv — retained as the test oracle and the naive
/// baseline in `benches/merge_ops.rs` (formerly the `#[cfg(test)]` oracle
/// inside `merge`).
pub fn conv2d_valid_ref(x: &Tensor, w: &Tensor, stride: usize) -> Tensor {
    let (b, h, wd, ci) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (co, ci2, k) = (w.dims[0], w.dims[1], w.dims[2]);
    assert_eq!(ci, ci2);
    let ho = (h - k) / stride + 1;
    let wo = (wd - k) / stride + 1;
    let mut y = Tensor::zeros(&[b, ho, wo, co]);
    for n in 0..b {
        for p in 0..ho {
            for q in 0..wo {
                for o in 0..co {
                    let mut acc = 0.0;
                    for c in 0..ci {
                        for a in 0..k {
                            for bb in 0..k {
                                acc += x.at4(n, p * stride + a, q * stride + bb, c)
                                    * w.at4(o, c, a, bb);
                            }
                        }
                    }
                    y.set4(n, p, q, o, acc);
                }
            }
        }
    }
    y
}

// ---------------------------------------------------------------------------
// Host-backend op set (runtime::HostBackend dispatches onto these)
// ---------------------------------------------------------------------------

/// Activation kinds the deployment stack knows — mirrors the AOT conv
/// artifact variants (`fa_relu` / `fa_swish` / `far_*`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    Relu,
    Swish,
}

impl Act {
    /// The artifact-variant spelling ("relu" / "swish").
    pub fn name(&self) -> &'static str {
        match self {
            Act::Relu => "relu",
            Act::Swish => "swish",
        }
    }

    /// Parse the spec's activation string; "none" is not an `Act` — model
    /// it as `Option<Act>::None` at the call site.
    pub fn parse(s: &str) -> Option<Act> {
        match s {
            "relu" => Some(Act::Relu),
            "swish" => Some(Act::Swish),
            _ => None,
        }
    }

    #[inline]
    pub fn apply(&self, x: f32) -> f32 {
        match self {
            Act::Relu => x.max(0.0),
            Act::Swish => x / (1.0 + (-x).exp()),
        }
    }
}

/// XLA/TF "SAME" padding split for one spatial dim: total padding is
/// `max((ceil(n/s) - 1) * s + k - n, 0)`, low half rounded down.
fn same_pad(n: usize, k: usize, stride: usize) -> (usize, usize) {
    let out = n.div_ceil(stride);
    let tot = ((out - 1) * stride + k).saturating_sub(n);
    (tot / 2, tot - tot / 2)
}

/// Zero-pad NHWC spatially (parallel per-batch row copies).
fn pad2d(x: &Tensor, ph: (usize, usize), pw: (usize, usize)) -> Tensor {
    let (bn, h, wd, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (hp, wp) = (h + ph.0 + ph.1, wd + pw.0 + pw.1);
    let mut out = Tensor::zeros(&[bn, hp, wp, c]);
    let plane = hp * wp * c;
    let threads = par::auto_threads(out.data.len());
    par::par_chunks_mut(&mut out.data, plane, threads, |n, dst| {
        for i in 0..h {
            let src = ((n * h + i) * wd) * c;
            let d0 = ((ph.0 + i) * wp + pw.0) * c;
            dst[d0..d0 + wd * c].copy_from_slice(&x.data[src..src + wd * c]);
        }
    });
    out
}

/// SAME conv on host tensors, matching the AOT `conv` artifacts exactly:
/// `x` NHWC, `w` OIHW (`[C, 1, k, k]` when `depthwise`), output spatial
/// dims `ceil(in / stride)`.  Dense goes through im2col + GEMM; depthwise
/// runs a direct tap-accumulated kernel (expanding to a diagonal dense
/// kernel would be CxC memory for C useful rows).
pub fn conv2d_same(x: &Tensor, w: &Tensor, stride: usize, depthwise: bool) -> Tensor {
    let (h, wd) = (x.dims[1], x.dims[2]);
    let k = w.dims[2];
    let ph = same_pad(h, k, stride);
    let pw = same_pad(wd, k, stride);
    let padded;
    let xr = if ph.0 + ph.1 + pw.0 + pw.1 == 0 {
        x
    } else {
        padded = pad2d(x, ph, pw);
        &padded
    };
    if depthwise {
        depthwise_conv2d_valid(xr, w, stride)
    } else {
        conv2d_valid(xr, w, stride)
    }
}

/// VALID depthwise conv: `x` NHWC `[B, H, W, C]`, `w` `[C, 1, k, k]`.
/// Per tap, the inner loop is a contiguous fused multiply-add over the
/// channel dim; parallel over output-row blocks.
fn depthwise_conv2d_valid(x: &Tensor, w: &Tensor, stride: usize) -> Tensor {
    let (bn, h, wd, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let (cw, one, k) = (w.dims[0], w.dims[1], w.dims[2]);
    assert_eq!(one, 1, "depthwise kernel must be [C,1,k,k]");
    assert_eq!(cw, c, "channel mismatch: x {:?} vs w {:?}", x.dims, w.dims);
    let ho = (h - k) / stride + 1;
    let wo = (wd - k) / stride + 1;
    // weight transposed once to tap-major [k*k, c] so the inner loop is
    // contiguous over channels
    let mut wt = vec![0.0f32; k * k * c];
    for ch in 0..c {
        for a in 0..k {
            for b2 in 0..k {
                wt[(a * k + b2) * c + ch] = w.data[(ch * k + a) * k + b2];
            }
        }
    }
    let mut y = Tensor::zeros(&[bn, ho, wo, c]);
    let rows = bn * ho;
    let threads = gemm_threads(2 * rows * wo * c * k * k);
    let rows_per = rows.div_ceil(threads * 4).max(1);
    par::par_chunks_mut(&mut y.data, rows_per * wo * c, threads, |ci, chunk| {
        let r0 = ci * rows_per;
        for (ri, drow) in chunk.chunks_mut(wo * c).enumerate() {
            let row = r0 + ri;
            let n = row / ho;
            let p = row % ho;
            for a in 0..k {
                let iy = p * stride + a;
                for b2 in 0..k {
                    let wtap = &wt[(a * k + b2) * c..][..c];
                    for q in 0..wo {
                        let src = ((n * h + iy) * wd + q * stride + b2) * c;
                        let xrow = &x.data[src..src + c];
                        let d = &mut drow[q * c..(q + 1) * c];
                        for ((dv, &xv), &wv) in d.iter_mut().zip(xrow).zip(wtap) {
                            *dv += xv * wv;
                        }
                    }
                }
            }
        }
    });
    y
}

/// Fused conv epilogue — `y = act(y + bias (+ res))`, in place, parallel
/// over pixel blocks.  This is the host twin of the `fa_*` / `far_*`
/// fused artifact variants (one pass over the output instead of three).
pub fn bias_act_res(y: &mut Tensor, bias: &[f32], act: Option<Act>, res: Option<&Tensor>) {
    let c = *y.dims.last().expect("bias_act_res needs a channel dim");
    assert_eq!(bias.len(), c, "bias length vs channel dim");
    if let Some(r) = res {
        assert_eq!(r.dims, y.dims, "residual shape mismatch");
    }
    let rows = y.data.len() / c;
    let threads = par::auto_threads(y.data.len());
    let rows_per = rows.div_ceil(threads * 4).max(1);
    let rdata = res.map(|r| &r.data[..]);
    par::par_chunks_mut(&mut y.data, rows_per * c, threads, |ci, chunk| {
        let base = ci * rows_per * c;
        for (pi, px) in chunk.chunks_mut(c).enumerate() {
            let roff = base + pi * c;
            for (o, v) in px.iter_mut().enumerate() {
                let mut acc = *v + bias[o];
                if let Some(rd) = rdata {
                    acc += rd[roff + o];
                }
                *v = match act {
                    Some(a) => a.apply(acc),
                    None => acc,
                };
            }
        }
    });
}

/// Elementwise activation in place (parallel) — the host twin of the
/// `relu_*` / `swish_*` elementwise artifacts.
pub fn act_inplace(y: &mut Tensor, act: Act) {
    let threads = par::auto_threads(y.data.len());
    let chunk = y.data.len().div_ceil(threads * 4).max(1);
    par::par_chunks_mut(&mut y.data, chunk, threads, |_, c| {
        for v in c {
            *v = act.apply(*v);
        }
    });
}

/// Group norm over NHWC, matching `python/compile/model.py::group_norm`:
/// per (batch, group) statistics over (H, W, C/groups), eps 1e-5,
/// per-channel scale + bias.  Parallel over batch elements.
pub fn group_norm(x: &Tensor, scale: &[f32], bias: &[f32], groups: usize) -> Tensor {
    let (bn, h, wd, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    assert!(groups >= 1 && c % groups == 0, "channels {c} not divisible into {groups} groups");
    assert_eq!(scale.len(), c);
    assert_eq!(bias.len(), c);
    let cg = c / groups;
    let hw = h * wd;
    let plane = hw * c;
    let mut y = Tensor::zeros(&[bn, h, wd, c]);
    let threads = par::auto_threads(x.data.len());
    par::par_chunks_mut(&mut y.data, plane, threads, |n, out| {
        let xin = &x.data[n * plane..(n + 1) * plane];
        for g in 0..groups {
            let c0 = g * cg;
            let (mut sum, mut sq) = (0.0f64, 0.0f64);
            for p in 0..hw {
                for v in &xin[p * c + c0..p * c + c0 + cg] {
                    let v = *v as f64;
                    sum += v;
                    sq += v * v;
                }
            }
            let cnt = (hw * cg) as f64;
            let mean = sum / cnt;
            let var = (sq / cnt - mean * mean).max(0.0);
            let inv = 1.0 / (var + 1e-5).sqrt();
            for p in 0..hw {
                for (o, v) in xin[p * c + c0..p * c + c0 + cg].iter().enumerate() {
                    let ci = c0 + o;
                    out[p * c + ci] =
                        ((*v as f64 - mean) * inv) as f32 * scale[ci] + bias[ci];
                }
            }
        }
    });
    y
}

/// 2x nearest-neighbour upsampling (NHWC) — each pixel's channel block is
/// copied twice along W, each expanded row twice along H.
pub fn upsample2x(x: &Tensor) -> Tensor {
    let (bn, h, wd, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    let mut y = Tensor::zeros(&[bn, 2 * h, 2 * wd, c]);
    let orow = 2 * wd * c;
    let threads = par::auto_threads(y.data.len());
    par::par_chunks_mut(&mut y.data, 2 * orow, threads, |r, chunk| {
        let n = r / h;
        let i = r % h;
        let src = ((n * h + i) * wd) * c;
        let (row0, row1) = chunk.split_at_mut(orow);
        for q in 0..wd {
            let px = &x.data[src + q * c..src + (q + 1) * c];
            row0[2 * q * c..(2 * q + 1) * c].copy_from_slice(px);
            row0[(2 * q + 1) * c..(2 * q + 2) * c].copy_from_slice(px);
        }
        row1.copy_from_slice(row0);
    });
    y
}

/// Single-head self-attention over spatial positions with residual,
/// matching `model.py::attention`: `softmax(q kᵀ / sqrt(c)) v @ wout + x`.
/// All four matrix products run on [`gemm`].
pub fn attention(x: &Tensor, wqkv: &Tensor, wout: &Tensor) -> Tensor {
    let (bn, h, wd, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    assert_eq!(wqkv.dims, vec![c, 3 * c], "wqkv must be [C, 3C]");
    assert_eq!(wout.dims, vec![c, c], "wout must be [C, C]");
    let s = h * wd;
    let mut qkv = vec![0.0f32; bn * s * 3 * c];
    gemm(bn * s, c, 3 * c, &x.data, &wqkv.data, &mut qkv);
    let scale = 1.0 / (c as f32).sqrt();
    let mut y = x.clone();
    let mut q = vec![0.0f32; s * c];
    let mut kt = vec![0.0f32; c * s];
    let mut v = vec![0.0f32; s * c];
    let mut att = vec![0.0f32; s * s];
    let mut av = vec![0.0f32; s * c];
    let mut out = vec![0.0f32; s * c];
    for n in 0..bn {
        for i in 0..s {
            let row = &qkv[(n * s + i) * 3 * c..][..3 * c];
            q[i * c..(i + 1) * c].copy_from_slice(&row[..c]);
            for (ci, &kv) in row[c..2 * c].iter().enumerate() {
                kt[ci * s + i] = kv; // K transposed for the q·kᵀ GEMM
            }
            v[i * c..(i + 1) * c].copy_from_slice(&row[2 * c..]);
        }
        att.fill(0.0);
        gemm(s, c, s, &q, &kt, &mut att);
        for row in att.chunks_mut(s) {
            let mut mx = f32::NEG_INFINITY;
            for val in row.iter_mut() {
                *val *= scale;
                mx = mx.max(*val);
            }
            let mut sum = 0.0f32;
            for val in row.iter_mut() {
                *val = (*val - mx).exp();
                sum += *val;
            }
            for val in row.iter_mut() {
                *val /= sum;
            }
        }
        av.fill(0.0);
        gemm(s, s, c, &att, &v, &mut av);
        out.fill(0.0);
        gemm(s, c, c, &av, &wout.data, &mut out);
        for (a, b2) in y.data[n * s * c..(n + 1) * s * c].iter_mut().zip(&out) {
            *a += *b2;
        }
    }
    y
}

/// Classifier head: global mean pool over (H, W) then a dense layer —
/// `x.mean(axis=(1,2)) @ w + b`, `w` `[C, classes]`.
pub fn mean_pool_dense(x: &Tensor, w: &Tensor, b: &[f32]) -> Tensor {
    let (bn, h, wd, c) = (x.dims[0], x.dims[1], x.dims[2], x.dims[3]);
    assert_eq!(w.dims[0], c, "head weight rows vs channels");
    let classes = w.dims[1];
    assert_eq!(b.len(), classes);
    let hw = (h * wd) as f32;
    let mut pooled = vec![0.0f32; bn * c];
    for n in 0..bn {
        let dst = &mut pooled[n * c..(n + 1) * c];
        for p in 0..h * wd {
            let src = &x.data[(n * h * wd + p) * c..][..c];
            for (d, &s) in dst.iter_mut().zip(src) {
                *d += s;
            }
        }
        for d in dst.iter_mut() {
            *d /= hw;
        }
    }
    let mut y = Tensor::zeros(&[bn, classes]);
    gemm(bn, c, classes, &pooled, &w.data, &mut y.data);
    for row in y.data.chunks_mut(classes) {
        for (v, &bb) in row.iter_mut().zip(b) {
            *v += bb;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randt(r: &mut Rng, dims: &[usize]) -> Tensor {
        let n: usize = dims.iter().product();
        Tensor::new(dims.to_vec(), (0..n).map(|_| r.normal()).collect())
    }

    #[test]
    fn gemm_matches_naive() {
        let mut r = Rng::new(21);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 4), (17, 33, 9), (64, 200, 48)] {
            let a: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
            let mut want = vec![0.0f32; m * n];
            gemm_ref(m, k, n, &a, &b, &mut want);
            let mut got = vec![0.0f32; m * n];
            gemm(m, k, n, &a, &b, &mut got);
            let diff = want
                .iter()
                .zip(&got)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(diff < 1e-4, "({m},{k},{n}) diff {diff}");
        }
    }

    #[test]
    fn gemm_accumulates() {
        // C += A·B twice == 2·(A·B)
        let mut r = Rng::new(22);
        let (m, k, n) = (4, 6, 5);
        let a: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
        let mut once = vec![0.0f32; m * n];
        gemm(m, k, n, &a, &b, &mut once);
        let mut twice = vec![0.0f32; m * n];
        gemm(m, k, n, &a, &b, &mut twice);
        gemm(m, k, n, &a, &b, &mut twice);
        for (x, y) in once.iter().zip(&twice) {
            assert!((2.0 * x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn gemm_parallel_path_matches() {
        // Large enough to cross PAR_FLOP_MIN with LM_THREADS unset.
        let mut r = Rng::new(23);
        let (m, k, n) = (96, 130, 97); // k > KC exercises the k-blocking
        let a: Vec<f32> = (0..m * k).map(|_| r.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| r.normal()).collect();
        let mut want = vec![0.0f32; m * n];
        gemm_ref(m, k, n, &a, &b, &mut want);
        let mut got = vec![0.0f32; m * n];
        gemm(m, k, n, &a, &b, &mut got);
        let diff = want
            .iter()
            .zip(&got)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(diff < 1e-3, "diff {diff}");
    }

    #[test]
    fn conv_matches_oracle() {
        let mut r = Rng::new(24);
        for &(b, h, ci, co, k, s) in &[
            (1, 8, 3, 4, 3, 1),
            (2, 9, 2, 5, 3, 2),
            (1, 11, 4, 4, 5, 3),
            (2, 7, 1, 2, 1, 1),
            (1, 13, 6, 3, 7, 2),
        ] {
            let x = randt(&mut r, &[b, h, h, ci]);
            let w = randt(&mut r, &[co, ci, k, k]);
            let want = conv2d_valid_ref(&x, &w, s);
            let got = conv2d_valid(&x, &w, s);
            assert_eq!(got.dims, want.dims);
            assert!(
                got.max_abs_diff(&want) < 1e-3,
                "(b{b} h{h} ci{ci} co{co} k{k} s{s}) diff {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn conv_rectangular_input() {
        let mut r = Rng::new(25);
        let x = randt(&mut r, &[2, 10, 6, 3]);
        let w = randt(&mut r, &[4, 3, 3, 3]);
        let want = conv2d_valid_ref(&x, &w, 2);
        let got = conv2d_valid(&x, &w, 2);
        assert_eq!(got.dims, vec![2, 4, 2, 4]);
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn same_pad_matches_xla_convention() {
        assert_eq!(same_pad(8, 3, 1), (1, 1)); // out 8, tot 2
        assert_eq!(same_pad(8, 3, 2), (0, 1)); // out 4, tot 1: low rounds down
        assert_eq!(same_pad(8, 1, 1), (0, 0));
        assert_eq!(same_pad(7, 5, 2), (1, 2)); // out 4, tot 3
    }

    #[test]
    fn conv_same_matches_manually_padded_valid() {
        let mut r = Rng::new(26);
        for &(b, h, ci, co, k, s) in
            &[(1, 8, 3, 4, 3, 1), (2, 8, 2, 3, 3, 2), (1, 7, 2, 2, 5, 2), (1, 6, 3, 5, 1, 1)]
        {
            let x = randt(&mut r, &[b, h, h, ci]);
            let w = randt(&mut r, &[co, ci, k, k]);
            let ph = same_pad(h, k, s);
            let want = conv2d_valid_ref(&pad2d(&x, ph, ph), &w, s);
            let got = conv2d_same(&x, &w, s, false);
            assert_eq!(got.dims, vec![b, h.div_ceil(s), h.div_ceil(s), co]);
            assert!(
                got.max_abs_diff(&want) < 1e-3,
                "(b{b} h{h} ci{ci} co{co} k{k} s{s}) diff {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn depthwise_matches_expanded_dense() {
        let mut r = Rng::new(27);
        for &(b, h, c, k, s) in &[(1, 8, 4, 3, 1), (2, 8, 6, 3, 2), (1, 9, 3, 5, 2)] {
            let x = randt(&mut r, &[b, h, h, c]);
            let w = randt(&mut r, &[c, 1, k, k]);
            let dense = crate::merge::expand_depthwise(&w);
            let want = conv2d_same(&x, &dense, s, false);
            let got = conv2d_same(&x, &w, s, true);
            assert_eq!(got.dims, want.dims);
            assert!(
                got.max_abs_diff(&want) < 1e-3,
                "(b{b} h{h} c{c} k{k} s{s}) diff {}",
                got.max_abs_diff(&want)
            );
        }
    }

    #[test]
    fn bias_act_res_matches_scalar_epilogue() {
        let mut r = Rng::new(28);
        let bias: Vec<f32> = (0..5).map(|_| r.normal()).collect();
        let res = randt(&mut r, &[2, 3, 3, 5]);
        for act in [None, Some(Act::Relu), Some(Act::Swish)] {
            for with_res in [false, true] {
                let y0 = randt(&mut r, &[2, 3, 3, 5]);
                let mut got = y0.clone();
                bias_act_res(&mut got, &bias, act, with_res.then_some(&res));
                for (i, (&v0, &g)) in y0.data.iter().zip(&got.data).enumerate() {
                    let mut want = v0 + bias[i % 5];
                    if with_res {
                        want += res.data[i];
                    }
                    if let Some(a) = act {
                        want = a.apply(want);
                    }
                    assert!((want - g).abs() < 1e-5, "act {act:?} res {with_res} idx {i}");
                }
            }
        }
    }

    #[test]
    fn group_norm_normalizes_per_group() {
        let mut r = Rng::new(29);
        let x = randt(&mut r, &[2, 4, 4, 8]);
        let ones = vec![1.0f32; 8];
        let zeros = vec![0.0f32; 8];
        let y = group_norm(&x, &ones, &zeros, 2);
        // each (batch, group) block must come out ~zero-mean unit-var
        for n in 0..2 {
            for g in 0..2 {
                let mut vals = Vec::new();
                for p in 0..16 {
                    for ci in g * 4..(g + 1) * 4 {
                        vals.push(y.data[(n * 16 + p) * 8 + ci]);
                    }
                }
                let m: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
                let v: f32 =
                    vals.iter().map(|x| (x - m) * (x - m)).sum::<f32>() / vals.len() as f32;
                assert!(m.abs() < 1e-4, "mean {m}");
                assert!((v - 1.0).abs() < 1e-2, "var {v}");
            }
        }
    }

    #[test]
    fn upsample_repeats_pixels() {
        let x = Tensor::new(vec![1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]);
        let y = upsample2x(&x);
        assert_eq!(y.dims, vec![1, 4, 4, 1]);
        assert_eq!(
            y.data,
            vec![
                1.0, 1.0, 2.0, 2.0, 1.0, 1.0, 2.0, 2.0, //
                3.0, 3.0, 4.0, 4.0, 3.0, 3.0, 4.0, 4.0,
            ]
        );
    }

    #[test]
    fn mean_pool_dense_small() {
        // 1 batch, 2x1 spatial, 2 channels: pooled = [(1+3)/2, (2+4)/2]
        let x = Tensor::new(vec![1, 2, 1, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let w = Tensor::new(vec![2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let y = mean_pool_dense(&x, &w, &[0.5, -0.5]);
        assert_eq!(y.dims, vec![1, 2]);
        assert!((y.data[0] - 2.5).abs() < 1e-6 && (y.data[1] - 2.5).abs() < 1e-6);
    }
}
