//! Network IR — the Rust-side view of `artifacts/specs/<model>.spec.json`.
//!
//! The spec is the single source of truth emitted by `python/compile/specs.py`;
//! this module adds the combinatorics LayerMerge needs on top of it:
//!
//! * the irreducible set R and the merge-barrier segments (Sec. 3.1 / App. A),
//! * `valid_span` — the skip-addition nesting rule (App. A),
//! * `kernel_options` — the achievable merged kernel sizes K_ij (Eq. 1 with
//!   the stride-dilation generalization),
//! * gate-vector construction for the table entries (A~_ij, C~_ijk of Eq. 3/4)
//!   and for full solutions (A*, C*).

use std::collections::BTreeSet;
use std::path::Path;

use crate::util::json::Json;

pub mod synth;

/// Largest merged kernel size considered anywhere in the stack.
/// MUST match `python/compile/specs.py::K_MAX` (cross-checked by
/// `tests/ir_python_parity.rs` against the artifact manifest).
pub const K_MAX: usize = 13;

#[derive(Debug, Clone)]
pub struct AddProj {
    pub k: usize,
    pub stride: usize,
    pub cin: usize,
    pub cout: usize,
}

#[derive(Debug, Clone)]
pub struct ConvLayer {
    pub idx: usize, // 1-based, the paper's l
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub depthwise: bool,
    pub h_in: usize,
    pub w_in: usize,
    pub act: String,
    pub act_gated: bool,
    pub conv_gated: bool,
    pub barrier_after: bool,
    pub barrier_reason: String,
    pub add_from: Option<usize>,
    pub add_proj: Option<AddProj>,
    pub concat_from: Option<String>,
    pub stash_as: Option<String>,
    pub gn: bool,
    pub gn_groups: usize,
    pub time_bias: bool,
}

impl ConvLayer {
    pub fn h_out(&self) -> usize {
        self.h_in / self.stride
    }

    pub fn w_out(&self) -> usize {
        self.w_in / self.stride
    }
}

#[derive(Debug, Clone)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Task {
    Classify,
    Diffusion,
}

#[derive(Debug, Clone)]
pub struct Spec {
    pub name: String,
    pub task: Task,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub batch: usize,
    pub num_classes: usize,
    pub head_hidden: usize,
    pub time_dim: usize,
    pub param_count: usize,
    pub convs: Vec<ConvLayer>,
    pub params: Vec<ParamEntry>,
}

impl Spec {
    pub fn load(path: &Path) -> anyhow::Result<Spec> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        Ok(Spec::from_json(&j))
    }

    pub fn from_json(j: &Json) -> Spec {
        let inp = j.req("input");
        let convs = j
            .req("convs")
            .as_arr()
            .expect("convs[]")
            .iter()
            .map(|c| ConvLayer {
                idx: c.req("idx").as_usize().unwrap(),
                cin: c.req("cin").as_usize().unwrap(),
                cout: c.req("cout").as_usize().unwrap(),
                k: c.req("k").as_usize().unwrap(),
                stride: c.req("stride").as_usize().unwrap(),
                depthwise: c.req("depthwise").as_bool().unwrap(),
                h_in: c.req("h_in").as_usize().unwrap(),
                w_in: c.req("w_in").as_usize().unwrap(),
                act: c.req("act").as_str().unwrap().to_string(),
                act_gated: c.req("act_gated").as_bool().unwrap(),
                conv_gated: c.req("conv_gated").as_bool().unwrap(),
                barrier_after: c.req("barrier_after").as_bool().unwrap(),
                barrier_reason: c.req("barrier_reason").as_str().unwrap().to_string(),
                add_from: c.req("add_from").as_usize(),
                add_proj: c.get("add_proj").and_then(|p| {
                    p.as_obj().map(|_| AddProj {
                        k: p.req("k").as_usize().unwrap(),
                        stride: p.req("stride").as_usize().unwrap(),
                        cin: p.req("cin").as_usize().unwrap(),
                        cout: p.req("cout").as_usize().unwrap(),
                    })
                }),
                concat_from: c.req("concat_from").as_str().map(String::from),
                stash_as: c.req("stash_as").as_str().map(String::from),
                gn: c.req("gn").as_bool().unwrap(),
                gn_groups: c.req("gn_groups").as_usize().unwrap(),
                time_bias: c.req("time_bias").as_bool().unwrap(),
            })
            .collect();
        let params = j
            .req("params")
            .as_arr()
            .expect("params[]")
            .iter()
            .map(|p| ParamEntry {
                name: p.req("name").as_str().unwrap().to_string(),
                shape: p
                    .req("shape")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|d| d.as_usize().unwrap())
                    .collect(),
                offset: p.req("offset").as_usize().unwrap(),
                size: p.req("size").as_usize().unwrap(),
            })
            .collect();
        Spec {
            name: j.req("name").as_str().unwrap().to_string(),
            task: match j.req("task").as_str().unwrap() {
                "classify" => Task::Classify,
                "diffusion" => Task::Diffusion,
                t => panic!("unknown task {t}"),
            },
            h: inp.req("h").as_usize().unwrap(),
            w: inp.req("w").as_usize().unwrap(),
            c: inp.req("c").as_usize().unwrap(),
            batch: inp.req("batch").as_usize().unwrap(),
            num_classes: j.req("num_classes").as_usize().unwrap(),
            head_hidden: j.req("head_hidden").as_usize().unwrap(),
            time_dim: j.req("time_dim").as_usize().unwrap(),
            param_count: j.req("param_count").as_usize().unwrap(),
            convs,
            params,
        }
    }

    pub fn len(&self) -> usize {
        self.convs.len()
    }

    pub fn conv(&self, idx: usize) -> &ConvLayer {
        &self.convs[idx - 1]
    }

    pub fn param(&self, name: &str) -> &ParamEntry {
        self.params
            .iter()
            .find(|p| p.name == name)
            .unwrap_or_else(|| panic!("no param {name}"))
    }

    pub fn param_slice<'a>(&self, flat: &'a [f32], name: &str) -> &'a [f32] {
        let p = self.param(name);
        &flat[p.offset..p.offset + p.size]
    }

    /// The irreducible set R (Sec. 3.1).
    pub fn irreducible(&self) -> Vec<usize> {
        self.convs.iter().filter(|c| !c.conv_gated).map(|c| c.idx).collect()
    }

    // ------------------------------------------------------------------
    // Segments and spans
    // ------------------------------------------------------------------

    /// Maximal merge-allowed segments [s, e] of 1-based conv indices
    /// (cut at barriers and skip-concatenation inputs).
    pub fn segments(&self) -> Vec<(usize, usize)> {
        let mut segs = Vec::new();
        let mut start = 1;
        for c in &self.convs {
            let next_concat = self
                .convs
                .get(c.idx) // idx is 1-based => convs[idx] is the next layer
                .map(|n| n.concat_from.is_some())
                .unwrap_or(false);
            if c.barrier_after || c.idx == self.len() || next_concat {
                segs.push((start, c.idx));
                start = c.idx + 1;
            }
        }
        segs
    }

    /// Skip-addition nesting rule (App. A; mirrors specs.py::valid_span).
    /// A span is invalid if an add lands strictly inside it with an
    /// external source, or if it swallows a source boundary whose add
    /// point lies beyond the span.  An add landing exactly at the span
    /// end executes externally on materialized boundary tensors.
    pub fn valid_span(&self, i: usize, j: usize) -> bool {
        for c in &self.convs {
            if let Some(af) = c.add_from {
                let (p_src, q) = (af - 1, c.idx);
                if p_src < i && i < q && q < j {
                    return false;
                }
                if i < p_src && p_src < j && j < q {
                    return false;
                }
            }
        }
        true
    }

    /// All (i, j) span boundaries within one segment with i < j, valid.
    pub fn spans(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for (s, e) in self.segments() {
            for i in (s - 1)..e {
                for j in (i + 1)..=e {
                    if self.valid_span(i, j) {
                        out.push((i, j));
                    }
                }
            }
        }
        out
    }

    /// Stride product of convs i+1 .. l-1 — the dilation factor layer l's
    /// taps acquire pulled back to the span input (App. A).
    pub fn stride_prefix(&self, i: usize, l: usize) -> usize {
        (i + 1..l).map(|m| self.conv(m).stride).product()
    }

    /// Total stride of the span (i, j].
    pub fn span_stride(&self, i: usize, j: usize) -> usize {
        (i + 1..=j).map(|m| self.conv(m).stride).product()
    }

    /// Is the merged layer over (i, j] depthwise? (true iff every layer in
    /// the span is depthwise — merging a depthwise conv with a dense one
    /// produces a dense layer; tracked per App. A.)
    pub fn span_depthwise(&self, i: usize, j: usize) -> bool {
        (i + 1..=j).all(|l| self.conv(l).depthwise)
            && self.conv(i + 1).cin == self.conv(j).cout
    }

    /// Kernel-size increment layer l contributes if kept in span starting
    /// at i: (k_l - 1) * prod(strides before it in the span).
    pub fn k_increment(&self, i: usize, l: usize) -> usize {
        (self.conv(l).k - 1) * self.stride_prefix(i, l)
    }

    /// K_ij: achievable merged kernel sizes over span (i, j], as subset
    /// sums of increments with irreducible layers forced (Sec. 3.2),
    /// capped at K_MAX.
    pub fn kernel_options(&self, i: usize, j: usize) -> Vec<usize> {
        let mut sums: BTreeSet<usize> = BTreeSet::new();
        sums.insert(0);
        let mut forced = 0usize;
        for l in (i + 1)..=j {
            let inc = self.k_increment(i, l);
            if !self.conv(l).conv_gated {
                forced += inc;
            } else if inc > 0 {
                let cur: Vec<usize> = sums.iter().copied().collect();
                for s in cur {
                    sums.insert(s + inc);
                }
            }
        }
        sums.iter()
            .map(|s| 1 + s + forced)
            .filter(|&k| k <= K_MAX)
            .collect()
    }

    // ------------------------------------------------------------------
    // Gate vectors
    // ------------------------------------------------------------------

    /// Pristine gates: the original network. For acts this is 1 where an
    /// activation exists (act != "none") and 0 otherwise; convs and gn all 1.
    pub fn pristine_gates(&self) -> Gates {
        Gates {
            act: self
                .convs
                .iter()
                .map(|c| if c.act == "none" { 0.0 } else { 1.0 })
                .collect(),
            conv: vec![1.0; self.len()],
            gn: vec![1.0; self.len()],
        }
    }

    /// Gates realizing a full solution (A: kept activation indices,
    /// C: kept conv indices, spans: the solver's merged spans).
    ///
    /// * GroupNorm layers inside merged spans are pruned (gate 0); only
    ///   boundary norms survive (our variant of App. A's norm move).
    /// * The MobileNetV2 trick (App. A): a *multi-layer* span ending at a
    ///   pristine-linear position gets an activation added.  Singleton
    ///   spans keep their pristine (possibly absent) activation — an
    ///   unmerged layer is not "a merged layer" in the paper's sense.
    pub fn solution_gates(
        &self,
        a_set: &BTreeSet<usize>,
        c_set: &BTreeSet<usize>,
        spans: &[(usize, usize, usize)],
    ) -> Gates {
        let multi_end: BTreeSet<usize> =
            spans.iter().filter(|(i, j, _)| j - i > 1).map(|&(_, j, _)| j).collect();
        let mut g = self.pristine_gates();
        for c in &self.convs {
            let li = c.idx - 1;
            if c.act_gated {
                let kept = a_set.contains(&c.idx) && c.idx != self.len();
                g.act[li] = if kept && (c.act != "none" || multi_end.contains(&c.idx))
                {
                    1.0
                } else {
                    0.0
                };
            }
            if c.conv_gated {
                g.conv[li] = if c_set.contains(&c.idx) { 1.0 } else { 0.0 };
            }
            if c.gn {
                // keep gn only at span boundaries (kept activations count
                // as boundaries, as does the end of each segment)
                let boundary = !c.act_gated || a_set.contains(&c.idx)
                    || c.barrier_after
                    || c.idx == self.len();
                g.gn[li] = if boundary { 1.0 } else { 0.0 };
            }
        }
        g
    }

    /// Gates for a table entry: everything outside the span (i, j] pristine,
    /// inside the span activations removed (A~_ij of Eq. 3) and convs kept
    /// per `kept` (C~_ijk).  Multi-layer spans get the App. A added
    /// activation at their boundary when the pristine position is linear.
    pub fn entry_gates(&self, i: usize, j: usize, kept: &BTreeSet<usize>) -> Gates {
        let mut g = self.pristine_gates();
        for l in (i + 1)..=j {
            let c = self.conv(l);
            let li = l - 1;
            if l < j && c.act_gated {
                g.act[li] = 0.0;
            }
            if c.gn && l < j {
                g.gn[li] = 0.0;
            }
            if c.conv_gated {
                g.conv[li] = if kept.contains(&l) { 1.0 } else { 0.0 };
            }
        }
        let cj = self.conv(j);
        if j - i > 1 && j < self.len() && cj.act_gated && cj.act == "none" {
            g.act[j - 1] = 1.0;
        }
        g
    }
}

/// Gate vectors fed to the AOT gated graph (f32, 1.0 = keep).
#[derive(Debug, Clone, PartialEq)]
pub struct Gates {
    pub act: Vec<f32>,
    pub conv: Vec<f32>,
    pub gn: Vec<f32>,
}

impl Gates {
    /// Number of surviving merged layers implied by the act gates within
    /// segment structure — used for quick sanity reporting.
    pub fn kept_act_count(&self) -> usize {
        self.act.iter().filter(|&&g| g > 0.5).count()
    }

    pub fn kept_conv_count(&self) -> usize {
        self.conv.iter().filter(|&&g| g > 0.5).count()
    }
}

#[cfg(test)]
pub mod tests {
    use super::*;

    /// A hand-built 4-layer toy spec: conv1 (irreducible stem), conv2-3
    /// residual block, conv4.
    pub fn toy_spec() -> Spec {
        let mk = |idx, cin, cout, k, stride, gated, add_from: Option<usize>| ConvLayer {
            idx,
            cin,
            cout,
            k,
            stride,
            depthwise: false,
            h_in: 8,
            w_in: 8,
            act: "relu".into(),
            act_gated: idx != 4,
            conv_gated: gated,
            barrier_after: false,
            barrier_reason: String::new(),
            add_from,
            add_proj: None,
            concat_from: None,
            stash_as: None,
            gn: false,
            gn_groups: 0,
            time_bias: false,
        };
        let mut convs = vec![
            mk(1, 3, 4, 3, 1, false, None),
            mk(2, 4, 4, 3, 1, true, None),
            mk(3, 4, 4, 3, 1, true, Some(2)),
            mk(4, 4, 4, 1, 1, true, None),
        ];
        convs[3].act = "none".into(); // sigma_L = id
        Spec {
            name: "toy".into(),
            task: Task::Classify,
            h: 8,
            w: 8,
            c: 3,
            batch: 2,
            num_classes: 10,
            head_hidden: 4,
            time_dim: 0,
            param_count: 0,
            convs,
            params: vec![],
        }
    }

    /// Toy spec plus a deterministic flat parameter vector whose layout
    /// registers conv{l}.w / conv{l}.b — shared by the merge-module tests.
    pub fn toy_spec_with_params() -> (Spec, Vec<f32>) {
        let mut sp = toy_spec();
        let mut rng = crate::util::rng::Rng::new(0xbeef);
        let mut flat = Vec::new();
        let mut params = Vec::new();
        for c in &sp.convs {
            let wshape = vec![c.cout, c.cin, c.k, c.k];
            let wsize: usize = wshape.iter().product();
            params.push(ParamEntry {
                name: format!("conv{}.w", c.idx),
                shape: wshape,
                offset: flat.len(),
                size: wsize,
            });
            for _ in 0..wsize {
                flat.push(rng.normal() * 0.5);
            }
            params.push(ParamEntry {
                name: format!("conv{}.b", c.idx),
                shape: vec![c.cout],
                offset: flat.len(),
                size: c.cout,
            });
            for _ in 0..c.cout {
                flat.push(rng.normal() * 0.1);
            }
        }
        sp.params = params;
        sp.param_count = flat.len();
        (sp, flat)
    }

    #[test]
    fn segments_single() {
        let sp = toy_spec();
        assert_eq!(sp.segments(), vec![(1, 4)]);
        assert_eq!(sp.irreducible(), vec![1]);
    }

    #[test]
    fn valid_span_nesting() {
        let sp = toy_spec();
        // residual branch: source boundary 1, add point after conv 3
        assert!(sp.valid_span(1, 3)); // whole branch inside -> Dirac fold
        assert!(sp.valid_span(0, 4)); // superset -> fold
        assert!(sp.valid_span(2, 3)); // add at span end: external add, ok
        assert!(sp.valid_span(1, 2)); // source at boundary 1 == i+? ok:
                                      // i=1 < p_src=1 is false -> valid
        assert!(!sp.valid_span(0, 2)); // swallows source boundary 1, add
                                       // point 3 beyond the span
        assert!(!sp.valid_span(0, 3) == false); // q == j: fold, valid
    }

    #[test]
    fn kernel_options_subset_sums() {
        let sp = toy_spec();
        // span (1, 4]: layers 2,3,4 all gated, increments 2,2,0
        assert_eq!(sp.kernel_options(1, 4), vec![1, 3, 5]);
        // span (0, 4]: layer 1 forced (k=3 -> +2)
        assert_eq!(sp.kernel_options(0, 4), vec![3, 5, 7]);
    }

    #[test]
    fn entry_gates_match_paper_tilde_sets() {
        let sp = toy_spec();
        let kept: BTreeSet<usize> = [3].into_iter().collect();
        let g = sp.entry_gates(1, 4, &kept);
        // acts 2,3 removed, act 4 is sigma_L
        assert_eq!(g.act, vec![1.0, 0.0, 0.0, 0.0]);
        // conv 2 dropped, conv 3 kept, conv 4 dropped, conv1 untouched
        assert_eq!(g.conv, vec![1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn solution_gates_roundtrip() {
        let sp = toy_spec();
        let a: BTreeSet<usize> = [3].into_iter().collect();
        let c: BTreeSet<usize> = [1, 3].into_iter().collect();
        let g = sp.solution_gates(&a, &c, &[]);
        assert_eq!(g.act, vec![0.0, 0.0, 1.0, 0.0]);
        assert_eq!(g.conv, vec![1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn stride_dilation() {
        let mut sp = toy_spec();
        sp.convs[1].stride = 2; // conv2 strided
        sp.convs[1].conv_gated = false;
        assert_eq!(sp.stride_prefix(0, 3), 2);
        assert_eq!(sp.k_increment(0, 3), 4); // (3-1) * 2
        assert_eq!(sp.span_stride(0, 4), 2);
    }
}
