//! Synthetic specs built directly in Rust — deterministic networks (spec
//! + flat parameter vector) that need **no** Python AOT step, so the host
//! backend can lower, serve, and measure real plans from a fresh offline
//! checkout.  The topologies exercise the execution paths that matter:
//! chains (the residency-counter case), residual blocks with and without
//! strided 1x1 projections (boundary slots + external adds), and strides
//! (SAME-padding geometry).
//!
//! Parameters are He-initialized from a seeded [`Rng`], registered under
//! the same names the AOT specs use (`conv{l}.w` / `conv{l}.b` /
//! `proj{l}.w` / `head.w` ...), so `Plan::original`,
//! `Plan::from_solution` and `merge::span_merge` work unchanged.

use crate::ir::{AddProj, ConvLayer, ParamEntry, Spec, Task};
use crate::util::rng::Rng;

const NUM_CLASSES: usize = 10;

struct Builder {
    rng: Rng,
    convs: Vec<ConvLayer>,
    params: Vec<ParamEntry>,
    flat: Vec<f32>,
    /// geometry at each boundary: bounds[i] = (h, w, c) after layer i
    bounds: Vec<(usize, usize, usize)>,
    batch: usize,
}

impl Builder {
    fn new(h: usize, c: usize, batch: usize, seed: u64) -> Builder {
        Builder {
            rng: Rng::new(seed),
            convs: Vec::new(),
            params: Vec::new(),
            flat: Vec::new(),
            bounds: vec![(h, h, c)],
            batch,
        }
    }

    fn push_param(&mut self, name: String, shape: Vec<usize>, scale: f32) {
        let size: usize = shape.iter().product();
        self.params.push(ParamEntry { name, shape, offset: self.flat.len(), size });
        for _ in 0..size {
            let v = self.rng.normal() * scale;
            self.flat.push(v);
        }
    }

    /// Append a conv layer; `add_from` is the layer index whose *input*
    /// boundary feeds the skip (a 1x1 projection is registered
    /// automatically when geometry disagrees).
    fn conv(&mut self, cout: usize, k: usize, stride: usize, act: &str, add_from: Option<usize>) {
        let idx = self.convs.len() + 1;
        let (h_in, w_in, cin) = *self.bounds.last().unwrap();
        let scale = (2.0 / (cin * k * k) as f32).sqrt();
        self.push_param(format!("conv{idx}.w"), vec![cout, cin, k, k], scale);
        self.push_param(format!("conv{idx}.b"), vec![cout], 0.01);
        let (h_out, w_out) = (h_in.div_ceil(stride), w_in.div_ceil(stride));
        let add_proj = add_from.and_then(|af| {
            let (hs, _, cs) = self.bounds[af - 1];
            if cs == cout && hs == h_out {
                None
            } else {
                assert_eq!(hs % h_out, 0, "skip stride must divide evenly");
                let pstride = hs / h_out;
                let pscale = (2.0 / cs as f32).sqrt();
                self.push_param(format!("proj{af}.w"), vec![cout, cs, 1, 1], pscale);
                self.push_param(format!("proj{af}.b"), vec![cout], 0.01);
                Some(AddProj { k: 1, stride: pstride, cin: cs, cout })
            }
        });
        self.convs.push(ConvLayer {
            idx,
            cin,
            cout,
            k,
            stride,
            depthwise: false,
            h_in,
            w_in,
            act: act.to_string(),
            act_gated: true,
            conv_gated: idx != 1, // stem is irreducible
            barrier_after: false,
            barrier_reason: String::new(),
            add_from,
            add_proj,
            concat_from: None,
            stash_as: None,
            gn: false,
            gn_groups: 0,
            time_bias: false,
        });
        self.bounds.push((h_out, w_out, cout));
    }

    fn finish(mut self, name: &str, h: usize, c: usize) -> (Spec, Vec<f32>) {
        // sigma_L = id, pristine (mirrors the AOT classify specs)
        let last = self.convs.last_mut().expect("at least one layer");
        last.act = "none".to_string();
        last.act_gated = false;
        let head_hidden = self.bounds.last().unwrap().2;
        let hscale = (1.0 / head_hidden as f32).sqrt();
        self.push_param("head.w".to_string(), vec![head_hidden, NUM_CLASSES], hscale);
        self.push_param("head.b".to_string(), vec![NUM_CLASSES], 0.01);
        let spec = Spec {
            name: name.to_string(),
            task: Task::Classify,
            h,
            w: h,
            c,
            batch: self.batch,
            num_classes: NUM_CLASSES,
            head_hidden,
            time_dim: 0,
            param_count: self.flat.len(),
            convs: self.convs,
            params: self.params,
        };
        (spec, self.flat)
    }
}

/// Pure chain classifier: `depth` 3x3 convs (one stride-2 in the middle),
/// no residuals — every boundary is consumed by exactly the next step, so
/// a device-resident forward is exactly one upload + one download.
pub fn chain(name: &str, depth: usize, c: usize, h: usize, batch: usize) -> (Spec, Vec<f32>) {
    assert!(depth >= 2);
    let mut b = Builder::new(h, 3, batch, 0x5e_11 ^ depth as u64);
    b.conv(c, 3, 1, "relu", None);
    for l in 1..depth {
        let stride = if l == depth / 2 { 2 } else { 1 };
        b.conv(c, 3, stride, "relu", None);
    }
    b.finish(name, h, 3)
}

/// ResNet-style classifier: a stem plus `blocks` two-conv residual
/// blocks; every other block is strided and channel-doubling (its skip
/// goes through a 1x1 projection) — exercises boundary slots, external
/// adds, and projection dispatches.
pub fn resnet(name: &str, blocks: usize, c0: usize, h: usize, batch: usize) -> (Spec, Vec<f32>) {
    assert!(blocks >= 1);
    let mut b = Builder::new(h, 3, batch, 0x4e57 ^ blocks as u64);
    b.conv(c0, 3, 1, "relu", None);
    let mut c = c0;
    for bi in 0..blocks {
        let (stride, cout) = if bi % 2 == 1 { (2, c * 2) } else { (1, c) };
        let first = b.convs.len() + 1;
        b.conv(cout, 3, stride, "relu", None);
        b.conv(cout, 3, 1, "relu", Some(first));
        c = cout;
    }
    b.finish(name, h, 3)
}

/// Named synthetic specs for the CLI / benches / tests.
pub fn by_name(name: &str) -> Option<(Spec, Vec<f32>)> {
    match name {
        "hostchain" => Some(chain(name, 8, 24, 16, 8)),
        "hostchain-tiny" => Some(chain(name, 4, 6, 8, 2)),
        "hostnet" => Some(resnet(name, 4, 16, 16, 8)),
        "hostnet-tiny" => Some(resnet(name, 2, 8, 8, 2)),
        _ => None,
    }
}

/// The names `by_name` accepts (usage/docs).
pub const NAMES: [&str; 4] = ["hostnet", "hostnet-tiny", "hostchain", "hostchain-tiny"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_spec_is_consistent() {
        let (spec, flat) = by_name("hostchain-tiny").unwrap();
        assert_eq!(spec.len(), 4);
        assert_eq!(spec.param_count, flat.len());
        // geometry threads through: each layer's input is the previous output
        for l in 2..=spec.len() {
            let prev = spec.conv(l - 1);
            let cur = spec.conv(l);
            assert_eq!(cur.h_in, prev.h_out(), "layer {l} geometry");
            assert_eq!(cur.cin, prev.cout, "layer {l} channels");
        }
        assert_eq!(spec.head_hidden, spec.conv(spec.len()).cout);
        assert!(spec.convs.iter().all(|c| c.add_from.is_none()));
    }

    #[test]
    fn resnet_spec_has_projected_and_identity_skips() {
        let (spec, flat) = by_name("hostnet").unwrap();
        assert_eq!(spec.param_count, flat.len());
        let adds: Vec<_> = spec.convs.iter().filter(|c| c.add_from.is_some()).collect();
        assert_eq!(adds.len(), 4);
        assert!(adds.iter().any(|c| c.add_proj.is_some()), "strided block needs a proj");
        assert!(adds.iter().any(|c| c.add_proj.is_none()), "identity skip expected");
        // every registered param is addressable through the spec
        for p in &spec.params {
            assert_eq!(spec.param_slice(&flat, &p.name).len(), p.size);
        }
        // skip sources must be legal span boundaries for the greedy cover
        assert_eq!(spec.segments(), vec![(1, spec.len())]);
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(by_name("nope").is_none());
        for n in NAMES {
            assert!(by_name(n).is_some(), "{n}");
        }
    }
}
