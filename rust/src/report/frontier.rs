//! Speedup-vs-quality frontier sweep (the paper's Fig. 4 shape, offline):
//! for each budget fraction, solve with Algorithm 1, the predecessor's
//! two-stage DP, the LayerOnly knapsack — all on the *same* host-measured
//! tables — plus the HALP-style channel-pruning reference on its
//! analytical latency model, and emit one frontier row per (method,
//! budget) point.
//!
//! Quality here is the solver objective (kept importance mass / kept
//! saliency): a training-free proxy that makes the frontier rankable
//! without fine-tuning runs, which is exactly what the table-driven
//! surrogate problem promises.  Rows are written to EXPERIMENTS.md under
//! a stable `frontier:<model>` marker via [`super::record`].

use std::path::Path;

use anyhow::Result;

use crate::baselines::channel;
use crate::bench::TableOut;
use crate::ir::synth;
use crate::pipeline::{solve_tables, Method};
use crate::tables::{self, BuildCfg};

/// One (method, budget) point of the frontier.
#[derive(Debug, Clone)]
pub struct FrontierPoint {
    pub method: String,
    pub budget_frac: f64,
    /// Whether the solver found a plan inside the budget.
    pub feasible: bool,
    /// Predicted latency of the chosen plan, ms (table sum for the DP
    /// family; analytical for the channel reference).
    pub pred_ms: f64,
    /// Predicted speedup over the original network, same latency model.
    pub speedup: f64,
    /// Solver objective — kept importance (DP family) or kept saliency
    /// (channel); comparable within a method across budgets, not across
    /// methods.
    pub objective: f64,
    /// Deployed depth in merged spans (DP family) or conv layers
    /// (channel / infeasible).
    pub depth: usize,
}

/// The DP-family methods the sweep runs on shared tables.
pub const METHODS: [Method; 3] = [Method::LayerMerge, Method::TwoStage, Method::LayerOnly];

/// Sweep `fracs` on a synthetic spec with host-built tables (no XLA, no
/// artifacts).  Infeasible points are kept in the output with
/// `feasible: false` and the original network's latency, so the emitted
/// frontier shows *where* each method stops being able to compress.
pub fn sweep_host(
    model: &str,
    fracs: &[f64],
    cfg: &BuildCfg,
    p_disc: usize,
    cache_root: &Path,
) -> Result<Vec<FrontierPoint>> {
    let (spec, flat) = synth::by_name(model)
        .ok_or_else(|| anyhow::anyhow!("unknown synthetic spec {model}"))?;
    let backend: std::sync::Arc<dyn crate::runtime::Backend> =
        std::sync::Arc::new(crate::runtime::HostBackend::new());
    let t = tables::build_host(&spec, &flat, &backend, cfg, cache_root)?;
    let orig = t.orig_ms();
    // the channel reference lives on the analytical model — use its own
    // full-network latency as the speedup denominator so the ratio is
    // internally consistent
    let chan_full: f64 =
        (1..=spec.len()).map(|l| channel::layer_latency(&spec, l, 1.0, 1.0)).sum();

    let mut out = Vec::new();
    for &frac in fracs {
        for method in METHODS {
            match solve_tables(&spec, &t, method, frac, p_disc) {
                Ok(sol) => out.push(FrontierPoint {
                    method: method.name().to_string(),
                    budget_frac: frac,
                    feasible: true,
                    pred_ms: sol.latency_est,
                    speedup: orig / sol.latency_est.max(1e-9),
                    objective: sol.objective,
                    // spans the plan builder actually deploys (an
                    // identity span tabulated at 0 latency is elided)
                    depth: sol
                        .spans
                        .iter()
                        .filter(|s| {
                            t.entries.get(&(s.0, s.1, s.2)).map_or(true, |e| e.lat_ms > 0.0)
                        })
                        .count(),
                }),
                Err(_) => out.push(FrontierPoint {
                    method: method.name().to_string(),
                    budget_frac: frac,
                    feasible: false,
                    pred_ms: orig,
                    speedup: 1.0,
                    objective: 0.0,
                    depth: spec.len(),
                }),
            }
        }
        let cp = channel::solve_halp(&spec, &flat, frac, p_disc);
        out.push(FrontierPoint {
            method: "Channel".to_string(),
            budget_frac: frac,
            feasible: cp.latency_ms <= frac * chan_full + 1e-9,
            pred_ms: cp.latency_ms,
            speedup: chan_full / cp.latency_ms.max(1e-9),
            objective: cp.saliency,
            depth: spec.len(),
        });
    }
    Ok(out)
}

/// Render the frontier as a paper-style table.
pub fn table(model: &str, points: &[FrontierPoint]) -> TableOut {
    let mut t = TableOut::new(
        &format!("Speedup-quality frontier — {model} (host tables)"),
        &["Method", "Budget", "Pred ms", "Speed-up ↑", "Objective ↑", "Depth"],
    );
    for p in points {
        t.row(vec![
            if p.feasible { p.method.clone() } else { format!("{} (infeasible)", p.method) },
            format!("{:.0}%", p.budget_frac * 100.0),
            format!("{:.4}", p.pred_ms),
            format!("{:.2}x", p.speedup),
            format!("{:.4}", p.objective),
            format!("{}", p.depth),
        ]);
    }
    t
}

/// Sweep and persist to EXPERIMENTS.md under the `frontier:<model>`
/// marker; returns the points for the caller to print or assert on.
pub fn emit(
    model: &str,
    fracs: &[f64],
    cfg: &BuildCfg,
    p_disc: usize,
    cache_root: &Path,
    experiments_md: &Path,
) -> Result<Vec<FrontierPoint>> {
    let points = sweep_host(model, fracs, cfg, p_disc, cache_root)?;
    let t = table(model, &points);
    t.print();
    super::record(experiments_md, &format!("frontier:{model}"), &t.markdown())?;
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tables::LatencyMode;

    fn scratch() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("lm_frontier_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn sweep_covers_every_method_at_every_budget() {
        let cfg = BuildCfg { mode: LatencyMode::Analytical, force: true, ..BuildCfg::default() };
        let fracs = [0.6, 0.9];
        let pts = sweep_host("hostchain-tiny", &fracs, &cfg, 100, &scratch()).unwrap();
        assert_eq!(pts.len(), fracs.len() * (METHODS.len() + 1));
        for p in &pts {
            assert!(p.pred_ms > 0.0 && p.speedup > 0.0, "{p:?}");
        }
        // a looser budget can never force a *worse* objective (budget
        // monotonicity of every solver in the sweep)
        for m in ["LayerMerge", "TwoStage", "LayerOnly"] {
            let at = |f: f64| {
                pts.iter()
                    .find(|p| p.method == m && p.budget_frac == f)
                    .unwrap()
                    .clone()
            };
            let (tight, loose) = (at(0.6), at(0.9));
            if tight.feasible && loose.feasible {
                assert!(loose.objective >= tight.objective - 1e-9, "{m}");
            }
        }
    }

    #[test]
    fn table_renders_one_row_per_point() {
        let pts = vec![FrontierPoint {
            method: "LayerMerge".into(),
            budget_frac: 0.5,
            feasible: true,
            pred_ms: 1.0,
            speedup: 2.0,
            objective: 3.0,
            depth: 2,
        }];
        let t = table("toy", &pts);
        assert_eq!(t.rows.len(), 1);
        assert!(t.markdown().contains("2.00x"));
    }
}
