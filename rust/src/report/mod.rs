//! Experiment reporting: renders paper-style tables and appends them to
//! EXPERIMENTS.md with a stable section marker per experiment, so reruns
//! replace rather than duplicate.  [`frontier`] sweeps budget fractions
//! into speedup-vs-quality frontiers on host-built tables.

pub mod frontier;

use std::path::Path;

use anyhow::Result;

use crate::bench::TableOut;
use crate::pipeline::Compressed;

/// Replace (or append) the section `<!-- exp:ID -->...<!-- /exp:ID -->` in
/// EXPERIMENTS.md with `body`.
pub fn record(path: &Path, id: &str, body: &str) -> Result<()> {
    let begin = format!("<!-- exp:{id} -->");
    let end = format!("<!-- /exp:{id} -->");
    let existing = std::fs::read_to_string(path).unwrap_or_default();
    let section = format!("{begin}\n{}\n{end}", body.trim_end());
    let updated = if let (Some(b), Some(e)) = (existing.find(&begin), existing.find(&end)) {
        let mut s = existing.clone();
        s.replace_range(b..e + end.len(), &section);
        s
    } else {
        let mut s = existing;
        if !s.is_empty() && !s.ends_with('\n') {
            s.push('\n');
        }
        s.push_str(&section);
        s.push('\n');
        s
    };
    std::fs::write(path, updated)?;
    Ok(())
}

/// Format one Compressed result as a paper-table row.
pub fn row(c: &Compressed, orig_metric: f32, _orig_eager: f64, _orig_fused: f64,
           classify: bool) -> Vec<String> {
    let metric = if classify {
        format!("{:.2}", c.merged_metric * 100.0)
    } else {
        // diffusion: report FDD-style "lower is better" proxy = positive loss
        format!("{:.4}", -c.merged_metric)
    };
    vec![
        format!("{}-{:.0}%", c.method, c.budget_frac * 100.0),
        metric,
        // contemporaneous baselines (measured back-to-back with the plan)
        format!("{:.2}x", c.base_eager_ms / c.lat_eager_ms),
        format!("{:.2}x", c.base_fused_ms / c.lat_fused_ms),
        format!("{}", c.depth),
        format!("{:.2}", (c.merged_metric - orig_metric) * if classify { 100.0 } else { 1.0 }),
    ]
}

/// Standard header for compression tables.
pub fn compression_table(title: &str, classify: bool) -> TableOut {
    let metric = if classify { "Acc (%) ↑" } else { "DiffLoss ↓" };
    TableOut::new(
        title,
        &[
            "Network", metric, "Eager Speed-up ↑", "Fused Speed-up ↑",
            "Depth", "Δ vs orig",
        ],
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_replaces_section() {
        let dir = std::env::temp_dir().join("lm_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("EXPERIMENTS.md");
        let _ = std::fs::remove_file(&p);
        record(&p, "t1", "first body").unwrap();
        record(&p, "t2", "other").unwrap();
        record(&p, "t1", "second body").unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("second body"));
        assert!(!s.contains("first body"));
        assert!(s.contains("other"));
        assert_eq!(s.matches("exp:t1").count(), 2); // begin + end markers
    }
}
