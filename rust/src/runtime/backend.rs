//! The pluggable execution backend: opaque [`Value`] buffer handles, the
//! backend-independent op descriptors a lowered plan is made of, and the
//! [`Backend`] trait with its PJRT implementation.
//!
//! The contract `exec::CompiledPlan` builds on:
//!
//! * **Lowering** resolves every op once (`lower_op`) and uploads every
//!   weight-scale operand once (`upload`) — merged conv weights, biases,
//!   group-norm affines, projection/attention/head weights all become
//!   persistent [`Value`]s owned by the plan.
//! * **Dispatch** (`run`) consumes and produces [`Value`]s: activations
//!   flow between steps as backend-resident handles, never crossing the
//!   host boundary.
//! * **Transfers** happen only through `upload` / `download`, which keep
//!   monotonic counters — device residency is *asserted by tests*
//!   (`tests/host_backend.rs`: a chain-topology forward is exactly one
//!   upload + one download), not just claimed.
//!
//! [`PjrtBackend`] maps descriptors onto the AOT artifact inventory
//! (manifest signature keys -> compiled executables) and keeps buffers on
//! the PJRT device.  [`super::HostBackend`] interprets the same
//! descriptors on `crate::kernels` with zero XLA dependency.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::kernels::{Act, PackedConv};
use crate::model::{sig_str, Manifest};
use crate::runtime::{from_literal, Exec, Runtime};
use crate::util::arena::Arena;
use crate::util::tensor::Tensor;

/// A buffer owned by a backend: host tensor (plain or arena-recycled),
/// pre-packed host conv weight, or device-resident PJRT buffer.  Cloning
/// is a refcount bump — boundary slots, stash entries and residual
/// sources share one underlying buffer.  When the last reference to a
/// `Pooled` value drops, its buffer goes back to the backend arena — this
/// is how inter-step activations get recycled across forwards.
#[derive(Clone)]
pub struct Value(Arc<ValueInner>);

enum ValueInner {
    Host(Tensor),
    /// Arena-recycled host tensor: the data vector returns to `arena`
    /// when the last clone drops.
    Pooled { t: Tensor, arena: Arc<Arena> },
    /// A conv weight lowered once into its GEMM-ready layout
    /// (`kernels::PackedConv`); `dims` keeps the original OIHW shape for
    /// diagnostics.
    Packed { pc: PackedConv, dims: Vec<usize> },
    Device { buf: xla::PjRtBuffer, dims: Vec<usize> },
}

// SAFETY: PJRT device buffers are thread-safe in the underlying C++
// runtime (same argument as the markers on `Exec`/`Runtime`); the host
// variants are plain owned data.
unsafe impl Send for ValueInner {}
unsafe impl Sync for ValueInner {}

impl Drop for ValueInner {
    fn drop(&mut self) {
        if let ValueInner::Pooled { t, arena } = self {
            arena.give(std::mem::take(&mut t.data));
        }
    }
}

impl Value {
    pub fn host(t: Tensor) -> Value {
        Value(Arc::new(ValueInner::Host(t)))
    }

    /// An arena-recycled host tensor (see [`ValueInner::Pooled`]).
    pub(crate) fn pooled(t: Tensor, arena: Arc<Arena>) -> Value {
        Value(Arc::new(ValueInner::Pooled { t, arena }))
    }

    pub(crate) fn packed(pc: PackedConv, dims: Vec<usize>) -> Value {
        Value(Arc::new(ValueInner::Packed { pc, dims }))
    }

    pub(crate) fn device(buf: xla::PjRtBuffer, dims: Vec<usize>) -> Value {
        Value(Arc::new(ValueInner::Device { buf, dims }))
    }

    /// Logical dims, tracked host-side for every variant.
    pub fn dims(&self) -> &[usize] {
        match &*self.0 {
            ValueInner::Host(t) | ValueInner::Pooled { t, .. } => &t.dims,
            ValueInner::Packed { dims, .. } | ValueInner::Device { dims, .. } => dims,
        }
    }

    /// Borrow the host tensor (None for device-resident / packed values).
    pub fn as_host(&self) -> Option<&Tensor> {
        match &*self.0 {
            ValueInner::Host(t) | ValueInner::Pooled { t, .. } => Some(t),
            ValueInner::Packed { .. } | ValueInner::Device { .. } => None,
        }
    }

    /// Borrow the packed conv weight (None for every other variant).
    pub(crate) fn as_packed(&self) -> Option<&PackedConv> {
        match &*self.0 {
            ValueInner::Packed { pc, .. } => Some(pc),
            _ => None,
        }
    }

    fn as_device(&self) -> Result<&xla::PjRtBuffer> {
        match &*self.0 {
            ValueInner::Device { buf, .. } => Ok(buf),
            ValueInner::Packed { .. } => {
                anyhow::bail!("packed host weight passed to a device-resident dispatch")
            }
            ValueInner::Host(_) | ValueInner::Pooled { .. } => {
                anyhow::bail!("host value passed to a device-resident dispatch")
            }
        }
    }
}

impl std::fmt::Debug for Value {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &*self.0 {
            ValueInner::Host(t) => write!(f, "Value::Host{:?}", t.dims),
            ValueInner::Pooled { t, .. } => write!(f, "Value::Pooled{:?}", t.dims),
            ValueInner::Packed { dims, .. } => write!(f, "Value::Packed{dims:?}"),
            ValueInner::Device { dims, .. } => write!(f, "Value::Device{dims:?}"),
        }
    }
}

/// Backend-independent description of one dispatchable op.  Mirrors the
/// AOT artifact families 1:1 (that is what makes the PJRT backend a pure
/// table lookup) and carries exactly the shape/attribute info the host
/// kernels need.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum OpDesc {
    /// SAME conv + bias, optionally fused with an activation and/or a
    /// residual add (the `plain` / `fa_*` / `far_*` artifact variants).
    /// Args: `(x, w, bias[, res])`.
    Conv {
        b: usize,
        h: usize,
        w: usize,
        cin: usize,
        cout: usize,
        k: usize,
        stride: usize,
        depthwise: bool,
        act: Option<Act>,
        residual: bool,
    },
    /// Group norm at the given geometry.  Args: `(x, scale, bias)`.
    GroupNorm { b: usize, h: usize, w: usize, c: usize, groups: usize },
    /// Elementwise add.  Args: `(x, y)`.
    Add { b: usize, h: usize, w: usize, c: usize },
    /// Elementwise activation.  Args: `(x)`.
    Activation { act: Act, b: usize, h: usize, w: usize, c: usize },
    /// Single-head spatial self-attention with residual.
    /// Args: `(x, wqkv, wout)`.
    Attention { b: usize, h: usize, w: usize, c: usize },
    /// 2x nearest upsampling.  Args: `(x)`.
    Upsample { b: usize, h: usize, w: usize, c: usize },
    /// Classifier head (mean pool + dense); `model` names the per-model
    /// AOT artifact.  Args: `(x, w, bias)`.
    Head { b: usize, h: usize, w: usize, hidden: usize, classes: usize, model: String },
}

impl OpDesc {
    /// Output dims — the host-side shape bookkeeping for device values.
    pub fn out_dims(&self) -> Vec<usize> {
        match self {
            OpDesc::Conv { b, h, w, cout, stride, .. } => {
                vec![*b, h.div_ceil(*stride), w.div_ceil(*stride), *cout]
            }
            OpDesc::GroupNorm { b, h, w, c, .. }
            | OpDesc::Add { b, h, w, c }
            | OpDesc::Activation { b, h, w, c, .. }
            | OpDesc::Attention { b, h, w, c } => vec![*b, *h, *w, *c],
            OpDesc::Upsample { b, h, w, c } => vec![*b, 2 * h, 2 * w, *c],
            OpDesc::Head { b, classes, .. } => vec![*b, *classes],
        }
    }

    /// Expected argument count (used by the host interpreter's checks).
    pub fn arity(&self) -> usize {
        match self {
            OpDesc::Conv { residual, .. } => 3 + usize::from(*residual),
            OpDesc::GroupNorm { .. } | OpDesc::Attention { .. } | OpDesc::Head { .. } => 3,
            OpDesc::Add { .. } => 2,
            OpDesc::Activation { .. } | OpDesc::Upsample { .. } => 1,
        }
    }
}

/// One lowered op: the descriptor plus (for PJRT) the resolved compiled
/// executable.  The host backend interprets the descriptor directly.
pub struct OpHandle {
    pub desc: OpDesc,
    exec: Option<Arc<Exec>>,
}

impl OpHandle {
    pub(crate) fn host(desc: OpDesc) -> OpHandle {
        OpHandle { desc, exec: None }
    }
}

/// The numeric format a backend lowers **weights** into at
/// `upload_weight` time.  Activations are f32 in every format — `Int8`
/// means dense conv weights are symmetric per-output-channel quantized
/// at lowering ([`crate::kernels::PackedConv::pack_i8`]) and dequantized
/// inside the GEMM epilogue, so everything above the kernel boundary
/// (exec, serve, fleet, chaos) is format-oblivious.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WeightFormat {
    #[default]
    F32,
    Int8,
}

impl WeightFormat {
    /// Stable lowercase spelling ("f32" / "int8") for CLI flags, profile
    /// / e2e output and the serve `/stats` frame.
    pub fn name(&self) -> &'static str {
        match self {
            WeightFormat::F32 => "f32",
            WeightFormat::Int8 => "int8",
        }
    }

    pub fn parse(s: &str) -> Option<WeightFormat> {
        match s {
            "f32" => Some(WeightFormat::F32),
            "int8" => Some(WeightFormat::Int8),
            _ => None,
        }
    }

    /// Process default: `LM_WEIGHT_FORMAT` (set by the `--weight-format`
    /// CLI flag), falling back to f32.  An unknown value falls back to
    /// f32 rather than erroring — the env var is a deployment knob, not
    /// an API.
    pub fn from_env() -> WeightFormat {
        std::env::var("LM_WEIGHT_FORMAT")
            .ok()
            .and_then(|v| WeightFormat::parse(&v))
            .unwrap_or_default()
    }

    /// Small stable integer for fingerprint mixing and weight-cache keys.
    pub fn tag(&self) -> u64 {
        match self {
            WeightFormat::F32 => 0,
            WeightFormat::Int8 => 1,
        }
    }
}

/// A runtime backend the lowered execution plans dispatch through.  Both
/// implementations are `Send + Sync`, so a `CompiledPlan` stays shareable
/// across serving workers.
///
/// Implementations may also be *decorators* over another backend —
/// [`crate::serve::chaos::FaultBackend`] wraps any inner backend and
/// injects scheduled failures/delays/panics into [`Backend::run`] while
/// delegating everything else.  Callers must therefore assume `run` can
/// return an error **or panic** on any dispatch; the serving tier
/// isolates both per batch (`dispatch_batch` catches the unwind and
/// converts it into typed per-ticket errors).
pub trait Backend: Send + Sync {
    fn name(&self) -> &'static str;

    /// Host tensor -> backend-resident buffer.  Counted.
    fn upload(&self, t: &Tensor) -> Result<Value>;

    /// Upload a weight operand in the backend's preferred **execution
    /// layout** for `desc`.  The default is a plain [`Backend::upload`];
    /// the host backend pre-packs conv weights once here
    /// (im2col-transposed + panel-packed dense, tap-major depthwise) so
    /// the steady-state forward never re-transposes a weight.  Counted
    /// like any upload.
    fn upload_weight(&self, desc: &OpDesc, w: &Tensor) -> Result<Value> {
        let _ = desc;
        self.upload(w)
    }

    /// The weight format `upload_weight` lowers into.  Default f32; the
    /// host backend returns its construction-time knob.  Decorators must
    /// delegate so weight-cache keys and `/stats` attribution see the
    /// real format.
    fn weight_format(&self) -> WeightFormat {
        WeightFormat::F32
    }

    /// Backend-resident buffer -> host tensor.  Counted.
    fn download(&self, v: &Value) -> Result<Tensor>;

    /// Can this backend lower `desc` at all?  `false` means the op has no
    /// implementation here (e.g. an elementwise artifact the manifest
    /// never emitted) and the caller may plan a host fallback; a `true`
    /// followed by a `lower_op` error is a real failure (corrupt
    /// artifact, compile error) and must propagate.
    fn supports(&self, desc: &OpDesc) -> bool;

    /// Resolve an op descriptor once, at plan-lowering time.
    fn lower_op(&self, desc: &OpDesc) -> Result<OpHandle>;

    /// Execute a lowered op on backend-resident values.
    fn run(&self, op: &OpHandle, args: &[&Value]) -> Result<Value>;

    /// Total host->device transfers performed (monotonic).
    fn uploads(&self) -> usize;

    /// Total device->host transfers performed (monotonic).
    fn downloads(&self) -> usize;
}

// ---------------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------------

/// Device-resident execution over the AOT artifact inventory: `lower_op`
/// resolves manifest signature keys to compiled executables, `upload`
/// pins operands as persistent `PjRtBuffer`s, and `run` dispatches with
/// device buffers in and out — activations never round-trip the host
/// between steps.
pub struct PjrtBackend {
    rt: Arc<Runtime>,
    man: Arc<Manifest>,
    uploads: AtomicUsize,
    downloads: AtomicUsize,
}

impl PjrtBackend {
    pub fn new(rt: Arc<Runtime>, man: Arc<Manifest>) -> PjrtBackend {
        PjrtBackend { rt, man, uploads: AtomicUsize::new(0), downloads: AtomicUsize::new(0) }
    }

    fn resolve(&self, desc: &OpDesc) -> Result<String> {
        let ew = |key: String| {
            self.man
                .ew_art(&key)
                .with_context(|| format!("elementwise artifact {key}"))
        };
        match desc {
            OpDesc::Conv { b, h, w, cin, cout, k, stride, depthwise, act, residual } => {
                let sig = sig_str(*b, *h, *w, *cin, *cout, *k, *stride, *depthwise);
                let variant = match (act, residual) {
                    (Some(a), true) => format!("far_{}", a.name()),
                    (Some(a), false) => format!("fa_{}", a.name()),
                    (None, true) => "far_none".to_string(),
                    (None, false) => "plain".to_string(),
                };
                self.man
                    .conv_art(&sig, &variant)
                    .with_context(|| format!("conv artifact {sig}.{variant}"))
            }
            OpDesc::GroupNorm { b, h, w, c, groups } => {
                ew(format!("gn{groups}_b{b}h{h}w{w}c{c}"))
            }
            OpDesc::Add { b, h, w, c } => ew(format!("add_b{b}h{h}w{w}c{c}")),
            OpDesc::Activation { act, b, h, w, c } => {
                ew(format!("{}_b{b}h{h}w{w}c{c}", act.name()))
            }
            OpDesc::Attention { b, h, w, c } => ew(format!("attn_b{b}h{h}w{w}c{c}")),
            OpDesc::Upsample { b, h, w, c } => ew(format!("up_b{b}h{h}w{w}c{c}")),
            OpDesc::Head { model, .. } => ew(format!("head_{model}")),
        }
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn upload(&self, t: &Tensor) -> Result<Value> {
        let buf = self.rt.to_device(t)?;
        self.uploads.fetch_add(1, Ordering::Relaxed);
        Ok(Value::device(buf, t.dims.clone()))
    }

    fn download(&self, v: &Value) -> Result<Tensor> {
        self.downloads.fetch_add(1, Ordering::Relaxed);
        match v.as_host() {
            // a host value can only appear here through caller misuse;
            // still count it so the transfer ledger never under-reports
            Some(t) => Ok(t.clone()),
            None => {
                let buf = v.as_device()?;
                let lit = buf
                    .to_literal_sync()
                    .map_err(|e| anyhow::anyhow!("device->host: {e:?}"))?;
                from_literal(lit)
            }
        }
    }

    fn supports(&self, desc: &OpDesc) -> bool {
        self.resolve(desc).is_ok()
    }

    fn lower_op(&self, desc: &OpDesc) -> Result<OpHandle> {
        let rel = self.resolve(desc)?;
        Ok(OpHandle { desc: desc.clone(), exec: Some(self.rt.load(&rel)?) })
    }

    fn run(&self, op: &OpHandle, args: &[&Value]) -> Result<Value> {
        let exec = op
            .exec
            .as_ref()
            .context("op lowered by a different backend (no executable)")?;
        anyhow::ensure!(
            args.len() == op.desc.arity(),
            "{:?} expects {} args, got {}",
            op.desc,
            op.desc.arity(),
            args.len()
        );
        let bufs: Vec<&xla::PjRtBuffer> =
            args.iter().map(|v| v.as_device()).collect::<Result<_>>()?;
        let out = exec.run_device(&bufs)?;
        Ok(Value::device(out, op.desc.out_dims()))
    }

    fn uploads(&self) -> usize {
        self.uploads.load(Ordering::Relaxed)
    }

    fn downloads(&self) -> usize {
        self.downloads.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn value_is_cheap_to_clone_and_tracks_dims() {
        let v = Value::host(Tensor::zeros(&[2, 3]));
        let v2 = v.clone();
        assert_eq!(v.dims(), &[2, 3]);
        assert_eq!(v2.dims(), &[2, 3]);
        assert!(v.as_host().is_some());
    }

    #[test]
    fn out_dims_and_arity() {
        let conv = OpDesc::Conv {
            b: 2,
            h: 9,
            w: 9,
            cin: 3,
            cout: 8,
            k: 3,
            stride: 2,
            depthwise: false,
            act: Some(Act::Relu),
            residual: true,
        };
        assert_eq!(conv.out_dims(), vec![2, 5, 5, 8]);
        assert_eq!(conv.arity(), 4);
        let up = OpDesc::Upsample { b: 1, h: 4, w: 4, c: 2 };
        assert_eq!(up.out_dims(), vec![1, 8, 8, 2]);
        assert_eq!(up.arity(), 1);
        let head = OpDesc::Head { b: 4, h: 2, w: 2, hidden: 8, classes: 10, model: "m".into() };
        assert_eq!(head.out_dims(), vec![4, 10]);
    }

    #[test]
    fn backend_trait_objects_are_send_sync() {
        fn check<T: Send + Sync + ?Sized>() {}
        check::<dyn Backend>();
        check::<Value>();
    }

    #[test]
    fn pooled_value_returns_its_buffer_on_last_drop() {
        let arena = Arc::new(Arena::new());
        let v = Value::pooled(Tensor::zeros(&[2, 3]), Arc::clone(&arena));
        let v2 = v.clone();
        assert_eq!(v2.dims(), &[2, 3]);
        drop(v);
        assert_eq!(arena.cached(), 0, "buffer must stay alive while referenced");
        drop(v2);
        assert_eq!(arena.cached(), 1, "last drop recycles the buffer");
        let buf = arena.take(6);
        assert_eq!((buf.len(), arena.hits()), (6, 1));
    }

    #[test]
    fn weight_format_names_round_trip() {
        for fmt in [WeightFormat::F32, WeightFormat::Int8] {
            assert_eq!(WeightFormat::parse(fmt.name()), Some(fmt));
        }
        assert_eq!(WeightFormat::parse("bf16"), None);
        assert_eq!(WeightFormat::default(), WeightFormat::F32);
        assert_ne!(WeightFormat::F32.tag(), WeightFormat::Int8.tag());
    }

    #[test]
    fn packed_value_tracks_dims_and_rejects_host_reads() {
        let w = Tensor::zeros(&[4, 3, 3, 3]);
        let v = Value::packed(PackedConv::pack(&w, false), w.dims.clone());
        assert_eq!(v.dims(), &[4, 3, 3, 3]);
        assert!(v.as_host().is_none());
        assert!(v.as_packed().is_some());
    }
}
