//! Native host backend — executes any lowered plan on `crate::kernels`
//! with **zero XLA dependency**, so `CompiledPlan::measure`, the `serve`
//! engine and the benches produce real latency numbers from a fresh
//! offline checkout (the vendored `xla` crate is a fail-fast stub).
//!
//! Two modes, selected at construction:
//!
//! * [`HostBackend::new`] — **resident**: `run` consumes and produces
//!   values in place; the only data copies are the genuine `upload` /
//!   `download` boundary crossings, exactly like the PJRT backend's
//!   device residency.  Conv weights are pre-packed once at lowering
//!   (`upload_weight` -> `kernels::PackedConv`), and every transient
//!   buffer — im2col columns, pad planes, attention scratch, op outputs —
//!   comes from a size-classed [`Arena`], so the steady-state forward
//!   (second call onward) performs **zero buffer allocations**: the
//!   arena's `hits()`/`misses()` counters assert it
//!   (`tests/steady_state.rs`).
//! * [`HostBackend::per_dispatch`] — models the *old* per-op round trip:
//!   every operand is downloaded (memcpy'd) on the way into each op and
//!   the output uploaded on the way out, weights stay unpacked (the
//!   per-call transpose is part of the old cost shape), and nothing runs
//!   through the arena.  This is the baseline side of
//!   `benches/runtime_dispatch.rs`, and it keeps the transfer counters
//!   honest for both modes.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::kernels::{self, Epilogue};
use crate::runtime::backend::{Backend, OpDesc, OpHandle, Value, WeightFormat};
use crate::util::arena::Arena;
use crate::util::tensor::Tensor;

pub struct HostBackend {
    per_dispatch: bool,
    format: WeightFormat,
    arena: Arc<Arena>,
    uploads: AtomicUsize,
    downloads: AtomicUsize,
}

impl HostBackend {
    /// Resident mode: values flow between ops as shared handles, scratch
    /// and activations recycle through the arena.  The weight format
    /// comes from `LM_WEIGHT_FORMAT` (the `--weight-format` CLI knob) so
    /// every construction site — engine, e2e loop, tables, benches —
    /// deploys the same lowering without signature churn; tests that
    /// need a specific format use [`HostBackend::with_format`].
    pub fn new() -> HostBackend {
        HostBackend::with_format(WeightFormat::from_env())
    }

    /// Resident mode with an explicit weight format.
    pub fn with_format(format: WeightFormat) -> HostBackend {
        HostBackend {
            per_dispatch: false,
            format,
            arena: Arc::new(Arena::new()),
            uploads: AtomicUsize::new(0),
            downloads: AtomicUsize::new(0),
        }
    }

    /// Per-dispatch mode: every op round-trips all operands through the
    /// (counted, memcpy'd) transfer boundary — the pre-residency cost
    /// model, kept as a measurable baseline.  Always f32: unpacked,
    /// re-transposed weights are part of the old cost shape.
    pub fn per_dispatch() -> HostBackend {
        HostBackend { per_dispatch: true, ..HostBackend::with_format(WeightFormat::F32) }
    }

    /// The scratch arena (hit/miss counters pin the zero-allocation
    /// steady state in tests and benches).
    pub fn arena(&self) -> &Arc<Arena> {
        &self.arena
    }
}

impl Default for HostBackend {
    fn default() -> Self {
        HostBackend::new()
    }
}

impl Backend for HostBackend {
    fn name(&self) -> &'static str {
        if self.per_dispatch {
            "host (per-dispatch)"
        } else {
            "host"
        }
    }

    fn upload(&self, t: &Tensor) -> Result<Value> {
        self.uploads.fetch_add(1, Ordering::Relaxed);
        if self.per_dispatch {
            Ok(Value::host(t.clone()))
        } else {
            // the input buffer recycles too: forward N+1's upload reuses
            // the buffer forward N's input released
            let mut buf = self.arena.take(t.data.len());
            buf.copy_from_slice(&t.data);
            Ok(Value::pooled(Tensor::new(t.dims.clone(), buf), Arc::clone(&self.arena)))
        }
    }

    fn weight_format(&self) -> WeightFormat {
        self.format
    }

    fn upload_weight(&self, desc: &OpDesc, w: &Tensor) -> Result<Value> {
        // per-dispatch keeps the old cost shape: unpacked weight, re-
        // transposed inside every conv call
        if self.per_dispatch {
            return self.upload(w);
        }
        if let OpDesc::Conv { depthwise, .. } = desc {
            self.uploads.fetch_add(1, Ordering::Relaxed);
            // int8 lowers dense convs to per-channel quantized panels;
            // depthwise stays f32 (its direct kernel never hits the GEMM)
            let pc = if self.format == WeightFormat::Int8 && !*depthwise {
                kernels::PackedConv::pack_i8(w)
            } else {
                kernels::PackedConv::pack(w, *depthwise)
            };
            Ok(Value::packed(pc, w.dims.clone()))
        } else {
            self.upload(w)
        }
    }

    fn download(&self, v: &Value) -> Result<Tensor> {
        self.downloads.fetch_add(1, Ordering::Relaxed);
        Ok(v.as_host().context("non-host value on the host backend")?.clone())
    }

    fn supports(&self, _desc: &OpDesc) -> bool {
        true // the native kernel set covers every descriptor
    }

    fn lower_op(&self, desc: &OpDesc) -> Result<OpHandle> {
        Ok(OpHandle::host(desc.clone()))
    }

    fn run(&self, op: &OpHandle, args: &[&Value]) -> Result<Value> {
        anyhow::ensure!(
            args.len() == op.desc.arity(),
            "{:?} expects {} args, got {}",
            op.desc,
            op.desc.arity(),
            args.len()
        );
        if self.per_dispatch {
            // the old world: every operand crosses the boundary per op
            let owned: Vec<Value> = args
                .iter()
                .map(|v| self.download(v).map(Value::host))
                .collect::<Result<_>>()?;
            let refs: Vec<&Value> = owned.iter().collect();
            let out = exec_host(&op.desc, &refs, None)?;
            self.upload(&out)
        } else {
            let out = exec_host(&op.desc, args, Some(&self.arena))?;
            Ok(Value::pooled(out, Arc::clone(&self.arena)))
        }
    }

    fn uploads(&self) -> usize {
        self.uploads.load(Ordering::Relaxed)
    }

    fn downloads(&self) -> usize {
        self.downloads.load(Ordering::Relaxed)
    }
}

/// Interpret one op descriptor on the host kernels.  Semantics mirror the
/// AOT artifacts (`python/compile/aot.py::conv_module` / `model.py`)
/// op for op; parity is pinned by `tests/host_backend.rs`.  With an
/// arena, every output and scratch buffer is recycled; a pre-packed conv
/// weight takes the micro-kernel path with the epilogue fused into the
/// GEMM tile loop, an unpacked one falls back to the pack-per-call path.
fn exec_host(desc: &OpDesc, args: &[&Value], arena: Option<&Arena>) -> Result<Tensor> {
    let host = |i: usize| -> Result<&Tensor> {
        args[i].as_host().context("non-host value on the host backend")
    };
    let buf = |len: usize, zeroed: bool| kernels::take_buf(arena, len, zeroed);
    match desc {
        OpDesc::Conv { b, h, w, cin, stride, depthwise, act, residual, .. } => {
            let x = host(0)?;
            anyhow::ensure!(
                x.dims == vec![*b, *h, *w, *cin],
                "conv input {:?} vs desc {:?}",
                x.dims,
                desc
            );
            let bias = host(2)?;
            let res = if *residual { Some(host(3)?) } else { None };
            if let Some(pc) = args[1].as_packed() {
                if let Some(r) = res {
                    anyhow::ensure!(
                        r.dims == desc.out_dims(),
                        "conv residual {:?} vs output {:?}",
                        r.dims,
                        desc.out_dims()
                    );
                }
                let epi = Epilogue {
                    bias: &bias.data,
                    act: *act,
                    res: res.map(|r| &r.data[..]),
                };
                Ok(kernels::conv2d_same_packed(x, pc, *stride, Some(&epi), arena))
            } else {
                let wt = host(1)?;
                let mut y = kernels::conv2d_same(x, wt, *stride, *depthwise);
                kernels::bias_act_res(&mut y, &bias.data, *act, res);
                Ok(y)
            }
        }
        OpDesc::GroupNorm { groups, .. } => {
            let x = host(0)?;
            let mut y = Tensor::new(x.dims.clone(), buf(x.data.len(), false));
            kernels::group_norm_into(x, &host(1)?.data, &host(2)?.data, *groups, &mut y);
            Ok(y)
        }
        OpDesc::Add { .. } => {
            let (a, b2) = (host(0)?, host(1)?);
            anyhow::ensure!(a.dims == b2.dims, "add shape mismatch");
            let mut y = Tensor::new(a.dims.clone(), buf(a.data.len(), false));
            kernels::add_into(a, b2, &mut y);
            Ok(y)
        }
        OpDesc::Activation { act, .. } => {
            let x = host(0)?;
            let mut y = Tensor::new(x.dims.clone(), buf(x.data.len(), false));
            kernels::act_into(x, *act, &mut y);
            Ok(y)
        }
        OpDesc::Attention { .. } => Ok(kernels::attention(host(0)?, host(1)?, host(2)?, arena)),
        OpDesc::Upsample { .. } => {
            let x = host(0)?;
            let mut y = Tensor::new(desc.out_dims(), buf(x.data.len() * 4, false));
            kernels::upsample2x_into(x, &mut y);
            Ok(y)
        }
        OpDesc::Head { .. } => {
            let (x, w) = (host(0)?, host(1)?);
            let mut y = Tensor::new(desc.out_dims(), buf(x.dims[0] * w.dims[1], true));
            kernels::mean_pool_dense_into(x, w, &host(2)?.data, arena, &mut y);
            Ok(y)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Act;

    #[test]
    fn resident_run_moves_no_data_through_the_counters() {
        let be = HostBackend::new();
        let x = be.upload(&Tensor::full(&[1, 2, 2, 3], 1.0)).unwrap();
        let op = be
            .lower_op(&OpDesc::Activation { act: Act::Relu, b: 1, h: 2, w: 2, c: 3 })
            .unwrap();
        let y = be.run(&op, &[&x]).unwrap();
        assert_eq!(y.dims(), &[1, 2, 2, 3]);
        assert_eq!((be.uploads(), be.downloads()), (1, 0));
        let out = be.download(&y).unwrap();
        assert_eq!((be.uploads(), be.downloads()), (1, 1));
        assert!(out.data.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn per_dispatch_run_round_trips_every_operand() {
        let be = HostBackend::per_dispatch();
        let x = be.upload(&Tensor::full(&[1, 2, 2, 3], -1.0)).unwrap();
        let op = be
            .lower_op(&OpDesc::Activation { act: Act::Relu, b: 1, h: 2, w: 2, c: 3 })
            .unwrap();
        let y = be.run(&op, &[&x]).unwrap();
        // 1 initial upload + 1 per-op output upload; 1 per-op input download
        assert_eq!((be.uploads(), be.downloads()), (2, 1));
        assert!(be.download(&y).unwrap().data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn arity_is_checked() {
        let be = HostBackend::new();
        let x = be.upload(&Tensor::zeros(&[1, 2, 2, 3])).unwrap();
        let op = be.lower_op(&OpDesc::Add { b: 1, h: 2, w: 2, c: 3 }).unwrap();
        assert!(be.run(&op, &[&x]).is_err());
    }

    #[test]
    fn resident_ops_recycle_through_the_arena() {
        let be = HostBackend::new();
        let desc = OpDesc::Activation { act: Act::Relu, b: 1, h: 2, w: 2, c: 3 };
        let op = be.lower_op(&desc).unwrap();
        let x = be.upload(&Tensor::full(&[1, 2, 2, 3], -2.0)).unwrap();
        let y = be.run(&op, &[&x]).unwrap();
        drop(y); // output buffer returns to the arena
        let m0 = be.arena().misses();
        let y2 = be.run(&op, &[&x]).unwrap();
        assert_eq!(be.arena().misses(), m0, "steady-state op must not allocate");
        assert!(be.download(&y2).unwrap().data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn packed_weight_conv_matches_unpacked_fallback() {
        use crate::util::rng::Rng;
        let mut r = Rng::new(77);
        let (b, h, w, cin, cout, k) = (2usize, 7usize, 7usize, 3usize, 5usize, 3usize);
        let x = Tensor::new(
            vec![b, h, w, cin],
            (0..b * h * w * cin).map(|_| r.normal()).collect(),
        );
        let wt = Tensor::new(
            vec![cout, cin, k, k],
            (0..cout * cin * k * k).map(|_| r.normal()).collect(),
        );
        let bias = Tensor::new(vec![cout], (0..cout).map(|_| r.normal()).collect());
        let desc = OpDesc::Conv {
            b,
            h,
            w,
            cin,
            cout,
            k,
            stride: 1,
            depthwise: false,
            act: Some(Act::Relu),
            residual: false,
        };
        let be = HostBackend::new();
        let op = be.lower_op(&desc).unwrap();
        let xb = be.upload(&x).unwrap();
        let bb = be.upload(&bias).unwrap();
        let packed = be.upload_weight(&desc, &wt).unwrap();
        let plain = be.upload(&wt).unwrap();
        let y_packed = be.download(&be.run(&op, &[&xb, &packed, &bb]).unwrap()).unwrap();
        let y_plain = be.download(&be.run(&op, &[&xb, &plain, &bb]).unwrap()).unwrap();
        assert_eq!(y_packed.dims, y_plain.dims);
        assert!(
            y_packed.max_abs_diff(&y_plain) < 1e-6,
            "packed vs fallback diff {}",
            y_packed.max_abs_diff(&y_plain)
        );
    }

    #[test]
    fn int8_backend_tracks_f32_backend_within_quant_tolerance() {
        use crate::util::rng::Rng;
        let mut r = Rng::new(78);
        let (b, h, w, cin, cout, k) = (1usize, 8usize, 8usize, 4usize, 6usize, 3usize);
        let x = Tensor::new(
            vec![b, h, w, cin],
            (0..b * h * w * cin).map(|_| r.normal()).collect(),
        );
        let wt = Tensor::new(
            vec![cout, cin, k, k],
            (0..cout * cin * k * k).map(|_| r.normal()).collect(),
        );
        let bias = Tensor::new(vec![cout], (0..cout).map(|_| r.normal()).collect());
        let desc = OpDesc::Conv {
            b,
            h,
            w,
            cin,
            cout,
            k,
            stride: 1,
            depthwise: false,
            act: None,
            residual: false,
        };
        let f32be = HostBackend::with_format(WeightFormat::F32);
        let i8be = HostBackend::with_format(WeightFormat::Int8);
        assert_eq!(f32be.weight_format(), WeightFormat::F32);
        assert_eq!(i8be.weight_format(), WeightFormat::Int8);
        let mut outs = Vec::new();
        for be in [&f32be, &i8be] {
            let op = be.lower_op(&desc).unwrap();
            let xb = be.upload(&x).unwrap();
            let bb = be.upload(&bias).unwrap();
            let wb = be.upload_weight(&desc, &wt).unwrap();
            outs.push(be.download(&be.run(&op, &[&xb, &wb, &bb]).unwrap()).unwrap());
        }
        assert_eq!(outs[0].dims, outs[1].dims);
        let scale = outs[0].data.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let diff = outs[0].max_abs_diff(&outs[1]);
        assert!(diff < 0.05 * scale + 0.01, "int8 vs f32 conv diff {diff}");
    }
}
