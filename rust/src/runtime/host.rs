//! Native host backend — executes any lowered plan on `crate::kernels`
//! with **zero XLA dependency**, so `CompiledPlan::measure`, the `serve`
//! engine and the benches produce real latency numbers from a fresh
//! offline checkout (the vendored `xla` crate is a fail-fast stub).
//!
//! Two modes, selected at construction:
//!
//! * [`HostBackend::new`] — **resident**: `run` consumes and produces
//!   values in place; the only data copies are the genuine `upload` /
//!   `download` boundary crossings, exactly like the PJRT backend's
//!   device residency.
//! * [`HostBackend::per_dispatch`] — models the *old* per-op round trip:
//!   every operand is downloaded (memcpy'd) on the way into each op and
//!   the output uploaded on the way out, the cost shape `Exec::run` had
//!   when each dispatch crossed the host<->device boundary.  This is the
//!   baseline side of `benches/runtime_dispatch.rs`, and it keeps the
//!   transfer counters honest for both modes.

use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::{Context, Result};

use crate::kernels;
use crate::runtime::backend::{Backend, OpDesc, OpHandle, Value};
use crate::util::tensor::Tensor;

pub struct HostBackend {
    per_dispatch: bool,
    uploads: AtomicUsize,
    downloads: AtomicUsize,
}

impl HostBackend {
    /// Resident mode: values flow between ops as shared handles.
    pub fn new() -> HostBackend {
        HostBackend {
            per_dispatch: false,
            uploads: AtomicUsize::new(0),
            downloads: AtomicUsize::new(0),
        }
    }

    /// Per-dispatch mode: every op round-trips all operands through the
    /// (counted, memcpy'd) transfer boundary — the pre-residency cost
    /// model, kept as a measurable baseline.
    pub fn per_dispatch() -> HostBackend {
        HostBackend { per_dispatch: true, ..HostBackend::new() }
    }
}

impl Default for HostBackend {
    fn default() -> Self {
        HostBackend::new()
    }
}

impl Backend for HostBackend {
    fn name(&self) -> &'static str {
        if self.per_dispatch {
            "host (per-dispatch)"
        } else {
            "host"
        }
    }

    fn upload(&self, t: &Tensor) -> Result<Value> {
        self.uploads.fetch_add(1, Ordering::Relaxed);
        Ok(Value::host(t.clone()))
    }

    fn download(&self, v: &Value) -> Result<Tensor> {
        self.downloads.fetch_add(1, Ordering::Relaxed);
        Ok(v.as_host().context("device value on the host backend")?.clone())
    }

    fn supports(&self, _desc: &OpDesc) -> bool {
        true // the native kernel set covers every descriptor
    }

    fn lower_op(&self, desc: &OpDesc) -> Result<OpHandle> {
        Ok(OpHandle::host(desc.clone()))
    }

    fn run(&self, op: &OpHandle, args: &[&Value]) -> Result<Value> {
        anyhow::ensure!(
            args.len() == op.desc.arity(),
            "{:?} expects {} args, got {}",
            op.desc,
            op.desc.arity(),
            args.len()
        );
        if self.per_dispatch {
            // the old world: every operand crosses the boundary per op
            let owned: Vec<Tensor> =
                args.iter().map(|v| self.download(v)).collect::<Result<_>>()?;
            let refs: Vec<&Tensor> = owned.iter().collect();
            let out = exec_host(&op.desc, &refs)?;
            self.upload(&out)
        } else {
            let host: Vec<&Tensor> = args
                .iter()
                .map(|v| v.as_host().context("device value on the host backend"))
                .collect::<Result<_>>()?;
            Ok(Value::host(exec_host(&op.desc, &host)?))
        }
    }

    fn uploads(&self) -> usize {
        self.uploads.load(Ordering::Relaxed)
    }

    fn downloads(&self) -> usize {
        self.downloads.load(Ordering::Relaxed)
    }
}

/// Interpret one op descriptor on the host kernels.  Semantics mirror the
/// AOT artifacts (`python/compile/aot.py::conv_module` / `model.py`)
/// op for op; parity is pinned by `tests/host_backend.rs`.
fn exec_host(desc: &OpDesc, args: &[&Tensor]) -> Result<Tensor> {
    match desc {
        OpDesc::Conv { b, h, w, cin, stride, depthwise, act, residual, .. } => {
            let (x, wt, bias) = (args[0], args[1], args[2]);
            anyhow::ensure!(
                x.dims == vec![*b, *h, *w, *cin],
                "conv input {:?} vs desc {:?}",
                x.dims,
                desc
            );
            let mut y = kernels::conv2d_same(x, wt, *stride, *depthwise);
            let res = if *residual { Some(args[3]) } else { None };
            kernels::bias_act_res(&mut y, &bias.data, *act, res);
            Ok(y)
        }
        OpDesc::GroupNorm { groups, .. } => {
            Ok(kernels::group_norm(args[0], &args[1].data, &args[2].data, *groups))
        }
        OpDesc::Add { .. } => {
            anyhow::ensure!(args[0].dims == args[1].dims, "add shape mismatch");
            let mut y = args[0].clone();
            for (a, b2) in y.data.iter_mut().zip(&args[1].data) {
                *a += *b2;
            }
            Ok(y)
        }
        OpDesc::Activation { act, .. } => {
            let mut y = args[0].clone();
            kernels::act_inplace(&mut y, *act);
            Ok(y)
        }
        OpDesc::Attention { .. } => Ok(kernels::attention(args[0], args[1], args[2])),
        OpDesc::Upsample { .. } => Ok(kernels::upsample2x(args[0])),
        OpDesc::Head { .. } => Ok(kernels::mean_pool_dense(args[0], args[1], &args[2].data)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::Act;

    #[test]
    fn resident_run_moves_no_data_through_the_counters() {
        let be = HostBackend::new();
        let x = be.upload(&Tensor::full(&[1, 2, 2, 3], 1.0)).unwrap();
        let op = be
            .lower_op(&OpDesc::Activation { act: Act::Relu, b: 1, h: 2, w: 2, c: 3 })
            .unwrap();
        let y = be.run(&op, &[&x]).unwrap();
        assert_eq!(y.dims(), &[1, 2, 2, 3]);
        assert_eq!((be.uploads(), be.downloads()), (1, 0));
        let out = be.download(&y).unwrap();
        assert_eq!((be.uploads(), be.downloads()), (1, 1));
        assert!(out.data.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn per_dispatch_run_round_trips_every_operand() {
        let be = HostBackend::per_dispatch();
        let x = be.upload(&Tensor::full(&[1, 2, 2, 3], -1.0)).unwrap();
        let op = be
            .lower_op(&OpDesc::Activation { act: Act::Relu, b: 1, h: 2, w: 2, c: 3 })
            .unwrap();
        let y = be.run(&op, &[&x]).unwrap();
        // 1 initial upload + 1 per-op output upload; 1 per-op input download
        assert_eq!((be.uploads(), be.downloads()), (2, 1));
        assert!(be.download(&y).unwrap().data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn arity_is_checked() {
        let be = HostBackend::new();
        let x = be.upload(&Tensor::zeros(&[1, 2, 2, 3])).unwrap();
        let op = be.lower_op(&OpDesc::Add { b: 1, h: 2, w: 2, c: 3 }).unwrap();
        assert!(be.run(&op, &[&x]).is_err());
    }
}
