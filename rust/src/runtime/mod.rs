//! PJRT runtime + the pluggable execution backends.
//!
//! Three layers live here:
//!
//! * [`Runtime`] / [`Exec`] — loads AOT HLO-text artifacts and executes
//!   them on the CPU PJRT client.  This module (plus [`backend`]) is the
//!   only code that touches the `xla` crate; everything above it speaks
//!   `util::tensor::Tensor` or opaque [`Value`] buffer handles.
//! * [`Backend`] / [`Value`] (see [`backend`]) — the runtime abstraction
//!   the lowered execution plans dispatch through.  [`PjrtBackend`] keeps
//!   activations and pre-uploaded operands device-resident across steps;
//!   [`HostBackend`] (see [`host`]) executes the same lowered plans on the
//!   native `crate::kernels` with zero XLA dependency.
//! * [`measure_protocol`] — the single measurement protocol (App. C:
//!   warm-up then timed iterations) shared by artifact-level
//!   [`measure`] and `CompiledPlan::measure`.
//!
//! Pattern from /opt/xla-example/load_hlo: HLO *text* -> HloModuleProto
//! (text parser reassigns 64-bit instruction ids) -> XlaComputation ->
//! client.compile -> execute.  All artifacts are lowered with
//! `return_tuple=True`, so every output is a 1+-tuple literal.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::util::tensor::Tensor;

pub mod backend;
pub mod host;

pub use backend::{Backend, OpDesc, OpHandle, PjrtBackend, Value, WeightFormat};
pub use host::HostBackend;

/// A compiled executable plus its artifact identity.
pub struct Exec {
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    pub path: PathBuf,
}

// SAFETY: PJRT executables and clients are thread-safe in the underlying
// C++ runtime (PJRT mandates thread-safe Execute); the Rust wrapper only
// lacks the marker because it holds raw pointers.  We serialize *compiles*
// through the cache mutex and allow concurrent executes, matching PJRT's
// documented contract.
unsafe impl Send for Exec {}
unsafe impl Sync for Exec {}

impl Exec {
    /// Execute with host tensors; returns the decomposed output tuple.
    ///
    /// Inputs go through `execute_b` with Rust-owned device buffers: the
    /// crate's literal-based `execute` leaks every input device buffer
    /// (xla_rs.cc `buffer.release()` with no reclamation), which at
    /// training-loop rates exhausts memory in minutes.  Buffers created
    /// here are freed by their Drop impl once the call returns.
    pub fn run(&self, args: &[&Tensor]) -> Result<Vec<Tensor>> {
        let bufs: Vec<xla::PjRtBuffer> = args
            .iter()
            .map(|t| {
                self.client
                    .buffer_from_host_buffer::<f32>(&t.data, &t.dims, None)
                    .map_err(|e| anyhow::anyhow!("host->device: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let out_bufs = self.exe.execute_b::<xla::PjRtBuffer>(&bufs)?;
        let mut out = out_bufs[0][0].to_literal_sync()?;
        let parts = out.decompose_tuple()?;
        parts.into_iter().map(from_literal).collect()
    }

    /// Execute with **device-resident** buffers and return the op's output
    /// buffer still on device — no host transfer in either direction.
    /// Single-output executables only (every conv/elementwise module the
    /// execution plans dispatch is one): with PJRT's untupled results the
    /// first leaf buffer is the output.
    pub fn run_device(&self, args: &[&xla::PjRtBuffer]) -> Result<xla::PjRtBuffer> {
        let outs = self.exe.execute_b::<&xla::PjRtBuffer>(args)?;
        outs.into_iter()
            .next()
            .and_then(|per_dev| per_dev.into_iter().next())
            .context("executable produced no output buffer")
    }
}

pub(crate) fn from_literal(lit: xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Ok(Tensor::new(dims, data))
}

/// Client + executable cache.  Compilation happens exactly once per
/// artifact path — the cache holds a per-path *slot* that is created under
/// the map lock but compiled under its own lock, so two threads racing on
/// the same artifact serialize on that slot (second one reuses the first's
/// result) while compilations of different artifacts proceed in parallel.
/// Executes are lock-free (Arc-shared Execs).
pub struct Runtime {
    client: xla::PjRtClient,
    root: PathBuf,
    cache: Mutex<HashMap<PathBuf, Arc<Mutex<Option<Arc<Exec>>>>>>,
    pub compile_count: Mutex<usize>,
    load_count: AtomicUsize,
}

unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// `root` is the artifacts directory (contains manifest.json).
    pub fn new(root: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            root: root.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
            compile_count: Mutex::new(0),
            load_count: AtomicUsize::new(0),
        })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Upload a host tensor to a device buffer on this runtime's client.
    /// The buffer persists until dropped — the PJRT backend uses this to
    /// pin weights/operands device-resident for the life of a plan.
    pub fn to_device(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(&t.data, &t.dims, None)
            .map_err(|e| anyhow::anyhow!("host->device: {e:?}"))
    }

    /// Load + compile an artifact by manifest-relative path, with caching.
    ///
    /// Racing loads of the same path compile it exactly once: the per-path
    /// slot is claimed under the map lock, then compilation happens under
    /// the slot's own lock, so a second requester blocks on the slot (not
    /// the whole cache) and wakes up to the finished executable.  A failed
    /// compile leaves the slot empty so the next caller retries.
    pub fn load(&self, rel: &str) -> Result<Arc<Exec>> {
        self.load_count.fetch_add(1, Ordering::Relaxed);
        let path = self.root.join(rel);
        let slot = {
            let mut cache = self.cache.lock().unwrap();
            cache.entry(path.clone()).or_default().clone()
        };
        let mut guard = slot.lock().unwrap();
        if let Some(e) = guard.as_ref() {
            return Ok(e.clone());
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("utf-8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {path:?}: {e:?}"))?;
        let exec = Arc::new(Exec {
            exe,
            client: self.client.clone(),
            path,
        });
        *self.compile_count.lock().unwrap() += 1;
        *guard = Some(exec.clone());
        Ok(exec)
    }

    /// Total `load` calls served (cache hits included) — lets callers
    /// assert that a hot loop performs zero cache lookups.
    pub fn loads(&self) -> usize {
        self.load_count.load(Ordering::Relaxed)
    }

    /// Number of executables currently cached (compiled slots only).
    /// Slot Arcs are cloned out first so the map lock is never held
    /// while waiting on an in-flight compile's slot lock.
    pub fn cached(&self) -> usize {
        let slots: Vec<_> = self.cache.lock().unwrap().values().cloned().collect();
        slots.iter().filter(|s| s.lock().unwrap().is_some()).count()
    }

    /// Drop compiled executables (frees device memory between phases).
    /// An in-flight compile keeps its orphaned slot alive and finishes
    /// harmlessly; the next `load` of that path recompiles.
    pub fn clear_cache(&self) {
        self.cache.lock().unwrap().clear();
    }
}

/// Latency statistics from the measurement protocol.
#[derive(Debug, Clone, Copy)]
pub struct LatencyStats {
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub iters: usize,
}

/// The paper's measurement protocol (App. C): warm up, then summarize
/// timed iterations.  This is the **single** implementation — artifact
/// benches ([`measure`]) and deployed-plan latency
/// (`CompiledPlan::measure`) both run through it, so every latency number
/// in the repo computes its quantiles identically.  Counts are
/// configurable because the paper's 300/200 split is overkill for CPU
/// microbenches in CI.
pub fn measure_protocol(
    warmup: usize,
    iters: usize,
    mut run: impl FnMut() -> Result<()>,
) -> Result<LatencyStats> {
    for _ in 0..warmup {
        run()?;
    }
    let iters = iters.max(1);
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        run()?;
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    crate::util::stats::sort_samples(&mut times);
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    Ok(LatencyStats {
        mean_ms: mean,
        p50_ms: crate::util::stats::percentile(&times, 0.5),
        p95_ms: crate::util::stats::percentile(&times, 0.95),
        iters,
    })
}

/// [`measure_protocol`] over one executable with fixed host args (the
/// per-op latency-table path; output materialized to host each iteration,
/// matching the paper's PyTorch-format protocol).
pub fn measure(
    exec: &Exec,
    args: &[&Tensor],
    warmup: usize,
    iters: usize,
) -> Result<LatencyStats> {
    measure_protocol(warmup, iters, || exec.run(args).map(|_| ()))
}
