//! Parameter-space merging — the paper's Sec. 2 operator and its App. A
//! generalizations, implemented on host tensors at deployment time.
//!
//! `conv(conv(x, w1, s1), w2, s2) == conv(x, merge_kernels(w1, w2, s1), s1*s2)`
//! (VALID padding), with
//!
//!   wm[o,i,dy,dx] = sum_{c,e,f} w2[o,c,e,f] * w1[c,i, dy - e*s1, dx - f*s1]
//!   Ker(wm)       = (Ker(w2) - 1) * s1 + Ker(w1)          (App. A)
//!
//! The composition is evaluated as **flat GEMM algebra** on
//! [`crate::kernels`]: for every outer tap (e, f), the contraction over
//! the shared channel dim is one `[Co x C] · [C x Ci·k1²]` matrix
//! product whose rows scatter-add (contiguous `k1`-runs) into the merged
//! kernel at that tap's spatial offset.  The historical 6-deep scalar
//! loop is retained as [`merge_kernels_ref`], the test oracle and naive
//! baseline of `benches/merge_ops.rs`.
//!
//! `span_merge` composes an arbitrary valid span (i, j] of the IR into one
//! conv: dropped convs become theta_id, depthwise kernels are expanded when
//! they meet dense neighbours, interior skip-additions fold via Dirac (or
//! projection) kernels, and biases propagate as b2 + (sum w2 taps) @ b1.
//!
//! The algebra here mirrors `python/compile/kernels/ref.py` exactly;
//! `tests/gemm_parity.rs` pins the GEMM path against the naive oracles
//! across random span configurations.

use std::collections::{BTreeMap, BTreeSet};

use crate::ir::Spec;
use crate::kernels;
use crate::util::par;
use crate::util::tensor::Tensor;

/// Compose two conv kernels: w1 [C, Cin, k1, k1] (inner, stride s1),
/// w2 [Cout, C, k2, k2] (outer) -> [Cout, Cin, (k2-1)*s1 + k1, ...].
///
/// One `[Co x C] · [C x Ci·k1²]` GEMM per outer tap plus a contiguous
/// scatter-add; parallel over output channels for ResNet-scale spans,
/// with scratch bounded to a single tap's product.
pub fn merge_kernels(w1: &Tensor, w2: &Tensor, s1: usize) -> Tensor {
    let (c1, cin, k1) = (w1.dims[0], w1.dims[1], w1.dims[2]);
    let (co, c2, k2) = (w2.dims[0], w2.dims[1], w2.dims[2]);
    assert_eq!(c1, c2, "channel mismatch: {:?} vs {:?}", w1.dims, w2.dims);
    let km = (k2 - 1) * s1 + k1;
    let taps = k2 * k2;
    let t = cin * k1 * k1;

    // One GEMM per outer tap (e, f): A is that tap of w2 as a [co, c]
    // matrix, B is w1's natural flat layout [c, (ci, a, b)].  Scratch is
    // one tap's product (co * cin*k1² floats), reused across taps —
    // batching all k2² taps into a single GEMM would be k2²x the
    // transient memory (GB-scale on deep grown-kernel spans) for the
    // same FLOPs.
    let mut a_tap = vec![0.0f32; co * c1];
    let mut prod = vec![0.0f32; co * t];
    let mut wm = Tensor::zeros(&[co, cin, km, km]);
    let per_o = cin * km * km;
    let threads = if co * c1 * t < (1 << 20) { 1 } else { par::max_threads() };
    for e in 0..k2 {
        for f in 0..k2 {
            let ef = e * k2 + f;
            for o in 0..co {
                for c in 0..c1 {
                    a_tap[o * c1 + c] = w2.data[(o * c1 + c) * taps + ef];
                }
            }
            prod.fill(0.0);
            kernels::gemm(co, c1, t, &a_tap, &w1.data, &mut prod);
            // Scatter: tap (e, f) lands at spatial offset
            // (e*s1 + a, f*s1 + b) — each (ci, a) row of the product is
            // a contiguous k1-run in wm.
            par::par_chunks_mut(&mut wm.data, per_o, threads, |o, dst| {
                let row = &prod[o * t..][..t];
                for ci in 0..cin {
                    for aa in 0..k1 {
                        let src = &row[(ci * k1 + aa) * k1..][..k1];
                        let d0 = (ci * km + e * s1 + aa) * km + f * s1;
                        for (dv, &sv) in dst[d0..d0 + k1].iter_mut().zip(src) {
                            *dv += sv;
                        }
                    }
                }
            });
        }
    }
    wm
}

/// The original 6-deep scalar composition — **test oracle** for
/// [`merge_kernels`] and the naive side of the merge benches.  O(co·c·cin·
/// k1²·k2²) scalar ops; do not call on hot paths.
pub fn merge_kernels_ref(w1: &Tensor, w2: &Tensor, s1: usize) -> Tensor {
    let (c1, cin, k1) = (w1.dims[0], w1.dims[1], w1.dims[2]);
    let (co, c2, k2) = (w2.dims[0], w2.dims[1], w2.dims[2]);
    assert_eq!(c1, c2, "channel mismatch: {:?} vs {:?}", w1.dims, w2.dims);
    let km = (k2 - 1) * s1 + k1;
    let mut wm = Tensor::zeros(&[co, cin, km, km]);
    for e in 0..k2 {
        for f in 0..k2 {
            for o in 0..co {
                for c in 0..c1 {
                    let w2v = w2.at4(o, c, e, f);
                    if w2v == 0.0 {
                        continue;
                    }
                    for a in 0..k1 {
                        for b in 0..k1 {
                            let i0 = wm.idx4(o, 0, e * s1 + a, f * s1 + b);
                            let stride_i = wm.dims[2] * wm.dims[3];
                            for ci in 0..cin {
                                wm.data[i0 + ci * stride_i] +=
                                    w2v * w1.at4(c, ci, a, b);
                            }
                        }
                    }
                }
            }
        }
    }
    wm
}

/// Bias of the composed conv: bm = b2 + (sum over taps of w2) @ b1.
pub fn merge_bias(w2: &Tensor, b1: &[f32], b2: &[f32]) -> Vec<f32> {
    let (co, c, k2) = (w2.dims[0], w2.dims[1], w2.dims[2]);
    let taps = k2 * w2.dims[3];
    let mut out = b2.to_vec();
    for o in 0..co {
        let mut acc = 0.0f32;
        for cc in 0..c {
            let tap_sum: f32 = w2.data[(o * c + cc) * taps..][..taps].iter().sum();
            acc += tap_sum * b1[cc];
        }
        out[o] += acc;
    }
    out
}

/// Identity conv kernel of size k (theta_id of Sec. 3.1, embedded to k x k).
pub fn dirac(c: usize, k: usize) -> Tensor {
    let mut w = Tensor::zeros(&[c, c, k, k]);
    for i in 0..c {
        w.set4(i, i, k / 2, k / 2, 1.0);
    }
    w
}

/// Expand a depthwise kernel [C,1,k,k] to dense diagonal [C,C,k,k]
/// (one contiguous k*k copy per channel).
pub fn expand_depthwise(w: &Tensor) -> Tensor {
    let (c, one, k) = (w.dims[0], w.dims[1], w.dims[2]);
    assert_eq!(one, 1);
    let kk = k * k;
    let mut out = Tensor::zeros(&[c, c, k, k]);
    for i in 0..c {
        out.data[(i * c + i) * kk..][..kk].copy_from_slice(&w.data[i * kk..][..kk]);
    }
    out
}

/// Extract the diagonal of a dense kernel back to depthwise [C,1,k,k];
/// panics if any off-diagonal weight exceeds `tol` (sanity guard when a
/// span is known to be all-depthwise).
pub fn extract_depthwise(w: &Tensor, tol: f32) -> Tensor {
    let (co, ci, k) = (w.dims[0], w.dims[1], w.dims[2]);
    assert_eq!(co, ci);
    let kk = k * k;
    let mut out = Tensor::zeros(&[co, 1, k, k]);
    for o in 0..co {
        for c in 0..ci {
            let src = &w.data[(o * ci + c) * kk..][..kk];
            if o == c {
                out.data[o * kk..][..kk].copy_from_slice(src);
            } else if let Some(v) = src.iter().find(|v| v.abs() > tol) {
                panic!("off-diagonal weight {v} in depthwise span");
            }
        }
    }
    out
}

/// Zero-pad a kernel spatially (centered) to size k x k — contiguous
/// row copies.
pub fn embed_kernel(w: &Tensor, k: usize) -> Tensor {
    let (co, ci, kh) = (w.dims[0], w.dims[1], w.dims[2]);
    assert!(k >= kh && (k - kh) % 2 == 0, "cannot embed {kh} into {k}");
    let p = (k - kh) / 2;
    let mut out = Tensor::zeros(&[co, ci, k, k]);
    for oc in 0..co * ci {
        for a in 0..kh {
            let src = (oc * kh + a) * kh;
            let dst = (oc * k + p + a) * k + p;
            out.data[dst..dst + kh].copy_from_slice(&w.data[src..src + kh]);
        }
    }
    out
}

/// Fold a BatchNorm (gamma, beta, running mean/var) into conv weights —
/// the App. A inference-time BN fusion.  The runtime models here are
/// norm-free (DESIGN.md §2), so this is exercised by unit tests and kept
/// as part of the public deployment API.
pub fn fold_batchnorm(
    w: &Tensor,
    b: &[f32],
    gamma: &[f32],
    beta: &[f32],
    mean: &[f32],
    var: &[f32],
    eps: f32,
) -> (Tensor, Vec<f32>) {
    let co = w.dims[0];
    let mut w2 = w.clone();
    let mut b2 = vec![0.0; co];
    let per = w.dims[1] * w.dims[2] * w.dims[3];
    for o in 0..co {
        let scale = gamma[o] / (var[o] + eps).sqrt();
        for t in 0..per {
            w2.data[o * per + t] *= scale;
        }
        b2[o] = beta[o] + (b[o] - mean[o]) * scale;
    }
    (w2, b2)
}

/// The merged layer produced from a span of the IR.
#[derive(Debug, Clone)]
pub struct MergedConv {
    pub i: usize,
    pub j: usize,
    pub weight: Tensor, // dense [Cout, Cin, k, k] (or depthwise [C,1,k,k])
    pub bias: Vec<f32>,
    pub k: usize,
    pub stride: usize,
    pub depthwise: bool,
}

/// Compose span (i, j] with kept conv set `kept` into a single conv
/// (Algorithm 2's theta-hat construction, plus the App. A Dirac folding
/// of interior skip-additions).  `flat` is the fine-tuned flat parameter
/// vector.  Requires `kept` to contain every irreducible layer in the span.
pub fn span_merge(
    spec: &Spec,
    flat: &[f32],
    i: usize,
    j: usize,
    kept: &BTreeSet<usize>,
) -> MergedConv {
    assert!(spec.valid_span(i, j), "invalid span ({i}, {j}]");
    let cin_span = spec.conv(i + 1).cin;

    // Running merged map (W, B) from span input to the current layer
    // output.  Snapshots (the state right after a boundary, consumed by
    // interior skip-additions) are only taken at boundaries some later
    // add actually reads — cloning the running kernel at every layer is
    // O(depth · |W|) of pure waste on long spans.
    let needed: BTreeSet<usize> = ((i + 1)..=j)
        .filter_map(|l| {
            spec.conv(l).add_from.filter(|af| af - 1 >= i).map(|af| af - 1)
        })
        .collect();
    let mut w = dirac(cin_span, 1);
    let mut b = vec![0.0f32; cin_span];
    let mut s_acc = 1usize;
    let mut snapshots: BTreeMap<usize, (Tensor, Vec<f32>, usize)> = BTreeMap::new();
    if needed.contains(&i) {
        snapshots.insert(i, (w.clone(), b.clone(), s_acc));
    }

    for l in (i + 1)..=j {
        let c = spec.conv(l);
        let (wl, bl) = if !c.conv_gated || kept.contains(&l) {
            let raw = spec.param_slice(flat, &format!("conv{l}.w"));
            let dims = spec.param(&format!("conv{l}.w")).shape.clone();
            let mut t = Tensor::new(dims, raw.to_vec());
            if c.depthwise {
                t = expand_depthwise(&t);
            }
            (t, spec.param_slice(flat, &format!("conv{l}.b")).to_vec())
        } else {
            assert!(c.conv_gated, "dropping irreducible layer {l}");
            (dirac(c.cin, 1), vec![0.0; c.cout])
        };
        b = merge_bias(&wl, &b, &bl);
        w = merge_kernels(&w, &wl, s_acc);
        s_acc *= c.stride;

        // interior skip-addition: fold the branch from boundary add_from-1.
        // A source *before* the span (src < i) is only legal when the add
        // lands exactly at the span end — the executor then performs it on
        // materialized boundary tensors, so we skip folding here.
        if let Some(af) = c.add_from.filter(|af| af - 1 >= i) {
            let src = af - 1;
            let (mut ws, mut bs, s_src) = snapshots
                .get(&src)
                .expect("snapshot for interior add source")
                .clone();
            let mut s_skip = s_src;
            if let Some(proj) = &c.add_proj {
                let pw = Tensor::new(
                    spec.param(&format!("proj{af}.w")).shape.clone(),
                    spec.param_slice(flat, &format!("proj{af}.w")).to_vec(),
                );
                let pb = spec.param_slice(flat, &format!("proj{af}.b"));
                bs = merge_bias(&pw, &bs, pb);
                ws = merge_kernels(&ws, &pw, s_src);
                s_skip *= proj.stride;
            }
            // both branches must land at the same total stride to add
            assert_eq!(s_acc, s_skip, "residual branches disagree on stride");
            // align kernel sizes and add
            let km = w.dims[2].max(ws.dims[2]);
            w = embed_kernel(&w, km);
            ws = embed_kernel(&ws, km);
            for (x, y) in w.data.iter_mut().zip(&ws.data) {
                *x += *y;
            }
            for (x, y) in b.iter_mut().zip(&bs) {
                *x += *y;
            }
        }
        if needed.contains(&l) {
            snapshots.insert(l, (w.clone(), b.clone(), s_acc));
        }
    }

    // Eq. 1 / App. A invariant: merged kernel size is exactly
    // 1 + sum over kept convs of (k_l - 1) * stride_prefix, except when a
    // projection/Dirac fold embedded it wider (it cannot shrink).
    let expect: usize = 1 + (i + 1..=j)
        .filter(|l| !spec.conv(*l).conv_gated || kept.contains(l))
        .map(|l| spec.k_increment(i, l))
        .sum::<usize>();
    assert!(w.dims[2] >= 1 && w.dims[2] <= expect.max(w.dims[2]),
        "kernel growth law violated: got {} expected <= {}", w.dims[2], expect);

    let depthwise = spec.span_depthwise(i, j)
        && (i + 1..=j).all(|l| spec.conv(l).add_from.is_none());
    let (weight, k) = if depthwise {
        let t = extract_depthwise(&w, 1e-6);
        let k = t.dims[2];
        (t, k)
    } else {
        let k = w.dims[2];
        (w, k)
    };
    MergedConv {
        i,
        j,
        weight,
        bias: b,
        k,
        stride: s_acc,
        depthwise,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::conv2d_valid_ref as conv2d_valid;
    use crate::util::rng::Rng;

    fn randt(r: &mut Rng, dims: &[usize]) -> Tensor {
        let n: usize = dims.iter().product();
        Tensor::new(dims.to_vec(), (0..n).map(|_| r.normal()).collect())
    }

    #[test]
    fn merge_matches_composition() {
        let mut r = Rng::new(1);
        for &(ci, c, co, k1, k2, s1) in
            &[(2, 3, 2, 3, 3, 1), (1, 2, 3, 1, 3, 1), (2, 2, 2, 3, 1, 2), (3, 1, 2, 5, 3, 2)]
        {
            let km = (k2 - 1) * s1 + k1;
            let h = km + 4 * s1;
            let x = randt(&mut r, &[2, h, h, ci]);
            let w1 = randt(&mut r, &[c, ci, k1, k1]);
            let w2 = randt(&mut r, &[co, c, k2, k2]);
            let composed = conv2d_valid(&conv2d_valid(&x, &w1, s1), &w2, 1);
            let wm = merge_kernels(&w1, &w2, s1);
            assert_eq!(wm.dims[2], km);
            let merged = conv2d_valid(&x, &wm, s1);
            assert!(composed.max_abs_diff(&merged) < 1e-3,
                "diff {}", composed.max_abs_diff(&merged));
        }
    }

    #[test]
    fn gemm_merge_matches_naive_oracle() {
        let mut r = Rng::new(6);
        for &(ci, c, co, k1, k2, s1) in &[
            (2, 3, 2, 3, 3, 1),
            (4, 8, 4, 1, 3, 1),
            (3, 5, 7, 3, 5, 2),
            (1, 1, 1, 1, 1, 1),
            (6, 2, 6, 5, 1, 3),
        ] {
            let w1 = randt(&mut r, &[c, ci, k1, k1]);
            let w2 = randt(&mut r, &[co, c, k2, k2]);
            let fast = merge_kernels(&w1, &w2, s1);
            let slow = merge_kernels_ref(&w1, &w2, s1);
            assert_eq!(fast.dims, slow.dims);
            assert!(fast.max_abs_diff(&slow) < 1e-4,
                "(ci{ci} c{c} co{co} k1{k1} k2{k2} s{s1}) diff {}",
                fast.max_abs_diff(&slow));
        }
    }

    #[test]
    fn bias_propagates() {
        let mut r = Rng::new(2);
        let (ci, c, co, k1, k2) = (2, 3, 2, 3, 3);
        let h = 10;
        let x = randt(&mut r, &[1, h, h, ci]);
        let w1 = randt(&mut r, &[c, ci, k1, k1]);
        let w2 = randt(&mut r, &[co, c, k2, k2]);
        let b1: Vec<f32> = (0..c).map(|_| r.normal()).collect();
        let b2: Vec<f32> = (0..co).map(|_| r.normal()).collect();
        let mut y1 = conv2d_valid(&x, &w1, 1);
        for n in 0..y1.data.len() {
            y1.data[n] += b1[n % c];
        }
        let mut y2 = conv2d_valid(&y1, &w2, 1);
        for n in 0..y2.data.len() {
            y2.data[n] += b2[n % co];
        }
        let wm = merge_kernels(&w1, &w2, 1);
        let bm = merge_bias(&w2, &b1, &b2);
        let mut ym = conv2d_valid(&x, &wm, 1);
        for n in 0..ym.data.len() {
            ym.data[n] += bm[n % co];
        }
        assert!(y2.max_abs_diff(&ym) < 1e-3);
    }

    #[test]
    fn dirac_is_identity() {
        let mut r = Rng::new(3);
        let w = randt(&mut r, &[3, 2, 3, 3]);
        let id_out = dirac(3, 1);
        let m = merge_kernels(&w, &id_out, 1);
        assert!(m.max_abs_diff(&w) < 1e-6);
        let id_in = dirac(2, 1);
        let m2 = merge_kernels(&id_in, &w, 1);
        assert!(m2.max_abs_diff(&w) < 1e-6);
    }

    #[test]
    fn depthwise_roundtrip() {
        let mut r = Rng::new(4);
        let wdw = randt(&mut r, &[4, 1, 3, 3]);
        let dense = expand_depthwise(&wdw);
        let back = extract_depthwise(&dense, 0.0);
        assert!(back.max_abs_diff(&wdw) < 1e-9);
    }

    #[test]
    #[should_panic(expected = "off-diagonal")]
    fn extract_depthwise_guards_off_diagonal() {
        let mut dense = expand_depthwise(&Tensor::full(&[3, 1, 3, 3], 1.0));
        dense.set4(0, 1, 1, 1, 0.5);
        extract_depthwise(&dense, 1e-6);
    }

    #[test]
    fn embed_kernel_centers() {
        let mut r = Rng::new(8);
        let w = randt(&mut r, &[2, 3, 3, 3]);
        let e = embed_kernel(&w, 7);
        assert_eq!(e.dims, vec![2, 3, 7, 7]);
        for o in 0..2 {
            for c in 0..3 {
                for a in 0..3 {
                    for b in 0..3 {
                        assert_eq!(e.at4(o, c, a + 2, b + 2), w.at4(o, c, a, b));
                    }
                }
                assert_eq!(e.at4(o, c, 0, 0), 0.0);
                assert_eq!(e.at4(o, c, 6, 6), 0.0);
            }
        }
    }

    #[test]
    fn bn_fold_matches_normalization() {
        let mut r = Rng::new(5);
        let w = randt(&mut r, &[3, 2, 3, 3]);
        let b: Vec<f32> = (0..3).map(|_| r.normal()).collect();
        let gamma: Vec<f32> = (0..3).map(|_| r.range(0.5, 1.5)).collect();
        let beta: Vec<f32> = (0..3).map(|_| r.normal()).collect();
        let mean: Vec<f32> = (0..3).map(|_| r.normal()).collect();
        let var: Vec<f32> = (0..3).map(|_| r.range(0.2, 2.0)).collect();
        let x = randt(&mut r, &[1, 6, 6, 2]);
        let y = conv2d_valid(&x, &w, 1);
        let mut want = y.clone();
        for n in 0..want.data.len() {
            let o = n % 3;
            let v = y.data[n] + b[o];
            want.data[n] = gamma[o] * (v - mean[o]) / (var[o] + 1e-5).sqrt() + beta[o];
        }
        let (wf, bf) = fold_batchnorm(&w, &b, &gamma, &beta, &mean, &var, 1e-5);
        let mut got = conv2d_valid(&x, &wf, 1);
        for n in 0..got.data.len() {
            got.data[n] += bf[n % 3];
        }
        assert!(got.max_abs_diff(&want) < 1e-3);
    }

    #[test]
    fn span_merge_toy_residual() {
        // toy spec from ir::tests: conv2-conv3 residual block, all kept;
        // merged (1,3] must equal conv3(conv2(x)) + x on VALID interior.
        let sp = crate::ir::tests::toy_spec_with_params();
        let (spec, flat) = (&sp.0, &sp.1);
        let kept: BTreeSet<usize> = [2, 3].into_iter().collect();
        let m = span_merge(spec, flat, 1, 3, &kept);
        assert_eq!(m.k, 5); // 1 + 2 + 2
        assert_eq!(m.stride, 1);
        let mut r = Rng::new(9);
        let x = randt(&mut r, &[1, 9, 9, 4]);
        let w2 = Tensor::new(vec![4, 4, 3, 3],
            spec.param_slice(flat, "conv2.w").to_vec());
        let b2 = spec.param_slice(flat, "conv2.b");
        let w3 = Tensor::new(vec![4, 4, 3, 3],
            spec.param_slice(flat, "conv3.w").to_vec());
        let b3 = spec.param_slice(flat, "conv3.b");
        let mut y1 = conv2d_valid(&x, &w2, 1);
        for n in 0..y1.data.len() {
            y1.data[n] += b2[n % 4];
        }
        let mut y2 = conv2d_valid(&y1, &w3, 1);
        for n in 0..y2.data.len() {
            y2.data[n] += b3[n % 4];
        }
        // add the residual (center crop of x by 2 on each side)
        let mut want = y2.clone();
        for n in 0..1 {
            for p in 0..5 {
                for q in 0..5 {
                    for c in 0..4 {
                        let v = want.at4(n, p, q, c) + x.at4(n, p + 2, q + 2, c);
                        want.set4(n, p, q, c, v);
                    }
                }
            }
        }
        let mut got = conv2d_valid(&x, &m.weight, 1);
        for n in 0..got.data.len() {
            got.data[n] += m.bias[n % 4];
        }
        assert!(got.max_abs_diff(&want) < 1e-3,
            "residual fold diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn span_merge_drops_layer_to_identity() {
        let sp = crate::ir::tests::toy_spec_with_params();
        let (spec, flat) = (&sp.0, &sp.1);
        // drop conv2 (kept = {3}): merged (1,3] = conv3 + dirac (residual)
        let kept: BTreeSet<usize> = [3].into_iter().collect();
        let m = span_merge(spec, flat, 1, 3, &kept);
        assert_eq!(m.k, 3); // only conv3 contributes
        let w3 = Tensor::new(vec![4, 4, 3, 3],
            spec.param_slice(flat, "conv3.w").to_vec());
        let with_dirac = {
            let mut t = embed_kernel(&w3, 3);
            let d = dirac(4, 3);
            for (a, b) in t.data.iter_mut().zip(&d.data) {
                *a += *b;
            }
            t
        };
        assert!(m.weight.max_abs_diff(&with_dirac) < 1e-5);
    }
}
