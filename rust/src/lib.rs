//! LayerMerge — depth compression through joint layer pruning and merging.
//!
//! A from-scratch reproduction of *LayerMerge: Neural Network Depth
//! Compression through Layer Pruning and Merging* (ICML 2024) as a
//! three-layer Rust + JAX + Pallas stack: Python authors and AOT-lowers
//! the gated model and kernels once (`make artifacts`); this crate owns
//! the entire pipeline afterwards — table construction, the DP solvers,
//! fine-tuning, merging, deployment and every experiment in the paper.
//!
//! Start at [`pipeline`] for the end-to-end flow, [`solver`] for the
//! paper's algorithms, [`serve`] for the owning Engine/Session deployment
//! API (micro-batched worker-pool serving), and DESIGN.md for the system
//! inventory.

pub mod baselines;
pub mod bench;
pub mod exec;
pub mod experiments;
pub mod ir;
pub mod kernels;
pub mod merge;
pub mod model;
pub mod pipeline;
pub mod profile;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod solver;
pub mod tables;
pub mod train;
pub mod util;

pub mod prelude {
    pub use crate::exec::{CompiledPlan, Format, Plan};
    pub use crate::ir::{Gates, Spec, Task};
    pub use crate::model::{Batch, Manifest, Model};
    pub use crate::pipeline::{Pipeline, PipelineCfg};
    pub use crate::profile::Profiler;
    pub use crate::runtime::{Backend, HostBackend, LatencyStats, Runtime, Value};
    pub use crate::serve::{BatchPolicy, Engine, ServeCfg, Session, Ticket};
    pub use crate::solver::Solution;
    pub use crate::tables::{BuildCfg, LatencyMode, Tables};
    pub use crate::util::tensor::Tensor;
}
