//! The LayerMerge pipeline (Algorithm 2) — pretrain, build tables, solve,
//! fine-tune, merge, deploy, measure.  Every experiment driver and example
//! sits on top of this module.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::baselines::twostage;
use crate::exec::{Format, Plan};
use crate::ir::{Gates, Spec, Task};
use crate::model::Model;
use crate::profile::Profiler;
use crate::runtime::{Backend, HostBackend};
use crate::serve::Engine;
use crate::solver::{self, depth, dp, layeronly};
use crate::tables::{self, BuildCfg, Tables};
use crate::train::{self, Gen};
use crate::util::tensor::Tensor;

/// Compression method under test (the paper's comparison set).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// Ours: joint activation + conv selection (Algorithm 1).
    LayerMerge,
    /// Kim et al. 2023: activations only (C = [L]).
    Depth,
    /// Our layer-pruning variant (Eq. 8 knapsack).
    LayerOnly,
    /// Kim et al. 2023's two-stage DP on the same tables
    /// (`baselines::twostage`): identical objective, different solver.
    TwoStage,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::LayerMerge => "LayerMerge",
            Method::Depth => "Depth",
            Method::LayerOnly => "LayerOnly",
            Method::TwoStage => "TwoStage",
        }
    }
}

#[derive(Debug, Clone)]
pub struct PipelineCfg {
    pub seed: u64,
    pub pretrain_steps: usize,
    pub pretrain_lr: f32,
    pub finetune_steps: usize,
    pub finetune_lr: f32,
    /// Discretization level P of Algorithm 1.
    pub p_disc: usize,
    pub build: BuildCfg,
    pub eval_batches: usize,
    /// Latency measurement protocol for deployed plans.
    pub lat_warmup: usize,
    pub lat_iters: usize,
    /// Ignore cached pretrained weights / tables and rebuild (`--force`).
    pub force: bool,
}

impl Default for PipelineCfg {
    fn default() -> Self {
        PipelineCfg {
            seed: 0,
            pretrain_steps: 300,
            pretrain_lr: 0.05,
            finetune_steps: 120,
            finetune_lr: 0.02,
            p_disc: 200,
            build: BuildCfg::default(),
            eval_batches: 8,
            lat_warmup: 5,
            lat_iters: 15,
            force: false,
        }
    }
}

/// A fully evaluated compressed model — one row of a paper table.
#[derive(Debug, Clone)]
pub struct Compressed {
    pub method: String,
    pub budget_frac: f64,
    pub solution: solver::Solution,
    /// metric of the fine-tuned pruned (un-merged) network.
    pub pruned_metric: f32,
    /// metric of the deployed merged network (Eager format numerics).
    pub merged_metric: f32,
    pub lat_eager_ms: f64,
    pub lat_fused_ms: f64,
    /// Original-plan latency re-measured back-to-back with this plan —
    /// speedups use this contemporaneous baseline (PJRT process state
    /// drifts over a long run, so A/B must be interleaved).
    pub base_eager_ms: f64,
    pub base_fused_ms: f64,
    pub depth: usize,
    pub finetuned: Vec<f32>,
    pub gates: Gates,
}

pub struct Pipeline {
    /// Owning deployment handle (runtime + manifest) — every lower /
    /// deploy / measure in the pipeline goes through it.
    pub engine: Engine,
    pub model: Model,
    pub gen: Gen,
    pub cfg: PipelineCfg,
    pub pretrained: Vec<f32>,
    pub tables: Option<Tables>,
    pub cache_root: PathBuf,
    /// Original-network baselines measured once.
    pub orig_metric: f32,
    pub orig_lat_eager: f64,
    pub orig_lat_fused: f64,
}

impl Pipeline {
    /// Load the model, pretrain (or reuse the cached pretrained weights),
    /// and measure the original network.
    pub fn new(
        engine: Engine,
        name: &str,
        cfg: PipelineCfg,
        cache_root: PathBuf,
    ) -> Result<Pipeline> {
        let model = engine.load_model(name)?;
        let gen = Gen::for_model(&model, cfg.seed ^ 0xda7a);

        let pre_path = cache_root.join("cache").join(format!(
            "{name}.pretrained.s{}.bin",
            cfg.pretrain_steps
        ));
        let pristine = model.spec.pristine_gates();
        let pretrained = if pre_path.exists() && !cfg.force {
            let p = Tensor::read_f32_file(&pre_path)?;
            anyhow::ensure!(p.len() == model.spec.param_count);
            eprintln!("[pipeline] {name}: reusing cached pretrained weights");
            p
        } else {
            eprintln!("[pipeline] {name}: pretraining {} steps", cfg.pretrain_steps);
            let mut params = model.init.clone();
            let log = train::train(
                &model, &gen, &mut params, &pristine, cfg.pretrain_steps,
                cfg.pretrain_lr, 0,
            )?;
            eprintln!(
                "[pipeline] {name}: pretrain loss {:.4} metric {:.4}",
                log.final_loss, log.final_metric
            );
            Tensor::write_f32_file(&pre_path, &params)?;
            params
        };
        let (_, orig_metric) =
            train::evaluate(&model, &gen, &pretrained, &pristine, cfg.eval_batches)?;
        let orig_plan = Arc::new(Plan::original(&model.spec, &pretrained)?);
        let orig_lat_eager = engine
            .measure(&orig_plan, Format::Eager, cfg.lat_warmup, cfg.lat_iters)?
            .p50_ms;
        let orig_lat_fused = engine
            .measure(&orig_plan, Format::Fused, cfg.lat_warmup, cfg.lat_iters)?
            .p50_ms;
        eprintln!(
            "[pipeline] {name}: orig metric {orig_metric:.4}, lat eager {orig_lat_eager:.2}ms fused {orig_lat_fused:.2}ms"
        );
        Ok(Pipeline {
            engine,
            model,
            gen,
            cfg,
            pretrained,
            tables: None,
            cache_root,
            orig_metric,
            orig_lat_eager,
            orig_lat_fused,
        })
    }

    /// Build or load the lookup tables (Sec. 3.2) — latency measured
    /// through the engine's backend, whatever it is.
    pub fn ensure_tables(&mut self) -> Result<&Tables> {
        if self.tables.is_none() {
            let t = tables::build(
                &self.model,
                self.engine.backend(),
                &self.gen,
                &self.pretrained,
                &self.cfg.build,
                &self.cache_root,
            )?;
            self.tables = Some(t);
        }
        Ok(self.tables.as_ref().unwrap())
    }

    /// Solve for (A*, C*) at `budget_frac` of the original latency.
    pub fn solve(&mut self, method: Method, budget_frac: f64) -> Result<solver::Solution> {
        let p_disc = self.cfg.p_disc;
        self.ensure_tables()?;
        let spec = self.model.spec.clone();
        let t = self.tables.as_ref().unwrap();
        solve_tables(&spec, t, method, budget_frac, p_disc)
    }

    /// Fine-tune the pruned network, merge, deploy, and measure — the tail
    /// of Algorithm 2.  `steps`/`lr` default to the pipeline config.
    pub fn finetune_and_deploy(
        &self,
        method: Method,
        budget_frac: f64,
        sol: &solver::Solution,
        steps: Option<usize>,
        distill: bool,
    ) -> Result<Compressed> {
        self.finetune_and_deploy_from(method, budget_frac, sol, steps, distill, None)
    }

    /// Like `finetune_and_deploy`, optionally starting from custom weights
    /// (the sequential ablation continues from the stage-1 checkpoint).
    pub fn finetune_and_deploy_from(
        &self,
        method: Method,
        budget_frac: f64,
        sol: &solver::Solution,
        steps: Option<usize>,
        distill: bool,
        init: Option<&[f32]>,
    ) -> Result<Compressed> {
        let spec = &self.model.spec;
        let a_set: BTreeSet<usize> = sol.a.iter().copied().collect();
        let gates = spec.solution_gates(&a_set, &sol.c, &sol.spans);
        let mut params = init.unwrap_or(&self.pretrained).to_vec();
        let steps = steps.unwrap_or(self.cfg.finetune_steps);
        let log = if distill {
            train::train_distill(
                &self.model, &self.gen, &self.pretrained, &mut params, &gates,
                steps, self.cfg.finetune_lr,
            )?
        } else {
            train::train(
                &self.model, &self.gen, &mut params, &gates, steps,
                self.cfg.finetune_lr, 0,
            )?
        };
        let _ = log;
        let (_, pruned_metric) = train::evaluate(
            &self.model, &self.gen, &params, &gates, self.cfg.eval_batches,
        )?;

        let plan =
            Arc::new(Plan::from_solution(spec, &params, &sol.a, &sol.c, &sol.spans)?);
        let merged_metric = self.eval_plan(&plan)?;
        // interleave compressed and original measurements (A/B fairness)
        let orig_plan = Arc::new(Plan::original(spec, &self.pretrained)?);
        let (w, it) = (self.cfg.lat_warmup, self.cfg.lat_iters);
        let lat_eager = self.engine.measure(&plan, Format::Eager, w, it)?.p50_ms;
        let base_eager = self.engine.measure(&orig_plan, Format::Eager, w, it)?.p50_ms;
        let lat_fused = self.engine.measure(&plan, Format::Fused, w, it)?.p50_ms;
        let base_fused = self.engine.measure(&orig_plan, Format::Fused, w, it)?.p50_ms;
        Ok(Compressed {
            method: method.name().to_string(),
            budget_frac,
            solution: sol.clone(),
            pruned_metric,
            merged_metric,
            lat_eager_ms: lat_eager,
            lat_fused_ms: lat_fused,
            base_eager_ms: base_eager,
            base_fused_ms: base_fused,
            depth: plan.depth(),
            finetuned: params,
            gates,
        })
    }

    /// Task metric of a deployed plan: accuracy (classify) or negative
    /// diffusion loss (diffusion), on the eval stream.
    pub fn eval_plan(&self, plan: &Arc<Plan>) -> Result<f32> {
        let n = self.cfg.eval_batches;
        // lower once; the per-batch loop is pure dispatch
        let cp = self.engine.lower(plan, Format::Eager)?;
        let mut acc = 0.0f32;
        for b in 0..n {
            let batch = self.gen.batch(train::STREAM_EVAL, b as u64);
            match (&batch, self.model.spec.task) {
                (crate::model::Batch::Classify { x, y }, Task::Classify) => {
                    let logits = cp.forward(x, None)?;
                    acc += host_accuracy(&logits, y);
                }
                (crate::model::Batch::Diffusion { x0, eps, t, abar }, Task::Diffusion) => {
                    // build x_t on host, predict eps, MSE
                    let mut xt = x0.clone();
                    let hw = x0.dims[1] * x0.dims[2] * x0.dims[3];
                    for n2 in 0..x0.dims[0] {
                        let (s, s1) = (abar.data[n2].sqrt(), (1.0 - abar.data[n2]).sqrt());
                        for i in 0..hw {
                            xt.data[n2 * hw + i] =
                                s * x0.data[n2 * hw + i] + s1 * eps.data[n2 * hw + i];
                        }
                    }
                    let pred = cp.forward(&xt, Some(t))?;
                    let mse: f32 = pred
                        .data
                        .iter()
                        .zip(&eps.data)
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum::<f32>()
                        / pred.data.len() as f32;
                    acc += -mse;
                }
                _ => anyhow::bail!("batch/task mismatch"),
            }
        }
        Ok(acc / n as f32)
    }

    /// Solve, relaxing the budget by 10% steps when the method cannot
    /// meet it (e.g. Depth on a testbed where merged-kernel growth is not
    /// amortized — itself a paper-relevant finding).  Returns the solution
    /// and the actually-used budget fraction.
    pub fn solve_relaxed(
        &mut self,
        method: Method,
        budget_frac: f64,
    ) -> Result<(solver::Solution, f64)> {
        relax_budget(budget_frac, 12, |b| self.solve(method, b))
            .with_context(|| format!("{}: budget relaxation failed", method.name()))
    }

    /// Convenience: solve + fine-tune + deploy in one call.
    pub fn run(&mut self, method: Method, budget_frac: f64) -> Result<Compressed> {
        let sol = self.solve(method, budget_frac)?;
        eprintln!(
            "[pipeline] {} {}@{budget_frac:.2}: {}",
            self.model.name,
            method.name(),
            sol.summary()
        );
        self.finetune_and_deploy(method, budget_frac, &sol, None, false)
    }
}

/// Solve for (A*, C*) on prebuilt tables — the method dispatch shared by
/// [`Pipeline::solve`] and the offline host paths ([`e2e_host`], the
/// frontier sweep).  `budget_frac` scales the table-estimated original
/// latency; fixed costs are subtracted before and re-added to
/// `latency_est` after, so every method optimizes the same budget.
pub fn solve_tables(
    spec: &Spec,
    t: &Tables,
    method: Method,
    budget_frac: f64,
    p_disc: usize,
) -> Result<solver::Solution> {
    let l_max = spec.len();
    let budget = budget_frac * t.orig_ms() - t.fixed_ms;
    anyhow::ensure!(budget > 0.0, "budget below fixed costs");

    match method {
        Method::LayerMerge | Method::Depth | Method::TwoStage => {
            let arcs = t.arcs(l_max);
            let input = dp::DpInput { l_max, budget_ms: budget, p: p_disc, arcs };
            let sol = match method {
                Method::LayerMerge => dp::solve(&input),
                Method::TwoStage => twostage::solve(&input),
                Method::Depth => {
                    depth::solve(spec, l_max, budget, p_disc, &input.arcs)
                }
                Method::LayerOnly => unreachable!(),
            }
            .with_context(|| format!("{:?}: no solution at {budget_frac}", method))?;
            // C* = union of per-span kept sets (Sec. 3.2)
            let mut c: BTreeSet<usize> = BTreeSet::new();
            for &(i, j, k) in &sol.spans {
                c.extend(&t.entries[&(i, j, k)].kept);
            }
            if method == Method::Depth {
                c = (1..=l_max).collect(); // Depth keeps every conv
            }
            Ok(solver::Solution {
                a: sol.a,
                c,
                spans: sol.spans,
                objective: sol.objective,
                latency_est: sol.latency_est + t.fixed_ms,
            })
        }
        Method::LayerOnly => {
            let forced: Vec<bool> = std::iter::once(false)
                .chain((1..=l_max).map(|l| !spec.conv(l).conv_gated))
                .collect();
            let sol = layeronly::solve(&layeronly::KnapsackInput {
                lat_ms: t.layer_lat.clone(),
                imp: t.layer_imp.clone(),
                forced,
                budget_ms: budget,
                p: p_disc,
            })
            .context("LayerOnly: no solution")?;
            let a = layeronly::deploy_a(spec, &sol.kept);
            let spans = layeronly::deploy_spans(spec, &sol.kept);
            Ok(solver::Solution {
                a,
                c: sol.kept,
                spans,
                objective: sol.objective,
                latency_est: sol.latency_est + t.fixed_ms,
            })
        }
    }
}

/// Outcome of one offline paper loop: profile → solve → merge → deploy →
/// measure, all on one backend, with the table-predicted and
/// actually-measured latencies side by side.
#[derive(Debug, Clone)]
pub struct E2eReport {
    pub model: String,
    pub budget_frac: f64,
    /// Table-predicted latency of the original network (sum approximation).
    pub pred_orig_ms: f64,
    /// Table-predicted latency of the chosen plan (solver's estimate).
    pub pred_merged_ms: f64,
    /// Measured latency of the deployed original plan.
    pub actual_orig_ms: f64,
    /// Measured latency of the deployed merged plan.
    pub actual_merged_ms: f64,
    pub depth_before: usize,
    pub depth_after: usize,
    pub spans: Vec<(usize, usize, usize)>,
    pub dp_objective: f64,
    pub dp_solve_ms: f64,
    pub twostage_objective: f64,
    pub twostage_solve_ms: f64,
    /// Active SIMD kernel ISA (`kernels::isa().name()`) the run executed
    /// with — latency numbers are meaningless without it.
    pub isa: String,
    /// Weight format the deployed plans lowered into (`"f32"`/`"int8"`).
    pub weight_format: String,
}

impl E2eReport {
    /// Relative error of the table prediction against the deployed
    /// measurement — the number the paper's whole premise rides on.
    pub fn rel_err(&self) -> f64 {
        (self.pred_merged_ms - self.actual_merged_ms).abs()
            / self.actual_merged_ms.max(1e-9)
    }

    pub fn pred_speedup(&self) -> f64 {
        self.pred_orig_ms / self.pred_merged_ms.max(1e-9)
    }

    pub fn actual_speedup(&self) -> f64 {
        self.actual_orig_ms / self.actual_merged_ms.max(1e-9)
    }
}

/// The full paper loop offline: build measured tables for a synthetic
/// spec on the host backend, solve with Algorithm 1 **and** the two-stage
/// baseline on the identical tables, deploy the DP's plan, and measure
/// predicted-vs-actual latency.  No XLA, no artifacts, no Python.
pub fn e2e_host(
    model: &str,
    budget_frac: f64,
    cfg: &PipelineCfg,
    cache_root: &Path,
) -> Result<E2eReport> {
    let (spec, flat) = crate::ir::synth::by_name(model)
        .with_context(|| format!("unknown synthetic spec {model}"))?;
    let backend: Arc<dyn Backend> = Arc::new(HostBackend::new());
    let t = tables::build_host(&spec, &flat, &backend, &cfg.build, cache_root)?;

    let l_max = spec.len();
    let budget = budget_frac * t.orig_ms() - t.fixed_ms;
    anyhow::ensure!(budget > 0.0, "budget below fixed costs");
    let input = dp::DpInput {
        l_max,
        budget_ms: budget,
        p: cfg.p_disc,
        arcs: t.arcs(l_max),
    };
    let dp_sol = dp::solve(&input)
        .with_context(|| format!("Algorithm 1 infeasible at {budget_frac}"))?;
    let two_sol = twostage::solve(&input)
        .with_context(|| format!("two-stage DP infeasible at {budget_frac}"))?;

    let mut c: BTreeSet<usize> = BTreeSet::new();
    for &(i, j, k) in &dp_sol.spans {
        c.extend(&t.entries[&(i, j, k)].kept);
    }
    let merged = Arc::new(Plan::from_solution(&spec, &flat, &dp_sol.a, &c, &dp_sol.spans)?);
    let orig = Arc::new(Plan::original(&spec, &flat)?);

    // deploy + measure both plans through the same protocol that built
    // the tables (Eager format — the per-op dispatch the entries model)
    let prof = Profiler::new(
        Arc::clone(&backend),
        cfg.build.mode,
        cfg.lat_warmup,
        cfg.lat_iters,
    );
    let actual_merged_ms = prof.measure_plan(Arc::clone(&merged), Format::Eager)?.p50_ms;
    let actual_orig_ms = prof.measure_plan(Arc::clone(&orig), Format::Eager)?.p50_ms;

    Ok(E2eReport {
        model: model.to_string(),
        budget_frac,
        pred_orig_ms: t.orig_ms(),
        pred_merged_ms: dp_sol.latency_est + t.fixed_ms,
        actual_orig_ms,
        actual_merged_ms,
        depth_before: orig.depth(),
        depth_after: merged.depth(),
        spans: dp_sol.spans,
        dp_objective: dp_sol.objective,
        dp_solve_ms: dp_sol.solve_ms,
        twostage_objective: two_sol.objective,
        twostage_solve_ms: two_sol.solve_ms,
        isa: crate::kernels::isa().name().to_string(),
        weight_format: backend.weight_format().name().to_string(),
    })
}

/// The budget relaxation ladder behind [`Pipeline::solve_relaxed`]: try
/// `solve` at `budget_frac`, relaxing by 10% steps up to `tries` times,
/// and report the budget fraction that finally succeeded.  Errors when
/// every rung is infeasible.
pub fn relax_budget<T>(
    budget_frac: f64,
    tries: usize,
    mut solve: impl FnMut(f64) -> Result<T>,
) -> Result<(T, f64)> {
    let mut b = budget_frac;
    for _ in 0..tries {
        match solve(b) {
            Ok(sol) => return Ok((sol, b)),
            Err(_) => b *= 1.1,
        }
    }
    anyhow::bail!(
        "infeasible even after {tries} relaxations (up to {:.2}x the original budget)",
        b / budget_frac.max(f64::MIN_POSITIVE)
    )
}

/// Host-side top-1 accuracy from logits + one-hot labels.
pub fn host_accuracy(logits: &Tensor, y1h: &Tensor) -> f32 {
    let (b, c) = (logits.dims[0], logits.dims[1]);
    let mut correct = 0;
    for n in 0..b {
        let row = &logits.data[n * c..(n + 1) * c];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let truth = y1h.data[n * c..(n + 1) * c]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred == truth {
            correct += 1;
        }
    }
    correct as f32 / b as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_accuracy_counts() {
        let logits = Tensor::new(vec![2, 3], vec![1.0, 5.0, 0.0, 2.0, 0.0, 1.0]);
        let y = Tensor::new(vec![2, 3], vec![0.0, 1.0, 0.0, 0.0, 0.0, 1.0]);
        assert!((host_accuracy(&logits, &y) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn csel_reexport_reachable() {
        // keep the module wiring honest
        let _ = crate::solver::csel::select;
    }

    #[test]
    fn relax_budget_climbs_the_ladder() {
        // infeasible below 0.8, feasible at or above: three 10% steps
        let mut calls = 0usize;
        let (sol, b) = relax_budget(0.65, 12, |b| {
            calls += 1;
            if b >= 0.8 {
                Ok(b)
            } else {
                anyhow::bail!("infeasible at {b}")
            }
        })
        .unwrap();
        assert_eq!(calls, 4); // 0.65, 0.715, 0.7865, 0.86515
        assert!((b - 0.65 * 1.1f64.powi(3)).abs() < 1e-12);
        assert_eq!(sol, b);
    }

    #[test]
    fn relax_budget_returns_first_feasible_unchanged() {
        let (sol, b) = relax_budget(0.5, 12, |b| Ok::<f64, anyhow::Error>(b)).unwrap();
        assert_eq!((sol, b), (0.5, 0.5));
    }

    #[test]
    fn relax_budget_errors_when_always_infeasible() {
        let mut calls = 0usize;
        let err = relax_budget(1.0, 5, |_| -> Result<()> {
            calls += 1;
            anyhow::bail!("no")
        })
        .unwrap_err();
        assert_eq!(calls, 5);
        assert!(format!("{err}").contains("infeasible"), "{err}");
    }
}
