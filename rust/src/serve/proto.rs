//! `serve::proto` — the length-prefixed binary wire protocol of the
//! network serving tier ([`super::net`]).
//!
//! This module is **pure bytes**: encoding and decoding of frame bodies,
//! no sockets, no timeouts (those live in `net`).  Keeping it IO-free
//! makes every framing rule unit-testable without a listener, and keeps
//! the decode path honest: every length is checked *before* it is used,
//! so a malformed or adversarial frame can produce [`DecodeError`] but
//! never an out-of-bounds slice, an overflowing multiply, or an
//! attempted giant allocation.
//!
//! # Frame layout
//!
//! Every frame on the wire is
//!
//! ```text
//! u32 LE body_len | body (body_len bytes, <= MAX_FRAME)
//! ```
//!
//! and every body starts with the same 6-byte preamble:
//!
//! ```text
//! u32 LE MAGIC ("LMRV") | u8 VERSION (2) | u8 kind
//! ```
//!
//! Request bodies (client → server):
//!
//! ```text
//! Infer:  preamble | u64 id | u64 deadline_us
//!         | u8 tenant_len | tenant_len x u8 tenant (UTF-8)
//!         | u8 has_t | u8 ndims
//!         | ndims x u32 dims | prod(dims) x f32 payload
//!         | has_t ? dims[0] x f32 timesteps
//! Stats:  preamble | u64 id
//! ```
//!
//! `deadline_us` is a **relative** budget from server receipt (0 = no
//! deadline) — relative, because client and server clocks need not
//! agree, and receipt is when admission control can first act on it.
//! `tenant` (≤ [`MAX_TENANT`] bytes; empty = the server's default
//! target) routes the request to a fleet tenant's budget ladder —
//! version 2's reason to exist.  A version-1 body (no tenant field) is
//! recognized and refused with the *typed* [`DecodeError::Legacy`], so
//! old clients get a clean `BadFrame` error frame naming the upgrade
//! instead of a silently misparsed tensor.
//!
//! Response bodies (server → client):
//!
//! ```text
//! Tensor: preamble | u64 id | u8 ndims | ndims x u32 dims
//!         | prod(dims) x f32 payload
//! Stats:  preamble | u64 id | rest = UTF-8 JSON
//! Error:  preamble | u64 id | u8 code | rest = UTF-8 message
//! ```
//!
//! The error `code` byte is the typed [`ErrCode`] — the wire image of
//! [`ServeError`] — so a client can distinguish "the server protected
//! itself" (`Shed`, `DeadlineExceeded`, `ShuttingDown`) from "the
//! request was bad" (`BadFrame`) and "the server broke" (`BackendFailed`)
//! without parsing prose.

use std::fmt;

use crate::util::tensor::Tensor;

use super::ServeError;

/// Frame magic: `b"LMRV"` little-endian ("LayerMerge serVe").  A frame
/// that does not open with it is not ours — the connection is closed
/// rather than resynchronized (there is no resync point in a
/// length-prefixed stream that lost framing).
pub const MAGIC: u32 = u32::from_le_bytes(*b"LMRV");

/// Protocol version; bumped on any incompatible layout change.
/// Version 2 added the tenant field to Infer bodies (fleet routing);
/// version-1 bodies decode to the typed [`DecodeError::Legacy`].
pub const VERSION: u8 = 2;

/// Longest tenant name an Infer frame may carry, bytes.
pub const MAX_TENANT: usize = 64;

/// Hard cap on a frame body, bytes (64 MiB).  Checked before any
/// allocation, so a hostile length prefix cannot OOM the server.
pub const MAX_FRAME: usize = 1 << 26;

/// Body byte offset of the `kind` byte (after magic + version).
const KIND_OFF: usize = 5;

/// Request frame kinds (client → server).
pub const KIND_INFER: u8 = 1;
pub const KIND_STATS: u8 = 2;

/// Response frame kinds (server → client).  High bit set, so a request
/// kind can never be confused for a response kind.
pub const KIND_TENSOR: u8 = 0x81;
pub const KIND_STATS_JSON: u8 = 0x82;
pub const KIND_ERROR: u8 = 0xff;

/// Most dims a wire tensor may carry — matches the small fixed ranks the
/// deployed networks use; anything larger is a malformed frame.
pub const MAX_NDIMS: usize = 8;

/// Typed wire error codes — the on-the-wire image of [`ServeError`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrCode {
    /// Refused at admission (queue wait would exceed deadline/SLO).
    Shed = 1,
    /// Deadline passed before dispatch; failed fast, not served late.
    DeadlineExceeded = 2,
    /// The request frame was malformed (framing, shapes, validation).
    BadFrame = 3,
    /// The dispatched batch errored or panicked.
    BackendFailed = 4,
    /// The server is draining and accepts no new work.
    ShuttingDown = 5,
}

impl ErrCode {
    /// The wire code for a typed serving error.  `Rejected` (shape /
    /// timestep validation) maps to `BadFrame`: from the client's seat a
    /// request the session refuses to parse and a frame the server
    /// refuses to parse are the same fault class.
    pub fn of(e: &ServeError) -> ErrCode {
        match e {
            ServeError::Rejected(_) => ErrCode::BadFrame,
            ServeError::Shed { .. } => ErrCode::Shed,
            ServeError::DeadlineExceeded => ErrCode::DeadlineExceeded,
            ServeError::BackendFailed(_) => ErrCode::BackendFailed,
            ServeError::ShuttingDown => ErrCode::ShuttingDown,
        }
    }

    pub fn from_u8(b: u8) -> Option<ErrCode> {
        match b {
            1 => Some(ErrCode::Shed),
            2 => Some(ErrCode::DeadlineExceeded),
            3 => Some(ErrCode::BadFrame),
            4 => Some(ErrCode::BackendFailed),
            5 => Some(ErrCode::ShuttingDown),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ErrCode::Shed => "Shed",
            ErrCode::DeadlineExceeded => "DeadlineExceeded",
            ErrCode::BadFrame => "BadFrame",
            ErrCode::BackendFailed => "BackendFailed",
            ErrCode::ShuttingDown => "ShuttingDown",
        }
    }
}

impl fmt::Display for ErrCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Why a frame body failed to decode.  The variant drives the
/// connection-level response in `net`: a body that carried our magic but
/// bad content gets a `BadFrame` error frame and the connection lives
/// (framing is intact — the next frame is readable); a body that is not
/// even ours ([`DecodeError::NotOurs`]) closes the connection (framing
/// trust is gone).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DecodeError {
    /// Wrong magic or an unknown protocol version — not a frame we speak.
    NotOurs(String),
    /// Our magic, but the content is malformed (truncated, bad kind,
    /// oversized dims, length mismatch...).
    Malformed(String),
    /// Our magic and a protocol version we *recognize but no longer
    /// serve* (version 1, before the tenant field).  Framing is intact —
    /// the server answers a typed `BadFrame` error naming the upgrade
    /// and keeps the connection, instead of closing on the old client.
    Legacy(String),
}

impl fmt::Display for DecodeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DecodeError::NotOurs(m)
            | DecodeError::Malformed(m)
            | DecodeError::Legacy(m) => f.write_str(m),
        }
    }
}

impl std::error::Error for DecodeError {}

type DecodeResult<T> = std::result::Result<T, DecodeError>;

/// A decoded request frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// One inference request: `x` is `[rows, tail..]`, `t` (present iff
    /// `has_t` was set) is `[rows]`, `deadline_us` is the relative
    /// serve-by budget from receipt (0 = none), `tenant` routes to a
    /// fleet tenant's ladder (empty = the server's default target).
    Infer { id: u64, deadline_us: u64, tenant: String, x: Tensor, t: Option<Tensor> },
    /// Ask for the server's cumulative `ServeStats` as JSON.
    Stats { id: u64 },
}

impl Request {
    pub fn id(&self) -> u64 {
        match self {
            Request::Infer { id, .. } | Request::Stats { id } => *id,
        }
    }
}

/// A decoded response frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Tensor { id: u64, y: Tensor },
    Stats { id: u64, json: String },
    Error { id: u64, code: ErrCode, msg: String },
}

impl Response {
    pub fn id(&self) -> u64 {
        match self {
            Response::Tensor { id, .. }
            | Response::Stats { id, .. }
            | Response::Error { id, .. } => *id,
        }
    }
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn preamble(out: &mut Vec<u8>, kind: u8, id: u64) {
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(VERSION);
    out.push(kind);
    out.extend_from_slice(&id.to_le_bytes());
}

fn put_tensor(out: &mut Vec<u8>, t: &Tensor) {
    debug_assert!(t.dims.len() <= MAX_NDIMS);
    out.push(t.dims.len() as u8);
    for &d in &t.dims {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    for &v in &t.data {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Encode a request **body** (the `u32` length prefix is written by the
/// socket layer, which is the only place that knows it is about to send).
pub fn encode_request(req: &Request) -> Vec<u8> {
    match req {
        Request::Infer { id, deadline_us, tenant, x, t } => {
            debug_assert!(tenant.len() <= MAX_TENANT, "tenant name too long");
            let mut out =
                Vec::with_capacity(33 + tenant.len() + 4 * (x.data.len() + x.dims.len()));
            preamble(&mut out, KIND_INFER, *id);
            out.extend_from_slice(&deadline_us.to_le_bytes());
            out.push(tenant.len().min(MAX_TENANT) as u8);
            out.extend_from_slice(&tenant.as_bytes()[..tenant.len().min(MAX_TENANT)]);
            out.push(u8::from(t.is_some()));
            put_tensor(&mut out, x);
            if let Some(tt) = t {
                for &v in &tt.data {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            out
        }
        Request::Stats { id } => {
            let mut out = Vec::with_capacity(14);
            preamble(&mut out, KIND_STATS, *id);
            out
        }
    }
}

/// Encode a response **body**.
pub fn encode_response(resp: &Response) -> Vec<u8> {
    match resp {
        Response::Tensor { id, y } => {
            let mut out = Vec::with_capacity(16 + 4 * (y.data.len() + y.dims.len()));
            preamble(&mut out, KIND_TENSOR, *id);
            put_tensor(&mut out, y);
            out
        }
        Response::Stats { id, json } => {
            let mut out = Vec::with_capacity(14 + json.len());
            preamble(&mut out, KIND_STATS_JSON, *id);
            out.extend_from_slice(json.as_bytes());
            out
        }
        Response::Error { id, code, msg } => {
            let mut out = Vec::with_capacity(15 + msg.len());
            preamble(&mut out, KIND_ERROR, *id);
            out.push(*code as u8);
            out.extend_from_slice(msg.as_bytes());
            out
        }
    }
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

/// Bounds-checked little-endian cursor over a frame body.  Every read
/// states what it was reading, so a truncated frame reports *which*
/// field ran off the end.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> DecodeResult<&'a [u8]> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        match end {
            Some(end) => {
                let s = &self.buf[self.pos..end];
                self.pos = end;
                Ok(s)
            }
            None => Err(DecodeError::Malformed(format!(
                "frame truncated reading {what}: need {n} bytes at offset {}, body is {}",
                self.pos,
                self.buf.len()
            ))),
        }
    }

    fn u8(&mut self, what: &str) -> DecodeResult<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> DecodeResult<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> DecodeResult<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes(b.try_into().expect("8-byte slice")))
    }

    fn f32s(&mut self, n: usize, what: &str) -> DecodeResult<Vec<f32>> {
        let bytes = n.checked_mul(4).ok_or_else(|| {
            DecodeError::Malformed(format!("{what}: element count {n} overflows"))
        })?;
        let b = self.take(bytes, what)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn rest(&mut self) -> &'a [u8] {
        let s = &self.buf[self.pos..];
        self.pos = self.buf.len();
        s
    }

    fn done(&self, what: &str) -> DecodeResult<()> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(DecodeError::Malformed(format!(
                "{what}: {} trailing bytes after a complete frame",
                self.buf.len() - self.pos
            )))
        }
    }
}

/// Validate the shared preamble and return the kind byte.
fn check_preamble(c: &mut Cursor<'_>) -> DecodeResult<u8> {
    let magic = c.u32("magic")?;
    if magic != MAGIC {
        return Err(DecodeError::NotOurs(format!(
            "bad magic {magic:#010x} (want {MAGIC:#010x})"
        )));
    }
    let version = c.u8("version")?;
    if version == 1 {
        // recognized-but-retired: v1 framing is intact (same preamble and
        // length prefix), so the caller can answer a typed error and keep
        // the connection rather than closing on an old client
        return Err(DecodeError::Legacy(format!(
            "protocol version 1 is no longer served (speak {VERSION}: \
             Infer frames carry a tenant field)"
        )));
    }
    if version != VERSION {
        return Err(DecodeError::NotOurs(format!(
            "unsupported protocol version {version} (speak {VERSION})"
        )));
    }
    c.u8("kind")
}

/// Decode tensor dims: rank, per-dim sizes, with the element count
/// bounded by what the body could possibly hold — so a hostile dim
/// vector is refused before any allocation sizing happens.
fn get_dims(c: &mut Cursor<'_>, body_len: usize) -> DecodeResult<Vec<usize>> {
    let ndims = c.u8("ndims")? as usize;
    if ndims == 0 || ndims > MAX_NDIMS {
        return Err(DecodeError::Malformed(format!(
            "tensor rank {ndims} out of range 1..={MAX_NDIMS}"
        )));
    }
    let mut dims = Vec::with_capacity(ndims);
    let mut elems: usize = 1;
    for i in 0..ndims {
        let d = c.u32(&format!("dim {i}"))? as usize;
        if d == 0 {
            return Err(DecodeError::Malformed(format!("dim {i} is zero")));
        }
        elems = elems.checked_mul(d).ok_or_else(|| {
            DecodeError::Malformed("tensor element count overflows".into())
        })?;
        // 4 bytes/elem must still fit in what the sender actually sent;
        // this refuses absurd shapes before f32s() sizes an allocation
        if elems > body_len / 4 + 1 {
            return Err(DecodeError::Malformed(format!(
                "tensor of {elems}+ elements cannot fit a {body_len}-byte body"
            )));
        }
        dims.push(d);
    }
    Ok(dims)
}

/// Decode a request body (everything after the `u32` length prefix).
pub fn decode_request(body: &[u8]) -> DecodeResult<Request> {
    if body.len() > MAX_FRAME {
        return Err(DecodeError::Malformed(format!(
            "frame body {} exceeds MAX_FRAME {MAX_FRAME}",
            body.len()
        )));
    }
    let mut c = Cursor::new(body);
    let kind = check_preamble(&mut c)?;
    let id = c.u64("request id")?;
    match kind {
        KIND_INFER => {
            let deadline_us = c.u64("deadline_us")?;
            let tlen = c.u8("tenant_len")? as usize;
            if tlen > MAX_TENANT {
                return Err(DecodeError::Malformed(format!(
                    "tenant name of {tlen} bytes exceeds MAX_TENANT {MAX_TENANT}"
                )));
            }
            let tenant = std::str::from_utf8(c.take(tlen, "tenant")?)
                .map_err(|_| {
                    DecodeError::Malformed("tenant name is not UTF-8".into())
                })?
                .to_string();
            let has_t = match c.u8("has_t")? {
                0 => false,
                1 => true,
                b => {
                    return Err(DecodeError::Malformed(format!(
                        "has_t byte must be 0 or 1, got {b}"
                    )))
                }
            };
            let dims = get_dims(&mut c, body.len())?;
            let n: usize = dims.iter().product();
            let data = c.f32s(n, "tensor payload")?;
            let t = if has_t {
                let rows = dims[0];
                Some(Tensor::new(vec![rows], c.f32s(rows, "timesteps")?))
            } else {
                None
            };
            c.done("infer request")?;
            Ok(Request::Infer { id, deadline_us, tenant, x: Tensor::new(dims, data), t })
        }
        KIND_STATS => {
            c.done("stats request")?;
            Ok(Request::Stats { id })
        }
        k => Err(DecodeError::Malformed(format!(
            "unknown request kind {k:#04x}"
        ))),
    }
}

/// Decode a response body.
pub fn decode_response(body: &[u8]) -> DecodeResult<Response> {
    if body.len() > MAX_FRAME {
        return Err(DecodeError::Malformed(format!(
            "frame body {} exceeds MAX_FRAME {MAX_FRAME}",
            body.len()
        )));
    }
    let mut c = Cursor::new(body);
    let kind = check_preamble(&mut c)?;
    let id = c.u64("response id")?;
    match kind {
        KIND_TENSOR => {
            let dims = get_dims(&mut c, body.len())?;
            let n: usize = dims.iter().product();
            let data = c.f32s(n, "tensor payload")?;
            c.done("tensor response")?;
            Ok(Response::Tensor { id, y: Tensor::new(dims, data) })
        }
        KIND_STATS_JSON => {
            let json = String::from_utf8(c.rest().to_vec()).map_err(|_| {
                DecodeError::Malformed("stats payload is not UTF-8".into())
            })?;
            Ok(Response::Stats { id, json })
        }
        KIND_ERROR => {
            let code_b = c.u8("error code")?;
            let code = ErrCode::from_u8(code_b).ok_or_else(|| {
                DecodeError::Malformed(format!("unknown error code {code_b}"))
            })?;
            let msg = String::from_utf8(c.rest().to_vec()).map_err(|_| {
                DecodeError::Malformed("error message is not UTF-8".into())
            })?;
            Ok(Response::Error { id, code, msg })
        }
        k => Err(DecodeError::Malformed(format!(
            "unknown response kind {k:#04x}"
        ))),
    }
}

/// Peek a body's kind byte without a full decode (the server uses it to
/// tell request kinds apart before committing to a decode path).
pub fn peek_kind(body: &[u8]) -> Option<u8> {
    body.get(KIND_OFF).copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn x23() -> Tensor {
        Tensor::new(vec![2, 3], vec![1.0, -2.5, 3.0, 0.0, 4.25, -0.5])
    }

    #[test]
    fn infer_roundtrip_without_t() {
        let r = Request::Infer {
            id: 42,
            deadline_us: 25_000,
            tenant: String::new(),
            x: x23(),
            t: None,
        };
        let body = encode_request(&r);
        assert_eq!(decode_request(&body).unwrap(), r);
    }

    #[test]
    fn infer_roundtrip_with_t() {
        let t = Tensor::new(vec![2], vec![100.0, 200.0]);
        let r = Request::Infer {
            id: 7,
            deadline_us: 0,
            tenant: String::new(),
            x: x23(),
            t: Some(t),
        };
        let body = encode_request(&r);
        assert_eq!(decode_request(&body).unwrap(), r);
    }

    #[test]
    fn infer_roundtrip_with_tenant() {
        let r = Request::Infer {
            id: 11,
            deadline_us: 5_000,
            tenant: "edge-résnet".into(), // multi-byte UTF-8 survives
            x: x23(),
            t: None,
        };
        let body = encode_request(&r);
        assert_eq!(decode_request(&body).unwrap(), r);
    }

    #[test]
    fn stats_roundtrip() {
        let r = Request::Stats { id: u64::MAX };
        assert_eq!(decode_request(&encode_request(&r)).unwrap(), r);
    }

    #[test]
    fn response_roundtrips() {
        for resp in [
            Response::Tensor { id: 1, y: x23() },
            Response::Stats { id: 2, json: "{\"requests\":3}".into() },
            Response::Error {
                id: 3,
                code: ErrCode::Shed,
                msg: "predicted wait 9000us exceeds 5000us".into(),
            },
        ] {
            let body = encode_response(&resp);
            assert_eq!(decode_response(&body).unwrap(), resp);
        }
    }

    #[test]
    fn bad_magic_is_not_ours() {
        let mut body = encode_request(&Request::Stats { id: 1 });
        body[0] ^= 0xff;
        match decode_request(&body) {
            Err(DecodeError::NotOurs(m)) => assert!(m.contains("magic"), "{m}"),
            other => panic!("want NotOurs, got {other:?}"),
        }
    }

    #[test]
    fn bad_version_is_not_ours() {
        let mut body = encode_request(&Request::Stats { id: 1 });
        body[4] = VERSION + 1;
        assert!(matches!(decode_request(&body), Err(DecodeError::NotOurs(_))));
    }

    #[test]
    fn version_one_is_typed_legacy_not_closed() {
        let mut body = encode_request(&Request::Stats { id: 1 });
        body[4] = 1;
        match decode_request(&body) {
            Err(DecodeError::Legacy(m)) => {
                assert!(m.contains("version 1"), "{m}");
                assert!(m.contains("tenant"), "should name the upgrade: {m}");
            }
            other => panic!("want Legacy, got {other:?}"),
        }
    }

    #[test]
    fn oversized_tenant_is_malformed() {
        // hand-build: tenant_len byte claims more than MAX_TENANT
        let mut body = Vec::new();
        body.extend_from_slice(&MAGIC.to_le_bytes());
        body.push(VERSION);
        body.push(KIND_INFER);
        body.extend_from_slice(&1u64.to_le_bytes()); // id
        body.extend_from_slice(&0u64.to_le_bytes()); // deadline
        body.push((MAX_TENANT + 1) as u8); // tenant_len
        body.extend_from_slice(&vec![b'a'; MAX_TENANT + 1]);
        match decode_request(&body) {
            Err(DecodeError::Malformed(m)) => assert!(m.contains("MAX_TENANT"), "{m}"),
            other => panic!("want Malformed, got {other:?}"),
        }
    }

    #[test]
    fn non_utf8_tenant_is_malformed() {
        let mut body = Vec::new();
        body.extend_from_slice(&MAGIC.to_le_bytes());
        body.push(VERSION);
        body.push(KIND_INFER);
        body.extend_from_slice(&1u64.to_le_bytes());
        body.extend_from_slice(&0u64.to_le_bytes());
        body.push(2); // tenant_len
        body.extend_from_slice(&[0xff, 0xfe]); // invalid UTF-8
        match decode_request(&body) {
            Err(DecodeError::Malformed(m)) => assert!(m.contains("UTF-8"), "{m}"),
            other => panic!("want Malformed, got {other:?}"),
        }
    }

    #[test]
    fn truncated_payload_is_malformed_and_names_the_field() {
        let body = encode_request(&Request::Infer {
            id: 9,
            deadline_us: 0,
            tenant: String::new(),
            x: x23(),
            t: None,
        });
        let cut = &body[..body.len() - 5];
        match decode_request(cut) {
            Err(DecodeError::Malformed(m)) => {
                assert!(m.contains("tensor payload"), "{m}")
            }
            other => panic!("want Malformed, got {other:?}"),
        }
    }

    #[test]
    fn trailing_garbage_is_malformed() {
        let mut body = encode_request(&Request::Stats { id: 1 });
        body.push(0);
        assert!(matches!(decode_request(&body), Err(DecodeError::Malformed(_))));
    }

    #[test]
    fn hostile_dims_are_refused_before_allocation() {
        // rank 2, dims [0xffff_ffff, 0xffff_ffff]: product overflows and
        // could never fit the body — must be Malformed, not a panic/OOM
        let mut body = Vec::new();
        body.extend_from_slice(&MAGIC.to_le_bytes());
        body.push(VERSION);
        body.push(KIND_INFER);
        body.extend_from_slice(&1u64.to_le_bytes()); // id
        body.extend_from_slice(&0u64.to_le_bytes()); // deadline
        body.push(0); // tenant_len
        body.push(0); // has_t
        body.push(2); // ndims
        body.extend_from_slice(&u32::MAX.to_le_bytes());
        body.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(decode_request(&body), Err(DecodeError::Malformed(_))));
    }

    #[test]
    fn zero_rank_and_oversized_rank_are_refused() {
        for ndims in [0u8, (MAX_NDIMS + 1) as u8] {
            let mut body = Vec::new();
            body.extend_from_slice(&MAGIC.to_le_bytes());
            body.push(VERSION);
            body.push(KIND_INFER);
            body.extend_from_slice(&1u64.to_le_bytes());
            body.extend_from_slice(&0u64.to_le_bytes());
            body.push(0); // tenant_len
            body.push(0); // has_t
            body.push(ndims);
            assert!(
                matches!(decode_request(&body), Err(DecodeError::Malformed(_))),
                "rank {ndims} must be refused"
            );
        }
    }

    #[test]
    fn err_code_maps_every_serve_error() {
        use ServeError as E;
        assert_eq!(ErrCode::of(&E::Rejected("x".into())), ErrCode::BadFrame);
        assert_eq!(
            ErrCode::of(&E::Shed { queued_rows: 1, predicted_us: 2, budget_us: 3 }),
            ErrCode::Shed
        );
        assert_eq!(ErrCode::of(&E::DeadlineExceeded), ErrCode::DeadlineExceeded);
        assert_eq!(ErrCode::of(&E::BackendFailed("x".into())), ErrCode::BackendFailed);
        assert_eq!(ErrCode::of(&E::ShuttingDown), ErrCode::ShuttingDown);
        for c in [
            ErrCode::Shed,
            ErrCode::DeadlineExceeded,
            ErrCode::BadFrame,
            ErrCode::BackendFailed,
            ErrCode::ShuttingDown,
        ] {
            assert_eq!(ErrCode::from_u8(c as u8), Some(c));
        }
        assert_eq!(ErrCode::from_u8(0), None);
        assert_eq!(ErrCode::from_u8(99), None);
    }

    #[test]
    fn peek_kind_sees_the_kind_byte() {
        let body = encode_request(&Request::Stats { id: 5 });
        assert_eq!(peek_kind(&body), Some(KIND_STATS));
        assert_eq!(peek_kind(&[0, 1, 2]), None);
    }

    /// Every valid v1/v2 frame this suite can produce, as mutation bases
    /// for the totality property below.
    fn frame_corpus() -> Vec<Vec<u8>> {
        let mut bases = vec![
            encode_request(&Request::Stats { id: 3 }),
            encode_request(&Request::Infer {
                id: 9,
                deadline_us: 25_000,
                tenant: "edge".into(),
                x: x23(),
                t: None,
            }),
            encode_request(&Request::Infer {
                id: 10,
                deadline_us: 0,
                tenant: String::new(),
                x: x23(),
                t: Some(Tensor::new(vec![2], vec![100.0, 200.0])),
            }),
            encode_response(&Response::Tensor { id: 1, y: x23() }),
            encode_response(&Response::Stats { id: 2, json: "{\"requests\":3}".into() }),
            encode_response(&Response::Error {
                id: 4,
                code: ErrCode::Shed,
                msg: "queue full".into(),
            }),
        ];
        // a wire-v1 lookalike (same framing, version byte 1): mutations
        // of legacy traffic must be exactly as harmless
        let mut v1 = encode_request(&Request::Stats { id: 7 });
        v1[4] = 1; // the version byte follows the u32 magic
        bases.push(v1);
        bases
    }

    /// The decoder is a *total function*: any byte-flip / truncate /
    /// extend mutation of a valid frame yields `Ok` or a typed
    /// [`DecodeError`] — never a panic.  (`DecodeError` has only the
    /// `NotOurs`/`Malformed`/`Legacy` variants, so "no panic" IS the
    /// whole property; the mutation space is what makes it bite.)
    #[test]
    fn prop_mutated_frames_never_panic_the_decoders() {
        let bases = frame_corpus();
        crate::util::prop::check_res(
            "mutated v1/v2 frames decode totally",
            800,
            |r| {
                let mut b = bases[r.below(bases.len())].clone();
                match r.below(4) {
                    0 => {
                        // flip up to 3 bits anywhere (magic, kind, dims,
                        // lengths, payload...)
                        for _ in 0..=r.below(3) {
                            if !b.is_empty() {
                                let i = r.below(b.len());
                                b[i] ^= 1 << r.below(8);
                            }
                        }
                    }
                    1 => {
                        let keep = r.below(b.len() + 1);
                        b.truncate(keep);
                    }
                    2 => {
                        for _ in 0..=r.below(16) {
                            b.push((r.next_u64() & 0xff) as u8);
                        }
                    }
                    _ => {
                        // corrupt *and* truncate
                        if !b.is_empty() {
                            let i = r.below(b.len());
                            b[i] ^= 0xff;
                        }
                        let keep = r.below(b.len() + 1);
                        b.truncate(keep);
                    }
                }
                b
            },
            |bytes| {
                let got = std::panic::catch_unwind(|| {
                    let _ = peek_kind(bytes);
                    let req = decode_request(bytes);
                    let resp = decode_response(bytes);
                    // totality, spelled out: each result is a frame or a
                    // typed error
                    matches!(req, Ok(_) | Err(_)) && matches!(resp, Ok(_) | Err(_))
                });
                match got {
                    Ok(true) => Ok(()),
                    Ok(false) => Err("non-total decode result".into()),
                    Err(_) => Err("decoder panicked".into()),
                }
            },
        );
    }

    /// Unmutated corpus frames decode cleanly (guards the corpus itself:
    /// a base that is already invalid would weaken the mutation test).
    #[test]
    fn frame_corpus_bases_decode() {
        for (i, b) in frame_corpus().iter().enumerate() {
            let req = decode_request(b);
            let resp = decode_response(b);
            assert!(
                req.is_ok()
                    || resp.is_ok()
                    || matches!(req, Err(DecodeError::Legacy(_))),
                "corpus frame {i} decodes as neither request, response, nor legacy"
            );
        }
    }
}
