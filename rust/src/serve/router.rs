//! `serve::router` — deadline-aware ladder routing for the fleet.
//!
//! A tenant deploys a *budget ladder*: the same base model lowered at
//! several depth-compression budgets, cheapest (most compressed) rung
//! first.  The router picks, per request, the **cheapest rung whose
//! predicted completion time meets the request deadline**, falling back
//! up the ladder when the cheap rungs are backed up and shedding (typed
//! [`crate::serve::ServeError::Shed`] at the fleet layer) when no rung
//! can make the deadline at all.
//!
//! The cost model is the same signal the serving tier already trusts:
//! an EWMA of per-batch service time, **seeded from the DP solver's
//! measured latency table** for the plan (so routing is sensible from
//! the first request, before any online signal exists) and refined
//! online from real dispatches with the same 3/4-decay the
//! `Adaptive` batch controller uses.
//!
//! The router itself is pure decision logic over [`RungView`] snapshots
//! — it owns no queues and takes no locks, so it is trivially testable
//! and the fleet can call it under its own scheduler lock.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Online per-rung service-time estimator: seeded from the solver's
/// latency table at deploy, refined from observed batch service times.
/// Shared between the fleet's dispatch path (writer) and the router
/// (reader), hence atomic.
#[derive(Debug)]
pub struct RungCost {
    /// EWMA per-batch service time, µs.  Never 0 after construction —
    /// the seed keeps the predictor defined before the first dispatch.
    svc_ewma_us: AtomicU64,
}

impl RungCost {
    /// A cost estimator seeded with the plan's expected per-batch
    /// latency in µs (from measurement or the DP latency table).  A zero
    /// seed is clamped to 1 so predictions stay defined.
    pub fn new(seed_us: u64) -> RungCost {
        RungCost { svc_ewma_us: AtomicU64::new(seed_us.max(1)) }
    }

    /// Fold one observed batch service time into the estimate (3/4
    /// decay, matching the batch controller's EWMA).
    pub fn observe(&self, svc_us: u64) {
        let svc_us = svc_us.max(1);
        // racing writers may each lose the other's sample to the RMW
        // gap; the estimator is advisory, so staleness beats a lock here
        let cur = self.svc_ewma_us.load(Ordering::Relaxed);
        self.svc_ewma_us.store((cur * 3 + svc_us) / 4, Ordering::Relaxed);
    }

    /// Current EWMA per-batch service time, µs (≥ 1).
    pub fn svc_us(&self) -> u64 {
        self.svc_ewma_us.load(Ordering::Relaxed)
    }
}

/// A scheduler-lock snapshot of one ladder rung, as the router scores it.
#[derive(Debug, Clone, Copy)]
pub struct RungView {
    /// Rows already queued on this rung.
    pub queued_rows: usize,
    /// The rung plan's batch size B.
    pub batch: usize,
    /// Current EWMA per-batch service time, µs ([`RungCost::svc_us`]).
    pub svc_us: u64,
    /// Whether the fleet's rung supervisor currently offers this rung
    /// (healthy or on a probation probe).  A quarantined rung is skipped
    /// — unless *every* rung is quarantined, in which case the whole
    /// ladder is offered rather than bricking the tenant.
    pub healthy: bool,
}

impl RungView {
    /// Predicted completion time for a `rows`-row request landing on
    /// this rung now: queued-ahead batches plus the request's own batch,
    /// spread over `workers` drainers, each costing the EWMA service
    /// time.  Conservative at light load (a partially full batch counts
    /// whole) — exactly the bias a deadline router wants.
    pub fn predicted_us(&self, rows: usize, workers: usize) -> u64 {
        let b = self.batch.max(1);
        let batches = (self.queued_rows + rows).div_ceil(b) as u64;
        batches * self.svc_us / workers.max(1) as u64
    }
}

/// Routing decision over a ladder of [`RungView`]s.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Serve on rung `.0` (index into the ladder, cheapest-first); the
    /// cheapest rung met the deadline.
    Hit(usize),
    /// Serve on rung `.0`, but only after falling back past cheaper
    /// rungs that could not meet the deadline.
    Fallback(usize),
    /// No rung's predicted completion meets the budget — shed.
    Shed {
        /// The best (smallest) predicted completion across the ladder, µs.
        predicted_us: u64,
    },
}

impl Route {
    /// The chosen rung index, if the request was not shed.
    pub fn rung(&self) -> Option<usize> {
        match *self {
            Route::Hit(i) | Route::Fallback(i) => Some(i),
            Route::Shed { .. } => None,
        }
    }
}

/// Cumulative router telemetry (monotonic counters).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RouterStats {
    /// Requests routed to the cheapest rung of their ladder.
    pub hits: usize,
    /// Requests that fell back to a costlier rung to make their deadline.
    pub fallbacks: usize,
    /// Requests no rung could serve in time.
    pub sheds: usize,
}

impl RouterStats {
    /// Fraction of non-shed decisions that landed on the cheapest rung —
    /// the bench compares this against the always-biggest-plan baseline.
    pub fn hit_rate(&self) -> f64 {
        let routed = self.hits + self.fallbacks;
        if routed == 0 {
            1.0
        } else {
            self.hits as f64 / routed as f64
        }
    }
}

/// The deadline-aware ladder router.  Stateless per decision (all rung
/// state arrives as [`RungView`]s); owns only its telemetry counters.
#[derive(Debug, Default)]
pub struct Router {
    hits: AtomicUsize,
    fallbacks: AtomicUsize,
    sheds: AtomicUsize,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    /// Pick a rung for a `rows`-row request with `budget_us` of headroom
    /// (`u64::MAX` = no deadline).  `rungs` is the tenant's ladder in
    /// deployment order; `workers` is the fleet pool draining it.
    ///
    /// Semantics:
    /// * Only rungs the supervisor offers ([`RungView::healthy`]) are
    ///   candidates; when *none* are offered, the full ladder is (no
    ///   healthy rung must not mean no service at all).
    /// * Candidates are scanned **cheapest-first by service EWMA** (the
    ///   deployment order is not trusted — online refinement may have
    ///   reordered the real costs).
    /// * The first candidate whose [`RungView::predicted_us`] fits the
    ///   budget wins: the cheapest rung that still meets the deadline.
    /// * With no deadline, the rung with the smallest *predicted
    ///   completion* wins (cheapest net of queueing, never shed).
    /// * If no rung fits a finite budget, the request sheds.
    pub fn route(&self, rungs: &[RungView], rows: usize, budget_us: u64, workers: usize) -> Route {
        assert!(!rungs.is_empty(), "route: tenant has an empty ladder");
        let mut order: Vec<usize> = (0..rungs.len()).filter(|&i| rungs[i].healthy).collect();
        if order.is_empty() {
            order = (0..rungs.len()).collect();
        }
        order.sort_by_key(|&i| (rungs[i].svc_us, i));
        if budget_us == u64::MAX {
            // no deadline: minimize predicted completion outright
            let best = *order
                .iter()
                .min_by_key(|&&i| (rungs[i].predicted_us(rows, workers), i))
                .unwrap();
            return self.tally(best, order[0]);
        }
        let mut best_pred = u64::MAX;
        for &i in &order {
            let pred = rungs[i].predicted_us(rows, workers);
            best_pred = best_pred.min(pred);
            if pred <= budget_us {
                return self.tally(i, order[0]);
            }
        }
        self.sheds.fetch_add(1, Ordering::Relaxed);
        Route::Shed { predicted_us: best_pred }
    }

    fn tally(&self, chosen: usize, cheapest: usize) -> Route {
        if chosen == cheapest {
            self.hits.fetch_add(1, Ordering::Relaxed);
            Route::Hit(chosen)
        } else {
            self.fallbacks.fetch_add(1, Ordering::Relaxed);
            Route::Fallback(chosen)
        }
    }

    pub fn stats(&self) -> RouterStats {
        RouterStats {
            hits: self.hits.load(Ordering::Relaxed),
            fallbacks: self.fallbacks.load(Ordering::Relaxed),
            sheds: self.sheds.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(queued_rows: usize, batch: usize, svc_us: u64) -> RungView {
        RungView { queued_rows, batch, svc_us, healthy: true }
    }

    #[test]
    fn quarantined_rung_is_bypassed() {
        let r = Router::new();
        // the cheapest rung is quarantined: the router must route around
        // it even though it would otherwise win
        let mut rungs = [view(0, 8, 100), view(0, 8, 300)];
        rungs[0].healthy = false;
        assert_eq!(r.route(&rungs, 1, 10_000, 1), Route::Hit(1));
        // re-admitted: it wins again
        rungs[0].healthy = true;
        assert_eq!(r.route(&rungs, 1, 10_000, 1), Route::Hit(0));
    }

    #[test]
    fn all_quarantined_offers_the_full_ladder() {
        let r = Router::new();
        let mut rungs = [view(0, 8, 100), view(0, 8, 300)];
        rungs[0].healthy = false;
        rungs[1].healthy = false;
        // no healthy rung must not brick the tenant
        assert_eq!(r.route(&rungs, 1, 10_000, 1), Route::Hit(0));
    }

    #[test]
    fn cost_seed_and_observe_converge() {
        let c = RungCost::new(0);
        assert_eq!(c.svc_us(), 1, "zero seed clamps to 1");
        let c = RungCost::new(1000);
        for _ in 0..64 {
            c.observe(2000);
        }
        assert!(
            (1900..=2000).contains(&c.svc_us()),
            "EWMA should converge toward the observed 2000us, got {}",
            c.svc_us()
        );
    }

    #[test]
    fn empty_idle_ladder_routes_to_cheapest() {
        let r = Router::new();
        let rungs = [view(0, 8, 100), view(0, 8, 300), view(0, 8, 900)];
        assert_eq!(r.route(&rungs, 1, 10_000, 1), Route::Hit(0));
        assert_eq!(r.stats().hits, 1);
        assert_eq!(r.stats().hit_rate(), 1.0);
    }

    #[test]
    fn backed_up_cheap_rung_falls_back_up_the_ladder() {
        let r = Router::new();
        // rung 0 is cheap per batch but has 10 batches queued ahead:
        // predicted 10*100+.. > budget; rung 1 is idle and fits
        let rungs = [view(80, 8, 100), view(0, 8, 300)];
        assert_eq!(r.route(&rungs, 1, 500, 1), Route::Fallback(1));
        let s = r.stats();
        assert_eq!((s.hits, s.fallbacks, s.sheds), (0, 1, 0));
    }

    #[test]
    fn cheapest_is_by_ewma_not_deployment_order() {
        let r = Router::new();
        // online refinement made rung 1 cheaper than rung 0: picking
        // rung 1 is a *hit* (it IS the cheapest), not a fallback
        let rungs = [view(0, 8, 700), view(0, 8, 200)];
        assert_eq!(r.route(&rungs, 1, 10_000, 1), Route::Hit(1));
        assert_eq!(r.stats().hits, 1);
    }

    #[test]
    fn no_rung_fits_sheds_with_best_prediction() {
        let r = Router::new();
        let rungs = [view(80, 8, 100), view(16, 8, 300)];
        match r.route(&rungs, 1, 50, 1) {
            Route::Shed { predicted_us } => {
                // best achievable was rung 0: ceil(81/8)=11 batches * 100us
                assert_eq!(predicted_us, 1100);
            }
            other => panic!("expected shed, got {other:?}"),
        }
        assert_eq!(r.stats().sheds, 1);
    }

    #[test]
    fn no_deadline_minimizes_predicted_completion_and_never_sheds() {
        let r = Router::new();
        // cheap rung is swamped; with no deadline the idle costlier rung
        // still completes sooner and must win
        let rungs = [view(800, 8, 100), view(0, 8, 300)];
        assert_eq!(r.route(&rungs, 1, u64::MAX, 1), Route::Fallback(1));
    }

    #[test]
    fn workers_divide_predicted_queue_wait() {
        let v = view(32, 8, 1000);
        // 5 batches (32+1 rows over B=8) * 1000us over 1 worker
        assert_eq!(v.predicted_us(1, 1), 5000);
        assert_eq!(v.predicted_us(1, 4), 1250);
    }
}
