//! `serve::net` — the TCP serving tier in front of [`Session`]: a small
//! acceptor plus a connection-handler pool speaking the length-prefixed
//! binary protocol of [`super::proto`].
//!
//! Robustness is the design center; the tier must *degrade gracefully*
//! rather than fall over:
//!
//! * **Deadlines propagate.**  Each `Infer` frame carries a relative
//!   `deadline_us` budget; the handler turns it into an absolute
//!   [`Instant`] at receipt and hands it to
//!   [`Session::submit_deadline`], so admission control can shed at the
//!   door ([`ErrCode::Shed`]) and the worker fails expired requests fast
//!   ([`ErrCode::DeadlineExceeded`]) instead of serving them late.
//! * **Every wait is bounded.**  Ticket waits are capped at the deadline
//!   plus a small grace (or [`NetCfg::max_wait_ms`] without one), reads
//!   are capped per frame ([`NetCfg::frame_stall_ms`] — a peer that
//!   stops mid-frame is disconnected, the slow-loris defense), writes by
//!   [`NetCfg::write_timeout_ms`].  No client can wedge a handler.
//! * **Malformed input never kills the process.**  A frame that decodes
//!   to garbage gets a typed [`ErrCode::BadFrame`] reply; the connection
//!   survives when framing is intact (the length prefix was honest) and
//!   is closed when it is not (wrong magic / hostile length — there is
//!   no resync point in a length-prefixed stream).  Handler panics are
//!   caught per connection: counted, connection dropped, handler thread
//!   lives on.
//! * **Graceful drain.**  [`NetServer::shutdown`] stops the acceptor,
//!   lets in-flight requests finish, sends [`ErrCode::ShuttingDown`] to
//!   idle or still-queued connections, and joins every thread.
//!
//! [`drive_net`] is the open-loop loopback load driver (deterministic
//! Poisson arrivals over N connections) the `serving_net` bench and the
//! overload tests use; [`NetClient`] is the minimal blocking client.

use std::io::{self, ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::json::Json;
use crate::util::par;
use crate::util::tensor::Tensor;

use super::fleet::Fleet;
use super::proto::{
    self, DecodeError, ErrCode, Request, Response, MAX_FRAME,
};
use super::{
    plock, punwrap, pwait, LoadReport, Outcomes, ServeError, ServeResult, ServeStats,
    Session, Ticket,
};
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Config
// ---------------------------------------------------------------------------

/// Sizing and timeout knobs of the network tier.  Every wait a client
/// can influence is bounded by one of these.
#[derive(Debug, Clone, Copy)]
pub struct NetCfg {
    /// Connection-handler threads — the cap on concurrently *served*
    /// connections (excess accepted connections queue).
    pub conn_workers: usize,
    /// Accepted-connection queue bound; beyond it new connections get a
    /// best-effort `Shed` frame and are dropped.
    pub backlog: usize,
    /// Idle poll granularity: how often a handler blocked on a quiet
    /// connection wakes to check for shutdown, ms.
    pub idle_tick_ms: u64,
    /// Once a frame has started arriving, the whole frame must land
    /// within this budget or the connection is dropped (slow-loris
    /// defense), ms.
    pub frame_stall_ms: u64,
    /// Socket write timeout for responses, ms.
    pub write_timeout_ms: u64,
    /// Ticket-wait cap for requests *without* a deadline, ms — a wedged
    /// batch becomes a typed error, never a hung handler.
    pub max_wait_ms: u64,
    /// Server-imposed deadline for frames that carry none (0 = none).
    pub default_deadline_ms: u64,
    /// Extra slack past a request's deadline before the handler stops
    /// waiting on its ticket, ms.  Covers the gap between "the worker
    /// expired it" and "the handler noticed".
    pub deadline_grace_ms: u64,
}

impl Default for NetCfg {
    fn default() -> Self {
        NetCfg {
            conn_workers: 4,
            backlog: 64,
            idle_tick_ms: 50,
            frame_stall_ms: 2_000,
            write_timeout_ms: 2_000,
            max_wait_ms: 30_000,
            default_deadline_ms: 0,
            deadline_grace_ms: 250,
        }
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Cumulative network-tier counters (monotonic; see [`NetServer::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetStats {
    /// Connections accepted.
    pub accepted: usize,
    /// Connections refused with `Shed` because the backlog was full.
    pub refused: usize,
    /// Request frames fully read.
    pub frames: usize,
    /// Response frames written (every read frame gets exactly one).
    pub replies: usize,
    /// Malformed bodies answered with `BadFrame` (connection kept).
    pub bad_frames: usize,
    /// Connections dropped on IO errors, stalls, or lost framing.
    pub conn_errors: usize,
    /// Handler panics caught (connection dropped, thread survived).
    pub handler_panics: usize,
}

#[derive(Default)]
struct NetStatsInner {
    accepted: AtomicUsize,
    refused: AtomicUsize,
    frames: AtomicUsize,
    replies: AtomicUsize,
    bad_frames: AtomicUsize,
    conn_errors: AtomicUsize,
    handler_panics: AtomicUsize,
}

/// What the network tier serves: one [`Session`], or a multi-tenant
/// [`Fleet`].  The wire protocol is identical either way — only `Infer`
/// routing (the frame's tenant field) and the `/stats` payload differ.
enum ServeTarget {
    Session(Arc<Session>),
    Fleet(Arc<Fleet>),
}

impl ServeTarget {
    /// Route one request by the frame's tenant field.  A session target
    /// has exactly one deployment, so a non-empty tenant is a typed
    /// rejection (the client is addressing a fleet that is not there); a
    /// fleet target resolves an empty tenant only when exactly one tenant
    /// exists — anything else must be named.
    fn submit(
        &self,
        tenant: &str,
        x: Tensor,
        t: Option<Tensor>,
        deadline: Option<Instant>,
    ) -> ServeResult<Ticket> {
        match self {
            ServeTarget::Session(s) => {
                if !tenant.is_empty() {
                    return Err(ServeError::Rejected(format!(
                        "this server hosts a single session; \
                         tenant {tenant:?} cannot be addressed here"
                    )));
                }
                s.submit_deadline(x, t, deadline)
            }
            ServeTarget::Fleet(f) => {
                if !tenant.is_empty() {
                    return f.submit(tenant, x, t, deadline);
                }
                let names = f.tenants();
                match names.as_slice() {
                    [only] => f.submit(only, x, t, deadline),
                    _ => Err(ServeError::Rejected(format!(
                        "fleet serves {} tenants; the Infer frame must name one",
                        names.len()
                    ))),
                }
            }
        }
    }
}

struct NetInner {
    target: ServeTarget,
    cfg: NetCfg,
    shutdown: AtomicBool,
    /// Accepted connections waiting for a handler (bounded by
    /// `cfg.backlog`).
    conns: Mutex<Vec<TcpStream>>,
    conn_cv: Condvar,
    stats: NetStatsInner,
}

/// A running network serving tier: acceptor thread + handler pool over
/// one shared [`Session`].  [`NetServer::shutdown`] (or drop) drains
/// gracefully.
pub struct NetServer {
    inner: Arc<NetInner>,
    acceptor: Option<JoinHandle<()>>,
    pool: Option<par::Pool>,
    addr: SocketAddr,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral test port) and
    /// start serving `session` over it.
    pub fn bind(session: Arc<Session>, addr: &str, cfg: NetCfg) -> Result<NetServer> {
        NetServer::bind_target(ServeTarget::Session(session), addr, cfg)
    }

    /// Bind `addr` and serve a multi-tenant [`Fleet`] over it: `Infer`
    /// frames route by their tenant field through the fleet's
    /// deadline-aware ladder router.
    pub fn bind_fleet(fleet: Arc<Fleet>, addr: &str, cfg: NetCfg) -> Result<NetServer> {
        NetServer::bind_target(ServeTarget::Fleet(fleet), addr, cfg)
    }

    fn bind_target(target: ServeTarget, addr: &str, cfg: NetCfg) -> Result<NetServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("serve-net: cannot bind {addr}"))?;
        let local = listener.local_addr().context("serve-net: local_addr")?;
        listener
            .set_nonblocking(true)
            .context("serve-net: nonblocking acceptor")?;
        let inner = Arc::new(NetInner {
            target,
            cfg,
            shutdown: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
            conn_cv: Condvar::new(),
            stats: NetStatsInner::default(),
        });
        let acc_inner = Arc::clone(&inner);
        let acceptor = std::thread::Builder::new()
            .name("lm-net-accept".into())
            .spawn(move || accept_loop(&acc_inner, listener))
            .context("serve-net: spawn acceptor")?;
        let pool_inner = Arc::clone(&inner);
        let pool = par::Pool::spawn(cfg.conn_workers.max(1), "lm-net-conn", move |_| {
            handler_loop(&pool_inner);
        });
        Ok(NetServer { inner, acceptor: Some(acceptor), pool: Some(pool), addr: local })
    }

    /// The bound address (resolves the ephemeral port of `":0"` binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn stats(&self) -> NetStats {
        let s = &self.inner.stats;
        NetStats {
            accepted: s.accepted.load(Ordering::Relaxed),
            refused: s.refused.load(Ordering::Relaxed),
            frames: s.frames.load(Ordering::Relaxed),
            replies: s.replies.load(Ordering::Relaxed),
            bad_frames: s.bad_frames.load(Ordering::Relaxed),
            conn_errors: s.conn_errors.load(Ordering::Relaxed),
            handler_panics: s.handler_panics.load(Ordering::Relaxed),
        }
    }

    /// The served session (e.g. for closing it after the net tier
    /// drains).  Panics on a fleet-backed server — use [`NetServer::fleet`].
    pub fn session(&self) -> &Arc<Session> {
        match &self.inner.target {
            ServeTarget::Session(s) => s,
            ServeTarget::Fleet(_) => {
                panic!("NetServer::session() on a fleet-backed server")
            }
        }
    }

    /// The served fleet, if this server was bound with
    /// [`NetServer::bind_fleet`].
    pub fn fleet(&self) -> Option<&Arc<Fleet>> {
        match &self.inner.target {
            ServeTarget::Session(_) => None,
            ServeTarget::Fleet(f) => Some(f),
        }
    }

    /// Graceful drain: stop accepting, finish in-flight requests, send
    /// [`ErrCode::ShuttingDown`] to idle and still-queued connections,
    /// join every thread.  The underlying [`Session`] is left open (it
    /// may be shared); close it after this returns.
    pub fn shutdown(mut self) {
        self.drain();
    }

    fn drain(&mut self) {
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.conn_cv.notify_all();
        if let Some(a) = self.acceptor.take() {
            let _ = a.join(); // drops the listener: no new connections
        }
        if let Some(mut p) = self.pool.take() {
            p.join(); // handlers notice the flag at their next idle tick
        }
        // connections that never reached a handler get a typed goodbye
        let stragglers = std::mem::take(&mut *plock(&self.inner.conns));
        for mut s in stragglers {
            let _ = s.set_write_timeout(Some(Duration::from_millis(
                self.inner.cfg.write_timeout_ms.max(1),
            )));
            let _ = write_frame(
                &mut s,
                &proto::encode_response(&Response::Error {
                    id: 0,
                    code: ErrCode::ShuttingDown,
                    msg: "server is draining".into(),
                }),
            );
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.drain();
    }
}

fn accept_loop(inner: &NetInner, listener: TcpListener) {
    while !inner.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                inner.stats.accepted.fetch_add(1, Ordering::Relaxed);
                let mut g = plock(&inner.conns);
                if g.len() >= inner.cfg.backlog.max(1) {
                    drop(g);
                    inner.stats.refused.fetch_add(1, Ordering::Relaxed);
                    refuse(inner, stream);
                    continue;
                }
                g.push(stream);
                drop(g);
                inner.conn_cv.notify_one();
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(
                    inner.cfg.idle_tick_ms.clamp(1, 50),
                ));
            }
            Err(_) => {
                // transient accept failure (EMFILE, aborted handshake...):
                // count it and keep accepting — never kill the acceptor
                inner.stats.conn_errors.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
}

/// Best-effort typed refusal for a connection the backlog cannot hold.
fn refuse(inner: &NetInner, mut stream: TcpStream) {
    let _ = stream.set_write_timeout(Some(Duration::from_millis(
        inner.cfg.write_timeout_ms.max(1),
    )));
    let _ = write_frame(
        &mut stream,
        &proto::encode_response(&Response::Error {
            id: 0,
            code: ErrCode::Shed,
            msg: "connection backlog full".into(),
        }),
    );
}

fn handler_loop(inner: &NetInner) {
    loop {
        let stream = {
            let mut g = plock(&inner.conns);
            loop {
                if let Some(s) = g.pop() {
                    break s;
                }
                if inner.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                g = pwait(&inner.conn_cv, g);
            }
        };
        // fault isolation: a panic while serving one connection is
        // counted and drops that connection only — the handler thread
        // (and every other connection) lives on
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            handle_conn(inner, stream)
        }));
        if r.is_err() {
            inner.stats.handler_panics.fetch_add(1, Ordering::Relaxed);
        }
    }
}

// ---------------------------------------------------------------------------
// Framed IO
// ---------------------------------------------------------------------------

enum Got {
    /// The buffer was filled.
    Data,
    /// Nothing had arrived when the idle tick expired (only possible
    /// when `mid_frame` is false).
    Idle,
    /// The peer closed cleanly on a frame boundary.
    Closed,
}

/// Fill `buf` from `s` (whose read timeout is the idle tick).
///
/// * `mid_frame == false`: a timeout before the first byte is a quiet
///   connection — returns [`Got::Idle`] so the caller can poll shutdown.
/// * once any byte has arrived (or `mid_frame == true`), the rest must
///   land within `stall_cap` or the read fails with `TimedOut` — a peer
///   that dribbles a frame forever cannot pin the handler.
fn read_exact_or_idle(
    s: &mut TcpStream,
    buf: &mut [u8],
    mid_frame: bool,
    stall_cap: Duration,
) -> io::Result<Got> {
    let mut filled = 0usize;
    let mut started: Option<Instant> = mid_frame.then(Instant::now);
    while filled < buf.len() {
        match s.read(&mut buf[filled..]) {
            Ok(0) => {
                return if filled == 0 && !mid_frame {
                    Ok(Got::Closed)
                } else {
                    Err(io::Error::new(
                        ErrorKind::UnexpectedEof,
                        "connection closed mid-frame",
                    ))
                };
            }
            Ok(n) => {
                filled += n;
                started.get_or_insert_with(Instant::now);
            }
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut =>
            {
                match started {
                    None => return Ok(Got::Idle),
                    Some(t0) if t0.elapsed() >= stall_cap => {
                        return Err(io::Error::new(
                            ErrorKind::TimedOut,
                            "frame stalled mid-read",
                        ));
                    }
                    Some(_) => {} // keep waiting out the stall budget
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(Got::Data)
}

/// Write one `u32 LE length + body` frame.
fn write_frame(s: &mut TcpStream, body: &[u8]) -> io::Result<()> {
    debug_assert!(body.len() <= MAX_FRAME);
    s.write_all(&(body.len() as u32).to_le_bytes())?;
    s.write_all(body)?;
    s.flush()
}

/// Blocking read of one frame (client side / tests): length prefix, cap
/// check, body.  `Ok(None)` on clean EOF.
pub(crate) fn read_frame_blocking(s: &mut TcpStream) -> io::Result<Option<Vec<u8>>> {
    let mut hdr = [0u8; 4];
    let mut at = 0usize;
    while at < 4 {
        match s.read(&mut hdr[at..]) {
            Ok(0) if at == 0 => return Ok(None),
            Ok(0) => {
                return Err(io::Error::new(
                    ErrorKind::UnexpectedEof,
                    "connection closed mid-header",
                ))
            }
            Ok(n) => at += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    let len = u32::from_le_bytes(hdr) as usize;
    if len > MAX_FRAME {
        return Err(io::Error::new(
            ErrorKind::InvalidData,
            format!("frame length {len} exceeds MAX_FRAME {MAX_FRAME}"),
        ));
    }
    let mut body = vec![0u8; len];
    s.read_exact(&mut body)?;
    Ok(Some(body))
}

// ---------------------------------------------------------------------------
// Connection handling
// ---------------------------------------------------------------------------

fn handle_conn(inner: &NetInner, mut stream: TcpStream) {
    let cfg = &inner.cfg;
    let _ = stream.set_nodelay(true);
    if stream
        .set_read_timeout(Some(Duration::from_millis(cfg.idle_tick_ms.max(1))))
        .is_err()
        || stream
            .set_write_timeout(Some(Duration::from_millis(cfg.write_timeout_ms.max(1))))
            .is_err()
    {
        inner.stats.conn_errors.fetch_add(1, Ordering::Relaxed);
        return;
    }
    let stall = Duration::from_millis(cfg.frame_stall_ms.max(1));
    loop {
        // -- length prefix (idle-tick aware) --------------------------------
        let mut hdr = [0u8; 4];
        match read_exact_or_idle(&mut stream, &mut hdr, false, stall) {
            Ok(Got::Idle) => {
                if inner.shutdown.load(Ordering::SeqCst) {
                    let _ = send(inner, &mut stream, &Response::Error {
                        id: 0,
                        code: ErrCode::ShuttingDown,
                        msg: "server is draining".into(),
                    });
                    return;
                }
                continue;
            }
            Ok(Got::Closed) => return,
            Ok(Got::Data) => {}
            Err(_) => {
                inner.stats.conn_errors.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
        let len = u32::from_le_bytes(hdr) as usize;
        if len > MAX_FRAME {
            // a hostile length prefix breaks framing trust: typed
            // refusal, then close — never allocate the claimed buffer
            inner.stats.bad_frames.fetch_add(1, Ordering::Relaxed);
            let _ = send(inner, &mut stream, &Response::Error {
                id: 0,
                code: ErrCode::BadFrame,
                msg: format!("frame length {len} exceeds MAX_FRAME {MAX_FRAME}"),
            });
            return;
        }
        // -- body (mid-frame: stall budget applies) -------------------------
        let mut body = vec![0u8; len];
        match read_exact_or_idle(&mut stream, &mut body, true, stall) {
            Ok(Got::Data) => {}
            _ => {
                // disconnect or stall mid-frame; nothing to reply to
                inner.stats.conn_errors.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
        inner.stats.frames.fetch_add(1, Ordering::Relaxed);
        // -- decode ---------------------------------------------------------
        let req = match proto::decode_request(&body) {
            Ok(r) => r,
            Err(DecodeError::Malformed(m)) => {
                // framing was honest (the length prefix matched), so the
                // stream is still in sync: reject the frame, keep the
                // connection
                inner.stats.bad_frames.fetch_add(1, Ordering::Relaxed);
                if send(inner, &mut stream, &Response::Error {
                    id: 0,
                    code: ErrCode::BadFrame,
                    msg: m,
                })
                .is_err()
                {
                    return;
                }
                continue;
            }
            Err(DecodeError::Legacy(m)) => {
                // a wire-v1 peer: framing is intact (same length-prefix
                // discipline), so answer with a typed upgrade notice and
                // keep the connection — the client sees *why* instead of
                // a dead socket
                inner.stats.bad_frames.fetch_add(1, Ordering::Relaxed);
                if send(inner, &mut stream, &Response::Error {
                    id: 0,
                    code: ErrCode::BadFrame,
                    msg: m,
                })
                .is_err()
                {
                    return;
                }
                continue;
            }
            Err(DecodeError::NotOurs(m)) => {
                // wrong magic/version: this peer does not speak our
                // protocol — one typed refusal, then close
                inner.stats.bad_frames.fetch_add(1, Ordering::Relaxed);
                let _ = send(inner, &mut stream, &Response::Error {
                    id: 0,
                    code: ErrCode::BadFrame,
                    msg: m,
                });
                return;
            }
        };
        // -- serve ----------------------------------------------------------
        let resp = match req {
            Request::Stats { id } => Response::Stats {
                id,
                json: stats_json(inner),
            },
            Request::Infer { id, deadline_us, tenant, x, t } => {
                serve_infer(inner, id, deadline_us, &tenant, x, t)
            }
        };
        if send(inner, &mut stream, &resp).is_err() {
            return;
        }
        if inner.shutdown.load(Ordering::SeqCst) {
            // drain: finish the request in flight, then say goodbye
            let _ = send(inner, &mut stream, &Response::Error {
                id: 0,
                code: ErrCode::ShuttingDown,
                msg: "server is draining".into(),
            });
            return;
        }
    }
}

/// One inference through the session, every failure mapped to its typed
/// wire code.  The ticket wait is bounded by the request deadline plus
/// grace (or `max_wait_ms` without one) — a wedged batch becomes a typed
/// error frame, never a hung handler.
fn serve_infer(
    inner: &NetInner,
    id: u64,
    deadline_us: u64,
    tenant: &str,
    x: Tensor,
    t: Option<Tensor>,
) -> Response {
    let cfg = &inner.cfg;
    let now = Instant::now();
    let deadline_us = if deadline_us > 0 {
        deadline_us
    } else {
        cfg.default_deadline_ms.saturating_mul(1_000)
    };
    let deadline = (deadline_us > 0).then(|| now + Duration::from_micros(deadline_us));
    let ticket = match inner.target.submit(tenant, x, t, deadline) {
        Ok(tk) => tk,
        Err(e) => {
            return Response::Error {
                id,
                code: ErrCode::of(&e),
                msg: e.to_string(),
            }
        }
    };
    let cap = match deadline {
        Some(d) => {
            d.saturating_duration_since(Instant::now())
                + Duration::from_millis(cfg.deadline_grace_ms)
        }
        None => Duration::from_millis(cfg.max_wait_ms.max(1)),
    };
    match ticket.wait_timeout_coded(cap) {
        Ok(Ok(y)) => Response::Tensor { id, y },
        Ok(Err(e)) => Response::Error {
            id,
            code: ErrCode::of(&e),
            msg: e.to_string(),
        },
        Err(_stale) => {
            // the wait cap expired: with a deadline the request is
            // (over)due — report it expired; without one the batch is
            // wedged — that's a backend failure
            let (code, msg) = if deadline.is_some() {
                (
                    ErrCode::DeadlineExceeded,
                    "request deadline exceeded before completion".to_string(),
                )
            } else {
                (
                    ErrCode::BackendFailed,
                    format!("request timed out after {}ms", cfg.max_wait_ms),
                )
            };
            Response::Error { id, code, msg }
        }
    }
}

fn send(inner: &NetInner, stream: &mut TcpStream, resp: &Response) -> io::Result<()> {
    let r = write_frame(stream, &proto::encode_response(resp));
    match &r {
        Ok(()) => {
            inner.stats.replies.fetch_add(1, Ordering::Relaxed);
        }
        Err(_) => {
            inner.stats.conn_errors.fetch_add(1, Ordering::Relaxed);
        }
    }
    r
}

/// Serialize one [`ServeStats`] snapshot as the flat counter fields the
/// `/stats` JSON has always carried — reused verbatim for the top-level
/// totals and for each per-tenant breakdown object, so a stats consumer
/// reads both with one schema.
fn stats_fields(s: &ServeStats) -> Vec<(&'static str, Json)> {
    vec![
        ("requests", Json::num(s.requests as f64)),
        ("rows", Json::num(s.rows as f64)),
        ("batches", Json::num(s.batches as f64)),
        ("padded_rows", Json::num(s.padded_rows as f64)),
        ("max_queue", Json::num(s.max_queue as f64)),
        ("expired_windows", Json::num(s.expired_windows as f64)),
        ("cur_window_us", Json::num(s.cur_window_us as f64)),
        ("shed_requests", Json::num(s.shed_requests as f64)),
        ("expired_requests", Json::num(s.expired_requests as f64)),
        ("failed_batches", Json::num(s.failed_batches as f64)),
        ("panicked_batches", Json::num(s.panicked_batches as f64)),
    ]
}

/// The `/stats` reply: [`super::ServeStats`] totals (one coherent
/// snapshot — every counter from the same lock acquisition) plus the
/// net-tier counters, live queue telemetry, and a `kernel` object (the
/// active SIMD ISA and deployed weight format), as one JSON object.  A
/// fleet-backed server additionally reports a `tenants` object (the same
/// counter schema per tenant, each its own coherent snapshot) and a
/// `fleet` object with weight-dedup bytes and router telemetry.
fn stats_json(inner: &NetInner) -> String {
    let n = &inner.stats;
    let mut fields = match &inner.target {
        ServeTarget::Session(sess) => {
            let mut f = stats_fields(&sess.stats());
            f.push(("queue_depth", Json::num(sess.queue_depth() as f64)));
            f.push((
                "ewma_service_us",
                Json::num(sess.ewma_service_us() as f64),
            ));
            f
        }
        ServeTarget::Fleet(fleet) => {
            let fs = fleet.stats();
            let names = fleet.tenants();
            let depth: usize = names.iter().map(|t| fleet.queue_depth(t)).sum();
            let mut f = stats_fields(&fs.total);
            f.push(("queue_depth", Json::num(depth as f64)));
            let mut tenants = std::collections::BTreeMap::new();
            for name in &names {
                if let Some(ts) = fleet.tenant_stats(name) {
                    let mut tf = stats_fields(&ts);
                    tf.push((
                        "queue_depth",
                        Json::num(fleet.queue_depth(name) as f64),
                    ));
                    tenants.insert(name.clone(), Json::obj(tf));
                }
            }
            f.push(("tenants", Json::Obj(tenants)));
            f.push((
                "fleet",
                Json::obj(vec![
                    (
                        "unique_weight_bytes",
                        Json::num(fs.unique_weight_bytes as f64),
                    ),
                    (
                        "dedup_saved_bytes",
                        Json::num(fs.dedup_saved_bytes as f64),
                    ),
                    ("router_hits", Json::num(fs.router.hits as f64)),
                    ("router_fallbacks", Json::num(fs.router.fallbacks as f64)),
                    ("router_sheds", Json::num(fs.router.sheds as f64)),
                ]),
            ));
            f
        }
    };
    let wf = match &inner.target {
        ServeTarget::Session(sess) => sess.weight_format(),
        ServeTarget::Fleet(fleet) => fleet.weight_format(),
    };
    fields.push((
        "kernel",
        Json::obj(vec![
            ("isa", Json::str(crate::kernels::isa().name())),
            ("weight_format", Json::str(wf.name())),
        ]),
    ));
    fields.push((
        "net",
        Json::obj(vec![
            ("accepted", Json::num(n.accepted.load(Ordering::Relaxed) as f64)),
            ("refused", Json::num(n.refused.load(Ordering::Relaxed) as f64)),
            ("frames", Json::num(n.frames.load(Ordering::Relaxed) as f64)),
            ("replies", Json::num(n.replies.load(Ordering::Relaxed) as f64)),
            (
                "bad_frames",
                Json::num(n.bad_frames.load(Ordering::Relaxed) as f64),
            ),
            (
                "conn_errors",
                Json::num(n.conn_errors.load(Ordering::Relaxed) as f64),
            ),
            (
                "handler_panics",
                Json::num(n.handler_panics.load(Ordering::Relaxed) as f64),
            ),
        ]),
    ));
    Json::obj(fields).to_string()
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Client-side transport timeouts.  Every socket wait a [`NetClient`]
/// can block on is bounded by one of these; a bound that expires
/// surfaces as the typed [`ClientError::TimedOut`] (downcastable from
/// the `anyhow` chain), not a raw io error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NetClientCfg {
    pub connect_timeout: Duration,
    pub read_timeout: Duration,
    pub write_timeout: Duration,
}

impl Default for NetClientCfg {
    fn default() -> Self {
        NetClientCfg {
            connect_timeout: Duration::from_secs(5),
            read_timeout: Duration::from_secs(60),
            write_timeout: Duration::from_secs(10),
        }
    }
}

/// Typed client-side transport failures.  Retrieve with
/// `err.downcast_ref::<ClientError>()` on the transport-level `Result`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClientError {
    /// A connect/read/write exceeded its [`NetClientCfg`] bound.  For an
    /// idempotent inference this is retry-safe *while deadline budget
    /// remains* — the reply may still be in flight, but re-asking cannot
    /// corrupt anything.
    TimedOut,
    /// The retry client's circuit breaker is open for this endpoint.
    CircuitOpen,
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::TimedOut => write!(f, "client transport timed out"),
            ClientError::CircuitOpen => write!(f, "endpoint circuit breaker is open"),
        }
    }
}

impl std::error::Error for ClientError {}

/// Map an io failure to the typed client error where a timeout is
/// involved, keeping everything downcastable.
fn client_io_err(e: io::Error, what: &str) -> anyhow::Error {
    if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) {
        anyhow::Error::new(ClientError::TimedOut)
            .context(format!("serve-net client: {what} timed out"))
    } else {
        anyhow::Error::new(e).context(format!("serve-net client: {what}"))
    }
}

/// Minimal blocking client for the wire protocol — one request in flight
/// per connection (send, then wait for the matching reply).
pub struct NetClient {
    stream: TcpStream,
    next_id: u64,
}

impl NetClient {
    /// Connect with [`NetClientCfg::default`] timeouts.
    pub fn connect(addr: SocketAddr) -> Result<NetClient> {
        NetClient::connect_cfg(addr, NetClientCfg::default())
    }

    /// Connect with explicit transport timeouts.
    pub fn connect_cfg(addr: SocketAddr, cfg: NetClientCfg) -> Result<NetClient> {
        let stream = TcpStream::connect_timeout(&addr, cfg.connect_timeout.max(Duration::from_millis(1)))
            .map_err(|e| client_io_err(e, "connect"))
            .with_context(|| format!("serve-net client: connect {addr}"))?;
        let _ = stream.set_nodelay(true);
        stream
            .set_read_timeout(Some(cfg.read_timeout.max(Duration::from_millis(1))))
            .context("serve-net client: read timeout")?;
        stream
            .set_write_timeout(Some(cfg.write_timeout.max(Duration::from_millis(1))))
            .context("serve-net client: write timeout")?;
        Ok(NetClient { stream, next_id: 1 })
    }

    fn roundtrip(&mut self, req: &Request) -> Result<Response> {
        write_frame(&mut self.stream, &proto::encode_request(req))
            .map_err(|e| client_io_err(e, "write"))?;
        loop {
            let body = read_frame_blocking(&mut self.stream)
                .map_err(|e| client_io_err(e, "read"))?
                .context("server closed the connection")?;
            let resp = proto::decode_response(&body)
                .map_err(|e| anyhow::anyhow!("bad response frame: {e}"))?;
            // an unsolicited id-0 drain notice can interleave with a
            // pending reply; surface it only if it IS the reply
            if resp.id() == req.id() || resp.id() == 0 {
                return Ok(resp);
            }
        }
    }

    /// One inference round-trip.  The outer `Result` is transport-level
    /// (IO, protocol); the inner one is the server's typed verdict.
    pub fn infer_deadline(
        &mut self,
        x: &Tensor,
        t: Option<&Tensor>,
        deadline: Option<Duration>,
    ) -> Result<std::result::Result<Tensor, (ErrCode, String)>> {
        self.infer_tenant("", x, t, deadline)
    }

    /// [`NetClient::infer_deadline`] addressed to a named fleet tenant
    /// (empty tenant = the server's sole deployment).
    pub fn infer_tenant(
        &mut self,
        tenant: &str,
        x: &Tensor,
        t: Option<&Tensor>,
        deadline: Option<Duration>,
    ) -> Result<std::result::Result<Tensor, (ErrCode, String)>> {
        let id = self.next_id;
        self.next_id += 1;
        let req = Request::Infer {
            id,
            deadline_us: deadline.map_or(0, |d| d.as_micros() as u64),
            tenant: tenant.to_string(),
            x: x.clone(),
            t: t.cloned(),
        };
        match self.roundtrip(&req)? {
            Response::Tensor { y, .. } => Ok(Ok(y)),
            Response::Error { code, msg, .. } => Ok(Err((code, msg))),
            Response::Stats { .. } => {
                anyhow::bail!("serve-net client: stats reply to an infer request")
            }
        }
    }

    pub fn infer(&mut self, x: &Tensor, t: Option<&Tensor>) -> Result<Tensor> {
        match self.infer_deadline(x, t, None)? {
            Ok(y) => Ok(y),
            Err((code, msg)) => anyhow::bail!("server error [{code}]: {msg}"),
        }
    }

    /// Fetch the server's cumulative stats as parsed JSON.
    pub fn stats(&mut self) -> Result<Json> {
        let id = self.next_id;
        self.next_id += 1;
        match self.roundtrip(&Request::Stats { id })? {
            Response::Stats { json, .. } => {
                Json::parse(&json).map_err(|e| anyhow::anyhow!("bad stats json: {e}"))
            }
            Response::Error { code, msg, .. } => {
                anyhow::bail!("server error [{code}]: {msg}")
            }
            Response::Tensor { .. } => {
                anyhow::bail!("serve-net client: tensor reply to a stats request")
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Retrying / hedging client
// ---------------------------------------------------------------------------

/// Bounded retry with decorrelated-jitter backoff (AWS-style: each sleep
/// is uniform in `[base, prev * 3]`, capped) — successive retries neither
/// synchronize with other clients nor pile onto a recovering server.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts, including the first (1 = no retries).
    pub attempts: usize,
    /// Backoff floor, ms.
    pub base_ms: u64,
    /// Backoff ceiling, ms.
    pub cap_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { attempts: 4, base_ms: 5, cap_ms: 200 }
    }
}

/// Per-endpoint circuit breaker knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BreakerCfg {
    /// Consecutive transport/retry-safe failures that open the circuit.
    pub failure_threshold: usize,
    /// How long an open circuit rejects before letting one probe through.
    pub cooldown: Duration,
}

impl Default for BreakerCfg {
    fn default() -> Self {
        BreakerCfg { failure_threshold: 5, cooldown: Duration::from_millis(500) }
    }
}

/// Closed → (threshold consecutive failures) → Open → (cooldown) →
/// half-open probe → Closed on success / Open again on failure.
enum BreakerState {
    Closed { fails: usize },
    Open { until: Instant },
}

struct Breaker {
    cfg: BreakerCfg,
    state: BreakerState,
}

impl Breaker {
    fn new(cfg: BreakerCfg) -> Breaker {
        Breaker { cfg, state: BreakerState::Closed { fails: 0 } }
    }

    /// May a request go out now?  An expired cooldown admits exactly the
    /// caller as the half-open probe (state flips on its outcome).
    fn allow(&self, now: Instant) -> bool {
        match &self.state {
            BreakerState::Closed { .. } => true,
            BreakerState::Open { until } => now >= *until,
        }
    }

    fn on_success(&mut self) {
        self.state = BreakerState::Closed { fails: 0 };
    }

    fn on_failure(&mut self, now: Instant) {
        let open = match &self.state {
            // a failed half-open probe re-arms the cooldown immediately
            BreakerState::Open { .. } => true,
            BreakerState::Closed { fails } => fails + 1 >= self.cfg.failure_threshold.max(1),
        };
        self.state = if open {
            BreakerState::Open { until: now + self.cfg.cooldown }
        } else {
            let fails = match &self.state {
                BreakerState::Closed { fails } => fails + 1,
                BreakerState::Open { .. } => unreachable!(),
            };
            BreakerState::Closed { fails }
        };
    }

    fn name(&self, now: Instant) -> &'static str {
        match &self.state {
            BreakerState::Closed { .. } => "closed",
            BreakerState::Open { until } if now >= *until => "half-open",
            BreakerState::Open { .. } => "open",
        }
    }
}

/// What the retry client did (cumulative; for tests and load reports).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RetryStats {
    /// Wire attempts actually sent (≥ logical requests).
    pub attempts: usize,
    /// Attempts that were retries of an earlier failure.
    pub retries: usize,
    /// Hedge legs launched.
    pub hedges: usize,
    /// Requests rejected locally because the breaker was open.
    pub breaker_rejections: usize,
}

/// A [`NetClient`] wrapper that survives transient faults instead of
/// converting them into lost goodput:
///
/// * **Bounded retries** on *retry-safe* outcomes only: [`ErrCode::Shed`],
///   [`ErrCode::ShuttingDown`], connection resets, and (while deadline
///   budget remains) client-side timeouts.  A spent deadline is never
///   retried — the answer could only arrive late.  `BadFrame` and
///   `BackendFailed` verdicts are *not* retried: the request executed (or
///   the protocol is broken) and re-asking burns server capacity.
/// * **Optional hedging**: after [`RetryClient::with_hedge`]'s delay with
///   no reply, a second identical request is raced on a fresh connection;
///   first verdict wins, the loser is abandoned.
/// * **A per-endpoint circuit breaker**: consecutive failures open it,
///   open means local typed rejection ([`ClientError::CircuitOpen`], no
///   socket traffic), one probe per cooldown re-closes it on success.
///
/// Backoff jitter comes from the deterministic seeded [`Rng`], so a
/// chaos-run's retry schedule replays exactly.
pub struct RetryClient {
    addr: SocketAddr,
    cfg: NetClientCfg,
    retry: RetryPolicy,
    hedge_after: Option<Duration>,
    tenant: String,
    rng: Rng,
    breaker: Breaker,
    conn: Option<NetClient>,
    stats: RetryStats,
}

impl RetryClient {
    pub fn new(addr: SocketAddr) -> RetryClient {
        RetryClient {
            addr,
            cfg: NetClientCfg::default(),
            retry: RetryPolicy::default(),
            hedge_after: None,
            tenant: String::new(),
            rng: Rng::new(0x9e37_79b9),
            breaker: Breaker::new(BreakerCfg::default()),
            conn: None,
            stats: RetryStats::default(),
        }
    }

    pub fn with_cfg(mut self, cfg: NetClientCfg) -> RetryClient {
        self.cfg = cfg;
        self
    }

    pub fn with_retry(mut self, retry: RetryPolicy) -> RetryClient {
        self.retry = retry;
        self
    }

    /// Hedge a request onto a second connection after `d` without a
    /// verdict.  Hedged mode opens a fresh connection per leg.
    pub fn with_hedge(mut self, d: Duration) -> RetryClient {
        self.hedge_after = Some(d);
        self
    }

    pub fn with_breaker(mut self, cfg: BreakerCfg) -> RetryClient {
        self.breaker = Breaker::new(cfg);
        self
    }

    /// Address every request to a named fleet tenant.
    pub fn with_tenant(mut self, tenant: &str) -> RetryClient {
        self.tenant = tenant.to_string();
        self
    }

    /// Seed the backoff-jitter stream (deterministic replay).
    pub fn with_seed(mut self, seed: u64) -> RetryClient {
        self.rng = Rng::new(seed);
        self
    }

    pub fn retry_stats(&self) -> RetryStats {
        self.stats
    }

    /// Current breaker state: `"closed"`, `"open"`, or `"half-open"`.
    pub fn breaker_state(&self) -> &'static str {
        self.breaker.name(Instant::now())
    }

    /// One logical inference, retried/hedged per policy.  Same contract
    /// as [`NetClient::infer_deadline`]: the outer `Result` is
    /// transport-level (after all attempts), the inner one the server's
    /// typed verdict.
    pub fn infer_deadline(
        &mut self,
        x: &Tensor,
        t: Option<&Tensor>,
        deadline: Option<Duration>,
    ) -> Result<std::result::Result<Tensor, (ErrCode, String)>> {
        let start = Instant::now();
        let mut prev_sleep = self.retry.base_ms.max(1);
        let mut last: Option<Result<std::result::Result<Tensor, (ErrCode, String)>>> = None;
        for attempt in 0..self.retry.attempts.max(1) {
            let now = Instant::now();
            if !self.breaker.allow(now) {
                self.stats.breaker_rejections += 1;
                return Err(anyhow::Error::new(ClientError::CircuitOpen)
                    .context(format!("serve-net client: {} circuit open", self.addr)));
            }
            // never start an attempt past a spent deadline
            let remaining = match deadline {
                None => None,
                Some(d) => match d.checked_sub(start.elapsed()) {
                    Some(r) if r > Duration::ZERO => Some(r),
                    _ => {
                        return Ok(Err((
                            ErrCode::DeadlineExceeded,
                            "deadline spent before another attempt".into(),
                        )))
                    }
                },
            };
            self.stats.attempts += 1;
            if attempt > 0 {
                self.stats.retries += 1;
            }
            let verdict = self.one_attempt(x, t, remaining);
            match &verdict {
                Ok(Ok(_)) => {
                    self.breaker.on_success();
                    return verdict;
                }
                Ok(Err((code, _))) => match code {
                    // retry-safe: the request never executed
                    ErrCode::Shed | ErrCode::ShuttingDown => {
                        self.breaker.on_failure(Instant::now());
                    }
                    // the deadline verdict is final by definition
                    ErrCode::DeadlineExceeded => return verdict,
                    // executed-and-failed (or protocol breakage): final
                    ErrCode::BadFrame | ErrCode::BackendFailed => {
                        self.breaker.on_failure(Instant::now());
                        return verdict;
                    }
                },
                Err(e) => {
                    // transport fault: drop the connection, maybe retry
                    self.conn = None;
                    self.breaker.on_failure(Instant::now());
                    let timed_out = e.downcast_ref::<ClientError>()
                        == Some(&ClientError::TimedOut);
                    if timed_out && deadline.is_none() {
                        // no budget to judge "still in flight" against:
                        // surface it rather than guess
                        return verdict;
                    }
                }
            }
            last = Some(verdict);
            if attempt + 1 < self.retry.attempts {
                // decorrelated jitter, clipped to the remaining budget
                let hi = prev_sleep.saturating_mul(3).max(self.retry.base_ms.max(1) + 1);
                let mut sleep = self.retry.base_ms.max(1)
                    + self.rng.below((hi - self.retry.base_ms.max(1)) as usize + 1) as u64;
                sleep = sleep.min(self.retry.cap_ms.max(1));
                prev_sleep = sleep;
                let mut d = Duration::from_millis(sleep);
                if let Some(dl) = deadline {
                    d = d.min(dl.saturating_sub(start.elapsed()));
                }
                std::thread::sleep(d);
            }
        }
        last.unwrap_or_else(|| {
            Err(anyhow::anyhow!("serve-net client: no attempts were made"))
        })
    }

    /// One wire attempt — direct on the kept connection, or hedged over
    /// fresh connections when [`RetryClient::with_hedge`] is armed.
    fn one_attempt(
        &mut self,
        x: &Tensor,
        t: Option<&Tensor>,
        remaining: Option<Duration>,
    ) -> Result<std::result::Result<Tensor, (ErrCode, String)>> {
        match self.hedge_after {
            None => {
                if self.conn.is_none() {
                    self.conn = Some(NetClient::connect_cfg(self.addr, self.cfg)?);
                }
                let conn = self.conn.as_mut().expect("connection just established");
                conn.infer_tenant(&self.tenant, x, t, remaining)
            }
            Some(hedge_after) => self.hedged(x, t, remaining, hedge_after),
        }
    }

    fn hedged(
        &mut self,
        x: &Tensor,
        t: Option<&Tensor>,
        remaining: Option<Duration>,
        hedge_after: Duration,
    ) -> Result<std::result::Result<Tensor, (ErrCode, String)>> {
        type Verdict = Result<std::result::Result<Tensor, (ErrCode, String)>>;
        fn leg(
            tx: std::sync::mpsc::Sender<Verdict>,
            addr: SocketAddr,
            cfg: NetClientCfg,
            tenant: String,
            x: Tensor,
            t: Option<Tensor>,
            deadline: Option<Duration>,
        ) {
            let _ = std::thread::Builder::new().name("lm-hedge".into()).spawn(move || {
                let verdict = NetClient::connect_cfg(addr, cfg)
                    .and_then(|mut c| c.infer_tenant(&tenant, &x, t.as_ref(), deadline));
                let _ = tx.send(verdict); // the loser's send fails silently
            });
        }
        let (tx, rx) = std::sync::mpsc::channel::<Verdict>();
        leg(
            tx.clone(),
            self.addr,
            self.cfg,
            self.tenant.clone(),
            x.clone(),
            t.cloned(),
            remaining,
        );
        // the hard cap on waiting for any leg: the deadline budget plus
        // slack, or the read timeout
        let cap = remaining
            .map(|r| r + Duration::from_millis(250))
            .unwrap_or(self.cfg.read_timeout)
            .max(Duration::from_millis(1));
        let first = match rx.recv_timeout(hedge_after.min(cap)) {
            Ok(v) => return v,
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                // primary is slow: race a second leg on a fresh socket
                self.stats.hedges += 1;
                leg(
                    tx,
                    self.addr,
                    self.cfg,
                    self.tenant.clone(),
                    x.clone(),
                    t.cloned(),
                    remaining,
                );
                match rx.recv_timeout(cap) {
                    Ok(v) => v,
                    Err(_) => {
                        return Err(anyhow::Error::new(ClientError::TimedOut)
                            .context("serve-net client: both hedge legs timed out"))
                    }
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                return Err(anyhow::anyhow!("serve-net client: hedge leg lost"))
            }
        };
        // a success wins outright; on failure give the other leg the
        // rest of the cap to do better
        if matches!(&first, Ok(Ok(_))) {
            return first;
        }
        match rx.recv_timeout(cap) {
            Ok(second) if matches!(&second, Ok(Ok(_))) => second,
            _ => first,
        }
    }
}

// ---------------------------------------------------------------------------
// Open-loop network load driver
// ---------------------------------------------------------------------------

/// One open-loop run against a [`NetServer`] over loopback: goodput and
/// p99-of-admitted next to the shed/expired/failed separation.  The
/// `serving_net` bench and the overload tests read these.
#[derive(Debug, Clone)]
pub struct NetLoadReport {
    pub arrival_rps: f64,
    pub conns: usize,
    /// Total requests completed (= ok + shed + expired + failed).
    pub requests: usize,
    pub ok: usize,
    pub shed: usize,
    pub expired: usize,
    pub failed: usize,
    pub wall_s: f64,
    /// Successful replies per second — what an overloaded server is
    /// judged by.
    pub goodput_rps: f64,
    /// Percentiles over successful requests only (`NaN` if none).
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
}

impl NetLoadReport {
    pub fn shed_rate(&self) -> f64 {
        self.shed as f64 / self.requests.max(1) as f64
    }

    pub fn row(&self, name: &str) -> String {
        format!(
            "{name:<26} {:>6.0} rps x{:<2}  ok {:>4} shed {:>4} exp {:>3} fail {:>3}  \
             goodput {:>7.1}/s  p50 {:>7.2}ms  p99 {:>7.2}ms",
            self.arrival_rps,
            self.conns,
            self.ok,
            self.shed,
            self.expired,
            self.failed,
            self.goodput_rps,
            self.p50_ms,
            self.p99_ms,
        )
    }
}

/// Drive `requests` open-loop Poisson arrivals at `rps` against `addr`
/// over `conns` connections (request `i` rides connection `i % conns`;
/// the exponential gaps come from the seeded deterministic RNG, so the
/// arrival schedule is reproducible).  Each connection is a blocking
/// client, so a reply in flight delays only its own connection's later
/// arrivals — with several connections the offered schedule tracks the
/// target rate even when the server is slow.
///
/// Every request carries `deadline` (when given); classification is
/// client-side from the typed wire codes: `Shed` → shed,
/// `DeadlineExceeded` → expired, everything else (including transport
/// errors) → failed.
pub fn drive_net<F>(
    addr: SocketAddr,
    rps: f64,
    requests: usize,
    conns: usize,
    deadline: Option<Duration>,
    seed: u64,
    make_input: F,
) -> Result<NetLoadReport>
where
    F: Fn(usize) -> (Tensor, Option<Tensor>) + Sync,
{
    drive_net_tenant(addr, "", rps, requests, conns, deadline, seed, make_input)
}

/// [`drive_net`] with every request addressed to a named fleet tenant
/// (empty = the server's sole deployment) — the per-tenant load arm of
/// the fleet bench and tests.
#[allow(clippy::too_many_arguments)]
pub fn drive_net_tenant<F>(
    addr: SocketAddr,
    tenant: &str,
    rps: f64,
    requests: usize,
    conns: usize,
    deadline: Option<Duration>,
    seed: u64,
    make_input: F,
) -> Result<NetLoadReport>
where
    F: Fn(usize) -> (Tensor, Option<Tensor>) + Sync,
{
    anyhow::ensure!(rps > 0.0, "drive_net: arrival rate must be positive");
    anyhow::ensure!(conns >= 1, "drive_net: need at least one connection");
    // one deterministic global schedule, partitioned round-robin
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut sched = Vec::with_capacity(requests);
    let mut t = 0.0f64;
    for _ in 0..requests {
        t += -(1.0 - rng.uniform()).ln() / rps;
        sched.push(t);
    }
    let lat = Mutex::new(Vec::with_capacity(requests));
    let out = Mutex::new(Outcomes::default());
    let rows = AtomicUsize::new(0);
    let t0 = Instant::now();
    std::thread::scope(|s| -> Result<()> {
        let mut joins = Vec::with_capacity(conns);
        for c in 0..conns {
            let (sched, lat, make_input) = (&sched, &lat, &make_input);
            let (out, rows) = (&out, &rows);
            joins.push(s.spawn(move || -> Result<()> {
                let mut client = NetClient::connect(addr)?;
                for i in (c..requests).step_by(conns) {
                    let target = t0 + Duration::from_secs_f64(sched[i]);
                    if let Some(d) = target.checked_duration_since(Instant::now()) {
                        std::thread::sleep(d);
                    }
                    let (x, t) = make_input(i);
                    rows.fetch_add(
                        x.dims.first().copied().unwrap_or(0),
                        Ordering::Relaxed,
                    );
                    let sent = Instant::now();
                    match client.infer_tenant(tenant, &x, t.as_ref(), deadline) {
                        Ok(Ok(_y)) => {
                            plock(&lat).push(sent.elapsed().as_secs_f64() * 1e3)
                        }
                        Ok(Err((code, _))) => plock(&out).note_code(code),
                        Err(_) => {
                            // transport fault: count it, reconnect, go on
                            plock(&out).note_code(ErrCode::BackendFailed);
                            client = NetClient::connect(addr)?;
                        }
                    }
                }
                Ok(())
            }));
        }
        for j in joins {
            j.join().expect("drive_net client thread panicked")?;
        }
        Ok(())
    })?;
    let wall_s = t0.elapsed().as_secs_f64();
    let lat = punwrap(lat);
    let out = punwrap(out);
    // the server's engine counters are not reachable from the client side
    // of the socket, so the shared assembler sees a zero delta there; the
    // client-observable fields are what NetLoadReport republishes
    let r = LoadReport::from_outcomes(
        lat,
        out,
        rows.into_inner(),
        wall_s,
        ServeStats::default(),
        ServeStats::default(),
        conns,
        rps,
    )?;
    Ok(NetLoadReport {
        arrival_rps: rps,
        conns,
        requests: r.requests,
        ok: r.ok_requests,
        shed: r.shed,
        expired: r.expired,
        failed: r.failed,
        wall_s: r.wall_s,
        goodput_rps: r.goodput_rps,
        p50_ms: r.p50_ms,
        p95_ms: r.p95_ms,
        p99_ms: r.p99_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn net_cfg_default_is_sane() {
        let c = NetCfg::default();
        assert!(c.conn_workers >= 1 && c.backlog >= 1);
        assert!(c.frame_stall_ms > 0 && c.max_wait_ms > 0);
        assert_eq!(c.default_deadline_ms, 0);
    }

    #[test]
    fn report_rates() {
        let r = NetLoadReport {
            arrival_rps: 100.0,
            conns: 2,
            requests: 10,
            ok: 6,
            shed: 3,
            expired: 1,
            failed: 0,
            wall_s: 2.0,
            goodput_rps: 3.0,
            p50_ms: 1.0,
            p95_ms: 2.0,
            p99_ms: 3.0,
        };
        assert!((r.shed_rate() - 0.3).abs() < 1e-12);
        let row = r.row("x");
        assert!(row.contains("shed"), "{row}");
    }
}
