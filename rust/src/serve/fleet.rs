//! `serve::fleet` — multi-tenant budget-ladder serving on one shared
//! worker pool.
//!
//! The product shape of depth compression is one base model lowered into
//! a *ladder* of compressed variants at different latency budgets.  A
//! [`Fleet`] owns N such deployments (tenant → ladder of rungs), and
//! layers four things on top of the single-tenant [`super::Session`]
//! machinery (whose queue/dispatch internals it reuses directly —
//! [`super::Request`], [`super::dispatch_batch`], [`super::BatchCtl`]):
//!
//! * **Shared packed weights.**  All rungs lower through one
//!   [`WeightCache`]: merged spans whose weights coincide across budget
//!   points (and across tenants serving the same base model) become
//!   `Arc` clones of a single backend [`crate::runtime::Value`].
//!   [`FleetStats::unique_weight_bytes`] / [`FleetStats::dedup_saved_bytes`]
//!   report the dedup win.
//!
//! * **Weighted-fair scheduling.**  Each tenant has bounded per-rung
//!   queues and a configurable weight; the shared workers drain them by
//!   deficit round-robin (credit in *rows*, `quantum × weight` per
//!   top-up round), so one tenant's overload cannot starve another —
//!   pinned by `tests/fleet.rs`.  Each tenant keeps its own
//!   [`BatchPolicy`] via a per-tenant [`super::BatchCtl`].
//!
//! * **Deadline-aware routing.**  [`Fleet::submit`] asks the
//!   [`Router`] for the cheapest rung whose predicted queue+service
//!   time (EWMA per rung, seeded from the DP solver's latency estimate
//!   at deploy) meets the request deadline, falling back up the ladder
//!   and shedding with the typed [`ServeError::Shed`] when none fits.
//!
//! * **Hot plan swap.**  [`Fleet::swap_plan`] replaces a rung's plan
//!   atomically: every queued request pinned its dispatch handle at
//!   submit time, so in-flight work completes on the *old* plan
//!   bit-identically while new submits land on the new plan — zero
//!   drops across the boundary, no drain pause.
//!
//! * **Rung supervision.**  Worker panics are already isolated per batch
//!   (caught in [`super::dispatch_batch`], converted to typed per-ticket
//!   errors — the worker thread itself survives and its locks recover
//!   from poisoning via `super::plock`).  On top of that, a per-rung
//!   `RungHealth` supervisor watches each rung's failed/panicked batch
//!   outcomes: [`FleetCfg::quarantine_after`] consecutive failures
//!   quarantine the rung (the router stops offering it and requests fall
//!   back up the ladder), [`FleetCfg::quarantine_cooldown_ms`] later one
//!   probation probe is admitted, and a clean probe re-admits the rung.
//!   If *every* rung of a tenant is quarantined the full ladder is
//!   offered anyway — a sick ladder must degrade, not brick.

use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::exec::{CompiledPlan, Format, Plan, WeightCache};
use crate::ir::Task;
use crate::tables::Tables;
use crate::util::par;
use crate::util::tensor::Tensor;

use super::router::{Route, Router, RouterStats, RungCost, RungView};
use super::{
    dispatch_batch, fulfill, plock, pwait, pwait_timeout, BatchCtl, BatchPolicy, Dispatch,
    Engine, LoadReport, Outcomes, Request, ServeError, ServeResult, ServeStats, Ticket,
    TicketInner, OPEN_LOOP_WAIT_CAP,
};

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Fleet-wide sizing: one worker pool and one DRR scheduler shared by
/// every tenant.
#[derive(Debug, Clone, Copy)]
pub struct FleetCfg {
    /// Worker threads draining all tenant queues.
    pub workers: usize,
    /// Bounded queue capacity per tenant, in *requests* (across its
    /// rungs).  A full tenant queue sheds (typed [`ServeError::Shed`])
    /// rather than blocking — fleet ingress is deadline-oriented, and a
    /// blocked submitter would let one tenant wedge another's client.
    pub queue_cap: usize,
    /// DRR credit quantum in rows: each top-up round grants every
    /// backlogged tenant `quantum_rows × weight` rows of credit.
    pub quantum_rows: usize,
    /// Consecutive failed (or panicked) batches on one rung before the
    /// supervisor quarantines it.  0 disables quarantine entirely.
    pub quarantine_after: usize,
    /// How long a quarantined rung is bypassed before one probation
    /// probe is admitted, ms.
    pub quarantine_cooldown_ms: u64,
}

impl Default for FleetCfg {
    fn default() -> Self {
        FleetCfg {
            workers: par::max_threads().min(4),
            queue_cap: 256,
            quantum_rows: 4,
            quarantine_after: 3,
            quarantine_cooldown_ms: 500,
        }
    }
}

/// Per-tenant deployment parameters.
#[derive(Debug, Clone)]
pub struct TenantCfg {
    /// Tenant name — the routing key carried in the wire Infer frame.
    pub name: String,
    /// DRR weight (service share relative to other tenants); clamped to
    /// ≥ 1.
    pub weight: usize,
    /// Batch-forming policy for this tenant's dispatches.
    pub policy: BatchPolicy,
}

impl TenantCfg {
    pub fn new(name: &str, weight: usize, policy: BatchPolicy) -> TenantCfg {
        TenantCfg { name: name.to_string(), weight, policy }
    }
}

// ---------------------------------------------------------------------------
// Internal state
// ---------------------------------------------------------------------------

/// A queued fleet request: the session-tier [`Request`] plus the rung
/// dispatch it was routed to, **pinned at submit time** so a concurrent
/// [`Fleet::swap_plan`] never reroutes admitted work (the swap guarantee:
/// in-flight requests complete on the plan they were admitted to).
struct FleetReq {
    req: Request,
    /// Rung generation at submit; batches coalesce only same-generation
    /// prefixes so no dispatch ever mixes plans.
    gen: u64,
    dispatch: Dispatch,
    /// The rung's batch size at submit — pinned with the dispatch, so a
    /// swap that changes B cannot mis-pad an admitted request.
    batch: usize,
}

/// Supervisor state of one rung.  Healthy → (`quarantine_after`
/// consecutive failed batches) → Quarantined(until) → (cooldown expires,
/// next routing decision admits one probe) → Probation → Healthy on a
/// clean batch, straight back to Quarantined on a dirty one.
enum HealthState {
    Healthy { fails: usize },
    Quarantined { until: Instant },
    Probation,
}

/// Per-rung failure supervisor.  Written by the dispatch path (batch
/// outcomes), read by the routing path (offer/bypass), hence its own
/// lock — never held together with the scheduler lock's critical work.
struct RungHealth {
    state: Mutex<HealthState>,
    /// Consecutive failures before quarantine; 0 disables.
    after: usize,
    cooldown: Duration,
}

impl RungHealth {
    fn new(after: usize, cooldown: Duration) -> RungHealth {
        RungHealth {
            state: Mutex::new(HealthState::Healthy { fails: 0 }),
            after,
            cooldown,
        }
    }

    /// Whether the router should offer this rung right now.  An expired
    /// quarantine flips to probation here — the caller's request becomes
    /// the probe.
    fn offered(&self, now: Instant) -> bool {
        let mut g = plock(&self.state);
        match &*g {
            HealthState::Healthy { .. } | HealthState::Probation => true,
            HealthState::Quarantined { until } => {
                if now >= *until {
                    *g = HealthState::Probation;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Fold one dispatched batch's outcome into the state machine.
    fn note_batch(&self, failed: bool, now: Instant) {
        if self.after == 0 {
            return; // supervision disabled
        }
        let mut g = plock(&self.state);
        *g = match (&*g, failed) {
            (_, false) => HealthState::Healthy { fails: 0 },
            // a dirty probe (or a batch raced into a quarantined rung)
            // re-arms the full cooldown
            (HealthState::Probation | HealthState::Quarantined { .. }, true) => {
                HealthState::Quarantined { until: now + self.cooldown }
            }
            (HealthState::Healthy { fails }, true) => {
                if fails + 1 >= self.after {
                    HealthState::Quarantined { until: now + self.cooldown }
                } else {
                    HealthState::Healthy { fails: fails + 1 }
                }
            }
        };
    }

    fn name(&self) -> &'static str {
        match &*plock(&self.state) {
            HealthState::Healthy { .. } => "healthy",
            HealthState::Quarantined { .. } => "quarantined",
            HealthState::Probation => "probation",
        }
    }
}

/// One deployed budget point of a tenant's ladder.
struct Rung {
    dispatch: Dispatch,
    /// Bumped by every swap; tags queued requests (see [`FleetReq::gen`]).
    gen: u64,
    batch: usize,
    cost: Arc<RungCost>,
    health: Arc<RungHealth>,
    queue: VecDeque<FleetReq>,
    rows_queued: usize,
}

struct Tenant {
    weight: usize,
    /// DRR credit, in rows.  Reset when the tenant's queues drain so idle
    /// tenants cannot bank unbounded credit.
    deficit: usize,
    ctl: Arc<BatchCtl>,
    rungs: Vec<Rung>,
    /// Input row shape all rungs share (`[rows, in_tail..]`).
    in_tail: Vec<usize>,
    needs_t: bool,
    stats: Arc<Mutex<ServeStats>>,
}

impl Tenant {
    fn queued_requests(&self) -> usize {
        self.rungs.iter().map(|r| r.queue.len()).sum()
    }
}

struct FleetState {
    tenants: BTreeMap<String, Tenant>,
    /// DRR visit order (insertion order) + rotating cursor.
    order: Vec<String>,
    cursor: usize,
    closed: bool,
}

struct FleetShared {
    state: Mutex<FleetState>,
    /// Signaled on submit / close / swap — wakes the scheduler.
    work: Condvar,
    workers: usize,
    queue_cap: usize,
    quantum_rows: usize,
    quarantine_after: usize,
    quarantine_cooldown: Duration,
    router: Router,
    cache: WeightCache,
}

// ---------------------------------------------------------------------------
// FleetStats
// ---------------------------------------------------------------------------

/// Fleet-wide snapshot: weight-dedup accounting, router telemetry, and
/// the tenant counters aggregated with `ServeStats + ServeStats`.
#[derive(Debug, Clone)]
pub struct FleetStats {
    /// Bytes of distinct weight data the fleet actually holds.
    pub unique_weight_bytes: usize,
    /// Bytes naive per-plan lowering would have uploaded on top —
    /// the shared-weight dedup win.
    pub dedup_saved_bytes: usize,
    pub tenants: usize,
    pub rungs: usize,
    pub router: RouterStats,
    /// All tenants' serve counters summed (`max_queue`/`cur_window_us`
    /// take the max — see `ServeStats`'s `Add`).
    pub total: ServeStats,
}

// ---------------------------------------------------------------------------
// Fleet
// ---------------------------------------------------------------------------

/// The multi-tenant serving engine.  `'static`, `Send + Sync`; dropping
/// (or [`Fleet::shutdown`]) closes every queue, serves already-admitted
/// requests, and joins the workers.
pub struct Fleet {
    shared: Arc<FleetShared>,
    pool: par::Pool,
    /// Live-user mark on the global compute pool — `par::shutdown_pool()`
    /// fails loudly while a fleet is up instead of deadlocking it.
    _serving: par::ServingGuard,
}

impl Fleet {
    pub fn new(cfg: FleetCfg) -> Fleet {
        let shared = Arc::new(FleetShared {
            state: Mutex::new(FleetState {
                tenants: BTreeMap::new(),
                order: Vec::new(),
                cursor: 0,
                closed: false,
            }),
            work: Condvar::new(),
            workers: cfg.workers.max(1),
            queue_cap: cfg.queue_cap.max(1),
            quantum_rows: cfg.quantum_rows.max(1),
            quarantine_after: cfg.quarantine_after,
            quarantine_cooldown: Duration::from_millis(cfg.quarantine_cooldown_ms.max(1)),
            router: Router::new(),
            cache: WeightCache::new(),
        });
        let ws = Arc::clone(&shared);
        let pool = par::Pool::spawn(cfg.workers, "lm-fleet", move |_| worker_loop(&ws));
        Fleet { shared, pool, _serving: par::serving_guard() }
    }

    /// Register a tenant (no rungs yet — deploy its ladder next).  Errors
    /// on a duplicate name.
    pub fn add_tenant(&self, cfg: TenantCfg) -> Result<()> {
        let mut g = plock(&self.shared.state);
        anyhow::ensure!(
            !g.tenants.contains_key(&cfg.name),
            "fleet: tenant {:?} already exists",
            cfg.name
        );
        g.order.push(cfg.name.clone());
        g.tenants.insert(
            cfg.name.clone(),
            Tenant {
                weight: cfg.weight.max(1),
                deficit: 0,
                ctl: Arc::new(BatchCtl::new(cfg.policy)),
                rungs: Vec::new(),
                in_tail: Vec::new(),
                needs_t: false,
                stats: Arc::new(Mutex::new(ServeStats::default())),
            },
        );
        Ok(())
    }

    /// Deploy a lowered plan as the tenant's next ladder rung (append in
    /// budget order, cheapest/most-compressed first).  `seed_svc_us`
    /// seeds the rung's routing cost estimate — pass the DP solver's
    /// latency-table prediction (or a measurement) for the plan so the
    /// router is sensible before any online signal exists.  Returns the
    /// rung index.
    pub fn deploy_compiled(
        &self,
        tenant: &str,
        cp: Arc<CompiledPlan>,
        seed_svc_us: u64,
    ) -> Result<usize> {
        let dims = cp
            .input_dims()
            .context("cannot deploy an empty plan (no steps)")?;
        let batch = cp.batch();
        let needs_t = cp.task() == Task::Diffusion;
        self.deploy_dispatch(tenant, Dispatch::Plan(cp), batch, dims[1..].to_vec(), needs_t, seed_svc_us)
    }

    /// Lower `plan` through the fleet's shared [`WeightCache`] (weights
    /// coinciding with an already-deployed rung dedup to `Arc` clones)
    /// and deploy it as the tenant's next rung.
    pub fn deploy(
        &self,
        tenant: &str,
        engine: &Engine,
        plan: &Arc<Plan>,
        fmt: Format,
        seed_svc_us: u64,
    ) -> Result<usize> {
        let cp = CompiledPlan::lower_cached(
            Arc::clone(plan),
            Arc::clone(engine.backend()),
            fmt,
            Some(&self.shared.cache),
        )?;
        self.deploy_compiled(tenant, Arc::new(cp), seed_svc_us)
    }

    /// [`Fleet::deploy`] with the routing cost seeded from measured
    /// latency tables: the seed is [`Tables::plan_seed_us`] — summing the
    /// same per-span entries the DP solver optimized over — so the router
    /// ranks the ladder correctly on the *first* request, before any
    /// online EWMA signal exists.  The EWMA then refines (never replaces)
    /// this seed as real service times arrive.
    pub fn deploy_seeded(
        &self,
        tenant: &str,
        engine: &Engine,
        plan: &Arc<Plan>,
        fmt: Format,
        tables: &Tables,
    ) -> Result<usize> {
        self.deploy(tenant, engine, plan, fmt, tables.plan_seed_us(plan))
    }

    /// Deploy an arbitrary host function as a rung — the fleet analogue
    /// of [`super::Session::from_fn`]; the test-suite and the mock
    /// serving bench run the scheduler without any runtime.
    pub fn deploy_fn<F>(
        &self,
        tenant: &str,
        batch: usize,
        in_tail: &[usize],
        needs_t: bool,
        seed_svc_us: u64,
        f: F,
    ) -> Result<usize>
    where
        F: Fn(&Tensor, Option<&Tensor>) -> Result<Tensor> + Send + Sync + 'static,
    {
        assert!(batch >= 1, "batch must be positive");
        self.deploy_dispatch(
            tenant,
            Dispatch::Fn(Arc::new(f)),
            batch,
            in_tail.to_vec(),
            needs_t,
            seed_svc_us,
        )
    }

    fn deploy_dispatch(
        &self,
        tenant: &str,
        dispatch: Dispatch,
        batch: usize,
        in_tail: Vec<usize>,
        needs_t: bool,
        seed_svc_us: u64,
    ) -> Result<usize> {
        let mut g = plock(&self.shared.state);
        let t = g
            .tenants
            .get_mut(tenant)
            .with_context(|| format!("fleet: unknown tenant {tenant:?}"))?;
        if t.rungs.is_empty() {
            t.in_tail = in_tail;
            t.needs_t = needs_t;
        } else {
            anyhow::ensure!(
                t.in_tail == in_tail && t.needs_t == needs_t,
                "fleet: ladder rungs must share the input shape: tenant {tenant:?} \
                 serves [b, {:?}] (needs_t={}), new rung is [b, {:?}] (needs_t={})",
                t.in_tail,
                t.needs_t,
                in_tail,
                needs_t
            );
        }
        t.rungs.push(Rung {
            dispatch,
            gen: 0,
            batch,
            cost: Arc::new(RungCost::new(seed_svc_us)),
            health: Arc::new(RungHealth::new(
                self.shared.quarantine_after,
                self.shared.quarantine_cooldown,
            )),
            queue: VecDeque::new(),
            rows_queued: 0,
        });
        Ok(t.rungs.len() - 1)
    }

    /// Hot-swap rung `rung` of `tenant` to a new compiled plan (lowered
    /// through the shared cache by the caller, or anywhere else).  The
    /// swap is atomic under the scheduler lock: requests admitted before
    /// it complete on the old plan (their dispatch handle was pinned at
    /// submit), requests admitted after it run the new plan, nothing is
    /// dropped and nothing waits for a drain.
    pub fn swap_compiled(
        &self,
        tenant: &str,
        rung: usize,
        cp: Arc<CompiledPlan>,
    ) -> Result<()> {
        let dims = cp
            .input_dims()
            .context("cannot deploy an empty plan (no steps)")?;
        let batch = cp.batch();
        let needs_t = cp.task() == Task::Diffusion;
        self.swap_dispatch(tenant, rung, Dispatch::Plan(cp), batch, dims[1..].to_vec(), needs_t)
    }

    /// [`Fleet::swap_compiled`] lowering `plan` through the fleet's
    /// shared weight cache first.
    pub fn swap_plan(
        &self,
        tenant: &str,
        rung: usize,
        engine: &Engine,
        plan: &Arc<Plan>,
        fmt: Format,
    ) -> Result<()> {
        let cp = CompiledPlan::lower_cached(
            Arc::clone(plan),
            Arc::clone(engine.backend()),
            fmt,
            Some(&self.shared.cache),
        )?;
        self.swap_compiled(tenant, rung, Arc::new(cp))
    }

    /// Function-dispatch swap (tests / mocks).
    pub fn swap_fn<F>(&self, tenant: &str, rung: usize, batch: usize, f: F) -> Result<()>
    where
        F: Fn(&Tensor, Option<&Tensor>) -> Result<Tensor> + Send + Sync + 'static,
    {
        let (in_tail, needs_t) = {
            let g = plock(&self.shared.state);
            let t = g
                .tenants
                .get(tenant)
                .with_context(|| format!("fleet: unknown tenant {tenant:?}"))?;
            (t.in_tail.clone(), t.needs_t)
        };
        self.swap_dispatch(tenant, rung, Dispatch::Fn(Arc::new(f)), batch, in_tail, needs_t)
    }

    fn swap_dispatch(
        &self,
        tenant: &str,
        rung: usize,
        dispatch: Dispatch,
        batch: usize,
        in_tail: Vec<usize>,
        needs_t: bool,
    ) -> Result<()> {
        let mut g = plock(&self.shared.state);
        anyhow::ensure!(!g.closed, "fleet: cannot swap after close");
        let t = g
            .tenants
            .get_mut(tenant)
            .with_context(|| format!("fleet: unknown tenant {tenant:?}"))?;
        anyhow::ensure!(
            t.in_tail == in_tail && t.needs_t == needs_t,
            "fleet: swapped plan must keep the tenant input shape \
             [b, {:?}] (needs_t={})",
            t.in_tail,
            t.needs_t
        );
        let r = t
            .rungs
            .get_mut(rung)
            .with_context(|| format!("fleet: tenant {tenant:?} has no rung {rung}"))?;
        r.dispatch = dispatch;
        r.batch = batch;
        r.gen += 1;
        drop(g);
        // queued old-generation work may now sit behind a generation
        // boundary; wake the workers so it drains promptly
        self.shared.work.notify_all();
        Ok(())
    }

    /// Route and enqueue a request for `tenant`.  The router picks the
    /// cheapest rung whose predicted completion meets the deadline (no
    /// deadline: the rung with the smallest predicted completion);
    /// admission sheds when no rung fits or the tenant queue is full.
    pub fn submit(
        &self,
        tenant: &str,
        x: Tensor,
        t: Option<Tensor>,
        deadline: Option<Instant>,
    ) -> ServeResult<Ticket> {
        self.submit_inner(tenant, x, t, deadline, None)
    }

    /// [`Fleet::submit`] pinned to ladder rung `rung`, bypassing the
    /// router — the "always-biggest-plan" baseline the bench compares
    /// routing against, and a per-rung test hook.
    pub fn submit_rung(
        &self,
        tenant: &str,
        rung: usize,
        x: Tensor,
        t: Option<Tensor>,
        deadline: Option<Instant>,
    ) -> ServeResult<Ticket> {
        self.submit_inner(tenant, x, t, deadline, Some(rung))
    }

    fn submit_inner(
        &self,
        tenant: &str,
        x: Tensor,
        t: Option<Tensor>,
        deadline: Option<Instant>,
        pin: Option<usize>,
    ) -> ServeResult<Ticket> {
        let now = Instant::now();
        if x.dims.is_empty() || x.dims[0] < 1 {
            return Err(ServeError::Rejected(
                "request must have a leading batch dim".into(),
            ));
        }
        let rows = x.dims[0];
        let mut g = plock(&self.shared.state);
        if g.closed {
            return Err(ServeError::ShuttingDown);
        }
        let ten = g
            .tenants
            .get_mut(tenant)
            .ok_or_else(|| ServeError::Rejected(format!("unknown tenant {tenant:?}")))?;
        if ten.rungs.is_empty() {
            return Err(ServeError::Rejected(format!(
                "tenant {tenant:?} has no deployed plans"
            )));
        }
        validate_shape(&x, &t, &ten.in_tail, ten.needs_t)?;
        let stats = Arc::clone(&ten.stats);
        if let Some(d) = deadline {
            if now >= d {
                drop(g);
                plock(&stats).expired_requests += 1;
                return Err(ServeError::DeadlineExceeded);
            }
        }
        let queued = ten.queued_requests();
        if queued >= self.shared.queue_cap {
            let queued_rows: usize = ten.rungs.iter().map(|r| r.rows_queued).sum();
            drop(g);
            plock(&stats).shed_requests += 1;
            return Err(ServeError::Shed {
                queued_rows,
                predicted_us: u64::MAX,
                budget_us: budget_from(deadline, now),
            });
        }
        let budget_us = budget_from(deadline, now);
        // candidate rungs: the pinned one, or everything the request fits
        // in (rows ≤ B) scored by the router
        let rung_idx = match pin {
            Some(i) => {
                let r = ten.rungs.get(i).ok_or_else(|| {
                    ServeError::Rejected(format!("tenant {tenant:?} has no rung {i}"))
                })?;
                if rows > r.batch {
                    return Err(ServeError::Rejected(format!(
                        "request rows {rows} exceed rung {i}'s batch size {}",
                        r.batch
                    )));
                }
                i
            }
            None => {
                let mut idx = Vec::new();
                let mut views = Vec::new();
                for (i, r) in ten.rungs.iter().enumerate() {
                    if rows <= r.batch {
                        idx.push(i);
                        views.push(RungView {
                            queued_rows: r.rows_queued,
                            batch: r.batch,
                            svc_us: r.cost.svc_us(),
                            healthy: r.health.offered(now),
                        });
                    }
                }
                if views.is_empty() {
                    return Err(ServeError::Rejected(format!(
                        "request rows {rows} exceed every rung's batch size"
                    )));
                }
                match self
                    .shared
                    .router
                    .route(&views, rows, budget_us, self.shared.workers)
                {
                    Route::Hit(v) | Route::Fallback(v) => idx[v],
                    Route::Shed { predicted_us } => {
                        let queued_rows: usize =
                            ten.rungs.iter().map(|r| r.rows_queued).sum();
                        drop(g);
                        plock(&stats).shed_requests += 1;
                        return Err(ServeError::Shed {
                            queued_rows,
                            predicted_us,
                            budget_us,
                        });
                    }
                }
            }
        };
        let ticket = Arc::new(TicketInner::default());
        let r = &mut ten.rungs[rung_idx];
        r.queue.push_back(FleetReq {
            req: Request {
                x,
                t,
                ticket: Arc::clone(&ticket),
                enqueued: now,
                deadline,
            },
            gen: r.gen,
            dispatch: r.dispatch.clone(),
            batch: r.batch,
        });
        r.rows_queued += rows;
        let depth = ten.queued_requests();
        drop(g);
        {
            let mut st = plock(&stats);
            st.max_queue = st.max_queue.max(depth);
        }
        self.shared.work.notify_one();
        Ok(Ticket { inner: ticket })
    }

    /// One coherent per-tenant counter snapshot (`None` for an unknown
    /// tenant); `cur_window_us` reflects the tenant's live batch window.
    pub fn tenant_stats(&self, tenant: &str) -> Option<ServeStats> {
        let (stats, ctl) = {
            let g = plock(&self.shared.state);
            let t = g.tenants.get(tenant)?;
            (Arc::clone(&t.stats), Arc::clone(&t.ctl))
        };
        let mut s = *plock(&stats);
        s.cur_window_us = ctl.window_us() as usize;
        Some(s)
    }

    /// Tenant names in DRR order.
    pub fn tenants(&self) -> Vec<String> {
        plock(&self.shared.state).order.clone()
    }

    /// Weight format the fleet's deployed plans execute with, taken from
    /// the first deployed rung in DRR order (all rungs of a fleet lower
    /// through the same backend, so one answer covers the ladder).  An
    /// empty fleet reports the process-default format.
    pub fn weight_format(&self) -> crate::runtime::WeightFormat {
        let g = plock(&self.shared.state);
        g.order
            .iter()
            .filter_map(|name| g.tenants.get(name))
            .flat_map(|t| t.rungs.first())
            .map(|r| r.dispatch.weight_format())
            .next()
            .unwrap_or_else(crate::runtime::WeightFormat::from_env)
    }

    /// Requests currently queued for `tenant` (0 for unknown tenants).
    pub fn queue_depth(&self, tenant: &str) -> usize {
        let g = plock(&self.shared.state);
        g.tenants.get(tenant).map_or(0, Tenant::queued_requests)
    }

    /// Ladder size of `tenant` (0 for unknown tenants).
    pub fn rungs(&self, tenant: &str) -> usize {
        let g = plock(&self.shared.state);
        g.tenants.get(tenant).map_or(0, |t| t.rungs.len())
    }

    pub fn router_stats(&self) -> RouterStats {
        self.shared.router.stats()
    }

    /// Fleet-wide snapshot: dedup accounting + router telemetry + the
    /// sum of every tenant's counters.
    pub fn stats(&self) -> FleetStats {
        let (tenants, rungs, stats_handles): (usize, usize, Vec<Arc<Mutex<ServeStats>>>) = {
            let g = plock(&self.shared.state);
            (
                g.tenants.len(),
                g.tenants.values().map(|t| t.rungs.len()).sum(),
                g.tenants.values().map(|t| Arc::clone(&t.stats)).collect(),
            )
        };
        let total = stats_handles
            .iter()
            .map(|s| *plock(s))
            .fold(ServeStats::default(), |a, b| a + b);
        FleetStats {
            unique_weight_bytes: self.shared.cache.unique_bytes(),
            dedup_saved_bytes: self.shared.cache.saved_bytes(),
            tenants,
            rungs,
            router: self.shared.router.stats(),
            total,
        }
    }

    /// Per-rung supervisor states for `tenant`, in ladder order:
    /// `"healthy"`, `"quarantined"`, or `"probation"` (`None` for an
    /// unknown tenant).  Telemetry for tests and the stats endpoint.
    pub fn rung_states(&self, tenant: &str) -> Option<Vec<&'static str>> {
        let g = plock(&self.shared.state);
        let t = g.tenants.get(tenant)?;
        Some(t.rungs.iter().map(|r| r.health.name()).collect())
    }

    /// Stop accepting new requests; already-admitted work is still served.
    pub fn close(&self) {
        plock(&self.shared.state).closed = true;
        self.shared.work.notify_all();
    }

    /// Clean shutdown: close, drain every queue, join the workers.
    pub fn shutdown(mut self) {
        self.close();
        self.pool.join();
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        self.close();
        self.pool.join();
    }
}

/// Shape/timestep validation against the tenant ladder's shared input
/// shape (the per-rung batch bound is checked during routing).
fn validate_shape(
    x: &Tensor,
    t: &Option<Tensor>,
    in_tail: &[usize],
    needs_t: bool,
) -> ServeResult<()> {
    let reject = |m: String| Err(ServeError::Rejected(m));
    let rows = x.dims[0];
    if x.dims[1..] != in_tail[..] {
        return reject(format!(
            "request dims {:?} don't match the deployed input [b, {in_tail:?}]",
            x.dims
        ));
    }
    match (t, needs_t) {
        (None, true) => reject("deployed plan requires a timestep tensor".into()),
        (Some(_), false) => reject("deployed plan takes no timestep tensor".into()),
        (Some(tt), true) if tt.dims != vec![rows] => {
            reject(format!("timestep dims {:?} must be [{rows}]", tt.dims))
        }
        _ => Ok(()),
    }
}

/// The admission budget in µs (deadline headroom; `u64::MAX` = none).
fn budget_from(deadline: Option<Instant>, now: Instant) -> u64 {
    deadline
        .map(|d| d.saturating_duration_since(now).as_micros() as u64)
        .unwrap_or(u64::MAX)
}

// ---------------------------------------------------------------------------
// Scheduler
// ---------------------------------------------------------------------------

/// What one DRR scan decided to do next.
enum Pick {
    /// Dispatch this coalesced batch.
    Batch {
        /// The dispatch the batch's requests pinned at submit — the old
        /// plan keeps serving its admitted work across a swap.
        dispatch: Dispatch,
        batch: usize,
        reqs: Vec<Request>,
        expired_window: bool,
        cost: Arc<RungCost>,
        health: Arc<RungHealth>,
        ctl: Arc<BatchCtl>,
        stats: Arc<Mutex<ServeStats>>,
    },
    /// Fail these past-deadline requests fast.
    Dead { reqs: Vec<Request>, stats: Arc<Mutex<ServeStats>> },
    /// Nothing actionable before `wake` (None: nothing queued at all).
    Idle { wake: Option<Instant> },
    /// Closed and fully drained.
    Exit,
}

/// Rows in the dispatchable prefix of a rung queue: consecutive
/// same-generation requests up to the *front's pinned* batch size
/// (whole requests only — a swap's generation boundary splits batches so
/// no dispatch ever mixes plans).
fn prefix_rows(q: &VecDeque<FleetReq>) -> usize {
    let Some(front) = q.front() else { return 0 };
    let (gen, b) = (front.gen, front.batch);
    let mut rows = 0usize;
    for fr in q {
        if fr.gen != gen {
            break;
        }
        let r = fr.req.x.dims[0];
        if rows + r > b {
            break;
        }
        rows += r;
        if rows == b {
            break;
        }
    }
    rows
}

/// Whether the queue front already forms a dispatch-ready batch — the
/// session tier's `batch_formed` over generation-tagged queues: the
/// same-generation prefix reaches the pinned B, or is blocked by a
/// request that no longer fits, or by a swap's generation boundary.
fn fleet_batch_formed(q: &VecDeque<FleetReq>) -> bool {
    let Some(front) = q.front() else { return false };
    let (gen, b) = (front.gen, front.batch);
    let mut rows = 0usize;
    for fr in q {
        if fr.gen != gen {
            // a generation boundary blocks coalescing exactly like an
            // oversize request: ship what is in front of it now
            return true;
        }
        let r = fr.req.x.dims[0];
        if rows + r >= b {
            return true;
        }
        rows += r;
    }
    false
}

/// One full DRR scan under the scheduler lock.  Visits tenants from the
/// cursor; a tenant with a dispatch-ready rung batch serves if it has
/// credit.  If every ready tenant lacks credit, all backlogged tenants
/// are topped up `quantum × weight` and the scan retries — bounded,
/// because each round strictly grows every contender's credit toward the
/// (batch-size-bounded) rows it is asking for.
fn scan(shared: &FleetShared, g: &mut FleetState) -> Pick {
    let now = Instant::now();
    let n = g.order.len();
    let closed = g.closed;
    let mut wake: Option<Instant> = None;
    let mut any_queued = false;
    loop {
        let mut ready_without_credit = false;
        for step in 0..n {
            let oi = (g.cursor + step) % n;
            let name = g.order[oi].clone();
            let t = g.tenants.get_mut(&name).expect("order tracks tenants");
            let window = Duration::from_micros(t.ctl.window_us());
            // (ready rung, whether the batching window expiring is why)
            let mut serve: Option<(usize, bool)> = None;
            for (ri, r) in t.rungs.iter_mut().enumerate() {
                // fail expired fronts fast regardless of credit — expiry
                // is not service, and holding them would distort DRR
                let mut dead = Vec::new();
                while let Some(front) = r.queue.front() {
                    if front.req.deadline.is_some_and(|d| now >= d) {
                        let fr = r.queue.pop_front().unwrap();
                        r.rows_queued -= fr.req.x.dims[0];
                        dead.push(fr.req);
                    } else {
                        break;
                    }
                }
                if !dead.is_empty() {
                    return Pick::Dead { reqs: dead, stats: Arc::clone(&t.stats) };
                }
                let Some(front) = r.queue.front() else { continue };
                any_queued = true;
                let formed = fleet_batch_formed(&r.queue);
                let elapsed =
                    window.is_zero() || now >= front.req.enqueued + window;
                if closed || formed || elapsed {
                    serve = Some((ri, !closed && !formed && !window.is_zero()));
                    break;
                }
                let mut w = front.req.enqueued + window;
                if let Some(d) = front.req.deadline {
                    w = w.min(d);
                }
                wake = Some(wake.map_or(w, |cur| cur.min(w)));
            }
            let Some((ri, expired_window)) = serve else { continue };
            let r = &mut t.rungs[ri];
            let rows = prefix_rows(&r.queue);
            if rows == 0 {
                continue;
            }
            if t.deficit < rows {
                ready_without_credit = true;
                continue;
            }
            // serve: pop the same-generation prefix, carrying its pinned
            // dispatch and batch size
            let front = r.queue.front().unwrap();
            let (gen, batch) = (front.gen, front.batch);
            let mut dispatch: Option<Dispatch> = None;
            let mut reqs = Vec::new();
            let mut took = 0usize;
            while let Some(front) = r.queue.front() {
                if front.gen != gen {
                    break;
                }
                let rr = front.req.x.dims[0];
                if took + rr > batch {
                    break;
                }
                took += rr;
                let fr = r.queue.pop_front().unwrap();
                r.rows_queued -= rr;
                dispatch.get_or_insert(fr.dispatch);
                reqs.push(fr.req);
                if took == batch {
                    break;
                }
            }
            let cost = Arc::clone(&r.cost);
            let health = Arc::clone(&r.health);
            t.deficit -= took;
            if t.queued_requests() == 0 {
                t.deficit = 0; // drained: no banking credit while idle
            }
            let pick = Pick::Batch {
                dispatch: dispatch.expect("prefix_rows > 0 pops at least one"),
                batch,
                reqs,
                expired_window,
                cost,
                health,
                ctl: Arc::clone(&t.ctl),
                stats: Arc::clone(&t.stats),
            };
            // stay on this tenant while it has credit (standard DRR);
            // the cursor moves on when its deficit runs out or it drains
            g.cursor = oi;
            return pick;
        }
        if ready_without_credit {
            // top-up round: weight-proportional credit to every tenant
            // with backlog
            let quantum = shared.quantum_rows;
            for t in g.tenants.values_mut() {
                if t.queued_requests() > 0 {
                    t.deficit = t.deficit.saturating_add(quantum * t.weight);
                }
            }
            continue;
        }
        if closed && !any_queued {
            return Pick::Exit;
        }
        return Pick::Idle { wake };
    }
}

fn worker_loop(shared: &FleetShared) {
    loop {
        let pick = {
            let mut g = plock(&shared.state);
            loop {
                match scan(shared, &mut g) {
                    Pick::Idle { wake } => {
                        g = match wake {
                            Some(w) => {
                                let now = Instant::now();
                                if now >= w {
                                    continue; // window elapsed during scan
                                }
                                pwait_timeout(&shared.work, g, w - now)
                            }
                            None => {
                                if g.closed {
                                    return;
                                }
                                pwait(&shared.work, g)
                            }
                        };
                    }
                    Pick::Exit => return,
                    other => break other,
                }
            }
        };
        match pick {
            Pick::Dead { reqs, stats } => {
                plock(&stats).expired_requests += reqs.len();
                for r in reqs {
                    fulfill(&r.ticket, Err(ServeError::DeadlineExceeded));
                }
                shared.work.notify_one();
            }
            Pick::Batch {
                dispatch,
                batch,
                reqs,
                expired_window,
                cost,
                health,
                ctl,
                stats,
            } => {
                let done = dispatch_batch(&dispatch, batch, reqs);
                {
                    let mut st = plock(&stats);
                    st.batches += 1;
                    st.padded_rows += done.padded;
                    st.requests += done.requests;
                    st.rows += done.rows;
                    st.expired_windows += usize::from(expired_window);
                    st.queue_wait_us += done.queue_wait_us;
                    st.service_us += done.svc_us as usize;
                    st.failed_batches += usize::from(done.failed);
                    st.panicked_batches += usize::from(done.panicked);
                }
                // the supervisor sees every batch outcome: consecutive
                // failures quarantine the rung, a clean one re-admits it
                health.note_batch(done.failed, Instant::now());
                ctl.note_batch(batch, done.rows, done.svc_us);
                cost.observe(done.svc_us);
                shared.work.notify_one();
            }
            Pick::Idle { .. } | Pick::Exit => unreachable!("resolved in the lock loop"),
        }
    }
}

// ---------------------------------------------------------------------------
// Mixed-tenant load driver
// ---------------------------------------------------------------------------

/// One tenant's share of a mixed fleet load run.
#[derive(Debug, Clone)]
pub struct FleetLoad {
    pub tenant: String,
    /// Open-loop arrival rate, requests/second.
    pub rps: f64,
    pub requests: usize,
    /// Per-request deadline (arrival + d); `None` = no deadline.
    pub deadline: Option<Duration>,
    pub seed: u64,
}

/// Drive every tenant's open-loop arrival process concurrently (one
/// generator thread per [`FleetLoad`]) and report per-tenant
/// [`LoadReport`]s, in the order of `loads`.  Latency accounting,
/// percentile rules, and failure classification are exactly
/// [`LoadReport::from_outcomes`] — the same aggregation every other load
/// driver uses.
pub fn drive_fleet<F>(fleet: &Fleet, loads: &[FleetLoad], make_input: F) -> Result<Vec<LoadReport>>
where
    F: Fn(&str, usize) -> (Tensor, Option<Tensor>) + Sync,
{
    anyhow::ensure!(!loads.is_empty(), "drive_fleet: no loads");
    for l in loads {
        anyhow::ensure!(l.rps > 0.0, "drive_fleet: arrival rate must be positive");
    }
    let reports: Vec<Result<LoadReport>> = std::thread::scope(|s| {
        let handles: Vec<_> = loads
            .iter()
            .map(|l| {
                let make_input = &make_input;
                s.spawn(move || drive_one(fleet, l, make_input))
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("fleet load generator panicked"))
            .collect()
    });
    reports.into_iter().collect()
}

fn drive_one<F>(fleet: &Fleet, l: &FleetLoad, make_input: &F) -> Result<LoadReport>
where
    F: Fn(&str, usize) -> (Tensor, Option<Tensor>) + Sync,
{
    let before = fleet
        .tenant_stats(&l.tenant)
        .with_context(|| format!("drive_fleet: unknown tenant {:?}", l.tenant))?;
    let mut rng = crate::util::rng::Rng::new(l.seed);
    let mut pending = Vec::with_capacity(l.requests);
    let mut out = Outcomes::default();
    let mut rows = 0usize;
    let mut sched_s = 0.0f64;
    let t0 = Instant::now();
    for i in 0..l.requests {
        sched_s += -(1.0 - rng.uniform()).ln() / l.rps;
        let target = t0 + Duration::from_secs_f64(sched_s);
        if let Some(d) = target.checked_duration_since(Instant::now()) {
            std::thread::sleep(d);
        }
        let (x, t) = make_input(&l.tenant, i);
        rows += x.dims[0];
        let arrival = Instant::now();
        match fleet.submit(&l.tenant, x, t, l.deadline.map(|d| arrival + d)) {
            Ok(ticket) => pending.push((ticket, arrival)),
            Err(e) => out.note(&e),
        }
    }
    let mut lat = Vec::with_capacity(pending.len());
    for (ticket, arrival) in pending {
        match ticket.wait_done_timeout(OPEN_LOOP_WAIT_CAP) {
            Ok((Ok(_), done)) => {
                lat.push(done.saturating_duration_since(arrival).as_secs_f64() * 1e3)
            }
            Ok((Err(e), _)) => out.note(&e),
            Err(_stale) => out.failed += 1,
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let after = fleet
        .tenant_stats(&l.tenant)
        .with_context(|| format!("drive_fleet: unknown tenant {:?}", l.tenant))?;
    LoadReport::from_outcomes(lat, out, rows, wall_s, before, after, 1, l.rps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_handles_are_send_sync_and_static() {
        fn check<T: Send + Sync + 'static>() {}
        check::<Fleet>();
        check::<FleetStats>();
    }

    #[test]
    fn fleet_cfg_default_is_sane() {
        let c = FleetCfg::default();
        assert!(c.workers >= 1 && c.queue_cap >= 1 && c.quantum_rows >= 1);
        assert!(c.quarantine_after >= 1 && c.quarantine_cooldown_ms >= 1);
    }

    #[test]
    fn rung_health_state_machine() {
        let h = RungHealth::new(2, Duration::from_millis(10));
        let t0 = Instant::now();
        assert!(h.offered(t0));
        h.note_batch(true, t0);
        assert_eq!(h.name(), "healthy", "one failure of two is tolerated");
        h.note_batch(true, t0);
        assert_eq!(h.name(), "quarantined");
        assert!(!h.offered(t0), "quarantined rungs are bypassed");
        // a success anywhere resets the streak
        let h2 = RungHealth::new(2, Duration::from_millis(10));
        h2.note_batch(true, t0);
        h2.note_batch(false, t0);
        h2.note_batch(true, t0);
        assert_eq!(h2.name(), "healthy");
        // cooldown expiry: the next routing decision admits the probe
        let later = t0 + Duration::from_millis(11);
        assert!(h.offered(later));
        assert_eq!(h.name(), "probation");
        // a dirty probe goes straight back to quarantine...
        h.note_batch(true, later);
        assert_eq!(h.name(), "quarantined");
        // ...and a clean one re-admits
        assert!(h.offered(later + Duration::from_millis(11)));
        h.note_batch(false, later);
        assert_eq!(h.name(), "healthy");
        // quarantine_after = 0 disables supervision entirely
        let off = RungHealth::new(0, Duration::from_millis(10));
        for _ in 0..16 {
            off.note_batch(true, t0);
        }
        assert!(off.offered(t0));
        assert_eq!(off.name(), "healthy");
    }

    #[test]
    fn unknown_tenant_is_rejected() {
        let f = Fleet::new(FleetCfg { workers: 1, ..FleetCfg::default() });
        let err = f
            .submit("nobody", Tensor::zeros(&[1, 2]), None, None)
            .unwrap_err();
        assert!(matches!(err, ServeError::Rejected(_)));
        f.shutdown();
    }

    #[test]
    fn tenant_without_rungs_is_rejected() {
        let f = Fleet::new(FleetCfg { workers: 1, ..FleetCfg::default() });
        f.add_tenant(TenantCfg::new("a", 1, BatchPolicy::Greedy)).unwrap();
        let err = f
            .submit("a", Tensor::zeros(&[1, 2]), None, None)
            .unwrap_err();
        assert!(matches!(err, ServeError::Rejected(_)));
        f.shutdown();
    }

    #[test]
    fn duplicate_tenant_errors() {
        let f = Fleet::new(FleetCfg { workers: 1, ..FleetCfg::default() });
        f.add_tenant(TenantCfg::new("a", 1, BatchPolicy::Greedy)).unwrap();
        assert!(f.add_tenant(TenantCfg::new("a", 2, BatchPolicy::Greedy)).is_err());
        f.shutdown();
    }

    #[test]
    fn ladder_shape_mismatch_errors() {
        let f = Fleet::new(FleetCfg { workers: 1, ..FleetCfg::default() });
        f.add_tenant(TenantCfg::new("a", 1, BatchPolicy::Greedy)).unwrap();
        f.deploy_fn("a", 4, &[2], false, 100, |x, _| Ok(x.clone())).unwrap();
        assert!(f
            .deploy_fn("a", 4, &[3], false, 100, |x, _| Ok(x.clone()))
            .is_err());
        f.shutdown();
    }
}
