//! `layermerge::serve` — the owning deployment API and micro-batched
//! worker-pool serving (the paper's "latency-critical application"
//! workload: many small clients, one deployed compressed network).
//!
//! Two layers:
//!
//! * [`Engine`] owns an execution [`Backend`] (PJRT over an artifact set
//!   via [`Engine::open`], or the native host kernels via
//!   [`Engine::host`]) and replaces the `(&Runtime, &Manifest)`
//!   parameter-threading the execution API used to require at every call
//!   site.  `Engine::lower` produces an owned [`CompiledPlan`] for hot
//!   loops; `Engine::deploy` produces a [`Session`].
//!
//! * [`Session`] is a `'static`, `Send + Sync` handle over a deployed
//!   network.  `Session::infer` is the synchronous one-shot path
//!   (full-batch tensors, zero queueing).  `Session::submit` enqueues a
//!   sub-batch request (1..=B rows) into a bounded queue and returns a
//!   [`Ticket`]; a pool of [`crate::util::par::Pool`] worker threads
//!   coalesces queued requests up to the spec batch size B, zero-pads the
//!   tail, dispatches one forward, and splits the output rows back onto
//!   the tickets.  The queue bound gives backpressure (`submit` blocks
//!   when full); `close`/drop drains the queue and joins the workers.
//!
//! Padding rows are sound because every per-row computation in the
//! deployed networks (convs, per-sample group norm / attention, the host
//! glue ops) is independent of the other rows in the batch — so a
//! micro-batched result is bit-identical to a one-shot forward over the
//! same rows in the same batch positions (pinned by `tests/serve_queue.rs`).

use std::collections::VecDeque;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::exec::{CompiledPlan, Format, Plan};
use crate::ir::Task;
use crate::model::{Manifest, Model};
use crate::runtime::{Backend, HostBackend, LatencyStats, PjrtBackend, Runtime};
use crate::util::par;
use crate::util::tensor::Tensor;

// ---------------------------------------------------------------------------
// Engine
// ---------------------------------------------------------------------------

/// Owning deployment handle over one execution [`Backend`].  For the PJRT
/// backend it also carries the runtime + manifest (gated-graph training
/// and table construction need them); the host backend needs neither —
/// `Engine::host()` works from a fresh checkout with no artifacts and no
/// XLA.  Cheap to clone (`Arc`s all the way down).
#[derive(Clone)]
pub struct Engine {
    backend: Arc<dyn Backend>,
    rt: Option<Arc<Runtime>>,
    man: Option<Arc<Manifest>>,
}

impl Engine {
    /// Engine over the PJRT backend for an already-open runtime+manifest.
    pub fn new(rt: Arc<Runtime>, man: Arc<Manifest>) -> Engine {
        Engine {
            backend: Arc::new(PjrtBackend::new(Arc::clone(&rt), Arc::clone(&man))),
            rt: Some(rt),
            man: Some(man),
        }
    }

    /// Open an artifacts directory: PJRT client + manifest in one call.
    pub fn open(artifacts: &Path) -> Result<Engine> {
        Ok(Engine::new(
            Arc::new(Runtime::new(artifacts)?),
            Arc::new(Manifest::load(artifacts)?),
        ))
    }

    /// Engine over the native host backend ([`HostBackend`]): executes
    /// lowered plans on `crate::kernels` — no artifacts, no XLA.
    pub fn host() -> Engine {
        Engine::with_backend(Arc::new(HostBackend::new()))
    }

    /// Engine over an arbitrary backend (e.g.
    /// [`HostBackend::per_dispatch`] for the round-trip baseline).
    pub fn with_backend(backend: Arc<dyn Backend>) -> Engine {
        Engine { backend, rt: None, man: None }
    }

    /// The execution backend (transfer counters live here).
    pub fn backend(&self) -> &Arc<dyn Backend> {
        &self.backend
    }

    pub fn try_runtime(&self) -> Option<&Arc<Runtime>> {
        self.rt.as_ref()
    }

    pub fn try_manifest(&self) -> Option<&Arc<Manifest>> {
        self.man.as_ref()
    }

    /// The PJRT runtime.  Panics on a host-backend engine; PJRT-only
    /// callers (tables, gated training, the artifact test suites) use
    /// this, everything else should go through [`Engine::backend`].
    pub fn runtime(&self) -> &Arc<Runtime> {
        self.try_runtime()
            .expect("engine has no PJRT runtime (host backend)")
    }

    /// The artifact manifest.  Panics on a host-backend engine.
    pub fn manifest(&self) -> &Arc<Manifest> {
        self.try_manifest()
            .expect("engine has no artifact manifest (host backend)")
    }

    /// Load a model family by manifest name (gated graph — PJRT only).
    pub fn load_model(&self, name: &str) -> Result<Model> {
        let rt = self
            .try_runtime()
            .context("gated-graph models need the PJRT backend (artifacts + XLA)")?;
        let man = self
            .try_manifest()
            .context("gated-graph models need the PJRT backend (artifacts + XLA)")?;
        Model::load(rt.clone(), man, name)
    }

    /// Lower a plan to an owned [`CompiledPlan`] (one-time cost; reuse it
    /// across calls).  The old `plan.compile(rt, man, fmt)` entry point.
    pub fn lower(&self, plan: &Arc<Plan>, fmt: Format) -> Result<CompiledPlan> {
        CompiledPlan::lower(Arc::clone(plan), Arc::clone(&self.backend), fmt)
    }

    /// One-shot forward: lowers, then runs.  Hot loops should [`Engine::lower`]
    /// once instead.
    pub fn infer(
        &self,
        plan: &Arc<Plan>,
        x: &Tensor,
        t: Option<&Tensor>,
        fmt: Format,
    ) -> Result<Tensor> {
        self.lower(plan, fmt)?.forward(x, t)
    }

    /// End-to-end latency with the App. C protocol (lowered once, so the
    /// measured loop carries no artifact-resolution overhead).
    pub fn measure(
        &self,
        plan: &Arc<Plan>,
        fmt: Format,
        warmup: usize,
        iters: usize,
    ) -> Result<LatencyStats> {
        self.lower(plan, fmt)?.measure(warmup, iters)
    }

    /// Deploy a plan as a micro-batched serving [`Session`] with default
    /// worker/queue sizing.
    pub fn deploy(&self, plan: Arc<Plan>, fmt: Format) -> Result<Session> {
        self.deploy_cfg(plan, fmt, ServeCfg::default())
    }

    pub fn deploy_cfg(&self, plan: Arc<Plan>, fmt: Format, cfg: ServeCfg) -> Result<Session> {
        Session::new(Arc::new(self.lower(&plan, fmt)?), cfg)
    }
}

// ---------------------------------------------------------------------------
// Session
// ---------------------------------------------------------------------------

/// Worker-pool and queue sizing for a [`Session`].
#[derive(Debug, Clone, Copy)]
pub struct ServeCfg {
    /// Worker threads draining the queue.  PJRT executes are thread-safe,
    /// so several batches can be in flight at once.
    pub workers: usize,
    /// Bounded queue capacity in *requests*; `submit` blocks (backpressure)
    /// when the queue is full.
    pub queue_cap: usize,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg { workers: par::max_threads().min(4), queue_cap: 256 }
    }
}

/// Cumulative serving counters (monotonic; snapshot with [`Session::stats`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServeStats {
    /// Requests fully served (tickets resolved).
    pub requests: usize,
    /// Input rows served (excludes padding).
    pub rows: usize,
    /// Device batches dispatched.
    pub batches: usize,
    /// Zero rows padded onto batch tails.
    pub padded_rows: usize,
    /// High-water mark of the request queue.
    pub max_queue: usize,
}

#[derive(Default)]
struct StatsInner {
    requests: AtomicUsize,
    rows: AtomicUsize,
    batches: AtomicUsize,
    padded_rows: AtomicUsize,
    max_queue: AtomicUsize,
}

#[derive(Default)]
struct TicketInner {
    slot: Mutex<Option<Result<Tensor>>>,
    cv: Condvar,
}

/// A pending micro-batched request.  `wait` blocks until a worker has
/// dispatched the batch containing this request and split its rows back.
pub struct Ticket {
    inner: Arc<TicketInner>,
}

impl Ticket {
    pub fn wait(self) -> Result<Tensor> {
        let mut g = self.inner.slot.lock().unwrap();
        while g.is_none() {
            g = self.inner.cv.wait(g).unwrap();
        }
        g.take().unwrap()
    }

    /// Non-blocking poll; returns the result if the batch has completed.
    pub fn try_wait(self) -> std::result::Result<Result<Tensor>, Ticket> {
        let done = self.inner.slot.lock().unwrap().take();
        match done {
            Some(r) => Ok(r),
            None => Err(self),
        }
    }
}

fn fulfill(t: &TicketInner, r: Result<Tensor>) {
    *t.slot.lock().unwrap() = Some(r);
    t.cv.notify_all();
}

struct Request {
    x: Tensor,
    t: Option<Tensor>,
    ticket: Arc<TicketInner>,
}

struct QState {
    items: VecDeque<Request>,
    closed: bool,
}

struct Shared {
    state: Mutex<QState>,
    not_empty: Condvar,
    not_full: Condvar,
    stats: StatsInner,
}

/// The dispatchable side of a session: a lowered plan (any backend), or
/// an arbitrary host function (tests / mock serving benches run the queue
/// machinery without any runtime at all).
#[derive(Clone)]
enum Dispatch {
    Plan(Arc<CompiledPlan>),
    Fn(Arc<dyn Fn(&Tensor, Option<&Tensor>) -> Result<Tensor> + Send + Sync>),
}

impl Dispatch {
    fn run(&self, x: &Tensor, t: Option<&Tensor>) -> Result<Tensor> {
        match self {
            Dispatch::Plan(cp) => cp.forward(x, t),
            Dispatch::Fn(f) => f(x, t),
        }
    }
}

/// A deployed network: `'static`, `Send + Sync`, shareable across client
/// threads.  Dropping (or [`Session::shutdown`]) closes the queue, serves
/// every already-accepted request, and joins the workers.
pub struct Session {
    backend: Dispatch,
    shared: Arc<Shared>,
    pool: par::Pool,
    batch: usize,
    in_tail: Vec<usize>,
    needs_t: bool,
    queue_cap: usize,
}

impl Session {
    /// Serve a lowered plan.  Fails on an empty plan (nothing to dispatch).
    pub fn new(cp: Arc<CompiledPlan>, cfg: ServeCfg) -> Result<Session> {
        let dims = cp
            .input_dims()
            .context("cannot serve an empty plan (no steps)")?;
        let batch = cp.batch();
        let needs_t = cp.task() == Task::Diffusion;
        let backend = Dispatch::Plan(cp);
        Ok(Session::start(backend, batch, dims[1..].to_vec(), needs_t, cfg))
    }

    /// Serve an arbitrary host function with the same queue machinery —
    /// the function receives full `[batch, in_tail..]` tensors and must
    /// return `[batch, ..]` outputs.  Used by the serve test-suite and the
    /// host-only serving bench; also handy for mocking a deployment.
    pub fn from_fn<F>(
        batch: usize,
        in_tail: &[usize],
        needs_t: bool,
        cfg: ServeCfg,
        f: F,
    ) -> Session
    where
        F: Fn(&Tensor, Option<&Tensor>) -> Result<Tensor> + Send + Sync + 'static,
    {
        assert!(batch >= 1, "batch must be positive");
        Session::start(Dispatch::Fn(Arc::new(f)), batch, in_tail.to_vec(), needs_t, cfg)
    }

    fn start(
        backend: Dispatch,
        batch: usize,
        in_tail: Vec<usize>,
        needs_t: bool,
        cfg: ServeCfg,
    ) -> Session {
        let shared = Arc::new(Shared {
            state: Mutex::new(QState { items: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            stats: StatsInner::default(),
        });
        let (ws, wb) = (Arc::clone(&shared), backend.clone());
        let pool = par::Pool::spawn(cfg.workers, "lm-serve", move |_| {
            worker_loop(&ws, &wb, batch);
        });
        Session {
            backend,
            shared,
            pool,
            batch,
            in_tail,
            needs_t,
            queue_cap: cfg.queue_cap.max(1),
        }
    }

    /// Spec batch size B — the coalescing target and the `infer` batch dim.
    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn workers(&self) -> usize {
        self.pool.len()
    }

    pub fn stats(&self) -> ServeStats {
        let s = &self.shared.stats;
        ServeStats {
            requests: s.requests.load(Ordering::Relaxed),
            rows: s.rows.load(Ordering::Relaxed),
            batches: s.batches.load(Ordering::Relaxed),
            padded_rows: s.padded_rows.load(Ordering::Relaxed),
            max_queue: s.max_queue.load(Ordering::Relaxed),
        }
    }

    /// Synchronous one-shot inference: full `[B, ..]` input, no queue.
    pub fn infer(&self, x: &Tensor, t: Option<&Tensor>) -> Result<Tensor> {
        self.backend.run(x, t)
    }

    /// Enqueue a sub-batch request of `1..=B` rows (`[rows, in_tail..]`).
    /// Blocks while the queue is at capacity (backpressure); errors once
    /// the session is closed.
    pub fn submit(&self, x: Tensor) -> Result<Ticket> {
        self.submit_with(x, None)
    }

    /// [`Session::submit`] with a per-row timestep tensor `[rows]`
    /// (required iff the deployed plan is a diffusion model).
    pub fn submit_with(&self, x: Tensor, t: Option<Tensor>) -> Result<Ticket> {
        anyhow::ensure!(
            !x.dims.is_empty() && x.dims[0] >= 1,
            "request must have a leading batch dim"
        );
        let rows = x.dims[0];
        anyhow::ensure!(
            rows <= self.batch,
            "request rows {rows} exceed the deployed batch size {}",
            self.batch
        );
        anyhow::ensure!(
            x.dims[1..] == self.in_tail[..],
            "request dims {:?} don't match the deployed input [b, {:?}]",
            x.dims,
            self.in_tail
        );
        match (&t, self.needs_t) {
            (None, true) => anyhow::bail!("deployed plan requires a timestep tensor"),
            (Some(_), false) => anyhow::bail!("deployed plan takes no timestep tensor"),
            (Some(tt), true) => anyhow::ensure!(
                tt.dims == vec![rows],
                "timestep dims {:?} must be [{rows}]",
                tt.dims
            ),
            (None, false) => {}
        }
        let ticket = Arc::new(TicketInner::default());
        {
            let mut g = self.shared.state.lock().unwrap();
            loop {
                anyhow::ensure!(!g.closed, "session is closed");
                if g.items.len() < self.queue_cap {
                    break;
                }
                g = self.shared.not_full.wait(g).unwrap();
            }
            g.items.push_back(Request { x, t, ticket: Arc::clone(&ticket) });
            let depth = g.items.len();
            let mq = &self.shared.stats.max_queue;
            let mut cur = mq.load(Ordering::Relaxed);
            while depth > cur {
                match mq.compare_exchange_weak(cur, depth, Ordering::Relaxed, Ordering::Relaxed)
                {
                    Ok(_) => break,
                    Err(seen) => cur = seen,
                }
            }
        }
        self.shared.not_empty.notify_one();
        Ok(Ticket { inner: ticket })
    }

    /// Stop accepting new requests.  Already-queued requests are still
    /// served; workers exit once the queue drains.
    pub fn close(&self) {
        self.shared.state.lock().unwrap().closed = true;
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
    }

    /// Clean shutdown: close, drain, join the workers.
    pub fn shutdown(mut self) {
        self.close();
        self.pool.join();
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        self.close();
        self.pool.join();
    }
}

fn worker_loop(shared: &Shared, backend: &Dispatch, b: usize) {
    loop {
        let taken = {
            let mut g = shared.state.lock().unwrap();
            loop {
                if !g.items.is_empty() {
                    break;
                }
                if g.closed {
                    return;
                }
                g = shared.not_empty.wait(g).unwrap();
            }
            // coalesce whole requests (submit bounds each to <= b rows)
            let mut taken: Vec<Request> = Vec::new();
            let mut rows = 0usize;
            while let Some(front) = g.items.front() {
                let r = front.x.dims[0];
                if rows + r > b {
                    break;
                }
                rows += r;
                taken.push(g.items.pop_front().unwrap());
                if rows == b {
                    break;
                }
            }
            taken
        };
        shared.not_full.notify_all();
        if !taken.is_empty() {
            run_batch(shared, backend, b, taken);
        }
    }
}

fn run_batch(shared: &Shared, backend: &Dispatch, b: usize, reqs: Vec<Request>) {
    let total_rows: usize = reqs.iter().map(|r| r.x.dims[0]).sum();
    // a panicking backend must not strand the batch's tickets (waiters
    // would block forever and the worker thread would die silently) —
    // unwind is converted into a per-ticket error instead
    let dispatch = || {
        if reqs.len() == 1 && total_rows == b {
            // full-batch request: dispatch as-is, zero copies
            backend.run(&reqs[0].x, reqs[0].t.as_ref())
        } else {
            let in_tail = &reqs[0].x.dims[1..];
            let row_len: usize = in_tail.iter().product();
            let mut data = vec![0.0f32; b * row_len];
            let mut off = 0usize;
            for r in &reqs {
                data[off..off + r.x.data.len()].copy_from_slice(&r.x.data);
                off += r.x.data.len();
            }
            let mut dims = vec![b];
            dims.extend_from_slice(in_tail);
            let xb = Tensor::new(dims, data);
            let tb = match reqs[0].t {
                Some(_) => {
                    let mut td = vec![0.0f32; b];
                    let mut o = 0usize;
                    for r in &reqs {
                        let tt =
                            r.t.as_ref().expect("submit enforces uniform t presence");
                        td[o..o + tt.data.len()].copy_from_slice(&tt.data);
                        o += tt.data.len();
                    }
                    Some(Tensor::new(vec![b], td))
                }
                None => None,
            };
            backend.run(&xb, tb.as_ref())
        }
    };
    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(dispatch))
        .unwrap_or_else(|p| {
            let msg = p
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| p.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "opaque panic payload".to_string());
            Err(anyhow::anyhow!("serve backend panicked: {msg}"))
        });
    let st = &shared.stats;
    st.batches.fetch_add(1, Ordering::Relaxed);
    st.padded_rows.fetch_add(b - total_rows, Ordering::Relaxed);
    st.requests.fetch_add(reqs.len(), Ordering::Relaxed);
    st.rows.fetch_add(total_rows, Ordering::Relaxed);
    match out {
        Ok(y) if y.dims.first() == Some(&b) && y.data.len() % b == 0 => {
            if reqs.len() == 1 && total_rows == b {
                // full-batch request: move the output straight to its ticket
                let r = reqs.into_iter().next().unwrap();
                fulfill(&r.ticket, Ok(y));
                return;
            }
            let out_row = y.data.len() / b;
            let out_tail = y.dims[1..].to_vec();
            let mut off = 0usize;
            for r in reqs {
                let rows = r.x.dims[0];
                let mut dims = vec![rows];
                dims.extend_from_slice(&out_tail);
                let part =
                    Tensor::new(dims, y.data[off..off + rows * out_row].to_vec());
                off += rows * out_row;
                fulfill(&r.ticket, Ok(part));
            }
        }
        Ok(y) => {
            let msg = format!(
                "serve batch produced dims {:?}, expected leading batch {b}",
                y.dims
            );
            for r in reqs {
                fulfill(&r.ticket, Err(anyhow::anyhow!("{msg}")));
            }
        }
        Err(e) => {
            let msg = format!("serve batch failed: {e}");
            for r in reqs {
                fulfill(&r.ticket, Err(anyhow::anyhow!("{msg}")));
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Concurrent-client load driver
// ---------------------------------------------------------------------------

/// One load run against a session: client-perceived latency percentiles
/// (queue wait included) and throughput, plus coalescing counters.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub clients: usize,
    pub requests: usize,
    pub rows: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub mean_ms: f64,
    pub min_ms: f64,
    pub wall_s: f64,
    pub rows_per_s: f64,
    pub batches: usize,
    pub padded_rows: usize,
}

impl LoadReport {
    pub fn row(&self, name: &str) -> String {
        format!(
            "{name:<26} clients {:>3}  p50 {:>8.2}ms  p95 {:>8.2}ms  {:>9.1} rows/s  \
             {:>4} batches ({} padded rows)",
            self.clients, self.p50_ms, self.p95_ms, self.rows_per_s, self.batches,
            self.padded_rows
        )
    }
}

/// Drive `clients` concurrent submitters, each issuing
/// `requests_per_client` requests produced by `make_input(client, i)`.
/// Every ticket is awaited by its submitter (closed-loop load).
pub fn drive<F>(
    session: &Session,
    clients: usize,
    requests_per_client: usize,
    make_input: F,
) -> Result<LoadReport>
where
    F: Fn(usize, usize) -> (Tensor, Option<Tensor>) + Sync,
{
    let before = session.stats();
    let lat = Mutex::new(Vec::with_capacity(clients * requests_per_client));
    let rows = AtomicUsize::new(0);
    let fail: Mutex<Option<anyhow::Error>> = Mutex::new(None);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let (lat, rows, fail, make_input) = (&lat, &rows, &fail, &make_input);
            s.spawn(move || {
                for i in 0..requests_per_client {
                    let (x, t) = make_input(c, i);
                    rows.fetch_add(x.dims[0], Ordering::Relaxed);
                    let tq = Instant::now();
                    match session.submit_with(x, t).and_then(Ticket::wait) {
                        Ok(_) => lat
                            .lock()
                            .unwrap()
                            .push(tq.elapsed().as_secs_f64() * 1e3),
                        Err(e) => {
                            *fail.lock().unwrap() = Some(e);
                            return;
                        }
                    }
                }
            });
        }
    });
    if let Some(e) = fail.into_inner().unwrap() {
        return Err(e);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let mut lat = lat.into_inner().unwrap();
    anyhow::ensure!(!lat.is_empty(), "drive: no requests completed");
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let after = session.stats();
    let rows = rows.load(Ordering::Relaxed);
    Ok(LoadReport {
        clients,
        requests: lat.len(),
        rows,
        p50_ms: lat[lat.len() / 2],
        p95_ms: lat[((lat.len() as f64 * 0.95) as usize).min(lat.len() - 1)],
        mean_ms: lat.iter().sum::<f64>() / lat.len() as f64,
        min_ms: lat[0],
        wall_s,
        rows_per_s: rows as f64 / wall_s.max(1e-9),
        batches: after.batches - before.batches,
        padded_rows: after.padded_rows - before.padded_rows,
    })
}

/// Slice the classify eval stream into single-row `(x, y)` request pairs
/// (`x: [1,h,w,c]`, `y: [1,classes]`) — the "many small clients" workload
/// the serving CLI and example drive against a [`Session`].  Returns an
/// empty pool for non-classify models.
pub fn classify_request_pool(gen: &crate::train::Gen, batches: usize) -> Vec<(Tensor, Tensor)> {
    let mut pool = Vec::new();
    for bi in 0..batches {
        let batch = gen.batch(crate::train::STREAM_EVAL, bi as u64);
        if let crate::model::Batch::Classify { x, y } = batch {
            let b = x.dims[0];
            let xl: usize = x.dims[1..].iter().product();
            let yl: usize = y.dims[1..].iter().product();
            for r in 0..b {
                let mut xd = vec![1];
                xd.extend_from_slice(&x.dims[1..]);
                let mut yd = vec![1];
                yd.extend_from_slice(&y.dims[1..]);
                pool.push((
                    Tensor::new(xd, x.data[r * xl..(r + 1) * xl].to_vec()),
                    Tensor::new(yd, y.data[r * yl..(r + 1) * yl].to_vec()),
                ));
            }
        }
    }
    pool
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_are_send_sync_and_static() {
        fn check<T: Send + Sync + 'static>() {}
        check::<Engine>();
        check::<Session>();
        check::<Ticket>();
    }

    #[test]
    fn serve_cfg_default_is_sane() {
        let c = ServeCfg::default();
        assert!(c.workers >= 1 && c.queue_cap >= 1);
    }
}
