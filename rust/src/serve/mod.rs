//! `layermerge::serve` — the owning deployment API and micro-batched
//! worker-pool serving (the paper's "latency-critical application"
//! workload: many small clients, one deployed compressed network).
//!
//! Two layers:
//!
//! * [`Engine`] owns an execution [`Backend`] (PJRT over an artifact set
//!   via [`Engine::open`], or the native host kernels via
//!   [`Engine::host`]) and replaces the `(&Runtime, &Manifest)`
//!   parameter-threading the execution API used to require at every call
//!   site.  `Engine::lower` produces an owned [`CompiledPlan`] for hot
//!   loops; `Engine::deploy` produces a [`Session`].
//!
//! * [`Session`] is a `'static`, `Send + Sync` handle over a deployed
//!   network.  `Session::infer` is the synchronous one-shot path
//!   (full-batch tensors, zero queueing).  `Session::submit` enqueues a
//!   sub-batch request (1..=B rows) into a bounded queue and returns a
//!   [`Ticket`]; a pool of [`crate::util::par::Pool`] worker threads
//!   coalesces queued requests up to the spec batch size B, zero-pads the
//!   tail, dispatches one forward, and splits the output rows back onto
//!   the tickets.  The queue bound gives backpressure (`submit` blocks
//!   when full); `close`/drop drains the queue and joins the workers.
//!
//! *When* a worker dispatches is governed by the [`BatchPolicy`] on
//! [`ServeCfg`]: `Greedy` ships whatever is queued the moment a worker is
//! free (minimum latency, maximum padding at light load); `Window` holds
//! a partial batch on a timed condvar wait until B rows arrive or
//! `max_wait_us` elapses from the *oldest* queued request (bounded extra
//! latency, traded for occupancy); `Adaptive` tunes that window online
//! from the observed per-batch occupancy and service time (EWMA
//! controller, capped by a latency budget).  `close()` flushes a held
//! partial batch immediately — no request is ever stranded for the full
//! window on shutdown.  [`drive`] (closed-loop clients) and [`drive_open`]
//! (deterministic Poisson arrivals at a target rate) measure the
//! resulting latency/padding tradeoff; [`ServeStats`] carries the
//! occupancy and window telemetry.
//!
//! Padding rows are sound because every per-row computation in the
//! deployed networks (convs, per-sample group norm / attention, the host
//! glue ops) is independent of the other rows in the batch — so a
//! micro-batched result is bit-identical to a one-shot forward over the
//! same rows in the same batch positions (pinned by `tests/serve_queue.rs`).
//!
//! On the host backend the scratch arena is sharded **per thread**, so
//! each serving worker reaches its own zero-allocation steady state
//! independently; `ServeCfg::warmup` runs one throwaway forward per
//! worker at deploy so the first real request is already in it.
//!
//! **Robustness tier.**  Every failure a request can hit is a *typed*
//! [`ServeError`] (shed, deadline-exceeded, backend-failed, shutting-down,
//! rejected), so callers — the network tier in [`net`] above all — can
//! tell "the system protected itself" from "the system broke".
//! [`Session::submit_deadline`] carries a per-request deadline into the
//! queue: requests whose deadline passes before dispatch are failed fast
//! by the worker (`expired_requests`) instead of served late, and
//! admission control sheds at the door (`shed_requests`) when the
//! predicted queue wait — queued batches times the EWMA per-batch service
//! time the `Adaptive` policy already tracks — exceeds the deadline (or
//! the [`ServeCfg::slo`] bound).  A panicking or erroring backend batch
//! poisons only its own tickets (`failed_batches`); the worker survives.
//! [`Ticket::wait_timeout`] bounds every wait so a wedged batch can never
//! block a caller forever.  [`net`] puts a TCP socket in front of all of
//! this ([`proto`] defines the wire frames).
//!
//! Every lock in the serving tier goes through the poison-recovering
//! [`plock`]/[`pwait`]/[`pwait_timeout`] helpers: a thread that panics
//! while holding a serve mutex poisons it, but the guarded state is
//! still coherent (critical sections here are short counter/queue
//! updates with no panicking calls inside), so other handler threads
//! recover the guard and keep serving instead of cascading
//! poisoned-lock panics across the whole session.  [`chaos`] provides
//! the deterministic fault-injection layer (backend, dispatch, and wire
//! faults) that exercises all of this on purpose.

use std::collections::VecDeque;
use std::fmt;
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::exec::{CompiledPlan, Format, Plan};
use crate::ir::Task;
use crate::model::{Manifest, Model};
use crate::runtime::{Backend, HostBackend, LatencyStats, PjrtBackend, Runtime, WeightFormat};
use crate::util::par;
use crate::util::tensor::Tensor;

pub mod chaos;
pub mod fleet;
pub mod net;
pub mod proto;
pub mod router;

// ---------------------------------------------------------------------------
// Poison-recovering lock helpers
// ---------------------------------------------------------------------------

/// Lock a serve-tier mutex, recovering the guard if a previous holder
/// panicked.  Serve critical sections are short counter/queue updates
/// that cannot leave the state half-written across a panic point, so
/// recovery is always sound here — and without it a single injected
/// panic in one handler thread would cascade `PoisonError` panics into
/// every other thread sharing the session.
pub(crate) fn plock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Condvar wait with the same poison recovery as [`plock`].
pub(crate) fn pwait<'a, T>(
    cv: &Condvar,
    g: std::sync::MutexGuard<'a, T>,
) -> std::sync::MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Timed condvar wait with the same poison recovery as [`plock`]
/// (callers re-check their predicate and the clock, so the timed-out
/// flag is not surfaced).
pub(crate) fn pwait_timeout<'a, T>(
    cv: &Condvar,
    g: std::sync::MutexGuard<'a, T>,
    d: Duration,
) -> std::sync::MutexGuard<'a, T> {
    match cv.wait_timeout(g, d) {
        Ok((g, _)) => g,
        Err(p) => p.into_inner().0,
    }
}

/// `Mutex::into_inner` with poison recovery — drivers collecting results
/// from scoped worker threads use it so one panicked client thread
/// cannot void the whole run's tally.
pub(crate) fn punwrap<T>(m: Mutex<T>) -> T {
    m.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner)
}

// ---------------------------------------------------------------------------
// Typed serving errors
// ---------------------------------------------------------------------------

/// Why a served request failed — typed, so the network tier can put a
/// wire code on it and load drivers can separate "the system protected
/// itself" (shed, expired) from "the system broke" (backend failed).
///
/// Converts into `anyhow::Error` (it implements `std::error::Error`), so
/// the untyped [`Ticket::wait`]/[`Session::submit`] surfaces are
/// unchanged; typed callers use [`Session::submit_deadline`] and
/// [`Ticket::wait_coded`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// The request itself was malformed (shape / timestep validation).
    /// Maps to `BadFrame` on the wire.
    Rejected(String),
    /// Admission control refused the request at the door: the predicted
    /// queue wait exceeded the request deadline / configured SLO, or the
    /// bounded queue was full for a deadlined request.
    Shed {
        /// Rows already queued when the request was refused.
        queued_rows: usize,
        /// Predicted wait before this request would dispatch, in µs.
        predicted_us: u64,
        /// The budget the prediction exceeded, in µs.
        budget_us: u64,
    },
    /// The request was admitted but its deadline passed before a worker
    /// dispatched it; it was failed fast instead of served late.
    DeadlineExceeded,
    /// The dispatched batch errored or panicked; only this batch's
    /// tickets carry the failure.
    BackendFailed(String),
    /// The session (or server) is draining and accepts no new work.
    ShuttingDown,
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Rejected(m) => f.write_str(m),
            ServeError::Shed { queued_rows, predicted_us, budget_us } => write!(
                f,
                "request shed at admission: predicted queue wait {predicted_us}us \
                 exceeds the {budget_us}us budget ({queued_rows} rows queued)"
            ),
            ServeError::DeadlineExceeded => {
                f.write_str("request deadline exceeded before dispatch")
            }
            ServeError::BackendFailed(m) => f.write_str(m),
            ServeError::ShuttingDown => f.write_str("session is closed (shutting down)"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Result of a typed serve operation.
pub type ServeResult<T> = std::result::Result<T, ServeError>;

// ---------------------------------------------------------------------------
// Engine
// ---------------------------------------------------------------------------

/// Owning deployment handle over one execution [`Backend`].  For the PJRT
/// backend it also carries the runtime + manifest (gated-graph training
/// and table construction need them); the host backend needs neither —
/// `Engine::host()` works from a fresh checkout with no artifacts and no
/// XLA.  Cheap to clone (`Arc`s all the way down).
#[derive(Clone)]
pub struct Engine {
    backend: Arc<dyn Backend>,
    rt: Option<Arc<Runtime>>,
    man: Option<Arc<Manifest>>,
}

impl Engine {
    /// Engine over the PJRT backend for an already-open runtime+manifest.
    pub fn new(rt: Arc<Runtime>, man: Arc<Manifest>) -> Engine {
        Engine {
            backend: Arc::new(PjrtBackend::new(Arc::clone(&rt), Arc::clone(&man))),
            rt: Some(rt),
            man: Some(man),
        }
    }

    /// Open an artifacts directory: PJRT client + manifest in one call.
    pub fn open(artifacts: &Path) -> Result<Engine> {
        Ok(Engine::new(
            Arc::new(Runtime::new(artifacts)?),
            Arc::new(Manifest::load(artifacts)?),
        ))
    }

    /// Engine over the native host backend ([`HostBackend`]): executes
    /// lowered plans on `crate::kernels` — no artifacts, no XLA.
    pub fn host() -> Engine {
        Engine::with_backend(Arc::new(HostBackend::new()))
    }

    /// Engine over an arbitrary backend (e.g.
    /// [`HostBackend::per_dispatch`] for the round-trip baseline).
    pub fn with_backend(backend: Arc<dyn Backend>) -> Engine {
        Engine { backend, rt: None, man: None }
    }

    /// The execution backend (transfer counters live here).
    pub fn backend(&self) -> &Arc<dyn Backend> {
        &self.backend
    }

    pub fn try_runtime(&self) -> Option<&Arc<Runtime>> {
        self.rt.as_ref()
    }

    pub fn try_manifest(&self) -> Option<&Arc<Manifest>> {
        self.man.as_ref()
    }

    /// The PJRT runtime.  Panics on a host-backend engine; PJRT-only
    /// callers (tables, gated training, the artifact test suites) use
    /// this, everything else should go through [`Engine::backend`].
    pub fn runtime(&self) -> &Arc<Runtime> {
        self.try_runtime()
            .expect("engine has no PJRT runtime (host backend)")
    }

    /// The artifact manifest.  Panics on a host-backend engine.
    pub fn manifest(&self) -> &Arc<Manifest> {
        self.try_manifest()
            .expect("engine has no artifact manifest (host backend)")
    }

    /// Load a model family by manifest name (gated graph — PJRT only).
    pub fn load_model(&self, name: &str) -> Result<Model> {
        let rt = self
            .try_runtime()
            .context("gated-graph models need the PJRT backend (artifacts + XLA)")?;
        let man = self
            .try_manifest()
            .context("gated-graph models need the PJRT backend (artifacts + XLA)")?;
        Model::load(rt.clone(), man, name)
    }

    /// Lower a plan to an owned [`CompiledPlan`] (one-time cost; reuse it
    /// across calls).  The old `plan.compile(rt, man, fmt)` entry point.
    pub fn lower(&self, plan: &Arc<Plan>, fmt: Format) -> Result<CompiledPlan> {
        CompiledPlan::lower(Arc::clone(plan), Arc::clone(&self.backend), fmt)
    }

    /// One-shot forward: lowers, then runs.  Hot loops should [`Engine::lower`]
    /// once instead.
    pub fn infer(
        &self,
        plan: &Arc<Plan>,
        x: &Tensor,
        t: Option<&Tensor>,
        fmt: Format,
    ) -> Result<Tensor> {
        self.lower(plan, fmt)?.forward(x, t)
    }

    /// End-to-end latency with the App. C protocol (lowered once, so the
    /// measured loop carries no artifact-resolution overhead).
    pub fn measure(
        &self,
        plan: &Arc<Plan>,
        fmt: Format,
        warmup: usize,
        iters: usize,
    ) -> Result<LatencyStats> {
        self.lower(plan, fmt)?.measure(warmup, iters)
    }

    /// Deploy a plan as a micro-batched serving [`Session`] with default
    /// worker/queue sizing.
    pub fn deploy(&self, plan: Arc<Plan>, fmt: Format) -> Result<Session> {
        self.deploy_cfg(plan, fmt, ServeCfg::default())
    }

    pub fn deploy_cfg(&self, plan: Arc<Plan>, fmt: Format, cfg: ServeCfg) -> Result<Session> {
        Session::new(Arc::new(self.lower(&plan, fmt)?), cfg)
    }
}

// ---------------------------------------------------------------------------
// Session
// ---------------------------------------------------------------------------

/// When a worker forms and dispatches a batch from the queue.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BatchPolicy {
    /// Dispatch whatever is queued the moment a worker is free.  Lowest
    /// per-request latency; at light load most batches go out mostly
    /// padding.
    Greedy,
    /// Hold a partial batch until B rows are available or `max_wait_us`
    /// has elapsed since the **oldest** queued request arrived (bounded
    /// wait: no request is delayed by more than the window before its
    /// batch dispatches).  A full batch always dispatches immediately.
    Window {
        /// The wait-a-little bound, in microseconds.
        max_wait_us: u64,
    },
    /// Tune the window online: an EWMA controller grows the window while
    /// observed batch occupancy (real rows / B) sits below
    /// `target_occupancy` and shrinks it once the target is met, capped
    /// by the `max_wait_us` latency budget and by the EWMA per-batch
    /// service time (waiting much longer than one dispatch takes cannot
    /// pay for itself).
    Adaptive {
        /// Desired fraction of real (non-padding) rows per batch, in
        /// `(0, 1]`.
        target_occupancy: f64,
        /// Hard latency-budget cap on the tuned window, in microseconds.
        max_wait_us: u64,
    },
}

impl BatchPolicy {
    /// The window a fresh session starts from: zero for `Greedy`, the
    /// full bound for `Window`, half the cap for `Adaptive` (the
    /// controller converges from the middle of its range).
    fn initial_window_us(&self) -> u64 {
        match *self {
            BatchPolicy::Greedy => 0,
            BatchPolicy::Window { max_wait_us } => max_wait_us,
            BatchPolicy::Adaptive { max_wait_us, .. } => max_wait_us / 2,
        }
    }
}

/// Worker-pool, queue sizing, and batch-forming policy for a [`Session`].
#[derive(Debug, Clone, Copy)]
pub struct ServeCfg {
    /// Worker threads draining the queue.  PJRT executes are thread-safe,
    /// so several batches can be in flight at once.
    pub workers: usize,
    /// Bounded queue capacity in *requests*; `submit` blocks (backpressure)
    /// when the queue is full.
    pub queue_cap: usize,
    /// How workers form batches from the queue (see [`BatchPolicy`]).
    pub policy: BatchPolicy,
    /// Run one throwaway zero forward on each worker thread at deploy.
    /// On the host backend this charges the worker's arena shard
    /// (scratch freelists are per-thread), so buffers the forward takes
    /// on the worker thread — activations, im2col columns, pad planes —
    /// are recycled from the first real request on.  Buffers taken
    /// *inside* compute-pool tasks can still miss once per pool thread
    /// (task-to-thread assignment is work-stealing), so the guarantee is
    /// "warm from request 1" for serial-dispatch plans and "warm after
    /// each pool thread's first claim" beyond that.  The warmup runs
    /// asynchronously on the worker threads and is not counted in
    /// [`ServeStats`] (transfer counters do move — snapshot deltas after
    /// traffic, not across deploy).  Off by default.
    pub warmup: bool,
    /// Admission-control latency SLO.  When set, every submitted request
    /// is shed at the door (typed [`ServeError::Shed`]) if the predicted
    /// queue wait — queued batches × the EWMA per-batch service time —
    /// exceeds this bound.  Per-request deadlines
    /// ([`Session::submit_deadline`]) tighten the budget further; `None`
    /// disables SLO-based shedding for deadline-less requests.
    pub slo: Option<Duration>,
}

impl Default for ServeCfg {
    fn default() -> Self {
        ServeCfg {
            workers: par::max_threads().min(4),
            queue_cap: 256,
            policy: BatchPolicy::Greedy,
            warmup: false,
            slo: None,
        }
    }
}

/// Cumulative serving counters (monotonic; snapshot with [`Session::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeStats {
    /// Requests fully served (tickets resolved; `infer` calls count one).
    pub requests: usize,
    /// Input rows served (excludes padding).
    pub rows: usize,
    /// Device batches dispatched (`infer` calls count one).
    pub batches: usize,
    /// Zero rows padded onto batch tails.
    pub padded_rows: usize,
    /// High-water mark of the request queue.
    pub max_queue: usize,
    /// Partial batches dispatched because their batching window expired
    /// (always zero under [`BatchPolicy::Greedy`]).
    pub expired_windows: usize,
    /// Cumulative per-request queue wait (submit to dispatch), in µs.
    pub queue_wait_us: usize,
    /// Cumulative per-batch dispatch (service) time, in µs.
    pub service_us: usize,
    /// The batching window currently applied by the policy, in µs
    /// (fixed for `Window`, tuned online for `Adaptive`, 0 for `Greedy`).
    pub cur_window_us: usize,
    /// Requests refused at admission (typed [`ServeError::Shed`]): the
    /// predicted queue wait exceeded their deadline / the SLO, or the
    /// queue was full for a deadlined request.
    pub shed_requests: usize,
    /// Admitted requests failed fast at dispatch time because their
    /// deadline had already passed ([`ServeError::DeadlineExceeded`]).
    pub expired_requests: usize,
    /// Dispatched batches that errored or panicked; each poisoned only
    /// its own tickets ([`ServeError::BackendFailed`]).
    pub failed_batches: usize,
    /// The subset of `failed_batches` that failed by *panicking* (caught
    /// and converted per-ticket).  The fleet supervisor watches this and
    /// `failed_batches` per rung to decide quarantine.
    pub panicked_batches: usize,
}

impl ServeStats {
    /// Fraction of dispatched rows that were real requests rather than
    /// tail padding: `rows / (rows + padded_rows)`.  1.0 before any
    /// batch has been dispatched.
    pub fn occupancy(&self) -> f64 {
        occupancy_of(self.rows, self.padded_rows)
    }
}

/// The one occupancy derivation ([`ServeStats::occupancy`] and the
/// per-run [`LoadReport`] both use it): real rows over dispatched rows,
/// 1.0 when nothing has been dispatched.
fn occupancy_of(rows: usize, padded_rows: usize) -> f64 {
    let total = rows + padded_rows;
    if total == 0 {
        1.0
    } else {
        rows as f64 / total as f64
    }
}

impl std::ops::Sub for ServeStats {
    type Output = ServeStats;

    /// Counter delta `after - before` — what the load drivers report a
    /// run by.  `cur_window_us` is a gauge, not a counter: the newer
    /// snapshot's value is kept as-is.
    fn sub(self, before: ServeStats) -> ServeStats {
        ServeStats {
            requests: self.requests - before.requests,
            rows: self.rows - before.rows,
            batches: self.batches - before.batches,
            padded_rows: self.padded_rows - before.padded_rows,
            // high-water mark, not a counter: the newer value stands
            max_queue: self.max_queue,
            expired_windows: self.expired_windows - before.expired_windows,
            queue_wait_us: self.queue_wait_us - before.queue_wait_us,
            service_us: self.service_us - before.service_us,
            cur_window_us: self.cur_window_us,
            shed_requests: self.shed_requests - before.shed_requests,
            expired_requests: self.expired_requests - before.expired_requests,
            failed_batches: self.failed_batches - before.failed_batches,
            panicked_batches: self.panicked_batches - before.panicked_batches,
        }
    }
}

impl std::ops::Add for ServeStats {
    type Output = ServeStats;

    /// Field-wise sum — the fleet aggregates per-tenant snapshots with
    /// it.  `max_queue` and `cur_window_us` take the max (they are
    /// high-water/gauge values, not additive counters).
    fn add(self, o: ServeStats) -> ServeStats {
        ServeStats {
            requests: self.requests + o.requests,
            rows: self.rows + o.rows,
            batches: self.batches + o.batches,
            padded_rows: self.padded_rows + o.padded_rows,
            max_queue: self.max_queue.max(o.max_queue),
            expired_windows: self.expired_windows + o.expired_windows,
            queue_wait_us: self.queue_wait_us + o.queue_wait_us,
            service_us: self.service_us + o.service_us,
            cur_window_us: self.cur_window_us.max(o.cur_window_us),
            shed_requests: self.shed_requests + o.shed_requests,
            expired_requests: self.expired_requests + o.expired_requests,
            failed_batches: self.failed_batches + o.failed_batches,
            panicked_batches: self.panicked_batches + o.panicked_batches,
        }
    }
}

#[derive(Default)]
struct TicketInner {
    /// The result plus the instant it was posted (the open-loop driver
    /// computes exact completion latency from it even when the ticket is
    /// awaited long after the batch finished).
    slot: Mutex<Option<(ServeResult<Tensor>, Instant)>>,
    cv: Condvar,
}

/// A pending micro-batched request.  `wait` blocks until a worker has
/// dispatched the batch containing this request and split its rows back.
pub struct Ticket {
    inner: Arc<TicketInner>,
}

impl Ticket {
    pub fn wait(self) -> Result<Tensor> {
        self.wait_coded().map_err(anyhow::Error::from)
    }

    /// [`Ticket::wait`] with the typed [`ServeError`] preserved — the
    /// network tier maps it onto a wire error code.
    pub fn wait_coded(self) -> ServeResult<Tensor> {
        self.wait_done().0
    }

    /// Bounded wait: the result if the batch completes within `d`, or the
    /// ticket back on timeout (retry, or drop it — a late fulfillment
    /// into a dropped ticket is harmless).  This is the wait the serving
    /// tier uses everywhere a wedged or slow batch must not block a
    /// caller forever.
    pub fn wait_timeout(self, d: Duration) -> std::result::Result<Result<Tensor>, Ticket> {
        self.wait_timeout_coded(d)
            .map(|r| r.map_err(anyhow::Error::from))
    }

    /// [`Ticket::wait_timeout`] with the typed error preserved.
    pub fn wait_timeout_coded(
        self,
        d: Duration,
    ) -> std::result::Result<ServeResult<Tensor>, Ticket> {
        self.wait_done_timeout(d).map(|(r, _)| r)
    }

    /// Like [`Ticket::wait_coded`], but also returns the instant the
    /// result was posted — the completion timestamp the open-loop load
    /// driver needs.
    pub(crate) fn wait_done(self) -> (ServeResult<Tensor>, Instant) {
        let mut g = plock(&self.inner.slot);
        loop {
            if let Some(done) = g.take() {
                return done;
            }
            g = pwait(&self.inner.cv, g);
        }
    }

    /// Timed [`Ticket::wait_done`]: `Err(self)` if `d` elapses first.
    pub(crate) fn wait_done_timeout(
        self,
        d: Duration,
    ) -> std::result::Result<(ServeResult<Tensor>, Instant), Ticket> {
        let deadline = Instant::now() + d;
        let mut g = plock(&self.inner.slot);
        loop {
            if let Some(done) = g.take() {
                return Ok(done);
            }
            let now = Instant::now();
            if now >= deadline {
                drop(g);
                return Err(self);
            }
            g = pwait_timeout(&self.inner.cv, g, deadline - now);
        }
    }

    /// Non-blocking poll; returns the result if the batch has completed.
    pub fn try_wait(self) -> std::result::Result<Result<Tensor>, Ticket> {
        let done = plock(&self.inner.slot).take();
        match done {
            Some((r, _)) => Ok(r.map_err(anyhow::Error::from)),
            None => Err(self),
        }
    }
}

fn fulfill(t: &TicketInner, r: ServeResult<Tensor>) {
    let mut slot = plock(&t.slot);
    // exactly-once resolution: the dead/taken split in the worker loop is
    // disjoint, so a slot is never written twice — the chaos invariant
    // suite leans on this
    debug_assert!(slot.is_none(), "ticket fulfilled twice");
    *slot = Some((r, Instant::now()));
    drop(slot);
    t.cv.notify_all();
}

struct Request {
    x: Tensor,
    t: Option<Tensor>,
    ticket: Arc<TicketInner>,
    /// When `submit` queued this request — anchors the batching window
    /// (bounded wait is measured from the oldest request in the batch)
    /// and the queue-wait telemetry.
    enqueued: Instant,
    /// Serve-by deadline: a worker that reaches this request after the
    /// deadline fails it fast ([`ServeError::DeadlineExceeded`]) instead
    /// of serving it late.
    deadline: Option<Instant>,
}

struct QState {
    items: VecDeque<Request>,
    /// Rows across `items` — maintained on push/pop so admission control
    /// predicts queue wait without walking the queue under the lock.
    rows_queued: usize,
    closed: bool,
}

/// EWMA state of the `Adaptive` controller.  Behind one mutex so
/// concurrent batch completions from a multi-worker pool serialize their
/// updates — a lock-free read-modify-write here would silently drop one
/// batch's occupancy/service signal whenever two dispatches race.
#[derive(Default)]
struct AdaptCtl {
    /// EWMA batch occupancy in parts-per-million (0 = no batch yet).
    ewma_occ_ppm: u64,
    /// EWMA per-batch service time in µs (0 = no batch yet).
    ewma_svc_us: u64,
}

/// One batch-forming policy instance: the deployed [`BatchPolicy`], the
/// window it currently applies, and the occupancy/service EWMA state the
/// `Adaptive` controller tunes it from.  A [`Session`] owns one; the
/// fleet owns one **per tenant** (each tenant keeps its own policy and
/// its own window/occupancy signal on the shared worker pool).
pub(crate) struct BatchCtl {
    policy: BatchPolicy,
    /// The window currently applied by the policy, in µs.  Constant for
    /// `Greedy` (0) and `Window`; written by the EWMA controller (under
    /// the `ctl` lock) for `Adaptive`.  Atomic so worker wait loops read
    /// it without extra locking.
    window_us: AtomicU64,
    ctl: Mutex<AdaptCtl>,
    /// Mirror of `ctl.ewma_svc_us`, updated after every batch regardless
    /// of policy — admission control reads it lock-free on the submit
    /// path.  0 until the first batch completes (no shedding before the
    /// estimator has a signal).
    svc_ewma_us: AtomicU64,
}

impl BatchCtl {
    pub(crate) fn new(policy: BatchPolicy) -> BatchCtl {
        BatchCtl {
            policy,
            window_us: AtomicU64::new(policy.initial_window_us()),
            ctl: Mutex::new(AdaptCtl::default()),
            svc_ewma_us: AtomicU64::new(0),
        }
    }

    pub(crate) fn policy(&self) -> BatchPolicy {
        self.policy
    }

    /// The window the policy currently applies, µs (0 = greedy dispatch).
    pub(crate) fn window_us(&self) -> u64 {
        self.window_us.load(Ordering::Relaxed)
    }

    /// EWMA per-batch service time, µs (0 until the first batch).
    pub(crate) fn svc_us(&self) -> u64 {
        self.svc_ewma_us.load(Ordering::Relaxed)
    }

    /// Per-batch EWMA bookkeeping, run once per dispatched batch for
    /// every policy: update the occupancy/service estimators (the service
    /// EWMA is mirrored for the lock-free admission check), then — for
    /// `Adaptive` only — run the window controller:
    /// multiplicative-increase the window while occupancy undershoots the
    /// target, decay it once the target is met; never exceed the latency
    /// budget `max_wait_us` or twice the EWMA service time (waiting much
    /// longer than one dispatch takes cannot improve amortization).
    pub(crate) fn note_batch(&self, b: usize, rows: usize, svc_us: u64) {
        // one controller step per batch; the lock serializes racing
        // workers so no batch's signal is lost to a concurrent RMW
        let mut ctl = plock(&self.ctl);
        let occ_ppm = (rows * 1_000_000 / b.max(1)) as u64;
        let occ = if ctl.ewma_occ_ppm == 0 {
            occ_ppm
        } else {
            (ctl.ewma_occ_ppm * 3 + occ_ppm) / 4
        };
        ctl.ewma_occ_ppm = occ;

        let svc_us = svc_us.max(1);
        let svc = if ctl.ewma_svc_us == 0 {
            svc_us
        } else {
            (ctl.ewma_svc_us * 3 + svc_us) / 4
        };
        ctl.ewma_svc_us = svc;
        self.svc_ewma_us.store(svc, Ordering::Relaxed);

        let BatchPolicy::Adaptive { target_occupancy, max_wait_us } = self.policy else {
            return;
        };
        let target_ppm = (target_occupancy.clamp(0.0, 1.0) * 1e6) as u64;
        let cur = self.window_us.load(Ordering::Relaxed);
        let next = if occ < target_ppm {
            (cur + cur / 2).max(64)
        } else {
            cur.saturating_sub((cur / 4).max(1))
        };
        let bound = max_wait_us.min(svc.saturating_mul(2));
        self.window_us.store(next.min(bound), Ordering::Relaxed);
    }
}

struct Shared {
    state: Mutex<QState>,
    not_empty: Condvar,
    not_full: Condvar,
    /// Cumulative counters behind one lock, so [`Session::stats`] returns
    /// one *coherent* snapshot (a single struct copy) instead of
    /// field-by-field atomic reads that can interleave with a concurrent
    /// batch completion.  Update sites take the lock once per event and
    /// bump every affected field together.
    stats: Mutex<ServeStats>,
    /// Batch-forming policy state (window + EWMA controller).
    ctl: BatchCtl,
    /// Worker count, for the queue-wait prediction (batches drain
    /// `workers` at a time).
    workers: usize,
    /// [`ServeCfg::slo`] in µs; 0 = no SLO-based shedding.
    slo_us: u64,
}

/// The dispatchable side of a session: a lowered plan (any backend), or
/// an arbitrary host function (tests / mock serving benches run the queue
/// machinery without any runtime at all).
#[derive(Clone)]
enum Dispatch {
    Plan(Arc<CompiledPlan>),
    Fn(Arc<dyn Fn(&Tensor, Option<&Tensor>) -> Result<Tensor> + Send + Sync>),
}

impl Dispatch {
    fn run(&self, x: &Tensor, t: Option<&Tensor>) -> Result<Tensor> {
        match self {
            Dispatch::Plan(cp) => cp.forward(x, t),
            Dispatch::Fn(f) => f(x, t),
        }
    }

    /// Weight format this dispatch executes with.  Plans recorded theirs
    /// at lower time; bare host functions have no lowered operands, so
    /// they report the process-default format.
    fn weight_format(&self) -> WeightFormat {
        match self {
            Dispatch::Plan(cp) => cp.weight_format(),
            Dispatch::Fn(_) => WeightFormat::from_env(),
        }
    }
}

/// A deployed network: `'static`, `Send + Sync`, shareable across client
/// threads.  Dropping (or [`Session::shutdown`]) closes the queue, serves
/// every already-accepted request, and joins the workers.
pub struct Session {
    backend: Dispatch,
    shared: Arc<Shared>,
    pool: par::Pool,
    batch: usize,
    in_tail: Vec<usize>,
    needs_t: bool,
    queue_cap: usize,
    /// Marks this session as a live user of the global compute pool for
    /// the whole session lifetime: `par::shutdown_pool()` fails loudly
    /// while any serving tier is up instead of deadlocking its workers.
    _serving: par::ServingGuard,
}

impl Session {
    /// Serve a lowered plan.  Fails on an empty plan (nothing to dispatch).
    pub fn new(cp: Arc<CompiledPlan>, cfg: ServeCfg) -> Result<Session> {
        let dims = cp
            .input_dims()
            .context("cannot serve an empty plan (no steps)")?;
        let batch = cp.batch();
        let needs_t = cp.task() == Task::Diffusion;
        let backend = Dispatch::Plan(cp);
        Ok(Session::start(backend, batch, dims[1..].to_vec(), needs_t, cfg))
    }

    /// Serve an arbitrary host function with the same queue machinery —
    /// the function receives full `[batch, in_tail..]` tensors and must
    /// return `[batch, ..]` outputs.  Used by the serve test-suite and the
    /// host-only serving bench; also handy for mocking a deployment.
    pub fn from_fn<F>(
        batch: usize,
        in_tail: &[usize],
        needs_t: bool,
        cfg: ServeCfg,
        f: F,
    ) -> Session
    where
        F: Fn(&Tensor, Option<&Tensor>) -> Result<Tensor> + Send + Sync + 'static,
    {
        assert!(batch >= 1, "batch must be positive");
        Session::start(Dispatch::Fn(Arc::new(f)), batch, in_tail.to_vec(), needs_t, cfg)
    }

    fn start(
        backend: Dispatch,
        batch: usize,
        in_tail: Vec<usize>,
        needs_t: bool,
        cfg: ServeCfg,
    ) -> Session {
        let shared = Arc::new(Shared {
            state: Mutex::new(QState {
                items: VecDeque::new(),
                rows_queued: 0,
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            stats: Mutex::new(ServeStats::default()),
            ctl: BatchCtl::new(cfg.policy),
            workers: cfg.workers.max(1),
            slo_us: cfg.slo.map_or(0, |d| d.as_micros() as u64),
        });
        // per-worker warmup input: one throwaway zero forward per worker
        // charges that worker's arena shard (buffers are recycled
        // per-thread), so its first real batch is already allocation-free
        let warm: Option<(Tensor, Option<Tensor>)> = match (&backend, cfg.warmup) {
            (Dispatch::Plan(_), true) => {
                let mut dims = vec![batch];
                dims.extend_from_slice(&in_tail);
                let t = needs_t.then(|| Tensor::full(&[batch], 500.0));
                Some((Tensor::zeros(&dims), t))
            }
            _ => None,
        };
        let (ws, wb) = (Arc::clone(&shared), backend.clone());
        let pool = par::Pool::spawn(cfg.workers, "lm-serve", move |_| {
            if let Some((x, t)) = &warm {
                let _ = wb.run(x, t.as_ref());
            }
            worker_loop(&ws, &wb, batch);
        });
        Session {
            backend,
            shared,
            pool,
            batch,
            in_tail,
            needs_t,
            queue_cap: cfg.queue_cap.max(1),
            _serving: par::serving_guard(),
        }
    }

    /// Spec batch size B — the coalescing target and the `infer` batch dim.
    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn workers(&self) -> usize {
        self.pool.len()
    }

    /// One coherent counter snapshot: a single struct copy under the
    /// stats lock, so no field can reflect a batch completion another
    /// field missed.
    pub fn stats(&self) -> ServeStats {
        let mut s = *plock(&self.shared.stats);
        s.cur_window_us = self.shared.ctl.window_us() as usize;
        s
    }

    /// The batch-forming policy this session was deployed with.
    pub fn policy(&self) -> BatchPolicy {
        self.shared.ctl.policy()
    }

    /// EWMA per-batch service time in µs (0 until the first batch
    /// completes) — the signal admission control predicts queue wait
    /// from.
    pub fn ewma_service_us(&self) -> u64 {
        self.shared.ctl.svc_us()
    }

    /// Requests currently queued (not yet taken by a worker).
    pub fn queue_depth(&self) -> usize {
        plock(&self.shared.state).items.len()
    }

    /// Weight format of the deployed plan (recorded at lower time —
    /// [`crate::exec::CompiledPlan::weight_format`]); surfaced in serve
    /// `/stats` so a running deployment is attributable to its kernel
    /// configuration.
    pub fn weight_format(&self) -> WeightFormat {
        self.backend.weight_format()
    }

    /// Synchronous one-shot inference: full `[B, ..]` input, no queue.
    /// Counts into [`ServeStats`] like any dispatched batch (one request,
    /// one batch, `x.dims[0]` rows, zero padding), so before/after deltas
    /// stay honest under mixed `infer` + `submit` workloads.
    pub fn infer(&self, x: &Tensor, t: Option<&Tensor>) -> Result<Tensor> {
        let started = Instant::now();
        let out = self.backend.run(x, t);
        let mut st = plock(&self.shared.stats);
        st.requests += 1;
        st.batches += 1;
        st.rows += x.dims.first().copied().unwrap_or(0);
        st.service_us += started.elapsed().as_micros() as usize;
        out
    }

    /// Enqueue a sub-batch request of `1..=B` rows (`[rows, in_tail..]`).
    /// Blocks while the queue is at capacity (backpressure); errors once
    /// the session is closed.
    pub fn submit(&self, x: Tensor) -> Result<Ticket> {
        self.submit_with(x, None)
    }

    /// [`Session::submit`] with a per-row timestep tensor `[rows]`
    /// (required iff the deployed plan is a diffusion model).
    pub fn submit_with(&self, x: Tensor, t: Option<Tensor>) -> Result<Ticket> {
        self.submit_deadline(x, t, None).map_err(anyhow::Error::from)
    }

    /// Shape/timestep validation shared by every submit path; failures
    /// are [`ServeError::Rejected`] (the wire maps them to `BadFrame`).
    fn validate(&self, x: &Tensor, t: &Option<Tensor>) -> ServeResult<()> {
        let reject = |m: String| Err(ServeError::Rejected(m));
        if x.dims.is_empty() || x.dims[0] < 1 {
            return reject("request must have a leading batch dim".into());
        }
        let rows = x.dims[0];
        if rows > self.batch {
            return reject(format!(
                "request rows {rows} exceed the deployed batch size {}",
                self.batch
            ));
        }
        if x.dims[1..] != self.in_tail[..] {
            return reject(format!(
                "request dims {:?} don't match the deployed input [b, {:?}]",
                x.dims, self.in_tail
            ));
        }
        match (t, self.needs_t) {
            (None, true) => reject("deployed plan requires a timestep tensor".into()),
            (Some(_), false) => reject("deployed plan takes no timestep tensor".into()),
            (Some(tt), true) if tt.dims != vec![rows] => {
                reject(format!("timestep dims {:?} must be [{rows}]", tt.dims))
            }
            _ => Ok(()),
        }
    }

    /// The typed, deadline-aware enqueue — what the network tier calls.
    ///
    /// Differences from [`Session::submit_with`]:
    ///
    /// * **Admission control.**  If the EWMA per-batch service time has a
    ///   signal, the predicted queue wait (`ceil(queued_rows / B)` batches
    ///   ahead, divided across the workers) is checked against the
    ///   tightest of `deadline - now` and [`ServeCfg::slo`]; requests
    ///   that cannot make it are shed at the door with
    ///   [`ServeError::Shed`] — bounded queue depth, O(1) refusal cost.
    /// * **No blocking for deadlined requests.**  A full queue sheds a
    ///   deadlined request immediately instead of blocking the caller
    ///   into its own deadline; deadline-less requests keep the classic
    ///   blocking backpressure.
    /// * **Deadline propagation.**  The deadline rides into the queue: a
    ///   worker that reaches the request late fails it fast
    ///   ([`ServeError::DeadlineExceeded`], counted in
    ///   `expired_requests`) instead of serving it late.
    pub fn submit_deadline(
        &self,
        x: Tensor,
        t: Option<Tensor>,
        deadline: Option<Instant>,
    ) -> ServeResult<Ticket> {
        self.validate(&x, &t)?;
        let rows = x.dims[0];
        let now = Instant::now();
        if let Some(d) = deadline {
            if now >= d {
                plock(&self.shared.stats).expired_requests += 1;
                return Err(ServeError::DeadlineExceeded);
            }
        }
        let ticket = Arc::new(TicketInner::default());
        {
            let mut g = plock(&self.shared.state);
            loop {
                if g.closed {
                    return Err(ServeError::ShuttingDown);
                }
                if g.items.len() < self.queue_cap {
                    break;
                }
                if deadline.is_some() || self.shared.slo_us > 0 {
                    // a deadlined request must not block into its own
                    // deadline: shed at the door instead
                    plock(&self.shared.stats).shed_requests += 1;
                    return Err(ServeError::Shed {
                        queued_rows: g.rows_queued,
                        predicted_us: u64::MAX,
                        budget_us: self.budget_us(deadline, now),
                    });
                }
                g = pwait(&self.shared.not_full, g);
            }
            // admission control: shed when the predicted wait exceeds the
            // deadline/SLO budget (needs an EWMA signal — the first
            // batches after deploy are always admitted)
            let svc = self.shared.ctl.svc_us();
            let budget_us = self.budget_us(deadline, now);
            if svc > 0 && budget_us < u64::MAX {
                let batches_ahead =
                    ((g.rows_queued + rows + self.batch - 1) / self.batch) as u64;
                let predicted_us = batches_ahead * svc / self.shared.workers as u64;
                if predicted_us > budget_us {
                    plock(&self.shared.stats).shed_requests += 1;
                    return Err(ServeError::Shed {
                        queued_rows: g.rows_queued,
                        predicted_us,
                        budget_us,
                    });
                }
            }
            g.items.push_back(Request {
                x,
                t,
                ticket: Arc::clone(&ticket),
                enqueued: now,
                deadline,
            });
            g.rows_queued += rows;
            let depth = g.items.len();
            let mut st = plock(&self.shared.stats);
            st.max_queue = st.max_queue.max(depth);
        }
        self.shared.not_empty.notify_one();
        Ok(Ticket { inner: ticket })
    }

    /// The admission budget in µs: the tightest of the request deadline
    /// and the configured SLO; `u64::MAX` when neither applies.
    fn budget_us(&self, deadline: Option<Instant>, now: Instant) -> u64 {
        let from_deadline = deadline
            .map(|d| d.saturating_duration_since(now).as_micros() as u64)
            .unwrap_or(u64::MAX);
        let from_slo = if self.shared.slo_us > 0 { self.shared.slo_us } else { u64::MAX };
        from_deadline.min(from_slo)
    }

    /// Stop accepting new requests.  Already-queued requests are still
    /// served; workers exit once the queue drains.
    pub fn close(&self) {
        plock(&self.shared.state).closed = true;
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
    }

    /// Clean shutdown: close, drain, join the workers.
    pub fn shutdown(mut self) {
        self.close();
        self.pool.join();
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        self.close();
        self.pool.join();
    }
}

/// Whether the queue front already forms a dispatch-ready batch: the
/// coalescible prefix either reaches B rows or is blocked by a request
/// that no longer fits (workers take whole requests only).
fn batch_formed(items: &VecDeque<Request>, b: usize) -> bool {
    let mut rows = 0usize;
    for it in items {
        let r = it.x.dims[0];
        if rows + r >= b {
            return true;
        }
        rows += r;
    }
    false
}

/// Whether the queue front's serve-by deadline has already passed (such
/// a request must be failed fast, not held for a batching window).
fn front_expired(items: &VecDeque<Request>, now: Instant) -> bool {
    items
        .front()
        .and_then(|r| r.deadline)
        .is_some_and(|d| now >= d)
}

fn worker_loop(shared: &Shared, backend: &Dispatch, b: usize) {
    loop {
        let mut expired = false;
        let (taken, dead) = {
            let mut g = plock(&shared.state);
            loop {
                if g.items.is_empty() {
                    if g.closed {
                        return;
                    }
                    g = pwait(&shared.not_empty, g);
                    continue;
                }
                let now = Instant::now();
                // close() flushes held partials immediately; a formed
                // batch never waits, and neither does an already-expired
                // front (it needs failing fast, not batching)
                if g.closed || batch_formed(&g.items, b) || front_expired(&g.items, now) {
                    break;
                }
                let window = shared.ctl.window_us();
                if window == 0 {
                    break; // greedy: ship whatever is queued
                }
                // bounded wait, anchored at the oldest queued request —
                // tightened to the front's serve-by deadline so expiry is
                // noticed when it happens, not a window later
                let front = g.items.front().unwrap();
                let mut wake = front.enqueued + Duration::from_micros(window);
                if let Some(d) = front.deadline {
                    wake = wake.min(d);
                }
                if now >= wake {
                    expired = true;
                    break;
                }
                g = pwait_timeout(&shared.not_empty, g, wake - now);
            }
            // coalesce whole requests (submit bounds each to <= b rows),
            // failing past-deadline requests fast instead of batching them
            let now = Instant::now();
            let mut taken: Vec<Request> = Vec::new();
            let mut dead: Vec<Request> = Vec::new();
            let mut rows = 0usize;
            while let Some(front) = g.items.front() {
                let r = front.x.dims[0];
                if front.deadline.is_some_and(|d| now >= d) {
                    g.rows_queued -= r;
                    dead.push(g.items.pop_front().unwrap());
                    continue;
                }
                if rows + r > b {
                    break;
                }
                rows += r;
                g.rows_queued -= r;
                taken.push(g.items.pop_front().unwrap());
                if rows == b {
                    break;
                }
            }
            (taken, dead)
        };
        shared.not_full.notify_all();
        if !dead.is_empty() {
            plock(&shared.stats).expired_requests += dead.len();
            for r in dead {
                fulfill(&r.ticket, Err(ServeError::DeadlineExceeded));
            }
        }
        if !taken.is_empty() {
            run_batch(shared, backend, b, taken, expired);
        }
    }
}

/// Telemetry of one dispatched batch, for the caller's accounting —
/// [`run_batch`] folds it into the session counters; the fleet folds it
/// into the owning tenant's.
pub(crate) struct BatchDone {
    /// Real request rows in the batch (padding excluded).
    pub(crate) rows: usize,
    /// Requests coalesced into the batch.
    pub(crate) requests: usize,
    /// Padding rows appended to reach the batch size.
    pub(crate) padded: usize,
    /// Summed submit-to-dispatch wait across the batch's requests, µs.
    pub(crate) queue_wait_us: usize,
    /// Dispatch (service) time, µs.
    pub(crate) svc_us: u64,
    /// Whether the dispatch failed (every ticket got `BackendFailed`).
    pub(crate) failed: bool,
    /// Whether the failure was a caught panic (subset of `failed`).
    pub(crate) panicked: bool,
}

/// Session wrapper over [`dispatch_batch`]: dispatch, then fold the
/// telemetry into the session counters (one coherent lock acquisition)
/// and step the policy controller.
fn run_batch(shared: &Shared, backend: &Dispatch, b: usize, reqs: Vec<Request>, expired: bool) {
    let done = dispatch_batch(backend, b, reqs);
    {
        let mut st = plock(&shared.stats);
        st.batches += 1;
        st.padded_rows += done.padded;
        st.requests += done.requests;
        st.rows += done.rows;
        st.expired_windows += usize::from(expired);
        st.queue_wait_us += done.queue_wait_us;
        st.service_us += done.svc_us as usize;
        st.failed_batches += usize::from(done.failed);
        st.panicked_batches += usize::from(done.panicked);
    }
    shared.ctl.note_batch(b, done.rows, done.svc_us);
}

/// Coalesce `reqs` (whole requests, ≤ `b` rows total) into one padded
/// `[b, tail..]` dispatch, run it with panic isolation, and split the
/// output rows back onto the tickets.  Pure of any session/fleet state —
/// both tiers drive their queues through it and do their own accounting
/// from the returned [`BatchDone`].
pub(crate) fn dispatch_batch(backend: &Dispatch, b: usize, reqs: Vec<Request>) -> BatchDone {
    let total_rows: usize = reqs.iter().map(|r| r.x.dims[0]).sum();
    let started = Instant::now();
    let queue_wait_us: u128 = reqs
        .iter()
        .map(|r| started.saturating_duration_since(r.enqueued).as_micros())
        .sum();
    // a panicking backend must not strand the batch's tickets (waiters
    // would block forever and the worker thread would die silently) —
    // unwind is converted into a per-ticket error instead
    let dispatch = || {
        if reqs.len() == 1 && total_rows == b {
            // full-batch request: dispatch as-is, zero copies
            backend.run(&reqs[0].x, reqs[0].t.as_ref())
        } else {
            let in_tail = &reqs[0].x.dims[1..];
            let row_len: usize = in_tail.iter().product();
            let mut data = vec![0.0f32; b * row_len];
            let mut off = 0usize;
            for r in &reqs {
                data[off..off + r.x.data.len()].copy_from_slice(&r.x.data);
                off += r.x.data.len();
            }
            let mut dims = vec![b];
            dims.extend_from_slice(in_tail);
            let xb = Tensor::new(dims, data);
            let tb = match reqs[0].t {
                Some(_) => {
                    let mut td = vec![0.0f32; b];
                    let mut o = 0usize;
                    for r in &reqs {
                        let tt =
                            r.t.as_ref().expect("submit enforces uniform t presence");
                        td[o..o + tt.data.len()].copy_from_slice(&tt.data);
                        o += tt.data.len();
                    }
                    Some(Tensor::new(vec![b], td))
                }
                None => None,
            };
            backend.run(&xb, tb.as_ref())
        }
    };
    let mut panicked = false;
    let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(dispatch))
        .unwrap_or_else(|p| {
            panicked = true;
            let msg = p
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| p.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "opaque panic payload".to_string());
            Err(anyhow::anyhow!("serve backend panicked: {msg}"))
        });
    let svc_us = started.elapsed().as_micros();
    let mut done = BatchDone {
        rows: total_rows,
        requests: reqs.len(),
        padded: b - total_rows,
        queue_wait_us: queue_wait_us as usize,
        svc_us: svc_us as u64,
        failed: false,
        panicked,
    };
    match out {
        Ok(y) if y.dims.first() == Some(&b) && y.data.len() % b == 0 => {
            if reqs.len() == 1 && total_rows == b {
                // full-batch request: move the output straight to its ticket
                let r = reqs.into_iter().next().unwrap();
                fulfill(&r.ticket, Ok(y));
                return done;
            }
            let out_row = y.data.len() / b;
            let out_tail = y.dims[1..].to_vec();
            let mut off = 0usize;
            for r in reqs {
                let rows = r.x.dims[0];
                let mut dims = vec![rows];
                dims.extend_from_slice(&out_tail);
                let part =
                    Tensor::new(dims, y.data[off..off + rows * out_row].to_vec());
                off += rows * out_row;
                fulfill(&r.ticket, Ok(part));
            }
        }
        Ok(y) => {
            // a batch is poisoned exactly once per failure: flagged here,
            // and every ticket of THIS batch (only) carries the error
            done.failed = true;
            let msg = format!(
                "serve batch produced dims {:?}, expected leading batch {b}",
                y.dims
            );
            for r in reqs {
                fulfill(&r.ticket, Err(ServeError::BackendFailed(msg.clone())));
            }
        }
        Err(e) => {
            done.failed = true;
            let msg = format!("serve batch failed: {e}");
            for r in reqs {
                fulfill(&r.ticket, Err(ServeError::BackendFailed(msg.clone())));
            }
        }
    }
    done
}

// ---------------------------------------------------------------------------
// Concurrent-client load driver
// ---------------------------------------------------------------------------

/// One load run against a session: client-perceived latency percentiles
/// **of successful requests** (queue wait included, nearest-rank via
/// [`crate::util::stats::percentile`]) and throughput, plus coalescing,
/// window, and failure-separation telemetry.  Produced by the closed-loop
/// [`drive`] and the open-loop [`drive_open`]/[`drive_open_deadline`].
///
/// Shed/expired/failed completions are **never** folded into the latency
/// percentiles — an overload run reports the latency of what it actually
/// served next to how much it refused, not a blend of the two.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Concurrent closed-loop submitters (1 for an open-loop run — a
    /// single generator thread owns the arrival process).
    pub clients: usize,
    /// Total completions: `ok_requests + shed + expired + failed`.
    pub requests: usize,
    /// Requests that returned a tensor; the latency percentiles cover
    /// exactly these.
    pub ok_requests: usize,
    /// Refused at admission ([`ServeError::Shed`]).
    pub shed: usize,
    /// Failed fast on a passed deadline ([`ServeError::DeadlineExceeded`]).
    pub expired: usize,
    /// Backend/other failures (including bounded-wait timeouts in the
    /// open-loop driver).
    pub failed: usize,
    /// Offered rows (submitted, whether or not they were served).
    pub rows: usize,
    /// Percentiles over successful requests only; `NaN` when none
    /// succeeded (the percentile helper is never handed an empty set).
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub min_ms: f64,
    pub wall_s: f64,
    /// Offered-row throughput over the wall clock.
    pub rows_per_s: f64,
    /// Successful requests per second — the goodput an overload run is
    /// judged by.
    pub goodput_rps: f64,
    pub batches: usize,
    pub padded_rows: usize,
    /// Mean per-request queue wait (submit to dispatch), ms.
    pub queue_ms: f64,
    /// Mean per-batch dispatch (service) time, ms.
    pub service_ms: f64,
    /// Real-row fraction of dispatched batches over this run.
    pub occupancy: f64,
    /// Partial batches dispatched on window expiry over this run.
    pub expired_windows: usize,
    /// Target arrival rate of an open-loop run; 0.0 for closed loop.
    pub arrival_rps: f64,
}

impl LoadReport {
    pub fn row(&self, name: &str) -> String {
        let load = if self.arrival_rps > 0.0 {
            format!("{:>6.0} rps", self.arrival_rps)
        } else {
            format!("{:>3} clients", self.clients)
        };
        let errs = if self.shed + self.expired + self.failed > 0 {
            format!(
                "  [ok {} shed {} exp {} fail {}]",
                self.ok_requests, self.shed, self.expired, self.failed
            )
        } else {
            String::new()
        };
        format!(
            "{name:<26} {load}  p50 {:>8.2}ms  p95 {:>8.2}ms  {:>9.1} rows/s  \
             {:>4} batches ({} padded, occ {:>4.2}, q {:>6.2}ms + svc {:>6.2}ms){errs}",
            self.p50_ms,
            self.p95_ms,
            self.rows_per_s,
            self.batches,
            self.padded_rows,
            self.occupancy,
            self.queue_ms,
            self.service_ms,
        )
    }

    /// Mean padded rows per dispatched batch — the padding waste the
    /// window policies exist to reduce.
    pub fn padded_per_batch(&self) -> f64 {
        self.padded_rows as f64 / self.batches.max(1) as f64
    }

    /// Fraction of completions refused at admission.
    pub fn shed_rate(&self) -> f64 {
        self.shed as f64 / self.requests.max(1) as f64
    }
}

/// Per-run failure tallies, classified from typed [`ServeError`]s (or,
/// for the network driver, from wire [`proto::ErrCode`]s).
#[derive(Debug, Default, Clone, Copy)]
pub(crate) struct Outcomes {
    pub(crate) shed: usize,
    pub(crate) expired: usize,
    pub(crate) failed: usize,
}

impl Outcomes {
    pub(crate) fn note(&mut self, e: &ServeError) {
        match e {
            ServeError::Shed { .. } => self.shed += 1,
            ServeError::DeadlineExceeded => self.expired += 1,
            _ => self.failed += 1,
        }
    }

    /// Classify a wire-level error code — the network driver sees typed
    /// codes, not `ServeError` values, but must tally identically.
    pub(crate) fn note_code(&mut self, c: proto::ErrCode) {
        match c {
            proto::ErrCode::Shed => self.shed += 1,
            proto::ErrCode::DeadlineExceeded => self.expired += 1,
            _ => self.failed += 1,
        }
    }

    pub(crate) fn total(&self) -> usize {
        self.shed + self.expired + self.failed
    }
}

impl LoadReport {
    /// Assemble a [`LoadReport`] from raw per-request success latencies,
    /// the classified failure tallies, and the engine-counter delta over
    /// the run — shared by [`drive`], [`drive_open_deadline`],
    /// [`net::drive_net`], and the fleet driver so every report computes
    /// its quantiles and telemetry identically instead of each load mode
    /// growing its own copy.
    pub(crate) fn from_outcomes(
        mut lat: Vec<f64>,
        out: Outcomes,
        rows: usize,
        wall_s: f64,
        before: ServeStats,
        after: ServeStats,
        clients: usize,
        arrival_rps: f64,
    ) -> Result<LoadReport> {
        use crate::util::stats::{percentile, sort_samples};
        anyhow::ensure!(
            !lat.is_empty() || out.total() > 0,
            "drive: no requests completed"
        );
        sort_samples(&mut lat);
        let d = after - before;
        // percentiles cover successes only — never hand percentile() an
        // empty set; an all-failure run reports NaN, not a fabricated
        // number
        let (p50, p95, p99, mean, min) = if lat.is_empty() {
            (f64::NAN, f64::NAN, f64::NAN, f64::NAN, f64::NAN)
        } else {
            (
                percentile(&lat, 0.5),
                percentile(&lat, 0.95),
                percentile(&lat, 0.99),
                lat.iter().sum::<f64>() / lat.len() as f64,
                lat[0],
            )
        };
        Ok(LoadReport {
            clients,
            requests: lat.len() + out.total(),
            ok_requests: lat.len(),
            shed: out.shed,
            expired: out.expired,
            failed: out.failed,
            rows,
            p50_ms: p50,
            p95_ms: p95,
            p99_ms: p99,
            mean_ms: mean,
            min_ms: min,
            wall_s,
            rows_per_s: rows as f64 / wall_s.max(1e-9),
            goodput_rps: lat.len() as f64 / wall_s.max(1e-9),
            batches: d.batches,
            padded_rows: d.padded_rows,
            queue_ms: d.queue_wait_us as f64 / 1e3 / d.requests.max(1) as f64,
            service_ms: d.service_us as f64 / 1e3 / d.batches.max(1) as f64,
            occupancy: occupancy_of(d.rows, d.padded_rows),
            expired_windows: d.expired_windows,
            arrival_rps,
        })
    }
}

/// Drive `clients` concurrent submitters, each issuing
/// `requests_per_client` requests produced by `make_input(client, i)`.
/// Every ticket is awaited by its submitter (closed-loop load: offered
/// load self-throttles to service speed, so the queue never grows beyond
/// the client count).  Typed failures (shed under an SLO'd session,
/// backend errors) are tallied per category instead of aborting the run;
/// the call errors only if *nothing* completed.
pub fn drive<F>(
    session: &Session,
    clients: usize,
    requests_per_client: usize,
    make_input: F,
) -> Result<LoadReport>
where
    F: Fn(usize, usize) -> (Tensor, Option<Tensor>) + Sync,
{
    let before = session.stats();
    let lat = Mutex::new(Vec::with_capacity(clients * requests_per_client));
    let out = Mutex::new(Outcomes::default());
    let rows = AtomicUsize::new(0);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let (lat, out, rows, make_input) = (&lat, &out, &rows, &make_input);
            s.spawn(move || {
                for i in 0..requests_per_client {
                    let (x, t) = make_input(c, i);
                    rows.fetch_add(x.dims[0], Ordering::Relaxed);
                    let tq = Instant::now();
                    let res = session
                        .submit_deadline(x, t, None)
                        .and_then(Ticket::wait_coded);
                    match res {
                        Ok(_) => {
                            plock(lat).push(tq.elapsed().as_secs_f64() * 1e3)
                        }
                        Err(e) => plock(out).note(&e),
                    }
                }
            });
        }
    });
    let wall_s = t0.elapsed().as_secs_f64();
    let lat = punwrap(lat);
    let out = punwrap(out);
    let rows = rows.load(Ordering::Relaxed);
    LoadReport::from_outcomes(lat, out, rows, wall_s, before, session.stats(), clients, 0.0)
}

/// Hard cap on how long the open-loop driver waits for any single ticket
/// — a wedged batch turns into a counted failure, never a hung driver.
const OPEN_LOOP_WAIT_CAP: Duration = Duration::from_secs(30);

/// Open-loop load: submit `requests` requests on a deterministic
/// Poisson-ish arrival schedule at `rps` requests/second (exponential
/// inter-arrival gaps from the seeded [`crate::util::rng::Rng`]), without
/// waiting for completions in between.  Unlike the closed loop, arrivals
/// do not self-throttle to service speed, so this is the mode that
/// exposes the padding/latency tradeoff of the batching window policies —
/// and, with a deadline, the shed/serve split under overload.
///
/// Per-request latency is completion-to-arrival (queue wait included;
/// the completion instant is captured at fulfillment, so awaiting the
/// tickets after the generation loop costs nothing).  If the bounded
/// queue fills, `submit` blocks the generator — the backpressure shows up
/// as schedule lag and in the latency numbers, exactly as a real bounded
/// ingress buffer would (deadlined requests are shed instead of
/// blocking).  Every ticket wait is bounded by [`OPEN_LOOP_WAIT_CAP`] via
/// `Ticket::wait_done_timeout`, so a wedged batch becomes a counted
/// failure rather than a hung driver.
pub fn drive_open<F>(
    session: &Session,
    rps: f64,
    requests: usize,
    seed: u64,
    make_input: F,
) -> Result<LoadReport>
where
    F: Fn(usize, usize) -> (Tensor, Option<Tensor>),
{
    drive_open_deadline(session, rps, requests, seed, None, make_input)
}

/// [`drive_open`] with a per-request deadline: each arrival is submitted
/// with `deadline = arrival + d`, so admission control and queue expiry
/// engage exactly as they would for network clients.  Shed, expired, and
/// failed completions are tallied separately from the success latencies.
pub fn drive_open_deadline<F>(
    session: &Session,
    rps: f64,
    requests: usize,
    seed: u64,
    deadline: Option<Duration>,
    make_input: F,
) -> Result<LoadReport>
where
    F: Fn(usize, usize) -> (Tensor, Option<Tensor>),
{
    anyhow::ensure!(rps > 0.0, "drive_open: arrival rate must be positive");
    let before = session.stats();
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut pending = Vec::with_capacity(requests);
    let mut out = Outcomes::default();
    let mut rows = 0usize;
    let mut sched_s = 0.0f64;
    let t0 = Instant::now();
    for i in 0..requests {
        // exponential gap; 1 - U in (0, 1] keeps ln() finite
        sched_s += -(1.0 - rng.uniform()).ln() / rps;
        let target = t0 + Duration::from_secs_f64(sched_s);
        if let Some(d) = target.checked_duration_since(Instant::now()) {
            std::thread::sleep(d);
        }
        let (x, t) = make_input(0, i);
        rows += x.dims[0];
        let arrival = Instant::now();
        match session.submit_deadline(x, t, deadline.map(|d| arrival + d)) {
            Ok(ticket) => pending.push((ticket, arrival)),
            Err(e) => out.note(&e),
        }
    }
    let mut lat = Vec::with_capacity(pending.len());
    for (ticket, arrival) in pending {
        match ticket.wait_done_timeout(OPEN_LOOP_WAIT_CAP) {
            Ok((Ok(_), done)) => {
                lat.push(done.saturating_duration_since(arrival).as_secs_f64() * 1e3)
            }
            Ok((Err(e), _)) => out.note(&e),
            // bounded wait expired: the batch is wedged — count it, move on
            Err(_stale) => out.failed += 1,
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();
    LoadReport::from_outcomes(lat, out, rows, wall_s, before, session.stats(), 1, rps)
}

/// Slice the classify eval stream into single-row `(x, y)` request pairs
/// (`x: [1,h,w,c]`, `y: [1,classes]`) — the "many small clients" workload
/// the serving CLI and example drive against a [`Session`].  Returns an
/// empty pool for non-classify models.
pub fn classify_request_pool(gen: &crate::train::Gen, batches: usize) -> Vec<(Tensor, Tensor)> {
    let mut pool = Vec::new();
    for bi in 0..batches {
        let batch = gen.batch(crate::train::STREAM_EVAL, bi as u64);
        if let crate::model::Batch::Classify { x, y } = batch {
            let b = x.dims[0];
            let xl: usize = x.dims[1..].iter().product();
            let yl: usize = y.dims[1..].iter().product();
            for r in 0..b {
                let mut xd = vec![1];
                xd.extend_from_slice(&x.dims[1..]);
                let mut yd = vec![1];
                yd.extend_from_slice(&y.dims[1..]);
                pool.push((
                    Tensor::new(xd, x.data[r * xl..(r + 1) * xl].to_vec()),
                    Tensor::new(yd, y.data[r * yl..(r + 1) * yl].to_vec()),
                ));
            }
        }
    }
    pool
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handles_are_send_sync_and_static() {
        fn check<T: Send + Sync + 'static>() {}
        check::<Engine>();
        check::<Session>();
        check::<Ticket>();
    }

    #[test]
    fn serve_cfg_default_is_sane() {
        let c = ServeCfg::default();
        assert!(c.workers >= 1 && c.queue_cap >= 1);
        assert_eq!(c.policy, BatchPolicy::Greedy);
        assert_eq!(c.policy.initial_window_us(), 0);
    }

    #[test]
    fn policy_initial_windows() {
        assert_eq!(BatchPolicy::Window { max_wait_us: 500 }.initial_window_us(), 500);
        let a = BatchPolicy::Adaptive { target_occupancy: 0.8, max_wait_us: 500 };
        assert_eq!(a.initial_window_us(), 250);
    }

    #[test]
    fn occupancy_derivation() {
        let mut s = ServeStats::default();
        assert_eq!(s.occupancy(), 1.0);
        s.rows = 6;
        s.padded_rows = 2;
        assert!((s.occupancy() - 0.75).abs() < 1e-12);
    }
}
